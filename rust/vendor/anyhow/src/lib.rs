//! Offline shim of the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The build environment is hermetic (no crates.io), so this path
//! dependency re-implements exactly the subset of anyhow's surface that
//! rishmem uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/
//! [`ensure!`] macros, and the [`Context`] extension trait. Semantics
//! follow the real crate: `Error` is a cause chain, `{:#}` renders
//! `msg: cause: cause`, and — like real anyhow — `Error` deliberately does
//! *not* implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// A dynamically typed error with a message chain.
pub struct Error {
    /// Outermost message first; each following entry is a cause.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (innermost becomes a cause).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_chains_and_alternate_renders() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn ensure_and_bail_forms() {
        fn check(x: usize) -> Result<usize> {
            ensure!(x > 0);
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert!(check(3).is_ok());
        assert!(format!("{}", check(0).unwrap_err()).contains("condition failed"));
        assert!(format!("{}", check(12).unwrap_err()).contains("too big"));
        assert!(format!("{}", check(5).unwrap_err()).contains("five"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
    }
}
