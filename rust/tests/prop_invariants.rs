//! Property tests over coordinator invariants: routing (locality
//! classification), symmetric-heap symmetry, cutover monotonicity,
//! work-group partitioning, team algebra, and RMA roundtrips with random
//! shapes — driven by the deterministic prop harness (seeds printed on
//! failure).

use rishmem::coordinator::metrics::Metrics;
use rishmem::ishmem::cutover::{CutoverConfig, Path};
use rishmem::ishmem::heap::SymAllocator;
use rishmem::ringbuf::{BatchDescriptor, RingOp, CHUNK_FIELD_MAX, DESC_SIZE};
use rishmem::sim::cost::{CostModel, CostParams};
use rishmem::util::prop::prop_check;
use rishmem::util::rng::Rng;
use rishmem::xfer::{AdaptiveTable, BucketKey, OpKind, Route, XferEngine};
use rishmem::{run_npes, run_spmd, IshmemConfig, Locality, ReduceOp, TeamId, Topology};

/// Every `RingOp`, including the batched-submission doorbell and the
/// batch-only `WaitSignal` trigger gate (ISSUE 10).
const ALL_RING_OPS: [RingOp; 11] = [
    RingOp::Nop,
    RingOp::Put,
    RingOp::Get,
    RingOp::PutInline,
    RingOp::Amo,
    RingOp::Quiet,
    RingOp::PutSignal,
    RingOp::Barrier,
    RingOp::Batch,
    RingOp::Shutdown,
    RingOp::WaitSignal,
];

#[test]
fn prop_ring_op_codec_exhaustive() {
    // Exhaustive over the whole byte domain: every encodable op value
    // decodes back to itself, every other value is rejected — so a codec
    // drift (added op, renumbered op) can never silently mis-dispatch.
    for v in 0..=255u8 {
        match ALL_RING_OPS.iter().find(|&&op| op as u8 == v) {
            Some(&op) => assert_eq!(RingOp::from_u8(v), Some(op), "op byte {v}"),
            None => assert_eq!(RingOp::from_u8(v), None, "op byte {v} must be rejected"),
        }
    }
}

#[test]
fn prop_batch_descriptor_roundtrip() {
    prop_check("batch descriptors round-trip through the slab codec", 200, |rng| {
        let n = rng.range(1, 32) as usize;
        let descs: Vec<BatchDescriptor> = (0..n)
            .map(|_| BatchDescriptor {
                // Any RingOp byte is encodable (the stream only emits
                // Put/Get/PutInline/Amo, but the codec must not care).
                op: ALL_RING_OPS[rng.below(ALL_RING_OPS.len() as u64) as usize] as u8,
                dtype: rng.below(256) as u8,
                flags: rng.below(1 << 16) as u16,
                pe: rng.next_u64() as u32,
                dst_off: rng.next_u64(),
                src_off: rng.next_u64(),
                len: rng.next_u64(),
                inline_val: rng.next_u64(),
                inline_val2: rng.next_u64(),
            })
            .collect();
        for d in &descs {
            assert_eq!(BatchDescriptor::from_bytes(&d.to_bytes()), Some(*d));
        }
        let block = BatchDescriptor::encode_block(&descs);
        assert_eq!(block.len(), n * DESC_SIZE);
        assert_eq!(BatchDescriptor::decode_block(&block, n), Some(descs));
        // A corrupt op byte poisons exactly its block decode.
        let mut bad = block.clone();
        let victim = rng.below(n as u64) as usize;
        bad[victim * DESC_SIZE] = 99;
        assert_eq!(BatchDescriptor::decode_block(&bad, n), None);
    });
}

#[test]
fn prop_chunk_continuation_fields_roundtrip() {
    // The striped pipeline's continuation fields (chunk id, chunk count,
    // engine hint) pack into the descriptor without disturbing the wire
    // codec, and ids stay monotone in the order the executor assigns them.
    prop_check("chunk fields pack, roundtrip, and stay monotone", 200, |rng| {
        let count = rng.range(1, CHUNK_FIELD_MAX as u64) as u32;
        let engine = rng.below(256) as u8;
        let probe = rng.below(count as u64) as u32;
        let d = BatchDescriptor::put(1, 64, 128, 4096).with_chunk(probe, count, engine);
        assert!(d.is_chunked());
        assert_eq!(
            (d.chunk_index(), d.chunk_count(), d.engine_hint()),
            (probe, count, engine as usize)
        );
        assert_eq!(BatchDescriptor::from_bytes(&d.to_bytes()), Some(d));
        // Ids assigned 0..n in issue order decode back monotone per stripe.
        let n = rng.range(2, 32) as u32;
        let width = rng.range(1, 8) as u32;
        let descs: Vec<BatchDescriptor> = (0..n)
            .map(|i| {
                BatchDescriptor::put(0, (i as usize) * 4096, 0, 4096).with_chunk(
                    i,
                    n,
                    (i % width) as u8,
                )
            })
            .collect();
        for lane in 0..width as usize {
            let ids: Vec<u32> = descs
                .iter()
                .filter(|d| d.engine_hint() == lane)
                .map(|d| d.chunk_index())
                .collect();
            assert!(ids.windows(2).all(|w| w[0] < w[1]), "lane {lane}: {ids:?}");
        }
    });
}

#[test]
fn prop_chunked_transfers_reassemble_exactly() {
    // Arbitrary payload sizes — crossing the chunk-min, stripe and slab
    // boundaries — must reassemble exactly through the striped pipeline
    // (put) and the windowed chunked get.
    prop_check("chunk split/reassembly is exact", 10, |rng| {
        let len = rng.range(1, 6 << 20) as usize;
        let seed = rng.next_u64();
        let cfg = IshmemConfig {
            topology: Topology::new(1, 2, 2),
            heap_bytes: 48 << 20,
            cutover: CutoverConfig::always(), // pin the engine route
            ..Default::default()
        };
        let ok = run_spmd(cfg, false, move |ctx| {
            let buf = ctx.calloc::<u8>(len);
            let mut payload = vec![0u8; len];
            Rng::new(seed ^ ctx.pe() as u64).fill_bytes(&mut payload);
            let t = (ctx.pe() + 1) % ctx.npes();
            ctx.put(buf, &payload, t);
            ctx.barrier_all();
            let mut back = vec![0u8; len];
            ctx.get(&mut back, buf, t);
            back == payload
        })
        .unwrap();
        assert!(ok.iter().all(|&b| b), "chunked roundtrip corrupted {len}B");
    });
}

#[test]
fn prop_ramped_chunk_layout_is_exact_and_monotone() {
    // The ramped chunk geometry (smaller leading fills, then the planned
    // chunk size) must cover the payload contiguously with monotone ids,
    // for any (bytes, chunk, ramp) combination — and degenerate to the
    // uniform slicing when ramping is off.
    prop_check("ramped chunk layout covers bytes exactly", 300, |rng| {
        let bytes = rng.range(1, 16 << 20) as usize;
        // Keep the layout bounded (~1k entries) while still crossing the
        // one-chunk / many-chunk and ramp boundaries.
        let chunk = rng.range((bytes as u64 / 1024).max(1), bytes as u64) as usize;
        let ramp_len = rng.range(1, chunk as u64) as usize;
        let ramp_chunks = rng.range(0, 4) as usize;
        let layout = rishmem::xfer::exec::chunk_layout(bytes, chunk, ramp_len, ramp_chunks);
        assert!(!layout.is_empty());
        let mut expect_off = 0usize;
        for (i, &(idx, off, len)) in layout.iter().enumerate() {
            assert_eq!(idx, i, "ids must be monotone from 0");
            assert_eq!(off, expect_off, "chunks must be contiguous");
            assert!(len >= 1);
            let full = if idx < ramp_chunks { ramp_len } else { chunk };
            assert!(len <= full, "chunk {idx} overshoots its fill: {len} > {full}");
            expect_off += len;
        }
        assert_eq!(expect_off, bytes, "layout must cover the payload exactly");
        // The O(1) count the charge model uses matches the real layout.
        assert_eq!(
            rishmem::xfer::exec::chunk_layout_len(bytes, chunk, ramp_len, ramp_chunks),
            layout.len(),
            "chunk_layout_len drifted from chunk_layout"
        );
        // Ramp off (or ramp_len == chunk) reproduces the uniform slicing.
        let uniform = rishmem::xfer::exec::chunk_layout(bytes, chunk, chunk, ramp_chunks);
        assert_eq!(uniform.len(), bytes.div_ceil(chunk));
    });
}

#[test]
fn prop_rail_chunked_remote_transfers_reassemble_exactly() {
    // Arbitrary payload sizes through the *rail* stripe pipeline —
    // crossing the rail chunk-min, rail width, and slab boundaries, with
    // ramped first chunks enabled — must reassemble exactly on the remote
    // node: blocking put, windowed chunked get, and NBI put + quiet.
    prop_check("rail chunk split/reassembly is exact", 6, |rng| {
        let len = rng.range(1, 5 << 20) as usize;
        let seed = rng.next_u64();
        let mut cost = CostParams::default();
        cost.nic.rails = 4;
        cost.stripe.ramp_factor = 0.5;
        let cfg = IshmemConfig {
            topology: Topology::new(2, 2, 2),
            heap_bytes: 48 << 20,
            cost,
            ..Default::default()
        };
        let ok = run_spmd(cfg, false, move |ctx| {
            let buf = ctx.calloc::<u8>(len);
            let mut payload = vec![0u8; len];
            Rng::new(seed ^ ctx.pe() as u64).fill_bytes(&mut payload);
            // Cross-node partner: PE i on node 0 ↔ PE i on node 1.
            let half = ctx.npes() / 2;
            let t = (ctx.pe() + half) % ctx.npes();
            ctx.put(buf, &payload, t);
            ctx.barrier_all();
            let mut back = vec![0u8; len];
            ctx.get(&mut back, buf, t);
            let blocking_ok = back == payload;
            ctx.barrier_all();
            // NBI flavour: delivery proven by quiet, then verified by the
            // target itself after the barrier.
            let mut nbi_payload = payload.clone();
            nbi_payload.rotate_left(len / 2);
            ctx.put_nbi(buf, &nbi_payload, t);
            ctx.quiet();
            ctx.barrier_all();
            let mut mine = vec![0u8; len];
            ctx.read_local(buf, &mut mine);
            let mut expect = vec![0u8; len];
            let src = (ctx.pe() + ctx.npes() - half) % ctx.npes();
            Rng::new(seed ^ src as u64).fill_bytes(&mut expect);
            expect.rotate_left(len / 2);
            blocking_ok && mine == expect
        })
        .unwrap();
        assert!(ok.iter().all(|&b| b), "rail chunked roundtrip corrupted {len}B");
    });
}

#[test]
fn prop_poisoned_adaptive_seed_recovers_with_exploration() {
    // ε-exploration keeps the losing path's EMA fresh, so a cell seeded
    // with a wildly wrong estimate converges back to the truly cheaper
    // path — while a greedy table stays stuck forever.
    prop_check("poisoned seed converges under ε-exploration", 20, |rng| {
        let alpha = 0.2 + 0.6 * rng.f64();
        let (true_ls, true_ce) = (100.0, 250.0);
        let key = BucketKey::p2p(Locality::SameNode, 1usize << rng.range(6, 20), 1);

        let observe_truth = |t: &AdaptiveTable| {
            let p = t.decide(key, true_ls, true_ce, 0); // re-seeding never resets
            let obs = match p {
                Path::LoadStore => true_ls,
                Path::CopyEngine => true_ce,
            };
            t.observe(key, p, obs, 0);
        };

        let explored = AdaptiveTable::new(alpha).with_exploration(0.15);
        // Poison: the cell believes load/store is catastrophically slow.
        explored.decide(key, 50_000.0, true_ce, 0);
        assert_eq!(explored.peek(key), Some(Path::CopyEngine));
        for _ in 0..500 {
            observe_truth(&explored);
        }
        assert_eq!(
            explored.peek(key),
            Some(Path::LoadStore),
            "poisoned cell never recovered (alpha {alpha})"
        );

        // Control: without exploration the losing path is never retried.
        let greedy = AdaptiveTable::new(alpha);
        greedy.decide(key, 50_000.0, true_ce, 0);
        for _ in 0..500 {
            observe_truth(&greedy);
        }
        assert_eq!(greedy.peek(key), Some(Path::CopyEngine), "greedy table escaped?");
    });
}

#[test]
fn prop_locality_classification_consistent() {
    prop_check("locality is symmetric and node-consistent", 200, |rng| {
        let nodes = rng.range(1, 3) as usize;
        let gpus = rng.range(1, 8) as usize;
        let tiles = rng.range(1, 2) as usize;
        let t = Topology::new(nodes, gpus, tiles);
        let a = rng.below(t.npes() as u64) as usize;
        let b = rng.below(t.npes() as u64) as usize;
        let ab = t.classify(a, b);
        let ba = t.classify(b, a);
        assert_eq!(ab, ba, "locality must be symmetric");
        match ab {
            Locality::Remote => assert_ne!(t.node_of(a), t.node_of(b)),
            Locality::SameNode => {
                assert_eq!(t.node_of(a), t.node_of(b));
                assert_ne!(t.gpu_of(a), t.gpu_of(b));
            }
            Locality::SameGpu => {
                assert_eq!(t.global_gpu_of(a), t.global_gpu_of(b));
                assert_ne!(t.tile_of(a), t.tile_of(b));
            }
            Locality::SameTile => assert_eq!(a, b),
        }
    });
}

#[test]
fn prop_symmetric_allocators_never_diverge() {
    prop_check("mirrored allocation sequences agree", 100, |rng| {
        let heap = 1 << 22;
        let mut mirrors: Vec<SymAllocator> = (0..4).map(|_| SymAllocator::new(heap)).collect();
        for _ in 0..rng.range(1, 30) {
            let n = rng.range(1, 2000) as usize;
            let offs: Vec<usize> = mirrors
                .iter_mut()
                .map(|a| match n % 3 {
                    0 => a.alloc::<u8>(n).byte_offset(),
                    1 => a.alloc::<f32>(n).byte_offset(),
                    _ => a.alloc::<u64>(n).byte_offset(),
                })
                .collect();
            assert!(offs.windows(2).all(|w| w[0] == w[1]), "{offs:?}");
        }
    });
}

#[test]
fn prop_cutover_monotone_in_size() {
    // Once the tuned policy picks the engine at size S, it must also pick
    // it for every larger size (same locality/work-group).
    prop_check("cutover is monotone in message size", 100, |rng| {
        let cost = CostModel::new(Topology::default(), CostParams::default());
        let cfg = CutoverConfig::tuned();
        let items = 1usize << rng.range(0, 10);
        let loc = *[Locality::SameTile, Locality::SameGpu, Locality::SameNode]
            .iter()
            .nth(rng.below(3) as usize)
            .unwrap();
        let mut engine_seen = false;
        for p in 3..26 {
            match cfg.decide(&cost, loc, 1usize << p, items) {
                Path::CopyEngine => engine_seen = true,
                Path::LoadStore => {
                    assert!(!engine_seen, "flip-flop at 2^{p} items={items} {loc:?}")
                }
            }
        }
    });
}

/// Probe grid shared by the planner properties: every locality × sizes
/// 8 B..16 MB × work-item buckets — the axes of paper Figs 4–6.
fn planner_probe_grid() -> Vec<(Locality, usize, usize)> {
    let mut grid = Vec::new();
    for loc in [Locality::SameTile, Locality::SameGpu, Locality::SameNode] {
        for p in 3..=24usize {
            for items in [1usize, 16, 128, 1024] {
                grid.push((loc, 1usize << p, items));
            }
        }
    }
    grid
}

#[test]
fn prop_planner_tuned_picks_argmin_of_modeled_paths() {
    // For every mode=Tuned probe point, the planner must choose the path
    // whose modeled cost is the smaller of the two, and carry both costs
    // on the plan (modeled_ns = chosen, alt_ns = rejected).
    let cost = CostModel::new(Topology::default(), CostParams::default());
    let engine = XferEngine::new(cost, CutoverConfig::tuned(), true, Metrics::new());
    for (loc, bytes, items) in planner_probe_grid() {
        let plan = engine.plan_p2p(OpKind::Put, true, loc, bytes, items);
        let alt = plan.alt_ns.expect("reachable plan keeps the alternative");
        assert!(
            plan.modeled_ns <= alt,
            "{loc:?}/{bytes}B/{items}wi: chosen {} !<= rejected {alt}",
            plan.modeled_ns
        );
        let ls = engine.est_loadstore_ns(loc, bytes, items);
        let ce = engine.est_copy_engine_ns(loc, bytes);
        let want = if ls <= ce { Route::LoadStore } else { Route::CopyEngine };
        assert_eq!(plan.route, want, "{loc:?}/{bytes}B/{items}wi");
    }
}

#[test]
fn prop_adaptive_converges_to_tuned_after_warmup() {
    // The adaptive cutover is seeded by the Tuned model and refined by
    // EMAs of observed costs. In the simulator observations *are* the
    // modeled costs, so after a warm-up sweep the adaptive decisions must
    // match Tuned on ≥ 90% of probe points (acceptance bar; exact match
    // expected) — for any EMA weight.
    prop_check("adaptive converges to tuned", 8, |rng| {
        let cost = CostModel::new(Topology::default(), CostParams::default());
        let tuned = XferEngine::new(
            cost.clone(),
            CutoverConfig::tuned(),
            true,
            Metrics::new(),
        );
        let mut acfg = CutoverConfig::adaptive();
        acfg.ema_alpha = 0.05 + 0.95 * rng.f64();
        let metrics = Metrics::new();
        let adaptive = XferEngine::new(cost, acfg, true, metrics.clone());

        let grid = planner_probe_grid();
        // Warm-up sweep: plan + feed back the observed (modeled) cost,
        // several rounds so the EMA settles regardless of alpha.
        for _ in 0..3 {
            for &(loc, bytes, items) in &grid {
                let plan = adaptive.plan_p2p(OpKind::Put, true, loc, bytes, items);
                adaptive.record(&plan, plan.modeled_ns);
            }
        }
        assert!(
            metrics.snapshot().adaptive_updates > 0,
            "warm-up produced no adaptive feedback"
        );

        let mut agree = 0usize;
        for &(loc, bytes, items) in &grid {
            let a = adaptive.plan_p2p(OpKind::Put, true, loc, bytes, items);
            let t = tuned.plan_p2p(OpKind::Put, true, loc, bytes, items);
            if a.route == t.route {
                agree += 1;
            }
        }
        assert!(
            agree * 10 >= grid.len() * 9,
            "adaptive agrees with tuned on only {agree}/{} probe points",
            grid.len()
        );

        // The learned crossover must exist and match the model's for a
        // representative curve (Fig 5, single work-item, cross-GPU).
        let learned = adaptive.learned_crossover_bytes(Locality::SameNode, 1);
        let modeled = adaptive.model_crossover_bytes(Locality::SameNode, 1);
        assert_eq!(learned, modeled, "learned crossover diverged from model");
    });
}

#[test]
fn prop_plan_cache_zero_drift_under_live_recalibration() {
    // The plan cache must be pure memoization: across random routes,
    // sizes, localities, work-item counts, calibration publishes, and CL
    // boundary re-seeds, a cache-on planner and a cache-off planner fed
    // the same probe/update sequence produce bit-for-bit identical plans.
    // A stale entry surviving a version bump or boundary flip, or any
    // cached-path arithmetic that differs from the uncached path, shows
    // up as a plan mismatch here.
    use rishmem::sim::LearnedParams;
    use rishmem::xfer::PlanCacheConfig;
    prop_check("cached plans bitwise match uncached", 20, |rng| {
        let cached = XferEngine::new(
            CostModel::new(Topology::default(), CostParams::default()),
            CutoverConfig::tuned(),
            true,
            Metrics::new(),
        );
        let mut uncached = XferEngine::new(
            CostModel::new(Topology::default(), CostParams::default()),
            CutoverConfig::tuned(),
            true,
            Metrics::new(),
        );
        uncached.set_plan_cache(PlanCacheConfig { enable: false, capacity: 1 });

        let reachable_locs = [Locality::SameTile, Locality::SameGpu, Locality::SameNode];
        for step in 0..300u32 {
            // Occasionally publish a calibration (version bump) or move
            // the CL boundary (re-seed at the same version) on BOTH
            // models, so the cached engine keeps chasing a moving target.
            if rng.below(10) == 0 {
                let sef = 0.2 + 0.6 * rng.f64();
                let rbf = 0.2 + 0.6 * rng.f64();
                let ssn = 4_000.0 + 20_000.0 * rng.f64();
                let set = move |l: &mut LearnedParams| {
                    l.single_engine_frac = sef;
                    l.rail_bw_frac = rbf;
                    l.startup_standard_ns = ssn;
                };
                cached.cost.model.update(set);
                uncached.cost.model.update(set);
            } else if rng.below(10) == 0 {
                let boundary = 1usize << (10 + rng.below(9));
                cached.set_cl_immediate_max_bytes(boundary);
                uncached.set_cl_immediate_max_bytes(boundary);
            }

            let bytes = 1usize << (3 + rng.below(21));
            let items = [1usize, 16, 1024][rng.below(3) as usize];
            let (reach, loc) = if rng.below(4) == 0 {
                (false, Locality::Remote)
            } else {
                (true, reachable_locs[rng.below(3) as usize])
            };
            let c = cached.plan_p2p(OpKind::Put, reach, loc, bytes, items);
            let u = uncached.plan_p2p(OpKind::Put, reach, loc, bytes, items);
            assert_eq!(c, u, "step {step}: {loc:?}/{bytes}B/{items}wi drifted");
        }
    });
}

#[test]
fn prop_team_split_algebra() {
    prop_check("team ranks round-trip through world", 60, |rng| {
        let npes = (rng.range(2, 6) * 2) as usize; // even, 4..12
        let start = rng.below((npes / 2) as u64) as usize;
        let stride = rng.range(1, 2) as usize;
        let max_size = (npes - start).div_ceil(stride);
        let size = rng.range(1, max_size as u64) as usize;

        let specs = run_npes(npes, move |ctx| {
            let team = ctx.team_split_strided(TeamId::WORLD, start, stride, size);
            ctx.barrier_all();
            let member = (ctx.pe() >= start)
                && (ctx.pe() - start) % stride == 0
                && (ctx.pe() - start) / stride < size;
            let rank = member.then(|| ctx.team_my_pe(team));
            // translate back to world
            let world = rank.map(|r| {
                ctx.team_translate_pe(team, r, TeamId::WORLD).unwrap()
            });
            (member, rank, world, ctx.team_n_pes(team))
        })
        .unwrap();
        for (pe, (member, rank, world, n)) in specs.iter().enumerate() {
            assert_eq!(*n, size);
            if *member {
                assert_eq!(rank.unwrap(), (pe - start) / stride);
                assert_eq!(world.unwrap(), pe);
            }
        }
    });
}

#[test]
fn prop_rma_roundtrip_random_shapes() {
    prop_check("put→get roundtrips arbitrary buffers", 25, |rng| {
        let npes = (rng.range(1, 4) * 2) as usize;
        let len = rng.range(1, 20_000) as usize;
        let seed = rng.next_u64();
        let ok = run_npes(npes, move |ctx| {
            let buf = ctx.calloc::<u8>(len);
            let mut payload = vec![0u8; len];
            let mut r = rishmem::util::rng::Rng::new(seed ^ ctx.pe() as u64);
            r.fill_bytes(&mut payload);
            let t = (ctx.pe() + 1) % ctx.npes();
            ctx.put(buf, &payload, t);
            ctx.barrier_all();
            let mut back = vec![0u8; len];
            ctx.get(&mut back, buf, t);
            // What I wrote to t is what I read back from t.
            back == payload
        })
        .unwrap();
        assert!(ok.iter().all(|&b| b));
    });
}

#[test]
fn prop_reduce_matches_scalar_model() {
    prop_check("reduce equals per-element fold", 12, |rng| {
        let npes = rng.range(2, 6) as usize;
        let n = rng.range(1, 3000) as usize;
        let op = *[
            ReduceOp::Sum,
            ReduceOp::Min,
            ReduceOp::Max,
            ReduceOp::And,
            ReduceOp::Or,
            ReduceOp::Xor,
        ]
        .iter()
        .nth(rng.below(6) as usize)
        .unwrap();
        let seed = rng.next_u64();
        let results = run_npes(npes, move |ctx| {
            let dest = ctx.calloc::<i64>(n);
            let src = ctx.calloc::<i64>(n);
            let mut r = rishmem::util::rng::Rng::new(seed ^ (ctx.pe() as u64) << 17);
            let mine: Vec<i64> = (0..n).map(|_| r.range(0, 1000) as i64).collect();
            ctx.write_local(src, &mine);
            ctx.reduce(dest, src, n, op, TeamId::WORLD);
            (mine, ctx.read_local_vec(dest))
        })
        .unwrap();
        // Oracle: fold the per-PE inputs.
        let inputs: Vec<&Vec<i64>> = results.iter().map(|(m, _)| m).collect();
        for i in 0..n {
            let mut want = inputs[0][i];
            for m in &inputs[1..] {
                want = match op {
                    ReduceOp::Sum => want.wrapping_add(m[i]),
                    ReduceOp::Prod => want.wrapping_mul(m[i]),
                    ReduceOp::Min => want.min(m[i]),
                    ReduceOp::Max => want.max(m[i]),
                    ReduceOp::And => want & m[i],
                    ReduceOp::Or => want | m[i],
                    ReduceOp::Xor => want ^ m[i],
                };
            }
            for (pe, (_, got)) in results.iter().enumerate() {
                assert_eq!(got[i], want, "pe={pe} elem={i} op={op:?}");
            }
        }
    });
}

#[test]
fn prop_checksum_attempt_fields_roundtrip() {
    use rishmem::ringbuf::{payload_checksum, ATTEMPT_MAX, DESC_FLAG_CHECKSUM};
    // Exhaustive over the whole 16-bit checksum domain on both entry
    // shapes: the sum must survive the wire codec without disturbing the
    // continuation fields it shares packing space with, and the 4-bit
    // attempt counter must compose with every checksum value.
    for sum in 0..=u16::MAX {
        // Chunked shape: sum rides inline_val2's top 16 bits.
        let c = BatchDescriptor::put(3, 4096, 8192, 1 << 20)
            .with_chunk(5, 9, 6)
            .with_transfer_bytes(9 << 20)
            .with_checksum(sum);
        assert_eq!(c.checksum(), Some(sum));
        assert_eq!(c.transfer_bytes(), 9 << 20, "sum {sum:#06x} disturbed transfer bytes");
        assert_eq!(
            (c.chunk_index(), c.chunk_count(), c.engine_hint()),
            (5, 9, 6),
            "sum {sum:#06x} disturbed continuation fields"
        );
        assert_eq!(BatchDescriptor::from_bytes(&c.to_bytes()), Some(c));
        // Un-chunked shape: sum parks in inline_val's low 16 bits.
        let p = BatchDescriptor::put(1, 64, 128, 256).with_checksum(sum);
        assert_eq!(p.checksum(), Some(sum));
        assert_eq!(BatchDescriptor::from_bytes(&p.to_bytes()), Some(p));
        // Attempt bits live in flags and never collide with the sum.
        let a = (sum & ATTEMPT_MAX) % (ATTEMPT_MAX + 1);
        let r = c.with_attempt(a);
        assert_eq!((r.attempt(), r.checksum()), (a, Some(sum)));
        assert_eq!(BatchDescriptor::from_bytes(&r.to_bytes()), Some(r));
    }
    // Random descriptor bodies: stamping is non-destructive and ordered
    // (checksum last), and the flag alone decides whether a sum exists.
    prop_check("checksum/attempt stamping is field-precise", 300, |rng| {
        let payload_len = rng.range(1, 8192) as usize;
        let mut payload = vec![0u8; payload_len];
        Rng::new(rng.next_u64()).fill_bytes(&mut payload);
        let sum = payload_checksum(&payload);
        let attempt = rng.below(ATTEMPT_MAX as u64 + 1) as u16;
        let d = BatchDescriptor::put(
            rng.next_u64() as usize & 0xFFFF,
            rng.next_u64() as usize >> 16,
            rng.next_u64() as usize >> 16,
            payload_len,
        );
        let chunked = rng.below(2) == 1;
        let d = if chunked {
            let count = rng.range(1, CHUNK_FIELD_MAX as u64) as u32;
            d.with_chunk(rng.below(count as u64) as u32, count, rng.below(256) as u8)
                .with_transfer_bytes(rng.next_u64() & ((1 << 48) - 1))
        } else {
            d
        };
        let bare = d;
        let d = d.with_checksum(sum).with_attempt(attempt);
        assert_eq!(d.checksum(), Some(sum));
        assert_eq!(d.attempt(), attempt);
        assert_eq!(d.is_chunked(), chunked);
        assert_eq!(
            (d.pe, d.dst_off, d.src_off, d.len),
            (bare.pe, bare.dst_off, bare.src_off, bare.len),
            "stamping touched an addressing field"
        );
        assert_eq!(BatchDescriptor::from_bytes(&d.to_bytes()), Some(d));
        // Without the flag there is no sum, whatever the field residue.
        assert_eq!(bare.checksum(), None);
        assert_eq!(bare.flags & DESC_FLAG_CHECKSUM, 0);
        // Re-stamping the attempt replaces; the sum is untouched.
        let r = d.with_attempt((attempt + 1) % (ATTEMPT_MAX + 1));
        assert_eq!(r.attempt(), (attempt + 1) % (ATTEMPT_MAX + 1));
        assert_eq!(r.checksum(), Some(sum));
    });
}

#[test]
fn prop_retry_disabled_is_bit_for_bit_baseline() {
    // `retry.enable = false` (the default) must be bit-for-bit the
    // pre-reliability machine, and enabling it over *clean* lanes must
    // change nothing either: checksum stamping and verification charge
    // zero modeled time, so every PE's modeled clock — and every payload —
    // is identical across the two runs, for random shapes crossing the
    // same-GPU, same-node, and cross-node (rail-striped) routes.
    prop_check("retry.enable leaves clean-lane runs bit-for-bit unchanged", 5, |rng| {
        let len = rng.range(1, 3 << 20) as usize;
        let seed = rng.next_u64();
        let run = |retry_on: bool| {
            let mut cfg = IshmemConfig {
                topology: Topology::new(2, 2, 2),
                heap_bytes: 48 << 20,
                ..Default::default()
            };
            cfg.retry.enable = retry_on;
            run_spmd(cfg, false, move |ctx| {
                let buf = ctx.calloc::<u8>(len);
                let mut payload = vec![0u8; len];
                Rng::new(seed ^ ctx.pe() as u64).fill_bytes(&mut payload);
                let half = ctx.npes() / 2;
                let t_remote = (ctx.pe() + half) % ctx.npes();
                let t_local = ctx.pe() ^ 1;
                // Cross-node blocking put (the checksummed batch path).
                ctx.put(buf, &payload, t_remote);
                ctx.barrier_all();
                // Same-node put, then read my own writes back.
                ctx.put(buf, &payload, t_local);
                ctx.barrier_all();
                let mut back = vec![0u8; len];
                ctx.get(&mut back, buf, t_local);
                // NBI flavour + quiet drain (the other bounded-wait path).
                ctx.put_nbi(buf, &payload, t_remote);
                ctx.quiet();
                ctx.barrier_all();
                (ctx.clock.now_ns().to_bits(), back == payload)
            })
            .unwrap()
        };
        let baseline = run(false);
        let with_retry = run(true);
        assert!(baseline.iter().all(|&(_, ok)| ok), "baseline run corrupted {len}B");
        assert_eq!(
            baseline, with_retry,
            "retry.enable changed a clean-lane run ({len}B): modeled clocks or payloads drifted"
        );
    });
}

#[test]
fn prop_chain_stage_fields_roundtrip() {
    use rishmem::ringbuf::DESC_FLAG_TRIGGERED;
    // Exhaustive over the whole stage byte on every chain-capable entry
    // shape (ISSUE 10): the stage must survive the wire codec, never
    // disturb the fields it shares packing space with, and read back 0
    // the moment the triggered flag is absent.
    for stage in 0..=255u8 {
        // Put: stage rides dtype, composed under chunk continuation,
        // transfer bytes, checksum, and attempt stamping.
        let p = BatchDescriptor::put(3, 4096, 8192, 1 << 20)
            .with_chunk(5, 9, 6)
            .with_transfer_bytes(9 << 20)
            .with_stage(stage)
            .with_checksum(0xBEEF)
            .with_attempt(7);
        assert!(p.is_triggered());
        assert_eq!(p.chain_stage(), stage);
        assert_eq!(
            (p.chunk_index(), p.chunk_count(), p.engine_hint()),
            (5, 9, 6),
            "stage {stage} disturbed continuation fields"
        );
        assert_eq!(p.checksum(), Some(0xBEEF));
        assert_eq!(p.attempt(), 7);
        assert_eq!(p.transfer_bytes(), 9 << 20);
        assert_eq!(BatchDescriptor::from_bytes(&p.to_bytes()), Some(p));
        // Get: same dtype packing.
        let g = BatchDescriptor::get(1, 64, 128, 256).with_stage(stage);
        assert_eq!((g.is_triggered(), g.chain_stage()), (true, stage));
        assert_eq!(BatchDescriptor::from_bytes(&g.to_bytes()), Some(g));
        // Amo: stage rides src_off's low byte (the amo builder zeroes
        // src_off); operand and comparand are untouched.
        let a = BatchDescriptor::amo(2, 512, 7, 2, u64::MAX, 0xABCD).with_stage(stage);
        assert_eq!((a.is_triggered(), a.chain_stage()), (true, stage));
        assert_eq!((a.inline_val, a.inline_val2), (u64::MAX, 0xABCD));
        assert_eq!(BatchDescriptor::from_bytes(&a.to_bytes()), Some(a));
        // WaitSignal gate: dtype packing, watch target untouched.
        let w = BatchDescriptor::wait_signal(4, 2048, u64::MAX - 1).with_stage(stage);
        assert_eq!((w.is_triggered(), w.chain_stage()), (true, stage));
        assert_eq!(w.inline_val, u64::MAX - 1);
        assert_eq!(BatchDescriptor::from_bytes(&w.to_bytes()), Some(w));
    }
    // Without the flag there is no stage, whatever the dtype residue:
    // a batch of unstamped entries is one all-stage-0 dispatch group.
    let bare = BatchDescriptor::put(1, 64, 128, 256);
    assert!(!bare.is_triggered());
    assert_eq!(bare.flags & DESC_FLAG_TRIGGERED, 0);
    assert_eq!(bare.chain_stage(), 0);
    // Whole-block decode of a stage-stamped chain preserves stage order.
    let descs: Vec<BatchDescriptor> = (0..6u8)
        .map(|s| BatchDescriptor::put(0, s as usize * 4096, 0, 4096).with_stage(s / 2))
        .collect();
    let block = BatchDescriptor::encode_block(&descs);
    let back = BatchDescriptor::decode_block(&block, descs.len()).unwrap();
    assert_eq!(back, descs);
    assert!(back.windows(2).all(|w| w[0].chain_stage() <= w[1].chain_stage()));
}

#[test]
fn prop_chain_disabled_is_bit_for_bit_baseline() {
    use rishmem::ishmem::signal::SignalOp;
    use rishmem::ishmem::Cmp;
    // `chain.enable = false` (the default) must make every chain API an
    // exact spelling of the chain-free program: same modeled clocks, same
    // payloads, same machine history — put_then_signal vs put_signal,
    // signal_then_get vs wait_until + get, and the builder ladder vs its
    // hand-written sequence.
    prop_check("disabled chain APIs are the chain-free program", 5, |rng| {
        let len = rng.range(1, 200_000) as usize;
        let seed = rng.next_u64();
        let run = |via_chain_api: bool| {
            let cfg = IshmemConfig {
                topology: Topology::new(1, 2, 2),
                heap_bytes: 48 << 20,
                ..Default::default()
            };
            run_spmd(cfg, false, move |ctx| {
                let data = ctx.calloc::<u8>(len);
                let inbox = ctx.calloc::<u8>(len);
                let sig = ctx.calloc::<u64>(1);
                let mut payload = vec![0u8; len];
                Rng::new(seed ^ ctx.pe() as u64).fill_bytes(&mut payload);
                ctx.write_local(data, &payload);
                ctx.barrier_all();
                let partner = ctx.pe() ^ 1;
                // Producer half: put + signal into the partner's inbox.
                if via_chain_api {
                    ctx.put_then_signal(inbox, &payload, sig, 1, SignalOp::Set, partner);
                } else {
                    ctx.put_signal(inbox, &payload, sig, 1, SignalOp::Set, partner);
                }
                // Consumer half: gate on my signal word, then pull the
                // partner's `data` block.
                let mut pulled = vec![0u8; len];
                if via_chain_api {
                    ctx.signal_then_get(sig, 1, &mut pulled, data, partner);
                } else {
                    ctx.wait_until::<u64>(sig, Cmp::Ge, 1);
                    ctx.get(&mut pulled, data, partner);
                }
                ctx.barrier_all();
                // Builder ladder vs its hand-written spelling.
                if via_chain_api {
                    ctx.chain()
                        .put(data, &pulled, partner)
                        .then()
                        .signal(sig, 1, SignalOp::Add, partner)
                        .submit();
                } else {
                    ctx.put(data, &pulled, partner);
                    ctx.atomic_add::<u64>(sig, 1, partner);
                }
                ctx.wait_until::<u64>(sig, Cmp::Ge, 2);
                ctx.barrier_all();
                (ctx.clock.now_ns().to_bits(), pulled, ctx.read_local_vec(data))
            })
            .unwrap()
        };
        let manual = run(false);
        let api = run(true);
        assert_eq!(
            manual, api,
            "disabled chain APIs drifted from the chain-free program ({len}B)"
        );
    });
}

#[test]
fn prop_fcollect_permutation_safety() {
    // fcollect output is identical on every PE and is exactly the
    // concatenation of inputs in rank order — for random sizes/teams.
    prop_check("fcollect is a rank-ordered concat", 15, |rng| {
        let npes = (rng.range(1, 6) * 2) as usize;
        let per = rng.range(1, 400) as usize;
        let ok = run_npes(npes, move |ctx| {
            let n = ctx.npes();
            let dest = ctx.calloc::<u64>(per * n);
            let src = ctx.calloc::<u64>(per);
            let mine: Vec<u64> = (0..per).map(|i| ((ctx.pe() << 20) + i) as u64).collect();
            ctx.write_local(src, &mine);
            ctx.barrier_all();
            ctx.fcollect(dest, src, per, TeamId::WORLD);
            let all = ctx.read_local_vec(dest);
            (0..n).all(|r| (0..per).all(|i| all[r * per + i] == ((r << 20) + i) as u64))
        })
        .unwrap();
        assert!(ok.iter().all(|&b| b));
    });
}
