//! Integration: triggered operation chains (ISSUE 10) — fused
//! put-signal reclaims doorbells with payloads intact, a chain replayed
//! around a dropped middle chunk never fires its successor early, the
//! offloaded signal-gated get matches the eager spelling bit-for-bit,
//! and a multi-stage `ChainBuilder` program fuses into one submission.
//!
//! Everything here runs on the simulated machine alone — unlike
//! `integration_runtime.rs` / `integration_train.rs`, no `make
//! artifacts` step is required and nothing is skipped.

use rishmem::ishmem::signal::SignalOp;
use rishmem::ishmem::{Cmp, CutoverConfig};
use rishmem::{Ishmem, IshmemConfig, Topology};

/// One node, two GPUs, two tiles: PE 0 → PE 2 is cross-GPU same-node,
/// the proxied copy-engine route once the cutover is pinned.
fn chain_cfg(enable: bool) -> IshmemConfig {
    let mut cfg = IshmemConfig {
        topology: Topology::new(1, 2, 2),
        heap_bytes: 48 << 20,
        cutover: CutoverConfig::always(),
        ..Default::default()
    };
    cfg.chain.enable = enable;
    cfg
}

/// Deterministic per-round payload so the consumer can verify exactly
/// which round's bytes it is looking at.
fn round_pattern(round: usize, len: usize) -> Vec<u8> {
    (0..len)
        .map(|i| (i as u8).wrapping_mul(31).wrapping_add(round as u8 + 1))
        .collect()
}

#[test]
fn fused_put_signal_reclaims_doorbells_and_stays_correct() {
    // The same 8-op put-signal workload on a chain-enabled and a default
    // machine: fused chains must spend strictly fewer host crossings
    // (one doorbell per chain instead of a blocking put flush plus a
    // separate signal update), count exactly one chain and one reclaimed
    // doorbell per op, and land bit-identical payloads.
    const ROUNDS: usize = 8;
    const LEN: usize = 32 << 10;
    let run = |enable: bool| {
        let ish = Ishmem::new(chain_cfg(enable)).unwrap();
        let out = ish.launch(|ctx| {
            let inbox = ctx.calloc::<u8>(ROUNDS * LEN);
            let sig = ctx.calloc::<u64>(1);
            ctx.barrier_all();
            if ctx.pe() == 0 {
                for r in 0..ROUNDS {
                    let pat = round_pattern(r, LEN);
                    ctx.put_then_signal(
                        inbox.slice(r * LEN, LEN),
                        &pat,
                        sig,
                        1,
                        SignalOp::Add,
                        2,
                    );
                }
            }
            ctx.barrier_all();
            if ctx.pe() == 2 {
                assert_eq!(ctx.signal_fetch(sig), ROUNDS as u64, "signal adds lost");
                Some(ctx.read_local_vec(inbox))
            } else {
                None
            }
        });
        let snap = ish.metrics.snapshot();
        ish.shutdown();
        let landed = out.into_iter().flatten().next().expect("PE 2 result");
        (snap, landed)
    };

    let (on, landed_on) = run(true);
    let (off, landed_off) = run(false);

    for r in 0..ROUNDS {
        assert_eq!(
            landed_on[r * LEN..(r + 1) * LEN],
            round_pattern(r, LEN)[..],
            "fused round {r} corrupted the payload"
        );
    }
    assert_eq!(landed_on, landed_off, "fused and unfused payloads diverged");

    // Each 32 KiB put is one chunk, so every chain is depth 2 (payload +
    // triggered signal): one submission and one reclaimed doorbell per op.
    assert_eq!(on.chain_submitted, ROUNDS as u64, "{on:?}");
    assert_eq!(on.chain_fused_doorbells, ROUNDS as u64, "{on:?}");
    assert!(on.chain_triggered >= ROUNDS as u64, "{on:?}");
    assert_eq!((off.chain_submitted, off.chain_fused_doorbells), (0, 0), "{off:?}");
    assert!(
        on.ring_messages < off.ring_messages,
        "fusion did not reduce host crossings: on={} off={}",
        on.ring_messages,
        off.ring_messages
    );
}

#[test]
fn chain_replay_with_dropped_chunk_never_fires_signal_early() {
    // A scripted transient plane drops roughly every fifth data chunk
    // while chained put-signals stream 2 MiB striped payloads. A dropped
    // chunk NACKs its stage, which must suppress the stage-1 signal AMO
    // until the replay re-lands the whole failed suffix — so whenever the
    // consumer observes the signal, that round's payload is already
    // bit-intact. Consumer-side verification happens under the signal,
    // not after a barrier, so an early-fired successor would be caught.
    const ROUNDS: usize = 4;
    const LEN: usize = 2 << 20;
    let mut cfg = chain_cfg(true);
    // 2 MiB stripes into up to `stripe_max_engines` (4) chunks → depth 5
    // with the triggered signal; the default cap of 4 would refuse to
    // fuse exactly the chains this test is about.
    cfg.chain.max_depth = 8;
    cfg.retry.enable = true;
    cfg.fault.enable = true;
    cfg.fault.transients = vec![rishmem::sim::TransientEvent::drop_chunk(1, u64::MAX, 5)];
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let inbox = ctx.calloc::<u8>(ROUNDS * LEN);
        let sig = ctx.calloc::<u64>(1);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            for r in 0..ROUNDS {
                let pat = round_pattern(r, LEN);
                ctx.put_then_signal(inbox.slice(r * LEN, LEN), &pat, sig, 1, SignalOp::Add, 2);
            }
        }
        if ctx.pe() == 2 {
            for r in 0..ROUNDS {
                ctx.wait_until::<u64>(sig, Cmp::Ge, r as u64 + 1);
                let got = ctx.read_local_vec(inbox);
                assert_eq!(
                    got[r * LEN..(r + 1) * LEN],
                    round_pattern(r, LEN)[..],
                    "signal for round {r} fired before its payload replayed"
                );
            }
        }
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();

    assert!(snap.chain_submitted >= ROUNDS as u64, "{snap:?}");
    assert!(
        snap.fault_dropped_chunks >= 1,
        "the transient plane never hit a chained chunk: {snap:?}"
    );
}

#[test]
fn signal_then_get_offloaded_matches_eager_spelling() {
    // Producer publishes a block locally and signals the consumer with a
    // fused put-signal; the consumer's signal_then_get offloads the wait
    // (a parked WaitSignal gate the proxy resumes) plus the get into one
    // doorbell. The pulled bytes must equal both the produced pattern and
    // the eager wait-then-get spelling on a default machine.
    const LEN: usize = 256 << 10;
    let run = |enable: bool| {
        let ish = Ishmem::new(chain_cfg(enable)).unwrap();
        let out = ish.launch(|ctx| {
            let data = ctx.calloc::<u8>(LEN);
            let hdr = ctx.calloc::<u64>(1);
            let sig = ctx.calloc::<u64>(1);
            ctx.barrier_all();
            if ctx.pe() == 0 {
                let pat = round_pattern(0, LEN);
                ctx.write_local(data, &pat);
                ctx.put_then_signal(hdr, &[LEN as u64], sig, 1, SignalOp::Set, 2);
            }
            let r = if ctx.pe() == 2 {
                let mut pulled = vec![0u8; LEN];
                ctx.signal_then_get(sig, 1, &mut pulled, data, 0);
                Some(pulled)
            } else {
                None
            };
            ctx.barrier_all();
            r
        });
        let snap = ish.metrics.snapshot();
        ish.shutdown();
        (snap, out.into_iter().flatten().next().expect("PE 2 result"))
    };

    let (on, pulled_on) = run(true);
    let (_, pulled_off) = run(false);
    assert_eq!(pulled_on, round_pattern(0, LEN), "offloaded get pulled wrong bytes");
    assert_eq!(pulled_on, pulled_off, "offloaded and eager spellings diverged");
    // Both the producer's put-signal and the consumer's gated get fused.
    assert!(on.chain_submitted >= 2, "{on:?}");
    assert!(on.chain_triggered >= 2, "{on:?}");
}

#[test]
fn chain_builder_multi_stage_program_fuses_once() {
    // A recorded three-stage program — two ordered puts then a signal —
    // submits as ONE chain: one submission counted, depth-1 reclaimed
    // doorbells, and the consumer observes both blocks under the signal.
    const LEN: usize = 8 << 10;
    let ish = Ishmem::new(chain_cfg(true)).unwrap();
    ish.launch(|ctx| {
        let inbox = ctx.calloc::<u8>(2 * LEN);
        let sig = ctx.calloc::<u64>(1);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            let a = round_pattern(0, LEN);
            let b = round_pattern(1, LEN);
            ctx.chain()
                .put(inbox.slice(0, LEN), &a, 2)
                .then()
                .put(inbox.slice(LEN, LEN), &b, 2)
                .then()
                .signal(sig, 1, SignalOp::Set, 2)
                .submit();
        }
        if ctx.pe() == 2 {
            ctx.wait_until::<u64>(sig, Cmp::Ge, 1);
            let got = ctx.read_local_vec(inbox);
            assert_eq!(got[..LEN], round_pattern(0, LEN)[..], "stage-0 block");
            assert_eq!(got[LEN..], round_pattern(1, LEN)[..], "stage-1 block");
        }
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();

    assert_eq!(snap.chain_submitted, 1, "{snap:?}");
    assert_eq!(snap.chain_fused_doorbells, 2, "depth-3 chain reclaims 2: {snap:?}");
    assert!(snap.chain_triggered >= 2, "{snap:?}");
    assert_eq!(
        snap.chain_depth_hist.iter().sum::<u64>(),
        snap.chain_submitted,
        "{snap:?}"
    );
}
