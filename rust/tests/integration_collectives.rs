//! Integration: collectives (sync/barrier/broadcast/fcollect/collect/
//! alltoall/reduce) across the simulated node with real threads — and
//! the hierarchical/flat algorithm equivalence contract: every algorithm
//! produces bitwise-identical results, single-node teams provably stay
//! on the flat path, and forced-hierarchical runs fill both stages of
//! the per-op byte table.

use rishmem::coordinator::metrics::{CollOpIdx, CollStage};
use rishmem::ishmem::CutoverConfig;
use rishmem::{
    run_npes, run_spmd, CollAlgoMode, CollConfig, Ishmem, IshmemConfig, ReduceOp, TeamId,
    Topology, WorkGroup,
};

#[test]
fn sync_all_is_a_real_barrier() {
    // Flag protocol: nobody may pass sync until everyone stored its flag.
    let ok = run_npes(12, |ctx| {
        let flags = ctx.calloc::<u64>(12);
        ctx.p(flags.at(ctx.pe()), 1u64, (ctx.pe() + 5) % 12);
        ctx.barrier_all();
        // After the barrier every remote flag deposit must be visible.
        let mine = ctx.read_local_vec(flags);
        mine[(ctx.pe() + 12 - 5) % 12] == 1
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn repeated_syncs_do_not_deadlock_or_leak_rounds() {
    let rounds = run_npes(6, |ctx| {
        for _ in 0..50 {
            ctx.sync_all();
        }
        50
    })
    .unwrap();
    assert_eq!(rounds.len(), 6);
}

#[test]
fn broadcast_from_each_root() {
    let ok = run_npes(6, |ctx| {
        let dest = ctx.calloc::<i64>(300);
        let src = ctx.calloc::<i64>(300);
        let mut all_ok = true;
        for root in 0..ctx.npes() {
            let data: Vec<i64> = (0..300).map(|i| (root * 10_000 + i) as i64).collect();
            if ctx.pe() == root {
                ctx.write_local(src, &data);
            }
            ctx.barrier_all();
            ctx.broadcast(dest, src, 300, root, TeamId::WORLD);
            all_ok &= ctx.read_local_vec(dest) == data;
        }
        all_ok
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn broadcast_work_group_matches() {
    let ok = run_npes(12, |ctx| {
        let dest = ctx.calloc::<f32>(2048);
        let src = ctx.calloc::<f32>(2048);
        let data: Vec<f32> = (0..2048).map(|i| i as f32).collect();
        if ctx.pe() == 3 {
            ctx.write_local(src, &data);
        }
        ctx.barrier_all();
        let wg = WorkGroup::new(128);
        ctx.broadcast_work_group(dest, src, 2048, 3, TeamId::WORLD, &wg);
        ctx.read_local_vec(dest) == data
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn fcollect_gathers_in_rank_order() {
    let n = 12;
    let per = 64usize;
    let ok = run_npes(n, |ctx| {
        let dest = ctx.calloc::<u32>(per * n);
        let src = ctx.calloc::<u32>(per);
        let mine: Vec<u32> = (0..per).map(|i| (ctx.pe() * 1000 + i) as u32).collect();
        ctx.write_local(src, &mine);
        ctx.barrier_all();
        ctx.fcollect(dest, src, per, TeamId::WORLD);
        let all = ctx.read_local_vec(dest);
        (0..n).all(|r| (0..per).all(|i| all[r * per + i] == (r * 1000 + i) as u32))
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn fcollect_correct_under_all_cutover_modes() {
    for mode in [
        CutoverConfig::never(),
        CutoverConfig::always(),
        CutoverConfig::tuned(),
        CutoverConfig::adaptive(),
    ] {
        let cfg = IshmemConfig {
            cutover: mode.clone(),
            ..IshmemConfig::with_npes(8)
        };
        let ok = run_spmd(cfg, false, |ctx| {
            let n = ctx.npes();
            let dest = ctx.calloc::<u64>(512 * n);
            let src = ctx.calloc::<u64>(512);
            let mine = vec![ctx.pe() as u64; 512];
            ctx.write_local(src, &mine);
            ctx.barrier_all();
            let wg = WorkGroup::new(256);
            ctx.fcollect_work_group(dest, src, 512, TeamId::WORLD, &wg);
            let all = ctx.read_local_vec(dest);
            (0..n).all(|r| (0..512).all(|i| all[r * 512 + i] == r as u64))
        })
        .unwrap();
        assert!(ok.iter().all(|&b| b), "fcollect corrupt under {mode:?}");
    }
}

#[test]
fn host_fcollect_matches_device_fcollect() {
    let ok = run_npes(4, |ctx| {
        let n = ctx.npes();
        let d1 = ctx.calloc::<u32>(128 * n);
        let d2 = ctx.calloc::<u32>(128 * n);
        let src = ctx.calloc::<u32>(128);
        let mine: Vec<u32> = (0..128).map(|i| (ctx.pe() * 7 + i) as u32).collect();
        ctx.write_local(src, &mine);
        ctx.barrier_all();
        ctx.fcollect(d1, src, 128, TeamId::WORLD);
        ctx.host_fcollect(d2, src, 128, TeamId::WORLD);
        ctx.read_local_vec(d1) == ctx.read_local_vec(d2)
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn collect_variable_sizes() {
    let ok = run_npes(6, |ctx| {
        // PE r contributes r+1 elements.
        let my_n = ctx.pe() + 1;
        let total: usize = (1..=ctx.npes()).sum();
        let dest = ctx.calloc::<i32>(total);
        let src = ctx.calloc::<i32>(ctx.npes());
        let mine = vec![ctx.pe() as i32; my_n];
        ctx.write_local(src, &mine);
        ctx.barrier_all();
        ctx.collect(dest, src, my_n, TeamId::WORLD);
        let all = ctx.read_local_vec(dest);
        let mut off = 0;
        (0..ctx.npes()).all(|r| {
            let good = (0..r + 1).all(|i| all[off + i] == r as i32);
            off += r + 1;
            good
        })
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn alltoall_transposes_blocks() {
    let n = 6;
    let per = 32;
    let ok = run_npes(n, |ctx| {
        let dest = ctx.calloc::<u64>(per * n);
        let src = ctx.calloc::<u64>(per * n);
        // Block j carries value my_pe*100 + j.
        let mine: Vec<u64> = (0..per * n)
            .map(|i| (ctx.pe() * 100 + i / per) as u64)
            .collect();
        ctx.write_local(src, &mine);
        ctx.barrier_all();
        ctx.alltoall(dest, src, per, TeamId::WORLD);
        let all = ctx.read_local_vec(dest);
        // Block r of my dest came from PE r's block my_pe.
        (0..n).all(|r| (0..per).all(|i| all[r * per + i] == (r * 100 + ctx.pe()) as u64))
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn alltoall_and_collect_work_group_match_scalar() {
    let ok = run_npes(6, |ctx| {
        let n = ctx.npes();
        let per = 48;
        let d1 = ctx.calloc::<u32>(per * n);
        let d2 = ctx.calloc::<u32>(per * n);
        let src = ctx.calloc::<u32>(per * n);
        let mine: Vec<u32> = (0..per * n).map(|i| (ctx.pe() * 31 + i) as u32).collect();
        ctx.write_local(src, &mine);
        ctx.barrier_all();
        let wg = WorkGroup::new(64);
        ctx.alltoall(d1, src, per, TeamId::WORLD);
        ctx.alltoall_work_group(d2, src, per, TeamId::WORLD, &wg);
        let a2a_ok = ctx.read_local_vec(d1) == ctx.read_local_vec(d2);

        let c1 = ctx.calloc::<u32>(per * n);
        let c2 = ctx.calloc::<u32>(per * n);
        ctx.collect(c1, src, per, TeamId::WORLD);
        ctx.collect_work_group(c2, src, per, TeamId::WORLD, &wg);
        a2a_ok && ctx.read_local_vec(c1) == ctx.read_local_vec(c2)
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn reduce_sum_f32_native() {
    let n = 12;
    let ok = run_npes(n, |ctx| {
        let dest = ctx.calloc::<f32>(500);
        let src = ctx.calloc::<f32>(500);
        let mine: Vec<f32> = (0..500).map(|i| (ctx.pe() + 1) as f32 * 0.5 + i as f32).collect();
        ctx.write_local(src, &mine);
        ctx.reduce(dest, src, 500, ReduceOp::Sum, TeamId::WORLD);
        let got = ctx.read_local_vec(dest);
        // sum over r of (r+1)*0.5 + i = 0.5*n(n+1)/2 + n*i
        let base = 0.5 * (n * (n + 1) / 2) as f32;
        got.iter()
            .enumerate()
            .all(|(i, &v)| (v - (base + (n * i) as f32)).abs() < 1e-3)
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn reduce_all_ops_integer() {
    let ok = run_npes(4, |ctx| {
        let n = ctx.npes() as i64;
        let dest = ctx.calloc::<i64>(64);
        let src = ctx.calloc::<i64>(64);
        let mine: Vec<i64> = (0..64).map(|i| (ctx.pe() as i64 + 2) * (i as i64 + 1)).collect();
        ctx.write_local(src, &mine);
        let mut all_ok = true;
        for op in [
            ReduceOp::Sum,
            ReduceOp::Prod,
            ReduceOp::Min,
            ReduceOp::Max,
            ReduceOp::And,
            ReduceOp::Or,
            ReduceOp::Xor,
        ] {
            ctx.reduce(dest, src, 64, op, TeamId::WORLD);
            let got = ctx.read_local_vec(dest);
            let want: Vec<i64> = (0..64)
                .map(|i| {
                    let vals = (0..n).map(|r| (r + 2) * (i as i64 + 1));
                    match op {
                        ReduceOp::Sum => vals.sum(),
                        ReduceOp::Prod => vals.product(),
                        ReduceOp::Min => vals.min().unwrap(),
                        ReduceOp::Max => vals.max().unwrap(),
                        ReduceOp::And => vals.fold(-1i64, |a, b| a & b),
                        ReduceOp::Or => vals.fold(0i64, |a, b| a | b),
                        ReduceOp::Xor => vals.fold(0i64, |a, b| a ^ b),
                    }
                })
                .collect();
            all_ok &= got == want;
        }
        all_ok
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn team_scoped_collectives() {
    // Split world into even/odd teams; reduce within each.
    let sums = run_npes(8, |ctx| {
        let parity = ctx.pe() % 2;
        let team = ctx.team_split_strided(TeamId::WORLD, parity, 2, 4);
        let dest = ctx.calloc::<i32>(16);
        let src = ctx.calloc::<i32>(16);
        ctx.write_local(src, &vec![ctx.pe() as i32; 16]);
        ctx.reduce(dest, src, 16, ReduceOp::Sum, team);
        ctx.barrier_all();
        ctx.read_local_vec(dest)[0]
    })
    .unwrap();
    // evens: 0+2+4+6 = 12; odds: 1+3+5+7 = 16.
    for (pe, s) in sums.iter().enumerate() {
        assert_eq!(*s, if pe % 2 == 0 { 12 } else { 16 }, "pe {pe}");
    }
}

#[test]
fn shared_team_is_node_scoped() {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 3, 2),
        ..Default::default()
    };
    let sums = run_spmd(cfg, false, |ctx| {
        let dest = ctx.calloc::<u64>(4);
        let src = ctx.calloc::<u64>(4);
        ctx.write_local(src, &[1u64; 4]);
        ctx.reduce(dest, src, 4, ReduceOp::Sum, TeamId::SHARED);
        ctx.barrier_all();
        ctx.read_local_vec(dest)[0]
    })
    .unwrap();
    // Each node has 6 PEs; every PE contributed 1 within its node.
    assert!(sums.iter().all(|&s| s == 6), "{sums:?}");
}

// ------------------------------------------------- hierarchical algorithms --

/// One fixed multi-node workload — world broadcast/fcollect/reduce plus a
/// node-spanning strided team reduce — with every float buffer returned
/// as raw bits so runs under different algorithms compare bitwise.
fn coll_workload_results(
    algo: CollAlgoMode,
) -> Vec<(Vec<u64>, Vec<u32>, Vec<u64>, Vec<u64>)> {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        coll: CollConfig { algo, leader_fanout: 2, ..CollConfig::default() },
        ..Default::default()
    };
    run_spmd(cfg, false, |ctx| {
        let n = ctx.npes();
        // Broadcast from a root that is neither PE 0 nor its node's
        // lowest member — the leader-election edge case.
        let bdest = ctx.calloc::<f64>(257);
        let bsrc = ctx.calloc::<f64>(257);
        if ctx.pe() == 3 {
            let data: Vec<f64> = (0..257).map(|i| 0.37 * i as f64 + 11.0).collect();
            ctx.write_local(bsrc, &data);
        }
        ctx.barrier_all();
        ctx.broadcast(bdest, bsrc, 257, 3, TeamId::WORLD);

        let fdest = ctx.calloc::<u32>(96 * n);
        let fsrc = ctx.calloc::<u32>(96);
        let mine: Vec<u32> = (0..96).map(|i| (ctx.pe() * 1000 + i) as u32).collect();
        ctx.write_local(fsrc, &mine);
        ctx.barrier_all();
        ctx.fcollect(fdest, fsrc, 96, TeamId::WORLD);

        // Order-sensitive f64 sum: bitwise equality holds only if every
        // algorithm folds in the same member order.
        let rdest = ctx.calloc::<f64>(333);
        let rsrc = ctx.calloc::<f64>(333);
        let rdata: Vec<f64> = (0..333)
            .map(|i| (ctx.pe() as f64 + 0.1) * (i as f64 + 0.01))
            .collect();
        ctx.write_local(rsrc, &rdata);
        ctx.reduce(rdest, rsrc, 333, ReduceOp::Sum, TeamId::WORLD);

        // Odd PEs {1,3,5,7}: a strided team spanning both nodes.
        let team = ctx.team_split_strided(TeamId::WORLD, 1, 2, 4);
        let tdest = ctx.calloc::<f64>(65);
        let tsrc = ctx.calloc::<f64>(65);
        let mut tres = vec![0.0f64; 65];
        if ctx.pe() % 2 == 1 {
            let tdata: Vec<f64> =
                (0..65).map(|i| ctx.pe() as f64 - 0.25 * i as f64).collect();
            ctx.write_local(tsrc, &tdata);
            ctx.team_barrier(team);
            ctx.reduce(tdest, tsrc, 65, ReduceOp::Sum, team);
            tres = ctx.read_local_vec(tdest);
        }
        ctx.barrier_all();
        (
            ctx.read_local_vec(bdest).iter().map(|v| v.to_bits()).collect(),
            ctx.read_local_vec(fdest),
            ctx.read_local_vec(rdest).iter().map(|v| v.to_bits()).collect(),
            tres.iter().map(|v| v.to_bits()).collect(),
        )
    })
    .unwrap()
}

#[test]
fn hierarchical_results_match_flat_bitwise() {
    let flat = coll_workload_results(CollAlgoMode::Flat);
    // The flat baseline itself must be right (not garbage == garbage).
    let bdata: Vec<u64> = (0..257)
        .map(|i| (0.37 * i as f64 + 11.0).to_bits())
        .collect();
    for (pe, (b, f, _, _)) in flat.iter().enumerate() {
        assert_eq!(*b, bdata, "flat broadcast corrupt on pe {pe}");
        assert!(
            (0..8).all(|r| (0..96).all(|i| f[r * 96 + i] == (r * 1000 + i) as u32)),
            "flat fcollect corrupt on pe {pe}"
        );
    }
    for algo in [CollAlgoMode::HierRing, CollAlgoMode::HierTree, CollAlgoMode::Auto] {
        let got = coll_workload_results(algo);
        assert_eq!(got, flat, "results diverged under {algo:?}");
    }
}

#[test]
fn single_node_team_takes_flat_path_even_when_forced_hier() {
    for algo in [CollAlgoMode::HierRing, CollAlgoMode::HierTree] {
        let cfg = IshmemConfig {
            topology: Topology::new(1, 2, 2),
            coll: CollConfig { algo, leader_fanout: 2, ..CollConfig::default() },
            ..Default::default()
        };
        let ish = Ishmem::new(cfg).unwrap();
        ish.launch(|ctx| {
            let n = ctx.npes();
            let dest = ctx.calloc::<u32>(64 * n);
            let src = ctx.calloc::<u32>(64);
            ctx.write_local(src, &vec![ctx.pe() as u32; 64]);
            ctx.barrier_all();
            ctx.fcollect(dest, src, 64, TeamId::WORLD);
            ctx.broadcast(dest, src, 64, 0, TeamId::WORLD);
            let rd = ctx.calloc::<i64>(32);
            let rs = ctx.calloc::<i64>(32);
            ctx.write_local(rs, &vec![1i64; 32]);
            ctx.reduce(rd, rs, 32, ReduceOp::Sum, TeamId::WORLD);
            ctx.barrier_all();
        });
        let snap = ish.metrics.snapshot();
        ish.shutdown();
        assert_eq!(snap.coll_hier, 0, "single node must stay flat under {algo:?}");
        assert!(snap.coll_broadcast >= 1 && snap.coll_fcollect >= 1);
        assert!(snap.coll_reduce >= 1, "{snap:?}");
        // No inter-node stage exists on one node.
        assert_eq!(snap.coll_stage_total(CollStage::Inter), 0, "{snap:?}");
        assert!(snap.coll_stage_total(CollStage::Intra) > 0, "{snap:?}");
    }
}

#[test]
fn forced_hierarchical_fills_both_stages_of_the_byte_table() {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        coll: CollConfig { algo: CollAlgoMode::HierRing, leader_fanout: 2, ..CollConfig::default() },
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let n = ctx.npes();
        let bd = ctx.calloc::<u64>(512);
        let bs = ctx.calloc::<u64>(512);
        let data: Vec<u64> = (0..512).map(|i| i as u64 * 3 + 1).collect();
        if ctx.pe() == 0 {
            ctx.write_local(bs, &data);
        }
        ctx.barrier_all();
        ctx.broadcast(bd, bs, 512, 0, TeamId::WORLD);
        assert_eq!(ctx.read_local_vec(bd), data, "hier broadcast corrupt");

        let fd = ctx.calloc::<u32>(128 * n);
        let fs = ctx.calloc::<u32>(128);
        ctx.write_local(fs, &vec![ctx.pe() as u32 + 7; 128]);
        ctx.barrier_all();
        ctx.fcollect(fd, fs, 128, TeamId::WORLD);
        let all = ctx.read_local_vec(fd);
        assert!(
            (0..n).all(|r| (0..128).all(|i| all[r * 128 + i] == r as u32 + 7)),
            "hier fcollect corrupt"
        );

        let rd = ctx.calloc::<i32>(64);
        let rs = ctx.calloc::<i32>(64);
        ctx.write_local(rs, &vec![ctx.pe() as i32; 64]);
        ctx.reduce(rd, rs, 64, ReduceOp::Max, TeamId::WORLD);
        assert!(
            ctx.read_local_vec(rd).iter().all(|&v| v == n as i32 - 1),
            "hier reduce corrupt"
        );
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();
    // 3 forced-hierarchical collectives × 8 PEs.
    assert_eq!(snap.coll_hier, 24, "{snap:?}");
    assert!(snap.collectives() >= 24, "{snap:?}");
    for op in [CollOpIdx::Broadcast, CollOpIdx::Fcollect, CollOpIdx::Reduce] {
        assert!(snap.coll_bytes(op, CollStage::Intra) > 0, "{op:?}: {snap:?}");
        assert!(snap.coll_bytes(op, CollStage::Inter) > 0, "{op:?}: {snap:?}");
    }
}

#[test]
fn work_group_collectives_ride_the_hierarchy() {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        coll: CollConfig { algo: CollAlgoMode::HierTree, leader_fanout: 2, ..CollConfig::default() },
        ..Default::default()
    };
    let ok = run_spmd(cfg, false, |ctx| {
        let n = ctx.npes();
        let wg = WorkGroup::new(64);
        let bd = ctx.calloc::<f32>(1024);
        let bs = ctx.calloc::<f32>(1024);
        let data: Vec<f32> = (0..1024).map(|i| i as f32 * 0.5).collect();
        if ctx.pe() == 5 {
            ctx.write_local(bs, &data);
        }
        ctx.barrier_all();
        ctx.broadcast_work_group(bd, bs, 1024, 5, TeamId::WORLD, &wg);
        let b_ok = ctx.read_local_vec(bd) == data;

        let fd = ctx.calloc::<u64>(256 * n);
        let fs = ctx.calloc::<u64>(256);
        ctx.write_local(fs, &vec![ctx.pe() as u64 * 3; 256]);
        ctx.barrier_all();
        ctx.fcollect_work_group(fd, fs, 256, TeamId::WORLD, &wg);
        let all = ctx.read_local_vec(fd);
        b_ok && (0..n).all(|r| (0..256).all(|i| all[r * 256 + i] == r as u64 * 3))
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn adaptive_auto_collectives_stay_correct_across_nodes() {
    // Auto + adaptive cutover on a 2-node machine: selection runs through
    // the published-decision protocol and coll_observe feedback on real
    // threads; repeated calls must stay correct whatever gets chosen.
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        cutover: CutoverConfig::adaptive(),
        ..Default::default()
    };
    let ok = run_spmd(cfg, false, |ctx| {
        let n = ctx.npes();
        let fd = ctx.calloc::<u32>(256 * n);
        let fs = ctx.calloc::<u32>(256);
        ctx.write_local(fs, &vec![ctx.pe() as u32; 256]);
        ctx.barrier_all();
        let mut good = true;
        for _ in 0..4 {
            ctx.fcollect(fd, fs, 256, TeamId::WORLD);
            let all = ctx.read_local_vec(fd);
            good &= (0..n).all(|r| (0..256).all(|i| all[r * 256 + i] == r as u32));
        }
        ctx.barrier_all();
        good
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn internode_world_collectives() {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        ..Default::default()
    };
    let ok = run_spmd(cfg, false, |ctx| {
        let n = ctx.npes();
        let dest = ctx.calloc::<u32>(16 * n);
        let src = ctx.calloc::<u32>(16);
        ctx.write_local(src, &vec![ctx.pe() as u32; 16]);
        ctx.barrier_all();
        ctx.fcollect(dest, src, 16, TeamId::WORLD);
        let all = ctx.read_local_vec(dest);
        (0..n).all(|r| (0..16).all(|i| all[r * 16 + i] == r as u32))
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}
