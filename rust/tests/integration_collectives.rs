//! Integration: collectives (sync/barrier/broadcast/fcollect/collect/
//! alltoall/reduce) across the simulated node with real threads.

use rishmem::ishmem::CutoverConfig;
use rishmem::{run_npes, run_spmd, IshmemConfig, ReduceOp, TeamId, Topology, WorkGroup};

#[test]
fn sync_all_is_a_real_barrier() {
    // Flag protocol: nobody may pass sync until everyone stored its flag.
    let ok = run_npes(12, |ctx| {
        let flags = ctx.calloc::<u64>(12);
        ctx.p(flags.at(ctx.pe()), 1u64, (ctx.pe() + 5) % 12);
        ctx.barrier_all();
        // After the barrier every remote flag deposit must be visible.
        let mine = ctx.read_local_vec(flags);
        mine[(ctx.pe() + 12 - 5) % 12] == 1
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn repeated_syncs_do_not_deadlock_or_leak_rounds() {
    let rounds = run_npes(6, |ctx| {
        for _ in 0..50 {
            ctx.sync_all();
        }
        50
    })
    .unwrap();
    assert_eq!(rounds.len(), 6);
}

#[test]
fn broadcast_from_each_root() {
    let ok = run_npes(6, |ctx| {
        let dest = ctx.calloc::<i64>(300);
        let src = ctx.calloc::<i64>(300);
        let mut all_ok = true;
        for root in 0..ctx.npes() {
            let data: Vec<i64> = (0..300).map(|i| (root * 10_000 + i) as i64).collect();
            if ctx.pe() == root {
                ctx.write_local(src, &data);
            }
            ctx.barrier_all();
            ctx.broadcast(dest, src, 300, root, TeamId::WORLD);
            all_ok &= ctx.read_local_vec(dest) == data;
        }
        all_ok
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn broadcast_work_group_matches() {
    let ok = run_npes(12, |ctx| {
        let dest = ctx.calloc::<f32>(2048);
        let src = ctx.calloc::<f32>(2048);
        let data: Vec<f32> = (0..2048).map(|i| i as f32).collect();
        if ctx.pe() == 3 {
            ctx.write_local(src, &data);
        }
        ctx.barrier_all();
        let wg = WorkGroup::new(128);
        ctx.broadcast_work_group(dest, src, 2048, 3, TeamId::WORLD, &wg);
        ctx.read_local_vec(dest) == data
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn fcollect_gathers_in_rank_order() {
    let n = 12;
    let per = 64usize;
    let ok = run_npes(n, |ctx| {
        let dest = ctx.calloc::<u32>(per * n);
        let src = ctx.calloc::<u32>(per);
        let mine: Vec<u32> = (0..per).map(|i| (ctx.pe() * 1000 + i) as u32).collect();
        ctx.write_local(src, &mine);
        ctx.barrier_all();
        ctx.fcollect(dest, src, per, TeamId::WORLD);
        let all = ctx.read_local_vec(dest);
        (0..n).all(|r| (0..per).all(|i| all[r * per + i] == (r * 1000 + i) as u32))
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn fcollect_correct_under_all_cutover_modes() {
    for mode in [
        CutoverConfig::never(),
        CutoverConfig::always(),
        CutoverConfig::tuned(),
        CutoverConfig::adaptive(),
    ] {
        let cfg = IshmemConfig {
            cutover: mode.clone(),
            ..IshmemConfig::with_npes(8)
        };
        let ok = run_spmd(cfg, false, |ctx| {
            let n = ctx.npes();
            let dest = ctx.calloc::<u64>(512 * n);
            let src = ctx.calloc::<u64>(512);
            let mine = vec![ctx.pe() as u64; 512];
            ctx.write_local(src, &mine);
            ctx.barrier_all();
            let wg = WorkGroup::new(256);
            ctx.fcollect_work_group(dest, src, 512, TeamId::WORLD, &wg);
            let all = ctx.read_local_vec(dest);
            (0..n).all(|r| (0..512).all(|i| all[r * 512 + i] == r as u64))
        })
        .unwrap();
        assert!(ok.iter().all(|&b| b), "fcollect corrupt under {mode:?}");
    }
}

#[test]
fn host_fcollect_matches_device_fcollect() {
    let ok = run_npes(4, |ctx| {
        let n = ctx.npes();
        let d1 = ctx.calloc::<u32>(128 * n);
        let d2 = ctx.calloc::<u32>(128 * n);
        let src = ctx.calloc::<u32>(128);
        let mine: Vec<u32> = (0..128).map(|i| (ctx.pe() * 7 + i) as u32).collect();
        ctx.write_local(src, &mine);
        ctx.barrier_all();
        ctx.fcollect(d1, src, 128, TeamId::WORLD);
        ctx.host_fcollect(d2, src, 128, TeamId::WORLD);
        ctx.read_local_vec(d1) == ctx.read_local_vec(d2)
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn collect_variable_sizes() {
    let ok = run_npes(6, |ctx| {
        // PE r contributes r+1 elements.
        let my_n = ctx.pe() + 1;
        let total: usize = (1..=ctx.npes()).sum();
        let dest = ctx.calloc::<i32>(total);
        let src = ctx.calloc::<i32>(ctx.npes());
        let mine = vec![ctx.pe() as i32; my_n];
        ctx.write_local(src, &mine);
        ctx.barrier_all();
        ctx.collect(dest, src, my_n, TeamId::WORLD);
        let all = ctx.read_local_vec(dest);
        let mut off = 0;
        (0..ctx.npes()).all(|r| {
            let good = (0..r + 1).all(|i| all[off + i] == r as i32);
            off += r + 1;
            good
        })
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn alltoall_transposes_blocks() {
    let n = 6;
    let per = 32;
    let ok = run_npes(n, |ctx| {
        let dest = ctx.calloc::<u64>(per * n);
        let src = ctx.calloc::<u64>(per * n);
        // Block j carries value my_pe*100 + j.
        let mine: Vec<u64> = (0..per * n)
            .map(|i| (ctx.pe() * 100 + i / per) as u64)
            .collect();
        ctx.write_local(src, &mine);
        ctx.barrier_all();
        ctx.alltoall(dest, src, per, TeamId::WORLD);
        let all = ctx.read_local_vec(dest);
        // Block r of my dest came from PE r's block my_pe.
        (0..n).all(|r| (0..per).all(|i| all[r * per + i] == (r * 100 + ctx.pe()) as u64))
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn alltoall_and_collect_work_group_match_scalar() {
    let ok = run_npes(6, |ctx| {
        let n = ctx.npes();
        let per = 48;
        let d1 = ctx.calloc::<u32>(per * n);
        let d2 = ctx.calloc::<u32>(per * n);
        let src = ctx.calloc::<u32>(per * n);
        let mine: Vec<u32> = (0..per * n).map(|i| (ctx.pe() * 31 + i) as u32).collect();
        ctx.write_local(src, &mine);
        ctx.barrier_all();
        let wg = WorkGroup::new(64);
        ctx.alltoall(d1, src, per, TeamId::WORLD);
        ctx.alltoall_work_group(d2, src, per, TeamId::WORLD, &wg);
        let a2a_ok = ctx.read_local_vec(d1) == ctx.read_local_vec(d2);

        let c1 = ctx.calloc::<u32>(per * n);
        let c2 = ctx.calloc::<u32>(per * n);
        ctx.collect(c1, src, per, TeamId::WORLD);
        ctx.collect_work_group(c2, src, per, TeamId::WORLD, &wg);
        a2a_ok && ctx.read_local_vec(c1) == ctx.read_local_vec(c2)
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn reduce_sum_f32_native() {
    let n = 12;
    let ok = run_npes(n, |ctx| {
        let dest = ctx.calloc::<f32>(500);
        let src = ctx.calloc::<f32>(500);
        let mine: Vec<f32> = (0..500).map(|i| (ctx.pe() + 1) as f32 * 0.5 + i as f32).collect();
        ctx.write_local(src, &mine);
        ctx.reduce(dest, src, 500, ReduceOp::Sum, TeamId::WORLD);
        let got = ctx.read_local_vec(dest);
        // sum over r of (r+1)*0.5 + i = 0.5*n(n+1)/2 + n*i
        let base = 0.5 * (n * (n + 1) / 2) as f32;
        got.iter()
            .enumerate()
            .all(|(i, &v)| (v - (base + (n * i) as f32)).abs() < 1e-3)
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn reduce_all_ops_integer() {
    let ok = run_npes(4, |ctx| {
        let n = ctx.npes() as i64;
        let dest = ctx.calloc::<i64>(64);
        let src = ctx.calloc::<i64>(64);
        let mine: Vec<i64> = (0..64).map(|i| (ctx.pe() as i64 + 2) * (i as i64 + 1)).collect();
        ctx.write_local(src, &mine);
        let mut all_ok = true;
        for op in [
            ReduceOp::Sum,
            ReduceOp::Prod,
            ReduceOp::Min,
            ReduceOp::Max,
            ReduceOp::And,
            ReduceOp::Or,
            ReduceOp::Xor,
        ] {
            ctx.reduce(dest, src, 64, op, TeamId::WORLD);
            let got = ctx.read_local_vec(dest);
            let want: Vec<i64> = (0..64)
                .map(|i| {
                    let vals = (0..n).map(|r| (r + 2) * (i as i64 + 1));
                    match op {
                        ReduceOp::Sum => vals.sum(),
                        ReduceOp::Prod => vals.product(),
                        ReduceOp::Min => vals.min().unwrap(),
                        ReduceOp::Max => vals.max().unwrap(),
                        ReduceOp::And => vals.fold(-1i64, |a, b| a & b),
                        ReduceOp::Or => vals.fold(0i64, |a, b| a | b),
                        ReduceOp::Xor => vals.fold(0i64, |a, b| a ^ b),
                    }
                })
                .collect();
            all_ok &= got == want;
        }
        all_ok
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn team_scoped_collectives() {
    // Split world into even/odd teams; reduce within each.
    let sums = run_npes(8, |ctx| {
        let parity = ctx.pe() % 2;
        let team = ctx.team_split_strided(TeamId::WORLD, parity, 2, 4);
        let dest = ctx.calloc::<i32>(16);
        let src = ctx.calloc::<i32>(16);
        ctx.write_local(src, &vec![ctx.pe() as i32; 16]);
        ctx.reduce(dest, src, 16, ReduceOp::Sum, team);
        ctx.barrier_all();
        ctx.read_local_vec(dest)[0]
    })
    .unwrap();
    // evens: 0+2+4+6 = 12; odds: 1+3+5+7 = 16.
    for (pe, s) in sums.iter().enumerate() {
        assert_eq!(*s, if pe % 2 == 0 { 12 } else { 16 }, "pe {pe}");
    }
}

#[test]
fn shared_team_is_node_scoped() {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 3, 2),
        ..Default::default()
    };
    let sums = run_spmd(cfg, false, |ctx| {
        let dest = ctx.calloc::<u64>(4);
        let src = ctx.calloc::<u64>(4);
        ctx.write_local(src, &[1u64; 4]);
        ctx.reduce(dest, src, 4, ReduceOp::Sum, TeamId::SHARED);
        ctx.barrier_all();
        ctx.read_local_vec(dest)[0]
    })
    .unwrap();
    // Each node has 6 PEs; every PE contributed 1 within its node.
    assert!(sums.iter().all(|&s| s == 6), "{sums:?}");
}

#[test]
fn internode_world_collectives() {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        ..Default::default()
    };
    let ok = run_spmd(cfg, false, |ctx| {
        let n = ctx.npes();
        let dest = ctx.calloc::<u32>(16 * n);
        let src = ctx.calloc::<u32>(16);
        ctx.write_local(src, &vec![ctx.pe() as u32; 16]);
        ctx.barrier_all();
        ctx.fcollect(dest, src, 16, TeamId::WORLD);
        let all = ctx.read_local_vec(dest);
        (0..n).all(|r| (0..16).all(|i| all[r * 16 + i] == r as u32))
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}
