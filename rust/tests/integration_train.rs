//! Integration: the e2e data-parallel trainer (L1+L2+L3 composed).
//! Short runs on the tiny model; the full e2e experiment lives in
//! examples/train_dataparallel.rs (EXPERIMENTS.md E12).

use rishmem::runtime::Manifest;
use rishmem::train::{train_data_parallel, TokenStream, TrainConfig};

fn artifacts_ready() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

#[test]
fn tiny_model_loss_decreases() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = TrainConfig {
        model: "tiny".into(),
        pes: 2,
        steps: 30,
        lr: 0.5,
        seed: 7,
        log_every: 10,
        eval_every: 0,
    };
    let report = train_data_parallel(&cfg).unwrap();
    assert!(report.first_loss.is_finite() && report.final_loss.is_finite());
    // tiny vocab=64 → initial loss ≈ ln 64 ≈ 4.16; Markov corpus is
    // learnable, so 30 steps must visibly move it.
    assert!(
        report.final_loss < report.first_loss - 0.05,
        "no learning: {} -> {}",
        report.first_loss,
        report.final_loss
    );
    // The gradient allreduce must have exercised the Pallas kernel path
    // (tiny has 15,200 params → 1 full chunk per fold).
    assert!(
        report.xla_reduce_calls > 0,
        "grad allreduce never hit the XLA kernel"
    );
    assert_eq!(report.param_count, 15_200);
}

#[test]
fn training_is_deterministic_across_runs() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = TrainConfig {
        model: "tiny".into(),
        pes: 2,
        steps: 5,
        lr: 0.5,
        seed: 123,
        log_every: 1,
        eval_every: 0,
    };
    let a = train_data_parallel(&cfg).unwrap();
    let b = train_data_parallel(&cfg).unwrap();
    assert_eq!(a.losses, b.losses, "same seed must reproduce the loss curve");
}

#[test]
fn data_parallel_equals_single_pe_on_same_global_batch() {
    // Sanity: with 1 PE the trainer still works end to end.
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = TrainConfig {
        model: "tiny".into(),
        pes: 1,
        steps: 3,
        lr: 0.1,
        seed: 3,
        log_every: 1,
        eval_every: 0,
    };
    let r = train_data_parallel(&cfg).unwrap();
    assert_eq!(r.losses.len(), 3);
}

#[test]
fn token_stream_is_learnable_structure() {
    // The Markov stream must be predictable above chance — otherwise the
    // loss-decrease assertions above are vacuous.
    let mut s = TokenStream::new(64, 9, 0);
    let toks = s.batch(8, 256);
    let mut correct = 0usize;
    let mut table = std::collections::HashMap::new();
    // Learn the argmax bigram table from the first half…
    for w in toks[..1024].windows(2) {
        *table
            .entry(w[0])
            .or_insert_with(std::collections::HashMap::new)
            .entry(w[1])
            .or_insert(0usize) += 1;
    }
    // …and predict the second half.
    let mut total = 0usize;
    for w in toks[1024..].windows(2) {
        if let Some(nexts) = table.get(&w[0]) {
            let best = nexts.iter().max_by_key(|(_, &c)| c).map(|(t, _)| *t);
            total += 1;
            if best == Some(w[1]) {
                correct += 1;
            }
        }
    }
    let acc = correct as f64 / total.max(1) as f64;
    assert!(acc > 0.3, "stream unlearnable: bigram acc {acc:.3}");
}
