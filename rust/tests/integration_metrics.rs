//! Integration: per-path traffic counters and transfer-plan counters are
//! populated by real traffic on every route (load/store, copy-engine,
//! NIC), and the adaptive table records feedback under
//! the adaptive cutover mode.

use rishmem::ishmem::CutoverConfig;
use rishmem::{Ishmem, IshmemConfig, Topology};

#[test]
fn per_path_byte_counters_populated() {
    // 2 nodes × 2 GPUs × 2 tiles: PE 0 can hit every route from one rank.
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(1 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            // Small same-node put → load/store path.
            ctx.put(buf, &[1u8; 64], 2);
            // Huge same-node put → copy-engine path under Tuned.
            ctx.put(buf, &vec![2u8; 1 << 20], 2);
            // Cross-node put → NIC path.
            ctx.put(buf, &[3u8; 512], 7);
        }
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();

    assert!(snap.bytes_loadstore >= 64, "load/store bytes: {snap:?}");
    assert!(snap.bytes_copy_engine >= 1 << 20, "copy-engine bytes: {snap:?}");
    assert!(snap.bytes_nic >= 512, "nic bytes: {snap:?}");

    // Every route was planned through the xfer engine.
    assert!(snap.xfer_plans_loadstore >= 1, "{snap:?}");
    assert!(snap.xfer_plans_copy_engine >= 1, "{snap:?}");
    assert!(snap.xfer_plans_nic >= 1, "{snap:?}");
    assert_eq!(
        snap.total_xfer_plans(),
        snap.xfer_plans_loadstore + snap.xfer_plans_copy_engine + snap.xfer_plans_nic
    );
    // Tuned mode performs no online refinement.
    assert_eq!(snap.adaptive_updates, 0, "{snap:?}");
}

#[test]
fn adaptive_mode_records_feedback() {
    let cfg = IshmemConfig {
        cutover: CutoverConfig::adaptive(),
        ..IshmemConfig::with_npes(4)
    };
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(1 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            for _ in 0..4 {
                ctx.put(buf, &[7u8; 4096], 2);
                ctx.put(buf, &vec![8u8; 1 << 20], 2);
            }
        }
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    let cells = ish.xfer.adaptive_snapshot();
    ish.shutdown();

    assert!(snap.adaptive_updates >= 8, "no adaptive feedback: {snap:?}");
    assert!(!cells.is_empty(), "adaptive table stayed empty");
    let observed: u64 = cells
        .iter()
        .map(|c| c.samples_loadstore + c.samples_copy_engine)
        .sum();
    assert!(observed >= 8, "table cells saw no samples: {cells:?}");
}
