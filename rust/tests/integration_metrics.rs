//! Integration: per-path traffic counters and transfer-plan counters are
//! populated by real traffic on every route (load/store, copy-engine,
//! NIC), with per-locality byte breakdowns; the adaptive table records
//! feedback under the adaptive cutover mode; and batched submission
//! populates the batch-depth and proxy service-time metrics.

use rishmem::coordinator::metrics::{PathIdx, ServiceOp, ENGINE_SLOTS, RAIL_SLOTS};
use rishmem::ishmem::CutoverConfig;
use rishmem::util::json::Json;
use rishmem::{Ishmem, IshmemConfig, Locality, TeamId, Topology};

#[test]
fn per_path_byte_counters_populated() {
    // 2 nodes × 2 GPUs × 2 tiles: PE 0 can hit every route from one rank.
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(1 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            // Small same-node put → load/store path.
            ctx.put(buf, &[1u8; 64], 2);
            // Huge same-node put → copy-engine path under Tuned.
            ctx.put(buf, &vec![2u8; 1 << 20], 2);
            // Cross-node put → NIC path.
            ctx.put(buf, &[3u8; 512], 7);
        }
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();

    assert!(snap.bytes_loadstore >= 64, "load/store bytes: {snap:?}");
    assert!(snap.bytes_copy_engine >= 1 << 20, "copy-engine bytes: {snap:?}");
    assert!(snap.bytes_nic >= 512, "nic bytes: {snap:?}");

    // Per-locality breakdown: PE 0 → PE 2 is same-node (cross-GPU), the
    // cross-node put is remote — and each per-path total must equal its
    // locality rows' sum (every call site reports a locality).
    assert!(
        snap.path_loc_bytes(PathIdx::LoadStore, Locality::SameNode) >= 64,
        "{snap:?}"
    );
    assert!(
        snap.path_loc_bytes(PathIdx::CopyEngine, Locality::SameNode) >= 1 << 20,
        "{snap:?}"
    );
    assert!(
        snap.path_loc_bytes(PathIdx::Nic, Locality::Remote) >= 512,
        "{snap:?}"
    );
    assert_eq!(snap.path_bytes_sum(PathIdx::LoadStore), snap.bytes_loadstore);
    assert_eq!(snap.path_bytes_sum(PathIdx::CopyEngine), snap.bytes_copy_engine);
    assert_eq!(snap.path_bytes_sum(PathIdx::Nic), snap.bytes_nic);

    // Every route was planned through the xfer engine.
    assert!(snap.xfer_plans_loadstore >= 1, "{snap:?}");
    assert!(snap.xfer_plans_copy_engine >= 1, "{snap:?}");
    assert!(snap.xfer_plans_nic >= 1, "{snap:?}");
    assert_eq!(
        snap.total_xfer_plans(),
        snap.xfer_plans_loadstore + snap.xfer_plans_copy_engine + snap.xfer_plans_nic
    );
    // Tuned mode performs no online refinement.
    assert_eq!(snap.adaptive_updates, 0, "{snap:?}");
}

#[test]
fn batch_and_service_metrics_populated() {
    // 8 NBI puts at depth 4 → two full batches; a blocking put → one
    // depth-1 batch. Engine route pinned so everything batches.
    let cfg = IshmemConfig {
        cutover: CutoverConfig::always(),
        max_batch_depth: 4,
        ..IshmemConfig::with_npes(4)
    };
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(16 << 10);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            let data = vec![0x11u8; 1024];
            for i in 0..8 {
                ctx.put_nbi(buf.slice(i * 1024, 1024), &data, 2);
            }
            ctx.quiet();
            ctx.put(buf, &data, 2);
        }
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();

    assert!(snap.xfer_batches >= 3, "batches: {snap:?}");
    assert!(snap.xfer_batch_entries >= 9, "batch entries: {snap:?}");
    // The depth histogram accounts for every serviced batch, and the two
    // capacity flushes land in the 3–4 bucket.
    assert_eq!(
        snap.xfer_batch_depth_hist.iter().sum::<u64>(),
        snap.xfer_batches,
        "{snap:?}"
    );
    assert!(snap.xfer_batch_depth_hist[2] >= 2, "depth-4 bucket: {snap:?}");
    assert!(snap.mean_batch_depth() >= 1.0, "{snap:?}");

    // Proxy service-time metrics: every batched entry is one serviced
    // put; histogram entries match the op counts.
    let put_ops = snap.proxy_service_ops[ServiceOp::Put as usize];
    assert!(put_ops >= 9, "proxy put services: {snap:?}");
    let hist_total: u64 = snap.proxy_service_hist.iter().flatten().sum();
    let ops_total: u64 = snap.proxy_service_ops.iter().sum();
    assert_eq!(hist_total, ops_total, "{snap:?}");

    // Batched ring traffic: 3 doorbells carried 9 ops — far fewer
    // messages than ops.
    assert!(snap.ring_messages < 9 + snap.xfer_batches, "{snap:?}");
}

#[test]
fn stripe_and_engine_metrics_with_json_export() {
    // One oversized engine put populates the stripe histogram and the
    // per-engine dispatch tables, and the JSON export mirrors the
    // snapshot (the `rishmem metrics --json` surface).
    let cfg = IshmemConfig {
        topology: Topology::new(1, 2, 2),
        heap_bytes: 48 << 20,
        cutover: CutoverConfig::always(),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(4 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            ctx.put(buf, &vec![9u8; 4 << 20], 2);
        }
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();

    assert!(snap.stripe_transfers >= 1, "{snap:?}");
    assert!(snap.stripe_chunks >= 4, "{snap:?}");
    assert_eq!(
        snap.stripe_chunk_hist.iter().sum::<u64>(),
        snap.stripe_transfers,
        "{snap:?}"
    );
    let engines_used = snap.engine_bytes.iter().filter(|&&b| b > 0).count();
    assert!(engines_used >= 2, "striping used {engines_used} engine(s): {snap:?}");
    assert_eq!(snap.engine_bytes.iter().sum::<u64>(), 4 << 20, "{snap:?}");
    assert_eq!(
        snap.engine_ops.iter().sum::<u64>(),
        snap.stripe_chunks,
        "every chunk dispatches on exactly one engine: {snap:?}"
    );

    let j = Json::parse(&snap.to_json()).expect("metrics JSON parses");
    assert_eq!(j.get("puts").unwrap().as_usize().unwrap() as u64, snap.puts);
    assert_eq!(
        j.get("stripe_chunks").unwrap().as_usize().unwrap() as u64,
        snap.stripe_chunks
    );
    let eng = j.get("engine_bytes").unwrap().as_arr().unwrap();
    assert_eq!(eng.len(), ENGINE_SLOTS);
    let eng_sum: u64 = eng.iter().map(|v| v.as_usize().unwrap() as u64).sum();
    assert_eq!(eng_sum, snap.engine_bytes.iter().sum::<u64>());
    assert!(j.get("bytes_by_path_loc").unwrap().get("copy_engine").is_some());
    assert_eq!(
        j.get("xfer_batches").unwrap().as_usize().unwrap() as u64,
        snap.xfer_batches
    );
}

#[test]
fn chain_metrics_populated_and_disabled_counts_nothing() {
    // Triggered chains (ISSUE 10): with `chain.enable` every fused
    // put-signal counts one chain submission, its dependent stage is
    // released host-side (`chain_triggered`), and the reclaimed doorbells
    // are ledgered; the depth histogram accounts for every submitted
    // chain and the text report + `rishmem metrics --json` surface all of
    // it. The default (disabled) machine moves the same traffic with
    // every chain counter pinned at zero.
    use rishmem::ishmem::signal::SignalOp;
    let run = |enable: bool| {
        let mut cfg = IshmemConfig {
            topology: Topology::new(1, 2, 2),
            heap_bytes: 48 << 20,
            cutover: CutoverConfig::always(),
            ..Default::default()
        };
        cfg.chain.enable = enable;
        let ish = Ishmem::new(cfg).unwrap();
        ish.launch(|ctx| {
            let inbox = ctx.calloc::<u8>(64 << 10);
            let sig = ctx.calloc::<u64>(1);
            ctx.barrier_all();
            if ctx.pe() == 0 {
                let payload = vec![9u8; 32 << 10];
                for i in 0..4u64 {
                    ctx.put_then_signal(inbox, &payload, sig, i + 1, SignalOp::Set, 2);
                }
            }
            ctx.barrier_all();
        });
        let snap = ish.metrics.snapshot();
        ish.shutdown();
        snap
    };

    let on = run(true);
    assert!(on.chain_submitted >= 4, "chains never fused: {on:?}");
    assert!(on.chain_triggered >= 4, "successors never released: {on:?}");
    assert!(on.chain_fused_doorbells >= 4, "no doorbells reclaimed: {on:?}");
    assert_eq!(
        on.chain_depth_hist.iter().sum::<u64>(),
        on.chain_submitted,
        "depth histogram must account for every chain: {on:?}"
    );
    let report = on.report();
    assert!(report.contains("chain: submitted="), "{report}");
    let j = Json::parse(&on.to_json()).unwrap();
    assert_eq!(
        j.get("chain_submitted").unwrap().as_usize().unwrap() as u64,
        on.chain_submitted
    );
    assert_eq!(
        j.get("chain_triggered").unwrap().as_usize().unwrap() as u64,
        on.chain_triggered
    );
    assert_eq!(
        j.get("chain_fused_doorbells").unwrap().as_usize().unwrap() as u64,
        on.chain_fused_doorbells
    );
    assert_eq!(
        j.get("chain_depth_hist").unwrap().as_arr().unwrap().len(),
        on.chain_depth_hist.len()
    );

    let off = run(false);
    assert!(off.puts >= 4, "disabled workload did not run: {off:?}");
    assert_eq!(
        (
            off.chain_submitted,
            off.chain_triggered,
            off.chain_fused_doorbells,
            off.chain_flushed_unfusable,
        ),
        (0, 0, 0, 0),
        "disabled chains counted: {off:?}"
    );
    assert_eq!(off.chain_depth_hist.iter().sum::<u64>(), 0, "{off:?}");
}

#[test]
fn plan_cache_counters_surface_in_text_and_json() {
    // Repeated same-shape puts hit the plan cache; the counters surface
    // in the `rishmem metrics` text report and the --json export. A
    // cache-disabled machine moves the same traffic with every counter
    // pinned at zero.
    let run = |enable: bool| {
        let mut cfg = IshmemConfig::with_npes(4);
        cfg.plan_cache.enable = enable;
        let ish = Ishmem::new(cfg).unwrap();
        ish.launch(|ctx| {
            let buf = ctx.calloc::<u8>(64 << 10);
            ctx.barrier_all();
            if ctx.pe() == 0 {
                for _ in 0..8 {
                    ctx.put(buf, &[7u8; 4096], 2);
                }
                ctx.quiet();
            }
            ctx.barrier_all();
        });
        let snap = ish.metrics.snapshot();
        ish.shutdown();
        snap
    };

    let snap = run(true);
    assert!(snap.plan_cache_misses >= 1, "{snap:?}");
    assert!(snap.plan_cache_hits >= 7, "repeated shapes must hit: {snap:?}");
    assert_eq!(snap.plan_cache_invalidations, 0, "nothing recalibrated: {snap:?}");
    let report = snap.report();
    assert!(report.contains("plan cache: hits="), "{report}");
    let j = Json::parse(&snap.to_json()).unwrap();
    assert_eq!(
        j.get("plan_cache_hits").unwrap().as_usize().unwrap() as u64,
        snap.plan_cache_hits
    );
    assert_eq!(
        j.get("plan_cache_misses").unwrap().as_usize().unwrap() as u64,
        snap.plan_cache_misses
    );
    assert_eq!(
        j.get("plan_cache_invalidations").unwrap().as_usize().unwrap() as u64,
        snap.plan_cache_invalidations
    );

    let off = run(false);
    assert_eq!(
        (off.plan_cache_hits, off.plan_cache_misses, off.plan_cache_invalidations),
        (0, 0, 0),
        "disabled cache must not count: {off:?}"
    );
}

#[test]
fn collective_fanout_plans_ride_the_plan_cache() {
    // A collective loop replays the same fan-out layout every iteration;
    // plan_fanout memoizes through the p2p PlanCache, so the root's
    // repeated broadcasts are one miss and the rest hits.
    let cfg = IshmemConfig::with_npes(8);
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let dest = ctx.calloc::<u8>(32 << 10);
        let src = ctx.calloc::<u8>(32 << 10);
        ctx.barrier_all();
        for _ in 0..8 {
            ctx.broadcast(dest, src, 32 << 10, 0, TeamId::WORLD);
        }
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();
    assert!(snap.plan_cache_misses >= 1, "{snap:?}");
    assert!(snap.plan_cache_hits >= 7, "repeated fan-outs must hit: {snap:?}");
}

#[test]
fn adaptive_table_persists_across_machines() {
    // `cutover.table_path`: machine A learns and saves at shutdown;
    // machine B starts warm with the identical table.
    let path = std::env::temp_dir().join(format!(
        "rishmem_adaptive_table_{}.json",
        std::process::id()
    ));
    let path_s = path.to_str().unwrap().to_string();
    let cfg = IshmemConfig {
        cutover: CutoverConfig::adaptive().with_table_path(path_s.clone()),
        ..IshmemConfig::with_npes(4)
    };
    let ish = Ishmem::new(cfg.clone()).unwrap();
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(1 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            for _ in 0..4 {
                ctx.put(buf, &[7u8; 4096], 2);
                ctx.put(buf, &vec![8u8; 1 << 20], 2);
            }
        }
        ctx.barrier_all();
    });
    let learned = ish.xfer.adaptive_snapshot();
    assert!(!learned.is_empty(), "nothing learned to persist");
    ish.shutdown(); // writes the table
    assert!(path.exists(), "shutdown did not save the table");

    let warm = Ishmem::new(cfg).unwrap();
    let loaded = warm.xfer.adaptive_snapshot();
    warm.shutdown();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded.len(), learned.len(), "loaded table diverged");
    for (a, b) in learned.iter().zip(&loaded) {
        assert_eq!(a.key, b.key);
        assert_eq!(
            (a.samples_loadstore, a.samples_copy_engine),
            (b.samples_loadstore, b.samples_copy_engine)
        );
    }
}

#[test]
fn rail_and_service_delta_metrics_populated() {
    // Cross-node striped traffic fills the per-rail dispatch tables and
    // both halves of the wall-vs-model service-delta ledger; the JSON
    // export mirrors them.
    let mut cost = rishmem::sim::cost::CostParams::default();
    cost.nic.rails = 4;
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        cost,
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(2 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            ctx.put(buf, &vec![5u8; 2 << 20], 4); // remote, rail-striped
            ctx.put(buf, &vec![6u8; 512], 4); // remote, small
        }
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();

    assert!(snap.rail_ops.iter().sum::<u64>() >= 2, "{snap:?}");
    assert!(
        snap.rail_bytes.iter().sum::<u64>() >= (2 << 20) as u64,
        "{:?}",
        snap.rail_bytes
    );
    // Both halves of the NIC service-delta ledger saw the traffic.
    let nic = rishmem::coordinator::metrics::PathIdx::Nic as usize;
    assert!(snap.service_wall_ops[nic].iter().sum::<u64>() >= 2, "{snap:?}");
    assert!(snap.service_model_ops[nic].iter().sum::<u64>() >= 2, "{snap:?}");
    assert!(snap.service_model_ns[nic].iter().sum::<u64>() > 0, "{snap:?}");
    let report = snap.service_delta_report();
    assert!(report.contains("nic"), "{report}");

    let j = Json::parse(&snap.to_json()).unwrap();
    let rails = j.get("rail_bytes").unwrap().as_arr().unwrap();
    assert_eq!(
        rails.iter().map(|v| v.as_usize().unwrap() as u64).sum::<u64>(),
        snap.rail_bytes.iter().sum::<u64>()
    );
    assert!(j.get("service_model_ns").unwrap().as_arr().is_some());
}

#[test]
fn adaptive_mode_records_feedback() {
    let cfg = IshmemConfig {
        cutover: CutoverConfig::adaptive(),
        ..IshmemConfig::with_npes(4)
    };
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(1 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            for _ in 0..4 {
                ctx.put(buf, &[7u8; 4096], 2);
                ctx.put(buf, &vec![8u8; 1 << 20], 2);
            }
        }
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    let cells = ish.xfer.adaptive_snapshot();
    ish.shutdown();

    assert!(snap.adaptive_updates >= 8, "no adaptive feedback: {snap:?}");
    assert!(!cells.is_empty(), "adaptive table stayed empty");
    let observed: u64 = cells
        .iter()
        .map(|c| c.samples_loadstore + c.samples_copy_engine)
        .sum();
    assert!(observed >= 8, "table cells saw no samples: {cells:?}");
}

#[test]
fn live_calibration_populates_ledgers_and_snapshot_json() {
    // A calib-enabled machine run through every proxied path: the proxy
    // tags serviced entries with lane + wall ns and the calibrator's
    // ledgers populate. Wall clocks on this substrate are nondeterministic
    // garbage relative to the modeled Aurora hardware, so the test asserts
    // plumbing (samples flow, clamps hold, JSON parses) — convergence is
    // property-tested against synthetic streams in xfer::calibrate and
    // asserted end-to-end by the fig_calib bench.
    let mut cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        cutover: CutoverConfig::always(),
        ..Default::default()
    };
    cfg.calib.enable = true;
    let ish = Ishmem::new(cfg).unwrap();
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(4 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            for size in [2 << 10, 128 << 10, 2 << 20] {
                ctx.put(buf, &vec![1u8; size], 2); // same-node → engine lanes
                ctx.put(buf, &vec![2u8; size], 4); // cross-node → rail lanes
            }
            ctx.quiet();
        }
        ctx.barrier_all();
    });
    let calib = ish.calib.snapshot();
    let seed = ish.cost.model.seed();
    let live = ish.cost.model.get();
    ish.shutdown();

    assert!(calib.enabled);
    assert!(
        !calib.classes.is_empty(),
        "proxy observations never reached the calibrator"
    );
    let total: u64 = calib.classes.iter().map(|c| c.samples).sum();
    assert!(total >= 6, "too few tagged observations: {calib:?}");
    // Whatever the wall clocks said, the clamp keeps learned values
    // within clamp_frac of the seed (fractions additionally ≤ 1).
    let cfg_clamp = ish.config.calib.clamp_frac;
    assert!(live.single_engine_frac <= (seed.single_engine_frac * cfg_clamp).min(1.0) + 1e-12);
    assert!(live.single_engine_frac >= seed.single_engine_frac / cfg_clamp - 1e-12);
    assert!(live.rail_bw_frac <= 1.0 + 1e-12);
    // The metrics JSON carries the calibration snapshot at the top level.
    let text = ish
        .metrics
        .snapshot()
        .to_json_with(vec![("calibration".to_string(), calib.to_json())]);
    let j = Json::parse(&text).unwrap();
    let c = j.get("calibration").expect("calibration key");
    assert_eq!(c.get("enabled"), Some(&Json::Bool(true)));
    assert!(c.get("params").unwrap().as_arr().unwrap().len() >= 6);
    assert!(c.get("mean_residual").unwrap().as_f64().is_some());
}

/// The traffic pattern both fault-metrics tests drive: alternating large
/// same-node and cross-node puts from PE 0 so the proxy's op clock keeps
/// advancing through engine-hinted batches and rail-hinted batches.
fn fault_workload(ish: &std::sync::Arc<Ishmem>) {
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(2 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            let big = vec![7u8; 2 << 20];
            for _ in 0..32 {
                ctx.put(buf, &big, 2); // same-node → engine-hinted chunks
                ctx.put(buf, &big, 4); // cross-node → rail-hinted chunks
            }
            ctx.quiet();
        }
        ctx.barrier_all();
    });
}

#[test]
fn fault_metrics_populated_and_json_exported() {
    // Scripted total outage: every NIC rail on node 0 and every engine on
    // GPU 0 dies at proxy op 12 and revives at op 24. While degraded, new
    // same-node plans fall back to load/store and remote descriptors hit
    // the dead-rail check — both count `fault_last_lane_fallbacks`. After
    // the revives the machine must report fully healed (gauges at zero,
    // degraded flag clear), and the JSON export mirrors every counter.
    let mut cost = rishmem::sim::cost::CostParams::default();
    cost.nic.rails = 4;
    let rails = cost.nic.rails;
    let engines = cost.ce.engines_per_gpu;
    let mut cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        cutover: CutoverConfig::always(),
        cost,
        ..Default::default()
    };
    cfg.fault.enable = true;
    for r in 0..rails {
        cfg.fault.events.push(rishmem::sim::FaultEvent::kill_rail(12, 0, r));
        cfg.fault.events.push(rishmem::sim::FaultEvent::revive_rail(24, 0, r));
    }
    for e in 0..engines {
        cfg.fault.events.push(rishmem::sim::FaultEvent::kill_engine(12, 0, e));
        cfg.fault.events.push(rishmem::sim::FaultEvent::revive_engine(24, 0, e));
    }
    let ish = Ishmem::new(cfg).unwrap();
    fault_workload(&ish);
    let snap = ish.metrics.snapshot();
    let healed = !ish.cost.degraded();
    ish.shutdown();

    assert_eq!(snap.fault_rail_kills, rails as u64, "{snap:?}");
    assert_eq!(snap.fault_rail_revives, rails as u64, "{snap:?}");
    assert_eq!(snap.fault_engine_kills, engines as u64, "{snap:?}");
    assert_eq!(snap.fault_engine_revives, engines as u64, "{snap:?}");
    assert!(
        snap.fault_last_lane_fallbacks >= 1,
        "degraded window moved traffic without counting a fallback: {snap:?}"
    );
    assert!(healed, "revives did not clear the health masks");
    assert_eq!(snap.degraded_mode, 0, "{snap:?}");
    assert!(snap.rail_dead.iter().all(|&d| d == 0), "{:?}", snap.rail_dead);
    assert!(snap.engine_dead.iter().all(|&d| d == 0), "{:?}", snap.engine_dead);

    let report = snap.report();
    assert!(report.contains("fault"), "{report}");
    let j = Json::parse(&snap.to_json()).unwrap();
    assert_eq!(
        j.get("fault_rail_kills").unwrap().as_usize().unwrap(),
        rails,
    );
    assert_eq!(
        j.get("fault_engine_revives").unwrap().as_usize().unwrap(),
        engines,
    );
    assert_eq!(
        j.get("fault_last_lane_fallbacks").unwrap().as_usize().unwrap() as u64,
        snap.fault_last_lane_fallbacks
    );
    assert_eq!(j.get("degraded_mode").unwrap().as_usize(), Some(0));
    assert_eq!(j.get("rail_dead").unwrap().as_arr().unwrap().len(), RAIL_SLOTS);
    assert_eq!(j.get("engine_dead").unwrap().as_arr().unwrap().len(), ENGINE_SLOTS);
}

#[test]
fn disabled_fault_plane_counts_nothing() {
    // Default config (fault.enable = false): the identical workload moves
    // real traffic with every fault counter and lane gauge pinned at zero
    // — the disabled plane never ticks and never re-routes.
    let mut cost = rishmem::sim::cost::CostParams::default();
    cost.nic.rails = 4;
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        cutover: CutoverConfig::always(),
        cost,
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    assert!(!ish.fault.enabled());
    fault_workload(&ish);
    let snap = ish.metrics.snapshot();
    let ops = ish.fault.ops();
    ish.shutdown();

    assert!(snap.puts >= 64, "workload did not run: {snap:?}");
    assert_eq!(ops, 0, "disabled plane ticked its op clock");
    assert_eq!(
        (
            snap.fault_rail_kills,
            snap.fault_rail_revives,
            snap.fault_engine_kills,
            snap.fault_engine_revives,
            snap.fault_quarantines,
            snap.fault_probes,
            snap.fault_redispatched_chunks,
            snap.fault_last_lane_fallbacks,
        ),
        (0, 0, 0, 0, 0, 0, 0, 0),
        "disabled fault plane counted: {snap:?}"
    );
    assert_eq!(snap.degraded_mode, 0, "{snap:?}");
    assert!(snap.rail_dead.iter().chain(snap.engine_dead.iter()).all(|&d| d == 0));
}

#[test]
fn disabled_calibration_is_bit_identical_to_the_seed_model() {
    // The other half of the acceptance bar, end-to-end: a default
    // (calib.enable = false) machine services real traffic and the
    // ModelParams store never moves — version 0, seed bits intact, so
    // every plan estimate is bit-identical to the pre-calibration code.
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    let est_before = ish.xfer.est_copy_engine_ns(Locality::SameNode, 1 << 20);
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(1 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            ctx.put(buf, &vec![5u8; 1 << 20], 2);
            ctx.put(buf, &[6u8; 512], 7);
            ctx.quiet();
        }
        ctx.barrier_all();
    });
    assert_eq!(ish.cost.model.version(), 0, "traffic must not move a disabled model");
    assert_eq!(
        ish.cost.model.get().single_engine_frac.to_bits(),
        ish.cost.model.seed().single_engine_frac.to_bits()
    );
    assert_eq!(
        ish.xfer.est_copy_engine_ns(Locality::SameNode, 1 << 20).to_bits(),
        est_before.to_bits(),
        "estimates drifted without calibration"
    );
    let calib = ish.calib.snapshot();
    ish.shutdown();
    assert!(!calib.enabled);
    assert!(calib.classes.is_empty(), "disabled calibrator accumulated state");
}
