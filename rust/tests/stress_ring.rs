//! Stress: the reverse-offload ring + completion pool under heavy real
//! concurrency, and the paper's §III-D claims in wall-clock terms.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use rishmem::ringbuf::{CompletionPool, Message, Ring, RingOp, COMPLETION_NONE};

#[test]
fn sustained_multiproducer_throughput() {
    // The paper claims >20M req/s on real HW with a single service thread;
    // on this 1-core CI box we only assert sustained six-figure throughput
    // and zero loss. (benches/ring_buffer.rs reports the actual rate.)
    const PRODUCERS: usize = 4;
    const PER: u64 = 25_000;
    let ring = Ring::new(1024);
    let mut consumer = ring.consumer();
    let done = Arc::new(AtomicU64::new(0));

    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for p in 0..PRODUCERS {
            let r = Arc::clone(&ring);
            s.spawn(move || {
                for i in 0..PER {
                    let mut m = Message::nop();
                    m.src_pe = p as u32;
                    m.inline_val = i;
                    r.send(m);
                }
            });
        }
        let d = done.clone();
        s.spawn(move || {
            let mut counts = [0u64; PRODUCERS];
            for _ in 0..(PRODUCERS as u64 * PER) {
                let m = consumer.recv();
                counts[m.src_pe as usize] += 1;
            }
            assert!(counts.iter().all(|&c| c == PER), "{counts:?}");
            d.store(1, Ordering::Release);
        });
    });
    let dt = t0.elapsed();
    assert_eq!(done.load(Ordering::Acquire), 1);
    let rate = (PRODUCERS as f64 * PER as f64) / dt.as_secs_f64();
    eprintln!("ring throughput: {:.2} M msg/s", rate / 1e6);
    assert!(rate > 100_000.0, "ring too slow: {rate}/s");
}

#[test]
fn blocking_roundtrips_with_out_of_order_completions() {
    // Producers issue fetching requests; a slow server completes them in
    // reversed batches. Every waiter must get *its* value.
    let ring = Ring::new(256);
    let pool = Arc::new(CompletionPool::new(64));
    let mut consumer = ring.consumer();
    const THREADS: usize = 6;
    const PER: u64 = 2_000;

    std::thread::scope(|s| {
        for t in 0..THREADS {
            let r = Arc::clone(&ring);
            let p = pool.clone();
            s.spawn(move || {
                for i in 0..PER {
                    let token = p.alloc();
                    let mut m = Message::nop();
                    m.op = RingOp::Amo as u8;
                    m.completion = token.index;
                    m.inline_val = (t as u64) << 32 | i;
                    r.send(m);
                    assert_eq!(p.wait(token), ((t as u64) << 32 | i) + 1);
                }
            });
        }
        let p = pool.clone();
        s.spawn(move || {
            let mut served = 0;
            let mut batch = Vec::with_capacity(32);
            while served < THREADS as u64 * PER {
                batch.clear();
                let n = consumer.recv_batch(&mut batch, 32);
                // Complete in reverse order to force OOO delivery.
                for m in batch.iter().rev() {
                    if m.completion != COMPLETION_NONE {
                        p.complete(m.completion, m.inline_val + 1);
                    }
                }
                served += n as u64;
                if n == 0 {
                    std::thread::yield_now();
                }
            }
        });
    });
    assert_eq!(pool.free_count(), 64);
}

#[test]
fn ring_survives_full_backpressure() {
    // Tiny ring, bursty producers: flow control must kick in without loss.
    let ring = Ring::new(4);
    let mut consumer = ring.consumer();
    std::thread::scope(|s| {
        for _ in 0..8 {
            let r = Arc::clone(&ring);
            s.spawn(move || {
                for _ in 0..500 {
                    r.send(Message::nop());
                }
            });
        }
        s.spawn(move || {
            for _ in 0..8 * 500 {
                consumer.recv();
            }
            assert!(consumer.try_recv().is_none());
        });
    });
}

#[test]
fn proxy_shutdown_is_clean_under_load() {
    // Spin up a full machine, hammer proxied ops, and drop it — shutdown
    // must join the proxy without hanging or losing completions.
    use rishmem::ishmem::CutoverConfig;
    use rishmem::IshmemConfig;
    for _ in 0..3 {
        let cfg = IshmemConfig {
            cutover: CutoverConfig::always(),
            ..IshmemConfig::with_npes(4)
        };
        let ish = rishmem::Ishmem::new(cfg).unwrap();
        let ok = ish.launch(|ctx| {
            let buf = ctx.calloc::<u64>(512);
            for i in 0..20u64 {
                ctx.put(buf, &vec![i; 512], (ctx.pe() + 1) % 4);
            }
            ctx.barrier_all();
            ctx.read_local_vec(buf)[0] == 19
        });
        assert!(ok.iter().all(|&b| b));
        ish.shutdown();
    }
}
