//! Integration: device/host RMA, AMOs, signals, ordering across a full
//! simulated node (12 PEs, real threads, real proxy).

use rishmem::ishmem::signal::SignalOp;
use rishmem::ishmem::CutoverConfig;
use rishmem::{run_npes, run_spmd, Cmp, Ishmem, IshmemConfig, Topology, WorkGroup};

#[test]
fn ring_exchange_put() {
    // Every PE puts its rank-stamped buffer to its right neighbour.
    let n = 12;
    let ok = run_npes(n, |ctx| {
        let buf = ctx.calloc::<u64>(256);
        let me = ctx.pe() as u64;
        let data: Vec<u64> = (0..256).map(|i| me * 1000 + i).collect();
        let right = (ctx.pe() + 1) % ctx.npes();
        ctx.put(buf, &data, right);
        ctx.barrier_all();
        let left = (ctx.pe() + ctx.npes() - 1) % ctx.npes();
        let got = ctx.read_local_vec(buf);
        got.iter()
            .enumerate()
            .all(|(i, &v)| v == (left as u64) * 1000 + i as u64)
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b), "ring exchange corrupted: {ok:?}");
}

#[test]
fn get_reads_remote() {
    let ok = run_npes(4, |ctx| {
        let buf = ctx.malloc::<i32>(64);
        let mine: Vec<i32> = (0..64).map(|i| (ctx.pe() * 100 + i) as i32).collect();
        ctx.write_local(buf, &mine);
        ctx.barrier_all();
        let mut out = vec![0i32; 64];
        let target = (ctx.pe() + 2) % ctx.npes();
        ctx.get(&mut out, buf, target);
        out.iter()
            .enumerate()
            .all(|(i, &v)| v == (target * 100 + i) as i32)
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn put_correct_on_every_path() {
    // Force each cutover mode; bytes must land identically.
    for mode in [
        CutoverConfig::never(),
        CutoverConfig::always(),
        CutoverConfig::tuned(),
        CutoverConfig::adaptive(),
    ] {
        let cfg = IshmemConfig {
            cutover: mode.clone(),
            ..IshmemConfig::with_npes(6)
        };
        let ok = run_spmd(cfg, false, |ctx| {
            let buf = ctx.calloc::<u8>(100_000);
            let payload = vec![ctx.pe() as u8 + 1; 100_000];
            let target = (ctx.pe() + 3) % ctx.npes();
            ctx.put(buf, &payload, target);
            ctx.barrier_all();
            let src = (ctx.pe() + ctx.npes() - 3) % ctx.npes();
            ctx.read_local_vec(buf).iter().all(|&b| b == src as u8 + 1)
        })
        .unwrap();
        assert!(ok.iter().all(|&b| b), "mode {mode:?} corrupted data");
    }
}

#[test]
fn work_group_put_matches_scalar_put() {
    let ok = run_npes(4, |ctx| {
        let a = ctx.calloc::<f32>(4096);
        let b = ctx.calloc::<f32>(4096);
        let data: Vec<f32> = (0..4096).map(|i| i as f32 * 0.5).collect();
        let t = (ctx.pe() + 1) % ctx.npes();
        ctx.put(a, &data, t);
        let wg = WorkGroup::new(128);
        ctx.put_work_group(b, &data, t, &wg);
        ctx.barrier_all();
        ctx.read_local_vec(a) == ctx.read_local_vec(b)
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn scalar_p_and_g() {
    let ok = run_npes(12, |ctx| {
        let cell = ctx.calloc::<i64>(12);
        // Everyone deposits its rank into slot[my_pe] on PE 0.
        ctx.p(cell.at(ctx.pe()), ctx.pe() as i64 * 7, 0);
        ctx.barrier_all();
        if ctx.pe() == 1 {
            (0..12).all(|i| ctx.g(cell.at(i), 0) == i as i64 * 7)
        } else {
            true
        }
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn nbi_completes_at_quiet() {
    let ok = run_npes(4, |ctx| {
        let buf = ctx.calloc::<u32>(4096);
        let data = vec![0xABCD_u32; 4096];
        let t = (ctx.pe() + 1) % ctx.npes();
        ctx.put_nbi(buf, &data, t);
        let before = ctx.clock.now_ns();
        ctx.quiet();
        let after = ctx.clock.now_ns();
        ctx.barrier_all();
        // quiet() must absorb the modeled transfer time.
        let all_there = ctx.read_local_vec(buf).iter().all(|&v| v == 0xABCD);
        all_there && after > before
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn blocking_put_flushes_pending_stream() {
    // An NBI entry sits in the pending command stream (depth 16 ≫ 2)
    // until a blocking op joins the plan-group and flushes it — after the
    // blocking put returns, *both* transfers must be delivered, no quiet.
    let cfg = IshmemConfig {
        cutover: CutoverConfig::always(),
        max_batch_depth: 16,
        ..IshmemConfig::with_npes(4)
    };
    let ok = run_spmd(cfg, false, |ctx| {
        let a = ctx.calloc::<u32>(1024);
        let b = ctx.calloc::<u32>(1024);
        let flag = ctx.calloc::<u64>(1);
        if ctx.pe() == 0 {
            ctx.put_nbi(a, &vec![0xAAAA_u32; 1024], 1);
            ctx.put(b, &vec![0xBBBB_u32; 1024], 1);
            ctx.atomic_set(flag, 1u64, 1);
            ctx.barrier_all();
            true
        } else if ctx.pe() == 1 {
            ctx.wait_until(flag, Cmp::Eq, 1u64);
            let good = ctx.read_local_vec(a).iter().all(|&v| v == 0xAAAA)
                && ctx.read_local_vec(b).iter().all(|&v| v == 0xBBBB);
            ctx.barrier_all();
            good
        } else {
            ctx.barrier_all();
            true
        }
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b), "blocking flush left NBI data undelivered");
}

#[test]
fn quiet_drains_pending_batches() {
    // Five NBI puts below the capacity trigger: nothing is delivered
    // until quiet pushes the plan-group out and drains it.
    let cfg = IshmemConfig {
        cutover: CutoverConfig::always(),
        max_batch_depth: 16,
        ..IshmemConfig::with_npes(4)
    };
    let ish = Ishmem::new(cfg).unwrap();
    let ok = ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(5 * 2048);
        let flag = ctx.calloc::<u64>(1);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            let data = vec![0x5Au8; 2048];
            for i in 0..5 {
                ctx.put_nbi(buf.slice(i * 2048, 2048), &data, 3);
            }
            ctx.quiet();
            ctx.atomic_set(flag, 1u64, 3);
            ctx.barrier_all();
            true
        } else if ctx.pe() == 3 {
            ctx.wait_until(flag, Cmp::Eq, 1u64);
            let good = ctx.read_local_vec(buf).iter().all(|&v| v == 0x5A);
            ctx.barrier_all();
            good
        } else {
            ctx.barrier_all();
            true
        }
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();
    assert!(ok.iter().all(|&b| b), "quiet left batched data undelivered");
    // All five puts rode quiet-flushed doorbells, not per-op messages.
    assert!(snap.xfer_batches >= 1, "{snap:?}");
    assert!(snap.xfer_batch_entries >= 5, "{snap:?}");
}

#[test]
fn nbi_completes_across_batch_boundary() {
    // Ten NBI puts at depth 4: two capacity flushes mid-stream, two
    // entries left pending — quiet must complete every one of them via
    // the tracker, and the modeled horizon must move the clock.
    let cfg = IshmemConfig {
        cutover: CutoverConfig::always(),
        max_batch_depth: 4,
        ..IshmemConfig::with_npes(4)
    };
    let ish = Ishmem::new(cfg).unwrap();
    let ok = ish.launch(|ctx| {
        let buf = ctx.calloc::<u32>(10 * 512);
        ctx.barrier_all();
        let quiet_ok = if ctx.pe() == 0 {
            let data: Vec<u32> = (0..512).collect();
            for i in 0..10 {
                ctx.put_nbi(buf.slice(i * 512, 512), &data, 2);
            }
            let before = ctx.clock.now_ns();
            ctx.quiet();
            let after = ctx.clock.now_ns();
            after > before
        } else {
            true
        };
        ctx.barrier_all();
        let data_ok = if ctx.pe() == 2 {
            let got = ctx.read_local_vec(buf);
            (0..10).all(|i| (0..512).all(|j| got[i * 512 + j] == j as u32))
        } else {
            true
        };
        quiet_ok && data_ok
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();
    assert!(ok.iter().all(|&b| b), "NBI data lost across a batch boundary");
    assert!(
        snap.xfer_batches >= 3,
        "expected 2 capacity flushes + 1 quiet flush: {snap:?}"
    );
}

#[test]
fn oversized_put_chunks_through_slab_striped() {
    // 8 MiB ≫ the 2 MiB staging slab: the payload must chunk *through*
    // the slab (no raw-pointer fallback) and spread across ≥2 engines.
    let cfg = IshmemConfig {
        topology: Topology::new(1, 2, 2),
        heap_bytes: 48 << 20,
        cutover: CutoverConfig::always(),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    let ok = ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(8 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            let payload: Vec<u8> = (0..8 << 20).map(|i| (i % 251) as u8).collect();
            ctx.put(buf, &payload, 2);
        }
        ctx.barrier_all();
        if ctx.pe() == 2 {
            ctx.read_local_vec(buf)
                .iter()
                .enumerate()
                .all(|(i, &v)| v == (i % 251) as u8)
        } else {
            true
        }
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();
    assert!(ok.iter().all(|&b| b), "chunked oversized put corrupted data");
    assert!(snap.stripe_transfers >= 1, "{snap:?}");
    assert!(snap.stripe_chunks >= 8, "8MiB through a ~1MiB chunk cap: {snap:?}");
    let engines_used = snap.engine_bytes.iter().filter(|&&b| b > 0).count();
    assert!(engines_used >= 2, "chunks all on one engine: {:?}", snap.engine_bytes);
    assert_eq!(
        snap.engine_bytes.iter().sum::<u64>(),
        8 << 20,
        "per-engine bytes must cover the payload: {:?}",
        snap.engine_bytes
    );
}

#[test]
fn quiet_drains_all_stripes_of_chunked_nbi_put() {
    // A chunked NBI put reserves backlog across several engines and
    // aggregates its chunks into one deferred completion; quiet must
    // deliver every stripe and return every reserved byte.
    let cfg = IshmemConfig {
        topology: Topology::new(1, 2, 2),
        heap_bytes: 48 << 20,
        cutover: CutoverConfig::always(),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    let ish2 = ish.clone();
    let ok = ish.launch(move |ctx| {
        let buf = ctx.calloc::<u8>(4 << 20);
        let flag = ctx.calloc::<u64>(1);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            let data = vec![0xC3u8; 4 << 20];
            ctx.put_nbi(buf, &data, 2);
            // The striped NBI put left live backlog on PE 0's GPU, and
            // its chunks aggregate into one outstanding completion.
            let loaded = ish2.cost.engine_backlog_bytes(0) >= (4 << 20) as u64
                && ctx.outstanding_chunk_count() >= 4;
            let before = ctx.clock.now_ns();
            ctx.quiet();
            let after = ctx.clock.now_ns();
            let drained = ish2.cost.engine_backlog_bytes(0) == 0
                && ctx.outstanding_chunk_count() == 0;
            ctx.atomic_set(flag, 1u64, 2);
            ctx.barrier_all();
            loaded && drained && after > before
        } else if ctx.pe() == 2 {
            ctx.wait_until(flag, Cmp::Eq, 1u64);
            let good = ctx.read_local_vec(buf).iter().all(|&v| v == 0xC3);
            ctx.barrier_all();
            good
        } else {
            ctx.barrier_all();
            true
        }
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();
    assert!(ok.iter().all(|&b| b), "quiet left stripes undelivered or backlog leaked");
    assert!(snap.stripe_transfers >= 1 && snap.stripe_chunks >= 4, "{snap:?}");
}

#[test]
fn chunked_transfers_correct_at_tiny_batch_depth() {
    // max_batch_depth 1 and 2 shrink the get window below the chunk
    // count: windows must never let a capacity flush release slab claims
    // before copy-out (depth 1 degrades to the raw per-op path; depth 2
    // runs one-chunk windows). Data must survive both ways.
    for depth in [1usize, 2] {
        let cfg = IshmemConfig {
            topology: Topology::new(1, 2, 2),
            heap_bytes: 48 << 20,
            cutover: CutoverConfig::always(),
            max_batch_depth: depth,
            ..Default::default()
        };
        let ok = run_spmd(cfg, false, move |ctx| {
            let len = 3 << 20;
            let buf = ctx.calloc::<u8>(len);
            let payload: Vec<u8> = (0..len).map(|i| (i % 239) as u8).collect();
            let t = (ctx.pe() + 1) % ctx.npes();
            ctx.put(buf, &payload, t);
            ctx.barrier_all();
            let mut back = vec![0u8; len];
            ctx.get(&mut back, buf, t);
            back == payload
        })
        .unwrap();
        assert!(ok.iter().all(|&b| b), "depth {depth} corrupted chunked data");
    }
}

#[test]
fn fence_pushes_out_inflight_stripes() {
    // fence must deliver every stripe of a chunked NBI put before later
    // traffic (here: the flag store) can overtake it.
    let cfg = IshmemConfig {
        topology: Topology::new(1, 2, 2),
        heap_bytes: 48 << 20,
        cutover: CutoverConfig::always(),
        ..Default::default()
    };
    let ok = run_spmd(cfg, false, |ctx| {
        let buf = ctx.calloc::<u8>(4 << 20);
        let flag = ctx.calloc::<u64>(1);
        if ctx.pe() == 0 {
            ctx.put_nbi(buf, &vec![0x7Du8; 4 << 20], 2);
            ctx.fence();
            ctx.atomic_set(flag, 1u64, 2);
            ctx.barrier_all();
            true
        } else if ctx.pe() == 2 {
            ctx.wait_until(flag, Cmp::Eq, 1u64);
            let good = ctx.read_local_vec(buf).iter().all(|&v| v == 0x7D);
            ctx.barrier_all();
            good
        } else {
            ctx.barrier_all();
            true
        }
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b), "fence let the flag overtake in-flight stripes");
}

#[test]
fn large_descriptors_auto_flush_their_batch() {
    // Size-adaptive batch depth: with `large_flush_bytes` lowered, each
    // big NBI put ships its plan-group immediately (one doorbell per
    // large entry) while a burst of tiny puts still batches deep.
    let cfg = IshmemConfig {
        cutover: CutoverConfig::always(),
        max_batch_depth: 16,
        large_flush_bytes: 8 << 10,
        ..IshmemConfig::with_npes(4)
    };
    let ish = Ishmem::new(cfg).unwrap();
    let ok = ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(3 * (16 << 10));
        let big = ctx.calloc::<u8>(2 << 20);
        ctx.barrier_all();
        let mut good = true;
        if ctx.pe() == 0 {
            let data = vec![0x6Du8; 16 << 10];
            for i in 0..3 {
                // ≥ large_flush_bytes → flushed at append, no quiet yet.
                ctx.put_nbi(buf.slice(i * (16 << 10), 16 << 10), &data, 2);
            }
            ctx.quiet();
            // Chunked put + windowed get where every chunk auto-flushes:
            // the get-window guard must close windows before a drained
            // batch can release un-copied results.
            let payload: Vec<u8> = (0..2 << 20).map(|i| (i % 241) as u8).collect();
            ctx.put(big, &payload, 2);
            let mut back = vec![0u8; 2 << 20];
            ctx.get(&mut back, big, 2);
            good = back == payload;
        }
        ctx.barrier_all();
        if ctx.pe() == 2 {
            good && ctx.read_local_vec(buf).iter().all(|&v| v == 0x6D)
        } else {
            good
        }
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();
    assert!(ok.iter().all(|&b| b), "auto-flushed large puts corrupted data");
    // Three large entries → three capacity-independent doorbells (depth
    // 16 would have held all three in one group without the auto-flush).
    assert!(snap.xfer_batches >= 3, "large entries did not auto-flush: {snap:?}");
    assert!(snap.xfer_batch_depth_hist[0] >= 3, "batches not shallow: {snap:?}");
}

#[test]
fn rail_striped_remote_put_spreads_across_rails() {
    // A large cross-node put on a 4-rail machine must chunk through the
    // slab and inject across ≥2 NIC rails, covering the payload exactly.
    let mut cost = rishmem::sim::cost::CostParams::default();
    cost.nic.rails = 4;
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        cost,
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    let ok = ish.launch(|ctx| {
        let len = 4 << 20;
        let buf = ctx.calloc::<u8>(len);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            let payload: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
            ctx.put(buf, &payload, 4); // PE 4 = first PE of node 1
        }
        ctx.barrier_all();
        if ctx.pe() == 4 {
            ctx.read_local_vec(buf)
                .iter()
                .enumerate()
                .all(|(i, &v)| v == (i % 251) as u8)
        } else {
            true
        }
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();
    assert!(ok.iter().all(|&b| b), "rail-chunked remote put corrupted data");
    assert!(snap.stripe_transfers >= 1, "{snap:?}");
    let rails_used = snap.rail_bytes.iter().filter(|&&b| b > 0).count();
    assert!(rails_used >= 2, "chunks all on one rail: {:?}", snap.rail_bytes);
    assert!(
        snap.rail_bytes.iter().sum::<u64>() >= (4 << 20) as u64,
        "per-rail bytes must cover the payload: {:?}",
        snap.rail_bytes
    );
}

#[test]
fn quiet_drains_rail_ledger_of_chunked_nbi_remote_put() {
    // A rail-chunked NBI remote put reserves backlog across several NIC
    // rails and aggregates its chunks into one deferred completion; quiet
    // must deliver every chunk, return every reserved byte
    // (`rail_backlog_bytes` → 0), and zero `outstanding_chunk_count`.
    let mut cost = rishmem::sim::cost::CostParams::default();
    cost.nic.rails = 4;
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        cost,
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    let ish2 = ish.clone();
    let ok = ish.launch(move |ctx| {
        let buf = ctx.calloc::<u8>(4 << 20);
        let flag = ctx.calloc::<u64>(1);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            let data = vec![0xB7u8; 4 << 20];
            ctx.put_nbi(buf, &data, 4);
            // The chunked NBI put left live backlog on node 0's rails,
            // and its chunks aggregate into one outstanding completion.
            let loaded = ish2.cost.rail_backlog_bytes(0) >= (4 << 20) as u64
                && ctx.outstanding_chunk_count() >= 4;
            let before = ctx.clock.now_ns();
            ctx.quiet();
            let after = ctx.clock.now_ns();
            let drained = ish2.cost.rail_backlog_bytes(0) == 0
                && ctx.outstanding_chunk_count() == 0;
            ctx.atomic_set(flag, 1u64, 4);
            ctx.barrier_all();
            loaded && drained && after > before
        } else if ctx.pe() == 4 {
            ctx.wait_until(flag, Cmp::Eq, 1u64);
            let good = ctx.read_local_vec(buf).iter().all(|&v| v == 0xB7);
            ctx.barrier_all();
            good
        } else {
            ctx.barrier_all();
            true
        }
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();
    assert!(ok.iter().all(|&b| b), "quiet left rail chunks undelivered or backlog leaked");
    assert!(snap.stripe_transfers >= 1 && snap.stripe_chunks >= 4, "{snap:?}");
}

#[test]
fn fence_pushes_out_inflight_rail_stripes() {
    // fence must deliver every rail chunk of a remote NBI put before
    // later traffic (the flag atomic) can overtake it.
    let mut cost = rishmem::sim::cost::CostParams::default();
    cost.nic.rails = 4;
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        cost,
        ..Default::default()
    };
    let ok = run_spmd(cfg, false, |ctx| {
        let buf = ctx.calloc::<u8>(2 << 20);
        let flag = ctx.calloc::<u64>(1);
        if ctx.pe() == 0 {
            ctx.put_nbi(buf, &vec![0x4Eu8; 2 << 20], 4);
            ctx.fence();
            ctx.atomic_set(flag, 1u64, 4);
            ctx.barrier_all();
            true
        } else if ctx.pe() == 4 {
            ctx.wait_until(flag, Cmp::Eq, 1u64);
            let good = ctx.read_local_vec(buf).iter().all(|&v| v == 0x4E);
            ctx.barrier_all();
            good
        } else {
            ctx.barrier_all();
            true
        }
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b), "fence let the flag overtake in-flight rail stripes");
}

#[test]
fn single_rail_config_matches_pre_striping_estimates() {
    // The degraded 1-rail machine must plan every remote transfer as one
    // un-chunked RDMA whose estimate equals the plain internode model —
    // and a 4-rail machine must beat it at ≥1 MiB.
    let mut cost = rishmem::sim::cost::CostParams::default();
    cost.nic.rails = 1;
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        ..Default::default()
    };
    let one = Ishmem::new(IshmemConfig { cost, ..cfg.clone() }).unwrap();
    let four = Ishmem::new(cfg).unwrap(); // default nic.rails = 4
    for bytes in [64usize, 4096, 1 << 20, 8 << 20] {
        assert_eq!(
            one.xfer.est_nic_ns(bytes),
            one.cost.internode_ns(bytes, true, true),
            "single-rail estimate drifted at {bytes}B"
        );
        let plan = one.xfer.plan_p2p(
            rishmem::xfer::OpKind::Put,
            false,
            rishmem::Locality::Remote,
            bytes,
            1,
        );
        assert_eq!((plan.chunk_bytes, plan.stripe_width, plan.chunks()), (bytes, 1, 1));
        if bytes >= 1 << 20 {
            assert!(
                four.xfer.est_nic_ns(bytes) * 2.0 <= one.xfer.est_nic_ns(bytes),
                "4 rails not ≥2x faster at {bytes}B"
            );
        }
    }
    one.shutdown();
    four.shutdown();
}

#[test]
fn fire_and_forget_amos_ride_the_batch_stream() {
    // Non-fetching remote AMOs batch through the command stream: one
    // doorbell carries the burst, quiet proves delivery, the values land.
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).unwrap();
    let vals = ish.launch(|ctx| {
        let c = ctx.calloc::<u64>(1);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            for _ in 0..10 {
                ctx.atomic_add(c, 1u64, 6); // cross-node → proxied
            }
            ctx.quiet();
        }
        ctx.barrier_all();
        if ctx.pe() == 6 {
            ctx.atomic_fetch(c, 6)
        } else {
            0
        }
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();
    assert_eq!(vals[6], 10, "batched AMOs lost updates");
    // The burst rode batched descriptors, not ten ring messages.
    assert!(snap.xfer_batch_entries >= 10, "{snap:?}");
}

#[test]
fn iput_iget_strided() {
    let ok = run_npes(2, |ctx| {
        let buf = ctx.calloc::<i32>(64);
        let src: Vec<i32> = (0..32).collect();
        // Every 2nd src element to every 4th dest slot on the peer.
        ctx.iput(buf, &src, 4, 2, 8, 1 - ctx.pe());
        ctx.barrier_all();
        let local = ctx.read_local_vec(buf);
        let spread_ok = (0..8).all(|i| local[i * 4] == (i * 2) as i32);

        let mut back = vec![0i32; 16];
        ctx.iget(&mut back, buf, 2, 4, 8, 1 - ctx.pe());
        let gather_ok = (0..8).all(|i| back[i * 2] == (i * 2) as i32);
        spread_ok && gather_ok
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn amo_fetch_add_is_linearizable() {
    let n = 8;
    let total = run_npes(n, |ctx| {
        let counter = ctx.calloc::<u64>(1);
        ctx.barrier_all();
        let mut sum = 0u64;
        for _ in 0..100 {
            sum += ctx.atomic_fetch_add(counter, 1u64, 0);
        }
        ctx.barrier_all();
        if ctx.pe() == 0 {
            ctx.atomic_fetch(counter, 0)
        } else {
            sum // unused
        }
    })
    .unwrap();
    assert_eq!(total[0], (n * 100) as u64);
}

#[test]
fn amo_compare_swap_elects_one_winner() {
    let winners = run_npes(12, |ctx| {
        let lock = ctx.calloc::<i64>(1);
        ctx.barrier_all();
        let won = ctx.atomic_compare_swap(lock, 0i64, ctx.pe() as i64 + 1, 0) == 0;
        ctx.barrier_all();
        won
    })
    .unwrap();
    assert_eq!(winners.iter().filter(|&&w| w).count(), 1);
}

#[test]
fn put_signal_orders_payload_before_signal() {
    let ok = run_npes(2, |ctx| {
        let data = ctx.calloc::<u64>(512);
        let sig = ctx.calloc::<u64>(1);
        if ctx.pe() == 0 {
            let payload = vec![42u64; 512];
            ctx.put_signal(data, &payload, sig, 1, SignalOp::Set, 1);
            ctx.barrier_all();
            true
        } else {
            ctx.signal_wait_until(sig, Cmp::Eq, 1);
            // Signal observed ⇒ payload must be fully visible.
            let good = ctx.read_local_vec(data).iter().all(|&v| v == 42);
            ctx.barrier_all();
            good
        }
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn wait_until_sees_remote_atomic() {
    let ok = run_npes(2, |ctx| {
        let flag = ctx.calloc::<u64>(1);
        if ctx.pe() == 0 {
            ctx.atomic_add(flag, 5u64, 1);
            ctx.barrier_all();
            true
        } else {
            ctx.wait_until(flag, Cmp::Ge, 5u64);
            ctx.barrier_all();
            ctx.atomic_fetch(flag, 1) == 5
        }
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn internode_put_via_proxy() {
    // 2 nodes × 2 GPUs × 2 tiles: PE 0 → PE 7 crosses the NIC.
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        ..Default::default()
    };
    let ok = run_spmd(cfg, false, |ctx| {
        let buf = ctx.calloc::<u32>(1024);
        if ctx.pe() == 0 {
            let data: Vec<u32> = (0..1024).collect();
            ctx.put(buf, &data, 7);
        }
        ctx.barrier_all();
        if ctx.pe() == 7 {
            ctx.read_local_vec(buf)
                .iter()
                .enumerate()
                .all(|(i, &v)| v == i as u32)
        } else {
            true
        }
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn internode_amo_and_scalar_p() {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        ..Default::default()
    };
    let vals = run_spmd(cfg, false, |ctx| {
        let c = ctx.calloc::<u64>(1);
        let s = ctx.calloc::<i32>(1);
        ctx.barrier_all();
        // Everyone bumps PE 6's counter across (possibly) the NIC.
        ctx.atomic_add(c, 1u64, 6);
        if ctx.pe() == 0 {
            ctx.p(s, -99i32, 6); // inline scalar via ring
        }
        ctx.barrier_all();
        if ctx.pe() == 6 {
            (ctx.atomic_fetch(c, 6), ctx.g(s, 6))
        } else {
            (0, 0)
        }
    })
    .unwrap();
    assert_eq!(vals[6], (8, -99));
}

#[test]
fn fetching_bitwise_amos() {
    let ok = run_npes(2, |ctx| {
        let w = ctx.calloc::<u64>(1);
        if ctx.pe() == 0 {
            ctx.atomic_set(w, 0b1100u64, 1);
            ctx.barrier_all();
            let old = ctx.atomic_fetch_and(w, 0b1010u64, 1);
            let old2 = ctx.atomic_fetch_or(w, 0b0001u64, 1);
            let old3 = ctx.atomic_fetch_xor(w, 0b1111u64, 1);
            ctx.barrier_all();
            old == 0b1100 && old2 == 0b1000 && old3 == 0b1001
        } else {
            ctx.barrier_all();
            ctx.barrier_all();
            ctx.atomic_fetch(w, 1) == 0b0110
        }
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn pe_accessible_matches_topology() {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        ..Default::default()
    };
    let ok = run_spmd(cfg, false, |ctx| {
        let my_node = ctx.pe() / 4;
        (0..ctx.npes()).all(|pe| ctx.pe_accessible(pe) == (pe / 4 == my_node))
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn host_put_get_roundtrip() {
    let ok = run_npes(4, |ctx| {
        let buf = ctx.calloc::<f64>(512);
        let data: Vec<f64> = (0..512).map(|i| i as f64 / 3.0).collect();
        ctx.host_put(buf, &data, (ctx.pe() + 1) % 4);
        ctx.barrier_all();
        let mut back = vec![0f64; 512];
        ctx.host_get(&mut back, buf, (ctx.pe() + 1) % 4);
        back == data
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn clock_charges_reflect_paths() {
    // A copy-engine put must charge at least ring RTT + startup; a
    // load/store put of 64 bytes charges far less.
    let cfg = IshmemConfig {
        cutover: CutoverConfig::always(),
        ..IshmemConfig::with_npes(3)
    };
    let t_engine = run_spmd(cfg, false, |ctx| {
        let buf = ctx.calloc::<u8>(4096);
        let t0 = ctx.clock.now_ns();
        if ctx.pe() == 0 {
            ctx.put(buf, &[7u8; 4096], 2);
        }
        let dt = ctx.clock.now_ns() - t0;
        ctx.barrier_all();
        dt
    })
    .unwrap()[0];
    assert!(t_engine >= 5_000.0, "engine path charged only {t_engine}ns");

    let cfg = IshmemConfig {
        cutover: CutoverConfig::never(),
        ..IshmemConfig::with_npes(3)
    };
    let t_store = run_spmd(cfg, false, |ctx| {
        let buf = ctx.calloc::<u8>(4096);
        let t0 = ctx.clock.now_ns();
        if ctx.pe() == 0 {
            ctx.put(buf, &[7u8; 64], 2);
        }
        let dt = ctx.clock.now_ns() - t0;
        ctx.barrier_all();
        dt
    })
    .unwrap()[0];
    assert!(t_store < t_engine, "{t_store} !< {t_engine}");
}
