//! Integration: teams API semantics (split, translate, ranks).

use rishmem::{run_npes, run_spmd, IshmemConfig, TeamId, Topology};

#[test]
fn world_and_shared_basics() {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 3, 2),
        ..Default::default()
    };
    let info = run_spmd(cfg, false, |ctx| {
        (
            ctx.team_my_pe(TeamId::WORLD),
            ctx.team_n_pes(TeamId::WORLD),
            ctx.team_my_pe(TeamId::SHARED),
            ctx.team_n_pes(TeamId::SHARED),
        )
    })
    .unwrap();
    for (pe, (wr, wn, sr, sn)) in info.iter().enumerate() {
        assert_eq!(*wr, pe);
        assert_eq!(*wn, 12);
        assert_eq!(*sr, pe % 6);
        assert_eq!(*sn, 6);
    }
}

#[test]
fn split_strided_ids_agree_across_members() {
    let ids = run_npes(8, |ctx| {
        let evens = ctx.team_split_strided(TeamId::WORLD, 0, 2, 4);
        let odds = ctx.team_split_strided(TeamId::WORLD, 1, 2, 4);
        ctx.barrier_all();
        (evens, odds)
    })
    .unwrap();
    let (e0, o0) = ids[0];
    assert_ne!(e0, o0);
    for (e, o) in &ids {
        assert_eq!(*e, e0, "even team id differs between PEs");
        assert_eq!(*o, o0);
    }
}

#[test]
fn nested_split() {
    // Split world {0..8} into evens {0,2,4,6}, then evens' first half {0,4}.
    let ranks = run_npes(8, |ctx| {
        let evens = ctx.team_split_strided(TeamId::WORLD, 0, 2, 4);
        let pair = ctx.team_split_strided(evens, 0, 2, 2);
        ctx.barrier_all();
        if ctx.pe() % 4 == 0 {
            Some((ctx.team_my_pe(pair), ctx.team_n_pes(pair)))
        } else {
            None
        }
    })
    .unwrap();
    assert_eq!(ranks[0], Some((0, 2)));
    assert_eq!(ranks[4], Some((1, 2)));
    assert_eq!(ranks[2], None);
}

#[test]
fn translate_pe_between_teams() {
    let t = run_npes(8, |ctx| {
        let evens = ctx.team_split_strided(TeamId::WORLD, 0, 2, 4);
        ctx.barrier_all();
        // Even-team rank 3 is world PE 6.
        (
            ctx.team_translate_pe(evens, 3, TeamId::WORLD),
            ctx.team_translate_pe(TeamId::WORLD, 6, evens),
            ctx.team_translate_pe(TeamId::WORLD, 5, evens), // odd PE: None
        )
    })
    .unwrap();
    for r in &t {
        assert_eq!(*r, (Some(6), Some(3), None));
    }
}

#[test]
fn team_sync_only_blocks_members() {
    // The odd team syncs 100 times while evens do nothing — must not hang.
    let ok = run_npes(6, |ctx| {
        if ctx.pe() % 2 == 1 {
            let odds = ctx.team_split_strided(TeamId::WORLD, 1, 2, 3);
            for _ in 0..100 {
                ctx.team_sync(odds);
            }
        } else {
            // Evens must also create their (unused) team so the creation
            // sequence stays mirrored? — No: split is collective over the
            // PARENT team per spec; our impl only requires members to
            // call. Evens skip entirely.
        }
        ctx.barrier_all();
        true
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}
