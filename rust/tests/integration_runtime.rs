//! Integration: PJRT runtime executing the AOT Pallas artifacts, and the
//! ishmem reduce path running the L1 kernel on the request path.
//!
//! Requires `make artifacts` (skipped gracefully when absent so unit CI
//! can run without Python).

use rishmem::ishmem::heap::RESERVED_BYTES;
use rishmem::runtime::{DType, HostTensor, Manifest, XlaRuntime};
use rishmem::{run_spmd, IshmemConfig, ReduceOp, TeamId};

fn artifacts_ready() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn reduce_kernel_matches_native() {
    require_artifacts!();
    let rt = XlaRuntime::load_default().unwrap();
    let chunk = rt.reduce_chunk_elems();
    assert_eq!(chunk, 8192);

    // f32 sum
    let a: Vec<f32> = (0..chunk).map(|i| i as f32 * 0.25).collect();
    let b: Vec<f32> = (0..chunk).map(|i| (chunk - i) as f32).collect();
    let mut acc: Vec<u8> = a.iter().flat_map(|x| x.to_le_bytes()).collect();
    let other: Vec<u8> = b.iter().flat_map(|x| x.to_le_bytes()).collect();
    rt.reduce_fold_bytes("sum", "f32", &mut acc, &other).unwrap();
    let got: Vec<f32> = acc
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    for i in 0..chunk {
        assert!((got[i] - (a[i] + b[i])).abs() < 1e-4, "i={i}");
    }

    // i64 xor
    let a: Vec<i64> = (0..chunk as i64).map(|i| i * 7919).collect();
    let b: Vec<i64> = (0..chunk as i64).map(|i| i ^ 0x5A5A).collect();
    let mut acc: Vec<u8> = a.iter().flat_map(|x| x.to_le_bytes()).collect();
    let other: Vec<u8> = b.iter().flat_map(|x| x.to_le_bytes()).collect();
    rt.reduce_fold_bytes("xor", "i64", &mut acc, &other).unwrap();
    let got: Vec<i64> = acc
        .chunks_exact(8)
        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    for i in 0..chunk {
        assert_eq!(got[i], a[i] ^ b[i], "i={i}");
    }
}

#[test]
fn reduce_kernel_rejects_bad_shapes() {
    require_artifacts!();
    let rt = XlaRuntime::load_default().unwrap();
    let mut acc = vec![0u8; 64];
    let other = vec![0u8; 64];
    assert!(rt.reduce_fold_bytes("sum", "f32", &mut acc, &other).is_err());
    let mut acc = vec![0u8; 8192 * 4];
    let other = vec![0u8; 8192 * 4];
    assert!(rt.reduce_fold_bytes("sum", "f64", &mut acc, &other).is_err());
    assert!(rt.reduce_fold_bytes("nope", "f32", &mut acc, &other).is_err());
}

#[test]
fn copy_kernel_is_identity() {
    require_artifacts!();
    let rt = XlaRuntime::load_default().unwrap();
    let file = rt.manifest().copy_file.clone();
    let data: Vec<f32> = (0..8192).map(|i| (i as f32).sin()).collect();
    let out = rt
        .execute(&file, vec![HostTensor::from_f32(vec![64, 128], &data)])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].dims, vec![64, 128]);
    assert_eq!(out[0].to_f32(), data);
}

#[test]
fn model_init_and_train_step_execute() {
    require_artifacts!();
    let rt = XlaRuntime::load_default().unwrap();
    let m = rt.manifest().model("tiny").unwrap().clone();

    // init_params(seed) -> params tuple
    let params = rt
        .execute(&m.init_file, vec![HostTensor::scalar_i32(7)])
        .unwrap();
    assert_eq!(params.len(), m.params.len());
    for (p, (name, shape)) in params.iter().zip(&m.params) {
        assert_eq!(&p.dims, shape, "shape mismatch for {name}");
        assert_eq!(p.dtype, DType::F32);
    }
    // Determinism.
    let params2 = rt
        .execute(&m.init_file, vec![HostTensor::scalar_i32(7)])
        .unwrap();
    assert_eq!(params[0].bytes, params2[0].bytes);

    // train_step(params..., tokens) -> (loss, grads...)
    let tokens: Vec<i32> = (0..m.batch * m.seq_len)
        .map(|i| (i * 13 % m.vocab) as i32)
        .collect();
    let mut args = params.clone();
    args.push(HostTensor::from_i32(vec![m.batch, m.seq_len], &tokens));
    let out = rt.execute(&m.train_step_file, args.clone()).unwrap();
    assert_eq!(out.len(), 1 + m.params.len());
    let loss = out[0].scalar_f32();
    assert!(loss.is_finite() && loss > 0.0, "loss = {loss}");
    // Initial loss ≈ ln(vocab) for a fresh model.
    let expect = (m.vocab as f32).ln();
    assert!((loss - expect).abs() < 1.0, "loss {loss} vs ln(V) {expect}");
    // Grads shaped like params and not all zero.
    let mut any_nonzero = false;
    for (g, (name, shape)) in out[1..].iter().zip(&m.params) {
        assert_eq!(&g.dims, shape, "grad shape for {name}");
        any_nonzero |= g.to_f32().iter().any(|&x| x != 0.0);
    }
    assert!(any_nonzero);

    // eval_loss agrees with train_step's loss on the same batch.
    let ev = rt.execute(&m.eval_loss_file, args).unwrap();
    assert!((ev[0].scalar_f32() - loss).abs() < 1e-4);
}

#[test]
fn ishmem_reduce_uses_xla_kernel() {
    require_artifacts!();
    // Large f32 reduce must route through the AOT kernel (metrics prove it)
    // and agree with the native result.
    let cfg = IshmemConfig {
        heap_bytes: RESERVED_BYTES + (1 << 22),
        xla_reduce_min_elems: 1024,
        ..IshmemConfig::with_npes(4)
    };
    let npes = 4;
    let elems = 3 * 8192 + 100; // 3 kernel chunks + native tail
    let ish = rishmem::Ishmem::new(cfg).unwrap();
    ish.attach_runtime(XlaRuntime::load_default().unwrap());
    let ok = ish.launch(|ctx| {
        let dest = ctx.calloc::<f32>(elems);
        let src = ctx.calloc::<f32>(elems);
        let mine: Vec<f32> = (0..elems)
            .map(|i| (ctx.pe() + 1) as f32 + (i % 97) as f32)
            .collect();
        ctx.write_local(src, &mine);
        ctx.reduce(dest, src, elems, ReduceOp::Sum, TeamId::WORLD);
        let got = ctx.read_local_vec(dest);
        (0..elems).all(|i| {
            let want: f32 = (0..npes).map(|r| (r + 1) as f32 + (i % 97) as f32).sum();
            (got[i] - want).abs() < 1e-3
        })
    });
    assert!(ok.iter().all(|&b| b));
    let snap = ish.metrics.snapshot();
    assert!(
        snap.xla_reduce_calls >= (npes as u64) * 3,
        "XLA kernel not used: {snap:?}"
    );
    assert!(snap.native_reduce_elems > 0, "tail should fold natively");
    ish.shutdown();
}

#[test]
fn reduce_identical_with_and_without_kernel() {
    require_artifacts!();
    let elems = 2 * 8192;
    let run = |attach: bool| -> Vec<i32> {
        let cfg = IshmemConfig {
            heap_bytes: RESERVED_BYTES + (1 << 22),
            ..IshmemConfig::with_npes(3)
        };
        let ish = rishmem::Ishmem::new(cfg).unwrap();
        if attach {
            ish.attach_runtime(XlaRuntime::load_default().unwrap());
        }
        let out = ish.launch(|ctx| {
            let dest = ctx.calloc::<i32>(elems);
            let src = ctx.calloc::<i32>(elems);
            let mine: Vec<i32> = (0..elems as i32).map(|i| i * (ctx.pe() as i32 + 1)).collect();
            ctx.write_local(src, &mine);
            ctx.reduce(dest, src, elems, ReduceOp::Max, TeamId::WORLD);
            ctx.read_local_vec(dest)
        });
        ish.shutdown();
        out[0].clone()
    };
    assert_eq!(run(true), run(false));
}

#[test]
fn run_spmd_with_runtime_flag() {
    require_artifacts!();
    let ok = run_spmd(IshmemConfig::with_npes(2), true, |ctx| {
        let dest = ctx.calloc::<f32>(9000);
        let src = ctx.calloc::<f32>(9000);
        ctx.write_local(src, &vec![1.5f32; 9000]);
        ctx.reduce(dest, src, 9000, ReduceOp::Sum, TeamId::WORLD);
        ctx.read_local_vec(dest).iter().all(|&v| (v - 3.0).abs() < 1e-5)
    })
    .unwrap();
    assert!(ok.iter().all(|&b| b));
}
