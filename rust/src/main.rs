//! `rishmem` CLI — launcher for figures, training, baselines and info.
//!
//! (Hand-rolled argument parsing: the offline vendor set has no `clap`.)

use std::collections::HashMap;

use rishmem::bench::{figures, Figure};
use rishmem::train::{train_data_parallel, TrainConfig};

const USAGE: &str = "\
rishmem — Intel® SHMEM reproduction (Rust + JAX/Pallas via PJRT)

USAGE:
  rishmem figure <ID> [--out DIR]     regenerate a paper figure
        IDs: fig3a fig3b fig4a fig4b fig5a fig5b fig5-adaptive
             fig6-4pe fig6-8pe fig6-12pe fig7a fig7b ring fig-batch
             fig-stripe fig-rail fig-fault fig-retry ablate-cl
             ablate-sync cutover-table service-delta calibration all
        cutover-table [--load FILE] [--save FILE]: load a previously
        saved adaptive table instead of warming up / save the table
        service-delta: wall-clock vs modeled proxy service times per
        (path, size class), classes off by >2x flagged
        calibration: closed-loop calibration against a planted ground
        truth — learned vs configured params + per-class residuals
  rishmem metrics [--json] [--pes N]  run a representative workload and
                                      dump the metrics snapshot (text or
                                      JSON for dashboard scraping),
                                      including the calibration snapshot
  rishmem fault [--json] [--pes N] [--kill-at OP] [--revive-at OP]
                [--drop F:U:P] [--corrupt F:U:P] [--delay F:U:P:NS]
                [--lane L] [--min-bytes N] [--max-bytes N] [--retry]
                [--max-attempts N] [--backoff-base-ns N]
                [--backoff-mult F] [--escalate-strikes N]
                [--op-timeout-ms MS]
                                      fault-injection demo: kill a NIC
                                      rail + a copy engine mid-workload,
                                      revive them later, dump per-lane
                                      health + degraded-mode metrics.
                                      Transient windows (F:U:P = from-op,
                                      until-op, period; U=0 means forever;
                                      period 20 ~ 5% of chunks) drop,
                                      corrupt or delay chunks; --lane /
                                      --min-bytes / --max-bytes filter
                                      them; --retry turns on checksummed
                                      replay with bounded backoff
  rishmem train [--model M] [--pes N] [--steps S] [--lr F] [--seed K]
                                      data-parallel training (e2e driver)
  rishmem ze-peer                     raw Level-Zero copy-engine baseline
  rishmem quickstart                  12-PE smoke demo (put/get/reduce)
  rishmem info                        machine/topology/cost-model summary
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("figure") => cmd_figure(&args[1..]),
        Some("metrics") => cmd_metrics(&args[1..]),
        Some("fault") => cmd_fault(&args[1..]),
        Some("train") => cmd_train(&args[1..]),
        Some("ze-peer") => cmd_zepeer(),
        Some("quickstart") => cmd_quickstart(),
        Some("info") => cmd_info(),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(anyhow::anyhow!("unknown command {other:?}\n{USAGE}")),
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e:#}");
            1
        },
        |()| 0,
    );
    std::process::exit(code);
}

fn flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut kv = HashMap::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            // Boolean flags (e.g. --json) must not swallow a following
            // flag as their value.
            let val = match it.peek() {
                Some(next) if !next.starts_with("--") => it.next().cloned().unwrap(),
                _ => String::new(),
            };
            kv.insert(key.to_string(), val);
        } else {
            pos.push(a.clone());
        }
    }
    (pos, kv)
}

fn emit(fig: &Figure, out_dir: Option<&str>) -> anyhow::Result<()> {
    println!("{}", fig.render_ascii());
    if let Some(dir) = out_dir {
        let p = fig.save_csv(dir)?;
        println!("  wrote {}", p.display());
    }
    Ok(())
}

fn cmd_figure(args: &[String]) -> anyhow::Result<()> {
    let (pos, kv) = flags(args);
    let id = pos
        .first()
        .ok_or_else(|| anyhow::anyhow!("figure: missing ID\n{USAGE}"))?;
    let out = kv.get("out").map(|s| s.as_str());

    let figs: Vec<Figure> = match id.as_str() {
        "fig3a" => vec![figures::fig3a()],
        "fig3b" => vec![figures::fig3b()],
        "fig4a" => vec![figures::fig4a()],
        "fig4b" => vec![figures::fig4b()],
        "fig5a" => vec![figures::fig5a()],
        "fig5b" => vec![figures::fig5b()],
        "fig5-adaptive" => vec![figures::fig5_adaptive()],
        "cutover-table" => {
            let load = kv.get("load").filter(|v| !v.is_empty()).map(|s| s.as_str());
            let save = kv.get("save").filter(|v| !v.is_empty()).map(|s| s.as_str());
            println!("{}", figures::adaptive_cutover_report_with(load, save));
            return Ok(());
        }
        "service-delta" => {
            println!("{}", figures::service_delta_report());
            return Ok(());
        }
        "calibration" => {
            println!("{}", figures::calibration_report());
            return Ok(());
        }
        "fig6-4pe" => vec![figures::fig6(4)],
        "fig6-8pe" => vec![figures::fig6(8)],
        "fig6-12pe" => vec![figures::fig6(12)],
        "fig7a" => vec![figures::fig7a()],
        "fig7b" => vec![figures::fig7b()],
        "ring" => vec![figures::ring_figure()],
        "fig-batch" => vec![figures::fig_batch()],
        "fig-stripe" => vec![figures::fig_stripe()],
        "fig-rail" => vec![figures::fig_rail()],
        "fig-fault" => vec![figures::fig_fault()],
        "fig-retry" => vec![figures::fig_retry()],
        "fig-chain" => vec![figures::fig_chain()],
        "fig-coll-scale" => vec![figures::fig_coll_scale()],
        "ablate-cl" => vec![figures::ablate_cmdlists()],
        "ablate-sync" => vec![figures::ablate_sync()],
        "all" => figures::all_figures(),
        other => anyhow::bail!("unknown figure id {other:?}"),
    };
    for f in &figs {
        emit(f, out)?;
    }
    Ok(())
}

/// Run a short representative workload (every data path: load/store,
/// striped copy-engine, NBI batch + quiet, AMOs) on a fresh machine and
/// dump the metrics snapshot — `--json` for dashboard scraping, including
/// the per-engine dispatch tables and the chunks-per-transfer histogram.
fn cmd_metrics(args: &[String]) -> anyhow::Result<()> {
    use rishmem::{Ishmem, IshmemConfig};
    let (_, kv) = flags(args);
    let json = kv.contains_key("json");
    let pes: usize = kv.get("pes").map_or(Ok(12), |v| v.parse())?;
    // Default config — the routing/plan metrics must reflect what a
    // default deployment does, so calibration stays at its configured
    // default (off): learning against this host's wall clocks mid-run
    // would make the reported tables nondeterministic. The calibration
    // snapshot is still embedded (seed params, zero samples when off);
    // `rishmem figure calibration` shows the closed loop converging.
    let ish = Ishmem::new(IshmemConfig::with_npes(pes))?;
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(4 << 20);
        let word = ctx.calloc::<u64>(1);
        ctx.barrier_all();
        let t = (ctx.pe() + 1) % ctx.npes();
        // Small put → load/store; large put → striped copy engines.
        ctx.put(buf, &[1u8; 64], t);
        ctx.put(buf, &vec![2u8; 2 << 20], t);
        // NBI burst riding one batched doorbell, drained by quiet.
        let data = vec![3u8; 1024];
        for i in 0..4 {
            ctx.put_nbi(buf.slice(i * 1024, 1024), &data, t);
        }
        ctx.atomic_add(word, 1u64, t);
        ctx.quiet();
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    let calib = ish.calib.snapshot();
    if json {
        println!(
            "{}",
            snap.to_json_with(vec![("calibration".to_string(), calib.to_json())])
        );
    } else {
        println!("{}", snap.report());
        println!();
        println!("{}", calib.report());
    }
    ish.shutdown();
    Ok(())
}

/// Parse a transient-window spec `FROM:UNTIL:PERIOD[:DELAY_NS]` (the
/// CLI's mirror of `fault.transients`; `UNTIL = 0` means forever).
fn parse_transient(kind: &str, spec: &str) -> anyhow::Result<rishmem::sim::TransientEvent> {
    use rishmem::sim::TransientEvent;
    let parts: Vec<&str> = spec.split(':').collect();
    let want = if kind == "delay" { 4 } else { 3 };
    anyhow::ensure!(
        parts.len() == want,
        "--{kind} expects {}, got {spec:?}",
        if kind == "delay" { "FROM:UNTIL:PERIOD:DELAY_NS" } else { "FROM:UNTIL:PERIOD" }
    );
    let num = |i: usize| -> anyhow::Result<u64> {
        parts[i]
            .parse()
            .map_err(|e| anyhow::anyhow!("--{kind}: bad field {:?}: {e}", parts[i]))
    };
    let (from, until, period) = (num(0)?, num(1)?, num(2)?);
    let until = if until == 0 { u64::MAX } else { until };
    Ok(match kind {
        "drop" => TransientEvent::drop_chunk(from, until, period),
        "corrupt" => TransientEvent::corrupt_chunk(from, until, period),
        "delay" => TransientEvent::delay_chunk(from, until, period, num(3)?),
        _ => unreachable!(),
    })
}

/// Scripted fault-injection demo: run a put-heavy workload with a fault
/// plane that kills NIC rail (0,1) and copy engine (0,0) at `--kill-at`
/// proxy ops and revives both at `--revive-at`, then dump the metrics
/// snapshot — per-lane health gauges, kill/revive counters,
/// re-dispatched chunks and the degraded-mode flag. `--json` for
/// dashboard scraping. Transient windows (`--drop/--corrupt/--delay`,
/// with `--lane`/`--min-bytes`/`--max-bytes` filters) exercise the
/// ISSUE 9 reliability layer; pair them with `--retry` so dropped and
/// corrupted chunks are replayed instead of failing the batch.
fn cmd_fault(args: &[String]) -> anyhow::Result<()> {
    use rishmem::sim::FaultEvent;
    use rishmem::{Ishmem, IshmemConfig};
    let (_, kv) = flags(args);
    let json = kv.contains_key("json");
    let pes: usize = kv.get("pes").map_or(Ok(12), |v| v.parse())?;
    let kill_at: u64 = kv.get("kill-at").map_or(Ok(16), |v| v.parse())?;
    let revive_at: u64 = kv.get("revive-at").map_or(Ok(96), |v| v.parse())?;
    anyhow::ensure!(kill_at < revive_at, "--kill-at must precede --revive-at");
    let mut cfg = IshmemConfig::with_npes(pes);
    cfg.fault.enable = true;
    cfg.fault.events = vec![
        FaultEvent::kill_rail(kill_at, 0, 1),
        FaultEvent::kill_engine(kill_at, 0, 0),
        FaultEvent::revive_rail(revive_at, 0, 1),
        FaultEvent::revive_engine(revive_at, 0, 0),
    ];
    let mut transients = Vec::new();
    for kind in ["drop", "corrupt", "delay"] {
        if let Some(spec) = kv.get(kind).filter(|v| !v.is_empty()) {
            transients.push(parse_transient(kind, spec)?);
        }
    }
    if !transients.is_empty() {
        let min: u64 = kv.get("min-bytes").map_or(Ok(0), |v| v.parse())?;
        let max: u64 = kv.get("max-bytes").map_or(Ok(u64::MAX), |v| v.parse())?;
        let lane: Option<usize> = match kv.get("lane").filter(|v| !v.is_empty()) {
            Some(v) => Some(v.parse()?),
            None => None,
        };
        transients = transients
            .into_iter()
            .map(|t| {
                let t = t.with_bytes(min, max);
                match lane {
                    Some(l) => t.with_lane(l),
                    None => t,
                }
            })
            .collect();
    }
    cfg.fault.transients = transients;
    if kv.contains_key("retry") {
        cfg.retry.enable = true;
    }
    if let Some(v) = kv.get("max-attempts").filter(|v| !v.is_empty()) {
        cfg.retry.max_attempts = v.parse()?;
    }
    if let Some(v) = kv.get("backoff-base-ns").filter(|v| !v.is_empty()) {
        cfg.retry.backoff_base_ns = v.parse()?;
    }
    if let Some(v) = kv.get("backoff-mult").filter(|v| !v.is_empty()) {
        cfg.retry.backoff_mult = v.parse()?;
    }
    if let Some(v) = kv.get("escalate-strikes").filter(|v| !v.is_empty()) {
        cfg.retry.escalate_strikes = v.parse()?;
    }
    if let Some(v) = kv.get("op-timeout-ms").filter(|v| !v.is_empty()) {
        cfg.xfer.op_timeout_ms = v.parse()?;
    }
    let n_transients = cfg.fault.transients.len();
    let retry_on = cfg.retry.enable;
    let ish = Ishmem::new(cfg)?;
    if !json {
        println!(
            "fault demo: kill rail(0,1) + engine(0,0) @ op {kill_at}, revive @ op {revive_at}"
        );
        if n_transients > 0 {
            println!(
                "  {n_transients} transient window(s), retry {}",
                if retry_on { "on" } else { "off" }
            );
        }
    }
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(4 << 20);
        ctx.barrier_all();
        let t = (ctx.pe() + 1) % ctx.npes();
        let data = vec![7u8; 1 << 20];
        // Enough striped large puts that the proxy's op clock crosses both
        // the kill and the revive thresholds while chunks are in flight.
        for _ in 0..8 {
            ctx.put(buf, &data, t);
        }
        ctx.quiet();
        ctx.barrier_all();
    });
    let snap = ish.metrics.snapshot();
    if json {
        println!("{}", snap.to_json());
    } else {
        println!("{}", snap.report());
        println!(
            "\nfinal health: rail(0,1) live={} engine(0,0) live={} degraded={}",
            ish.cost.rail_is_live(0, 1),
            ish.cost.engine_is_live(0, 0),
            ish.cost.degraded(),
        );
    }
    ish.shutdown();
    Ok(())
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let (_, kv) = flags(args);
    let mut cfg = TrainConfig::default();
    if let Some(m) = kv.get("model") {
        cfg.model = m.clone();
    }
    if let Some(v) = kv.get("pes") {
        cfg.pes = v.parse()?;
    }
    if let Some(v) = kv.get("steps") {
        cfg.steps = v.parse()?;
    }
    if let Some(v) = kv.get("lr") {
        cfg.lr = v.parse()?;
    }
    if let Some(v) = kv.get("seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = kv.get("log-every") {
        cfg.log_every = v.parse()?;
    }
    println!(
        "training {} | {} PEs | {} steps | lr {}",
        cfg.model, cfg.pes, cfg.steps, cfg.lr
    );
    let r = train_data_parallel(&cfg)?;
    println!(
        "\ndone: loss {:.4} -> {:.4} | {} params | {} tok/step | {:.1}s wall | {} XLA reduce-kernel calls",
        r.first_loss, r.final_loss, r.param_count, r.tokens_per_step, r.wall_seconds,
        r.xla_reduce_calls
    );
    println!("loss curve:");
    for (s, l) in &r.losses {
        println!("  step {s:5}  {l:.4}");
    }
    for (s, l) in &r.eval_losses {
        println!("  eval {s:5}  {l:.4}");
    }
    Ok(())
}

fn cmd_zepeer() -> anyhow::Result<()> {
    use rishmem::bench::zepeer::zepeer_write_series;
    use rishmem::Topology;
    let topo = Topology::new(1, 2, 2);
    let sizes = rishmem::bench::size_sweep();
    let mut fig = Figure::new("ze_peer", "ze_peer copy-engine baseline", "msg size", "GB/s");
    for (name, target) in [("same-tile", 1usize), ("cross-GPU", 2)] {
        fig.series
            .push(zepeer_write_series(&topo, 0, target, &sizes, name));
    }
    emit(&fig, None)
}

fn cmd_quickstart() -> anyhow::Result<()> {
    use rishmem::{run_npes, ReduceOp, TeamId};
    println!("launching 12 PEs on a simulated Aurora node…");
    let sums = run_npes(12, |ctx| {
        let buf = ctx.calloc::<i64>(12);
        ctx.p(buf.at(ctx.pe()), ctx.pe() as i64, (ctx.pe() + 1) % 12);
        ctx.barrier_all();
        let dest = ctx.calloc::<i64>(1);
        let src = ctx.calloc::<i64>(1);
        ctx.write_local(src, &[ctx.pe() as i64]);
        ctx.reduce(dest, src, 1, ReduceOp::Sum, TeamId::WORLD);
        ctx.read_local_vec(dest)[0]
    })?;
    println!("sum over PE ranks on every PE: {sums:?} (expect 66s)");
    anyhow::ensure!(sums.iter().all(|&s| s == 66));
    println!("quickstart OK");
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    use rishmem::sim::cost::CostParams;
    let p = CostParams::default();
    println!("rishmem — simulated node (Borealis/Aurora-like)");
    println!("  topology: 6 GPUs × 2 tiles = 12 PEs, fully-connected Xe-Link");
    println!(
        "  Xe-Link: {} GB/s/link | MDFI {} GB/s | HBM {} GB/s",
        p.xe.link_bw_gbs, p.xe.mdfi_bw_gbs, p.xe.hbm_bw_gbs
    );
    println!(
        "  per-work-item store rate: {} GB/s (local {})",
        p.xe.per_item_rate_gbs, p.xe.per_item_local_rate_gbs
    );
    println!(
        "  copy engine: startup {} ns (immediate) / {} ns (standard)",
        p.ce.startup_immediate_ns, p.ce.startup_standard_ns
    );
    println!(
        "  ring RTT: {} ns | NIC: {} GB/s, {} ns",
        p.pcie.ring_rtt_ns, p.nic.bw_gbs, p.nic.latency_ns
    );
    println!(
        "  artifacts: {}",
        rishmem::runtime::Manifest::default_dir().display()
    );
    match rishmem::runtime::Manifest::load(rishmem::runtime::Manifest::default_dir()) {
        Ok(m) => {
            println!(
                "  reduce kernels: {} | models: {:?}",
                m.reduce_files.len(),
                m.models.keys().collect::<Vec<_>>()
            );
        }
        Err(_) => println!("  (artifacts not built — run `make artifacts`)"),
    }
    Ok(())
}
