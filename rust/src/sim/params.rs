//! Versioned store of the *learnable* hardware constants (closed-loop
//! cost-model calibration, ISSUE 5).
//!
//! The cost model's first-order constants come from `CostParams` — config
//! defaults calibrated against public PVC/Slingshot figures, not measured
//! silicon. PR 4's wall-vs-model ledgers measure exactly how wrong those
//! constants are on the machine actually running, and the ROADMAP names
//! the feedback loop from four directions ("learn `single_engine_frac`
//! from observed ze_peer runs", "learn `rail_bw_frac` from observed wire
//! times", "feed flagged classes back into cost-model calibration",
//! "learn the CL boundary online").
//!
//! [`ModelParams`] closes that loop's state side: the learnable subset of
//! the constants lives here as a **mutable, versioned** store shared by
//! every reader of the cost model. Planners read the *live* values
//! ([`CostModel::ce_eff`]/[`CostModel::nic_eff`] overlay them onto the
//! structural params), the calibrator (`xfer::calibrate`) writes refined
//! values through [`ModelParams::update`], and the version counter bumps
//! only when a value actually changes — so transfer plans and adaptive-
//! table cells stamped with the version can age out exactly when the
//! hardware model moved, and never spuriously.
//!
//! Seeding discipline: the store is seeded bit-for-bit from the configured
//! `CostParams`, and a machine whose calibrator never applies an update
//! (`calib.enable = false`) reads back the identical f64 bits — every
//! estimate stays bit-identical to the pre-calibration formulas (tested
//! here and in `sim::cost`).
//!
//! [`CostModel::ce_eff`]: super::cost::CostModel::ce_eff
//! [`CostModel::nic_eff`]: super::cost::CostModel::nic_eff

use std::sync::{Arc, RwLock};

use super::cost::CostParams;

/// The learnable subset of the hardware constants: the fractions and
/// startup terms the calibrator refines from observed wall times, plus
/// the per-op command-list boundary (the third learned quantity — the
/// calibrator nudges it toward the observed immediate-vs-standard
/// crossover the way `Adaptive` learns the cutover).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LearnedParams {
    /// Live value of `ce.single_engine_frac` (sustained single-blitter
    /// rate as a fraction of the path roofline).
    pub single_engine_frac: f64,
    /// Live value of `ce.startup_immediate_ns`.
    pub startup_immediate_ns: f64,
    /// Live value of `ce.startup_standard_ns`.
    pub startup_standard_ns: f64,
    /// Live value of `nic.rail_bw_frac` (sustained per-rail injection as
    /// a fraction of nominal NIC bandwidth).
    pub rail_bw_frac: f64,
    /// Live value of `nic.rail_startup_ns` (per-chunk rail injection
    /// startup).
    pub rail_startup_ns: f64,
    /// Live per-op command-list boundary (`cl_immediate_max_bytes`):
    /// descriptors at or below run immediate lists. Seeded to
    /// `usize::MAX` for cost models built without a machine config;
    /// `Ishmem::new` re-seeds it from `IshmemConfig`.
    pub cl_immediate_max_bytes: usize,
}

impl LearnedParams {
    /// Extract the learnable constants from the configured params
    /// (bit-for-bit — no arithmetic on the way in or out).
    pub fn from_cost(params: &CostParams) -> Self {
        LearnedParams {
            single_engine_frac: params.ce.single_engine_frac,
            startup_immediate_ns: params.ce.startup_immediate_ns,
            startup_standard_ns: params.ce.startup_standard_ns,
            rail_bw_frac: params.nic.rail_bw_frac,
            rail_startup_ns: params.nic.rail_startup_ns,
            cl_immediate_max_bytes: usize::MAX,
        }
    }
}

/// One immutable published generation of the learned params: the live
/// values *and* the version that produced them, bound together so a
/// reader can never observe params from one generation stamped with
/// another generation's version (the param-tearing class of bug).
///
/// A planning pass grabs one snapshot up front and threads the same
/// `Arc` through every estimate term — mid-pass calibration applies
/// publish a *new* snapshot and never mutate this one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParamsSnapshot {
    /// The live learned values at publication time.
    pub params: LearnedParams,
    /// The model version these values belong to. 0 = pure config.
    pub version: u64,
}

/// Mutable, versioned store of [`LearnedParams`], shared machine-wide via
/// the `CostModel`.
///
/// Publication is arc-swap style: the current generation lives in one
/// immutable [`ParamsSnapshot`] behind an `Arc`, and the calibrator's
/// apply path replaces the whole `Arc` atomically — readers clone the
/// `Arc` (one refcount bump under a read lock held for nanoseconds) and
/// then read params + version lock-free for the rest of the planning
/// pass. The version lives *inside* the snapshot, so (params, version)
/// can never tear. Writes go through [`Self::update`], which bumps the
/// version *only* when a value actually changed — the version is the
/// staleness token plans and adaptive cells carry.
#[derive(Debug)]
pub struct ModelParams {
    /// The configured seed — the calibrator's clamp anchor
    /// (`calib.clamp_frac` bounds how far live values may drift from it).
    seed: RwLock<LearnedParams>,
    /// The published generation. The lock guards only the `Arc` swap
    /// itself (a refcount op), never the params behind it.
    snap: RwLock<Arc<ParamsSnapshot>>,
}

impl ModelParams {
    /// Seed the store from the configured cost params (version 0; live ==
    /// seed bit-for-bit).
    pub fn new(params: &CostParams) -> Self {
        let seed = LearnedParams::from_cost(params);
        ModelParams {
            seed: RwLock::new(seed),
            snap: RwLock::new(Arc::new(ParamsSnapshot { params: seed, version: 0 })),
        }
    }

    /// The current published generation: live params + their version as
    /// one immutable unit. Cheap (one `Arc` clone); hold it across a
    /// whole planning pass so every term prices against one generation.
    pub fn snapshot(&self) -> Arc<ParamsSnapshot> {
        Arc::clone(&self.snap.read().unwrap())
    }

    /// The live learned values (what every estimate uses).
    pub fn get(&self) -> LearnedParams {
        self.snap.read().unwrap().params
    }

    /// The configured seed values (the calibrator's clamp anchor).
    pub fn seed(&self) -> LearnedParams {
        *self.seed.read().unwrap()
    }

    /// Current model version. 0 = never recalibrated (pure config).
    pub fn version(&self) -> u64 {
        self.snap.read().unwrap().version
    }

    /// Apply a calibration update. The closure mutates a copy of the live
    /// values; if anything actually changed a new snapshot (params +
    /// bumped version) is published atomically — in-flight readers keep
    /// their old generation untouched. A no-op closure publishes nothing
    /// and leaves the version (and therefore every stamped plan and
    /// adaptive cell) untouched. Returns the version after the call.
    pub fn update(&self, f: impl FnOnce(&mut LearnedParams)) -> u64 {
        let mut snap = self.snap.write().unwrap();
        let mut live = snap.params;
        f(&mut live);
        if live != snap.params {
            let version = snap.version + 1;
            *snap = Arc::new(ParamsSnapshot { params: live, version });
            version
        } else {
            snap.version
        }
    }

    /// Re-seed the per-op CL boundary at machine construction (this is
    /// configuration, not a calibration event: seed *and* live move, the
    /// version does not).
    pub fn seed_cl_boundary(&self, bytes: usize) {
        self.seed.write().unwrap().cl_immediate_max_bytes = bytes;
        let mut snap = self.snap.write().unwrap();
        let mut params = snap.params;
        params.cl_immediate_max_bytes = bytes;
        *snap = Arc::new(ParamsSnapshot { params, version: snap.version });
    }

    /// Discard everything learned: live returns to the seed. Bumps the
    /// version iff anything had been learned (so dependent state ages out
    /// exactly once).
    pub fn reset(&self) -> u64 {
        let seed = *self.seed.read().unwrap();
        let mut snap = self.snap.write().unwrap();
        if snap.params != seed {
            let version = snap.version + 1;
            *snap = Arc::new(ParamsSnapshot { params: seed, version });
            version
        } else {
            snap.version
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_bit_for_bit_from_cost_params() {
        let p = CostParams::default();
        let m = ModelParams::new(&p);
        let l = m.get();
        assert_eq!(l.single_engine_frac.to_bits(), p.ce.single_engine_frac.to_bits());
        assert_eq!(l.startup_immediate_ns.to_bits(), p.ce.startup_immediate_ns.to_bits());
        assert_eq!(l.startup_standard_ns.to_bits(), p.ce.startup_standard_ns.to_bits());
        assert_eq!(l.rail_bw_frac.to_bits(), p.nic.rail_bw_frac.to_bits());
        assert_eq!(l.rail_startup_ns.to_bits(), p.nic.rail_startup_ns.to_bits());
        assert_eq!(l.cl_immediate_max_bytes, usize::MAX);
        assert_eq!(m.version(), 0);
        assert_eq!(m.get(), m.seed());
    }

    #[test]
    fn update_bumps_version_only_on_real_change() {
        let m = ModelParams::new(&CostParams::default());
        // A no-op update never bumps.
        assert_eq!(m.update(|_| {}), 0);
        // Writing the identical value never bumps.
        let frac = m.get().single_engine_frac;
        assert_eq!(m.update(|l| l.single_engine_frac = frac), 0);
        // A real change bumps exactly once.
        assert_eq!(m.update(|l| l.single_engine_frac = 0.5), 1);
        assert_eq!(m.get().single_engine_frac, 0.5);
        assert_eq!(m.version(), 1);
        // The seed is untouched by updates.
        assert_eq!(m.seed().single_engine_frac, CostParams::default().ce.single_engine_frac);
    }

    #[test]
    fn seed_cl_boundary_moves_seed_and_live_without_versioning() {
        let m = ModelParams::new(&CostParams::default());
        m.seed_cl_boundary(64 << 10);
        assert_eq!(m.get().cl_immediate_max_bytes, 64 << 10);
        assert_eq!(m.seed().cl_immediate_max_bytes, 64 << 10);
        assert_eq!(m.version(), 0);
    }

    #[test]
    fn snapshot_binds_params_and_version_immutably() {
        let m = ModelParams::new(&CostParams::default());
        let s0 = m.snapshot();
        assert_eq!(s0.version, 0);
        assert_eq!(s0.params, m.get());
        // Publishing a new generation leaves the held snapshot untouched.
        let v = m.update(|l| l.single_engine_frac = 0.5);
        assert_eq!(v, 1);
        assert_eq!(s0.version, 0, "held snapshot keeps its generation");
        assert_eq!(
            s0.params.single_engine_frac,
            CostParams::default().ce.single_engine_frac
        );
        let s1 = m.snapshot();
        assert_eq!(s1.version, 1);
        assert_eq!(s1.params.single_engine_frac, 0.5);
        // seed_cl_boundary re-publishes (same version, new boundary) so a
        // fresh snapshot sees the boundary without a calibration event.
        m.seed_cl_boundary(64 << 10);
        let s2 = m.snapshot();
        assert_eq!(s2.version, 1);
        assert_eq!(s2.params.cl_immediate_max_bytes, 64 << 10);
        assert_eq!(s1.params.cl_immediate_max_bytes, usize::MAX);
    }

    #[test]
    fn reset_returns_to_seed_and_bumps_once() {
        let m = ModelParams::new(&CostParams::default());
        assert_eq!(m.reset(), 0, "resetting a pristine store must not bump");
        m.update(|l| {
            l.rail_bw_frac = 0.5;
            l.rail_startup_ns = 900.0;
        });
        assert_eq!(m.version(), 1);
        let v = m.reset();
        assert_eq!(v, 2);
        assert_eq!(m.get(), m.seed());
        assert_eq!(m.reset(), 2, "second reset is a no-op");
    }
}
