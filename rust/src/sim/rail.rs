//! NIC rail set: per-rail backlog state for multi-rail scale-out striping.
//!
//! The paper's testbed exposes 8 Slingshot NICs per node (§III-A); a
//! single proxy-driven RDMA sequence rides exactly one of them, capping
//! inter-node bandwidth at one rail's injection rate. Striping a large
//! remote transfer's chunks across `nic.rails` rails recovers the node's
//! aggregate injection bandwidth — the remote-path twin of the per-GPU
//! copy-engine striping in [`super::copyengine`] ("Exploring Fully
//! Offloaded GPU Stream-Aware Message Passing" and NVSHMEM's per-rail
//! proxy channels do the same on other stacks).
//!
//! [`RailSet`] is the per-*node* mirror of [`super::copyengine::EngineQueue`]:
//! each rail keeps a byte backlog of accepted-but-incomplete remote work
//! (blocking transfers hold their bytes for the call; NBI transfers until
//! `quiet`), so the planner can fold the node's remote backlog into its
//! NIC estimate and executors can place new chunks on the least-loaded
//! rails.
//!
//! The rail *count* here is structural (one slot per physical NIC rail);
//! the sustained per-rail rate the backlog drains at is the learnable
//! `nic.rail_bw_frac`, read live through [`super::cost::CostModel::nic_eff`]
//! — a calibration update re-prices every drain estimate without touching
//! this state.

use std::sync::atomic::{AtomicU64, Ordering};

/// Per-node rail state: a byte backlog per NIC rail.
#[derive(Debug)]
pub struct RailSet {
    /// Outstanding bytes per rail (index = rail slot on this node).
    per_rail_bytes: Vec<AtomicU64>,
}

impl RailSet {
    pub fn new(rails: usize) -> Self {
        let rails = rails.max(1);
        RailSet {
            per_rail_bytes: (0..rails).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn rails(&self) -> usize {
        self.per_rail_bytes.len()
    }

    fn slot(&self, rail: usize) -> &AtomicU64 {
        &self.per_rail_bytes[rail.min(self.per_rail_bytes.len() - 1)]
    }

    /// Register `bytes` of accepted-but-incomplete remote work on `rail`.
    pub fn reserve_on(&self, rail: usize, bytes: u64) {
        self.slot(rail).fetch_add(bytes, Ordering::AcqRel);
    }

    /// Retire work previously reserved on `rail`.
    pub fn release_on(&self, rail: usize, bytes: u64) {
        let prev = self.slot(rail).fetch_sub(bytes, Ordering::AcqRel);
        debug_assert!(prev >= bytes, "rail backlog underflow: {prev} - {bytes}");
    }

    /// Current byte backlog of one rail.
    pub fn rail_bytes(&self, rail: usize) -> u64 {
        self.slot(rail).load(Ordering::Acquire)
    }

    /// Total byte backlog across this node's rails.
    pub fn queued_bytes(&self) -> u64 {
        self.per_rail_bytes
            .iter()
            .map(|b| b.load(Ordering::Acquire))
            .sum()
    }

    /// The `width` least-loaded rail slots, lightest first (approximate
    /// under concurrency — placement, not correctness, depends on it).
    pub fn least_loaded(&self, width: usize) -> Vec<usize> {
        let mut loads: Vec<(u64, usize)> = self
            .per_rail_bytes
            .iter()
            .enumerate()
            .map(|(i, b)| (b.load(Ordering::Acquire), i))
            .collect();
        loads.sort_unstable();
        loads
            .into_iter()
            .take(width.clamp(1, self.per_rail_bytes.len()))
            .map(|(_, i)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_rail_backlog_is_independent() {
        let r = RailSet::new(4);
        assert_eq!(r.rails(), 4);
        r.reserve_on(1, 100);
        r.reserve_on(3, 50);
        assert_eq!(r.rail_bytes(1), 100);
        assert_eq!(r.rail_bytes(3), 50);
        assert_eq!(r.rail_bytes(0), 0);
        assert_eq!(r.queued_bytes(), 150);
        // Out-of-range rail indices clamp to the last slot.
        r.reserve_on(99, 7);
        assert_eq!(r.rail_bytes(3), 57);
        r.release_on(99, 7);
        r.release_on(1, 100);
        r.release_on(3, 50);
        assert_eq!(r.queued_bytes(), 0);
    }

    #[test]
    fn least_loaded_orders_by_backlog() {
        let r = RailSet::new(4);
        r.reserve_on(0, 300);
        r.reserve_on(1, 100);
        r.reserve_on(2, 200);
        assert_eq!(r.least_loaded(4), vec![3, 1, 2, 0]);
        assert_eq!(r.least_loaded(2), vec![3, 1]);
        // Width clamps to the rail count and to ≥1.
        assert_eq!(r.least_loaded(0).len(), 1);
        assert_eq!(r.least_loaded(99).len(), 4);
    }

    #[test]
    fn zero_rail_request_still_builds_one_rail() {
        let r = RailSet::new(0);
        assert_eq!(r.rails(), 1);
        r.reserve_on(0, 8);
        assert_eq!(r.queued_bytes(), 8);
        r.release_on(0, 8);
    }
}
