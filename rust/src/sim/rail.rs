//! NIC rail set: per-rail backlog state for multi-rail scale-out striping.
//!
//! The paper's testbed exposes 8 Slingshot NICs per node (§III-A); a
//! single proxy-driven RDMA sequence rides exactly one of them, capping
//! inter-node bandwidth at one rail's injection rate. Striping a large
//! remote transfer's chunks across `nic.rails` rails recovers the node's
//! aggregate injection bandwidth — the remote-path twin of the per-GPU
//! copy-engine striping in [`super::copyengine`] ("Exploring Fully
//! Offloaded GPU Stream-Aware Message Passing" and NVSHMEM's per-rail
//! proxy channels do the same on other stacks).
//!
//! [`RailSet`] is the per-*node* mirror of [`super::copyengine::EngineQueue`]:
//! each rail keeps a byte backlog of accepted-but-incomplete remote work
//! (blocking transfers hold their bytes for the call; NBI transfers until
//! `quiet`), so the planner can fold the node's remote backlog into its
//! NIC estimate and executors can place new chunks on the least-loaded
//! rails.
//!
//! The rail *count* here is structural (one slot per physical NIC rail);
//! the sustained per-rail rate the backlog drains at is the learnable
//! `nic.rail_bw_frac`, read live through [`super::cost::CostModel::nic_eff`]
//! — a calibration update re-prices every drain estimate without touching
//! this state.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Per-node rail state: a byte backlog per NIC rail, plus a liveness bit
/// per rail (fault injection, ISSUE 8 — a dead rail is excluded from
/// placement and planning until revived).
#[derive(Debug)]
pub struct RailSet {
    /// Outstanding bytes per rail (index = rail slot on this node).
    per_rail_bytes: Vec<AtomicU64>,
    /// Liveness per rail: `false` = killed/quarantined. All-true at
    /// construction, so a machine that never injects faults behaves
    /// bit-identically to the pre-fault code.
    alive: Vec<AtomicBool>,
}

impl RailSet {
    pub fn new(rails: usize) -> Self {
        let rails = rails.max(1);
        RailSet {
            per_rail_bytes: (0..rails).map(|_| AtomicU64::new(0)).collect(),
            alive: (0..rails).map(|_| AtomicBool::new(true)).collect(),
        }
    }

    pub fn rails(&self) -> usize {
        self.per_rail_bytes.len()
    }

    fn slot(&self, rail: usize) -> &AtomicU64 {
        &self.per_rail_bytes[rail.min(self.per_rail_bytes.len() - 1)]
    }

    fn slot_idx(&self, rail: usize) -> usize {
        rail.min(self.alive.len() - 1)
    }

    /// Mark `rail` dead. Returns `true` iff it was alive (a transition).
    pub fn kill(&self, rail: usize) -> bool {
        self.alive[self.slot_idx(rail)].swap(false, Ordering::AcqRel)
    }

    /// Mark `rail` live again. Returns `true` iff it was dead.
    pub fn revive(&self, rail: usize) -> bool {
        !self.alive[self.slot_idx(rail)].swap(true, Ordering::AcqRel)
    }

    /// Is `rail` currently live?
    pub fn is_live(&self, rail: usize) -> bool {
        self.alive[self.slot_idx(rail)].load(Ordering::Acquire)
    }

    /// Number of live rails (0 = every rail on this node is dead).
    pub fn live_count(&self) -> usize {
        self.alive
            .iter()
            .filter(|a| a.load(Ordering::Acquire))
            .count()
    }

    /// Register `bytes` of accepted-but-incomplete remote work on `rail`.
    pub fn reserve_on(&self, rail: usize, bytes: u64) {
        self.slot(rail).fetch_add(bytes, Ordering::AcqRel);
    }

    /// Retire work previously reserved on `rail`. Saturating: a chunk
    /// whose backlog was migrated off a dead rail by the proxy may be
    /// released against its original slot later (the initiator's ledger
    /// predates the migration), so under-releases floor at zero instead
    /// of wrapping.
    pub fn release_on(&self, rail: usize, bytes: u64) {
        let slot = self.slot(rail);
        let mut cur = slot.load(Ordering::Acquire);
        loop {
            let next = cur.saturating_sub(bytes);
            match slot.compare_exchange_weak(cur, next, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    /// Move up to `bytes` of backlog from `from` to `to` (proxy
    /// re-dispatch of in-flight chunks off a dead lane). Saturates at
    /// whatever `from` actually holds.
    pub fn migrate(&self, from: usize, to: usize, bytes: u64) {
        if self.slot_idx(from) == self.slot_idx(to) {
            return;
        }
        let src = self.slot(from);
        let mut cur = src.load(Ordering::Acquire);
        let moved = loop {
            let take = cur.min(bytes);
            let next = cur - take;
            match src.compare_exchange_weak(cur, next, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => break take,
                Err(now) => cur = now,
            }
        };
        if moved > 0 {
            self.slot(to).fetch_add(moved, Ordering::AcqRel);
        }
    }

    /// Current byte backlog of one rail.
    pub fn rail_bytes(&self, rail: usize) -> u64 {
        self.slot(rail).load(Ordering::Acquire)
    }

    /// Total byte backlog across this node's rails.
    pub fn queued_bytes(&self) -> u64 {
        self.per_rail_bytes
            .iter()
            .map(|b| b.load(Ordering::Acquire))
            .sum()
    }

    /// The `width` least-loaded *live* rail slots, lightest first
    /// (approximate under concurrency — placement, not correctness,
    /// depends on it). Dead rails are excluded; if every rail is dead the
    /// full set is returned unfiltered (last-lane fallback — the caller
    /// counts the degradation, the transfer still has to move).
    pub fn least_loaded(&self, width: usize) -> Vec<usize> {
        let mut loads: Vec<(u64, usize)> = self
            .per_rail_bytes
            .iter()
            .enumerate()
            .filter(|(i, _)| self.alive[*i].load(Ordering::Acquire))
            .map(|(i, b)| (b.load(Ordering::Acquire), i))
            .collect();
        if loads.is_empty() {
            loads = self
                .per_rail_bytes
                .iter()
                .enumerate()
                .map(|(i, b)| (b.load(Ordering::Acquire), i))
                .collect();
        }
        loads.sort_unstable();
        let n = loads.len();
        loads
            .into_iter()
            .take(width.clamp(1, n))
            .map(|(_, i)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_rail_backlog_is_independent() {
        let r = RailSet::new(4);
        assert_eq!(r.rails(), 4);
        r.reserve_on(1, 100);
        r.reserve_on(3, 50);
        assert_eq!(r.rail_bytes(1), 100);
        assert_eq!(r.rail_bytes(3), 50);
        assert_eq!(r.rail_bytes(0), 0);
        assert_eq!(r.queued_bytes(), 150);
        // Out-of-range rail indices clamp to the last slot.
        r.reserve_on(99, 7);
        assert_eq!(r.rail_bytes(3), 57);
        r.release_on(99, 7);
        r.release_on(1, 100);
        r.release_on(3, 50);
        assert_eq!(r.queued_bytes(), 0);
    }

    #[test]
    fn least_loaded_orders_by_backlog() {
        let r = RailSet::new(4);
        r.reserve_on(0, 300);
        r.reserve_on(1, 100);
        r.reserve_on(2, 200);
        assert_eq!(r.least_loaded(4), vec![3, 1, 2, 0]);
        assert_eq!(r.least_loaded(2), vec![3, 1]);
        // Width clamps to the rail count and to ≥1.
        assert_eq!(r.least_loaded(0).len(), 1);
        assert_eq!(r.least_loaded(99).len(), 4);
    }

    #[test]
    fn zero_rail_request_still_builds_one_rail() {
        let r = RailSet::new(0);
        assert_eq!(r.rails(), 1);
        r.reserve_on(0, 8);
        assert_eq!(r.queued_bytes(), 8);
        r.release_on(0, 8);
    }

    #[test]
    fn dead_rails_are_excluded_from_placement() {
        let r = RailSet::new(4);
        assert_eq!(r.live_count(), 4);
        assert!(r.kill(2), "first kill is a transition");
        assert!(!r.kill(2), "second kill is not");
        assert!(!r.is_live(2));
        assert_eq!(r.live_count(), 3);
        let picked = r.least_loaded(4);
        assert_eq!(picked.len(), 3);
        assert!(!picked.contains(&2), "dead rail placed: {picked:?}");
        assert!(r.revive(2), "revive of a dead rail is a transition");
        assert!(!r.revive(2));
        assert_eq!(r.live_count(), 4);
        assert_eq!(r.least_loaded(4).len(), 4);
    }

    #[test]
    fn all_dead_falls_back_to_the_full_set() {
        let r = RailSet::new(2);
        r.kill(0);
        r.kill(1);
        assert_eq!(r.live_count(), 0);
        // Placement still answers — the caller counts the fallback.
        assert_eq!(r.least_loaded(2).len(), 2);
    }

    #[test]
    fn migrate_moves_backlog_and_release_saturates() {
        let r = RailSet::new(4);
        r.reserve_on(1, 100);
        r.migrate(1, 3, 60);
        assert_eq!(r.rail_bytes(1), 40);
        assert_eq!(r.rail_bytes(3), 60);
        // Migrating more than the slot holds saturates.
        r.migrate(1, 0, 1000);
        assert_eq!(r.rail_bytes(1), 0);
        assert_eq!(r.rail_bytes(0), 40);
        // A stale release against the drained slot floors at zero.
        r.release_on(1, 100);
        assert_eq!(r.rail_bytes(1), 0);
        assert_eq!(r.queued_bytes(), 100);
    }
}
