//! Slingshot-class NIC model for inter-node (scale-out) traffic
//! (paper §III-A: 8 Slingshot 11 NICs per node; §III-C: the host proxy
//! hands GPU-initiated inter-node ops to the host OpenSHMEM library, which
//! RDMAs directly into device memory via FI_HMEM registration).

#[derive(Clone, Debug)]
pub struct NicParams {
    /// Per-NIC injection bandwidth, GB/s (Slingshot 11 ≈ 200 Gb/s).
    pub bw_gbs: f64,
    /// End-to-end small-message latency, ns.
    pub latency_ns: f64,
    /// Extra latency when the target buffer is GPU memory without dmabuf
    /// peer-mapping (bounce through host) — exercised by failure-injection
    /// tests only; FI_HMEM-registered heaps skip it.
    pub bounce_penalty_ns: f64,
    /// NICs per node (traffic stripes across them).
    pub nics_per_node: usize,
    /// NIC rails one transfer may stripe its chunks across (≤
    /// `nics_per_node`; 1 disables the remote chunk pipeline entirely —
    /// the pre-striping single-RDMA behavior).
    pub rails: usize,
    /// Sustained per-rail injection rate as a fraction of the nominal
    /// per-NIC bandwidth (a proxy-driven command sequence may not saturate
    /// its NIC; the remote twin of `ce.single_engine_frac`).
    pub rail_bw_frac: f64,
    /// Per-chunk injection startup on a rail: each additional back-to-back
    /// chunk round on the critical path pays this (the first chunk's
    /// startup is covered by `latency_ns`).
    pub rail_startup_ns: f64,
    /// Smallest chunk worth its own rail injection startup: remote
    /// transfers at or below twice this size never stripe (planner knob).
    pub rail_chunk_min_bytes: usize,
}

impl Default for NicParams {
    fn default() -> Self {
        NicParams {
            bw_gbs: 23.0,
            latency_ns: 1_800.0,
            bounce_penalty_ns: 6_000.0,
            nics_per_node: 8,
            rails: 4,
            rail_bw_frac: 1.0,
            rail_startup_ns: 500.0,
            rail_chunk_min_bytes: 256 << 10,
        }
    }
}

impl NicParams {
    /// Overlay the live learned constants (closed-loop calibration,
    /// `sim::params`) onto this configured param set: the calibrated
    /// per-rail fraction and injection startup replace the config values,
    /// the structural knobs (NIC count, rail count, latency, chunk
    /// minimum) stay configured. An un-calibrated store hands back the
    /// identical f64 bits — estimates stay bit-identical.
    pub fn with_learned(&self, learned: &crate::sim::params::LearnedParams) -> Self {
        NicParams {
            rail_bw_frac: learned.rail_bw_frac,
            rail_startup_ns: learned.rail_startup_ns,
            ..self.clone()
        }
    }

    /// RDMA put/get of `bytes` into a registered (FI_HMEM) heap, ns.
    pub fn rdma_ns(&self, bytes: usize) -> f64 {
        self.latency_ns + bytes as f64 / self.bw_gbs
    }

    /// Sustained rate of one rail.
    pub fn rail_bw_gbs(&self) -> f64 {
        self.bw_gbs * self.rail_bw_frac.clamp(0.01, 1.0)
    }

    /// Aggregate rate of `width` rails striping one transfer, capped at
    /// the configured rail count (each rail is its own NIC; the node's
    /// other NICs carry other traffic).
    pub fn rail_striped_bw_gbs(&self, width: usize) -> f64 {
        width.clamp(1, self.rails.max(1)) as f64 * self.rail_bw_gbs()
    }

    /// RDMA of `bytes` split into `chunks` chunks striped over `width`
    /// rails, ns: one end-to-end latency, `ceil(chunks/width) - 1`
    /// additional back-to-back injection startups on the critical path,
    /// and the data at the striped rate. Degenerates to [`Self::rdma_ns`]
    /// at `(width, chunks) = (1, 1)`.
    pub fn rdma_striped_ns(&self, bytes: usize, width: usize, chunks: usize) -> f64 {
        let chunks = chunks.max(1);
        let width = width.clamp(1, self.rails.max(1)).min(chunks);
        let rounds = chunks.div_ceil(width);
        self.latency_ns
            + (rounds - 1) as f64 * self.rail_startup_ns
            + bytes as f64 / self.rail_striped_bw_gbs(width)
    }

    /// Same transfer when the heap is NOT registered for device RDMA:
    /// staged through host memory.
    pub fn bounce_ns(&self, bytes: usize) -> f64 {
        self.rdma_ns(bytes) + self.bounce_penalty_ns + bytes as f64 / self.bw_gbs
    }

    /// Aggregate node injection bandwidth with all NICs striped.
    pub fn node_bw_gbs(&self) -> f64 {
        self.bw_gbs * self.nics_per_node as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registered_beats_bounce() {
        let n = NicParams::default();
        assert!(n.rdma_ns(1 << 20) < n.bounce_ns(1 << 20));
    }

    #[test]
    fn striped_rdma_degenerates_to_single_rail() {
        let n = NicParams::default();
        for bytes in [64usize, 1 << 20, 8 << 20] {
            assert_eq!(n.rdma_striped_ns(bytes, 1, 1), n.rdma_ns(bytes));
        }
        // Width never exceeds the configured rail count.
        let one_rail = NicParams { rails: 1, ..NicParams::default() };
        assert_eq!(
            one_rail.rdma_striped_ns(1 << 20, 4, 4),
            one_rail.latency_ns
                + 3.0 * one_rail.rail_startup_ns
                + (1 << 20) as f64 / one_rail.rail_bw_gbs()
        );
    }

    #[test]
    fn rail_striping_recovers_aggregate_injection() {
        let n = NicParams::default();
        let bytes = 8 << 20;
        let single = n.rdma_striped_ns(bytes, 1, 1);
        let striped = n.rdma_striped_ns(bytes, 4, 4);
        assert!(striped * 2.0 <= single, "striped {striped} !<= single {single}/2");
        assert_eq!(n.rail_striped_bw_gbs(4), 4.0 * n.rail_bw_gbs());
    }

    #[test]
    fn with_learned_overlays_only_the_learnable_fields() {
        let n = NicParams::default();
        let mut learned = crate::sim::params::LearnedParams::from_cost(
            &crate::sim::cost::CostParams::default(),
        );
        let same = n.with_learned(&learned);
        assert_eq!(same.rail_bw_frac.to_bits(), n.rail_bw_frac.to_bits());
        assert_eq!(same.rail_startup_ns.to_bits(), n.rail_startup_ns.to_bits());
        learned.rail_bw_frac = 0.5;
        learned.rail_startup_ns = 750.0;
        let eff = n.with_learned(&learned);
        assert_eq!(eff.rail_bw_frac, 0.5);
        assert_eq!(eff.rail_startup_ns, 750.0);
        assert_eq!(eff.rails, n.rails);
        assert_eq!(eff.latency_ns, n.latency_ns);
        assert_eq!(eff.rail_bw_gbs(), n.bw_gbs * 0.5);
    }

    #[test]
    fn nic_slower_than_xelink_latency() {
        // Scale-out latency must exceed scale-up store latency, or the
        // proxy cutover logic would be meaningless.
        let n = NicParams::default();
        let xe = super::super::xelink::XeLinkParams::default();
        assert!(n.latency_ns > xe.store_latency_ns);
    }
}
