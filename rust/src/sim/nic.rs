//! Slingshot-class NIC model for inter-node (scale-out) traffic
//! (paper §III-A: 8 Slingshot 11 NICs per node; §III-C: the host proxy
//! hands GPU-initiated inter-node ops to the host OpenSHMEM library, which
//! RDMAs directly into device memory via FI_HMEM registration).

#[derive(Clone, Debug)]
pub struct NicParams {
    /// Per-NIC injection bandwidth, GB/s (Slingshot 11 ≈ 200 Gb/s).
    pub bw_gbs: f64,
    /// End-to-end small-message latency, ns.
    pub latency_ns: f64,
    /// Extra latency when the target buffer is GPU memory without dmabuf
    /// peer-mapping (bounce through host) — exercised by failure-injection
    /// tests only; FI_HMEM-registered heaps skip it.
    pub bounce_penalty_ns: f64,
    /// NICs per node (traffic stripes across them).
    pub nics_per_node: usize,
}

impl Default for NicParams {
    fn default() -> Self {
        NicParams {
            bw_gbs: 23.0,
            latency_ns: 1_800.0,
            bounce_penalty_ns: 6_000.0,
            nics_per_node: 8,
        }
    }
}

impl NicParams {
    /// RDMA put/get of `bytes` into a registered (FI_HMEM) heap, ns.
    pub fn rdma_ns(&self, bytes: usize) -> f64 {
        self.latency_ns + bytes as f64 / self.bw_gbs
    }

    /// Same transfer when the heap is NOT registered for device RDMA:
    /// staged through host memory.
    pub fn bounce_ns(&self, bytes: usize) -> f64 {
        self.rdma_ns(bytes) + self.bounce_penalty_ns + bytes as f64 / self.bw_gbs
    }

    /// Aggregate node injection bandwidth with all NICs striped.
    pub fn node_bw_gbs(&self) -> f64 {
        self.bw_gbs * self.nics_per_node as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registered_beats_bounce() {
        let n = NicParams::default();
        assert!(n.rdma_ns(1 << 20) < n.bounce_ns(1 << 20));
    }

    #[test]
    fn nic_slower_than_xelink_latency() {
        // Scale-out latency must exceed scale-up store latency, or the
        // proxy cutover logic would be meaningless.
        let n = NicParams::default();
        let xe = super::super::xelink::XeLinkParams::default();
        assert!(n.latency_ns > xe.store_latency_ns);
    }
}
