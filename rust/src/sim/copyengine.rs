//! GPU copy-engine model (paper §III-B, §III-C).
//!
//! PVC blitter engines run Xe-Links at full speed while compute cores stay
//! busy — but pay a startup latency per transfer. ishmem's cutover strategy
//! exists precisely because of this trade-off: organic load/store wins for
//! small messages, engines win for big ones (Fig 3–5).
//!
//! The model: `startup + doorbell + bytes / path_bw`. Engines are a per-GPU
//! resource; concurrent users of one GPU's engines queue (modeled by an
//! occupancy counter so collectives that fan out N transfers see
//! serialization on the shared engine).

use std::sync::atomic::{AtomicU64, Ordering};

use super::topology::Locality;
use super::xelink::XeLinkParams;

#[derive(Clone, Debug)]
pub struct CopyEngineParams {
    /// Engine startup latency with an *immediate* command list, ns.
    pub startup_immediate_ns: f64,
    /// Engine startup latency with a standard command list, ns
    /// (paper §III-C: ishmem supports both; immediate is the low-latency one).
    pub startup_standard_ns: f64,
    /// Extra host-side doorbell cost when the host proxy starts the engine
    /// (PCIe write + arbitration), ns.
    pub host_doorbell_ns: f64,
    /// Number of main copy engines per GPU.
    pub engines_per_gpu: usize,
}

impl Default for CopyEngineParams {
    fn default() -> Self {
        CopyEngineParams {
            startup_immediate_ns: 3_200.0,
            startup_standard_ns: 5_500.0,
            host_doorbell_ns: 900.0,
            engines_per_gpu: 8,
        }
    }
}

impl CopyEngineParams {
    /// Copy-engine path bandwidth — engines drive the same links as
    /// load/store but sustain the full rate (plus faster same-tile blits).
    pub fn path_bw_gbs(&self, xe: &XeLinkParams, loc: Locality) -> f64 {
        match loc {
            Locality::SameTile => xe.hbm_bw_gbs / 2.0,
            Locality::SameGpu => xe.mdfi_bw_gbs,
            Locality::SameNode => xe.link_bw_gbs,
            Locality::Remote => 0.0,
        }
    }

    /// Modeled duration of one engine transfer (ns).
    pub fn transfer_ns(
        &self,
        xe: &XeLinkParams,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        host_initiated: bool,
    ) -> f64 {
        assert!(loc != Locality::Remote, "engines cannot cross nodes");
        let mut t = if immediate_cl {
            self.startup_immediate_ns
        } else {
            self.startup_standard_ns
        };
        if host_initiated {
            t += self.host_doorbell_ns;
        }
        t + bytes as f64 / self.path_bw_gbs(xe, loc)
    }
}

/// Per-GPU engine occupancy: transfers queued beyond `engines_per_gpu`
/// serialize. Tracked with a simple in-flight counter — enough to model the
/// contention shape (fcollect fanning out N copies on one GPU) — plus an
/// outstanding-bytes backlog that the planner folds into its engine-path
/// estimate, so cutover decisions shift while the queue is loaded.
#[derive(Debug)]
pub struct EngineQueue {
    in_flight: AtomicU64,
    /// Bytes of copy-engine work accepted but not yet modeled complete
    /// (blocking ops hold their bytes for the call; NBI ops until quiet).
    queued_bytes: AtomicU64,
    engines: u64,
}

impl EngineQueue {
    pub fn new(engines: usize) -> Self {
        EngineQueue {
            in_flight: AtomicU64::new(0),
            queued_bytes: AtomicU64::new(0),
            engines: engines.max(1) as u64,
        }
    }

    /// Charge factor for a new transfer: 1.0 while engines are free, then
    /// proportional queueing delay.
    pub fn begin(&self) -> f64 {
        let q = self.in_flight.fetch_add(1, Ordering::AcqRel);
        if q < self.engines {
            1.0
        } else {
            (q + 1) as f64 / self.engines as f64
        }
    }

    pub fn end(&self) {
        self.in_flight.fetch_sub(1, Ordering::AcqRel);
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Register `bytes` of accepted-but-incomplete engine work.
    pub fn reserve_bytes(&self, bytes: u64) {
        self.queued_bytes.fetch_add(bytes, Ordering::AcqRel);
    }

    /// Retire previously reserved engine work.
    pub fn release_bytes(&self, bytes: u64) {
        let prev = self.queued_bytes.fetch_sub(bytes, Ordering::AcqRel);
        debug_assert!(prev >= bytes, "engine backlog underflow: {prev} - {bytes}");
    }

    /// Current byte backlog on this GPU's engines.
    pub fn queued_bytes(&self) -> u64 {
        self.queued_bytes.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn startup_dominates_small_messages() {
        let ce = CopyEngineParams::default();
        let xe = XeLinkParams::default();
        let t = ce.transfer_ns(&xe, Locality::SameNode, 8, true, false);
        assert!(t >= ce.startup_immediate_ns);
        // Effectively all startup:
        assert!((t - ce.startup_immediate_ns) < 10.0);
    }

    #[test]
    fn immediate_cl_faster_than_standard() {
        let ce = CopyEngineParams::default();
        let xe = XeLinkParams::default();
        let ti = ce.transfer_ns(&xe, Locality::SameGpu, 4096, true, false);
        let ts = ce.transfer_ns(&xe, Locality::SameGpu, 4096, false, false);
        assert!(ti < ts);
    }

    #[test]
    fn engine_beats_loadstore_for_large_only() {
        // The Fig 3 crossover: single-thread load/store wins below ~4KB,
        // engine wins above.
        let ce = CopyEngineParams::default();
        let xe = XeLinkParams::default();
        let small = 1024;
        let large = 1 << 20;
        assert!(
            xe.loadstore_ns(Locality::SameNode, small, 1)
                < ce.transfer_ns(&xe, Locality::SameNode, small, true, false)
        );
        assert!(
            xe.loadstore_ns(Locality::SameNode, large, 1)
                > ce.transfer_ns(&xe, Locality::SameNode, large, true, false)
        );
    }

    #[test]
    fn queue_serializes_past_engine_count() {
        let q = EngineQueue::new(2);
        assert_eq!(q.begin(), 1.0);
        assert_eq!(q.begin(), 1.0);
        assert!(q.begin() > 1.0);
        q.end();
        q.end();
        q.end();
        assert_eq!(q.in_flight(), 0);
    }

    #[test]
    fn byte_backlog_tracks_reserve_release() {
        let q = EngineQueue::new(4);
        assert_eq!(q.queued_bytes(), 0);
        q.reserve_bytes(1 << 20);
        q.reserve_bytes(4096);
        assert_eq!(q.queued_bytes(), (1 << 20) + 4096);
        q.release_bytes(4096);
        q.release_bytes(1 << 20);
        assert_eq!(q.queued_bytes(), 0);
    }
}
