//! GPU copy-engine model (paper §III-B, §III-C).
//!
//! PVC blitter engines run Xe-Links at full speed while compute cores stay
//! busy — but pay a startup latency per transfer, and a *single* engine
//! sustains only a fraction of the path roofline (`single_engine_frac`).
//! PVC exposes `engines_per_gpu` main copy engines: striping a large
//! transfer's chunks across `k` engines sustains `min(k · engine_bw,
//! path_bw)` — which is why the xfer planner pipelines chunked slabs over
//! several engines (ISSUE 3) instead of parking everything on one queue.
//!
//! ishmem's cutover strategy exists precisely because of the startup
//! trade-off: organic load/store wins for small messages, engines win for
//! big ones (Fig 3–5).
//!
//! The model: `startups + doorbell + bytes / striped_bw`. Engines are a
//! per-GPU resource; each engine keeps its own byte backlog of
//! accepted-but-incomplete work ([`EngineQueue`]), so the planner can both
//! fold the total backlog into its engine-path estimate *and* place new
//! chunks on the least-loaded engines.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use super::topology::Locality;
use super::xelink::XeLinkParams;

#[derive(Clone, Debug)]
pub struct CopyEngineParams {
    /// Engine startup latency with an *immediate* command list, ns.
    pub startup_immediate_ns: f64,
    /// Engine startup latency with a standard command list, ns
    /// (paper §III-C: ishmem supports both; immediate is the low-latency one).
    pub startup_standard_ns: f64,
    /// Extra host-side doorbell cost when the host proxy starts the engine
    /// (PCIe write + arbitration), ns.
    pub host_doorbell_ns: f64,
    /// Number of main copy engines per GPU.
    pub engines_per_gpu: usize,
    /// Sustained single-engine copy rate as a fraction of the path
    /// roofline: one blitter cannot saturate the link on its own; striping
    /// chunks across `k` engines sustains `min(k · frac, 1) · path_bw`.
    pub single_engine_frac: f64,
    /// Maximum engines one transfer may stripe across (planner knob; the
    /// per-GPU engine count still caps it).
    pub stripe_max_engines: usize,
    /// Smallest chunk worth its own engine startup: transfers at or below
    /// twice this size never stripe (planner knob).
    pub chunk_min_bytes: usize,
}

impl Default for CopyEngineParams {
    fn default() -> Self {
        CopyEngineParams {
            startup_immediate_ns: 3_200.0,
            startup_standard_ns: 5_500.0,
            host_doorbell_ns: 900.0,
            engines_per_gpu: 8,
            single_engine_frac: 0.25,
            stripe_max_engines: 4,
            chunk_min_bytes: 256 << 10,
        }
    }
}

impl CopyEngineParams {
    /// Overlay the live learned constants (closed-loop calibration,
    /// `sim::params`) onto this configured param set: the calibrated
    /// fraction and startup terms replace the config values, the
    /// structural knobs (engine count, stripe limits, chunk minimum,
    /// doorbell) stay configured. An un-calibrated store hands back the
    /// identical f64 bits, so every downstream estimate is bit-identical
    /// to the pre-calibration formula.
    pub fn with_learned(&self, learned: &crate::sim::params::LearnedParams) -> Self {
        CopyEngineParams {
            single_engine_frac: learned.single_engine_frac,
            startup_immediate_ns: learned.startup_immediate_ns,
            startup_standard_ns: learned.startup_standard_ns,
            ..self.clone()
        }
    }

    /// Copy-engine path roofline — the engines drive the same links as
    /// load/store and, striped wide enough, sustain the full rate (plus
    /// faster same-tile blits).
    pub fn path_bw_gbs(&self, xe: &XeLinkParams, loc: Locality) -> f64 {
        match loc {
            Locality::SameTile => xe.hbm_bw_gbs / 2.0,
            Locality::SameGpu => xe.mdfi_bw_gbs,
            Locality::SameNode => xe.link_bw_gbs,
            Locality::Remote => 0.0,
        }
    }

    /// Sustained rate of one engine on this path.
    pub fn engine_bw_gbs(&self, xe: &XeLinkParams, loc: Locality) -> f64 {
        self.path_bw_gbs(xe, loc) * self.single_engine_frac.clamp(0.01, 1.0)
    }

    /// Aggregate rate of `width` engines striping one transfer, capped at
    /// the path roofline (the physical link is still shared).
    pub fn striped_bw_gbs(&self, xe: &XeLinkParams, loc: Locality, width: usize) -> f64 {
        (width.max(1) as f64 * self.engine_bw_gbs(xe, loc)).min(self.path_bw_gbs(xe, loc))
    }

    /// Modeled duration of one *single-engine* transfer (ns).
    pub fn transfer_ns(
        &self,
        xe: &XeLinkParams,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        host_initiated: bool,
    ) -> f64 {
        self.striped_transfer_ns(xe, loc, bytes, immediate_cl, host_initiated, 1, 1)
    }

    /// Modeled duration of `bytes` split into `chunks` chunks striped over
    /// `width` engines (ns): each engine runs its chunks back-to-back
    /// (`ceil(chunks / width)` startups on the critical path), the data
    /// itself moves at the striped rate.
    pub fn striped_transfer_ns(
        &self,
        xe: &XeLinkParams,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        host_initiated: bool,
        width: usize,
        chunks: usize,
    ) -> f64 {
        assert!(loc != Locality::Remote, "engines cannot cross nodes");
        let chunks = chunks.max(1);
        let width = width.clamp(1, self.engines_per_gpu.max(1)).min(chunks);
        let startup = if immediate_cl {
            self.startup_immediate_ns
        } else {
            self.startup_standard_ns
        };
        let mut t = chunks.div_ceil(width) as f64 * startup;
        if host_initiated {
            t += self.host_doorbell_ns;
        }
        t + bytes as f64 / self.striped_bw_gbs(xe, loc, width)
    }
}

/// Per-GPU engine state: an in-flight counter (transfers queued beyond
/// `engines_per_gpu` serialize) plus a *per-engine* byte backlog of
/// accepted-but-incomplete work (blocking ops hold their bytes for the
/// call; NBI ops until quiet). The planner folds the total backlog into
/// its engine-path estimate and places new chunks on the least-loaded
/// engines.
#[derive(Debug)]
pub struct EngineQueue {
    in_flight: AtomicU64,
    /// Outstanding bytes per engine (index = engine slot on this GPU).
    per_engine_bytes: Vec<AtomicU64>,
    /// Liveness per engine: `false` = killed/quarantined (fault injection,
    /// ISSUE 8). All-true at construction, so a machine that never injects
    /// faults behaves bit-identically to the pre-fault code.
    alive: Vec<AtomicBool>,
    engines: u64,
}

impl EngineQueue {
    pub fn new(engines: usize) -> Self {
        let engines = engines.max(1);
        EngineQueue {
            in_flight: AtomicU64::new(0),
            per_engine_bytes: (0..engines).map(|_| AtomicU64::new(0)).collect(),
            alive: (0..engines).map(|_| AtomicBool::new(true)).collect(),
            engines: engines as u64,
        }
    }

    pub fn engines(&self) -> usize {
        self.per_engine_bytes.len()
    }

    /// Charge factor for a new transfer: 1.0 while engines are free, then
    /// proportional queueing delay.
    pub fn begin(&self) -> f64 {
        let q = self.in_flight.fetch_add(1, Ordering::AcqRel);
        if q < self.engines {
            1.0
        } else {
            (q + 1) as f64 / self.engines as f64
        }
    }

    pub fn end(&self) {
        self.in_flight.fetch_sub(1, Ordering::AcqRel);
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Acquire)
    }

    fn slot(&self, engine: usize) -> &AtomicU64 {
        &self.per_engine_bytes[engine.min(self.per_engine_bytes.len() - 1)]
    }

    fn slot_idx(&self, engine: usize) -> usize {
        engine.min(self.alive.len() - 1)
    }

    /// Mark `engine` dead. Returns `true` iff it was alive (a transition).
    pub fn kill(&self, engine: usize) -> bool {
        self.alive[self.slot_idx(engine)].swap(false, Ordering::AcqRel)
    }

    /// Mark `engine` live again. Returns `true` iff it was dead.
    pub fn revive(&self, engine: usize) -> bool {
        !self.alive[self.slot_idx(engine)].swap(true, Ordering::AcqRel)
    }

    /// Is `engine` currently live?
    pub fn is_live(&self, engine: usize) -> bool {
        self.alive[self.slot_idx(engine)].load(Ordering::Acquire)
    }

    /// Number of live engines (0 = every engine on this GPU is dead).
    pub fn live_count(&self) -> usize {
        self.alive
            .iter()
            .filter(|a| a.load(Ordering::Acquire))
            .count()
    }

    /// Register `bytes` of accepted-but-incomplete work on `engine`.
    pub fn reserve_on(&self, engine: usize, bytes: u64) {
        self.slot(engine).fetch_add(bytes, Ordering::AcqRel);
    }

    /// Retire work previously reserved on `engine`. Saturating: a chunk
    /// whose backlog was migrated off a dead engine by the proxy may be
    /// released against its original slot later (the initiator's ledger
    /// predates the migration), so under-releases floor at zero instead
    /// of wrapping.
    pub fn release_on(&self, engine: usize, bytes: u64) {
        let slot = self.slot(engine);
        let mut cur = slot.load(Ordering::Acquire);
        loop {
            let next = cur.saturating_sub(bytes);
            match slot.compare_exchange_weak(cur, next, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    /// Move up to `bytes` of backlog from `from` to `to` (proxy
    /// re-dispatch of in-flight chunks off a dead engine). Saturates at
    /// whatever `from` actually holds.
    pub fn migrate(&self, from: usize, to: usize, bytes: u64) {
        if self.slot_idx(from) == self.slot_idx(to) {
            return;
        }
        let src = self.slot(from);
        let mut cur = src.load(Ordering::Acquire);
        let moved = loop {
            let take = cur.min(bytes);
            let next = cur - take;
            match src.compare_exchange_weak(cur, next, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => break take,
                Err(now) => cur = now,
            }
        };
        if moved > 0 {
            self.slot(to).fetch_add(moved, Ordering::AcqRel);
        }
    }

    /// Legacy single-queue view: reserve on engine 0.
    pub fn reserve_bytes(&self, bytes: u64) {
        self.reserve_on(0, bytes);
    }

    /// Legacy single-queue view: release from engine 0.
    pub fn release_bytes(&self, bytes: u64) {
        self.release_on(0, bytes);
    }

    /// Current byte backlog of one engine.
    pub fn engine_bytes(&self, engine: usize) -> u64 {
        self.slot(engine).load(Ordering::Acquire)
    }

    /// Total byte backlog across this GPU's engines.
    pub fn queued_bytes(&self) -> u64 {
        self.per_engine_bytes
            .iter()
            .map(|b| b.load(Ordering::Acquire))
            .sum()
    }

    /// The `width` least-loaded *live* engine slots, lightest first
    /// (approximate under concurrency — placement, not correctness,
    /// depends on it). Dead engines are excluded; if every engine is dead
    /// the full set is returned unfiltered (last-lane fallback — the
    /// caller counts the degradation, the transfer still has to move).
    pub fn least_loaded(&self, width: usize) -> Vec<usize> {
        let mut loads: Vec<(u64, usize)> = self
            .per_engine_bytes
            .iter()
            .enumerate()
            .filter(|(i, _)| self.alive[*i].load(Ordering::Acquire))
            .map(|(i, b)| (b.load(Ordering::Acquire), i))
            .collect();
        if loads.is_empty() {
            loads = self
                .per_engine_bytes
                .iter()
                .enumerate()
                .map(|(i, b)| (b.load(Ordering::Acquire), i))
                .collect();
        }
        loads.sort_unstable();
        let n = loads.len();
        loads
            .into_iter()
            .take(width.clamp(1, n))
            .map(|(_, i)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn startup_dominates_small_messages() {
        let ce = CopyEngineParams::default();
        let xe = XeLinkParams::default();
        let t = ce.transfer_ns(&xe, Locality::SameNode, 8, true, false);
        assert!(t >= ce.startup_immediate_ns);
        // Effectively all startup:
        assert!((t - ce.startup_immediate_ns) < 10.0);
    }

    #[test]
    fn immediate_cl_faster_than_standard() {
        let ce = CopyEngineParams::default();
        let xe = XeLinkParams::default();
        let ti = ce.transfer_ns(&xe, Locality::SameGpu, 4096, true, false);
        let ts = ce.transfer_ns(&xe, Locality::SameGpu, 4096, false, false);
        assert!(ti < ts);
    }

    #[test]
    fn engine_beats_loadstore_for_large_only() {
        // The Fig 3 crossover: single-thread load/store wins below ~4KB,
        // engine wins above (even at the single-engine rate).
        let ce = CopyEngineParams::default();
        let xe = XeLinkParams::default();
        let small = 1024;
        let large = 1 << 20;
        assert!(
            xe.loadstore_ns(Locality::SameNode, small, 1)
                < ce.transfer_ns(&xe, Locality::SameNode, small, true, false)
        );
        assert!(
            xe.loadstore_ns(Locality::SameNode, large, 1)
                > ce.transfer_ns(&xe, Locality::SameNode, large, true, false)
        );
    }

    #[test]
    fn striping_recovers_the_link_roofline() {
        let ce = CopyEngineParams::default();
        let xe = XeLinkParams::default();
        let loc = Locality::SameNode;
        // One engine is a fraction of the link; four reach the roofline.
        assert!(ce.engine_bw_gbs(&xe, loc) < ce.path_bw_gbs(&xe, loc) / 2.0);
        assert_eq!(ce.striped_bw_gbs(&xe, loc, 4), ce.path_bw_gbs(&xe, loc));
        // Width never pushes past the physical link.
        assert_eq!(ce.striped_bw_gbs(&xe, loc, 64), ce.path_bw_gbs(&xe, loc));
        // A striped 4 MiB transfer beats the single-engine one ≥2×.
        let bytes = 4 << 20;
        let single = ce.striped_transfer_ns(&xe, loc, bytes, true, false, 1, 1);
        let striped = ce.striped_transfer_ns(&xe, loc, bytes, true, false, 4, 4);
        assert!(striped * 2.0 <= single, "striped {striped} !<= single {single}/2");
    }

    #[test]
    fn striped_transfer_degenerates_to_single() {
        let ce = CopyEngineParams::default();
        let xe = XeLinkParams::default();
        let a = ce.transfer_ns(&xe, Locality::SameGpu, 4096, true, true);
        let b = ce.striped_transfer_ns(&xe, Locality::SameGpu, 4096, true, true, 1, 1);
        assert_eq!(a, b);
    }

    #[test]
    fn with_learned_overlays_only_the_learnable_fields() {
        let ce = CopyEngineParams::default();
        let mut learned = crate::sim::params::LearnedParams::from_cost(
            &crate::sim::cost::CostParams::default(),
        );
        // Un-learned overlay is the identity (bit-for-bit).
        let same = ce.with_learned(&learned);
        assert_eq!(same.single_engine_frac.to_bits(), ce.single_engine_frac.to_bits());
        assert_eq!(same.startup_immediate_ns.to_bits(), ce.startup_immediate_ns.to_bits());
        // Learned values replace the fractions/startups; structure stays.
        learned.single_engine_frac = 0.5;
        learned.startup_standard_ns = 9_000.0;
        let eff = ce.with_learned(&learned);
        assert_eq!(eff.single_engine_frac, 0.5);
        assert_eq!(eff.startup_standard_ns, 9_000.0);
        assert_eq!(eff.engines_per_gpu, ce.engines_per_gpu);
        assert_eq!(eff.chunk_min_bytes, ce.chunk_min_bytes);
        let xe = XeLinkParams::default();
        assert_eq!(
            eff.engine_bw_gbs(&xe, Locality::SameNode),
            2.0 * ce.engine_bw_gbs(&xe, Locality::SameNode),
        );
    }

    #[test]
    fn queue_serializes_past_engine_count() {
        let q = EngineQueue::new(2);
        assert_eq!(q.begin(), 1.0);
        assert_eq!(q.begin(), 1.0);
        assert!(q.begin() > 1.0);
        q.end();
        q.end();
        q.end();
        assert_eq!(q.in_flight(), 0);
    }

    #[test]
    fn byte_backlog_tracks_reserve_release() {
        let q = EngineQueue::new(4);
        assert_eq!(q.queued_bytes(), 0);
        q.reserve_bytes(1 << 20);
        q.reserve_bytes(4096);
        assert_eq!(q.queued_bytes(), (1 << 20) + 4096);
        q.release_bytes(4096);
        q.release_bytes(1 << 20);
        assert_eq!(q.queued_bytes(), 0);
    }

    #[test]
    fn per_engine_backlog_is_independent() {
        let q = EngineQueue::new(4);
        q.reserve_on(1, 100);
        q.reserve_on(3, 50);
        assert_eq!(q.engine_bytes(1), 100);
        assert_eq!(q.engine_bytes(3), 50);
        assert_eq!(q.engine_bytes(0), 0);
        assert_eq!(q.queued_bytes(), 150);
        // Out-of-range engine indices clamp to the last slot.
        q.reserve_on(99, 7);
        assert_eq!(q.engine_bytes(3), 57);
        q.release_on(99, 7);
        q.release_on(1, 100);
        q.release_on(3, 50);
        assert_eq!(q.queued_bytes(), 0);
    }

    #[test]
    fn least_loaded_orders_by_backlog() {
        let q = EngineQueue::new(4);
        q.reserve_on(0, 300);
        q.reserve_on(1, 100);
        q.reserve_on(2, 200);
        // Engine 3 is empty → lightest; then 1, 2, 0.
        assert_eq!(q.least_loaded(4), vec![3, 1, 2, 0]);
        assert_eq!(q.least_loaded(2), vec![3, 1]);
        // Width clamps to the engine count and to ≥1.
        assert_eq!(q.least_loaded(0).len(), 1);
        assert_eq!(q.least_loaded(99).len(), 4);
    }

    #[test]
    fn dead_engines_are_excluded_from_placement() {
        let q = EngineQueue::new(4);
        assert_eq!(q.live_count(), 4);
        assert!(q.kill(2), "first kill is a transition");
        assert!(!q.kill(2), "second kill is not");
        assert!(!q.is_live(2));
        assert_eq!(q.live_count(), 3);
        let picked = q.least_loaded(4);
        assert_eq!(picked.len(), 3);
        assert!(!picked.contains(&2), "dead engine placed: {picked:?}");
        assert!(q.revive(2), "revive of a dead engine is a transition");
        assert!(!q.revive(2));
        assert_eq!(q.live_count(), 4);
        assert_eq!(q.least_loaded(4).len(), 4);
    }

    #[test]
    fn all_dead_falls_back_to_the_full_set() {
        let q = EngineQueue::new(2);
        q.kill(0);
        q.kill(1);
        assert_eq!(q.live_count(), 0);
        // Placement still answers — the caller counts the fallback.
        assert_eq!(q.least_loaded(2).len(), 2);
    }

    #[test]
    fn migrate_moves_backlog_and_release_saturates() {
        let q = EngineQueue::new(4);
        q.reserve_on(1, 100);
        q.migrate(1, 3, 60);
        assert_eq!(q.engine_bytes(1), 40);
        assert_eq!(q.engine_bytes(3), 60);
        // Migrating more than the slot holds saturates.
        q.migrate(1, 0, 1000);
        assert_eq!(q.engine_bytes(1), 0);
        assert_eq!(q.engine_bytes(0), 40);
        // A stale release against the drained slot floors at zero.
        q.release_on(1, 100);
        assert_eq!(q.engine_bytes(1), 0);
        assert_eq!(q.queued_bytes(), 100);
    }
}
