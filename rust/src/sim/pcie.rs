//! PCIe Gen5 CPU↔GPU interface model (paper §III-A, §III-D).
//!
//! Two behaviours matter to ishmem: (1) individual loads/stores across PCIe
//! are latency-bound (which is why ishmem keeps separate host- and
//! device-resident data structures, §III-G.1), and (2) the reverse-offload
//! ring uses only *store* instructions which are fire-and-forget and
//! pipelined (§III-D) — a message transmission is a single bus operation.

#[derive(Clone, Debug)]
pub struct PcieParams {
    /// PCIe Gen5 x16 effective bandwidth, GB/s.
    pub bw_gbs: f64,
    /// One-way posted-write latency (GPU→host visibility), ns.
    pub write_latency_ns: f64,
    /// Full round trip GPU→host→GPU for a request+completion pair, ns.
    /// Paper §III-D: "about 5 us round trip ... close to the required PCIe
    /// bus and arbitration times".
    pub ring_rtt_ns: f64,
    /// Slot arbitration on the ring (single atomic fetch-add), ns.
    pub ring_slot_ns: f64,
}

impl Default for PcieParams {
    fn default() -> Self {
        PcieParams {
            bw_gbs: 55.0,
            write_latency_ns: 700.0,
            ring_rtt_ns: 5_000.0,
            ring_slot_ns: 50.0,
        }
    }
}

impl PcieParams {
    /// Bulk transfer over PCIe (host-staged path), ns.
    pub fn transfer_ns(&self, bytes: usize) -> f64 {
        self.write_latency_ns + bytes as f64 / self.bw_gbs
    }

    /// Device-side cost of posting one ring message (fire-and-forget).
    pub fn ring_post_ns(&self) -> f64 {
        self.ring_slot_ns + self.write_latency_ns * 0.1
    }

    /// Device-visible completion wait for one proxied op (blocking path).
    pub fn ring_round_trip_ns(&self) -> f64 {
        self.ring_rtt_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_rtt_matches_paper_claim() {
        let p = PcieParams::default();
        assert!((p.ring_round_trip_ns() - 5_000.0).abs() < 1.0);
    }

    #[test]
    fn posting_is_much_cheaper_than_waiting() {
        let p = PcieParams::default();
        // >20M req/s from many threads requires post cost ≪ RTT.
        assert!(p.ring_post_ns() * 40.0 < p.ring_round_trip_ns());
    }
}
