//! Node/GPU/tile topology and PE placement (paper §III-A, Fig. 1).
//!
//! Intel SHMEM maps one PE to one GPU *tile* (§III-E: 1:1 PE-to-SYCL-device
//! with a PVC GPU exposing 2 tiles). Xe-Link can be configured 2/4/6/8-way
//! with every GPU linked directly to every other GPU (§III-A).

/// Processing element id (OpenSHMEM rank), `0..npes`.
pub type PeId = usize;

/// Relative placement of two PEs — decides the transfer path and its cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Locality {
    /// Same tile: src and dst live in the same HBM stack.
    SameTile,
    /// Two tiles of one GPU (MDFI on-package fabric).
    SameGpu,
    /// Different GPUs on one node, reachable over Xe-Link load/store.
    SameNode,
    /// Different nodes: only reachable through the NIC (host proxy + OFI).
    Remote,
}

/// Immutable machine shape. The default mirrors Borealis/Aurora:
/// 1 node × 6 GPUs × 2 tiles = 12 PEs.
#[derive(Clone, Debug)]
pub struct Topology {
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub tiles_per_gpu: usize,
}

impl Default for Topology {
    fn default() -> Self {
        Topology { nodes: 1, gpus_per_node: 6, tiles_per_gpu: 2 }
    }
}

impl Topology {
    pub fn new(nodes: usize, gpus_per_node: usize, tiles_per_gpu: usize) -> Self {
        assert!(nodes > 0 && gpus_per_node > 0 && tiles_per_gpu > 0);
        assert!(
            matches!(gpus_per_node, 1..=8),
            "Xe-Link supports up to 8-way topologies (paper §III-A)"
        );
        Topology { nodes, gpus_per_node, tiles_per_gpu }
    }

    /// Single-node topology hosting *exactly* `npes` PEs: PVC-style
    /// 2-tile GPUs when even, 1-tile GPUs when odd (tests/benches that
    /// care about tile-vs-GPU locality should build an explicit topology).
    pub fn single_node_for(npes: usize) -> Self {
        assert!(npes >= 1, "need at least one PE");
        let (gpus, tiles) = if npes % 2 == 0 { (npes / 2, 2) } else { (npes, 1) };
        assert!(
            gpus <= 8,
            "single node supports at most 8 GPUs (asked for {npes} PEs)"
        );
        Topology::new(1, gpus, tiles)
    }

    /// Multi-node topology hosting *exactly* `npes` PEs, preferring the
    /// Aurora-like dense node shape (8 GPUs × 2 tiles = 16 PEs/node) and
    /// degrading gracefully: benches and tests build 64–1024-PE machines
    /// in one line. Falls back to `single_node_for` when one node fits.
    pub fn multi_node_for(npes: usize) -> Self {
        assert!(npes >= 1, "need at least one PE");
        if npes <= 16 && (npes % 2 == 0 && npes / 2 <= 8 || npes <= 8) {
            return Topology::single_node_for(npes);
        }
        // Prefer 2-tile GPUs and the widest Xe-Link fabric that divides
        // evenly; node counts grow as shapes shrink.
        for tiles in [2usize, 1] {
            for gpus in (1..=8).rev() {
                let per_node = gpus * tiles;
                if npes % per_node == 0 {
                    return Topology::new(npes / per_node, gpus, tiles);
                }
            }
        }
        unreachable!("gpus=1, tiles=1 always divides");
    }

    pub fn pes_per_gpu(&self) -> usize {
        self.tiles_per_gpu
    }

    pub fn pes_per_node(&self) -> usize {
        self.gpus_per_node * self.tiles_per_gpu
    }

    pub fn npes(&self) -> usize {
        self.nodes * self.pes_per_node()
    }

    pub fn node_of(&self, pe: PeId) -> usize {
        pe / self.pes_per_node()
    }

    pub fn gpu_of(&self, pe: PeId) -> usize {
        (pe % self.pes_per_node()) / self.tiles_per_gpu
    }

    pub fn tile_of(&self, pe: PeId) -> usize {
        pe % self.tiles_per_gpu
    }

    /// Global GPU index (unique across nodes) — copy engines queue per GPU.
    pub fn global_gpu_of(&self, pe: PeId) -> usize {
        self.node_of(pe) * self.gpus_per_node + self.gpu_of(pe)
    }

    pub fn classify(&self, a: PeId, b: PeId) -> Locality {
        assert!(a < self.npes() && b < self.npes(), "PE out of range");
        if self.node_of(a) != self.node_of(b) {
            Locality::Remote
        } else if self.gpu_of(a) != self.gpu_of(b) {
            Locality::SameNode
        } else if a != b && self.tiles_per_gpu > 1 && self.tile_of(a) != self.tile_of(b) {
            Locality::SameGpu
        } else if a == b {
            Locality::SameTile
        } else {
            // Distinct PEs mapped to the same tile cannot happen with the
            // 1:1 PE-per-tile mapping; classify conservatively.
            Locality::SameTile
        }
    }

    /// PEs co-resident on `pe`'s node (the ISHMEM_TEAM_SHARED domain).
    pub fn node_peers(&self, pe: PeId) -> std::ops::Range<PeId> {
        let node = self.node_of(pe);
        node * self.pes_per_node()..(node + 1) * self.pes_per_node()
    }

    /// Number of Xe-Links out of each GPU (fully connected topology).
    pub fn xelinks_per_gpu(&self) -> usize {
        self.gpus_per_node.saturating_sub(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_aurora_node() {
        let t = Topology::default();
        assert_eq!(t.npes(), 12);
        assert_eq!(t.pes_per_node(), 12);
        assert_eq!(t.xelinks_per_gpu(), 5);
    }

    #[test]
    fn classify_matches_fig3_setups() {
        // Fig 3: 1 PE = same tile, 2 PEs = other tile of same GPU,
        // 3 PEs = different GPU.
        let t = Topology::default();
        assert_eq!(t.classify(0, 0), Locality::SameTile);
        assert_eq!(t.classify(0, 1), Locality::SameGpu);
        assert_eq!(t.classify(0, 2), Locality::SameNode);
    }

    #[test]
    fn classify_remote_across_nodes() {
        let t = Topology::new(2, 6, 2);
        assert_eq!(t.npes(), 24);
        assert_eq!(t.classify(0, 12), Locality::Remote);
        assert_eq!(t.classify(13, 12), Locality::SameGpu);
    }

    #[test]
    fn pe_coordinates_roundtrip() {
        let t = Topology::new(2, 4, 2);
        for pe in 0..t.npes() {
            let reconstructed = t.node_of(pe) * t.pes_per_node()
                + t.gpu_of(pe) * t.tiles_per_gpu
                + t.tile_of(pe);
            assert_eq!(reconstructed, pe);
        }
    }

    #[test]
    fn node_peers_range() {
        let t = Topology::new(2, 6, 2);
        assert_eq!(t.node_peers(3), 0..12);
        assert_eq!(t.node_peers(17), 12..24);
    }

    #[test]
    #[should_panic]
    fn rejects_9way() {
        Topology::new(1, 9, 2);
    }

    #[test]
    fn multi_node_for_builds_exact_sizes() {
        for npes in [1usize, 2, 6, 12, 16, 24, 48, 64, 96, 128, 256, 512, 1024] {
            let t = Topology::multi_node_for(npes);
            assert_eq!(t.npes(), npes, "npes {npes} → {t:?}");
            assert!(t.gpus_per_node <= 8, "{t:?}");
        }
        // Dense shapes pick the 16-PE Aurora-like node.
        let t = Topology::multi_node_for(1024);
        assert_eq!((t.nodes, t.gpus_per_node, t.tiles_per_gpu), (64, 8, 2));
        // Small even sizes stay single-node (pre-PR behavior).
        let t = Topology::multi_node_for(12);
        assert_eq!(t.nodes, 1);
    }
}
