//! Simulated device memory: real bytes behind the modeled hardware.
//!
//! Each PE owns one `SymHeap` — the stand-in for its GPU tile's HBM — and
//! the `HeapRegistry` is the stand-in for the node-wide unified address
//! space that Xe-Link + Level-Zero IPC mappings provide (paper §III-G.1:
//! "Intel SHMEM sets up memory mapping from every GPU to the symmetric
//! heaps of every other GPU on the local node").
//!
//! Remote stores are real `memcpy`s between heap regions and remote AMOs
//! are real hardware atomics, so every correctness property is exercised on
//! actual shared memory while the cost model charges virtual time.
//!
//! # Memory model
//! OpenSHMEM makes unsynchronized conflicting access a *user* error; the
//! library itself only needs (a) plain byte copies for RMA and (b)
//! sequentially-consistent atomics for AMO/signal/sync words. We mirror
//! that: RMA uses raw `copy_nonoverlapping` (treating the heap as untyped
//! bytes), AMOs go through `AtomicU32`/`AtomicU64` references constructed
//! over properly aligned heap words.

use std::sync::atomic::{AtomicU32, AtomicU64};

/// One PE's symmetric heap (device-resident, paper §III-E).
#[derive(Debug)]
pub struct SymHeap {
    ptr: *mut u8,
    len: usize,
    layout: std::alloc::Layout,
}

// SAFETY: all cross-thread access goes through raw copies/atomics with
// OpenSHMEM's "races are user bugs" contract; the allocation itself is
// plain heap memory that outlives every PE thread (owned by the registry).
unsafe impl Send for SymHeap {}
unsafe impl Sync for SymHeap {}

impl SymHeap {
    /// Allocate a zeroed heap of `len` bytes, 128-byte aligned (vector-lane
    /// alignment; also guarantees atomic word alignment everywhere).
    pub fn new(len: usize) -> Self {
        assert!(len > 0);
        let layout = std::alloc::Layout::from_size_align(len, 128).unwrap();
        // SAFETY: layout has non-zero size.
        let ptr = unsafe { std::alloc::alloc_zeroed(layout) };
        assert!(!ptr.is_null(), "heap allocation failed");
        SymHeap { ptr, len, layout }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn base_ptr(&self) -> *mut u8 {
        self.ptr
    }

    #[inline]
    fn check(&self, offset: usize, len: usize) {
        assert!(
            offset.checked_add(len).is_some_and(|end| end <= self.len),
            "symmetric heap access out of bounds: off={offset} len={len} heap={}",
            self.len
        );
    }

    /// Copy bytes in from a local buffer (a "store" into this heap).
    #[inline]
    pub fn write(&self, offset: usize, src: &[u8]) {
        self.check(offset, src.len());
        // SAFETY: bounds checked; src is a distinct allocation.
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), self.ptr.add(offset), src.len());
        }
    }

    /// Copy bytes out into a local buffer (a "load" from this heap).
    #[inline]
    pub fn read(&self, offset: usize, dst: &mut [u8]) {
        self.check(offset, dst.len());
        // SAFETY: bounds checked; dst is a distinct allocation.
        unsafe {
            std::ptr::copy_nonoverlapping(self.ptr.add(offset), dst.as_mut_ptr(), dst.len());
        }
    }

    /// Raw pointer to `offset` (for heap-to-heap copies).
    #[inline]
    pub fn at(&self, offset: usize, len: usize) -> *mut u8 {
        self.check(offset, len);
        // SAFETY: bounds checked.
        unsafe { self.ptr.add(offset) }
    }

    /// Atomic view of an aligned u64 heap word.
    #[inline]
    pub fn atomic_u64(&self, offset: usize) -> &AtomicU64 {
        self.check(offset, 8);
        assert_eq!(offset % 8, 0, "unaligned atomic access at {offset}");
        // SAFETY: aligned, in-bounds, and AtomicU64 has the same layout as u64.
        unsafe { &*(self.ptr.add(offset) as *const AtomicU64) }
    }

    /// Atomic view of an aligned u32 heap word.
    #[inline]
    pub fn atomic_u32(&self, offset: usize) -> &AtomicU32 {
        self.check(offset, 4);
        assert_eq!(offset % 4, 0, "unaligned atomic access at {offset}");
        // SAFETY: as above.
        unsafe { &*(self.ptr.add(offset) as *const AtomicU32) }
    }
}

impl Drop for SymHeap {
    fn drop(&mut self) {
        // SAFETY: allocated with the stored layout in `new`.
        unsafe { std::alloc::dealloc(self.ptr, self.layout) };
    }
}

/// All PEs' heaps — the node-wide "unified address space".
///
/// The *symmetry invariant*: every heap has identical size and every
/// symmetric allocation resolves to the same offset on every PE. The
/// allocator enforcing that invariant lives in `ishmem::heap`; this type
/// only provides the mapped windows.
#[derive(Debug)]
pub struct HeapRegistry {
    heaps: Vec<SymHeap>,
}

impl HeapRegistry {
    pub fn new(npes: usize, heap_bytes: usize) -> Self {
        HeapRegistry {
            heaps: (0..npes).map(|_| SymHeap::new(heap_bytes)).collect(),
        }
    }

    pub fn npes(&self) -> usize {
        self.heaps.len()
    }

    pub fn heap(&self, pe: usize) -> &SymHeap {
        &self.heaps[pe]
    }

    pub fn heap_bytes(&self) -> usize {
        self.heaps.first().map_or(0, |h| h.len())
    }

    /// Heap-to-heap copy — the data plane of every put/get/collective.
    pub fn copy(
        &self,
        src_pe: usize,
        src_off: usize,
        dst_pe: usize,
        dst_off: usize,
        len: usize,
    ) {
        if len == 0 {
            return;
        }
        let src = self.heaps[src_pe].at(src_off, len);
        let dst = self.heaps[dst_pe].at(dst_off, len);
        if src_pe == dst_pe {
            // Same heap: ranges may overlap (self-put of adjacent buffers).
            // SAFETY: bounds checked by `at`.
            unsafe { std::ptr::copy(src, dst, len) };
        } else {
            // SAFETY: distinct allocations cannot overlap.
            unsafe { std::ptr::copy_nonoverlapping(src, dst, len) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn zeroed_on_allocation() {
        let h = SymHeap::new(4096);
        let mut buf = vec![0xAAu8; 4096];
        h.read(0, &mut buf);
        assert!(buf.iter().all(|&b| b == 0));
    }

    #[test]
    fn write_read_roundtrip() {
        let h = SymHeap::new(1024);
        let data: Vec<u8> = (0..=255).collect();
        h.write(100, &data);
        let mut out = vec![0u8; 256];
        h.read(100, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_write_panics() {
        let h = SymHeap::new(64);
        h.write(60, &[0u8; 8]);
    }

    #[test]
    #[should_panic(expected = "unaligned")]
    fn unaligned_atomic_panics() {
        let h = SymHeap::new(64);
        h.atomic_u64(3);
    }

    #[test]
    fn atomics_are_live_views() {
        let h = SymHeap::new(64);
        h.atomic_u64(8).store(0xDEADBEEF, Ordering::SeqCst);
        let mut out = [0u8; 8];
        h.read(8, &mut out);
        assert_eq!(u64::from_le_bytes(out), 0xDEADBEEF);
    }

    #[test]
    fn registry_cross_pe_copy() {
        let reg = HeapRegistry::new(4, 4096);
        let payload = vec![7u8; 512];
        reg.heap(1).write(0, &payload);
        reg.copy(1, 0, 3, 1024, 512);
        let mut out = vec![0u8; 512];
        reg.heap(3).read(1024, &mut out);
        assert_eq!(out, payload);
    }

    #[test]
    fn registry_self_overlapping_copy() {
        let reg = HeapRegistry::new(1, 1024);
        reg.heap(0).write(0, &[1, 2, 3, 4, 5, 6, 7, 8]);
        reg.copy(0, 0, 0, 4, 8); // overlapping forward copy
        let mut out = vec![0u8; 12];
        reg.heap(0).read(0, &mut out);
        assert_eq!(out, [1, 2, 3, 4, 1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn concurrent_atomic_increments() {
        let reg = std::sync::Arc::new(HeapRegistry::new(1, 64));
        let mut handles = vec![];
        for _ in 0..4 {
            let r = reg.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    r.heap(0).atomic_u64(0).fetch_add(1, Ordering::AcqRel);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.heap(0).atomic_u64(0).load(Ordering::SeqCst), 4000);
    }
}
