//! Unified cost model: one façade over the per-component hardware models.
//!
//! Everything that charges modeled time goes through here, so calibration
//! lives in exactly one place (DESIGN.md §6) and ablations can swap params
//! wholesale.

use std::sync::Arc;

use super::copyengine::{CopyEngineParams, EngineQueue};
use super::nic::NicParams;
use super::pcie::PcieParams;
use super::topology::{Locality, Topology};
use super::xelink::XeLinkParams;

#[derive(Clone, Debug, Default)]
pub struct CostParams {
    pub xe: XeLinkParams,
    pub ce: CopyEngineParams,
    pub pcie: PcieParams,
    pub nic: NicParams,
    pub overhead: OverheadParams,
}

#[derive(Clone, Debug)]
pub struct OverheadParams {
    /// Device-side issue overhead of any ishmem op: load the GPU-resident
    /// info block, the local-PE table lookup, pointer arithmetic
    /// (paper §III-G.1's five-step `ishmem_long_p` recipe).
    pub device_issue_ns: f64,
    /// Host-side issue overhead of a host-initiated op.
    pub host_issue_ns: f64,
    /// SYCL work-group barrier, ns (used by work_group inter-node ops to
    /// validate input buffers before the leader posts the proxy call).
    pub group_barrier_ns: f64,
    /// Kernel-launch overhead for host-initiated device work, ns.
    pub kernel_launch_ns: f64,
}

impl Default for OverheadParams {
    fn default() -> Self {
        OverheadParams {
            device_issue_ns: 250.0,
            host_issue_ns: 120.0,
            group_barrier_ns: 400.0,
            kernel_launch_ns: 8_000.0,
        }
    }
}

/// Shared, thread-safe cost model (one per launched machine).
#[derive(Debug)]
pub struct CostModel {
    pub params: CostParams,
    pub topo: Topology,
    /// Per-GPU copy-engine occupancy (global GPU index).
    engine_queues: Vec<EngineQueue>,
}

impl CostModel {
    pub fn new(topo: Topology, params: CostParams) -> Arc<Self> {
        let gpus = topo.nodes * topo.gpus_per_node;
        Arc::new(CostModel {
            engine_queues: (0..gpus)
                .map(|_| EngineQueue::new(params.ce.engines_per_gpu))
                .collect(),
            params,
            topo,
        })
    }

    pub fn locality(&self, from: usize, to: usize) -> Locality {
        self.topo.classify(from, to)
    }

    // ----------------------------------------------------------- paths ----

    /// Device-initiated load/store transfer by `items` work-items.
    pub fn loadstore_ns(&self, loc: Locality, bytes: usize, items: usize) -> f64 {
        self.params.overhead.device_issue_ns
            + self.params.xe.loadstore_ns(loc, bytes, items)
    }

    /// Copy-engine transfer. `host_initiated` adds the PCIe doorbell;
    /// `via_ring` adds the reverse-offload round trip (device-initiated
    /// large ops go: GPU → ring → proxy → engine, paper Fig 2 circle 3).
    pub fn copy_engine_ns(
        &self,
        src_gpu: usize,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        host_initiated: bool,
        via_ring: bool,
    ) -> f64 {
        let q = &self.engine_queues[src_gpu];
        let factor = q.begin();
        let base = self
            .params
            .ce
            .transfer_ns(&self.params.xe, loc, bytes, immediate_cl, host_initiated);
        q.end();
        let ring = if via_ring {
            self.params.pcie.ring_round_trip_ns()
        } else {
            0.0
        };
        ring + base * factor
    }

    /// Planning *estimate* of the device-initiated engine path: ring
    /// round trip + one engine transfer at full link speed, no queueing.
    /// The single copy of the cutover decision's engine-side formula —
    /// shared by the xfer planner (configured CL flavour) and the
    /// policy-level reference in `ishmem::cutover` (immediate CL).
    pub fn p2p_engine_estimate_ns(&self, loc: Locality, bytes: usize, immediate_cl: bool) -> f64 {
        self.ring_rtt_ns()
            + self
                .params
                .ce
                .transfer_ns(&self.params.xe, loc, bytes, immediate_cl, false)
    }

    /// Occupancy-aware engine estimate: the pure estimate plus the time to
    /// drain `backlog_bytes` already queued on the source GPU's engines at
    /// the path bandwidth. This is what makes cutover decisions shift
    /// under load — a loaded engine queue makes the store path win at
    /// sizes where an idle queue would pick the engines.
    pub fn p2p_engine_estimate_loaded_ns(
        &self,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        backlog_bytes: u64,
    ) -> f64 {
        let bw = self.params.ce.path_bw_gbs(&self.params.xe, loc);
        let drain = if bw > 0.0 { backlog_bytes as f64 / bw } else { 0.0 };
        self.p2p_engine_estimate_ns(loc, bytes, immediate_cl) + drain
    }

    // --------------------------------------------- engine-queue backlog ----

    /// Register accepted-but-incomplete engine work on `gpu`.
    pub fn engine_reserve(&self, gpu: usize, bytes: u64) {
        self.engine_queues[gpu].reserve_bytes(bytes);
    }

    /// Retire engine work previously registered with [`Self::engine_reserve`].
    pub fn engine_release(&self, gpu: usize, bytes: u64) {
        self.engine_queues[gpu].release_bytes(bytes);
    }

    /// Current copy-engine byte backlog on `gpu`.
    pub fn engine_backlog_bytes(&self, gpu: usize) -> u64 {
        self.engine_queues[gpu].queued_bytes()
    }

    /// Device-side cost of staging `bytes` through the symmetric-heap
    /// staging slab (an HBM-local copy by the issuing work-items; latency
    /// hides in pipelining, so pure bandwidth).
    pub fn staging_copy_ns(&self, bytes: usize) -> f64 {
        bytes as f64 / self.params.xe.hbm_bw_gbs
    }

    /// Inter-node transfer: ring hand-off + host proxy + NIC RDMA.
    pub fn internode_ns(&self, bytes: usize, registered_heap: bool, via_ring: bool) -> f64 {
        let ring = if via_ring {
            self.params.pcie.ring_round_trip_ns()
        } else {
            0.0
        };
        let wire = if registered_heap {
            self.params.nic.rdma_ns(bytes)
        } else {
            self.params.nic.bounce_ns(bytes)
        };
        ring + self.params.overhead.host_issue_ns + wire
    }

    /// Pipelined remote atomics (push sync/broadcast primitives).
    pub fn pipelined_atomics_ns(&self, n: usize) -> f64 {
        self.params.xe.pipelined_atomics_ns(n)
    }

    /// One fetching atomic (AMO with a result).
    pub fn fetch_atomic_ns(&self, loc: Locality) -> f64 {
        match loc {
            Locality::SameTile => self.params.xe.atomic_fetch_ns * 0.2,
            Locality::SameGpu => self.params.xe.atomic_fetch_ns * 0.6,
            Locality::SameNode => self.params.xe.atomic_fetch_ns,
            Locality::Remote => {
                self.params.pcie.ring_round_trip_ns() + self.params.nic.latency_ns * 2.0
            }
        }
    }

    pub fn device_issue_ns(&self) -> f64 {
        self.params.overhead.device_issue_ns
    }

    pub fn group_barrier_ns(&self) -> f64 {
        self.params.overhead.group_barrier_ns
    }

    pub fn ring_post_ns(&self) -> f64 {
        self.params.pcie.ring_post_ns()
    }

    pub fn ring_rtt_ns(&self) -> f64 {
        self.params.pcie.ring_round_trip_ns()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> Arc<CostModel> {
        CostModel::new(Topology::default(), CostParams::default())
    }

    #[test]
    fn fig3_crossover_shape() {
        // Paper Fig 3: load/store wins up to ~4KB, engine path wins for
        // large messages; both converge at the link roofline.
        let m = model();
        let loc = Locality::SameNode;
        let small = m.loadstore_ns(loc, 2048, 1);
        let small_ce = m.copy_engine_ns(0, loc, 2048, true, false, true);
        assert!(small < small_ce, "{small} !< {small_ce}");

        let big = m.loadstore_ns(loc, 8 << 20, 1);
        let big_ce = m.copy_engine_ns(0, loc, 8 << 20, true, false, true);
        assert!(big_ce < big, "{big_ce} !< {big}");
    }

    #[test]
    fn loaded_estimate_grows_with_backlog() {
        let m = model();
        let loc = Locality::SameNode;
        let idle = m.p2p_engine_estimate_loaded_ns(loc, 4096, true, 0);
        assert_eq!(idle, m.p2p_engine_estimate_ns(loc, 4096, true));
        let loaded = m.p2p_engine_estimate_loaded_ns(loc, 4096, true, 64 << 20);
        assert!(loaded > idle * 2.0, "{loaded} !> {idle}*2");
        // Live backlog flows through reserve/release.
        m.engine_reserve(0, 4096);
        assert_eq!(m.engine_backlog_bytes(0), 4096);
        m.engine_release(0, 4096);
        assert_eq!(m.engine_backlog_bytes(0), 0);
    }

    #[test]
    fn internode_registration_matters() {
        let m = model();
        assert!(m.internode_ns(1 << 20, true, true) < m.internode_ns(1 << 20, false, true));
    }

    #[test]
    fn fetch_atomic_cost_grows_with_distance() {
        let m = model();
        assert!(
            m.fetch_atomic_ns(Locality::SameTile) < m.fetch_atomic_ns(Locality::SameGpu)
        );
        assert!(
            m.fetch_atomic_ns(Locality::SameGpu) < m.fetch_atomic_ns(Locality::SameNode)
        );
        assert!(
            m.fetch_atomic_ns(Locality::SameNode) < m.fetch_atomic_ns(Locality::Remote)
        );
    }
}
