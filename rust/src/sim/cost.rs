//! Unified cost model: one façade over the per-component hardware models.
//!
//! Everything that charges modeled time goes through here, so calibration
//! lives in exactly one place (DESIGN.md §6) and ablations can swap params
//! wholesale.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::copyengine::{CopyEngineParams, EngineQueue};
use super::nic::NicParams;
use super::params::{LearnedParams, ModelParams};
use super::pcie::PcieParams;
use super::rail::RailSet;
use super::topology::{Locality, Topology};
use super::xelink::XeLinkParams;

#[derive(Clone, Debug, Default)]
pub struct CostParams {
    pub xe: XeLinkParams,
    pub ce: CopyEngineParams,
    pub pcie: PcieParams,
    pub nic: NicParams,
    pub stripe: StripeParams,
    pub overhead: OverheadParams,
}

/// Shared knobs of the chunked stripe pipelines (engine *and* rail): the
/// ramped-first-chunk geometry. The pipeline's serial prefix is the
/// staging of its first chunk; shrinking the first 1–2 fills starts the
/// first engine/rail earlier at the price of one or two extra chunk
/// startups later — a latency-for-startups trade the executors charge via
/// `max(exec, staging) + first-fill` with the reduced fill term.
#[derive(Clone, Debug)]
pub struct StripeParams {
    /// Fill-size factor of the leading ramped chunks, in (0, 1]. 1.0
    /// disables ramping (every chunk uses the planned `chunk_bytes`).
    pub ramp_factor: f64,
    /// How many leading chunks use the ramped fill (1–2 typical).
    pub ramp_chunks: usize,
}

impl Default for StripeParams {
    fn default() -> Self {
        StripeParams { ramp_factor: 1.0, ramp_chunks: 2 }
    }
}

impl StripeParams {
    /// Whether ramped first chunks are enabled.
    pub fn ramp_enabled(&self) -> bool {
        self.ramp_factor < 1.0
    }

    /// Fill size of the leading ramped chunks for a planned `chunk_bytes`
    /// (= `chunk_bytes` when ramping is off).
    pub fn first_fill_bytes(&self, chunk_bytes: usize) -> usize {
        if self.ramp_enabled() {
            ((chunk_bytes as f64 * self.ramp_factor) as usize).max(1)
        } else {
            chunk_bytes
        }
    }
}

#[derive(Clone, Debug)]
pub struct OverheadParams {
    /// Device-side issue overhead of any ishmem op: load the GPU-resident
    /// info block, the local-PE table lookup, pointer arithmetic
    /// (paper §III-G.1's five-step `ishmem_long_p` recipe).
    pub device_issue_ns: f64,
    /// Host-side issue overhead of a host-initiated op.
    pub host_issue_ns: f64,
    /// SYCL work-group barrier, ns (used by work_group inter-node ops to
    /// validate input buffers before the leader posts the proxy call).
    pub group_barrier_ns: f64,
    /// Kernel-launch overhead for host-initiated device work, ns.
    pub kernel_launch_ns: f64,
}

impl Default for OverheadParams {
    fn default() -> Self {
        OverheadParams {
            device_issue_ns: 250.0,
            host_issue_ns: 120.0,
            group_barrier_ns: 400.0,
            kernel_launch_ns: 8_000.0,
        }
    }
}

/// Modeled price of one transient strike when the stripe scan scores a
/// candidate shape (retry-aware planning, ISSUE 10 satellite): each chunk
/// of a candidate is one more exposure to a flaky lane, so a shape with
/// `n` chunks on a domain whose worst lane has `s` unexpired strikes pays
/// `s × n × STRIKE_PENALTY_NS` on top of its modeled transfer. Small by
/// design — roughly one ring post per strike-chunk — so it biases the
/// argmin toward fewer chunks *before* the lane escalates into
/// quarantine, without overriding genuine bandwidth differences.
pub const STRIKE_PENALTY_NS: f64 = 400.0;

/// The stripe scans' strike penalty term: exactly 0.0 at zero strikes
/// (a strike-free machine scores — and therefore plans — bit-for-bit
/// identically to the pre-penalty code).
pub fn strike_penalty_ns(strikes: u64, chunks: usize) -> f64 {
    if strikes == 0 {
        0.0
    } else {
        strikes as f64 * chunks as f64 * STRIKE_PENALTY_NS
    }
}

/// Route-generic stripe scan: pick the (chunk size, lane width) whose
/// modeled transfer is cheapest under `score(width, chunk, chunks)`, where
/// the lane table behind `score` is either the copy-engine model
/// ([`CostModel::stripe_for`]) or the NIC rail model
/// ([`CostModel::rail_stripe_for`]). `chunk_cap` is the caller's slab
/// ceiling; a cap below `chunk_min` disables the chunk pipeline entirely,
/// and transfers strictly below `2 · chunk_min` that fit the cap ship as
/// one un-striped unit (a second startup cannot amortize — and engaging at
/// exactly two minimum chunks keeps per-pow2-step estimates monotone).
fn stripe_scan(
    bytes: usize,
    chunk_cap: usize,
    chunk_min: usize,
    w_max: usize,
    score: impl Fn(usize, usize, usize) -> f64,
) -> (usize, usize) {
    let chunk_min = chunk_min.max(1);
    if bytes == 0 || chunk_cap < chunk_min {
        return (bytes.max(1), 1);
    }
    if bytes < 2 * chunk_min && bytes <= chunk_cap {
        return (bytes, 1);
    }
    let w_max = w_max.max(1);
    let mut best = (bytes.min(chunk_cap), 1usize);
    let mut best_ns = f64::INFINITY;
    for w in 1..=w_max {
        let chunk = bytes.div_ceil(w).clamp(chunk_min, chunk_cap);
        let n = bytes.div_ceil(chunk);
        let eff_w = w.min(n);
        let ns = score(eff_w, chunk, n);
        if ns < best_ns {
            best_ns = ns;
            best = (chunk, eff_w);
        }
    }
    best
}

/// Collective algorithm a team-spanning op can run (ISSUE 7): the flat
/// per-peer fan-out, or the hierarchical tile/GPU/node decomposition with
/// a ring or tree inter-node stage among node leaders.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CollAlgo {
    Flat,
    HierRing,
    HierTree,
}

/// Which collective an estimate prices (they move different byte volumes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CollOp {
    Broadcast,
    Fcollect,
    Reduce,
}

/// Topology digest of one team as the collective estimators see it:
/// member and distinct-GPU counts per participating node. Built once per
/// op from the team spec ([`Self::from_members`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CollShape {
    /// Team size.
    pub npes: usize,
    /// Members resident on each participating node.
    pub node_members: Vec<usize>,
    /// Distinct GPUs holding members, per participating node.
    pub node_gpus: Vec<usize>,
}

impl CollShape {
    /// Digest an ascending member list against the machine topology.
    pub fn from_members(topo: &Topology, members: impl Iterator<Item = usize>) -> Self {
        let mut npes = 0usize;
        let mut nodes: Vec<(usize, usize, std::collections::BTreeSet<usize>)> = Vec::new();
        for pe in members {
            npes += 1;
            let node = topo.node_of(pe);
            let gpu = topo.global_gpu_of(pe);
            match nodes.iter_mut().find(|(n, _, _)| *n == node) {
                Some((_, count, gpus)) => {
                    *count += 1;
                    gpus.insert(gpu);
                }
                None => {
                    let mut gpus = std::collections::BTreeSet::new();
                    gpus.insert(gpu);
                    nodes.push((node, 1, gpus));
                }
            }
        }
        CollShape {
            npes,
            node_members: nodes.iter().map(|(_, c, _)| *c).collect(),
            node_gpus: nodes.iter().map(|(_, _, g)| g.len()).collect(),
        }
    }

    /// Participating node count.
    pub fn nnodes(&self) -> usize {
        self.node_members.len()
    }

    /// A single-node team has no inter-node stage — it always takes the
    /// flat path (bit-for-bit the pre-hierarchy behavior).
    pub fn single_node(&self) -> bool {
        self.nnodes() <= 1
    }

    /// (members, gpus) of the most populated node — the stage bottleneck.
    pub fn max_node(&self) -> (usize, usize) {
        self.node_members
            .iter()
            .zip(&self.node_gpus)
            .map(|(&m, &g)| (m, g))
            .max()
            .unwrap_or((1, 1))
    }
}

/// All three algorithm estimates for one collective, priced from one
/// snapshot ([`CostModel::coll_estimates_at`]).
#[derive(Clone, Copy, Debug)]
pub struct CollEstimates {
    pub flat_ns: f64,
    pub ring_ns: f64,
    pub tree_ns: f64,
}

impl CollEstimates {
    /// The cheaper hierarchical variant.
    pub fn best_hier(&self) -> (CollAlgo, f64) {
        if self.tree_ns < self.ring_ns {
            (CollAlgo::HierTree, self.tree_ns)
        } else {
            (CollAlgo::HierRing, self.ring_ns)
        }
    }

    /// Model argmin over all three (ties favor flat — the simpler path).
    pub fn best(&self) -> (CollAlgo, f64) {
        let (hier, hier_ns) = self.best_hier();
        if hier_ns < self.flat_ns {
            (hier, hier_ns)
        } else {
            (CollAlgo::Flat, self.flat_ns)
        }
    }
}

/// Levels of a `k`-ary tree spanning `nodes` leaves.
pub fn tree_depth(nodes: usize, k: usize) -> usize {
    let k = k.max(2);
    let mut depth = 0usize;
    let mut span = 1usize;
    while span < nodes {
        span = span.saturating_mul(k);
        depth += 1;
    }
    depth
}

/// Shared, thread-safe cost model (one per launched machine).
#[derive(Debug)]
pub struct CostModel {
    /// Configured hardware constants (the calibration *seed*). Structural
    /// knobs (engine/rail counts, chunk minimums, rooflines) are read from
    /// here directly; the learnable constants are read through
    /// [`Self::ce_eff`]/[`Self::nic_eff`] so calibration updates reach
    /// every estimate.
    pub params: CostParams,
    /// Mutable, versioned store of the learnable constants
    /// (`single_engine_frac`, `rail_bw_frac`, startup terms, the CL
    /// boundary), seeded bit-for-bit from `params` — the write side of
    /// the closed calibration loop (`xfer::calibrate`).
    pub model: ModelParams,
    pub topo: Topology,
    /// Per-GPU copy-engine occupancy (global GPU index).
    engine_queues: Vec<EngineQueue>,
    /// Per-node NIC-rail occupancy (node index).
    rail_sets: Vec<RailSet>,
    /// Bumped on every lane kill/revive transition — the health twin of
    /// the `ModelParams` version: plan caches stamp it and flush when it
    /// moves, so no cached shape outlives a lane's liveness.
    health_gen: AtomicU64,
    /// Count of currently-dead lanes across every rail set and engine
    /// queue. Zero (the only state a fault-free run ever sees) lets the
    /// per-plan health reads skip the per-lane scans entirely.
    dead_lanes: AtomicU64,
    /// Per-rail unexpired strike counts, `node × rails + rail` (transient
    /// faults the reliability layer attributed to the lane; cleared on a
    /// clean dispatch). Feeds the stripe scans' strike penalty so a flaky
    /// lane prices worse *before* it escalates to quarantine.
    rail_strikes: Vec<AtomicU64>,
    /// Per-engine unexpired strike counts, `gpu × engines_per_gpu + engine`.
    engine_strikes: Vec<AtomicU64>,
    /// Bumped on every strike note/clear transition — folded with
    /// `health_gen` into [`Self::planning_generation`] so plan caches age
    /// out shapes priced under a stale strike picture.
    strike_gen: AtomicU64,
    /// Live strikes across all lanes (fast zero check: a strike-free run
    /// never scans the per-lane vectors and its scores gain exactly 0.0).
    strike_total: AtomicU64,
}

impl CostModel {
    pub fn new(topo: Topology, params: CostParams) -> Arc<Self> {
        let gpus = topo.nodes * topo.gpus_per_node;
        Arc::new(CostModel {
            engine_queues: (0..gpus)
                .map(|_| EngineQueue::new(params.ce.engines_per_gpu))
                .collect(),
            rail_sets: (0..topo.nodes).map(|_| RailSet::new(params.nic.rails)).collect(),
            health_gen: AtomicU64::new(0),
            dead_lanes: AtomicU64::new(0),
            rail_strikes: (0..topo.nodes * params.nic.rails.max(1))
                .map(|_| AtomicU64::new(0))
                .collect(),
            engine_strikes: (0..gpus * params.ce.engines_per_gpu.max(1))
                .map(|_| AtomicU64::new(0))
                .collect(),
            strike_gen: AtomicU64::new(0),
            strike_total: AtomicU64::new(0),
            model: ModelParams::new(&params),
            params,
            topo,
        })
    }

    pub fn locality(&self, from: usize, to: usize) -> Locality {
        self.topo.classify(from, to)
    }

    /// The *effective* copy-engine params: configured structure with the
    /// live learned constants overlaid. Recompute-on-update is automatic —
    /// every estimate fetches this per call, so a calibration write is
    /// visible to the very next plan.
    pub fn ce_eff(&self) -> CopyEngineParams {
        self.ce_eff_at(&self.model.get())
    }

    /// [`Self::ce_eff`] against one caller-held learned-params snapshot —
    /// the building block of tear-free multi-term estimates: grab the
    /// snapshot once, thread it through every term.
    pub fn ce_eff_at(&self, l: &LearnedParams) -> CopyEngineParams {
        self.params.ce.with_learned(l)
    }

    /// The *effective* NIC params (see [`Self::ce_eff`]).
    pub fn nic_eff(&self) -> NicParams {
        self.nic_eff_at(&self.model.get())
    }

    /// [`Self::nic_eff`] against one caller-held snapshot (see
    /// [`Self::ce_eff_at`]).
    pub fn nic_eff_at(&self, l: &LearnedParams) -> NicParams {
        self.params.nic.with_learned(l)
    }

    // ----------------------------------------------------------- paths ----

    /// Device-initiated load/store transfer by `items` work-items.
    pub fn loadstore_ns(&self, loc: Locality, bytes: usize, items: usize) -> f64 {
        self.params.overhead.device_issue_ns
            + self.params.xe.loadstore_ns(loc, bytes, items)
    }

    /// Copy-engine transfer. `host_initiated` adds the PCIe doorbell;
    /// `via_ring` adds the reverse-offload round trip (device-initiated
    /// large ops go: GPU → ring → proxy → engine, paper Fig 2 circle 3).
    pub fn copy_engine_ns(
        &self,
        src_gpu: usize,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        host_initiated: bool,
        via_ring: bool,
    ) -> f64 {
        let q = &self.engine_queues[src_gpu];
        let factor = q.begin();
        let base = self
            .ce_eff()
            .transfer_ns(&self.params.xe, loc, bytes, immediate_cl, host_initiated);
        q.end();
        let ring = if via_ring {
            self.params.pcie.ring_round_trip_ns()
        } else {
            0.0
        };
        ring + base * factor
    }

    /// Queue-aware charge for a device-initiated transfer of `bytes` in
    /// `chunks` chunks striped over `width` engines (ring round trip +
    /// striped engine pipeline, scaled by the live occupancy factor).
    pub fn copy_engine_striped_ns(
        &self,
        src_gpu: usize,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        width: usize,
        chunks: usize,
    ) -> f64 {
        let q = &self.engine_queues[src_gpu];
        let factor = q.begin();
        let base = self.ce_eff().striped_transfer_ns(
            &self.params.xe,
            loc,
            bytes,
            immediate_cl,
            false,
            width,
            chunks,
        );
        q.end();
        self.ring_rtt_ns() + base * factor
    }

    // ------------------------------------------------- stripe planning ----

    /// Pick a (chunk size, stripe width) for an engine-path transfer of
    /// `bytes`: scan widths up to `stripe_max_engines`, charging each
    /// candidate's startup amortization against its striped bandwidth, and
    /// keep the modeled argmin. `chunk_cap` is the caller's slab ceiling
    /// (the largest chunk the staging pipeline can double-buffer);
    /// `usize::MAX` for policy-level references with no slab in the path.
    /// `cl_immediate_max` is the per-op CL boundary: candidates whose
    /// chunks fit it are scored with the immediate startup, larger ones
    /// with the standard startup — the same flavor the estimate and the
    /// executors will actually use (`usize::MAX` = all immediate, 0 = all
    /// standard). A cap below `chunk_min_bytes` disables the chunk
    /// pipeline entirely: the transfer stays a single un-striped unit.
    pub fn stripe_for(
        &self,
        loc: Locality,
        bytes: usize,
        chunk_cap: usize,
        cl_immediate_max: usize,
    ) -> (usize, usize) {
        self.stripe_for_at(&self.model.get(), loc, bytes, chunk_cap, cl_immediate_max)
    }

    /// [`Self::stripe_for`] against one caller-held snapshot.
    pub fn stripe_for_at(
        &self,
        l: &LearnedParams,
        loc: Locality,
        bytes: usize,
        chunk_cap: usize,
        cl_immediate_max: usize,
    ) -> (usize, usize) {
        let ce = self.ce_eff_at(l);
        let w_max = ce
            .stripe_max_engines
            .clamp(1, ce.engines_per_gpu.max(1))
            .min(self.min_live_engines());
        let strikes = self.max_engine_strikes();
        stripe_scan(bytes, chunk_cap, ce.chunk_min_bytes, w_max, |w, chunk, n| {
            let imm = chunk <= cl_immediate_max;
            ce.striped_transfer_ns(&self.params.xe, loc, bytes, imm, false, w, n)
                + strike_penalty_ns(strikes, n)
        })
    }

    /// Rail-table counterpart of [`Self::stripe_for`]: pick a (chunk size,
    /// rail width) for an inter-node transfer of `bytes`, scoring
    /// candidates against the NIC rail model (`nic.rails`,
    /// `nic.rail_bw_frac`, `nic.rail_startup_ns`). A 1-rail configuration
    /// never chunks — the transfer stays one RDMA, preserving the
    /// pre-striping single-rail estimates exactly.
    pub fn rail_stripe_for(&self, bytes: usize, chunk_cap: usize) -> (usize, usize) {
        self.rail_stripe_for_at(&self.model.get(), bytes, chunk_cap)
    }

    /// [`Self::rail_stripe_for`] against one caller-held snapshot.
    pub fn rail_stripe_for_at(
        &self,
        l: &LearnedParams,
        bytes: usize,
        chunk_cap: usize,
    ) -> (usize, usize) {
        let nic = self.nic_eff_at(l);
        let rails_eff = nic.rails.min(self.min_live_rails());
        if rails_eff <= 1 {
            return (bytes.max(1), 1);
        }
        let strikes = self.max_rail_strikes();
        stripe_scan(bytes, chunk_cap, nic.rail_chunk_min_bytes, rails_eff, |w, _chunk, n| {
            nic.rdma_striped_ns(bytes, w, n) + strike_penalty_ns(strikes, n)
        })
    }

    /// Planning *estimate* of the device-initiated engine path: ring round
    /// trip + the striped chunk pipeline (no queueing), with the stripe
    /// shape chosen under `chunk_cap`. The single copy of the cutover
    /// decision's engine-side formula — shared by the xfer planner
    /// (slab-capped chunks, configured CL flavour) and the policy-level
    /// reference in `ishmem::cutover` (uncapped, immediate CL).
    pub fn p2p_engine_estimate_capped_ns(
        &self,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        chunk_cap: usize,
    ) -> f64 {
        self.p2p_engine_estimate_capped_ns_at(&self.model.get(), loc, bytes, immediate_cl, chunk_cap)
    }

    /// [`Self::p2p_engine_estimate_capped_ns`] against one caller-held
    /// snapshot. Both terms (the stripe scan and the striped pipeline)
    /// price against the same generation — this estimate used to read the
    /// live params twice and could tear across a concurrent calibration
    /// apply.
    pub fn p2p_engine_estimate_capped_ns_at(
        &self,
        l: &LearnedParams,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        chunk_cap: usize,
    ) -> f64 {
        let cl_max = if immediate_cl { usize::MAX } else { 0 };
        let (chunk, width) = self.stripe_for_at(l, loc, bytes, chunk_cap, cl_max);
        let n = bytes.max(1).div_ceil(chunk.max(1));
        self.ring_rtt_ns()
            + self.ce_eff_at(l).striped_transfer_ns(
                &self.params.xe,
                loc,
                bytes,
                immediate_cl,
                false,
                width,
                n,
            )
    }

    /// Uncapped reference estimate (see [`Self::p2p_engine_estimate_capped_ns`]).
    pub fn p2p_engine_estimate_ns(&self, loc: Locality, bytes: usize, immediate_cl: bool) -> f64 {
        self.p2p_engine_estimate_capped_ns(loc, bytes, immediate_cl, usize::MAX)
    }

    /// Occupancy-aware engine estimate: the pure estimate plus the time to
    /// drain `backlog_bytes` already queued on the source GPU's engines at
    /// the aggregate engine rate. This is what makes cutover decisions
    /// shift under load — a loaded engine queue makes the store path win
    /// at sizes where an idle queue would pick the engines.
    pub fn p2p_engine_estimate_loaded_ns(
        &self,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        backlog_bytes: u64,
    ) -> f64 {
        self.p2p_engine_estimate_capped_loaded_ns(loc, bytes, immediate_cl, usize::MAX, backlog_bytes)
    }

    /// Slab-capped variant of the loaded estimate (the xfer planner's
    /// live formula).
    pub fn p2p_engine_estimate_capped_loaded_ns(
        &self,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        chunk_cap: usize,
        backlog_bytes: u64,
    ) -> f64 {
        self.p2p_engine_estimate_capped_loaded_ns_at(
            &self.model.get(),
            loc,
            bytes,
            immediate_cl,
            chunk_cap,
            backlog_bytes,
        )
    }

    /// [`Self::p2p_engine_estimate_capped_loaded_ns`] against one
    /// caller-held snapshot (the pure estimate *and* the drain term price
    /// against the same generation — this formula used to read the live
    /// params three times).
    pub fn p2p_engine_estimate_capped_loaded_ns_at(
        &self,
        l: &LearnedParams,
        loc: Locality,
        bytes: usize,
        immediate_cl: bool,
        chunk_cap: usize,
        backlog_bytes: u64,
    ) -> f64 {
        self.p2p_engine_estimate_capped_ns_at(l, loc, bytes, immediate_cl, chunk_cap)
            + self.engine_drain_ns_at(l, loc, backlog_bytes)
    }

    /// Time to drain `backlog_bytes` already queued on a GPU's engines at
    /// the aggregate engine rate (the occupancy term of the loaded
    /// estimates).
    pub fn engine_drain_ns(&self, loc: Locality, backlog_bytes: u64) -> f64 {
        self.engine_drain_ns_at(&self.model.get(), loc, backlog_bytes)
    }

    /// [`Self::engine_drain_ns`] against one caller-held snapshot.
    pub fn engine_drain_ns_at(&self, l: &LearnedParams, loc: Locality, backlog_bytes: u64) -> f64 {
        let ce = self.ce_eff_at(l);
        let width = ce.engines_per_gpu.min(self.min_live_engines());
        let bw = ce.striped_bw_gbs(&self.params.xe, loc, width);
        if bw > 0.0 {
            backlog_bytes as f64 / bw
        } else {
            0.0
        }
    }

    // --------------------------------------------- engine-queue backlog ----

    /// Register accepted-but-incomplete engine work on `gpu` (engine 0 —
    /// the legacy single-queue view; striped call sites use
    /// [`Self::engine_reserve_on`]).
    pub fn engine_reserve(&self, gpu: usize, bytes: u64) {
        self.engine_queues[gpu].reserve_bytes(bytes);
    }

    /// Retire engine work previously registered with [`Self::engine_reserve`].
    pub fn engine_release(&self, gpu: usize, bytes: u64) {
        self.engine_queues[gpu].release_bytes(bytes);
    }

    /// Register accepted-but-incomplete work on one engine of `gpu`.
    pub fn engine_reserve_on(&self, gpu: usize, engine: usize, bytes: u64) {
        self.engine_queues[gpu].reserve_on(engine, bytes);
    }

    /// Retire work previously reserved with [`Self::engine_reserve_on`].
    pub fn engine_release_on(&self, gpu: usize, engine: usize, bytes: u64) {
        self.engine_queues[gpu].release_on(engine, bytes);
    }

    /// Total copy-engine byte backlog on `gpu` (sum over its engines).
    pub fn engine_backlog_bytes(&self, gpu: usize) -> u64 {
        self.engine_queues[gpu].queued_bytes()
    }

    /// Byte backlog of one engine of `gpu`.
    pub fn engine_backlog_on(&self, gpu: usize, engine: usize) -> u64 {
        self.engine_queues[gpu].engine_bytes(engine)
    }

    /// The `width` least-loaded engine slots of `gpu`, lightest first —
    /// where the executor places the next stripe's chunks.
    pub fn engine_pick(&self, gpu: usize, width: usize) -> Vec<usize> {
        self.engine_queues[gpu].least_loaded(width)
    }

    // ----------------------------------------------- rail-queue backlog ----

    /// Register accepted-but-incomplete remote work on one rail of `node`.
    pub fn rail_reserve_on(&self, node: usize, rail: usize, bytes: u64) {
        self.rail_sets[node].reserve_on(rail, bytes);
    }

    /// Retire work previously reserved with [`Self::rail_reserve_on`].
    pub fn rail_release_on(&self, node: usize, rail: usize, bytes: u64) {
        self.rail_sets[node].release_on(rail, bytes);
    }

    /// Total NIC-rail byte backlog on `node` (sum over its rails).
    pub fn rail_backlog_bytes(&self, node: usize) -> u64 {
        self.rail_sets[node].queued_bytes()
    }

    /// Byte backlog of one rail of `node`.
    pub fn rail_backlog_on(&self, node: usize, rail: usize) -> u64 {
        self.rail_sets[node].rail_bytes(rail)
    }

    /// The `width` least-loaded rail slots of `node`, lightest first —
    /// where the executor places the next remote stripe's chunks.
    pub fn rail_pick(&self, node: usize, width: usize) -> Vec<usize> {
        self.rail_sets[node].least_loaded(width)
    }

    /// Time to drain `backlog_bytes` already queued on a node's rails at
    /// the aggregate rail rate (the occupancy term of the loaded remote
    /// estimate).
    pub fn rail_drain_ns(&self, backlog_bytes: u64) -> f64 {
        self.rail_drain_ns_at(&self.model.get(), backlog_bytes)
    }

    /// [`Self::rail_drain_ns`] against one caller-held snapshot.
    pub fn rail_drain_ns_at(&self, l: &LearnedParams, backlog_bytes: u64) -> f64 {
        let nic = self.nic_eff_at(l);
        let bw = nic.rail_striped_bw_gbs(nic.rails.min(self.min_live_rails()));
        if bw > 0.0 {
            backlog_bytes as f64 / bw
        } else {
            0.0
        }
    }

    // ------------------------------------------------------ lane health ----

    /// Kill one NIC rail of `node` (fault injection / quarantine). Returns
    /// `true` iff the rail was live — a real transition, which bumps the
    /// health generation so plan caches age out shapes striped across it.
    pub fn kill_rail(&self, node: usize, rail: usize) -> bool {
        let t = self.rail_sets[node.min(self.rail_sets.len() - 1)].kill(rail);
        if t {
            self.dead_lanes.fetch_add(1, Ordering::AcqRel);
            self.health_gen.fetch_add(1, Ordering::AcqRel);
        }
        t
    }

    /// Revive one NIC rail of `node`. Returns `true` iff it was dead.
    pub fn revive_rail(&self, node: usize, rail: usize) -> bool {
        let t = self.rail_sets[node.min(self.rail_sets.len() - 1)].revive(rail);
        if t {
            self.dead_lanes.fetch_sub(1, Ordering::AcqRel);
            self.health_gen.fetch_add(1, Ordering::AcqRel);
        }
        t
    }

    /// Kill one copy engine of `gpu` (global GPU index).
    pub fn kill_engine(&self, gpu: usize, engine: usize) -> bool {
        let t = self.engine_queues[gpu.min(self.engine_queues.len() - 1)].kill(engine);
        if t {
            self.dead_lanes.fetch_add(1, Ordering::AcqRel);
            self.health_gen.fetch_add(1, Ordering::AcqRel);
        }
        t
    }

    /// Revive one copy engine of `gpu`. Returns `true` iff it was dead.
    pub fn revive_engine(&self, gpu: usize, engine: usize) -> bool {
        let t = self.engine_queues[gpu.min(self.engine_queues.len() - 1)].revive(engine);
        if t {
            self.dead_lanes.fetch_sub(1, Ordering::AcqRel);
            self.health_gen.fetch_add(1, Ordering::AcqRel);
        }
        t
    }

    /// Is this rail of `node` currently live?
    pub fn rail_is_live(&self, node: usize, rail: usize) -> bool {
        self.rail_sets[node.min(self.rail_sets.len() - 1)].is_live(rail)
    }

    /// Is this engine of `gpu` currently live?
    pub fn engine_is_live(&self, gpu: usize, engine: usize) -> bool {
        self.engine_queues[gpu.min(self.engine_queues.len() - 1)].is_live(engine)
    }

    /// Live rails on `node`.
    pub fn rail_live_count(&self, node: usize) -> usize {
        self.rail_sets[node.min(self.rail_sets.len() - 1)].live_count()
    }

    /// Live engines on `gpu`.
    pub fn engine_live_count(&self, gpu: usize) -> usize {
        self.engine_queues[gpu.min(self.engine_queues.len() - 1)].live_count()
    }

    /// Monotone counter of lane kill/revive transitions — the plan-cache
    /// invalidation stamp (health twin of `ModelParams::version`).
    pub fn health_generation(&self) -> u64 {
        self.health_gen.load(Ordering::Acquire)
    }

    // -------------------------------------------------- strike ledger ----

    /// Note one transient strike against a NIC rail (retry-aware
    /// planning): the lane prices worse in the rail stripe scans until
    /// cleared by a clean dispatch or quarantine.
    pub fn note_rail_strike(&self, node: usize, rail: usize) {
        let rails = self.params.nic.rails.max(1);
        let i = (node * rails + rail.min(rails - 1)).min(self.rail_strikes.len() - 1);
        self.rail_strikes[i].fetch_add(1, Ordering::AcqRel);
        self.strike_total.fetch_add(1, Ordering::AcqRel);
        self.strike_gen.fetch_add(1, Ordering::AcqRel);
    }

    /// Note one transient strike against a copy engine (global GPU index).
    pub fn note_engine_strike(&self, gpu: usize, engine: usize) {
        let engines = self.params.ce.engines_per_gpu.max(1);
        let i = (gpu * engines + engine.min(engines - 1)).min(self.engine_strikes.len() - 1);
        self.engine_strikes[i].fetch_add(1, Ordering::AcqRel);
        self.strike_total.fetch_add(1, Ordering::AcqRel);
        self.strike_gen.fetch_add(1, Ordering::AcqRel);
    }

    /// Forgive a rail's strikes (clean dispatch / quarantine absorbed the
    /// lane). A no-op — and no generation bump — when the lane is clean.
    pub fn clear_rail_strikes(&self, node: usize, rail: usize) {
        let rails = self.params.nic.rails.max(1);
        let i = (node * rails + rail.min(rails - 1)).min(self.rail_strikes.len() - 1);
        let had = self.rail_strikes[i].swap(0, Ordering::AcqRel);
        if had > 0 {
            self.strike_total.fetch_sub(had, Ordering::AcqRel);
            self.strike_gen.fetch_add(1, Ordering::AcqRel);
        }
    }

    /// Forgive an engine's strikes (see [`Self::clear_rail_strikes`]).
    pub fn clear_engine_strikes(&self, gpu: usize, engine: usize) {
        let engines = self.params.ce.engines_per_gpu.max(1);
        let i = (gpu * engines + engine.min(engines - 1)).min(self.engine_strikes.len() - 1);
        let had = self.engine_strikes[i].swap(0, Ordering::AcqRel);
        if had > 0 {
            self.strike_total.fetch_sub(had, Ordering::AcqRel);
            self.strike_gen.fetch_add(1, Ordering::AcqRel);
        }
    }

    /// Worst unexpired strike count across every NIC rail (0 on a clean
    /// machine without scanning).
    pub fn max_rail_strikes(&self) -> u64 {
        if self.strike_total.load(Ordering::Acquire) == 0 {
            return 0;
        }
        self.rail_strikes.iter().map(|s| s.load(Ordering::Acquire)).max().unwrap_or(0)
    }

    /// Worst unexpired strike count across every copy engine.
    pub fn max_engine_strikes(&self) -> u64 {
        if self.strike_total.load(Ordering::Acquire) == 0 {
            return 0;
        }
        self.engine_strikes.iter().map(|s| s.load(Ordering::Acquire)).max().unwrap_or(0)
    }

    /// Monotone counter of strike note/clear transitions.
    pub fn strike_generation(&self) -> u64 {
        self.strike_gen.load(Ordering::Acquire)
    }

    /// The planner's cache stamp: lane health *and* the strike picture
    /// folded into one u64. Stays exactly `health_generation()` until the
    /// first strike ever lands (fault-free runs never perturb cached
    /// plans), then moves on every strike transition so no cached shape
    /// outlives the penalty inputs it was priced under.
    pub fn planning_generation(&self) -> u64 {
        let h = self.health_gen.load(Ordering::Acquire);
        let s = self.strike_gen.load(Ordering::Acquire);
        h.wrapping_add(s.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Any dead lane anywhere?
    pub fn degraded(&self) -> bool {
        self.dead_lanes.load(Ordering::Acquire) > 0
    }

    /// The worst-case live rail width across nodes — what a topology-blind
    /// plan may safely stripe across. Full-width (and zero-cost) on a
    /// healthy machine; floors at 1 so an all-dead node still gets a
    /// single-lane plan (last-lane fallback) rather than a panic.
    pub fn min_live_rails(&self) -> usize {
        if self.dead_lanes.load(Ordering::Acquire) == 0 {
            return self.params.nic.rails.max(1);
        }
        self.rail_sets
            .iter()
            .map(|r| r.live_count())
            .min()
            .unwrap_or(1)
            .max(1)
    }

    /// The worst-case live engine width across GPUs (see
    /// [`Self::min_live_rails`]).
    pub fn min_live_engines(&self) -> usize {
        if self.dead_lanes.load(Ordering::Acquire) == 0 {
            return self.params.ce.engines_per_gpu.max(1);
        }
        self.engine_queues
            .iter()
            .map(|q| q.live_count())
            .min()
            .unwrap_or(1)
            .max(1)
    }

    /// Move up to `bytes` of rail backlog between two rails of `node`
    /// (proxy re-dispatch off a dead rail).
    pub fn rail_migrate(&self, node: usize, from: usize, to: usize, bytes: u64) {
        self.rail_sets[node.min(self.rail_sets.len() - 1)].migrate(from, to, bytes);
    }

    /// Move up to `bytes` of engine backlog between two engines of `gpu`
    /// (proxy re-dispatch off a dead engine).
    pub fn engine_migrate(&self, gpu: usize, from: usize, to: usize, bytes: u64) {
        self.engine_queues[gpu.min(self.engine_queues.len() - 1)].migrate(from, to, bytes);
    }

    /// Device-side cost of staging `bytes` through the symmetric-heap
    /// staging slab (an HBM-local copy by the issuing work-items; latency
    /// hides in pipelining, so pure bandwidth).
    pub fn staging_copy_ns(&self, bytes: usize) -> f64 {
        bytes as f64 / self.params.xe.hbm_bw_gbs
    }

    /// Inter-node transfer: ring hand-off + host proxy + NIC RDMA.
    pub fn internode_ns(&self, bytes: usize, registered_heap: bool, via_ring: bool) -> f64 {
        let ring = if via_ring {
            self.params.pcie.ring_round_trip_ns()
        } else {
            0.0
        };
        let wire = if registered_heap {
            self.params.nic.rdma_ns(bytes)
        } else {
            self.params.nic.bounce_ns(bytes)
        };
        ring + self.params.overhead.host_issue_ns + wire
    }

    /// Inter-node transfer of `bytes` split into `chunks` chunks striped
    /// over `width` NIC rails. Striping requires FI_HMEM registration —
    /// an unregistered target bounces through host memory un-striped.
    /// Degenerates to [`Self::internode_ns`] at `(width, chunks) = (1, 1)`
    /// under the default `rail_bw_frac`.
    pub fn internode_striped_ns(
        &self,
        bytes: usize,
        registered_heap: bool,
        via_ring: bool,
        width: usize,
        chunks: usize,
    ) -> f64 {
        self.internode_striped_ns_at(
            &self.model.get(),
            bytes,
            registered_heap,
            via_ring,
            width,
            chunks,
        )
    }

    /// [`Self::internode_striped_ns`] against one caller-held snapshot.
    pub fn internode_striped_ns_at(
        &self,
        l: &LearnedParams,
        bytes: usize,
        registered_heap: bool,
        via_ring: bool,
        width: usize,
        chunks: usize,
    ) -> f64 {
        if !registered_heap {
            return self.internode_ns(bytes, false, via_ring);
        }
        let ring = if via_ring {
            self.params.pcie.ring_round_trip_ns()
        } else {
            0.0
        };
        ring + self.params.overhead.host_issue_ns
            + self.nic_eff_at(l).rdma_striped_ns(bytes, width, chunks)
    }

    // --------------------------------------------------- time-to-first-byte

    /// Modeled time until the first byte of a chunked *engine* transfer is
    /// on an engine: ring hand-off + staging of the first (possibly
    /// ramped) fill + the engine startup. Ramping (`stripe.ramp_factor` <
    /// 1) strictly shrinks the fill term, so the first engine starts
    /// earlier at equal total bytes.
    pub fn engine_ttfb_ns(&self, chunk_bytes: usize, immediate_cl: bool) -> f64 {
        self.engine_ttfb_ns_at(&self.model.get(), chunk_bytes, immediate_cl)
    }

    /// [`Self::engine_ttfb_ns`] against one caller-held snapshot.
    pub fn engine_ttfb_ns_at(
        &self,
        l: &LearnedParams,
        chunk_bytes: usize,
        immediate_cl: bool,
    ) -> f64 {
        let ce = self.ce_eff_at(l);
        let startup = if immediate_cl {
            ce.startup_immediate_ns
        } else {
            ce.startup_standard_ns
        };
        self.ring_rtt_ns()
            + self.staging_copy_ns(self.params.stripe.first_fill_bytes(chunk_bytes))
            + startup
    }

    /// Modeled time until the first byte of a chunked *rail* transfer is
    /// on the wire: ring hand-off + host proxy + staging of the first
    /// (possibly ramped) fill + the NIC injection latency.
    pub fn nic_ttfb_ns(&self, chunk_bytes: usize) -> f64 {
        self.ring_rtt_ns()
            + self.params.overhead.host_issue_ns
            + self.staging_copy_ns(self.params.stripe.first_fill_bytes(chunk_bytes))
            + self.params.nic.latency_ns
    }

    /// Pipelined remote atomics (push sync/broadcast primitives).
    pub fn pipelined_atomics_ns(&self, n: usize) -> f64 {
        self.params.xe.pipelined_atomics_ns(n)
    }

    /// One fetching atomic (AMO with a result).
    pub fn fetch_atomic_ns(&self, loc: Locality) -> f64 {
        match loc {
            Locality::SameTile => self.params.xe.atomic_fetch_ns * 0.2,
            Locality::SameGpu => self.params.xe.atomic_fetch_ns * 0.6,
            Locality::SameNode => self.params.xe.atomic_fetch_ns,
            Locality::Remote => {
                self.params.pcie.ring_round_trip_ns() + self.params.nic.latency_ns * 2.0
            }
        }
    }

    // ------------------------------------------- collective estimators ----

    /// One inter-node leader hop of `bytes` (rail-striped RDMA, shape
    /// chosen by the rail planner) — the wire term every hierarchical
    /// stage composes.
    pub fn coll_wire_ns_at(&self, l: &LearnedParams, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let (chunk, width) = self.rail_stripe_for_at(l, bytes, usize::MAX);
        let n = bytes.div_ceil(chunk.max(1));
        self.internode_striped_ns_at(l, bytes, true, true, width, n)
    }

    /// Flat-collective wire term: `blocks` *independent* per-peer RDMA
    /// blocks of `block_bytes` each, injected through one node's rails.
    /// Unlike [`Self::coll_wire_ns_at`] the per-block injection startup is
    /// charged for every block (that is what the flat execution does: one
    /// `transport.put` per remote peer), so flat grows super-linearly in
    /// team size while the aggregated hierarchical hops do not.
    pub fn coll_wire_blocks_ns_at(
        &self,
        l: &LearnedParams,
        block_bytes: usize,
        blocks: usize,
    ) -> f64 {
        if block_bytes == 0 || blocks == 0 {
            return 0.0;
        }
        let nic = self.nic_eff_at(l);
        self.ring_rtt_ns()
            + self.params.overhead.host_issue_ns
            + nic.rdma_striped_ns(block_bytes * blocks, nic.rails.max(1), blocks)
    }

    /// One intra-node distribution (or gather) stage: a source pushing
    /// `bytes` per peer to `peers` members spread over `gpus` Xe-Links,
    /// each link running its GPU's engines at the striped rate. The links
    /// run concurrently, so the stage costs the busiest link.
    pub fn coll_intra_ns_at(
        &self,
        l: &LearnedParams,
        bytes: usize,
        peers: usize,
        gpus: usize,
    ) -> f64 {
        if peers == 0 || bytes == 0 {
            return 0.0;
        }
        let ce = self.ce_eff_at(l);
        let links = gpus.clamp(1, self.topo.gpus_per_node.max(1));
        let per_link_peers = peers.div_ceil(links);
        let startups = per_link_peers.div_ceil(ce.engines_per_gpu.max(1)) as f64
            * ce.startup_immediate_ns;
        let bw = ce.striped_bw_gbs(
            &self.params.xe,
            Locality::SameNode,
            ce.engines_per_gpu.max(1),
        );
        self.ring_rtt_ns() + startups + bytes as f64 * per_link_peers as f64 / bw
    }

    /// Intra-node distribution of ONE payload to every node member, the
    /// way the hierarchical executor moves it: a pipelined GPU-leader
    /// chain (the payload crosses each Xe-Link once, links run
    /// concurrently) followed by an MDFI fan to the remaining tiles of
    /// each GPU. Unlike [`Self::coll_intra_ns_at`] the cost is (nearly)
    /// independent of the member count — that is the whole point of the
    /// GPU-leader stage.
    pub fn coll_intra_bcast_ns_at(
        &self,
        l: &LearnedParams,
        bytes: usize,
        members: usize,
        gpus: usize,
    ) -> f64 {
        if members <= 1 || bytes == 0 {
            return 0.0;
        }
        let ce = self.ce_eff_at(l);
        let engines = ce.engines_per_gpu.max(1);
        let gpus = gpus.clamp(1, self.topo.gpus_per_node.max(1));
        let link = if gpus > 1 {
            bytes as f64 / ce.striped_bw_gbs(&self.params.xe, Locality::SameNode, engines)
        } else {
            0.0
        };
        let tiles = members.div_ceil(gpus);
        let mdfi = bytes as f64 * tiles.saturating_sub(1) as f64
            / ce.striped_bw_gbs(&self.params.xe, Locality::SameGpu, engines);
        self.ring_rtt_ns() + ce.startup_immediate_ns + link + mdfi
    }

    /// All three algorithm estimates for one collective, priced from ONE
    /// caller-held snapshot (the p2p single-generation discipline).
    /// `bytes` is the broadcast payload / fcollect block / reduce vector;
    /// `leader_fanout` is the inter-node tree arity.
    pub fn coll_estimates_at(
        &self,
        l: &LearnedParams,
        shape: &CollShape,
        op: CollOp,
        bytes: usize,
        leader_fanout: usize,
    ) -> CollEstimates {
        let npes = shape.npes.max(1);
        let nnodes = shape.nnodes().max(1);
        let (m_max, g_max) = shape.max_node();
        if shape.single_node() {
            // No inter-node stage exists: every algorithm IS the flat path
            // (and the executor gates it there), so the estimates agree.
            let flat = match op {
                CollOp::Broadcast => {
                    self.params.overhead.device_issue_ns
                        + self.coll_intra_ns_at(l, bytes, m_max.saturating_sub(1), g_max)
                }
                CollOp::Fcollect => {
                    self.params.overhead.device_issue_ns
                        + self.coll_intra_ns_at(
                            l,
                            bytes * m_max,
                            m_max.saturating_sub(1),
                            g_max,
                        )
                }
                CollOp::Reduce => {
                    self.params.overhead.device_issue_ns * npes as f64
                        + self.coll_intra_ns_at(
                            l,
                            bytes * m_max,
                            m_max.saturating_sub(1),
                            g_max,
                        )
                        + bytes as f64 * npes.saturating_sub(1) as f64
                            / (self.params.xe.hbm_bw_gbs / 2.0)
                }
            };
            return CollEstimates { flat_ns: flat, ring_ns: flat, tree_ns: flat };
        }
        let remote = npes - m_max.min(npes);
        let issue = self.params.overhead.device_issue_ns;
        let k = leader_fanout.clamp(2, nnodes.max(2)).min(nnodes.saturating_sub(1).max(1));
        let depth = tree_depth(nnodes, k);
        let (flat_ns, ring_ns, tree_ns) = match op {
            CollOp::Broadcast => {
                // Flat: the root pushes one block per member — remote
                // blocks all serialize through the root node's rails.
                let flat = issue
                    + self.coll_intra_ns_at(l, bytes, m_max.saturating_sub(1), g_max)
                    + self.coll_wire_blocks_ns_at(l, bytes, remote);
                let intra = self.coll_intra_bcast_ns_at(l, bytes, m_max, g_max);
                // Ring: pipelined chain over node leaders — the first full
                // payload plus one chunk-time per extra hop.
                let (chunk, _w) = self.rail_stripe_for_at(l, bytes.max(1), usize::MAX);
                let ring = issue
                    + self.coll_wire_ns_at(l, bytes)
                    + nnodes.saturating_sub(2) as f64
                        * self.coll_wire_ns_at(l, chunk.min(bytes))
                    + intra;
                // Tree: depth levels, each parent feeding ≤k children off
                // its own rails (serialized per parent).
                let tree = issue
                    + depth as f64 * k as f64 * self.coll_wire_ns_at(l, bytes)
                    + intra;
                (flat, ring, tree)
            }
            CollOp::Fcollect => {
                // Flat: every PE fans its block to all members; the
                // busiest node's NIC moves block · m · (npes − m).
                let flat = issue
                    + self.coll_intra_ns_at(
                        l,
                        bytes * m_max,
                        m_max.saturating_sub(1),
                        g_max,
                    )
                    + self.coll_wire_blocks_ns_at(l, bytes, m_max * remote);
                let total = bytes * npes;
                let gather = self.coll_intra_ns_at(l, bytes, m_max.saturating_sub(1), g_max);
                let bcast = self.coll_intra_bcast_ns_at(l, total, m_max, g_max);
                // Ring allgather of node blocks among leaders.
                let ring = issue
                    + gather
                    + nnodes.saturating_sub(1) as f64
                        * self.coll_wire_ns_at(l, bytes * m_max)
                    + bcast;
                // Tree: gather node blocks to the root, broadcast the full
                // result back down.
                let tree = issue
                    + gather
                    + 2.0
                        * k as f64
                        * depth as f64
                        * self.coll_wire_ns_at(l, total / depth.max(1))
                    + bcast;
                (flat, ring, tree)
            }
            CollOp::Reduce => {
                // Shared compute: n−1 elementwise folds over the vector.
                let compute = bytes as f64 * npes.saturating_sub(1) as f64
                    / (self.params.xe.hbm_bw_gbs / 2.0);
                // Flat mirrors the duplicated-gather execution: every PE
                // pulls every remote block, so each node's NIC carries
                // vector · m · (npes − m).
                let flat = issue * npes as f64
                    + self.coll_intra_ns_at(
                        l,
                        bytes * m_max,
                        m_max.saturating_sub(1),
                        g_max,
                    )
                    + self.coll_wire_blocks_ns_at(l, bytes, m_max * remote)
                    + compute;
                let gather = self.coll_intra_ns_at(l, bytes, m_max.saturating_sub(1), g_max);
                let bcast = self.coll_intra_bcast_ns_at(l, bytes, m_max, g_max);
                // Leaders exchange raw per-node gathered blocks (keeps the
                // fold order — and therefore the bits — identical to flat).
                let ring = issue
                    + gather
                    + nnodes.saturating_sub(1) as f64
                        * self.coll_wire_ns_at(l, bytes * m_max)
                    + compute
                    + bcast;
                let total = bytes * npes;
                let tree = issue
                    + gather
                    + 2.0
                        * k as f64
                        * depth as f64
                        * self.coll_wire_ns_at(l, total / depth.max(1))
                    + compute
                    + bcast;
                (flat, ring, tree)
            }
        };
        CollEstimates { flat_ns, ring_ns, tree_ns }
    }

    /// [`Self::coll_estimates_at`] against the current generation.
    pub fn coll_estimates(
        &self,
        shape: &CollShape,
        op: CollOp,
        bytes: usize,
        leader_fanout: usize,
    ) -> CollEstimates {
        self.coll_estimates_at(&self.model.get(), shape, op, bytes, leader_fanout)
    }

    pub fn device_issue_ns(&self) -> f64 {
        self.params.overhead.device_issue_ns
    }

    pub fn group_barrier_ns(&self) -> f64 {
        self.params.overhead.group_barrier_ns
    }

    pub fn ring_post_ns(&self) -> f64 {
        self.params.pcie.ring_post_ns()
    }

    pub fn ring_rtt_ns(&self) -> f64 {
        self.params.pcie.ring_round_trip_ns()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> Arc<CostModel> {
        CostModel::new(Topology::default(), CostParams::default())
    }

    #[test]
    fn fig3_crossover_shape() {
        // Paper Fig 3: load/store wins up to ~4KB, engine path wins for
        // large messages; both converge at the link roofline.
        let m = model();
        let loc = Locality::SameNode;
        let small = m.loadstore_ns(loc, 2048, 1);
        let small_ce = m.copy_engine_ns(0, loc, 2048, true, false, true);
        assert!(small < small_ce, "{small} !< {small_ce}");

        let big = m.loadstore_ns(loc, 8 << 20, 1);
        let big_ce = m.copy_engine_ns(0, loc, 8 << 20, true, false, true);
        assert!(big_ce < big, "{big_ce} !< {big}");
    }

    #[test]
    fn loaded_estimate_grows_with_backlog() {
        let m = model();
        let loc = Locality::SameNode;
        let idle = m.p2p_engine_estimate_loaded_ns(loc, 4096, true, 0);
        assert_eq!(idle, m.p2p_engine_estimate_ns(loc, 4096, true));
        let loaded = m.p2p_engine_estimate_loaded_ns(loc, 4096, true, 64 << 20);
        assert!(loaded > idle * 2.0, "{loaded} !> {idle}*2");
        // Live backlog flows through reserve/release.
        m.engine_reserve(0, 4096);
        assert_eq!(m.engine_backlog_bytes(0), 4096);
        m.engine_release(0, 4096);
        assert_eq!(m.engine_backlog_bytes(0), 0);
    }

    #[test]
    fn stripe_planner_balances_startup_against_bandwidth() {
        let m = model();
        let loc = Locality::SameNode;
        let chunk_min = m.params.ce.chunk_min_bytes;
        // Small transfers never stripe.
        let (c, w) = m.stripe_for(loc, 4096, usize::MAX, usize::MAX);
        assert_eq!((c, w), (4096, 1));
        // Large transfers stripe wide and the estimate beats single-engine.
        let big = 8 << 20;
        let (c, w) = m.stripe_for(loc, big, usize::MAX, usize::MAX);
        assert!(w >= 2, "no striping for {big}B: width {w}");
        assert!(c >= chunk_min && c <= big);
        let striped = m.p2p_engine_estimate_ns(loc, big, true);
        let single = m.ring_rtt_ns()
            + m.params
                .ce
                .striped_transfer_ns(&m.params.xe, loc, big, true, false, 1, 1);
        assert!(striped * 2.0 <= single, "{striped} !<= {single}/2");
        // A chunk cap below chunk_min disables the pipeline.
        assert_eq!(m.stripe_for(loc, big, chunk_min - 1, usize::MAX), (big, 1));
        // A slab-sized cap forces more, smaller chunks — never above cap.
        let (c, w) = m.stripe_for(loc, big, 1 << 20, usize::MAX);
        assert!(c <= 1 << 20 && w >= 2, "cap ignored: chunk {c} width {w}");
        // The scan scores candidates at the flavor they will run with:
        // an all-standard boundary never yields a cheaper shape than the
        // estimate it feeds (both use the standard startup).
        let (c_std, w_std) = m.stripe_for(loc, big, usize::MAX, 0);
        assert!(w_std >= 2 && c_std >= chunk_min);
    }

    #[test]
    fn capped_estimate_matches_uncapped_when_cap_is_loose() {
        let m = model();
        let loc = Locality::SameNode;
        for bytes in [64usize, 4096, 1 << 20, 8 << 20] {
            assert_eq!(
                m.p2p_engine_estimate_ns(loc, bytes, true),
                m.p2p_engine_estimate_capped_ns(loc, bytes, true, usize::MAX),
            );
        }
    }

    #[test]
    fn per_engine_reserve_release_roundtrip() {
        let m = model();
        m.engine_reserve_on(0, 2, 4096);
        m.engine_reserve_on(0, 5, 100);
        assert_eq!(m.engine_backlog_on(0, 2), 4096);
        assert_eq!(m.engine_backlog_bytes(0), 4196);
        // The picker avoids the loaded engines.
        let picked = m.engine_pick(0, 2);
        assert!(!picked.contains(&2) && !picked.contains(&5), "{picked:?}");
        m.engine_release_on(0, 2, 4096);
        m.engine_release_on(0, 5, 100);
        assert_eq!(m.engine_backlog_bytes(0), 0);
    }

    #[test]
    fn internode_registration_matters() {
        let m = model();
        assert!(m.internode_ns(1 << 20, true, true) < m.internode_ns(1 << 20, false, true));
    }

    #[test]
    fn rail_stripe_planner_mirrors_engine_planner() {
        let m = model();
        let chunk_min = m.params.nic.rail_chunk_min_bytes;
        // Small remote transfers never stripe.
        assert_eq!(m.rail_stripe_for(4096, usize::MAX), (4096, 1));
        // Large remote transfers stripe across rails and beat one rail.
        let big = 8 << 20;
        let (c, w) = m.rail_stripe_for(big, usize::MAX);
        assert!(w >= 2, "no rail striping for {big}B: width {w}");
        assert!(c >= chunk_min && c <= big);
        let n = big.div_ceil(c);
        let striped = m.internode_striped_ns(big, true, true, w, n);
        let single = m.internode_ns(big, true, true);
        assert!(striped * 2.0 <= single, "{striped} !<= {single}/2");
        // A cap below the rail chunk minimum disables the pipeline.
        assert_eq!(m.rail_stripe_for(big, chunk_min - 1), (big, 1));
        // A slab-sized cap forces more, smaller chunks — never above cap.
        let (c, w) = m.rail_stripe_for(big, 1 << 20);
        assert!(c <= 1 << 20 && w >= 2, "cap ignored: chunk {c} width {w}");
    }

    #[test]
    fn one_rail_config_never_chunks_and_matches_plain_internode() {
        let mut p = CostParams::default();
        p.nic.rails = 1;
        let m = CostModel::new(Topology::default(), p);
        for bytes in [64usize, 4096, 1 << 20, 8 << 20] {
            assert_eq!(m.rail_stripe_for(bytes, usize::MAX), (bytes.max(1), 1));
            assert_eq!(
                m.internode_striped_ns(bytes, true, true, 1, 1),
                m.internode_ns(bytes, true, true),
            );
        }
    }

    #[test]
    fn killing_all_but_one_rail_reproduces_one_rail_estimates() {
        // Degraded-mode twin of the 1-rail config test above: with every
        // rail but one dead, plans never chunk and match the plain
        // internode estimate exactly.
        let m = model();
        for r in 1..m.params.nic.rails {
            assert!(m.kill_rail(0, r));
        }
        assert_eq!(m.min_live_rails(), 1);
        for bytes in [64usize, 4096, 1 << 20, 8 << 20] {
            assert_eq!(m.rail_stripe_for(bytes, usize::MAX), (bytes.max(1), 1));
            assert_eq!(
                m.internode_striped_ns(bytes, true, true, 1, 1),
                m.internode_ns(bytes, true, true),
            );
        }
    }

    #[test]
    fn dead_rail_replans_to_the_n_minus_one_model_and_revival_restores() {
        // The ISSUE 8 property: killing 1 of N rails makes every remote
        // plan and estimate bit-identical to an (N-1)-rail machine, and
        // revival restores the N-rail numbers bit-for-bit.
        let m = model();
        let rails = m.params.nic.rails;
        assert!(rails >= 2);
        let mut p = CostParams::default();
        p.nic.rails = rails - 1;
        let reduced = CostModel::new(Topology::default(), p);
        let sizes = [4096usize, 512 << 10, 1 << 20, 8 << 20, 64 << 20];
        let baseline: Vec<((usize, usize), u64)> = sizes
            .iter()
            .map(|&b| {
                let (c, w) = m.rail_stripe_for(b, usize::MAX);
                let n = b.div_ceil(c.max(1));
                ((c, w), m.internode_striped_ns(b, true, true, w, n).to_bits())
            })
            .collect();

        assert!(m.kill_rail(0, 2));
        assert!(m.degraded());
        assert_eq!(m.min_live_rails(), rails - 1);
        for &bytes in &sizes {
            let shape = m.rail_stripe_for(bytes, usize::MAX);
            assert_eq!(
                shape,
                reduced.rail_stripe_for(bytes, usize::MAX),
                "degraded shape diverges from the {}-rail model at {bytes}B",
                rails - 1
            );
            let (c, w) = shape;
            let n = bytes.div_ceil(c.max(1));
            assert_eq!(
                m.internode_striped_ns(bytes, true, true, w, n).to_bits(),
                reduced.internode_striped_ns(bytes, true, true, w, n).to_bits(),
                "degraded estimate diverges at {bytes}B"
            );
        }
        assert_eq!(
            m.rail_drain_ns(64 << 20).to_bits(),
            reduced.rail_drain_ns(64 << 20).to_bits(),
        );
        // The degraded plan genuinely re-striped (not a vacuous pass).
        assert!(baseline.iter().zip(&sizes).any(|(b, &bytes)| {
            m.rail_stripe_for(bytes, usize::MAX) != b.0
        }));

        assert!(m.revive_rail(0, 2));
        assert!(!m.degraded());
        for (&bytes, b) in sizes.iter().zip(&baseline) {
            let (c, w) = m.rail_stripe_for(bytes, usize::MAX);
            assert_eq!((c, w), b.0, "revival did not restore the shape at {bytes}B");
            let n = bytes.div_ceil(c.max(1));
            assert_eq!(
                m.internode_striped_ns(bytes, true, true, w, n).to_bits(),
                b.1,
                "revival did not restore the estimate at {bytes}B"
            );
        }
    }

    #[test]
    fn dead_engines_replan_to_the_reduced_engine_model_and_revival_restores() {
        // Engine twin of the rail property: with only `live` engines left
        // on the worst GPU, shapes, estimates, and drains are bit-identical
        // to a machine configured with `live` engines per GPU.
        let m = model();
        let per_gpu = m.params.ce.engines_per_gpu;
        let live = 2usize;
        assert!(per_gpu > live);
        let mut p = CostParams::default();
        p.ce.engines_per_gpu = live;
        let reduced = CostModel::new(Topology::default(), p);
        let loc = Locality::SameNode;
        let sizes = [4096usize, 1 << 20, 8 << 20];
        let baseline: Vec<u64> = sizes
            .iter()
            .map(|&b| m.p2p_engine_estimate_ns(loc, b, true).to_bits())
            .collect();

        for e in live..per_gpu {
            assert!(m.kill_engine(0, e));
        }
        assert_eq!(m.min_live_engines(), live);
        for &bytes in &sizes {
            assert_eq!(
                m.stripe_for(loc, bytes, usize::MAX, usize::MAX),
                reduced.stripe_for(loc, bytes, usize::MAX, usize::MAX),
                "degraded shape diverges from the {live}-engine model at {bytes}B"
            );
            assert_eq!(
                m.p2p_engine_estimate_ns(loc, bytes, true).to_bits(),
                reduced.p2p_engine_estimate_ns(loc, bytes, true).to_bits(),
                "degraded estimate diverges at {bytes}B"
            );
        }
        assert_eq!(
            m.engine_drain_ns(loc, 64 << 20).to_bits(),
            reduced.engine_drain_ns(loc, 64 << 20).to_bits(),
        );

        for e in live..per_gpu {
            assert!(m.revive_engine(0, e));
        }
        assert!(!m.degraded());
        for (&bytes, &bits) in sizes.iter().zip(&baseline) {
            assert_eq!(
                m.p2p_engine_estimate_ns(loc, bytes, true).to_bits(),
                bits,
                "revival did not restore the engine estimate at {bytes}B"
            );
        }
    }

    #[test]
    fn health_generation_bumps_on_transitions_only() {
        let m = model();
        assert_eq!(m.health_generation(), 0);
        assert!(!m.degraded());
        assert!(m.kill_rail(0, 1));
        assert_eq!(m.health_generation(), 1);
        assert!(!m.kill_rail(0, 1), "re-kill is not a transition");
        assert_eq!(m.health_generation(), 1);
        assert!(!m.rail_is_live(0, 1));
        assert!(m.kill_engine(0, 0));
        assert!(!m.engine_is_live(0, 0));
        assert_eq!(m.health_generation(), 2);
        assert!(m.degraded());
        assert!(m.revive_rail(0, 1));
        assert!(m.revive_engine(0, 0));
        assert!(!m.revive_engine(0, 0), "re-revive is not a transition");
        assert_eq!(m.health_generation(), 4);
        assert!(!m.degraded());
        assert!(m.rail_is_live(0, 1) && m.engine_is_live(0, 0));
    }

    #[test]
    fn zero_strikes_is_bit_identical_and_strikes_bias_plans() {
        let m = model();
        let loc = Locality::SameNode;
        let big = 8 << 20;
        let base_engine = m.stripe_for(loc, big, usize::MAX, usize::MAX);
        let base_rail = m.rail_stripe_for(big, usize::MAX);
        assert_eq!(m.max_rail_strikes(), 0);
        assert_eq!(m.max_engine_strikes(), 0);
        assert_eq!(strike_penalty_ns(0, 1024), 0.0, "penalty must be exactly zero");

        // Strikes raise the per-chunk price, biasing the scan toward fewer
        // chunks (never more).
        m.note_rail_strike(0, 1);
        m.note_rail_strike(0, 1);
        m.note_engine_strike(0, 0);
        assert_eq!(m.max_rail_strikes(), 2);
        assert_eq!(m.max_engine_strikes(), 1);
        let struck_engine = m.stripe_for(loc, big, usize::MAX, usize::MAX);
        let struck_rail = m.rail_stripe_for(big, usize::MAX);
        assert!(
            big.div_ceil(struck_engine.0) <= big.div_ceil(base_engine.0),
            "strikes must not increase engine chunk count: {base_engine:?} -> {struck_engine:?}"
        );
        assert!(
            big.div_ceil(struck_rail.0) <= big.div_ceil(base_rail.0),
            "strikes must not increase rail chunk count: {base_rail:?} -> {struck_rail:?}"
        );

        // Clearing restores the exact strike-free shapes (bit-for-bit).
        m.clear_rail_strikes(0, 1);
        m.clear_engine_strikes(0, 0);
        assert_eq!(m.max_rail_strikes(), 0);
        assert_eq!(m.max_engine_strikes(), 0);
        assert_eq!(m.stripe_for(loc, big, usize::MAX, usize::MAX), base_engine);
        assert_eq!(m.rail_stripe_for(big, usize::MAX), base_rail);
    }

    #[test]
    fn planning_generation_tracks_strike_and_health_transitions() {
        let m = model();
        let g0 = m.planning_generation();
        assert_eq!(g0, m.health_generation(), "clean machine: pure health stamp");

        m.note_rail_strike(0, 0);
        let g1 = m.planning_generation();
        assert_ne!(g1, g0, "a strike must move the planning stamp");
        assert_eq!(m.strike_generation(), 1);

        // Clearing a clean lane is not a transition.
        m.clear_rail_strikes(0, 1);
        assert_eq!(m.planning_generation(), g1);

        m.clear_rail_strikes(0, 0);
        let g2 = m.planning_generation();
        assert_ne!(g2, g1, "forgiving a struck lane must move the stamp");
        assert_eq!(m.max_rail_strikes(), 0);

        // Health transitions still move the folded stamp.
        assert!(m.kill_rail(0, 1));
        assert_ne!(m.planning_generation(), g2);
        assert!(m.revive_rail(0, 1));
    }

    #[test]
    fn unregistered_targets_bounce_unstriped() {
        let m = model();
        assert_eq!(
            m.internode_striped_ns(1 << 20, false, true, 4, 4),
            m.internode_ns(1 << 20, false, true),
        );
    }

    #[test]
    fn per_rail_reserve_release_roundtrip() {
        let m = model();
        m.rail_reserve_on(0, 2, 4096);
        m.rail_reserve_on(0, 3, 100);
        assert_eq!(m.rail_backlog_on(0, 2), 4096);
        assert_eq!(m.rail_backlog_bytes(0), 4196);
        let picked = m.rail_pick(0, 2);
        assert!(!picked.contains(&2) && !picked.contains(&3), "{picked:?}");
        m.rail_release_on(0, 2, 4096);
        m.rail_release_on(0, 3, 100);
        assert_eq!(m.rail_backlog_bytes(0), 0);
        assert!(m.rail_drain_ns(8 << 20) > 0.0);
    }

    #[test]
    fn ramp_strictly_reduces_time_to_first_byte() {
        let mut p = CostParams::default();
        let base = CostModel::new(Topology::default(), p.clone());
        p.stripe.ramp_factor = 0.25;
        let ramped = CostModel::new(Topology::default(), p);
        let chunk = 1 << 20;
        assert!(ramped.nic_ttfb_ns(chunk) < base.nic_ttfb_ns(chunk));
        assert!(ramped.engine_ttfb_ns(chunk, true) < base.engine_ttfb_ns(chunk, true));
        assert!(ramped.engine_ttfb_ns(chunk, false) < base.engine_ttfb_ns(chunk, false));
        // Ramp off is the identity fill.
        assert_eq!(base.params.stripe.first_fill_bytes(chunk), chunk);
        assert_eq!(ramped.params.stripe.first_fill_bytes(chunk), chunk / 4);
    }

    #[test]
    fn uncalibrated_estimates_are_bit_identical_to_seed_formulas() {
        // The `calib.enable = false` acceptance bar: with nothing learned,
        // every estimate that now reads through the ModelParams overlay
        // must produce the identical f64 bits the raw configured-param
        // formulas produce (the pre-calibration code path).
        let m = model();
        assert_eq!(m.model.version(), 0);
        for loc in [Locality::SameTile, Locality::SameGpu, Locality::SameNode] {
            for bytes in [64usize, 4096, 256 << 10, 1 << 20, 8 << 20] {
                let (chunk, width) = m.stripe_for(loc, bytes, usize::MAX, usize::MAX);
                let n = bytes.div_ceil(chunk.max(1));
                let seed = m.ring_rtt_ns()
                    + m.params
                        .ce
                        .striped_transfer_ns(&m.params.xe, loc, bytes, true, false, width, n);
                assert_eq!(
                    m.p2p_engine_estimate_ns(loc, bytes, true).to_bits(),
                    seed.to_bits(),
                    "engine estimate drifted at {loc:?}/{bytes}B"
                );
            }
        }
        for bytes in [4096usize, 1 << 20, 8 << 20] {
            let (chunk, width) = m.rail_stripe_for(bytes, usize::MAX);
            let n = bytes.div_ceil(chunk.max(1));
            let seed = m.ring_rtt_ns()
                + m.params.overhead.host_issue_ns
                + m.params.nic.rdma_striped_ns(bytes, width, n);
            assert_eq!(
                m.internode_striped_ns(bytes, true, true, width, n).to_bits(),
                seed.to_bits(),
                "rail estimate drifted at {bytes}B"
            );
        }
        assert_eq!(
            m.engine_ttfb_ns(1 << 20, true).to_bits(),
            (m.ring_rtt_ns()
                + m.staging_copy_ns(1 << 20)
                + m.params.ce.startup_immediate_ns)
                .to_bits(),
        );
    }

    #[test]
    fn model_update_recomputes_every_estimate_and_bumps_version() {
        let m = model();
        let loc = Locality::SameNode;
        let big = 8 << 20;
        let before_engine = m.p2p_engine_estimate_ns(loc, big, true);
        let before_drain = m.engine_drain_ns(loc, 64 << 20);
        let (c, w) = m.rail_stripe_for(big, usize::MAX);
        let before_rail = m.internode_striped_ns(big, true, true, w, big.div_ceil(c));
        // Calibration doubles the single-engine fraction and halves the
        // per-rail fraction: engine transfers get faster, rail transfers
        // slower — with no re-construction of anything.
        let v = m.model.update(|l| {
            l.single_engine_frac = 0.5;
            l.rail_bw_frac = 0.5;
        });
        assert_eq!(v, 1);
        assert_eq!(m.model.version(), 1);
        assert!(
            m.p2p_engine_estimate_ns(loc, big, true) < before_engine,
            "faster learned engines must shrink the estimate"
        );
        assert!(
            m.engine_drain_ns(loc, 64 << 20) < before_drain,
            "faster learned engines must drain backlog faster"
        );
        let (c2, w2) = m.rail_stripe_for(big, usize::MAX);
        assert!(
            m.internode_striped_ns(big, true, true, w2, big.div_ceil(c2)) > before_rail,
            "slower learned rails must grow the remote estimate"
        );
        // ce_eff/nic_eff expose the live values.
        assert_eq!(m.ce_eff().single_engine_frac, 0.5);
        assert_eq!(m.nic_eff().rail_bw_frac, 0.5);
        // Resetting restores the seed estimates bit-for-bit.
        m.model.reset();
        assert_eq!(
            m.p2p_engine_estimate_ns(loc, big, true).to_bits(),
            before_engine.to_bits()
        );
    }

    #[test]
    fn snapshot_threaded_estimates_match_the_public_wrappers() {
        // The `_at` variants against the current generation are the same
        // formulas the no-snapshot entry points compute — bit-for-bit,
        // before and after a calibration apply.
        let m = model();
        for pass in 0..2 {
            let l = m.model.get();
            for loc in [Locality::SameTile, Locality::SameGpu, Locality::SameNode] {
                for bytes in [64usize, 4096, 1 << 20, 8 << 20] {
                    assert_eq!(
                        m.p2p_engine_estimate_capped_ns_at(&l, loc, bytes, true, 1 << 20)
                            .to_bits(),
                        m.p2p_engine_estimate_capped_ns(loc, bytes, true, 1 << 20).to_bits(),
                        "pass {pass} {loc:?}/{bytes}B"
                    );
                    assert_eq!(
                        m.p2p_engine_estimate_capped_loaded_ns_at(
                            &l, loc, bytes, false, 1 << 20, 8 << 20
                        )
                        .to_bits(),
                        m.p2p_engine_estimate_capped_loaded_ns(loc, bytes, false, 1 << 20, 8 << 20)
                            .to_bits(),
                    );
                    assert_eq!(
                        m.stripe_for_at(&l, loc, bytes, 1 << 20, 64 << 10),
                        m.stripe_for(loc, bytes, 1 << 20, 64 << 10),
                    );
                }
            }
            for bytes in [4096usize, 1 << 20, 8 << 20] {
                assert_eq!(
                    m.rail_stripe_for_at(&l, bytes, 1 << 20),
                    m.rail_stripe_for(bytes, 1 << 20),
                );
                let (c, w) = m.rail_stripe_for(bytes, usize::MAX);
                let n = bytes.div_ceil(c.max(1));
                assert_eq!(
                    m.internode_striped_ns_at(&l, bytes, true, true, w, n).to_bits(),
                    m.internode_striped_ns(bytes, true, true, w, n).to_bits(),
                );
            }
            assert_eq!(
                m.engine_drain_ns_at(&l, Locality::SameNode, 64 << 20).to_bits(),
                m.engine_drain_ns(Locality::SameNode, 64 << 20).to_bits(),
            );
            assert_eq!(
                m.rail_drain_ns_at(&l, 64 << 20).to_bits(),
                m.rail_drain_ns(64 << 20).to_bits(),
            );
            assert_eq!(
                m.engine_ttfb_ns_at(&l, 1 << 20, true).to_bits(),
                m.engine_ttfb_ns(1 << 20, true).to_bits(),
            );
            if pass == 0 {
                m.model.update(|l| {
                    l.single_engine_frac = 0.5;
                    l.rail_bw_frac = 0.5;
                    l.startup_standard_ns = 9_000.0;
                });
            }
        }
    }

    fn shape_for(npes: usize) -> (Topology, CollShape) {
        let topo = Topology::multi_node_for(npes);
        let shape = CollShape::from_members(&topo, 0..npes);
        (topo, shape)
    }

    #[test]
    fn coll_shape_digests_members_per_node() {
        let topo = Topology::new(2, 2, 2);
        let shape = CollShape::from_members(&topo, 0..8);
        assert_eq!(shape.npes, 8);
        assert_eq!(shape.node_members, vec![4, 4]);
        assert_eq!(shape.node_gpus, vec![2, 2]);
        assert!(!shape.single_node());
        // A node-local slice is single-node.
        let local = CollShape::from_members(&topo, 0..4);
        assert!(local.single_node());
        // Strided teams land on both nodes.
        let strided = CollShape::from_members(&topo, (0..8).step_by(2));
        assert_eq!(strided.node_members, vec![2, 2]);
    }

    #[test]
    fn hierarchical_beats_flat_at_scale_with_growing_ratio() {
        // The fig_coll_scale acceptance shape at estimator level: ≥2× at
        // 64 PEs / 1 MiB, ratio non-decreasing as the machine grows.
        for op in [CollOp::Broadcast, CollOp::Fcollect, CollOp::Reduce] {
            let mut last_ratio = 0.0f64;
            for npes in [64usize, 256, 1024] {
                let (topo, shape) = shape_for(npes);
                let m = CostModel::new(topo, CostParams::default());
                let est = m.coll_estimates(&shape, op, 1 << 20, 2);
                let (_, hier_ns) = est.best_hier();
                let ratio = est.flat_ns / hier_ns;
                assert!(
                    ratio >= 2.0,
                    "{op:?} at {npes} PEs: flat/hier = {ratio} < 2"
                );
                assert!(
                    ratio >= last_ratio * 0.999,
                    "{op:?}: ratio fell {last_ratio} → {ratio} at {npes} PEs"
                );
                last_ratio = ratio;
            }
        }
    }

    #[test]
    fn single_node_teams_select_flat() {
        let m = model();
        let shape = CollShape::from_members(&m.topo, 0..12);
        assert!(shape.single_node());
        // The runtime gate short-circuits on single_node(); the estimator
        // itself also never prefers a hierarchy with no wire stage to
        // collapse (remote byte volume is zero → flat has no wire term).
        let est = m.coll_estimates(&shape, CollOp::Broadcast, 1 << 20, 2);
        assert_eq!(est.best().0, CollAlgo::Flat, "{est:?}");
    }

    #[test]
    fn coll_estimates_snapshot_variant_matches_wrapper_and_recomputes() {
        let (topo, shape) = shape_for(64);
        let m = CostModel::new(topo, CostParams::default());
        let l = m.model.get();
        for op in [CollOp::Broadcast, CollOp::Fcollect, CollOp::Reduce] {
            let a = m.coll_estimates_at(&l, &shape, op, 1 << 20, 4);
            let b = m.coll_estimates(&shape, op, 1 << 20, 4);
            assert_eq!(a.flat_ns.to_bits(), b.flat_ns.to_bits());
            assert_eq!(a.ring_ns.to_bits(), b.ring_ns.to_bits());
            assert_eq!(a.tree_ns.to_bits(), b.tree_ns.to_bits());
        }
        // A calibration apply that slows the rails moves every wire-bound
        // estimate; the held snapshot keeps pricing the old generation.
        let before = m.coll_estimates(&shape, CollOp::Broadcast, 1 << 20, 2);
        m.model.update(|lp| lp.rail_bw_frac *= 0.5);
        let after = m.coll_estimates(&shape, CollOp::Broadcast, 1 << 20, 2);
        assert!(after.ring_ns > before.ring_ns);
        let held = m.coll_estimates_at(&l, &shape, CollOp::Broadcast, 1 << 20, 2);
        assert_eq!(held.ring_ns.to_bits(), before.ring_ns.to_bits());
    }

    #[test]
    fn fetch_atomic_cost_grows_with_distance() {
        let m = model();
        assert!(
            m.fetch_atomic_ns(Locality::SameTile) < m.fetch_atomic_ns(Locality::SameGpu)
        );
        assert!(
            m.fetch_atomic_ns(Locality::SameGpu) < m.fetch_atomic_ns(Locality::SameNode)
        );
        assert!(
            m.fetch_atomic_ns(Locality::SameNode) < m.fetch_atomic_ns(Locality::Remote)
        );
    }
}
