//! Xe-Link fabric model (paper §III-B).
//!
//! Xe-Link lets individual GPU threads issue loads/stores/atomics into
//! another GPU's memory. Key behaviours the paper leans on:
//!   * single-thread load/store has very low latency but limited bandwidth;
//!   * many threads storing simultaneously approach link bandwidth (at the
//!     cost of burning compute threads — the work_group trade-off);
//!   * remote atomics are pipelined fire-and-forget (the "push" sync).
//!
//! The model: a transfer of `bytes` issued by `work_items` parallel lanes
//! costs `issue_latency + bytes / min(items * per_item_rate, link_bw)`.

use super::topology::Locality;

#[derive(Clone, Debug)]
pub struct XeLinkParams {
    /// Per-link unidirectional bandwidth, GB/s (cross-GPU).
    pub link_bw_gbs: f64,
    /// MDFI cross-tile bandwidth within one GPU, GB/s.
    pub mdfi_bw_gbs: f64,
    /// Same-tile HBM copy bandwidth (read+write), GB/s.
    pub hbm_bw_gbs: f64,
    /// Sustained vector-store rate of a single work-item, GB/s (cross-GPU).
    pub per_item_rate_gbs: f64,
    /// Same-tile per-item rate (no link in the way), GB/s.
    pub per_item_local_rate_gbs: f64,
    /// First-byte latency of a remote store, ns.
    pub store_latency_ns: f64,
    /// Issue cost of one pipelined remote atomic, ns (fire-and-forget).
    pub atomic_issue_ns: f64,
    /// Completion latency of a fetching atomic (round trip), ns.
    pub atomic_fetch_ns: f64,
    /// Fraction of peak path bandwidth that thread stores can sustain
    /// (address generation / scoreboarding overhead). The copy engines
    /// sustain the full rate — this gap is why a cutover exists even for
    /// 1024 work-items (paper Fig 4a vs 4b).
    pub loadstore_efficiency: f64,
}

impl Default for XeLinkParams {
    fn default() -> Self {
        // Calibration: DESIGN.md §6. Public PVC Xe-Link figures and the
        // paper's curve crossovers, not measured silicon.
        XeLinkParams {
            link_bw_gbs: 25.0,
            mdfi_bw_gbs: 180.0,
            hbm_bw_gbs: 1000.0,
            per_item_rate_gbs: 0.8,
            per_item_local_rate_gbs: 2.2,
            store_latency_ns: 500.0,
            atomic_issue_ns: 80.0,
            atomic_fetch_ns: 900.0,
            loadstore_efficiency: 0.85,
        }
    }
}

impl XeLinkParams {
    /// Peak bandwidth of the load/store path for a locality class.
    pub fn path_bw_gbs(&self, loc: Locality) -> f64 {
        match loc {
            Locality::SameTile => self.hbm_bw_gbs / 2.0, // read + write share HBM
            Locality::SameGpu => self.mdfi_bw_gbs,
            Locality::SameNode => self.link_bw_gbs,
            Locality::Remote => 0.0, // unreachable by load/store
        }
    }

    /// Aggregate store rate of `items` cooperating work-items on this path.
    ///
    /// Linear scaling until the store-path ceiling; the ceiling itself
    /// grows mildly with occupancy (more outstanding stores hide more
    /// latency), which keeps 128 vs 1024 work-items distinct at large
    /// sizes — the Fig 4(a) ordering.
    pub fn items_rate_gbs(&self, loc: Locality, items: usize) -> f64 {
        let items = items.max(1);
        let per_item = match loc {
            Locality::SameTile | Locality::SameGpu => self.per_item_local_rate_gbs,
            Locality::SameNode => self.per_item_rate_gbs,
            Locality::Remote => return 0.0,
        };
        let occupancy = 0.75 + 0.25 * (items as f64 / 1024.0).min(1.0);
        let ceiling = self.path_bw_gbs(loc) * self.loadstore_efficiency * occupancy;
        (items as f64 * per_item).min(ceiling)
    }

    /// Modeled duration of a load/store transfer (ns).
    pub fn loadstore_ns(&self, loc: Locality, bytes: usize, items: usize) -> f64 {
        assert!(loc != Locality::Remote, "load/store cannot cross nodes");
        let rate = self.items_rate_gbs(loc, items);
        let latency = match loc {
            Locality::SameTile => self.store_latency_ns * 0.25,
            Locality::SameGpu => self.store_latency_ns * 0.6,
            _ => self.store_latency_ns,
        };
        latency + bytes as f64 / rate
    }

    /// `n` pipelined fire-and-forget remote atomics (the "push" sync).
    pub fn pipelined_atomics_ns(&self, n: usize) -> f64 {
        self.atomic_issue_ns * n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_items_more_bandwidth_until_saturation() {
        let p = XeLinkParams::default();
        let r1 = p.items_rate_gbs(Locality::SameNode, 1);
        let r16 = p.items_rate_gbs(Locality::SameNode, 16);
        let r128 = p.items_rate_gbs(Locality::SameNode, 128);
        let r1024 = p.items_rate_gbs(Locality::SameNode, 1024);
        assert!(r1 < r16 && r16 < r128, "{r1} {r16} {r128}");
        // Saturated groups still order by occupancy (Fig 4a: 1024 > 128),
        // and thread stores never reach the engines' full link rate.
        assert!(r128 < r1024, "{r128} !< {r1024}");
        assert!(r1024 < p.link_bw_gbs);
    }

    #[test]
    fn small_transfer_latency_dominated() {
        let p = XeLinkParams::default();
        let t8 = p.loadstore_ns(Locality::SameNode, 8, 1);
        let t16 = p.loadstore_ns(Locality::SameNode, 16, 1);
        // Latency dominates: doubling bytes barely moves the time.
        assert!((t16 - t8) / t8 < 0.05);
    }

    #[test]
    fn locality_ordering() {
        let p = XeLinkParams::default();
        let same_tile = p.loadstore_ns(Locality::SameTile, 1 << 20, 1024);
        let same_gpu = p.loadstore_ns(Locality::SameGpu, 1 << 20, 1024);
        let cross_gpu = p.loadstore_ns(Locality::SameNode, 1 << 20, 1024);
        assert!(same_tile < same_gpu && same_gpu < cross_gpu);
    }

    #[test]
    #[should_panic]
    fn loadstore_cannot_cross_nodes() {
        XeLinkParams::default().loadstore_ns(Locality::Remote, 64, 1);
    }
}
