//! Hardware substrate: a parametric model of an Aurora-class compute node.
//!
//! The paper's testbed (Borealis) is 2× SPR CPUs + 6× PVC GPUs (2 tiles
//! each), fully connected by Xe-Link, with 8 Slingshot NICs. None of that
//! hardware exists here, so this module provides the *substitute substrate*
//! (DESIGN.md §2): real shared-memory data movement (each PE owns a real
//! heap region; remote stores are real `memcpy`/atomics — the moral
//! equivalent of the paper's unified GPU address space), plus an analytic
//! **cost model** that assigns every transfer a modeled duration from
//! first-order hardware constants (link bandwidth, per-thread store rate,
//! copy-engine startup, ring RTT). Bandwidth figures are computed from the
//! modeled durations; correctness is always checked on the real bytes.

pub mod clock;
pub mod copyengine;
pub mod cost;
pub mod fault;
pub mod memory;
pub mod nic;
pub mod params;
pub mod pcie;
pub mod rail;
pub mod topology;
pub mod xelink;

pub use clock::SimClock;
pub use cost::{CollAlgo, CollEstimates, CollOp, CollShape, CostModel, CostParams};
pub use fault::{
    bounded_poll, DegradedError, DegradedKind, DegradedScope, FaultAction, FaultConfig, FaultEvent,
    FaultPlane, LaneRef, TransientEvent, TransientKind,
};
pub use memory::{HeapRegistry, SymHeap};
pub use params::{LearnedParams, ModelParams, ParamsSnapshot};
pub use rail::RailSet;
pub use topology::{Locality, PeId, Topology};
