//! Virtual time accounting.
//!
//! Every modeled hardware action charges nanoseconds to a `SimClock`. Each
//! PE thread owns one clock; the figure harness reads `elapsed_ns` around an
//! operation to compute modeled bandwidth/latency exactly the way the
//! paper's SYCL profiling (`enable_profiling`) reads event timestamps.
//!
//! Clocks are plain accumulators (no global ordering): OpenSHMEM one-sided
//! semantics mean the initiator pays the cost of an operation, and the
//! paper's micro-benchmarks are all initiator-timed.

use std::cell::Cell;

#[derive(Debug, Default)]
pub struct SimClock {
    ns: Cell<f64>,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { ns: Cell::new(0.0) }
    }

    /// Charge `ns` nanoseconds of modeled time.
    #[inline]
    pub fn advance(&self, ns: f64) {
        debug_assert!(ns >= 0.0, "negative time charge: {ns}");
        self.ns.set(self.ns.get() + ns);
    }

    #[inline]
    pub fn now_ns(&self) -> f64 {
        self.ns.get()
    }

    pub fn reset(&self) {
        self.ns.set(0.0);
    }

    /// Elapsed time of `f` on this clock.
    pub fn time<R>(&self, f: impl FnOnce() -> R) -> (R, f64) {
        let t0 = self.now_ns();
        let r = f();
        (r, self.now_ns() - t0)
    }
}

/// GB/s from bytes moved in `ns` modeled nanoseconds.
pub fn gib_per_s(bytes: usize, ns: f64) -> f64 {
    if ns <= 0.0 {
        return f64::INFINITY;
    }
    bytes as f64 / ns // bytes/ns == GB/s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let c = SimClock::new();
        c.advance(10.0);
        c.advance(5.5);
        assert!((c.now_ns() - 15.5).abs() < 1e-12);
        c.reset();
        assert_eq!(c.now_ns(), 0.0);
    }

    #[test]
    fn times_closures() {
        let c = SimClock::new();
        let (v, dt) = c.time(|| {
            c.advance(42.0);
            "ok"
        });
        assert_eq!(v, "ok");
        assert!((dt - 42.0).abs() < 1e-12);
    }

    #[test]
    fn bandwidth_units() {
        // 1 GiB-ish: 1e9 bytes in 1e9 ns (1 s) = 1 GB/s.
        assert!((gib_per_s(1_000_000_000, 1e9) - 1.0).abs() < 1e-9);
    }
}
