//! Fault injection & degraded mode (ISSUE 8).
//!
//! The cutover claim — pick load/store vs copy-engine vs NIC per
//! configuration — silently assumes every lane stays healthy. A production
//! machine loses NIC rails, copy engines, and whole PEs; without a health
//! plane a single dead rail mis-prices every remote plan forever. This
//! module is the injection side of that plane:
//!
//! * [`FaultConfig`] — the `fault.*` knob surface: a master `enable`
//!   switch (default **off**: a disabled plane never touches the cost
//!   model, so planning stays bit-for-bit identical to the pre-fault
//!   code), detection thresholds for the calibrator-as-detector
//!   (`xfer::calibrate`), and a script of [`FaultEvent`]s to fire at
//!   given proxy op counts.
//! * [`FaultPlane`] — applies the script: the proxy ticks it once per
//!   serviced descriptor ([`FaultPlane::tick_op`]), due events flip lane
//!   liveness in the [`super::cost::CostModel`] (which bumps its health
//!   generation → plan caches flush → new plans re-stripe onto
//!   survivors), and the applied-transition summary flows back so the
//!   caller can count kills/revives into its metrics. `sim` stays
//!   metrics-free; the layers that own `Metrics` do the counting.
//! * [`DegradedError`] — the structured error the collective decision
//!   registry and sync paths return when a peer never shows up within
//!   the configured deadline, instead of spinning forever.

use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use super::cost::CostModel;

/// One scripted lane transition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    KillRail { node: usize, rail: usize },
    ReviveRail { node: usize, rail: usize },
    KillEngine { gpu: usize, engine: usize },
    ReviveEngine { gpu: usize, engine: usize },
}

/// A scripted transition firing once the proxy has serviced `at_op`
/// descriptors (0 = before the first op).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub at_op: u64,
    pub action: FaultAction,
}

impl FaultEvent {
    pub fn kill_rail(at_op: u64, node: usize, rail: usize) -> Self {
        FaultEvent { at_op, action: FaultAction::KillRail { node, rail } }
    }

    pub fn revive_rail(at_op: u64, node: usize, rail: usize) -> Self {
        FaultEvent { at_op, action: FaultAction::ReviveRail { node, rail } }
    }

    pub fn kill_engine(at_op: u64, gpu: usize, engine: usize) -> Self {
        FaultEvent { at_op, action: FaultAction::KillEngine { gpu, engine } }
    }

    pub fn revive_engine(at_op: u64, gpu: usize, engine: usize) -> Self {
        FaultEvent { at_op, action: FaultAction::ReviveEngine { gpu, engine } }
    }
}

/// The `fault.*` knob surface (validated in `ishmem::config`).
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Master switch. Off (the default) means the plane never ticks,
    /// never applies events, and the calibrator never quarantines —
    /// planning is bit-for-bit identical to the pre-fault code.
    pub enable: bool,
    /// Calibrator-as-detector threshold: a rail whose learned per-rail
    /// bandwidth EMA collapses below `detect_frac` × the mean of its
    /// peers is quarantined (killed). Must lie in (0, 1) exclusive.
    pub detect_frac: f64,
    /// Minimum per-rail observations before the detector may judge a
    /// rail (both the suspect and its peers).
    pub detect_min_samples: u64,
    /// Revival probing: after this many further observations on the same
    /// node, a quarantined rail is probationally revived — if it is
    /// still collapsed the detector re-kills it on the next judgment.
    pub probe_after: u64,
    /// Scripted transitions, fired by proxy op count.
    pub events: Vec<FaultEvent>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            enable: false,
            detect_frac: 0.35,
            detect_min_samples: 48,
            probe_after: 512,
            events: Vec::new(),
        }
    }
}

/// The fault-injection plane: owns the event script, ticks with the
/// proxy's serviced-op count, and flips lane liveness in the shared
/// [`CostModel`].
#[derive(Debug)]
pub struct FaultPlane {
    cost: Arc<CostModel>,
    cfg: FaultConfig,
    /// Serviced-op counter (only advanced while enabled).
    ops: AtomicU64,
    /// Cursor into the (sorted) event script; events are claimed by CAS
    /// so concurrent proxy threads fire each exactly once.
    next_event: AtomicUsize,
}

impl FaultPlane {
    /// Build a plane over the shared cost model. The event script is
    /// sorted by `at_op` (stable, so same-op events keep their written
    /// order).
    pub fn new(cost: Arc<CostModel>, mut cfg: FaultConfig) -> Arc<Self> {
        cfg.events.sort_by_key(|e| e.at_op);
        Arc::new(FaultPlane {
            cost,
            cfg,
            ops: AtomicU64::new(0),
            next_event: AtomicUsize::new(0),
        })
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enable
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    pub fn cost(&self) -> &Arc<CostModel> {
        &self.cost
    }

    /// Ops ticked so far (0 forever while disabled).
    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Acquire)
    }

    /// Tick one serviced op and fire any due scripted events. Returns the
    /// applied transitions — lane indices included, so the caller can
    /// maintain per-slot health gauges — empty when nothing changed
    /// (including the fast path of a disabled plane, which does not even
    /// count the op; `Vec::new` never allocates).
    pub fn tick_op(&self) -> Vec<FaultAction> {
        if !self.cfg.enable {
            return Vec::new();
        }
        let op = self.ops.fetch_add(1, Ordering::AcqRel) + 1;
        let mut applied = Vec::new();
        loop {
            let i = self.next_event.load(Ordering::Acquire);
            if i >= self.cfg.events.len() || self.cfg.events[i].at_op > op {
                break;
            }
            if self
                .next_event
                .compare_exchange(i, i + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                if let Some(a) = self.apply(self.cfg.events[i].action) {
                    applied.push(a);
                }
            }
        }
        applied
    }

    /// Apply one action directly (CLI / tests / the detector's revival
    /// probe). Returns the action iff it was a real transition.
    pub fn apply(&self, action: FaultAction) -> Option<FaultAction> {
        let t = match action {
            FaultAction::KillRail { node, rail } => self.cost.kill_rail(node, rail),
            FaultAction::ReviveRail { node, rail } => self.cost.revive_rail(node, rail),
            FaultAction::KillEngine { gpu, engine } => self.cost.kill_engine(gpu, engine),
            FaultAction::ReviveEngine { gpu, engine } => self.cost.revive_engine(gpu, engine),
        };
        t.then_some(action)
    }
}

/// Why a collective wait gave up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradedKind {
    /// The per-(team, epoch) decision registry never saw the leader's
    /// published algorithm within the deadline.
    DecisionTimeout,
    /// A team sync round never saw every peer arrive within the deadline.
    SyncTimeout,
}

/// Structured degraded-mode error: a collective wait exceeded its
/// configured deadline (PE churn / a dead peer), instead of spinning the
/// thread forever.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DegradedError {
    pub kind: DegradedKind,
    /// Team the wait belonged to.
    pub team: usize,
    /// Collective epoch (per-team op sequence number) of the wait.
    pub epoch: u64,
    /// PE that gave up waiting.
    pub pe: usize,
    /// How long it waited before giving up, ms.
    pub waited_ms: u64,
}

impl fmt::Display for DegradedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let what = match self.kind {
            DegradedKind::DecisionTimeout => "collective decision",
            DegradedKind::SyncTimeout => "team sync",
        };
        write!(
            f,
            "degraded mode: {what} timed out after {}ms (team {}, epoch {}, pe {}) — \
             a peer died or churned out mid-collective",
            self.waited_ms, self.team, self.epoch, self.pe
        )
    }
}

impl std::error::Error for DegradedError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::CostParams;
    use crate::sim::topology::Topology;

    fn cost() -> Arc<CostModel> {
        CostModel::new(Topology::default(), CostParams::default())
    }

    #[test]
    fn disabled_plane_never_ticks_or_applies() {
        let c = cost();
        let cfg = FaultConfig {
            events: vec![FaultEvent::kill_rail(0, 0, 1)],
            ..FaultConfig::default()
        };
        assert!(!cfg.enable, "fault injection must default off");
        let plane = FaultPlane::new(Arc::clone(&c), cfg);
        for _ in 0..10 {
            assert!(plane.tick_op().is_empty());
        }
        assert_eq!(plane.ops(), 0);
        assert_eq!(c.health_generation(), 0);
        assert!(c.rail_is_live(0, 1));
    }

    #[test]
    fn scripted_events_fire_once_at_their_op() {
        let c = cost();
        let cfg = FaultConfig {
            enable: true,
            // Deliberately unsorted: revival at op 5, kills at 2 and 3.
            events: vec![
                FaultEvent::revive_rail(5, 0, 1),
                FaultEvent::kill_engine(3, 0, 0),
                FaultEvent::kill_rail(2, 0, 1),
            ],
            ..FaultConfig::default()
        };
        let plane = FaultPlane::new(Arc::clone(&c), cfg);
        assert!(plane.tick_op().is_empty(), "op 1: nothing due");
        let a = plane.tick_op();
        assert_eq!(a, vec![FaultAction::KillRail { node: 0, rail: 1 }], "op 2");
        assert!(!c.rail_is_live(0, 1));
        let a = plane.tick_op();
        assert_eq!(a, vec![FaultAction::KillEngine { gpu: 0, engine: 0 }], "op 3");
        assert!(!c.engine_is_live(0, 0));
        assert!(plane.tick_op().is_empty(), "op 4: nothing due");
        let a = plane.tick_op();
        assert_eq!(a, vec![FaultAction::ReviveRail { node: 0, rail: 1 }], "op 5");
        assert!(c.rail_is_live(0, 1));
        assert!(plane.tick_op().is_empty(), "script exhausted");
        assert_eq!(plane.ops(), 6);
        // Engine kill + rail kill + rail revive = 3 transitions.
        assert_eq!(c.health_generation(), 3);
    }

    #[test]
    fn direct_apply_reports_transitions_only() {
        let c = cost();
        let plane = FaultPlane::new(
            Arc::clone(&c),
            FaultConfig { enable: true, ..FaultConfig::default() },
        );
        let kill = FaultAction::KillRail { node: 0, rail: 2 };
        assert_eq!(plane.apply(kill), Some(kill));
        assert_eq!(plane.apply(kill), None, "re-kill is not a transition");
        let revive = FaultAction::ReviveRail { node: 0, rail: 2 };
        assert_eq!(plane.apply(revive), Some(revive));
        assert_eq!(plane.apply(revive), None);
    }

    #[test]
    fn degraded_error_is_structured_and_displayable() {
        let e = DegradedError {
            kind: DegradedKind::DecisionTimeout,
            team: 3,
            epoch: 17,
            pe: 5,
            waited_ms: 250,
        };
        let msg = e.to_string();
        assert!(msg.contains("collective decision"), "{msg}");
        assert!(msg.contains("team 3") && msg.contains("epoch 17"), "{msg}");
        let s = DegradedError { kind: DegradedKind::SyncTimeout, ..e };
        assert!(s.to_string().contains("team sync"));
    }
}
