//! Fault injection & degraded mode (ISSUE 8).
//!
//! The cutover claim — pick load/store vs copy-engine vs NIC per
//! configuration — silently assumes every lane stays healthy. A production
//! machine loses NIC rails, copy engines, and whole PEs; without a health
//! plane a single dead rail mis-prices every remote plan forever. This
//! module is the injection side of that plane:
//!
//! * [`FaultConfig`] — the `fault.*` knob surface: a master `enable`
//!   switch (default **off**: a disabled plane never touches the cost
//!   model, so planning stays bit-for-bit identical to the pre-fault
//!   code), detection thresholds for the calibrator-as-detector
//!   (`xfer::calibrate`), and a script of [`FaultEvent`]s to fire at
//!   given proxy op counts.
//! * [`FaultPlane`] — applies the script: the proxy ticks it once per
//!   serviced descriptor ([`FaultPlane::tick_op`]), due events flip lane
//!   liveness in the [`super::cost::CostModel`] (which bumps its health
//!   generation → plan caches flush → new plans re-stripe onto
//!   survivors), and the applied-transition summary flows back so the
//!   caller can count kills/revives into its metrics. `sim` stays
//!   metrics-free; the layers that own `Metrics` do the counting.
//! * [`DegradedError`] — the structured error the collective decision
//!   registry and sync paths return when a peer never shows up within
//!   the configured deadline, instead of spinning forever.
//!
//! ISSUE 9 widens the plane from *permanent* lane transitions to
//! *transient* per-chunk anomalies:
//!
//! * [`TransientEvent`] — a scripted window `[from_op, until_op]` on the
//!   same proxy op clock in which every `period`-th serviced data entry
//!   (optionally filtered by payload size and lane) is dropped, corrupted,
//!   or delayed ([`TransientKind`]). Drop/corrupt surface as proxy NACKs
//!   that the initiator's replay loop retries from the retained staging
//!   slab; delay charges extra nanoseconds to the lane clock.
//! * Strike ledger — repeat transient offenders escalate: once a lane
//!   accumulates `retry.escalate_strikes` consecutive faulted chunks it is
//!   handed to the PR 8 quarantine machinery (rails through the
//!   calibrator's probation bookkeeping, engines as a direct kill).
//! * [`DegradedScope`]/[`bounded_poll`] — the deadline machinery grows a
//!   p2p face: blocking ops, quiet/fence drains, and slab-reclaim waits
//!   poll under `xfer.op_timeout_ms` and surface a structured
//!   [`DegradedError`] naming the op, route, lane, and attempt count.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::cost::CostModel;

/// One scripted lane transition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    KillRail { node: usize, rail: usize },
    ReviveRail { node: usize, rail: usize },
    KillEngine { gpu: usize, engine: usize },
    ReviveEngine { gpu: usize, engine: usize },
}

/// A scripted transition firing once the proxy has serviced `at_op`
/// descriptors (0 = before the first op).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub at_op: u64,
    pub action: FaultAction,
}

impl FaultEvent {
    pub fn kill_rail(at_op: u64, node: usize, rail: usize) -> Self {
        FaultEvent { at_op, action: FaultAction::KillRail { node, rail } }
    }

    pub fn revive_rail(at_op: u64, node: usize, rail: usize) -> Self {
        FaultEvent { at_op, action: FaultAction::ReviveRail { node, rail } }
    }

    pub fn kill_engine(at_op: u64, gpu: usize, engine: usize) -> Self {
        FaultEvent { at_op, action: FaultAction::KillEngine { gpu, engine } }
    }

    pub fn revive_engine(at_op: u64, gpu: usize, engine: usize) -> Self {
        FaultEvent { at_op, action: FaultAction::ReviveEngine { gpu, engine } }
    }
}

/// What a transient event does to the data entry it fires on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransientKind {
    /// The proxy never dispatches the chunk: NACK, payload stays in the
    /// initiator's staging slab for replay.
    DropChunk,
    /// The chunk's payload checksum verification is forced to fail (the
    /// slab bytes themselves are left pristine — the slab *is* the replay
    /// source, so real mutation would poison every retry): NACK + replay.
    CorruptChunk,
    /// The chunk dispatches, but its lane clock is charged `delay_ns`
    /// extra (a fabric hiccup). No NACK; the wall-time observation is
    /// discarded so the calibrator never learns the inflated sample.
    DelayChunk { delay_ns: u64 },
}

/// A scripted *transient* anomaly window on the proxy op clock. Within
/// `[from_op, until_op]` (inclusive; `u64::MAX` = forever), every
/// `period`-th eligible data entry fires the kind — period 20 models a
/// deterministic 5% loss rate. Size and lane filters narrow eligibility.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransientEvent {
    pub kind: TransientKind,
    pub from_op: u64,
    pub until_op: u64,
    /// Fire when `(op - from_op) % period == 0`; must be ≥ 1. Period 1
    /// faults every eligible entry (a permanently-dropping lane).
    pub period: u64,
    /// Payload-size eligibility window, bytes (inclusive).
    pub min_bytes: u64,
    pub max_bytes: u64,
    /// Restrict to one lane slot (engine hint / rail hint); `None` = any.
    pub lane: Option<usize>,
}

impl TransientEvent {
    fn new(kind: TransientKind, from_op: u64, until_op: u64, period: u64) -> Self {
        TransientEvent {
            kind,
            from_op,
            until_op,
            period: period.max(1),
            min_bytes: 0,
            max_bytes: u64::MAX,
            lane: None,
        }
    }

    pub fn drop_chunk(from_op: u64, until_op: u64, period: u64) -> Self {
        Self::new(TransientKind::DropChunk, from_op, until_op, period)
    }

    pub fn corrupt_chunk(from_op: u64, until_op: u64, period: u64) -> Self {
        Self::new(TransientKind::CorruptChunk, from_op, until_op, period)
    }

    pub fn delay_chunk(from_op: u64, until_op: u64, period: u64, delay_ns: u64) -> Self {
        Self::new(TransientKind::DelayChunk { delay_ns }, from_op, until_op, period)
    }

    /// Narrow eligibility to payloads in `[min, max]` bytes.
    pub fn with_bytes(mut self, min: u64, max: u64) -> Self {
        self.min_bytes = min;
        self.max_bytes = max;
        self
    }

    /// Narrow eligibility to one lane slot.
    pub fn with_lane(mut self, lane: usize) -> Self {
        self.lane = Some(lane);
        self
    }

    /// Whether this event fires for a data entry serviced at proxy op
    /// `op` with `bytes` payload on lane slot `lane`.
    pub fn fires(&self, op: u64, bytes: u64, lane: usize) -> bool {
        op >= self.from_op
            && op <= self.until_op
            && (op - self.from_op) % self.period == 0
            && bytes >= self.min_bytes
            && bytes <= self.max_bytes
            && self.lane.map_or(true, |l| l == lane)
    }
}

/// A lane identity for the strike ledger (which physical queue keeps
/// eating transient faults).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LaneRef {
    Rail { node: usize, rail: usize },
    Engine { gpu: usize, engine: usize },
}

/// The `fault.*` knob surface (validated in `ishmem::config`).
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Master switch. Off (the default) means the plane never ticks,
    /// never applies events, and the calibrator never quarantines —
    /// planning is bit-for-bit identical to the pre-fault code.
    pub enable: bool,
    /// Calibrator-as-detector threshold: a rail whose learned per-rail
    /// bandwidth EMA collapses below `detect_frac` × the mean of its
    /// peers is quarantined (killed). Must lie in (0, 1) exclusive.
    pub detect_frac: f64,
    /// Minimum per-rail observations before the detector may judge a
    /// rail (both the suspect and its peers).
    pub detect_min_samples: u64,
    /// Revival probing: after this many further observations on the same
    /// node, a quarantined rail is probationally revived — if it is
    /// still collapsed the detector re-kills it on the next judgment.
    pub probe_after: u64,
    /// Scripted transitions, fired by proxy op count.
    pub events: Vec<FaultEvent>,
    /// Scripted transient anomaly windows (drop/corrupt/delay), matched
    /// per serviced data entry by op count, payload size, and lane.
    pub transients: Vec<TransientEvent>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            enable: false,
            detect_frac: 0.35,
            detect_min_samples: 48,
            probe_after: 512,
            events: Vec::new(),
            transients: Vec::new(),
        }
    }
}

/// The fault-injection plane: owns the event script, ticks with the
/// proxy's serviced-op count, and flips lane liveness in the shared
/// [`CostModel`].
#[derive(Debug)]
pub struct FaultPlane {
    cost: Arc<CostModel>,
    cfg: FaultConfig,
    /// Serviced-op counter (only advanced while enabled).
    ops: AtomicU64,
    /// Cursor into the (sorted) event script; events are claimed by CAS
    /// so concurrent proxy threads fire each exactly once.
    next_event: AtomicUsize,
    /// Consecutive-transient-fault counts per lane. A clean dispatch
    /// resets a lane's count; crossing `retry.escalate_strikes` hands
    /// the lane to the quarantine machinery.
    strikes: Mutex<HashMap<LaneRef, u32>>,
}

impl FaultPlane {
    /// Build a plane over the shared cost model. The event script is
    /// sorted by `at_op` (stable, so same-op events keep their written
    /// order).
    pub fn new(cost: Arc<CostModel>, mut cfg: FaultConfig) -> Arc<Self> {
        cfg.events.sort_by_key(|e| e.at_op);
        Arc::new(FaultPlane {
            cost,
            cfg,
            ops: AtomicU64::new(0),
            next_event: AtomicUsize::new(0),
            strikes: Mutex::new(HashMap::new()),
        })
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enable
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    pub fn cost(&self) -> &Arc<CostModel> {
        &self.cost
    }

    /// Ops ticked so far (0 forever while disabled).
    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Acquire)
    }

    /// Tick one serviced op and fire any due scripted events. Returns the
    /// applied transitions — lane indices included, so the caller can
    /// maintain per-slot health gauges — empty when nothing changed
    /// (including the fast path of a disabled plane, which does not even
    /// count the op; `Vec::new` never allocates).
    pub fn tick_op(&self) -> Vec<FaultAction> {
        self.tick_counted().1
    }

    /// [`Self::tick_op`], additionally returning the op number this tick
    /// landed on (0 while disabled). The proxy threads the op number into
    /// [`Self::transient_at`] so concurrent proxies can't mis-attribute
    /// another thread's tick to their own descriptor.
    pub fn tick_counted(&self) -> (u64, Vec<FaultAction>) {
        if !self.cfg.enable {
            return (0, Vec::new());
        }
        let op = self.ops.fetch_add(1, Ordering::AcqRel) + 1;
        let mut applied = Vec::new();
        loop {
            let i = self.next_event.load(Ordering::Acquire);
            if i >= self.cfg.events.len() || self.cfg.events[i].at_op > op {
                break;
            }
            if self
                .next_event
                .compare_exchange(i, i + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                if let Some(a) = self.apply(self.cfg.events[i].action) {
                    applied.push(a);
                }
            }
        }
        (op, applied)
    }

    /// The transient anomaly (if any) scripted for a data entry serviced
    /// at proxy op `op` with `bytes` payload on lane slot `lane`. First
    /// matching window wins (script order = priority). Never fires while
    /// the plane is disabled or before the first real tick (`op == 0`).
    pub fn transient_at(&self, op: u64, bytes: u64, lane: usize) -> Option<TransientKind> {
        if !self.cfg.enable || op == 0 {
            return None;
        }
        self.cfg
            .transients
            .iter()
            .find(|t| t.fires(op, bytes, lane))
            .map(|t| t.kind)
    }

    /// Whether any transient windows are scripted at all (lets the proxy
    /// skip the per-entry scan on the common healthy path).
    pub fn has_transients(&self) -> bool {
        self.cfg.enable && !self.cfg.transients.is_empty()
    }

    /// Record one transient fault against `lane`; returns the lane's new
    /// consecutive-strike count so the caller can compare it to
    /// `retry.escalate_strikes` and escalate into quarantine. The strike
    /// is mirrored into the shared [`CostModel`] ledger so the planner's
    /// stripe scans price suspect lanes pessimistically (ISSUE 10
    /// retry-aware planning) — and so the planning generation moves,
    /// flushing cached shapes priced under the old strike picture.
    pub fn note_strike(&self, lane: LaneRef) -> u32 {
        match lane {
            LaneRef::Rail { node, rail } => self.cost.note_rail_strike(node, rail),
            LaneRef::Engine { gpu, engine } => self.cost.note_engine_strike(gpu, engine),
        }
        let mut s = self.strikes.lock().unwrap();
        let n = s.entry(lane).or_insert(0);
        *n += 1;
        *n
    }

    /// A clean dispatch on `lane`: forgive its accumulated strikes
    /// (escalation is about *consecutive* failures, not lifetime totals).
    /// Mirrored into the cost-model ledger; forgiving an already-clean
    /// lane stays a planning no-op (no generation bump).
    pub fn clear_strikes(&self, lane: LaneRef) {
        match lane {
            LaneRef::Rail { node, rail } => self.cost.clear_rail_strikes(node, rail),
            LaneRef::Engine { gpu, engine } => self.cost.clear_engine_strikes(gpu, engine),
        }
        self.strikes.lock().unwrap().remove(&lane);
    }

    /// Current consecutive-strike count for `lane` (observability/tests).
    pub fn strikes(&self, lane: LaneRef) -> u32 {
        self.strikes.lock().unwrap().get(&lane).copied().unwrap_or(0)
    }

    /// Apply one action directly (CLI / tests / the detector's revival
    /// probe). Returns the action iff it was a real transition.
    pub fn apply(&self, action: FaultAction) -> Option<FaultAction> {
        let t = match action {
            FaultAction::KillRail { node, rail } => self.cost.kill_rail(node, rail),
            FaultAction::ReviveRail { node, rail } => self.cost.revive_rail(node, rail),
            FaultAction::KillEngine { gpu, engine } => self.cost.kill_engine(gpu, engine),
            FaultAction::ReviveEngine { gpu, engine } => self.cost.revive_engine(gpu, engine),
        };
        t.then_some(action)
    }
}

/// Why a deadline-bounded wait gave up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradedKind {
    /// The per-(team, epoch) decision registry never saw the leader's
    /// published algorithm within the deadline.
    DecisionTimeout,
    /// A team sync round never saw every peer arrive within the deadline.
    SyncTimeout,
    /// A p2p op's proxy completion never arrived within
    /// `xfer.op_timeout_ms` (blocking put/get, quiet/fence drain, or a
    /// slab-reclaim wait).
    OpTimeout,
    /// A NACKed batch burned through `retry.max_attempts` replays without
    /// a clean completion.
    RetryExhausted,
}

/// Where a degraded wait happened: the collective machinery (PR 8) or
/// the p2p transfer path (ISSUE 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradedScope {
    Collective {
        /// Team the wait belonged to.
        team: usize,
        /// Collective epoch (per-team op sequence number) of the wait.
        epoch: u64,
    },
    P2p {
        /// Static op name ("put", "get", "quiet", "batch-flush", …).
        op: &'static str,
        /// Static route name ("engine", "rail", "proxy", …).
        route: &'static str,
        /// Lane slot the op was bound for (0 when unknown/any).
        lane: usize,
        /// Replay attempts consumed when the wait gave up (0 = first
        /// transmission was still pending).
        attempts: u32,
    },
}

/// Structured degraded-mode error: a bounded wait exceeded its configured
/// deadline (PE churn, a dead peer, or a lane that eats every replay),
/// instead of spinning the thread forever.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DegradedError {
    pub kind: DegradedKind,
    pub scope: DegradedScope,
    /// PE that gave up waiting.
    pub pe: usize,
    /// How long it waited before giving up, ms (modeled backoff total for
    /// `RetryExhausted`).
    pub waited_ms: u64,
}

impl DegradedError {
    /// Builder for the collective scope (keeps PR 8 call sites terse).
    pub fn collective(kind: DegradedKind, team: usize, epoch: u64, pe: usize, waited_ms: u64) -> Self {
        DegradedError { kind, scope: DegradedScope::Collective { team, epoch }, pe, waited_ms }
    }

    /// Builder for the p2p scope.
    pub fn p2p(
        kind: DegradedKind,
        op: &'static str,
        route: &'static str,
        lane: usize,
        attempts: u32,
        pe: usize,
        waited_ms: u64,
    ) -> Self {
        DegradedError { kind, scope: DegradedScope::P2p { op, route, lane, attempts }, pe, waited_ms }
    }
}

impl fmt::Display for DegradedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match (self.kind, self.scope) {
            (kind, DegradedScope::Collective { team, epoch }) => {
                let what = match kind {
                    DegradedKind::DecisionTimeout => "collective decision",
                    DegradedKind::SyncTimeout => "team sync",
                    DegradedKind::OpTimeout => "collective wait",
                    DegradedKind::RetryExhausted => "collective replay",
                };
                write!(
                    f,
                    "degraded mode: {what} timed out after {}ms (team {}, epoch {}, pe {}) — \
                     a peer died or churned out mid-collective",
                    self.waited_ms, team, epoch, self.pe
                )
            }
            (DegradedKind::RetryExhausted, DegradedScope::P2p { op, route, lane, attempts }) => {
                write!(
                    f,
                    "degraded mode: {op} on {route} lane {lane} exhausted its replay budget \
                     ({attempts} attempts, ~{}ms modeled backoff, pe {}) — \
                     the lane is eating every retry",
                    self.waited_ms, self.pe
                )
            }
            (_, DegradedScope::P2p { op, route, lane, attempts }) => {
                write!(
                    f,
                    "degraded mode: {op} on {route} lane {lane} timed out after {}ms \
                     (pe {}, {attempts} replay attempts) — \
                     the proxy never completed the op",
                    self.waited_ms, self.pe
                )
            }
        }
    }
}

impl std::error::Error for DegradedError {}

/// Poll `poll` until it yields a value or the deadline expires. Both
/// paths escalate from busy spinning to `yield_now` after 64 empty polls
/// (PE threads routinely outnumber cores; a pure spin could livelock a
/// wait whose producer is scheduled out). `timeout_ms == 0` means
/// *unbounded*: the wall clock is never consulted, preserving the
/// bit-for-bit disabled-is-identical guarantee. On expiry, `err` builds
/// the structured error from the measured wait in ms.
pub fn bounded_poll<T>(
    timeout_ms: u64,
    mut poll: impl FnMut() -> Option<T>,
    err: impl FnOnce(u64) -> DegradedError,
) -> Result<T, DegradedError> {
    let deadline =
        (timeout_ms != 0).then(|| (Instant::now(), Duration::from_millis(timeout_ms)));
    let mut spins = 0u32;
    loop {
        if let Some(v) = poll() {
            return Ok(v);
        }
        if let Some((start, limit)) = deadline {
            if start.elapsed() >= limit {
                return Err(err(start.elapsed().as_millis() as u64));
            }
        }
        spins += 1;
        if spins < 64 {
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::CostParams;
    use crate::sim::topology::Topology;

    fn cost() -> Arc<CostModel> {
        CostModel::new(Topology::default(), CostParams::default())
    }

    #[test]
    fn disabled_plane_never_ticks_or_applies() {
        let c = cost();
        let cfg = FaultConfig {
            events: vec![FaultEvent::kill_rail(0, 0, 1)],
            ..FaultConfig::default()
        };
        assert!(!cfg.enable, "fault injection must default off");
        let plane = FaultPlane::new(Arc::clone(&c), cfg);
        for _ in 0..10 {
            assert!(plane.tick_op().is_empty());
        }
        assert_eq!(plane.ops(), 0);
        assert_eq!(c.health_generation(), 0);
        assert!(c.rail_is_live(0, 1));
    }

    #[test]
    fn scripted_events_fire_once_at_their_op() {
        let c = cost();
        let cfg = FaultConfig {
            enable: true,
            // Deliberately unsorted: revival at op 5, kills at 2 and 3.
            events: vec![
                FaultEvent::revive_rail(5, 0, 1),
                FaultEvent::kill_engine(3, 0, 0),
                FaultEvent::kill_rail(2, 0, 1),
            ],
            ..FaultConfig::default()
        };
        let plane = FaultPlane::new(Arc::clone(&c), cfg);
        assert!(plane.tick_op().is_empty(), "op 1: nothing due");
        let a = plane.tick_op();
        assert_eq!(a, vec![FaultAction::KillRail { node: 0, rail: 1 }], "op 2");
        assert!(!c.rail_is_live(0, 1));
        let a = plane.tick_op();
        assert_eq!(a, vec![FaultAction::KillEngine { gpu: 0, engine: 0 }], "op 3");
        assert!(!c.engine_is_live(0, 0));
        assert!(plane.tick_op().is_empty(), "op 4: nothing due");
        let a = plane.tick_op();
        assert_eq!(a, vec![FaultAction::ReviveRail { node: 0, rail: 1 }], "op 5");
        assert!(c.rail_is_live(0, 1));
        assert!(plane.tick_op().is_empty(), "script exhausted");
        assert_eq!(plane.ops(), 6);
        // Engine kill + rail kill + rail revive = 3 transitions.
        assert_eq!(c.health_generation(), 3);
    }

    #[test]
    fn direct_apply_reports_transitions_only() {
        let c = cost();
        let plane = FaultPlane::new(
            Arc::clone(&c),
            FaultConfig { enable: true, ..FaultConfig::default() },
        );
        let kill = FaultAction::KillRail { node: 0, rail: 2 };
        assert_eq!(plane.apply(kill), Some(kill));
        assert_eq!(plane.apply(kill), None, "re-kill is not a transition");
        let revive = FaultAction::ReviveRail { node: 0, rail: 2 };
        assert_eq!(plane.apply(revive), Some(revive));
        assert_eq!(plane.apply(revive), None);
    }

    #[test]
    fn degraded_error_is_structured_and_displayable() {
        let e = DegradedError::collective(DegradedKind::DecisionTimeout, 3, 17, 5, 250);
        let msg = e.to_string();
        assert!(msg.contains("collective decision"), "{msg}");
        assert!(msg.contains("team 3") && msg.contains("epoch 17"), "{msg}");
        let s = DegradedError { kind: DegradedKind::SyncTimeout, ..e };
        assert!(s.to_string().contains("team sync"));
        // P2p scope names the op, route, lane, and attempt count.
        let p = DegradedError::p2p(DegradedKind::OpTimeout, "put", "rail", 2, 3, 7, 400);
        assert_eq!(
            p.scope,
            DegradedScope::P2p { op: "put", route: "rail", lane: 2, attempts: 3 }
        );
        let msg = p.to_string();
        assert!(msg.contains("put") && msg.contains("rail lane 2"), "{msg}");
        assert!(msg.contains("3 replay attempts"), "{msg}");
        let x = DegradedError::p2p(DegradedKind::RetryExhausted, "put", "rail", 1, 4, 0, 12);
        let msg = x.to_string();
        assert!(msg.contains("exhausted its replay budget"), "{msg}");
        assert!(msg.contains("4 attempts"), "{msg}");
    }

    #[test]
    fn transient_windows_fire_on_period_and_filters() {
        let t = TransientEvent::drop_chunk(10, 20, 5);
        // In-window period hits: 10, 15, 20.
        assert!(t.fires(10, 64, 0) && t.fires(15, 64, 3) && t.fires(20, 64, 0));
        // Off-period / out-of-window misses.
        assert!(!t.fires(11, 64, 0) && !t.fires(9, 64, 0) && !t.fires(25, 64, 0));
        // Size filter.
        let big = TransientEvent::corrupt_chunk(0, u64::MAX, 1).with_bytes(1 << 20, u64::MAX);
        assert!(big.fires(1, 1 << 20, 0) && !big.fires(1, 4096, 0));
        // Lane filter.
        let lane1 = TransientEvent::delay_chunk(0, u64::MAX, 1, 500).with_lane(1);
        assert!(lane1.fires(1, 64, 1) && !lane1.fires(1, 64, 0));
        assert_eq!(lane1.kind, TransientKind::DelayChunk { delay_ns: 500 });
        // Period 0 is clamped to 1 (fires every eligible op).
        assert_eq!(TransientEvent::drop_chunk(0, 10, 0).period, 1);
    }

    #[test]
    fn plane_transient_lookup_respects_enable_and_order() {
        let c = cost();
        let cfg = FaultConfig {
            enable: true,
            transients: vec![
                TransientEvent::drop_chunk(5, 10, 1).with_lane(0),
                TransientEvent::corrupt_chunk(5, 10, 1),
            ],
            ..FaultConfig::default()
        };
        let plane = FaultPlane::new(Arc::clone(&c), cfg);
        assert!(plane.has_transients());
        // First matching window wins: lane 0 drops, other lanes corrupt.
        assert_eq!(plane.transient_at(5, 64, 0), Some(TransientKind::DropChunk));
        assert_eq!(plane.transient_at(5, 64, 1), Some(TransientKind::CorruptChunk));
        assert_eq!(plane.transient_at(4, 64, 0), None);
        assert_eq!(plane.transient_at(0, 64, 0), None, "op 0 = disabled tick");
        // A disabled plane never fires transients.
        let off = FaultPlane::new(
            cost(),
            FaultConfig {
                transients: vec![TransientEvent::drop_chunk(0, u64::MAX, 1)],
                ..FaultConfig::default()
            },
        );
        assert!(!off.has_transients());
        assert_eq!(off.transient_at(5, 64, 0), None);
    }

    #[test]
    fn strike_ledger_counts_consecutive_and_forgives_on_success() {
        let c = cost();
        let plane = FaultPlane::new(
            Arc::clone(&c),
            FaultConfig { enable: true, ..FaultConfig::default() },
        );
        let rail = LaneRef::Rail { node: 0, rail: 1 };
        let engine = LaneRef::Engine { gpu: 0, engine: 0 };
        assert_eq!(plane.note_strike(rail), 1);
        assert_eq!(plane.note_strike(rail), 2);
        assert_eq!(plane.note_strike(engine), 1, "lanes are independent");
        // Strikes mirror into the planner's cost-model ledger.
        assert_eq!(c.max_rail_strikes(), 2);
        assert_eq!(c.max_engine_strikes(), 1);
        let g = c.planning_generation();
        plane.clear_strikes(rail);
        assert_eq!(plane.strikes(rail), 0);
        assert_eq!(plane.strikes(engine), 1);
        assert_eq!(c.max_rail_strikes(), 0, "forgiveness mirrors too");
        assert_ne!(c.planning_generation(), g, "forgiving a struck lane reprices plans");
        assert_eq!(plane.note_strike(rail), 1, "count restarts after a clean dispatch");
        plane.clear_strikes(rail);
        plane.clear_strikes(engine);
        assert_eq!(c.max_engine_strikes(), 0);
    }

    #[test]
    fn tick_counted_reports_the_op_number() {
        let plane = FaultPlane::new(
            cost(),
            FaultConfig { enable: true, ..FaultConfig::default() },
        );
        assert_eq!(plane.tick_counted().0, 1);
        assert_eq!(plane.tick_counted().0, 2);
        let off = FaultPlane::new(cost(), FaultConfig::default());
        assert_eq!(off.tick_counted(), (0, Vec::new()));
    }

    #[test]
    fn bounded_poll_returns_value_or_structured_timeout() {
        // Immediate value, bounded or not.
        assert_eq!(bounded_poll(0, || Some(7), |_| unreachable!()).unwrap(), 7);
        assert_eq!(bounded_poll(50, || Some(7), |_| unreachable!()).unwrap(), 7);
        // A never-ready poll under a short deadline surfaces the error.
        let e = bounded_poll::<()>(
            1,
            || None,
            |ms| DegradedError::p2p(DegradedKind::OpTimeout, "put", "rail", 0, 0, 3, ms),
        )
        .unwrap_err();
        assert_eq!(e.kind, DegradedKind::OpTimeout);
        assert!(e.waited_ms >= 1);
        // Eventually-ready polls succeed before the deadline.
        let mut n = 0;
        let v = bounded_poll(1_000, || { n += 1; (n > 10).then_some(n) }, |_| unreachable!());
        assert_eq!(v.unwrap(), 11);
    }
}
