//! The paper's §IV measurement methodology, on either clock domain:
//!
//! "we warm-up the execution by running a variable number of iterations
//! … We double the number of iterations until the execution time reaches
//! more than 2 ms, at which point we stop … Then, we execute 10 trial
//! iterations and take the best execution time from these."

use crate::sim::SimClock;

pub const WARMUP_TARGET_NS: f64 = 2_000_000.0; // 2 ms
pub const TRIALS: usize = 10;

#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub best_ns: f64,
    pub mean_ns: f64,
    pub trials: usize,
    pub warmup_iters: usize,
}

impl Measurement {
    /// GB/s for an op that moves `bytes` per invocation.
    pub fn bandwidth_gbs(&self, bytes: usize) -> f64 {
        bytes as f64 / self.best_ns
    }
}

/// Paper methodology against the modeled clock.
pub fn measure<F: FnMut()>(clock: &SimClock, mut op: F) -> Measurement {
    let mut iters = 1usize;
    let mut warmup = 0usize;
    loop {
        let (_, dt) = clock.time(|| {
            for _ in 0..iters {
                op();
            }
        });
        warmup += iters;
        if dt > WARMUP_TARGET_NS || iters >= (1 << 22) {
            break;
        }
        iters *= 2;
    }
    let mut best = f64::INFINITY;
    let mut sum = 0.0;
    for _ in 0..TRIALS {
        let (_, dt) = clock.time(&mut op);
        best = best.min(dt);
        sum += dt;
    }
    Measurement { best_ns: best, mean_ns: sum / TRIALS as f64, trials: TRIALS, warmup_iters: warmup }
}

/// Fixed-plan variant for *collective* ops: every team member must execute
/// the same call count or the collective deadlocks, so the adaptive
/// warm-up is replaced by a deterministic plan (documented deviation).
pub fn measure_fixed<F: FnMut()>(
    clock: &SimClock,
    warmup: usize,
    trials: usize,
    mut op: F,
) -> Measurement {
    for _ in 0..warmup {
        op();
    }
    clock.reset();
    let mut best = f64::INFINITY;
    let mut sum = 0.0;
    for _ in 0..trials {
        let (_, dt) = clock.time(&mut op);
        best = best.min(dt);
        sum += dt;
    }
    Measurement { best_ns: best, mean_ns: sum / trials as f64, trials, warmup_iters: warmup }
}

/// Paper methodology in wall-clock (for the real concurrent structures).
pub fn measure_wall<F: FnMut()>(mut op: F) -> Measurement {
    let mut iters = 1usize;
    let mut warmup = 0usize;
    loop {
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            op();
        }
        warmup += iters;
        if t0.elapsed().as_nanos() as f64 > WARMUP_TARGET_NS || iters >= (1 << 22) {
            break;
        }
        iters *= 2;
    }
    let mut best = f64::INFINITY;
    let mut sum = 0.0;
    for _ in 0..TRIALS {
        let t0 = std::time::Instant::now();
        op();
        let dt = t0.elapsed().as_nanos() as f64;
        best = best.min(dt);
        sum += dt;
    }
    Measurement { best_ns: best, mean_ns: sum / TRIALS as f64, trials: TRIALS, warmup_iters: warmup }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_doubles_until_2ms() {
        let clock = SimClock::new();
        let m = measure(&clock, || clock.advance(1000.0)); // 1 µs/op
        // Warm-up needs ≥ 2048 iterations of 1 µs to pass 2 ms.
        assert!(m.warmup_iters >= 2048, "{}", m.warmup_iters);
        assert!((m.best_ns - 1000.0).abs() < 1.0);
        assert_eq!(m.trials, TRIALS);
    }

    #[test]
    fn best_of_trials_is_min() {
        let clock = SimClock::new();
        let mut i = 0;
        let m = measure_fixed(&clock, 0, 10, || {
            i += 1;
            clock.advance(if i % 3 == 0 { 500.0 } else { 900.0 });
        });
        assert_eq!(m.best_ns, 500.0);
        assert!(m.mean_ns > 500.0);
    }

    #[test]
    fn bandwidth_units() {
        let m = Measurement { best_ns: 1000.0, mean_ns: 1000.0, trials: 1, warmup_iters: 0 };
        // 1 MB in 1 µs = 1000 GB/s.
        assert!((m.bandwidth_gbs(1_000_000) - 1000.0).abs() < 1e-9);
    }
}
