//! Generators for every evaluation figure in the paper (§IV, Figs 3–7)
//! plus the §III-D ring-buffer claims. Each returns a [`Figure`] whose
//! series mirror the paper's legends; `EXPERIMENTS.md` records the
//! shape comparison.

use std::sync::Arc;

use crate::device::WorkGroup;
use crate::ishmem::{CutoverConfig, Ishmem, IshmemConfig};
use crate::ringbuf::{CompletionPool, Message, Ring, RingOp, COMPLETION_NONE};
use crate::sim::{Locality, Topology};

use super::report::{Figure, Series};
use super::timer::{measure, measure_fixed, measure_wall};
use super::zepeer;
use super::{nelem_sweep, size_sweep};

/// Fig 3 targets: (legend, target PE) under a (1 node, 2 GPU, 2 tile)
/// topology — PE 0 is the initiator.
const FIG3_TARGETS: [(&str, usize); 3] =
    [("same-tile", 0), ("cross-tile", 1), ("cross-GPU", 2)];

fn fig3_machine() -> Arc<Ishmem> {
    let cfg = IshmemConfig {
        topology: Topology::new(1, 2, 2),
        heap_bytes: 40 << 20,
        // Deep slab: the striped pipeline double-buffers 4 MiB chunks, so
        // a 16 MiB put runs one startup per engine (the ze_peer
        // convergence regime).
        staging_slab_bytes: 9 << 20,
        ..Default::default()
    };
    Ishmem::new(cfg).expect("fig3 machine")
}

/// Fig 3(a): single-threaded `ishmem_put` bandwidth vs message size for
/// same-tile / cross-tile / cross-GPU, with the ze_peer write baseline.
pub fn fig3a() -> Figure {
    fig3(false)
}

/// Fig 3(b): `ishmem_get` + ze_peer read baseline.
pub fn fig3b() -> Figure {
    fig3(true)
}

fn fig3(get: bool) -> Figure {
    let sizes = size_sweep();
    let (id, title) = if get {
        ("fig3b", "Intra-node single-threaded Get bandwidth")
    } else {
        ("fig3a", "Intra-node single-threaded Put bandwidth")
    };
    let mut fig = Figure::new(id, title, "msg size", "GB/s");

    let ish = fig3_machine();
    let sizes2 = sizes.clone();
    let results = ish.launch(move |ctx| {
        let max = *sizes2.iter().max().unwrap();
        let buf = ctx.calloc::<u8>(max);
        let mut local = vec![0xA5u8; max];
        ctx.barrier_all();
        if ctx.pe() != 0 {
            return None;
        }
        let mut out = Vec::new();
        for (name, target) in FIG3_TARGETS {
            let mut series = Series::new(format!("ishmem {name}"));
            for &size in &sizes2 {
                let m = if get {
                    measure(&ctx.clock, || ctx.get(&mut local[..size], buf, target))
                } else {
                    measure(&ctx.clock, || ctx.put(buf, &local[..size], target))
                };
                series.push(size as f64, m.bandwidth_gbs(size));
            }
            out.push(series);
        }
        Some(out)
    });
    ish.shutdown();
    fig.series = results.into_iter().flatten().next().expect("pe0 series");

    // ze_peer overlays (engine-only baseline, no library in the path).
    let topo = Topology::new(1, 2, 2);
    for (name, target) in FIG3_TARGETS {
        let s = if get {
            zepeer::zepeer_read_series(&topo, 0, target, &sizes, &format!("ze_peer {name}"))
        } else {
            zepeer::zepeer_write_series(&topo, 0, target, &sizes, &format!("ze_peer {name}"))
        };
        fig.series.push(s);
    }
    fig
}

/// Fig 4(a): `ishmemx_put_work_group`, pure store path (cutover=Never),
/// bandwidth vs size for 1/16/128/1024 work-items, cross-GPU.
pub fn fig4a() -> Figure {
    fig4(CutoverConfig::never(), "fig4a", "work_group Put, kernel store path")
}

/// Fig 4(b): same sweep on the copy-engine path (cutover=Always) — the
/// curves collapse: engine bandwidth is work-group invariant.
pub fn fig4b() -> Figure {
    fig4(CutoverConfig::always(), "fig4b", "work_group Put, copy-engine path")
}

fn fig4(cutover: CutoverConfig, id: &str, title: &str) -> Figure {
    let sizes = size_sweep();
    let wgs = [1usize, 16, 128, 1024];
    let cfg = IshmemConfig {
        topology: Topology::new(1, 2, 2),
        heap_bytes: 40 << 20,
        staging_slab_bytes: 9 << 20,
        cutover,
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).expect("fig4 machine");
    let sizes2 = sizes.clone();
    let results = ish.launch(move |ctx| {
        let max = *sizes2.iter().max().unwrap();
        let buf = ctx.calloc::<u8>(max);
        let local = vec![0x5Au8; max];
        ctx.barrier_all();
        if ctx.pe() != 0 {
            return None;
        }
        let mut out = Vec::new();
        for wg_size in wgs {
            let wg = WorkGroup::new(wg_size);
            let mut series = Series::new(format!("{wg_size} work-items"));
            for &size in &sizes2 {
                let m = measure(&ctx.clock, || {
                    ctx.put_work_group(buf, &local[..size], 2, &wg)
                });
                series.push(size as f64, m.bandwidth_gbs(size));
            }
            out.push(series);
        }
        Some(out)
    });
    ish.shutdown();
    let mut fig = Figure::new(id, title, "msg size", "GB/s");
    fig.series = results.into_iter().flatten().next().unwrap();
    fig
}

/// Fig 5(a): work_group Put with the tuned cutover — store bandwidth for
/// small/medium, engine bandwidth past the (wg-dependent) crossover.
pub fn fig5a() -> Figure {
    let mut f = fig4(CutoverConfig::tuned(), "fig5a", "work_group Put, tuned cutover");
    f.y_label = "GB/s".into();
    f
}

/// Fig 5(a) under the adaptive cutover mode: same sweep with the online
/// learned thresholds. The measurement warm-up doubles as the adaptive
/// warm-up, so the curve should track the tuned envelope once the table
/// converges (compare with [`adaptive_cutover_report`]).
pub fn fig5_adaptive() -> Figure {
    let mut f = fig4(
        CutoverConfig::adaptive(),
        "fig5a-adaptive",
        "work_group Put, adaptive cutover",
    );
    f.y_label = "GB/s".into();
    f
}

/// Learned-vs-modeled crossover table: run an Adaptive machine through the
/// Fig 5 sweep, then dump the engine's learned table next to the `Tuned`
/// model's crossovers (the Fig 5 comparison the paper tunes by hand).
pub fn adaptive_cutover_report() -> String {
    adaptive_cutover_report_with(None, None)
}

/// [`adaptive_cutover_report`] with table persistence: `load` installs a
/// previously-saved table (`rishmem figure cutover-table --load FILE`)
/// *instead of* running the warm-up sweep — the point of persistence is
/// that the next run starts warm; `save` writes the (warmed or loaded)
/// table out after the report, always from this run's state alone.
pub fn adaptive_cutover_report_with(load: Option<&str>, save: Option<&str>) -> String {
    let sizes = size_sweep();
    // `--save` writes explicitly below rather than via `cutover.table_path`
    // — routing through the config knob would *load* any existing file at
    // that path on construction and silently seed the "fresh" warm-up.
    let cfg = IshmemConfig {
        topology: Topology::new(1, 2, 2),
        heap_bytes: 40 << 20,
        staging_slab_bytes: 9 << 20,
        cutover: CutoverConfig::adaptive(),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).expect("adaptive machine");
    let mut header = String::new();
    match load {
        Some(path) => {
            let cells = ish.xfer.adaptive_load(path).expect("load adaptive table");
            header = format!("loaded {cells} learned cells from {path}\n");
        }
        None => {
            let sizes2 = sizes.clone();
            ish.launch(move |ctx| {
                let max = *sizes2.iter().max().unwrap();
                let buf = ctx.calloc::<u8>(max);
                let local = vec![0x3Cu8; max];
                ctx.barrier_all();
                if ctx.pe() != 0 {
                    return;
                }
                // Warm-up sweep: several passes per (size, work-items)
                // bucket so the EMAs see both the store and engine
                // regimes.
                for wg_size in [1usize, 16, 128, 1024] {
                    let wg = WorkGroup::new(wg_size);
                    for &size in &sizes2 {
                        for _ in 0..4 {
                            ctx.put_work_group(buf, &local[..size], 2, &wg);
                        }
                    }
                }
            });
        }
    }
    let mut report = format!(
        "{header}{}\n{}",
        ish.xfer.adaptive_report(),
        ish.xfer.occupancy_crossover_report()
    );
    if let Some(path) = save {
        ish.xfer.adaptive_save(path).expect("save adaptive table");
        report.push_str(&format!("saved learned table to {path}\n"));
    }
    ish.shutdown();
    report
}

/// Batched-submission figure: per-op submission overhead (everything the
/// initiator pays on top of the engine transfer itself — staging, the
/// descriptor write, the amortized doorbell and drain round trip) versus
/// batch depth, for small copy-engine puts. One plan-group of `d` NBI
/// puts is flushed by one `Batch` doorbell and drained by one `quiet`;
/// depth 1 reproduces per-op submission. A second series reports ring
/// messages per op (the doorbell amortization itself).
pub fn fig_batch() -> Figure {
    const PUT_BYTES: usize = 512;
    let depths = [1usize, 2, 4, 8, 16, 32];
    let mut fig = Figure::new(
        "fig-batch",
        "batched command streams: per-op submission overhead vs batch depth",
        "batch depth",
        "ns/op",
    );
    let mut overhead = Series::new("per-op submission overhead");
    let mut msgs = Series::new("batch doorbells per op (x1000)");
    for &d in &depths {
        let cfg = IshmemConfig {
            topology: Topology::new(1, 2, 2),
            // Pin the engine route so the overhead comparison is
            // apples-to-apples at every depth.
            cutover: CutoverConfig::always(),
            max_batch_depth: d,
            ..Default::default()
        };
        let ish = Ishmem::new(cfg).expect("fig_batch machine");
        let engine_est = ish.xfer.est_copy_engine_ns(Locality::SameNode, PUT_BYTES);
        let trials = 5usize;
        let warmup = 1usize;
        let best_ns = ish.launch(move |ctx| {
            let buf = ctx.calloc::<u8>(PUT_BYTES * 32);
            ctx.barrier_all();
            if ctx.pe() != 0 {
                return None;
            }
            let data = vec![0x7Bu8; PUT_BYTES];
            // One plan-group per trial: d small NBI puts + the quiet that
            // drains the batch.
            let m = measure_fixed(&ctx.clock, warmup, trials, || {
                for i in 0..d {
                    ctx.put_nbi(buf.slice(i * PUT_BYTES, PUT_BYTES), &data, 2);
                }
                ctx.quiet();
            });
            Some(m.best_ns)
        });
        let snap = ish.metrics.snapshot();
        ish.shutdown();
        let best = best_ns.into_iter().flatten().next().expect("pe0 measurement");
        overhead.push(d as f64, (best - engine_est).max(0.0) / d as f64);
        // Batch doorbells per op over the whole run (warmup + trials).
        let ops = ((warmup + trials) * d) as f64;
        msgs.push(d as f64, snap.xfer_batches as f64 / ops * 1000.0);
    }
    fig.series.push(overhead);
    fig.series.push(msgs);
    fig
}

/// Striped-pipeline figure (ISSUE 3): large same-node put bandwidth,
/// striped chunk pipeline vs the same machine pinned to one engine
/// (`stripe_max_engines = 1`). A single blitter sustains only
/// `single_engine_frac` of the link; striping chunks across 4+ engines
/// recovers the roofline — the acceptance bar is ≥2× at ≥1 MiB.
pub fn fig_stripe() -> Figure {
    let sizes: Vec<usize> = if super::smoke() {
        vec![1 << 20, 2 << 20]
    } else {
        vec![1 << 20, 2 << 20, 4 << 20, 8 << 20]
    };
    let mut fig = Figure::new(
        "fig-stripe",
        "striped chunk pipeline: large same-node puts, striped vs single-engine",
        "msg size",
        "GB/s",
    );
    for (name, width) in [("single-engine", 1usize), ("striped", 4)] {
        let mut cost = crate::sim::cost::CostParams::default();
        cost.ce.stripe_max_engines = width;
        let cfg = IshmemConfig {
            topology: Topology::new(1, 2, 2),
            heap_bytes: 48 << 20,
            // Pin the engine route: the comparison is engine vs engine.
            cutover: CutoverConfig::always(),
            cost,
            ..Default::default()
        };
        let ish = Ishmem::new(cfg).expect("fig_stripe machine");
        let sizes2 = sizes.clone();
        let series = ish.launch(move |ctx| {
            let max = *sizes2.iter().max().unwrap();
            let buf = ctx.calloc::<u8>(max);
            let local = vec![0xEEu8; max];
            ctx.barrier_all();
            if ctx.pe() != 0 {
                return None;
            }
            let mut s = Series::new(name);
            for &size in &sizes2 {
                let m = measure(&ctx.clock, || ctx.put(buf, &local[..size], 2));
                s.push(size as f64, m.bandwidth_gbs(size));
            }
            Some(s)
        });
        let snap = ish.metrics.snapshot();
        ish.shutdown();
        fig.series.push(series.into_iter().flatten().next().unwrap());
        if width > 1 {
            assert!(
                snap.stripe_transfers > 0,
                "striped machine never chunked: {snap:?}"
            );
        }
    }
    fig
}

/// Multi-rail figure (ISSUE 4): large *remote* put bandwidth, rail-striped
/// chunk pipeline vs the same machine pinned to one NIC rail
/// (`nic.rails = 1`). One proxy-driven RDMA sequence rides one rail;
/// striping slab-staged chunks across 4 rails recovers the node's
/// aggregate injection rate — the acceptance bar is ≥2× at ≥1 MiB. A
/// third series enables ramped first chunks (`stripe.ramp_factor`), the
/// time-to-first-byte trade the fig_rail bench asserts separately.
pub fn fig_rail() -> Figure {
    let sizes: Vec<usize> = if super::smoke() {
        vec![1 << 20, 2 << 20]
    } else {
        vec![1 << 20, 2 << 20, 4 << 20, 8 << 20]
    };
    let mut fig = Figure::new(
        "fig-rail",
        "rail-striped remote puts: 4 NIC rails vs single rail",
        "msg size",
        "GB/s",
    );
    for (name, rails, ramp) in
        [("single-rail", 1usize, 1.0f64), ("4-rail", 4, 1.0), ("4-rail ramped", 4, 0.25)]
    {
        let mut cost = crate::sim::cost::CostParams::default();
        cost.nic.rails = rails;
        cost.stripe.ramp_factor = ramp;
        let cfg = IshmemConfig {
            topology: Topology::new(2, 2, 2),
            heap_bytes: 48 << 20,
            cost,
            ..Default::default()
        };
        let ish = Ishmem::new(cfg).expect("fig_rail machine");
        let sizes2 = sizes.clone();
        let series = ish.launch(move |ctx| {
            let max = *sizes2.iter().max().unwrap();
            let buf = ctx.calloc::<u8>(max);
            let local = vec![0xABu8; max];
            ctx.barrier_all();
            if ctx.pe() != 0 {
                return None;
            }
            // First PE of the second node: cross-node → Route::Nic.
            let target = ctx.topo().pes_per_node();
            let mut s = Series::new(name);
            for &size in &sizes2 {
                let m = measure(&ctx.clock, || ctx.put(buf, &local[..size], target));
                s.push(size as f64, m.bandwidth_gbs(size));
            }
            Some(s)
        });
        let snap = ish.metrics.snapshot();
        ish.shutdown();
        fig.series.push(series.into_iter().flatten().next().unwrap());
        if rails > 1 {
            assert!(snap.stripe_transfers > 0, "rail machine never chunked: {snap:?}");
            let rails_used = snap.rail_bytes.iter().filter(|&&b| b > 0).count();
            assert!(rails_used >= 2, "chunks all on one rail: {:?}", snap.rail_bytes);
        }
    }
    fig
}

/// One remote-put bandwidth sweep for the fault figure: a 2-node machine
/// with `rails` configured NIC rails, optionally with rail (0, 1) killed
/// (and revived again) at the cost-model health layer before the sweep.
fn fault_put_series(name: &str, rails: usize, kill: bool, revive: bool, sizes: &[usize]) -> Series {
    let mut cost = crate::sim::cost::CostParams::default();
    cost.nic.rails = rails;
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        cost,
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).expect("fig_fault machine");
    if kill {
        assert!(ish.cost.kill_rail(0, 1), "rail (0,1) already dead");
    }
    if revive {
        assert!(ish.cost.revive_rail(0, 1), "rail (0,1) already live");
    }
    let sizes2 = sizes.to_vec();
    let name2 = name.to_string();
    let series = ish.launch(move |ctx| {
        let max = *sizes2.iter().max().unwrap();
        let buf = ctx.calloc::<u8>(max);
        let local = vec![0xCDu8; max];
        ctx.barrier_all();
        if ctx.pe() != 0 {
            return None;
        }
        let target = ctx.topo().pes_per_node();
        let mut s = Series::new(&name2);
        for &size in &sizes2 {
            let m = measure(&ctx.clock, || ctx.put(buf, &local[..size], target));
            s.push(size as f64, m.bandwidth_gbs(size));
        }
        Some(s)
    });
    ish.shutdown();
    series.into_iter().flatten().next().unwrap()
}

/// Fault-injection figure (ISSUE 8): large remote-put bandwidth on a
/// 4-rail machine — healthy, after killing one NIC rail (plans re-stripe
/// onto the 3 survivors), against a 3-rail-configured machine (the
/// (N−1)-lane model the degraded machine must converge to), and after
/// reviving the rail (must restore the healthy series bit for bit). The
/// fig_fault bench asserts those bars.
pub fn fig_fault() -> Figure {
    let sizes: Vec<usize> = if super::smoke() {
        vec![1 << 20, 4 << 20]
    } else {
        vec![1 << 20, 2 << 20, 4 << 20, 8 << 20]
    };
    let mut fig = Figure::new(
        "fig-fault",
        "degraded-mode re-striping: rail kill vs (N-1)-rail model",
        "msg size",
        "GB/s",
    );
    for (name, rails, kill, revive) in [
        ("healthy-4rail", 4usize, false, false),
        ("degraded-3live", 4, true, false),
        ("model-3rail", 3, false, false),
        ("recovered", 4, true, true),
    ] {
        fig.series.push(fault_put_series(name, rails, kill, revive, &sizes));
    }
    fig
}

// ------------------------------------------------- retry reliability ---

/// One reliability-sweep scenario for [`fig_retry`]: a 2-node machine
/// running a fixed count of blocking remote puts per size plus a
/// get-back verification pass, optionally under one scripted transient
/// window. Beyond the goodput series the bench asserts the returned
/// invariants: payload bit-identity, the attempt-histogram ↔
/// backoff-metric identity, and the modeled retry-cost identity
/// (`faulty − clean == Σbackoff + nacks × ring_post`).
pub struct RetryScenario {
    pub series: Series,
    /// Total modeled ns across every put and get of the sweep.
    pub modeled_ns: f64,
    /// PE 0's per-attempt clean-completion histogram (index = attempt).
    pub attempt_hist: [u64; 16],
    /// Every get-back payload matched the pattern it put.
    pub payloads_ok: bool,
    /// Modeled cost of one ring doorbell post (the replay loop charges
    /// one per NACK round, on top of the backoff).
    pub ring_post_ns: f64,
    pub snapshot: crate::coordinator::metrics::MetricsSnapshot,
}

/// Sizes swept by [`fig_retry`] and its bench.
pub fn retry_sweep_sizes() -> Vec<usize> {
    if super::smoke() {
        vec![1 << 20]
    } else {
        vec![1 << 20, 4 << 20]
    }
}

/// Blocking puts issued per size in a retry scenario. A fixed count, not
/// the adaptive warm-up: the modeled totals feed exact cost identities.
/// 24 ≥ the scripted transient period (20), and PE 0's chunks occupy
/// consecutive proxy op-clock ticks after the opening barrier, so a
/// period-20 window is guaranteed at least one hit regardless of how
/// many op-clock ticks the barrier itself consumed.
pub const RETRY_PUTS_PER_SIZE: usize = 24;

/// Run one scenario (see [`RetryScenario`]). `transient` of `None` is a
/// clean run; the window is scripted on a fresh fault plane otherwise.
pub fn retry_scenario(
    name: &str,
    retry_on: bool,
    transient: Option<crate::sim::TransientEvent>,
) -> RetryScenario {
    let sizes = retry_sweep_sizes();
    let mut cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        ..Default::default()
    };
    cfg.retry.enable = retry_on;
    if let Some(t) = transient {
        cfg.fault.enable = true;
        cfg.fault.transients = vec![t];
    }
    let ish = Ishmem::new(cfg).expect("fig_retry machine");
    let ring_post_ns = ish.cost.ring_post_ns();
    let name2 = name.to_string();
    let sizes2 = sizes.clone();
    let out = ish.launch(move |ctx| {
        let max = *sizes2.iter().max().unwrap();
        let buf = ctx.calloc::<u8>(max);
        ctx.barrier_all();
        if ctx.pe() != 0 {
            return None;
        }
        let target = ctx.topo().pes_per_node();
        let mut s = Series::new(&name2);
        let mut total_ns = 0.0;
        let mut ok = true;
        let mut back = vec![0u8; max];
        for &size in &sizes2 {
            let pat: Vec<u8> = (0..size)
                .map(|i| (i as u8).wrapping_mul(31).wrapping_add(size as u8))
                .collect();
            let (_, dt) = ctx.clock.time(|| {
                for _ in 0..RETRY_PUTS_PER_SIZE {
                    ctx.put(buf, &pat, target);
                }
            });
            total_ns += dt;
            // Bit-identity check rides the same (possibly faulty) lanes
            // back: a silently lost chunk on either direction shows here.
            let (_, dt_get) = ctx.clock.time(|| ctx.get(&mut back[..size], buf, target));
            total_ns += dt_get;
            ok &= back[..size] == pat[..];
            s.push(size as f64, (RETRY_PUTS_PER_SIZE * size) as f64 / dt);
        }
        Some((s, total_ns, ctx.track.attempt_hist(), ok))
    });
    let snapshot = ish.metrics.snapshot();
    ish.shutdown();
    let (series, modeled_ns, attempt_hist, payloads_ok) =
        out.into_iter().flatten().next().expect("PE 0 result");
    RetryScenario { series, modeled_ns, attempt_hist, payloads_ok, ring_post_ns, snapshot }
}

/// Blocking put against a permanently-dropping lane: every chunk NACKs,
/// every replay NACKs again, and after `retry.max_attempts` replays the
/// op must unwind promptly with a structured [`DegradedError`] instead
/// of hanging. Returns the caught error and the wall ms the op took to
/// give up (the fig_retry bench asserts it beat `xfer.op_timeout_ms`).
///
/// [`DegradedError`]: crate::sim::DegradedError
pub fn retry_exhaustion_probe() -> (Option<crate::sim::DegradedError>, u64) {
    let mut cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        ..Default::default()
    };
    cfg.retry.enable = true;
    cfg.retry.max_attempts = 2;
    cfg.retry.backoff_base_ns = 10_000;
    cfg.fault.enable = true;
    cfg.fault.transients = vec![crate::sim::TransientEvent::drop_chunk(1, u64::MAX, 1)];
    cfg.xfer.op_timeout_ms = 2_000;
    let ish = Ishmem::new(cfg).expect("retry probe machine");
    let out = ish.launch(move |ctx| {
        let buf = ctx.calloc::<u8>(1 << 20);
        ctx.barrier_all();
        if ctx.pe() != 0 {
            return None;
        }
        let target = ctx.topo().pes_per_node();
        let data = vec![0xA5u8; 1 << 20];
        let t0 = std::time::Instant::now();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ctx.put(buf, &data, target)
        }));
        let waited_ms = t0.elapsed().as_millis() as u64;
        let err = r
            .err()
            .and_then(|p| p.downcast::<crate::sim::DegradedError>().ok())
            .map(|b| *b);
        Some((err, waited_ms))
    });
    ish.shutdown();
    out.into_iter().flatten().next().expect("PE 0 result")
}

/// Reliability figure (ISSUE 9): remote-put goodput with the retry layer
/// off (the PR 8 baseline), on over clean lanes (must be bit-identical
/// to off — checksums charge no modeled time), and on under scripted
/// ~5% chunk drops and ~5% forced corruption (period-20 windows). The
/// fig_retry bench asserts payload bit-identity, the backoff identities,
/// and the exhaustion probe on top of these series.
pub fn fig_retry() -> Figure {
    let mut fig = Figure::new(
        "fig-retry",
        "transfer reliability: goodput under transient chunk faults",
        "msg size",
        "GB/s",
    );
    for sc in retry_scenarios() {
        fig.series.push(sc.series);
    }
    fig
}

/// The four scenarios behind [`fig_retry`], with their full invariant
/// payloads (the fig_retry bench asserts on these, not just the series):
/// retry off over clean lanes (the PR 8 baseline), retry on over clean
/// lanes (must be bit-identical — checksums charge no modeled time), and
/// retry on under ~5% scripted chunk drops / forced corruption
/// (period-20 transient windows, open-ended from op 1).
pub fn retry_scenarios() -> Vec<RetryScenario> {
    vec![
        retry_scenario("retry-off-clean", false, None),
        retry_scenario("retry-on-clean", true, None),
        retry_scenario(
            "drop-5pct",
            true,
            Some(crate::sim::TransientEvent::drop_chunk(1, u64::MAX, 20)),
        ),
        retry_scenario(
            "corrupt-5pct",
            true,
            Some(crate::sim::TransientEvent::corrupt_chunk(1, u64::MAX, 20)),
        ),
    ]
}

// ------------------------------------------------- triggered chains ---

/// One [`fig_chain`] scenario: a fixed count of depth-*d* dependent
/// programs (d−1 ordered puts then a signal add) issued through the
/// [`crate::ishmem::ChainBuilder`] on a machine with chains fused
/// (`chain.enable`) or left sequential (the default). Beyond the series
/// the bench asserts the returned invariants: the consumer's landed
/// bytes (fused must be bit-identical to sequential), the exact
/// host-crossing ledger (a fused depth-*d* chain is ONE doorbell), and
/// the chain metrics.
pub struct ChainScenario {
    pub name: String,
    /// Stages per program (puts + the trailing signal).
    pub depth: usize,
    /// Dependent programs issued by PE 0.
    pub programs: usize,
    /// Ring messages the whole run spent (machine total; subtract the
    /// zero-program control scenario to isolate the programs).
    pub ring_messages: u64,
    /// PE 0's modeled ns across the program loop.
    pub modeled_ns: f64,
    /// The consumer's inbox after the run (the last program's bytes).
    pub landed: Vec<u8>,
    pub snapshot: crate::coordinator::metrics::MetricsSnapshot,
}

/// Bytes each chained put stage moves in a [`chain_scenario`].
pub const CHAIN_STAGE_BYTES: usize = 16 << 10;

/// Programs issued per scenario (shrunk under `RISHMEM_SMOKE=1`).
pub fn chain_programs() -> usize {
    if super::smoke() {
        8
    } else {
        32
    }
}

/// Deterministic per-(program, stage) payload pattern, so the landed
/// bytes identify exactly which program and stage wrote them.
pub fn chain_pattern(program: usize, stage: usize, len: usize) -> Vec<u8> {
    (0..len)
        .map(|i| {
            (i as u8)
                .wrapping_mul(31)
                .wrapping_add(program as u8)
                .wrapping_mul(2)
                .wrapping_add(stage as u8 + 1)
        })
        .collect()
}

/// Run one chain scenario: `programs` depth-`depth` dependent programs
/// from PE 0 to its cross-GPU neighbour (PE 2), engine route pinned so
/// everything batches. `fused` flips `chain.enable`; `programs == 0` is
/// the control run that measures the fixed launch overhead (barriers,
/// handshakes) in ring messages.
pub fn chain_scenario(name: &str, depth: usize, programs: usize, fused: bool) -> ChainScenario {
    use crate::ishmem::signal::SignalOp;
    use crate::ishmem::Cmp;
    assert!(depth >= 2, "a chain needs a dependency");
    let mut cfg = IshmemConfig {
        topology: Topology::new(1, 2, 2),
        heap_bytes: 48 << 20,
        cutover: CutoverConfig::always(),
        ..Default::default()
    };
    cfg.chain.enable = fused;
    cfg.chain.max_depth = depth.max(4);
    let ish = Ishmem::new(cfg).expect("fig_chain machine");
    let before = ish.metrics.snapshot().ring_messages;
    let out = ish.launch(move |ctx| {
        let len = CHAIN_STAGE_BYTES;
        let inbox = ctx.calloc::<u8>((depth - 1) * len);
        let sig = ctx.calloc::<u64>(1);
        ctx.barrier_all();
        let mut modeled = 0.0;
        if ctx.pe() == 0 {
            let (_, dt) = ctx.clock.time(|| {
                for p in 0..programs {
                    let mut c = ctx.chain();
                    for s in 0..depth - 1 {
                        c = c.put(inbox.slice(s * len, len), &chain_pattern(p, s, len), 2);
                        c = c.then();
                    }
                    c.signal(sig, 1, SignalOp::Add, 2).submit();
                }
            });
            modeled = dt;
        }
        ctx.barrier_all();
        if ctx.pe() == 2 {
            ctx.wait_until::<u64>(sig, Cmp::Ge, programs as u64);
            assert_eq!(ctx.signal_fetch(sig), programs as u64, "signal adds lost");
            Some((modeled, ctx.read_local_vec(inbox)))
        } else if ctx.pe() == 0 {
            Some((modeled, Vec::new()))
        } else {
            None
        }
    });
    let snapshot = ish.metrics.snapshot();
    let ring_messages = snapshot.ring_messages - before;
    ish.shutdown();
    let mut modeled_ns = 0.0;
    let mut landed = Vec::new();
    for (m, l) in out.into_iter().flatten() {
        modeled_ns = modeled_ns.max(m);
        if !l.is_empty() {
            landed = l;
        }
    }
    ChainScenario {
        name: name.to_string(),
        depth,
        programs,
        ring_messages,
        modeled_ns,
        landed,
        snapshot,
    }
}

/// Depths swept by [`fig_chain`] (shrunk under `RISHMEM_SMOKE=1`).
pub fn chain_depth_sweep() -> Vec<usize> {
    if super::smoke() {
        vec![2, 3]
    } else {
        vec![2, 3, 4, 6]
    }
}

/// The scenarios behind [`fig_chain`]: one zero-program control (fixed
/// launch overhead), then a fused and a sequential run per depth.
pub fn chain_scenarios() -> Vec<ChainScenario> {
    let mut out = vec![chain_scenario("control", 2, 0, true)];
    for d in chain_depth_sweep() {
        out.push(chain_scenario(&format!("fused-d{d}"), d, chain_programs(), true));
        out.push(chain_scenario(&format!("seq-d{d}"), d, chain_programs(), false));
    }
    out
}

/// Fully offloaded progress figure (ISSUE 10): host crossings per
/// dependent program vs chain depth — a fused depth-*d* chain submits
/// with ONE doorbell while the sequential spelling pays roughly one
/// crossing per stage. The fig_chain bench asserts the single-doorbell
/// identity exactly (against the control run's fixed overhead), the
/// ≥2× host-crossing reduction from depth 3, and fused-vs-sequential
/// payload bit-identity on top of this series.
pub fn fig_chain() -> Figure {
    let mut fig = Figure::new(
        "fig-chain",
        "triggered chains: host crossings per dependent program vs depth",
        "chain depth",
        "ring msgs / program",
    );
    let scenarios = chain_scenarios();
    let control = scenarios[0].ring_messages;
    let mut fused = Series::new("fused");
    let mut seq = Series::new("sequential");
    for sc in &scenarios[1..] {
        let per = sc.ring_messages.saturating_sub(control) as f64 / sc.programs.max(1) as f64;
        if sc.name.starts_with("fused") {
            fused.push(sc.depth as f64, per);
        } else {
            seq.push(sc.depth as f64, per);
        }
    }
    fig.series.push(fused);
    fig.series.push(seq);
    fig
}

/// Collective-scaling figure (ISSUE 7): modeled 1 MiB broadcast time
/// across machine sizes — the flat per-peer fan-out against the
/// hierarchical tile/GPU/node decomposition with ring and tree
/// inter-node stages, priced by the cost model's collective estimator
/// on [`Topology::multi_node_for`] machines. The fig_coll_scale bench
/// asserts the acceptance bars (best hierarchical ≥2× flat from 64 PEs
/// at ≥1 MiB, advantage non-decreasing in PE count) across all three
/// ops and validates a real 64-PE machine end to end.
pub fn fig_coll_scale() -> Figure {
    let sweep = coll_scale_sweep();
    let bytes = 1 << 20;
    let mut fig = Figure::new(
        "fig-coll-scale",
        "hierarchical collectives: flat vs leader decomposition, 1 MiB broadcast",
        "PEs",
        "modeled ms",
    );
    let mut flat = Series::new("flat");
    let mut ring = Series::new("hier-ring");
    let mut tree = Series::new("hier-tree");
    for &npes in &sweep {
        let topo = Topology::multi_node_for(npes);
        let shape = crate::sim::CollShape::from_members(&topo, 0..npes);
        let cost = crate::sim::CostModel::new(topo, crate::sim::cost::CostParams::default());
        let est = cost.coll_estimates(&shape, crate::sim::CollOp::Broadcast, bytes, 4);
        flat.push(npes as f64, est.flat_ns / 1e6);
        ring.push(npes as f64, est.ring_ns / 1e6);
        tree.push(npes as f64, est.tree_ns / 1e6);
    }
    fig.series.push(flat);
    fig.series.push(ring);
    fig.series.push(tree);
    fig
}

/// PE-count sweep shared by [`fig_coll_scale`] and its bench.
pub fn coll_scale_sweep() -> Vec<usize> {
    if super::smoke() {
        vec![64, 256]
    } else {
        vec![64, 128, 256, 512, 1024]
    }
}

/// Wall-clock vs modeled service-time comparison (`rishmem figure
/// service-delta`): run every proxied path through the size classes and
/// diff the proxy's wall sums against the cost model's charges per
/// (path, size-bucket), flagging classes off by >2×.
pub fn service_delta_report() -> String {
    let cfg = IshmemConfig {
        topology: Topology::new(2, 2, 2),
        heap_bytes: 48 << 20,
        // Pin the engine route so every same-node size class is proxied.
        cutover: CutoverConfig::always(),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).expect("service-delta machine");
    ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(4 << 20);
        ctx.barrier_all();
        if ctx.pe() == 0 {
            for size in [2 << 10, 128 << 10, 1 << 20, 4 << 20] {
                // Same-node → copy-engine rows; cross-node → NIC rows
                // (rail-striped at the larger sizes).
                ctx.put(buf, &vec![1u8; size], 2);
                ctx.put(buf, &vec![2u8; size], 4);
            }
            ctx.quiet();
        }
        ctx.barrier_all();
    });
    let report = ish.metrics.snapshot().service_delta_report();
    ish.shutdown();
    report
}

/// One closed-loop calibration sweep (ISSUE 5): outcome of
/// [`calibration_run`], consumed by `rishmem figure calibration` and the
/// `fig_calib` bench.
pub struct CalibrationRun {
    /// Mean per-class residual (|wall − model| / wall at the then-current
    /// learned params) after each round — the convergence trajectory.
    pub round_residuals: Vec<f64>,
    /// Mean residual of the *uncalibrated* (seed) model against the same
    /// observation stream — the baseline the residuals must shrink from.
    pub baseline_residual: f64,
    pub truth_engine_frac: f64,
    pub truth_rail_frac: f64,
    pub learned: crate::sim::LearnedParams,
    pub configured: crate::sim::LearnedParams,
    pub snapshot: crate::xfer::CalibrationSnapshot,
}

/// Run the closed calibration loop against a synthetic ground-truth
/// hardware model: a machine whose *real* constants differ from the
/// configured ones (single-engine fraction 2× the config, rail fraction
/// half, startups off by ~25%) emits per-(lane, size-class) wall-time
/// observations; the calibrator inverts them, EMA-refines the learnable
/// constants in `ModelParams`, and the per-class residual against the
/// learned model shrinks round over round — while the identical stream
/// against the frozen seed model stays at the baseline error. This is the
/// `figure calibration` / `fig_calib` acceptance loop; the live path
/// (proxy → calibrator) feeds the same entry points.
pub fn calibration_run() -> CalibrationRun {
    use crate::sim::cost::{CostModel, CostParams};
    use crate::xfer::{CalibConfig, Calibrator};

    let cost = CostModel::new(Topology::new(2, 2, 2), CostParams::default());
    let configured = cost.model.get();
    cost.model.seed_cl_boundary(64 << 10);
    let cal = Calibrator::new(
        cost.clone(),
        CalibConfig {
            enable: true,
            ema_alpha: 0.25,
            min_samples: 8,
            clamp_frac: 4.0,
        },
    );

    // Planted ground truth, inside the clamp's reach of the seed.
    let truth_engine_frac = configured.single_engine_frac * 2.0;
    let truth_rail_frac = configured.rail_bw_frac * 0.5;
    let truth_s_imm = configured.startup_immediate_ns * 1.25;
    let truth_s_std = configured.startup_standard_ns * 1.25;
    let truth_rail_startup = configured.rail_startup_ns * 1.5;
    let engine_roofline = cost.params.ce.path_bw_gbs(&cost.params.xe, Locality::SameNode);
    let truth_engine_ns = |bytes: usize, imm: bool| {
        (if imm { truth_s_imm } else { truth_s_std })
            + bytes as f64 / (engine_roofline * truth_engine_frac)
    };
    let truth_rail_ns = |bytes: usize| {
        truth_rail_startup + bytes as f64 / (cost.params.nic.bw_gbs * truth_rail_frac)
    };

    let sizes = [2 << 10, 16 << 10, 128 << 10, 512 << 10, 1 << 20, 4 << 20];
    // Baseline: the seed model's residual against the truth stream (what
    // an uncalibrated machine is stuck with).
    let seed_resid = |bytes: usize, imm: bool| {
        let t = truth_engine_ns(bytes, imm);
        let p = (if imm { configured.startup_immediate_ns } else { configured.startup_standard_ns })
            + bytes as f64 / (engine_roofline * configured.single_engine_frac);
        (t - p).abs() / t
    };
    let seed_rail_resid = |bytes: usize| {
        let t = truth_rail_ns(bytes);
        let p = configured.rail_startup_ns
            + bytes as f64 / (cost.params.nic.bw_gbs * configured.rail_bw_frac);
        (t - p).abs() / t
    };
    let mut baseline = 0.0;
    for &b in &sizes {
        baseline += seed_resid(b, true) + seed_resid(b, false) + seed_rail_resid(b);
    }
    let baseline_residual = baseline / (sizes.len() * 3) as f64;

    let rounds = if super::smoke() { 6 } else { 12 };
    let mut round_residuals = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        for &bytes in &sizes {
            for _ in 0..4 {
                let (t_imm, t_std) =
                    (truth_engine_ns(bytes, true), truth_engine_ns(bytes, false));
                cal.observe_engine(Locality::SameNode, bytes, true, t_imm);
                cal.observe_engine(Locality::SameNode, bytes, false, t_std);
                // Flavor evidence for the CL boundary (total per-byte cost
                // per flavor — here the truth service times themselves).
                cal.observe_cl_flavor(bytes, true, t_imm / bytes as f64);
                cal.observe_cl_flavor(bytes, false, t_std / bytes as f64);
                cal.observe_rail(0, 0, bytes, truth_rail_ns(bytes));
            }
        }
        cal.refine_cl_boundary();
        round_residuals.push(cal.snapshot().mean_residual());
    }

    CalibrationRun {
        round_residuals,
        baseline_residual,
        truth_engine_frac,
        truth_rail_frac,
        learned: cost.model.get(),
        configured,
        snapshot: cal.snapshot(),
    }
}

/// `rishmem figure calibration`: learned vs configured params, the
/// per-class residual table, and the per-round convergence trajectory.
pub fn calibration_report() -> String {
    let run = calibration_run();
    let mut out = String::from(
        "closed-loop calibration against a planted ground-truth hardware model\n",
    );
    out.push_str(&format!(
        "planted truth: single_engine_frac={:.3} (configured {:.3}), rail_bw_frac={:.3} \
         (configured {:.3})\n\n",
        run.truth_engine_frac,
        run.configured.single_engine_frac,
        run.truth_rail_frac,
        run.configured.rail_bw_frac,
    ));
    out.push_str(&run.snapshot.report());
    out.push_str(&format!(
        "\nresidual trajectory (uncalibrated baseline {:.4}):\n",
        run.baseline_residual
    ));
    for (i, r) in run.round_residuals.iter().enumerate() {
        out.push_str(&format!("  round {:>2}  {r:.4}\n", i + 1));
    }
    out
}

/// Fig 5(b): same, reported as latency (µs).
pub fn fig5b() -> Figure {
    let bw = fig4(CutoverConfig::tuned(), "fig5b", "work_group Put latency, tuned cutover");
    let mut fig = Figure::new("fig5b", bw.title.clone(), "msg size", "µs");
    for s in bw.series {
        let mut ls = Series::new(s.name);
        for (x, gbs) in s.points {
            // GB/s = bytes/ns ⇒ ns = bytes / GB/s; µs = ns / 1000.
            ls.push(x, x / gbs / 1000.0);
        }
        fig.series.push(ls);
    }
    fig
}

/// Fig 6: `ishmemx_fcollect_work_group` vs element count for 16/64/256/
/// 1024 work-items at a given PE count, vs the host-initiated copy-engine
/// baseline (dashed in the paper). `npes` ∈ {4, 8, 12}.
pub fn fig6(npes: usize) -> Figure {
    assert!(npes >= 2 && npes <= 12);
    let wgs = [16usize, 64, 256, 1024];
    let nelems = nelem_sweep();
    let cfg = IshmemConfig {
        topology: Topology::new(1, 6, 2),
        heap_bytes: 32 << 20,
        cutover: CutoverConfig::never(), // device store path
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).expect("fig6 machine");
    let nelems2 = nelems.clone();
    let results = ish.launch(move |ctx| {
        let max = *nelems2.iter().max().unwrap();
        let dest = ctx.calloc::<f32>(max * 12);
        let src = ctx.calloc::<f32>(max);
        ctx.barrier_all();
        if ctx.pe() >= npes {
            return None; // not a member of the benched team
        }
        let team = ctx.team_split_strided(crate::ishmem::TeamId::WORLD, 0, 1, npes);
        let mut out = Vec::new();
        for wg_size in wgs {
            let wg = WorkGroup::new(wg_size);
            let mut series = Series::new(format!("{wg_size} work-items"));
            for &n in &nelems2 {
                let m = measure_fixed(&ctx.clock, 1, 3, || {
                    ctx.fcollect_work_group(dest, src, n, team, &wg)
                });
                series.push(n as f64, m.bandwidth_gbs(n * 4 * (npes - 1)));
            }
            out.push(series);
        }
        // Host-initiated copy-engine baseline (paper's dashed line).
        let mut host = Series::new("host copy-engine".to_string());
        for &n in &nelems2 {
            let m = measure_fixed(&ctx.clock, 1, 3, || {
                ctx.host_fcollect(dest, src, n, team)
            });
            host.push(n as f64, m.bandwidth_gbs(n * 4 * (npes - 1)));
        }
        out.push(host);
        if ctx.pe() == 0 {
            Some(out)
        } else {
            None
        }
    });
    ish.shutdown();
    let mut fig = Figure::new(
        format!("fig6-{npes}pe"),
        format!("fcollect_work_group, {npes} PEs (store path vs host engine)"),
        "nelems",
        "GB/s",
    );
    fig.series = results.into_iter().flatten().next().unwrap();
    fig
}

/// Fig 7(a): fcollect with the **tuned** cutover at 12 PEs — the adaptive
/// policy tracks the upper envelope of Fig 6(c).
pub fn fig7a() -> Figure {
    let wgs = [16usize, 64, 256, 1024];
    let nelems = nelem_sweep();
    let cfg = IshmemConfig {
        topology: Topology::new(1, 6, 2),
        heap_bytes: 32 << 20,
        cutover: CutoverConfig::tuned(),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).expect("fig7a machine");
    let nelems2 = nelems.clone();
    let results = ish.launch(move |ctx| {
        let max = *nelems2.iter().max().unwrap();
        let dest = ctx.calloc::<f32>(max * 12);
        let src = ctx.calloc::<f32>(max);
        ctx.barrier_all();
        let team = crate::ishmem::TeamId::WORLD;
        let mut out = Vec::new();
        for wg_size in wgs {
            let wg = WorkGroup::new(wg_size);
            let mut series = Series::new(format!("{wg_size} work-items"));
            for &n in &nelems2 {
                let m = measure_fixed(&ctx.clock, 1, 3, || {
                    ctx.fcollect_work_group(dest, src, n, team, &wg)
                });
                series.push(n as f64, m.bandwidth_gbs(n * 4 * 11));
            }
            out.push(series);
        }
        let mut host = Series::new("host copy-engine".to_string());
        for &n in &nelems2 {
            let m = measure_fixed(&ctx.clock, 1, 3, || ctx.host_fcollect(dest, src, n, team));
            host.push(n as f64, m.bandwidth_gbs(n * 4 * 11));
        }
        out.push(host);
        (ctx.pe() == 0).then_some(out)
    });
    ish.shutdown();
    let mut fig = Figure::new(
        "fig7a",
        "fcollect_work_group, 12 PEs, tuned cutover",
        "nelems",
        "GB/s",
    );
    fig.series = results.into_iter().flatten().next().unwrap();
    fig
}

/// Fig 7(b): `ishmemx_broadcast_work_group` with 128 work-items, varying
/// the PE count 2…12 — 2 PEs stand out (same-GPU cross-tile, no Xe-Link).
pub fn fig7b() -> Figure {
    let nelems = nelem_sweep();
    let pe_counts = [2usize, 4, 6, 8, 10, 12];
    let cfg = IshmemConfig {
        topology: Topology::new(1, 6, 2),
        heap_bytes: 32 << 20,
        cutover: CutoverConfig::tuned(),
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).expect("fig7b machine");
    let nelems2 = nelems.clone();
    let results = ish.launch(move |ctx| {
        let max = *nelems2.iter().max().unwrap();
        let dest = ctx.calloc::<f32>(max);
        let src = ctx.calloc::<f32>(max);
        ctx.barrier_all();
        let wg = WorkGroup::new(128);
        let mut out = Vec::new();
        for &n_pes in &pe_counts {
            // Every PE must run the split so the mirrored creation
            // sequence stays aligned; only members then use the team.
            let team =
                ctx.team_split_strided(crate::ishmem::TeamId::WORLD, 0, 1, n_pes);
            if ctx.pe() >= n_pes {
                continue; // non-members sit this round out
            }
            let mut series = Series::new(format!("{n_pes} PEs"));
            for &n in &nelems2 {
                let m = measure_fixed(&ctx.clock, 1, 3, || {
                    ctx.broadcast_work_group(dest, src, n, 0, team, &wg)
                });
                // Payload bandwidth (bytes delivered per destination / time):
                // the paper's per-op view, where the 2-PE same-GPU case
                // stands out.
                series.push(n as f64, m.bandwidth_gbs(n * 4));
            }
            out.push(series);
        }
        (ctx.pe() == 0).then_some(out)
    });
    ish.shutdown();
    let mut fig = Figure::new(
        "fig7b",
        "broadcast_work_group, 128 work-items, varying PEs",
        "nelems",
        "GB/s",
    );
    fig.series = results.into_iter().flatten().next().unwrap();
    fig
}

/// §III-D ring claims, measured in *wall clock* on the real lock-free
/// ring: request throughput vs producer count, plus single-thread RTT.
pub fn ring_figure() -> Figure {
    let mut fig = Figure::new(
        "ring",
        "reverse-offload ring: real wall-clock throughput & RTT",
        "producers",
        "M req/s (throughput) / µs (rtt)",
    );

    let mut tput = Series::new("M req/s");
    for producers in [1usize, 2, 4, 8] {
        let ring = Ring::new(4096);
        let mut consumer = ring.consumer();
        const PER: u64 = 50_000;
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for p in 0..producers {
                let r = Arc::clone(&ring);
                s.spawn(move || {
                    for i in 0..PER {
                        let mut m = Message::nop();
                        m.src_pe = p as u32;
                        m.inline_val = i;
                        r.send(m);
                    }
                });
            }
            s.spawn(move || {
                for _ in 0..producers as u64 * PER {
                    consumer.recv();
                }
            });
        });
        let rate = producers as f64 * PER as f64 / t0.elapsed().as_secs_f64();
        tput.push(producers as f64, rate / 1e6);
    }
    fig.series.push(tput);

    // Single-thread round trip through a live echo service.
    let ring = Ring::new(64);
    let pool = Arc::new(CompletionPool::new(16));
    let mut consumer = ring.consumer();
    let pool2 = pool.clone();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop2 = stop.clone();
    let echo = std::thread::spawn(move || {
        while !stop2.load(std::sync::atomic::Ordering::Acquire) {
            if let Some(m) = consumer.try_recv() {
                if m.ring_op() == Some(RingOp::Shutdown) {
                    return;
                }
                if m.completion != COMPLETION_NONE {
                    pool2.complete(m.completion, m.inline_val);
                }
            } else {
                std::hint::spin_loop();
            }
        }
    });
    let m = measure_wall(|| {
        let token = pool.alloc();
        let mut msg = Message::nop();
        msg.completion = token.index;
        msg.inline_val = 9;
        ring.send(msg);
        assert_eq!(pool.wait(token), 9);
    });
    let mut rtt = Series::new("RTT µs");
    rtt.push(1.0, m.best_ns / 1000.0);
    fig.series.push(rtt);

    stop.store(true, std::sync::atomic::Ordering::Release);
    let mut sd = Message::nop();
    sd.op = RingOp::Shutdown as u8;
    ring.send(sd);
    let _ = echo.join();
    fig
}

/// Ablation: immediate vs standard command lists on the proxied
/// (copy-engine) put path — the §III-C design choice ("immediate command
/// lists for low latency copy operations").
pub fn ablate_cmdlists() -> Figure {
    let sizes = size_sweep();
    let mut fig = Figure::new(
        "ablate-cl",
        "ablation: immediate vs standard command lists (engine put path)",
        "msg size",
        "GB/s",
    );
    for (name, immediate) in [("immediate CL", true), ("standard CL", false)] {
        let cfg = IshmemConfig {
            topology: Topology::new(1, 2, 2),
            heap_bytes: 40 << 20,
            cutover: CutoverConfig::always(),
            use_immediate_cl: immediate,
            ..Default::default()
        };
        let ish = Ishmem::new(cfg).expect("ablate machine");
        let sizes2 = sizes.clone();
        let series = ish.launch(move |ctx| {
            let max = *sizes2.iter().max().unwrap();
            let buf = ctx.calloc::<u8>(max);
            let local = vec![1u8; max];
            ctx.barrier_all();
            if ctx.pe() != 0 {
                return None;
            }
            let mut s = Series::new(name);
            for &size in &sizes2 {
                let m = measure(&ctx.clock, || ctx.put(buf, &local[..size], 2));
                s.push(size as f64, m.bandwidth_gbs(size));
            }
            Some(s)
        });
        ish.shutdown();
        fig.series.push(series.into_iter().flatten().next().unwrap());
    }
    fig
}

/// Ablation: the push (atomic-increment) sync vs a naive pull barrier
/// (every PE polls every other PE's flag with fetching atomics) — the
/// §III-G.2 design choice, in modeled time per sync.
pub fn ablate_sync() -> Figure {
    let mut fig = Figure::new(
        "ablate-sync",
        "ablation: push atomic sync vs pull (fetching) barrier",
        "npes",
        "µs per sync",
    );
    let mut push = Series::new("push fire-and-forget (ishmem)");
    let mut pull = Series::new("pull fetching-atomic");
    for npes in [2usize, 4, 6, 8, 10, 12] {
        let cfg = IshmemConfig {
            topology: Topology::new(1, 6, 2),
            ..Default::default()
        };
        let ish = Ishmem::new(cfg).expect("ablate machine");
        let times = ish.launch(move |ctx| {
            let team = ctx.team_split_strided(crate::ishmem::TeamId::WORLD, 0, 1, npes);
            let flags = ctx.calloc::<u64>(12);
            if ctx.pe() >= npes {
                return None;
            }
            // Push: the shipping implementation.
            let m_push = measure_fixed(&ctx.clock, 1, 5, || ctx.team_sync(team));

            // Pull: set my flag once, then fetch every member's flag until
            // seen — each poll is a *fetching* remote atomic (round trip,
            // not pipelined). Modeled directly from the cost terms.
            let m_pull = measure_fixed(&ctx.clock, 1, 5, || {
                ctx.atomic_add(flags.at(ctx.pe()), 1u64, ctx.pe());
                for peer in 0..npes {
                    // One fetching atomic per member — a full round trip
                    // each (optimistic: every flag ready on the first poll).
                    ctx.atomic_fetch(flags.at(peer), peer);
                }
            });
            (ctx.pe() == 0).then_some((m_push.best_ns, m_pull.best_ns))
        });
        ish.shutdown();
        let (p, q) = times.into_iter().flatten().next().unwrap();
        push.push(npes as f64, p / 1000.0);
        pull.push(npes as f64, q / 1000.0);
    }
    fig.series.push(push);
    fig.series.push(pull);
    fig
}

/// All paper figures, in order, plus the batched-submission figure.
pub fn all_figures() -> Vec<Figure> {
    let mut v = vec![fig3a(), fig3b(), fig4a(), fig4b(), fig5a(), fig5b(), fig5_adaptive()];
    for npes in [4, 8, 12] {
        v.push(fig6(npes));
    }
    v.push(fig7a());
    v.push(fig7b());
    v.push(ring_figure());
    v.push(fig_batch());
    v.push(fig_stripe());
    v.push(fig_rail());
    v.push(fig_fault());
    v.push(fig_coll_scale());
    v
}
