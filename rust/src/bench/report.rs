//! Figure assembly: CSV output + ASCII rendering of the paper-shaped
//! series (who wins, where the crossovers fall).

use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    /// (x, y) points; x is message size / nelems / npes, y is GB/s or µs.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn y_at(&self, x: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|(px, _)| (*px - x).abs() < 1e-9)
            .map(|(_, y)| *y)
    }
}

#[derive(Clone, Debug)]
pub struct Figure {
    pub id: String,
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
}

impl Figure {
    pub fn new(
        id: impl Into<String>,
        title: impl Into<String>,
        x_label: impl Into<String>,
        y_label: impl Into<String>,
    ) -> Self {
        Figure {
            id: id.into(),
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
        }
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("# {} — {}\n", self.id, self.title));
        out.push_str(&format!("{}", self.x_label));
        for s in &self.series {
            out.push_str(&format!(",{}", s.name));
        }
        out.push('\n');
        for &(x, _) in &self.series.first().map(|s| s.points.clone()).unwrap_or_default() {
            out.push_str(&format!("{x}"));
            for s in &self.series {
                match s.y_at(x) {
                    Some(y) => out.push_str(&format!(",{y:.4}")),
                    None => out.push(','),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Render the paper-style rows: one line per x, one column per series.
    pub fn render_ascii(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} — {} ==\n", self.id, self.title));
        let w = self.series.iter().map(|s| s.name.len()).max().unwrap_or(8).max(10);
        out.push_str(&format!("{:>12} ", self.x_label));
        for s in &self.series {
            out.push_str(&format!("{:>w$} ", s.name, w = w));
        }
        out.push('\n');
        let xs: Vec<f64> = self
            .series
            .first()
            .map(|s| s.points.iter().map(|(x, _)| *x).collect())
            .unwrap_or_default();
        for x in xs {
            let xfmt = if x >= 1024.0 && (x as usize).is_power_of_two() {
                crate::util::fmt_bytes(x as usize)
            } else {
                format!("{x}")
            };
            out.push_str(&format!("{xfmt:>12} "));
            for s in &self.series {
                match s.y_at(x) {
                    Some(y) => out.push_str(&format!("{:>w$.3} ", y, w = w)),
                    None => out.push_str(&format!("{:>w$} ", "-", w = w)),
                }
            }
            out.push('\n');
        }
        out.push_str(&format!("    (y = {})\n", self.y_label));
        out
    }

    pub fn save_csv(&self, dir: impl AsRef<Path>) -> anyhow::Result<PathBuf> {
        std::fs::create_dir_all(dir.as_ref())?;
        let path = dir.as_ref().join(format!("{}.csv", self.id));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }

    /// First x where series `a` drops below series `b` (crossover finder
    /// used by tests and EXPERIMENTS.md tables).
    pub fn crossover(&self, a: &str, b: &str) -> Option<f64> {
        let sa = self.series.iter().find(|s| s.name == a)?;
        let sb = self.series.iter().find(|s| s.name == b)?;
        for (x, ya) in &sa.points {
            if let Some(yb) = sb.y_at(*x) {
                if *ya < yb {
                    return Some(*x);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig() -> Figure {
        let mut f = Figure::new("t1", "test", "bytes", "GB/s");
        let mut a = Series::new("store");
        let mut b = Series::new("engine");
        for (x, ya, yb) in [(8.0, 1.0, 0.1), (4096.0, 5.0, 4.0), (1e6, 10.0, 24.0)] {
            a.push(x, ya);
            b.push(x, yb);
        }
        f.series.push(a);
        f.series.push(b);
        f
    }

    #[test]
    fn csv_has_all_series() {
        let csv = fig().to_csv();
        assert!(csv.contains("bytes,store,engine"));
        assert!(csv.lines().count() >= 5);
    }

    #[test]
    fn crossover_found() {
        assert_eq!(fig().crossover("store", "engine"), Some(1e6));
        assert_eq!(fig().crossover("engine", "store"), Some(8.0));
    }

    #[test]
    fn ascii_renders() {
        let a = fig().render_ascii();
        assert!(a.contains("store") && a.contains("engine"));
    }
}
