//! `ze_peer` baseline (paper §IV, [3]): the Level-Zero perf test that
//! measures raw copy-engine bandwidth between two L0 devices, with no
//! SHMEM library in the path. Reproduced against our `ze` substrate —
//! host-initiated command-list copies, sized like the paper's read/write
//! benchmarks, in ze_peer's *multi-engine* mode (`-u`): the copy splits
//! one chunk per main engine, so the measured rate is the engines'
//! aggregate (the link roofline a single blitter cannot sustain alone —
//! `CopyEngineParams::single_engine_frac`).

use std::sync::Arc;

use crate::sim::memory::HeapRegistry;
use crate::sim::{CostModel, CostParams, SimClock, Topology};
use crate::ze::cmdlist::{CommandQueue, DeviceAddr};
use crate::ze::ZeDriver;

use super::report::Series;
use super::timer::measure;

/// ze_peer write (src device → dst device) bandwidth sweep, GB/s.
pub fn zepeer_write_series(
    topo: &Topology,
    src_pe: usize,
    dst_pe: usize,
    sizes: &[usize],
    name: &str,
) -> Series {
    run(topo, src_pe, dst_pe, sizes, name, true)
}

/// ze_peer read (dst pulls from src) — same engine path, reversed.
pub fn zepeer_read_series(
    topo: &Topology,
    src_pe: usize,
    dst_pe: usize,
    sizes: &[usize],
    name: &str,
) -> Series {
    run(topo, dst_pe, src_pe, sizes, name, true)
}

fn run(
    topo: &Topology,
    src_pe: usize,
    dst_pe: usize,
    sizes: &[usize],
    name: &str,
    _host: bool,
) -> Series {
    let max = *sizes.iter().max().unwrap_or(&4096);
    let cost = CostModel::new(topo.clone(), CostParams::default());
    let heaps = Arc::new(HeapRegistry::new(topo.npes(), max * 2));
    let driver = ZeDriver::new(heaps, cost);
    // ze_peer drives *standard* command lists executed on a host command
    // queue. The real bytes move through the substrate on a scratch clock
    // (the cmdlist charges one engine per copy); the measured clock is
    // charged at ze_peer's multi-engine aggregate — one chunk per main
    // engine, the paper's saturated baseline.
    let queue = CommandQueue::host();
    let clock = SimClock::new();
    let loc = driver.cost.locality(src_pe, dst_pe);
    let engines = driver.cost.params.ce.engines_per_gpu.max(1);

    let mut series = Series::new(name);
    for &size in sizes {
        let m = measure(&clock, || {
            let scratch = SimClock::new();
            let mut cl = driver.create_command_list(src_pe);
            cl.append_memory_copy(
                DeviceAddr { pe: dst_pe, offset: 0 },
                DeviceAddr { pe: src_pe, offset: max },
                size,
                None,
            );
            cl.close();
            cl.execute(&queue, &scratch);
            // Multi-engine split: chunks ≤ engines so every engine pays
            // exactly one standard-CL startup; one host doorbell.
            let chunks = engines.min(size.max(1));
            clock.advance(driver.cost.params.ce.striped_transfer_ns(
                &driver.cost.params.xe,
                loc,
                size,
                false,
                true,
                chunks,
                chunks,
            ));
        });
        series.push(size as f64, m.bandwidth_gbs(size));
    }
    series
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zepeer_bandwidth_monotone_until_roofline() {
        let topo = Topology::new(1, 2, 2);
        let sizes: Vec<usize> = (3..=22).map(|p| 1 << p).collect();
        let s = zepeer_write_series(&topo, 0, 2, &sizes, "zepeer");
        // Engine startup dominates small messages; large ones approach the
        // Xe-Link roofline (25 GB/s).
        let first = s.points.first().unwrap().1;
        let last = s.points.last().unwrap().1;
        assert!(first < 0.1, "8B should be startup-bound: {first}");
        assert!(last > 20.0, "4MB should approach the link rate: {last}");
    }

    #[test]
    fn same_device_faster_than_cross() {
        let topo = Topology::new(1, 2, 2);
        let sizes = vec![1 << 20];
        let same = zepeer_write_series(&topo, 0, 1, &sizes, "tile").points[0].1;
        let cross = zepeer_write_series(&topo, 0, 2, &sizes, "gpu").points[0].1;
        assert!(same > cross);
    }
}
