//! `ze_peer` baseline (paper §IV, [3]): the Level-Zero perf test that
//! measures raw copy-engine bandwidth between two L0 devices, with no
//! SHMEM library in the path. Reproduced against our `ze` substrate —
//! host-initiated immediate-command-list copies, sized like the paper's
//! read/write benchmarks.

use std::sync::Arc;

use crate::sim::memory::HeapRegistry;
use crate::sim::{CostModel, CostParams, SimClock, Topology};
use crate::ze::cmdlist::{CommandQueue, DeviceAddr};
use crate::ze::ZeDriver;

use super::report::Series;
use super::timer::measure;

/// ze_peer write (src device → dst device) bandwidth sweep, GB/s.
pub fn zepeer_write_series(
    topo: &Topology,
    src_pe: usize,
    dst_pe: usize,
    sizes: &[usize],
    name: &str,
) -> Series {
    run(topo, src_pe, dst_pe, sizes, name, true)
}

/// ze_peer read (dst pulls from src) — same engine path, reversed.
pub fn zepeer_read_series(
    topo: &Topology,
    src_pe: usize,
    dst_pe: usize,
    sizes: &[usize],
    name: &str,
) -> Series {
    run(topo, dst_pe, src_pe, sizes, name, true)
}

fn run(
    topo: &Topology,
    src_pe: usize,
    dst_pe: usize,
    sizes: &[usize],
    name: &str,
    _host: bool,
) -> Series {
    let max = *sizes.iter().max().unwrap_or(&4096);
    let cost = CostModel::new(topo.clone(), CostParams::default());
    let heaps = Arc::new(HeapRegistry::new(topo.npes(), max * 2));
    let driver = ZeDriver::new(heaps, cost);
    // ze_peer drives *standard* command lists executed on a host command
    // queue (one engine dispatch per measured copy).
    let queue = CommandQueue::host();
    let clock = SimClock::new();

    let mut series = Series::new(name);
    for &size in sizes {
        let m = measure(&clock, || {
            let mut cl = driver.create_command_list(src_pe);
            cl.append_memory_copy(
                DeviceAddr { pe: dst_pe, offset: 0 },
                DeviceAddr { pe: src_pe, offset: max },
                size,
                None,
            );
            cl.close();
            cl.execute(&queue, &clock);
        });
        series.push(size as f64, m.bandwidth_gbs(size));
    }
    series
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zepeer_bandwidth_monotone_until_roofline() {
        let topo = Topology::new(1, 2, 2);
        let sizes: Vec<usize> = (3..=22).map(|p| 1 << p).collect();
        let s = zepeer_write_series(&topo, 0, 2, &sizes, "zepeer");
        // Engine startup dominates small messages; large ones approach the
        // Xe-Link roofline (25 GB/s).
        let first = s.points.first().unwrap().1;
        let last = s.points.last().unwrap().1;
        assert!(first < 0.1, "8B should be startup-bound: {first}");
        assert!(last > 20.0, "4MB should approach the link rate: {last}");
    }

    #[test]
    fn same_device_faster_than_cross() {
        let topo = Topology::new(1, 2, 2);
        let sizes = vec![1 << 20];
        let same = zepeer_write_series(&topo, 0, 1, &sizes, "tile").points[0].1;
        let cross = zepeer_write_series(&topo, 0, 2, &sizes, "gpu").points[0].1;
        assert!(same > cross);
    }
}
