//! Benchmark harness: regenerates every figure in the paper's evaluation
//! (§IV, Figs 3–7) plus the §III-D ring claims, using the measurement
//! methodology the paper describes (warm-up doubling until ≥2 ms, then 10
//! trials, best time).
//!
//! Bandwidth/latency numbers come from the **modeled** PE timeline
//! (`SimClock`) — the substitute for the paper's SYCL event profiling —
//! while all data movement underneath is real (DESIGN.md §2). The ring
//! figure is the exception: the ring is real software, so it is measured
//! in wall-clock.

pub mod figures;
pub mod report;
pub mod timer;
pub mod zepeer;

pub use report::{Figure, Series};
pub use timer::{measure, measure_fixed, measure_wall, Measurement};

/// Message-size sweep used by the RMA figures: 8 B … 16 MB, powers of two.
pub fn size_sweep() -> Vec<usize> {
    (3..=24).map(|p| 1usize << p).collect()
}

/// Element-count sweep used by the collective figures: 1 … 256 Ki f32.
pub fn nelem_sweep() -> Vec<usize> {
    (0..=18).map(|p| 1usize << p).collect()
}
