//! Benchmark harness: regenerates every figure in the paper's evaluation
//! (§IV, Figs 3–7) plus the §III-D ring claims, using the measurement
//! methodology the paper describes (warm-up doubling until ≥2 ms, then 10
//! trials, best time).
//!
//! Bandwidth/latency numbers come from the **modeled** PE timeline
//! (`SimClock`) — the substitute for the paper's SYCL event profiling —
//! while all data movement underneath is real (DESIGN.md §2). The ring
//! figure is the exception: the ring is real software, so it is measured
//! in wall-clock.

pub mod figures;
pub mod report;
pub mod timer;
pub mod zepeer;

pub use report::{Figure, Series};
pub use timer::{measure, measure_fixed, measure_wall, Measurement};

/// CI smoke mode (`RISHMEM_SMOKE=1`): shrink the sweeps so the bench
/// binaries finish in seconds while still crossing every cutover point.
pub fn smoke() -> bool {
    std::env::var("RISHMEM_SMOKE").is_ok_and(|v| v != "0")
}

/// Message-size sweep used by the RMA figures: 8 B … 16 MB, powers of two
/// (8 B … 1 MB under `RISHMEM_SMOKE`).
pub fn size_sweep() -> Vec<usize> {
    let max_pow = if smoke() { 20 } else { 24 };
    (3..=max_pow).map(|p| 1usize << p).collect()
}

/// Element-count sweep used by the collective figures: 1 … 256 Ki f32
/// (… 16 Ki under `RISHMEM_SMOKE`).
pub fn nelem_sweep() -> Vec<usize> {
    let max_pow = if smoke() { 14 } else { 18 };
    (0..=max_pow).map(|p| 1usize << p).collect()
}
