//! Deterministic RNG (SplitMix64) — no `rand` crate in the offline set.
//!
//! Used for synthetic workloads (token streams, payload patterns) and the
//! property-test driver. Determinism matters: every test failure must be
//! reproducible from its printed seed.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (bound > 0), debiased by rejection.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard-ish normal via Irwin–Hall (sum of 12 uniforms).
    pub fn normal(&mut self) -> f64 {
        (0..12).map(|_| self.f64()).sum::<f64>() - 6.0
    }

    /// Fill a byte slice with a reproducible pattern.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
            let v = r.range(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut acc = 0.0;
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            acc += v;
        }
        // Mean should be near 0.5.
        assert!((acc / 1000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(3);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
