//! Deterministic RNG (SplitMix64) — no `rand` crate in the offline set.
//!
//! Used for synthetic workloads (token streams, payload patterns) and the
//! property-test driver. Determinism matters: every test failure must be
//! reproducible from its printed seed.

use std::sync::atomic::{AtomicU64, Ordering};

/// The SplitMix64 additive constant (the "golden gamma").
const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// The SplitMix64 output mix of one state word.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        mix(self.state)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (bound > 0), debiased by rejection.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard-ish normal via Irwin–Hall (sum of 12 uniforms).
    pub fn normal(&mut self) -> f64 {
        (0..12).map(|_| self.f64()).sum::<f64>() - 6.0
    }

    /// Fill a byte slice with a reproducible pattern.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }
}

/// Lock-free SplitMix64 on a shared `AtomicU64` state: `fetch_add` hands
/// each caller a distinct state word, `mix` turns it into the draw — no
/// `Mutex`, no serialization of concurrent callers, and (because the
/// state advance is the same `wrapping_add(GAMMA)`) a single-threaded
/// caller sees *exactly* the [`Rng`] stream for the same seed. Under
/// concurrency the interleaving of draws is racy but every draw is still
/// a distinct, well-mixed SplitMix64 output.
#[derive(Debug)]
pub struct AtomicRng {
    state: AtomicU64,
}

impl AtomicRng {
    pub fn new(seed: u64) -> Self {
        AtomicRng { state: AtomicU64::new(seed) }
    }

    #[inline]
    pub fn next_u64(&self) -> u64 {
        // fetch_add returns the *previous* state; the draw mixes the
        // advanced word, matching `Rng::next_u64` exactly.
        mix(self.state.fetch_add(GAMMA, Ordering::Relaxed).wrapping_add(GAMMA))
    }

    /// Uniform f64 in [0, 1) (same construction as [`Rng::f64`]).
    #[inline]
    pub fn f64(&self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_rng_reproduces_the_sequential_stream() {
        let mut seq = Rng::new(0xADA9_71CE);
        let atomic = AtomicRng::new(0xADA9_71CE);
        for _ in 0..200 {
            assert_eq!(seq.next_u64(), atomic.next_u64());
        }
        // And the f64 construction matches bit-for-bit.
        let mut seq = Rng::new(7);
        let atomic = AtomicRng::new(7);
        for _ in 0..50 {
            assert_eq!(seq.f64().to_bits(), atomic.f64().to_bits());
        }
    }

    #[test]
    fn atomic_rng_draws_are_distinct_across_threads() {
        let atomic = AtomicRng::new(42);
        let mut all: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| s.spawn(|| (0..100).map(|_| atomic.next_u64()).collect::<Vec<_>>()))
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 400, "every concurrent draw is a distinct state word");
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
            let v = r.range(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut acc = 0.0;
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            acc += v;
        }
        // Mean should be near 0.5.
        assert!((acc / 1000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(3);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
