//! Tiny property-test driver (the offline vendor set has no `proptest`).
//!
//! `prop_check(name, cases, |rng| ...)` runs a closure over `cases`
//! deterministic seeds; a failure panics with the seed so the exact case can
//! be replayed with `prop_replay`. Shrinking is intentionally out of scope —
//! generators here draw small sizes to begin with.

use super::rng::Rng;

/// Run `f` for `cases` deterministic seeds; panic with the failing seed.
pub fn prop_check<F: Fn(&mut Rng)>(name: &str, cases: u64, f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing seed printed by `prop_check`.
pub fn prop_replay<F: Fn(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("u64 below bound", 50, |rng| {
            let b = rng.range(1, 1000);
            assert!(rng.below(b) < b);
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn reports_failure_seed() {
        prop_check("always fails eventually", 10, |rng| {
            assert!(rng.f64() < 0.5, "drew a large value");
        });
    }
}
