//! A tiny non-cryptographic hasher for hot-path hash maps.
//!
//! `std`'s default `SipHash` is DoS-resistant but costs real nanoseconds
//! per lookup — too much for the plan cache and the sharded adaptive
//! table, which sit on the per-op issue path and hash only small
//! fixed-shape keys built from trusted internal state (no attacker-
//! controlled strings). This is the FxHash construction (rustc's own
//! internal hasher): fold the input in 8-byte words through a rotate,
//! xor, multiply. No vendored crates in the offline set, so it lives
//! here, from scratch.

use std::hash::{BuildHasher, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash-style word-at-a-time hasher. Implements the generic
/// `write(&[u8])`, so every derived `Hash` impl (structs, enums, the
/// discriminant writes) funnels through the same fold.
#[derive(Clone, Debug, Default)]
pub struct FastHasher {
    state: u64,
}

impl FastHasher {
    #[inline]
    fn fold(&mut self, word: u64) {
        self.state = (self.state.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.fold(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            // Length in the pad byte keeps "ab" and "ab\0" distinct.
            buf[7] = buf[7].wrapping_add(rem.len() as u8);
            self.fold(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.fold(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.fold(v as u64);
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.fold(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.fold(v as u64);
    }
}

/// `BuildHasher` plugging [`FastHasher`] into `HashMap`:
/// `HashMap::with_hasher(FastState)`.
#[derive(Clone, Copy, Debug, Default)]
pub struct FastState;

impl BuildHasher for FastState {
    type Hasher = FastHasher;

    #[inline]
    fn build_hasher(&self) -> FastHasher {
        FastHasher::default()
    }
}

/// Hash one value with [`FastHasher`] (shard selection).
#[inline]
pub fn fast_hash<T: std::hash::Hash>(v: &T) -> u64 {
    let mut h = FastHasher::default();
    v.hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn equal_keys_hash_equal_and_maps_work() {
        #[derive(Hash, PartialEq, Eq, Clone, Copy, Debug)]
        struct Key {
            a: usize,
            b: u64,
            c: bool,
        }
        let k1 = Key { a: 7, b: 1 << 40, c: true };
        let k2 = Key { a: 7, b: 1 << 40, c: true };
        assert_eq!(fast_hash(&k1), fast_hash(&k2));
        let mut m: HashMap<Key, u32, FastState> = HashMap::with_hasher(FastState);
        m.insert(k1, 99);
        assert_eq!(m.get(&k2), Some(&99));
    }

    #[test]
    fn nearby_keys_spread() {
        // Not a statistical test — just catch a degenerate fold that maps
        // small consecutive keys onto a handful of buckets.
        let mut seen = std::collections::HashSet::new();
        for i in 0u64..1000 {
            seen.insert(fast_hash(&i) % 64);
        }
        assert!(seen.len() >= 48, "spread over {}/64 buckets", seen.len());
    }

    #[test]
    fn byte_slices_of_different_length_differ() {
        assert_ne!(fast_hash(&[1u8, 2, 3][..]), fast_hash(&[1u8, 2, 3, 0][..]));
        assert_ne!(fast_hash(&"ab"), fast_hash(&"ab\0"));
    }
}
