//! Small self-contained utilities.
//!
//! The build environment is fully offline with a narrow vendored crate set,
//! so a few things that would normally be dependencies (JSON, RNG, a
//! property-test driver) are implemented here from scratch and unit-tested.

pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;

/// Round `n` up to the next multiple of `m` (m > 0).
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// Human-readable byte count (for report tables).
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}{}", UNITS[0])
    } else if v < 10.0 {
        format!("{v:.1}{}", UNITS[u])
    } else {
        format!("{v:.0}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(8192, 8192), 8192);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KB");
        assert_eq!(fmt_bytes(4 * 1024 * 1024), "4.0MB");
    }
}
