//! Level-Zero-like substrate (paper §II-B, §III-C).
//!
//! ishmem's intra-node proxy path is literally
//! `zeCommandListAppendMemoryCopy` on standard or *immediate* command lists,
//! plus Level-Zero IPC handles for cross-process mapping of peer symmetric
//! heaps. This module rebuilds that seam against the simulated memory and
//! cost model so the ishmem proxy code path is structured exactly like the
//! real library's.

pub mod cmdlist;
pub mod event;
pub mod ipc;

pub use cmdlist::{CommandList, CommandQueue, ImmediateCommandList};
pub use event::ZeEvent;
pub use ipc::{IpcHandle, IpcTable};

use std::sync::Arc;

use crate::sim::{CostModel, HeapRegistry};

/// A Level-Zero "driver" scoped to one machine: owns nothing, maps device
/// (tile) operations onto the shared heap registry + cost model.
#[derive(Clone)]
pub struct ZeDriver {
    pub heaps: Arc<HeapRegistry>,
    pub cost: Arc<CostModel>,
}

impl ZeDriver {
    pub fn new(heaps: Arc<HeapRegistry>, cost: Arc<CostModel>) -> Self {
        ZeDriver { heaps, cost }
    }

    /// Number of L0 devices (PE tiles) visible to this driver.
    pub fn device_count(&self) -> usize {
        self.heaps.npes()
    }

    /// Create a standard command list for the GPU owning `pe`.
    pub fn create_command_list(&self, pe: usize) -> CommandList {
        CommandList::new(self.clone(), pe)
    }

    /// Create an immediate command list (low-latency path, paper §III-C).
    pub fn create_immediate_command_list(&self, pe: usize) -> ImmediateCommandList {
        ImmediateCommandList::new(self.clone(), pe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{CostParams, Topology};

    pub(crate) fn test_driver(npes: usize) -> ZeDriver {
        let topo = Topology::single_node_for(npes);
        let cost = CostModel::new(topo, CostParams::default());
        let heaps = Arc::new(HeapRegistry::new(npes, 1 << 16));
        ZeDriver::new(heaps, cost)
    }

    #[test]
    fn driver_sees_all_tiles() {
        let d = test_driver(12);
        assert_eq!(d.device_count(), 12);
    }
}
