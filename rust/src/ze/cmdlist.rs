//! Command lists: the copy-engine control interface (paper §III-C).
//!
//! "The core routine for intra-node transfers is
//! `zeCommandListAppendMemoryCopy`. Intel SHMEM supports both standard
//! Level Zero command lists and immediate command lists for low latency
//! copy operations."
//!
//! A standard list batches appends and executes on a queue (startup paid
//! once per execute, per entry engine dispatch); an immediate list executes
//! each append right away with the lower startup constant.

use super::event::ZeEvent;
use super::ZeDriver;
use crate::sim::topology::Locality;
use crate::sim::SimClock;

/// A symmetric-heap address usable by command lists: (pe, byte offset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeviceAddr {
    pub pe: usize,
    pub offset: usize,
}

#[derive(Clone, Debug)]
struct CopyCmd {
    dst: DeviceAddr,
    src: DeviceAddr,
    len: usize,
    event: Option<ZeEvent>,
}

/// Standard command list: append*, close, then execute on a queue.
pub struct CommandList {
    driver: ZeDriver,
    /// The PE whose GPU's copy engines run this list.
    owner_pe: usize,
    cmds: Vec<CopyCmd>,
    closed: bool,
}

impl CommandList {
    pub(super) fn new(driver: ZeDriver, owner_pe: usize) -> Self {
        CommandList { driver, owner_pe, cmds: Vec::new(), closed: false }
    }

    pub fn append_memory_copy(
        &mut self,
        dst: DeviceAddr,
        src: DeviceAddr,
        len: usize,
        event: Option<ZeEvent>,
    ) {
        assert!(!self.closed, "append to closed command list");
        self.cmds.push(CopyCmd { dst, src, len, event });
    }

    pub fn close(&mut self) {
        self.closed = true;
    }

    pub fn len(&self) -> usize {
        self.cmds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cmds.is_empty()
    }

    /// Execute on `queue`, charging modeled time to `clock`.
    pub fn execute(&mut self, queue: &CommandQueue, clock: &SimClock) {
        assert!(self.closed, "execute before close");
        for cmd in self.cmds.drain(..) {
            queue.run_copy(&self.driver, self.owner_pe, &cmd, clock, false);
        }
        self.closed = false;
    }
}

/// Immediate command list: each append executes synchronously with the
/// low-latency startup constant.
pub struct ImmediateCommandList {
    driver: ZeDriver,
    owner_pe: usize,
    queue: CommandQueue,
}

impl ImmediateCommandList {
    pub(super) fn new(driver: ZeDriver, owner_pe: usize) -> Self {
        ImmediateCommandList { driver, owner_pe, queue: CommandQueue::default() }
    }

    pub fn append_memory_copy(
        &self,
        dst: DeviceAddr,
        src: DeviceAddr,
        len: usize,
        event: Option<ZeEvent>,
        clock: &SimClock,
    ) {
        let cmd = CopyCmd { dst, src, len, event };
        self.queue
            .run_copy(&self.driver, self.owner_pe, &cmd, clock, true);
    }
}

/// Command queue: dispatches copies to the owning GPU's engines.
#[derive(Default)]
pub struct CommandQueue {
    /// Host-initiated execution pays the PCIe doorbell (paper §III-G.1:
    /// host-initiated copy engines suffer startup cost per transfer).
    pub host_initiated: bool,
}

impl CommandQueue {
    pub fn host() -> Self {
        CommandQueue { host_initiated: true }
    }

    fn run_copy(
        &self,
        driver: &ZeDriver,
        owner_pe: usize,
        cmd: &CopyCmd,
        clock: &SimClock,
        immediate: bool,
    ) {
        let loc = driver.cost.locality(cmd.src.pe, cmd.dst.pe);
        assert!(
            loc != Locality::Remote,
            "L0 command lists cannot reach a remote node"
        );
        // Real data movement first …
        driver
            .heaps
            .copy(cmd.src.pe, cmd.src.offset, cmd.dst.pe, cmd.dst.offset, cmd.len);
        // … then the modeled engine time.
        let gpu = driver.cost.topo.global_gpu_of(owner_pe);
        let ns = driver.cost.copy_engine_ns(
            gpu,
            loc,
            cmd.len,
            immediate,
            self.host_initiated,
            false,
        );
        clock.advance(ns);
        if let Some(ev) = &cmd.event {
            ev.signal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::test_driver;
    use super::*;

    #[test]
    fn immediate_copy_moves_bytes_and_charges_time() {
        let d = test_driver(4);
        let clock = SimClock::new();
        d.heaps.heap(0).write(0, &[9u8; 256]);
        let icl = d.create_immediate_command_list(0);
        let ev = ZeEvent::new();
        icl.append_memory_copy(
            DeviceAddr { pe: 2, offset: 512 },
            DeviceAddr { pe: 0, offset: 0 },
            256,
            Some(ev.clone()),
            &clock,
        );
        let mut out = [0u8; 256];
        d.heaps.heap(2).read(512, &mut out);
        assert!(out.iter().all(|&b| b == 9));
        assert!(ev.is_signaled());
        assert!(clock.now_ns() >= d.cost.params.ce.startup_immediate_ns);
    }

    #[test]
    fn standard_list_batches() {
        let d = test_driver(4);
        let clock = SimClock::new();
        d.heaps.heap(1).write(0, &[5u8; 64]);
        let mut cl = d.create_command_list(1);
        for i in 0..4 {
            cl.append_memory_copy(
                DeviceAddr { pe: 3, offset: i * 64 },
                DeviceAddr { pe: 1, offset: 0 },
                64,
                None,
            );
        }
        assert_eq!(cl.len(), 4);
        cl.close();
        cl.execute(&CommandQueue::host(), &clock);
        let mut out = [0u8; 256];
        d.heaps.heap(3).read(0, &mut out);
        assert!(out.iter().all(|&b| b == 5));
        // Standard CL startup > immediate CL startup, 4 copies charged.
        assert!(clock.now_ns() > 4.0 * d.cost.params.ce.startup_standard_ns);
    }

    #[test]
    #[should_panic(expected = "before close")]
    fn execute_requires_close() {
        let d = test_driver(2);
        let mut cl = d.create_command_list(0);
        cl.append_memory_copy(
            DeviceAddr { pe: 1, offset: 0 },
            DeviceAddr { pe: 0, offset: 0 },
            8,
            None,
        );
        cl.execute(&CommandQueue::default(), &SimClock::new());
    }
}
