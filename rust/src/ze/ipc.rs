//! Level-Zero IPC: cross-PE mapping of peer symmetric heaps
//! (paper §III-C: "Intel SHMEM can directly leverage the Level Zero
//! inter-process communication (IPC) interfaces without invoking a host
//! operation").
//!
//! During init every PE publishes an IPC handle for its heap; every other
//! local PE opens it to obtain a direct window. ishmem's per-op "is the
//! target PE local?" table (§III-C) is built from this.

use crate::sim::topology::Topology;

/// An exportable handle to one PE's symmetric heap region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IpcHandle {
    pub owner_pe: usize,
    pub bytes: usize,
}

/// An opened mapping: the local view of a peer heap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IpcMapping {
    pub owner_pe: usize,
    pub bytes: usize,
}

/// Per-PE table of opened peer mappings — the stashed array every GPU RMA
/// op consults first (paper §III-C: "loads from a stashed array to
/// determine whether the target PE is local").
#[derive(Debug)]
pub struct IpcTable {
    /// `local[pe]` is `Some(mapping)` iff `pe` is reachable by load/store.
    local: Vec<Option<IpcMapping>>,
}

impl IpcTable {
    /// Build the table for `me` on `topo`: all same-node PEs are mapped.
    pub fn build(me: usize, topo: &Topology, heap_bytes: usize) -> Self {
        let mut local = vec![None; topo.npes()];
        for pe in topo.node_peers(me) {
            let handle = IpcHandle { owner_pe: pe, bytes: heap_bytes };
            local[pe] = Some(Self::open(handle));
        }
        IpcTable { local }
    }

    fn open(handle: IpcHandle) -> IpcMapping {
        IpcMapping { owner_pe: handle.owner_pe, bytes: handle.bytes }
    }

    /// The hot-path lookup: `Some` means direct load/store is possible.
    #[inline]
    pub fn lookup(&self, pe: usize) -> Option<&IpcMapping> {
        self.local.get(pe).and_then(|m| m.as_ref())
    }

    pub fn local_count(&self) -> usize {
        self.local.iter().filter(|m| m.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_node_all_local() {
        let topo = Topology::default();
        let t = IpcTable::build(0, &topo, 4096);
        assert_eq!(t.local_count(), 12);
        assert!(t.lookup(11).is_some());
    }

    #[test]
    fn cross_node_not_mapped() {
        let topo = Topology::new(2, 6, 2);
        let t = IpcTable::build(0, &topo, 4096);
        assert_eq!(t.local_count(), 12);
        assert!(t.lookup(12).is_none());
        assert!(t.lookup(23).is_none());

        let t2 = IpcTable::build(13, &topo, 4096);
        assert!(t2.lookup(0).is_none());
        assert!(t2.lookup(12).is_some());
    }
}
