//! Level-Zero events: completion signalling for command-list execution.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Shareable completion flag (zeEventCreate / zeEventHostSynchronize).
#[derive(Clone, Debug, Default)]
pub struct ZeEvent {
    signaled: Arc<AtomicBool>,
}

impl ZeEvent {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn signal(&self) {
        self.signaled.store(true, Ordering::Release);
    }

    pub fn is_signaled(&self) -> bool {
        self.signaled.load(Ordering::Acquire)
    }

    /// Spin-wait for the event (host synchronize). The simulation executes
    /// copies synchronously, so waits are short; yield to stay fair on the
    /// 1-core CI box.
    pub fn host_synchronize(&self) {
        while !self.is_signaled() {
            std::thread::yield_now();
        }
    }

    pub fn reset(&self) {
        self.signaled.store(false, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signal_wait_reset() {
        let e = ZeEvent::new();
        assert!(!e.is_signaled());
        e.signal();
        e.host_synchronize();
        assert!(e.is_signaled());
        e.reset();
        assert!(!e.is_signaled());
    }

    #[test]
    fn cross_thread_signal() {
        let e = ZeEvent::new();
        let e2 = e.clone();
        let h = std::thread::spawn(move || e2.signal());
        e.host_synchronize();
        h.join().unwrap();
        assert!(e.is_signaled());
    }
}
