//! Work-group / sub-group handles passed to `ishmemx_*_work_group` APIs.

/// A SYCL-like work-group: `size` work-items, fixed sub-group width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkGroup {
    size: usize,
    sub_group_size: usize,
}

impl WorkGroup {
    /// PVC-like bounds: 1..=1024 items, sub-groups of 16 lanes.
    pub const MAX_SIZE: usize = 1024;
    pub const SUB_GROUP_SIZE: usize = 16;

    pub fn new(size: usize) -> Self {
        assert!(
            (1..=Self::MAX_SIZE).contains(&size),
            "work-group size {size} out of range 1..={}",
            Self::MAX_SIZE
        );
        WorkGroup { size, sub_group_size: Self::SUB_GROUP_SIZE }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// The designated leader work-item (paper: proxy calls are restricted
    /// to a leader thread to avoid NIC/ring contention).
    pub fn leader(&self) -> usize {
        0
    }

    pub fn is_leader(&self, item: usize) -> bool {
        item == self.leader()
    }

    pub fn sub_groups(&self) -> usize {
        self.size.div_ceil(self.sub_group_size)
    }

    pub fn sub_group_of(&self, item: usize) -> SubGroup {
        assert!(item < self.size);
        SubGroup {
            index: item / self.sub_group_size,
            size: self
                .sub_group_size
                .min(self.size - (item / self.sub_group_size) * self.sub_group_size),
        }
    }

    /// Partition `len` bytes across the items: item `i` handles
    /// `[chunk_range(i, len)]`. Every byte is covered exactly once and
    /// chunks are contiguous, matching the collaborative-copy layout.
    pub fn chunk_range(&self, item: usize, len: usize) -> std::ops::Range<usize> {
        assert!(item < self.size);
        let per = len / self.size;
        let rem = len % self.size;
        // First `rem` items take one extra byte (balanced partition).
        let start = item * per + item.min(rem);
        let extra = usize::from(item < rem);
        start..start + per + extra
    }
}

/// A sub-group (vector-lane bundle) view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SubGroup {
    pub index: usize,
    pub size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn leader_is_item_zero() {
        let wg = WorkGroup::new(128);
        assert!(wg.is_leader(0));
        assert!(!wg.is_leader(1));
    }

    #[test]
    fn sub_group_partition() {
        let wg = WorkGroup::new(40);
        assert_eq!(wg.sub_groups(), 3);
        assert_eq!(wg.sub_group_of(0).index, 0);
        assert_eq!(wg.sub_group_of(16).index, 1);
        assert_eq!(wg.sub_group_of(39).index, 2);
        assert_eq!(wg.sub_group_of(39).size, 8); // tail sub-group
    }

    #[test]
    #[should_panic]
    fn oversized_group_rejected() {
        WorkGroup::new(2048);
    }

    #[test]
    fn chunks_tile_exactly() {
        prop_check("work-group chunks cover every byte once", 200, |rng| {
            let size = rng.range(1, WorkGroup::MAX_SIZE as u64) as usize;
            let len = rng.range(0, 10_000) as usize;
            let wg = WorkGroup::new(size);
            let mut covered = 0usize;
            let mut expected_start = 0usize;
            for item in 0..size {
                let r = wg.chunk_range(item, len);
                assert_eq!(r.start, expected_start, "contiguous chunks");
                expected_start = r.end;
                covered += r.len();
                // Balanced: no chunk differs from another by more than 1.
                assert!(r.len() <= len / size + 1);
            }
            assert_eq!(covered, len);
            assert_eq!(expected_start, len);
        });
    }
}
