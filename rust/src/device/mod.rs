//! Simulated SYCL device execution (paper §II-A).
//!
//! SYCL decomposes a kernel into work-groups of work-items (with sub-groups
//! as vector lanes). ishmem's `work_group` extension APIs take the calling
//! group and either (a) spread a copy across all items — the collaborative
//! multi-threaded vectorized memcpy — or (b) elect the leader item to talk
//! to the host proxy while the rest wait at a group barrier (§III-G.1).
//!
//! On this 1-core substrate work-items are *logical lanes*: the partitioning
//! arithmetic, leader election and barrier semantics are executed for real
//! (and unit-tested), while the parallel speedup is charged by the cost
//! model (`sim::xelink::items_rate_gbs`).

pub mod vecops;
pub mod workgroup;

pub use vecops::collaborative_copy;
pub use workgroup::{SubGroup, WorkGroup};
