//! Collaborative vectorized copy — the data plane of
//! `ishmemx_put_work_group` (paper §III-G.1: "the intra-node versions use a
//! multi-threaded vectorized memcpy").
//!
//! Each logical work-item moves its `chunk_range` of the transfer. We
//! execute the per-item chunks for real (so the partition arithmetic is on
//! the correctness path), in sub-group-interleaved order to mimic the SIMT
//! access pattern rather than one linear memcpy.

use super::workgroup::WorkGroup;
use crate::sim::memory::HeapRegistry;

/// Copy `len` bytes from (`src_pe`, `src_off`) to (`dst_pe`, `dst_off`)
/// as `wg.size()` cooperating lanes. Returns the number of lanes that
/// moved at least one byte (≤ wg.size(), used by cost accounting).
pub fn collaborative_copy(
    heaps: &HeapRegistry,
    src_pe: usize,
    src_off: usize,
    dst_pe: usize,
    dst_off: usize,
    len: usize,
    wg: &WorkGroup,
) -> usize {
    let mut active = 0;
    // Iterate items in sub-group-major order (lane bundles issue together).
    for sg in 0..wg.sub_groups() {
        let base = sg * WorkGroup::SUB_GROUP_SIZE;
        for lane in 0..WorkGroup::SUB_GROUP_SIZE {
            let item = base + lane;
            if item >= wg.size() {
                break;
            }
            let r = wg.chunk_range(item, len);
            if r.is_empty() {
                continue;
            }
            heaps.copy(src_pe, src_off + r.start, dst_pe, dst_off + r.start, r.len());
            active += 1;
        }
    }
    active
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    #[test]
    fn copies_identically_to_memcpy() {
        prop_check("collaborative copy == memcpy", 60, |rng: &mut Rng| {
            let heaps = HeapRegistry::new(2, 1 << 14);
            let len = rng.range(0, 8192) as usize;
            let items = rng.range(1, 1024) as usize;
            let mut src = vec![0u8; len];
            rng.fill_bytes(&mut src);
            heaps.heap(0).write(64, &src);

            let wg = WorkGroup::new(items);
            let active = collaborative_copy(&heaps, 0, 64, 1, 128, len, &wg);
            assert!(active <= items.min(len.max(1)));

            let mut out = vec![0u8; len];
            heaps.heap(1).read(128, &mut out);
            assert_eq!(out, src);
        });
    }

    #[test]
    fn zero_len_is_noop() {
        let heaps = HeapRegistry::new(1, 4096);
        let wg = WorkGroup::new(64);
        assert_eq!(collaborative_copy(&heaps, 0, 0, 0, 2048, 0, &wg), 0);
    }

    #[test]
    fn active_lane_count_small_transfers() {
        let heaps = HeapRegistry::new(2, 4096);
        let wg = WorkGroup::new(1024);
        // 10-byte transfer can keep at most 10 lanes busy.
        heaps.heap(0).write(0, &[1u8; 10]);
        let active = collaborative_copy(&heaps, 0, 0, 1, 0, 10, &wg);
        assert_eq!(active, 10);
    }
}
