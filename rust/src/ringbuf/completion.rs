//! Completion pool: out-of-order reply slots (paper §III-D: "Completions
//! are independently allocated to permit out of order replies").
//!
//! A GPU thread that needs a reply (blocking put/get, fetching AMO)
//! allocates a completion slot *before* posting its ring message, embeds
//! the slot index in the message, and spins on the slot — so replies can
//! land in any order while waiters never interfere with each other.
//!
//! Allocation is a lock-free Treiber stack of free indices with an ABA tag.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Sentinel: "no completion requested" (fire-and-forget message).
pub const COMPLETION_NONE: u32 = u32::MAX;

const STATE_FREE: u32 = 0;
const STATE_PENDING: u32 = 1;
const STATE_DONE: u32 = 2;

struct CompletionSlot {
    state: AtomicU32,
    /// Fetch-result payload (AMO old value, etc.).
    value: AtomicU64,
    /// Next free index (Treiber stack link).
    next: AtomicU32,
}

pub struct CompletionPool {
    slots: Box<[CompletionSlot]>,
    /// Stack head: (tag << 32) | index, index == u32::MAX ⇒ empty.
    head: AtomicU64,
}

/// A claimed completion slot. Must be waited or cancelled exactly once.
#[derive(Debug, PartialEq, Eq)]
pub struct CompletionToken {
    pub index: u32,
}

impl CompletionPool {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0 && capacity < u32::MAX as usize);
        let slots = (0..capacity)
            .map(|i| CompletionSlot {
                state: AtomicU32::new(STATE_FREE),
                value: AtomicU64::new(0),
                next: AtomicU32::new(if i + 1 < capacity {
                    (i + 1) as u32
                } else {
                    u32::MAX
                }),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        CompletionPool { slots, head: AtomicU64::new(0) }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    fn pack(tag: u32, idx: u32) -> u64 {
        ((tag as u64) << 32) | idx as u64
    }

    fn unpack(v: u64) -> (u32, u32) {
        ((v >> 32) as u32, v as u32)
    }

    /// Claim a slot; spins (yielding) if the pool is exhausted — bounded
    /// outstanding-request flow control, off the fast path.
    pub fn alloc(&self) -> CompletionToken {
        loop {
            let head = self.head.load(Ordering::Acquire);
            let (tag, idx) = Self::unpack(head);
            if idx == u32::MAX {
                std::thread::yield_now();
                continue;
            }
            let next = self.slots[idx as usize].next.load(Ordering::Relaxed);
            let new_head = Self::pack(tag.wrapping_add(1), next);
            if self
                .head
                .compare_exchange_weak(head, new_head, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let slot = &self.slots[idx as usize];
                slot.value.store(0, Ordering::Relaxed);
                slot.state.store(STATE_PENDING, Ordering::Release);
                return CompletionToken { index: idx };
            }
        }
    }

    fn free(&self, idx: u32) {
        let slot = &self.slots[idx as usize];
        slot.state.store(STATE_FREE, Ordering::Relaxed);
        loop {
            let head = self.head.load(Ordering::Acquire);
            let (tag, old_idx) = Self::unpack(head);
            slot.next.store(old_idx, Ordering::Relaxed);
            let new_head = Self::pack(tag.wrapping_add(1), idx);
            if self
                .head
                .compare_exchange_weak(head, new_head, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
        }
    }

    /// Host side: post the reply into slot `idx`.
    pub fn complete(&self, idx: u32, value: u64) {
        assert_ne!(idx, COMPLETION_NONE);
        let slot = &self.slots[idx as usize];
        debug_assert_eq!(slot.state.load(Ordering::Acquire), STATE_PENDING);
        slot.value.store(value, Ordering::Relaxed);
        slot.state.store(STATE_DONE, Ordering::Release);
    }

    /// Device side: spin until the reply arrives, return its payload, and
    /// recycle the slot.
    pub fn wait(&self, token: CompletionToken) -> u64 {
        let slot = &self.slots[token.index as usize];
        let mut spins = 0u32;
        while slot.state.load(Ordering::Acquire) != STATE_DONE {
            spins += 1;
            if spins > 128 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        let v = slot.value.load(Ordering::Relaxed);
        self.free(token.index);
        v
    }

    /// Poll without blocking; returns the payload if done.
    pub fn try_wait(&self, token: &CompletionToken) -> Option<u64> {
        let slot = &self.slots[token.index as usize];
        if slot.state.load(Ordering::Acquire) == STATE_DONE {
            Some(slot.value.load(Ordering::Relaxed))
        } else {
            None
        }
    }

    /// Consume a token previously confirmed done via `try_wait`.
    pub fn finish(&self, token: CompletionToken) -> u64 {
        let v = self.slots[token.index as usize].value.load(Ordering::Relaxed);
        self.free(token.index);
        v
    }

    /// Number of free slots (stats / flow-control tests).
    pub fn free_count(&self) -> usize {
        let mut n = 0;
        let (_, mut idx) = Self::unpack(self.head.load(Ordering::Acquire));
        while idx != u32::MAX {
            n += 1;
            idx = self.slots[idx as usize].next.load(Ordering::Relaxed);
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn alloc_complete_wait_roundtrip() {
        let pool = CompletionPool::new(4);
        let t = pool.alloc();
        let idx = t.index;
        pool.complete(idx, 1234);
        assert_eq!(pool.wait(t), 1234);
        assert_eq!(pool.free_count(), 4);
    }

    #[test]
    fn out_of_order_completion() {
        let pool = CompletionPool::new(8);
        let t1 = pool.alloc();
        let t2 = pool.alloc();
        let t3 = pool.alloc();
        pool.complete(t3.index, 3);
        pool.complete(t1.index, 1);
        pool.complete(t2.index, 2);
        assert_eq!(pool.wait(t2), 2);
        assert_eq!(pool.wait(t3), 3);
        assert_eq!(pool.wait(t1), 1);
    }

    #[test]
    fn try_wait_then_finish() {
        let pool = CompletionPool::new(2);
        let t = pool.alloc();
        assert_eq!(pool.try_wait(&t), None);
        pool.complete(t.index, 9);
        assert_eq!(pool.try_wait(&t), Some(9));
        assert_eq!(pool.finish(t), 9);
    }

    #[test]
    fn pool_exhaustion_blocks_until_free() {
        let pool = Arc::new(CompletionPool::new(2));
        let a = pool.alloc();
        let b = pool.alloc();
        assert_eq!(pool.free_count(), 0);
        let p = pool.clone();
        let waiter = std::thread::spawn(move || {
            // This alloc must block until one slot frees.
            let t = p.alloc();
            p.complete(t.index, 7);
            p.wait(t)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.complete(a.index, 1);
        assert_eq!(pool.wait(a), 1);
        assert_eq!(waiter.join().unwrap(), 7);
        pool.complete(b.index, 2);
        assert_eq!(pool.wait(b), 2);
        assert_eq!(pool.free_count(), 2);
    }

    #[test]
    fn concurrent_alloc_free_stress() {
        let pool = Arc::new(CompletionPool::new(16));
        let mut handles = vec![];
        for _ in 0..4 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2_000u64 {
                    let t = p.alloc();
                    p.complete(t.index, i);
                    assert_eq!(p.wait(t), i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.free_count(), 16);
    }
}
