//! Reverse-offload queue: lock-free GPU→CPU message ring (paper §III-D).
//!
//! When a device-initiated operation needs host assistance (inter-node
//! transfer, copy-engine start), the GPU thread composes a fixed 64-byte
//! request, allocates a transmit slot with a *single atomic fetch-add*
//! (fast arbitration among thousands of threads), and stores the message.
//! Completions live in an independently allocated pool so replies can land
//! out of order. The GPU end needs no progress thread; flow control is off
//! the critical path.
//!
//! This is the one paper contribution that is pure concurrent software, so
//! it is implemented *for real* (actual lock-free ring, actual threads) and
//! stress-tested against the paper's claims (~5 µs RTT modeled, >20 M req/s
//! arbitration — see benches/ring_buffer.rs and tests/stress_ring.rs).

pub mod batch;
pub mod completion;
pub mod message;
pub mod ring;

pub use batch::{
    payload_checksum, BatchDescriptor, ATTEMPT_MAX, CHUNK_FIELD_MAX, DESC_FLAG_CHECKSUM,
    DESC_FLAG_CHUNKED, DESC_FLAG_STANDARD_CL, DESC_FLAG_TRIGGERED, DESC_SIZE,
};
pub use completion::{CompletionPool, CompletionToken, COMPLETION_NONE};
pub use message::{Message, RingOp, MSG_SIZE};
pub use ring::{Ring, RingConsumer};
