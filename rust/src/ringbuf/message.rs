//! Fixed 64-byte ring messages (paper §III-D: "Messages are fixed size
//! (64 bytes)" — one cache line, one bus operation to transmit).

/// Operation encoded in a ring message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum RingOp {
    /// No-op (used by flow-control probes and tests).
    Nop = 0,
    /// Contiguous put: copy `len` bytes src_off(initiator) → dst_off(pe).
    Put = 1,
    /// Contiguous get: copy `len` bytes src_off(pe) → dst_off(initiator).
    Get = 2,
    /// Scalar put of `inline_val` (≤8 bytes ride inside the message).
    PutInline = 3,
    /// Atomic memory op on the target word; result via completion.
    Amo = 4,
    /// Memory-ordering flush of this PE's outstanding proxied ops.
    Quiet = 5,
    /// Put + signal update (paper: signaling ops).
    PutSignal = 6,
    /// Team barrier hand-off (inter-node phase of barriers).
    Barrier = 7,
    /// Batched submission: one doorbell for a whole plan-group. `dst_off`
    /// is the byte offset of a descriptor block in the *initiator's*
    /// symmetric heap (staging slab), `len` is the entry count; see
    /// [`crate::ringbuf::batch::BatchDescriptor`].
    Batch = 8,
    /// Batch-only trigger pseudo-op (ISSUE 10): wait until the u64 signal
    /// word at `dst_off` in `pe`'s heap reaches (`>=`) `inline_val`. Never
    /// travels as its own ring message — it rides inside a batched chain
    /// as a stage gate; the proxy parks the chain suffix until the
    /// condition holds.
    WaitSignal = 9,
    /// Proxy shutdown (host side only).
    Shutdown = 255,
}

impl RingOp {
    pub fn from_u8(v: u8) -> Option<RingOp> {
        Some(match v {
            0 => RingOp::Nop,
            1 => RingOp::Put,
            2 => RingOp::Get,
            3 => RingOp::PutInline,
            4 => RingOp::Amo,
            5 => RingOp::Quiet,
            6 => RingOp::PutSignal,
            7 => RingOp::Barrier,
            8 => RingOp::Batch,
            9 => RingOp::WaitSignal,
            255 => RingOp::Shutdown,
            _ => return None,
        })
    }
}

/// AMO sub-opcode carried in `flags` low byte for `RingOp::Amo`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum AmoKind {
    Set = 0,
    Fetch = 1,
    Add = 2,
    FetchAdd = 3,
    CompareSwap = 4,
    And = 5,
    Or = 6,
    Xor = 7,
    Swap = 8,
    Inc = 9,
    FetchInc = 10,
}

impl AmoKind {
    pub fn from_u8(v: u8) -> Option<AmoKind> {
        Some(match v {
            0 => AmoKind::Set,
            1 => AmoKind::Fetch,
            2 => AmoKind::Add,
            3 => AmoKind::FetchAdd,
            4 => AmoKind::CompareSwap,
            5 => AmoKind::And,
            6 => AmoKind::Or,
            7 => AmoKind::Xor,
            8 => AmoKind::Swap,
            9 => AmoKind::Inc,
            10 => AmoKind::FetchInc,
            _ => return None,
        })
    }
}

pub const MSG_SIZE: usize = 64;

/// One ring message. `#[repr(C)]` + size assertion pin the 64-byte wire
/// format; the whole struct is POD and copied by value into the ring slot.
#[derive(Clone, Copy, Debug)]
#[repr(C)]
pub struct Message {
    pub op: u8,
    /// dtype tag (ishmem::types::TypeTag) for AMO width dispatch.
    pub dtype: u8,
    /// op-specific flags; for AMO the low byte is `AmoKind`.
    pub flags: u16,
    /// Initiating PE (the proxy serves a whole node).
    pub src_pe: u32,
    /// Target PE.
    pub pe: u32,
    /// Completion slot index, or `COMPLETION_NONE` for fire-and-forget.
    pub completion: u32,
    pub dst_off: u64,
    pub src_off: u64,
    pub len: u64,
    /// Inline scalar (PutInline, AMO operand) .
    pub inline_val: u64,
    /// Second operand (CompareSwap comparand; PutSignal signal offset).
    pub inline_val2: u64,
    /// Pad to exactly one cache line (64 B wire format).
    pub _pad: u64,
}

const _: () = assert!(std::mem::size_of::<Message>() == MSG_SIZE);

impl Message {
    pub fn nop() -> Self {
        Message {
            op: RingOp::Nop as u8,
            dtype: 0,
            flags: 0,
            src_pe: 0,
            pe: 0,
            completion: super::COMPLETION_NONE,
            dst_off: 0,
            src_off: 0,
            len: 0,
            inline_val: 0,
            inline_val2: 0,
            _pad: 0,
        }
    }

    pub fn ring_op(&self) -> Option<RingOp> {
        RingOp::from_u8(self.op)
    }

    pub fn amo_kind(&self) -> Option<AmoKind> {
        AmoKind::from_u8((self.flags & 0xFF) as u8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_is_one_cache_line() {
        assert_eq!(std::mem::size_of::<Message>(), 64);
        assert_eq!(std::mem::align_of::<Message>() % 8, 0);
    }

    #[test]
    fn op_roundtrip() {
        for op in [
            RingOp::Nop,
            RingOp::Put,
            RingOp::Get,
            RingOp::PutInline,
            RingOp::Amo,
            RingOp::Quiet,
            RingOp::PutSignal,
            RingOp::Barrier,
            RingOp::Batch,
            RingOp::WaitSignal,
            RingOp::Shutdown,
        ] {
            assert_eq!(RingOp::from_u8(op as u8), Some(op));
        }
        assert_eq!(RingOp::from_u8(99), None);
    }

    #[test]
    fn amo_kind_roundtrip() {
        for k in 0..=10u8 {
            assert_eq!(AmoKind::from_u8(k).map(|x| x as u8), Some(k));
        }
        assert_eq!(AmoKind::from_u8(11), None);
    }
}
