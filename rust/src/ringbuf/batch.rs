//! Batch descriptor codec: the on-heap wire format behind `RingOp::Batch`.
//!
//! Batched submission replaces one 64-byte ring message *per op* with one
//! ring doorbell *per plan-group*: the initiator writes a block of
//! fixed-size descriptors into its staging slab (device symmetric heap)
//! and posts a single `Batch` message pointing at the block. The proxy
//! reads the block back out of the initiator's heap and dispatches each
//! entry under its own command-list policy (paper §III-C: immediate vs
//! standard command lists, chosen per descriptor).
//!
//! The codec is explicit little-endian field-by-field serialization — no
//! `unsafe`, no `repr` tricks — so a layout drift between the device-side
//! encoder and the proxy-side decoder is impossible to introduce silently
//! (round-trip is property-tested in `tests/prop_invariants.rs`).

use super::message::RingOp;

/// Encoded size of one descriptor, bytes.
pub const DESC_SIZE: usize = 48;

/// Descriptor flag: this entry is part of a *triggered chain* (ISSUE 10)
/// and carries a stage number — see [`BatchDescriptor::with_stage`]. The
/// proxy dispatches a batch stage by stage: every entry of stage `s`
/// waits for all entries of stages `< s` to complete (the predecessor
/// completion event), and a NACKed predecessor stage suppresses all later
/// stages un-dispatched. Bit 8 is free on every descriptor kind (the
/// Message-level `FLAG_RAW_PTR` never appears in descriptors).
pub const DESC_FLAG_TRIGGERED: u16 = 1 << 8;

/// Descriptor flag: this entry executes on a *standard* command list
/// (append → close → execute on a queue); clear = immediate command list.
/// Same bit position for every op kind.
pub const DESC_FLAG_STANDARD_CL: u16 = 1 << 9;

/// Descriptor flag: this entry is one chunk of a striped transfer —
/// `inline_val` carries the continuation fields (chunk index, chunk
/// count, engine hint; see [`BatchDescriptor::with_chunk`]). Only set on
/// Put/Get entries, whose `inline_val` is otherwise unused.
pub const DESC_FLAG_CHUNKED: u16 = 1 << 10;

/// Widest chunk index / chunk count the continuation field can carry.
pub const CHUNK_FIELD_MAX: u32 = (1 << 24) - 1;

/// Descriptor flag: this entry carries a payload checksum the proxy must
/// verify before dispatch (reliability layer, `retry.enable`). Where the
/// 16-bit sum lives depends on the entry shape — see
/// [`BatchDescriptor::with_checksum`].
pub const DESC_FLAG_CHECKSUM: u16 = 1 << 11;

/// Bit position of the 4-bit replay-attempt counter inside `flags`
/// (bits 12–15). Attempt 0 is the first transmission; replays stamp
/// 1, 2, … so the proxy can tag its wall-time observations and the
/// calibrator can discard retried samples.
pub const ATTEMPT_SHIFT: u16 = 12;

/// Widest replay attempt the flag field can carry (bounds
/// `retry.max_attempts`).
pub const ATTEMPT_MAX: u16 = 0xF;

/// Low 48 bits of `inline_val2`: the whole-transfer byte count on
/// chunked entries once a checksum occupies the top 16 bits.
pub const TRANSFER_BYTES_MAX: u64 = (1 << 48) - 1;

/// 16-bit payload checksum: 64-bit FNV-1a folded by XOR into 16 bits.
/// Not cryptographic — it exists to catch staging/fabric corruption of a
/// chunk's bytes, exactly like a NIC-level CRC would, and to give the
/// fault plane a deterministic verification point to force-fail.
pub fn payload_checksum(bytes: &[u8]) -> u16 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    (h ^ (h >> 16) ^ (h >> 32) ^ (h >> 48)) as u16
}

/// One batched-operation descriptor. Offsets are symmetric-heap byte
/// offsets: `src_off`/`dst_off` never carry raw pointers — raw-pointer
/// payloads are staged through the slab before the descriptor is written,
/// which is what lets the proxy run real `DeviceAddr` command lists.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchDescriptor {
    /// Entry operation (`RingOp::Put`, `Get`, `PutInline`, or `Amo`).
    pub op: u8,
    /// dtype tag for AMO width dispatch (0 otherwise).
    pub dtype: u8,
    /// `DESC_FLAG_*` bits; for AMO entries the low byte is `AmoKind`.
    pub flags: u16,
    /// Target PE.
    pub pe: u32,
    /// Destination heap offset (target PE for puts, initiator slab for
    /// gets).
    pub dst_off: u64,
    /// Source heap offset (initiator heap/slab for puts, target PE for
    /// gets).
    pub src_off: u64,
    /// Payload length, bytes.
    pub len: u64,
    /// Inline scalar (PutInline payload, AMO operand).
    pub inline_val: u64,
    /// Second operand (AMO comparand).
    pub inline_val2: u64,
}

impl BatchDescriptor {
    /// A zeroed put-shaped descriptor (builder convenience).
    pub fn put(pe: usize, dst_off: usize, src_off: usize, len: usize) -> Self {
        BatchDescriptor {
            op: RingOp::Put as u8,
            dtype: 0,
            flags: 0,
            pe: pe as u32,
            dst_off: dst_off as u64,
            src_off: src_off as u64,
            len: len as u64,
            inline_val: 0,
            inline_val2: 0,
        }
    }

    /// A get-shaped descriptor: remote `src_off` on `pe` lands at the
    /// initiator-slab `dst_off`.
    pub fn get(pe: usize, dst_off: usize, src_off: usize, len: usize) -> Self {
        BatchDescriptor { op: RingOp::Get as u8, ..Self::put(pe, dst_off, src_off, len) }
    }

    /// A non-fetching AMO entry (fire-and-forget atomics batch through the
    /// stream; fetching kinds gate their caller and ship their own
    /// message). The kind rides in the low flag byte, mirroring
    /// `Message::amo_kind`.
    pub fn amo(pe: usize, dst_off: usize, dtype: u8, kind: u8, operand: u64, comparand: u64) -> Self {
        BatchDescriptor {
            op: RingOp::Amo as u8,
            dtype,
            flags: kind as u16,
            pe: pe as u32,
            dst_off: dst_off as u64,
            src_off: 0,
            len: 0,
            inline_val: operand,
            inline_val2: comparand,
        }
    }

    /// A chain-trigger gate (batch-only pseudo-op, never its own ring
    /// message): wait until the u64 signal word at heap offset `sig_off`
    /// on `pe` reaches (`>=`) `target`. Entries of the same and later
    /// stages dispatch only once the condition holds; the proxy parks the
    /// chain suffix in its pending-trigger table when it does not.
    pub fn wait_signal(pe: usize, sig_off: usize, target: u64) -> Self {
        BatchDescriptor {
            op: RingOp::WaitSignal as u8,
            dtype: 0,
            flags: 0,
            pe: pe as u32,
            dst_off: sig_off as u64,
            src_off: 0,
            len: 0,
            inline_val: target,
            inline_val2: 0,
        }
    }

    /// Stamp the chain-stage number on this entry and mark it triggered.
    /// The stage rides the `dtype` byte for Put/Get/PutInline/WaitSignal
    /// entries (which never use dtype) and the low byte of `src_off` for
    /// Amo entries (whose source offset is always 0) — so the stage never
    /// collides with the chunk/checksum/attempt packings in
    /// `inline_val`/`inline_val2`/`flags`. Apply before `with_checksum`
    /// by convention (stage fields are disjoint from the sum, but builder
    /// chains read better stamped in wire order).
    pub fn with_stage(mut self, stage: u8) -> Self {
        self.flags |= DESC_FLAG_TRIGGERED;
        if self.op == RingOp::Amo as u8 {
            self.src_off = (self.src_off & !0xFF) | stage as u64;
        } else {
            self.dtype = stage;
        }
        self
    }

    /// Whether this entry is part of a triggered chain.
    pub fn is_triggered(&self) -> bool {
        self.flags & DESC_FLAG_TRIGGERED != 0
    }

    /// Chain stage of this entry (0 for every non-chain entry, so a batch
    /// with no triggered descriptors is one all-stage-0 group — exactly
    /// the pre-chain dispatch order).
    pub fn chain_stage(&self) -> u8 {
        if !self.is_triggered() {
            return 0;
        }
        if self.op == RingOp::Amo as u8 {
            (self.src_off & 0xFF) as u8
        } else {
            self.dtype
        }
    }

    /// Mark this entry as chunk `index` of `count` in a striped transfer,
    /// bound for engine slot `engine` on the initiator's GPU. The
    /// continuation fields pack into `inline_val` (bits 0–23 index,
    /// 24–47 count, 48–55 engine), which Put/Get entries never use.
    /// Un-striped engine-route entries use the degenerate `(0, 1, eng)`
    /// shape purely to carry their engine placement to the proxy.
    pub fn with_chunk(mut self, index: u32, count: u32, engine: u8) -> Self {
        assert!(index <= CHUNK_FIELD_MAX && count <= CHUNK_FIELD_MAX, "chunk field overflow");
        assert!(
            self.flags & DESC_FLAG_CHECKSUM == 0,
            "with_chunk overwrites inline_val: stamp the checksum last"
        );
        self.flags |= DESC_FLAG_CHUNKED;
        self.inline_val =
            index as u64 | ((count as u64) << 24) | ((engine as u64) << 48);
        self
    }

    /// Whether this entry is one chunk of a striped transfer.
    pub fn is_chunked(&self) -> bool {
        self.flags & DESC_FLAG_CHUNKED != 0
    }

    /// Chunk index within the transfer (0 for un-chunked entries).
    pub fn chunk_index(&self) -> u32 {
        if self.is_chunked() {
            (self.inline_val & CHUNK_FIELD_MAX as u64) as u32
        } else {
            0
        }
    }

    /// Total chunks in the transfer (1 for un-chunked entries).
    pub fn chunk_count(&self) -> u32 {
        if self.is_chunked() {
            ((self.inline_val >> 24) & CHUNK_FIELD_MAX as u64) as u32
        } else {
            1
        }
    }

    /// Engine slot this chunk should dispatch on (0 when un-chunked —
    /// the proxy's default standard command list).
    pub fn engine_hint(&self) -> usize {
        if self.is_chunked() {
            ((self.inline_val >> 48) & 0xFF) as usize
        } else {
            0
        }
    }

    /// The same continuation field read as a NIC-rail slot: inter-node
    /// chunks carry which rail's in-flight command sequence should inject
    /// them (the proxy dispatches one sequence per rail per batch).
    pub fn rail_hint(&self) -> usize {
        self.engine_hint()
    }

    /// Stamp the whole transfer's byte count on a chunked Put/Get entry
    /// (`inline_val2`, unused by those op kinds): the proxy's wall-clock
    /// service ledger buckets every chunk by its transfer's size, exactly
    /// matching the executor's one whole-transfer model charge.
    pub fn with_transfer_bytes(mut self, bytes: u64) -> Self {
        assert!(
            self.flags & DESC_FLAG_CHECKSUM == 0,
            "with_transfer_bytes overwrites inline_val2: stamp the checksum last"
        );
        self.inline_val2 = bytes;
        self
    }

    /// Byte count of the whole transfer this entry belongs to: the
    /// stamped total for chunked entries, the entry's own length
    /// otherwise. When a checksum occupies the top 16 bits of
    /// `inline_val2` only the low 48 count (transfers above 256 TiB per
    /// call do not exist in this machine).
    pub fn transfer_bytes(&self) -> u64 {
        let stamped = if self.has_checksum() && self.is_chunked() {
            self.inline_val2 & TRANSFER_BYTES_MAX
        } else {
            self.inline_val2
        };
        if self.is_chunked() && stamped > 0 {
            stamped
        } else {
            self.len
        }
    }

    /// Stamp a payload checksum on a Put-shaped entry. Must be applied
    /// *after* `with_chunk`/`with_transfer_bytes` (those overwrite the
    /// fields the sum packs into): chunked entries keep their
    /// continuation word, so the sum rides the top 16 bits of
    /// `inline_val2` (transfer bytes keep the low 48); un-chunked puts
    /// park it in the low 16 bits of the otherwise-unused `inline_val`.
    pub fn with_checksum(mut self, sum: u16) -> Self {
        if self.is_chunked() {
            assert!(
                self.inline_val2 <= TRANSFER_BYTES_MAX,
                "transfer_bytes overflows the 48-bit checksum layout"
            );
            self.inline_val2 |= (sum as u64) << 48;
        } else {
            self.inline_val = (self.inline_val & !0xFFFF) | sum as u64;
        }
        self.flags |= DESC_FLAG_CHECKSUM;
        self
    }

    /// Whether a checksum is stamped on this entry.
    pub fn has_checksum(&self) -> bool {
        self.flags & DESC_FLAG_CHECKSUM != 0
    }

    /// The stamped payload checksum, if any.
    pub fn checksum(&self) -> Option<u16> {
        if !self.has_checksum() {
            return None;
        }
        Some(if self.is_chunked() {
            (self.inline_val2 >> 48) as u16
        } else {
            (self.inline_val & 0xFFFF) as u16
        })
    }

    /// Stamp the replay-attempt counter (0 = first transmission). The
    /// replay loop re-posts NACKed entries with 1, 2, …; saturates at
    /// [`ATTEMPT_MAX`], which `retry.max_attempts` is validated against.
    pub fn with_attempt(mut self, attempt: u16) -> Self {
        assert!(attempt <= ATTEMPT_MAX, "attempt counter overflow");
        self.flags = (self.flags & !(ATTEMPT_MAX << ATTEMPT_SHIFT)) | (attempt << ATTEMPT_SHIFT);
        self
    }

    /// Replay attempt this entry is on (0 = first transmission).
    pub fn attempt(&self) -> u16 {
        (self.flags >> ATTEMPT_SHIFT) & ATTEMPT_MAX
    }

    /// Whether this entry asks for a standard command list.
    pub fn standard_cl(&self) -> bool {
        self.flags & DESC_FLAG_STANDARD_CL != 0
    }

    pub fn with_standard_cl(mut self, standard: bool) -> Self {
        if standard {
            self.flags |= DESC_FLAG_STANDARD_CL;
        } else {
            self.flags &= !DESC_FLAG_STANDARD_CL;
        }
        self
    }

    pub fn ring_op(&self) -> Option<RingOp> {
        RingOp::from_u8(self.op)
    }

    /// Serialize into the 48-byte wire form (little-endian fields).
    pub fn to_bytes(&self) -> [u8; DESC_SIZE] {
        let mut b = [0u8; DESC_SIZE];
        b[0] = self.op;
        b[1] = self.dtype;
        b[2..4].copy_from_slice(&self.flags.to_le_bytes());
        b[4..8].copy_from_slice(&self.pe.to_le_bytes());
        b[8..16].copy_from_slice(&self.dst_off.to_le_bytes());
        b[16..24].copy_from_slice(&self.src_off.to_le_bytes());
        b[24..32].copy_from_slice(&self.len.to_le_bytes());
        b[32..40].copy_from_slice(&self.inline_val.to_le_bytes());
        b[40..48].copy_from_slice(&self.inline_val2.to_le_bytes());
        b
    }

    /// Decode one descriptor; `None` if the op byte is not a valid
    /// `RingOp` (corrupt block — the proxy treats this as fatal).
    pub fn from_bytes(b: &[u8; DESC_SIZE]) -> Option<Self> {
        let d = BatchDescriptor {
            op: b[0],
            dtype: b[1],
            flags: u16::from_le_bytes(b[2..4].try_into().unwrap()),
            pe: u32::from_le_bytes(b[4..8].try_into().unwrap()),
            dst_off: u64::from_le_bytes(b[8..16].try_into().unwrap()),
            src_off: u64::from_le_bytes(b[16..24].try_into().unwrap()),
            len: u64::from_le_bytes(b[24..32].try_into().unwrap()),
            inline_val: u64::from_le_bytes(b[32..40].try_into().unwrap()),
            inline_val2: u64::from_le_bytes(b[40..48].try_into().unwrap()),
        };
        RingOp::from_u8(d.op)?;
        Some(d)
    }

    /// Serialize a whole descriptor block (the bytes written to the slab).
    pub fn encode_block(descs: &[BatchDescriptor]) -> Vec<u8> {
        let mut out = Vec::with_capacity(descs.len() * DESC_SIZE);
        for d in descs {
            out.extend_from_slice(&d.to_bytes());
        }
        out
    }

    /// Decode a block of `n` descriptors; `None` on short buffers or a
    /// corrupt entry.
    pub fn decode_block(bytes: &[u8], n: usize) -> Option<Vec<BatchDescriptor>> {
        if bytes.len() < n * DESC_SIZE {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let chunk: &[u8; DESC_SIZE] =
                bytes[i * DESC_SIZE..(i + 1) * DESC_SIZE].try_into().unwrap();
            out.push(BatchDescriptor::from_bytes(chunk)?);
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptor_roundtrips() {
        let d = BatchDescriptor {
            op: RingOp::Put as u8,
            dtype: 3,
            flags: DESC_FLAG_STANDARD_CL | 0x5,
            pe: 11,
            dst_off: 0xDEAD_BEEF,
            src_off: 0x1234_5678_9ABC,
            len: 4096,
            inline_val: u64::MAX,
            inline_val2: 7,
        };
        assert_eq!(BatchDescriptor::from_bytes(&d.to_bytes()), Some(d));
    }

    #[test]
    fn bad_op_rejected() {
        let mut b = BatchDescriptor::put(1, 0, 0, 8).to_bytes();
        b[0] = 99; // not a RingOp
        assert_eq!(BatchDescriptor::from_bytes(&b), None);
    }

    #[test]
    fn block_roundtrips() {
        let descs: Vec<_> = (0..5)
            .map(|i| BatchDescriptor::put(i, i * 64, i * 128, 32).with_standard_cl(i % 2 == 0))
            .collect();
        let bytes = BatchDescriptor::encode_block(&descs);
        assert_eq!(bytes.len(), 5 * DESC_SIZE);
        assert_eq!(BatchDescriptor::decode_block(&bytes, 5), Some(descs));
        assert_eq!(BatchDescriptor::decode_block(&bytes[..40], 5), None);
    }

    #[test]
    fn chunk_fields_pack_and_roundtrip() {
        let d = BatchDescriptor::put(3, 4096, 8192, 1 << 20)
            .with_chunk(5, 9, 6)
            .with_transfer_bytes(9 << 20);
        assert!(d.is_chunked());
        assert_eq!(d.chunk_index(), 5);
        assert_eq!(d.chunk_count(), 9);
        assert_eq!(d.engine_hint(), 6);
        assert_eq!(d.rail_hint(), 6);
        assert_eq!(d.transfer_bytes(), 9 << 20);
        // Un-stamped entries fall back to their own length.
        let u = BatchDescriptor::put(3, 0, 0, 4096);
        assert_eq!(u.transfer_bytes(), 4096);
        assert_eq!(BatchDescriptor::from_bytes(&d.to_bytes()), Some(d));
        // Un-chunked entries report the identity shape.
        let p = BatchDescriptor::put(3, 0, 0, 64);
        assert!(!p.is_chunked());
        assert_eq!((p.chunk_index(), p.chunk_count(), p.engine_hint()), (0, 1, 0));
        // Extremes of the packed fields survive.
        let d = BatchDescriptor::get(0, 0, 0, 8).with_chunk(CHUNK_FIELD_MAX, CHUNK_FIELD_MAX, 255);
        assert_eq!(d.chunk_index(), CHUNK_FIELD_MAX);
        assert_eq!(d.chunk_count(), CHUNK_FIELD_MAX);
        assert_eq!(d.engine_hint(), 255);
    }

    #[test]
    fn amo_descriptor_carries_kind_and_operands() {
        use crate::ringbuf::message::AmoKind;
        let d = BatchDescriptor::amo(4, 128, 7, AmoKind::Add as u8, 42, 9);
        assert_eq!(d.ring_op(), Some(RingOp::Amo));
        assert_eq!(d.flags & 0xFF, AmoKind::Add as u8 as u16);
        assert_eq!((d.inline_val, d.inline_val2), (42, 9));
        assert_eq!(BatchDescriptor::from_bytes(&d.to_bytes()), Some(d));
    }

    #[test]
    fn checksum_packs_without_disturbing_continuation_fields() {
        // Chunked: sum rides inline_val2[48..64], transfer bytes keep 48.
        let d = BatchDescriptor::put(3, 4096, 8192, 1 << 20)
            .with_chunk(5, 9, 6)
            .with_transfer_bytes(9 << 20)
            .with_checksum(0xBEEF);
        assert!(d.has_checksum());
        assert_eq!(d.checksum(), Some(0xBEEF));
        assert_eq!(d.chunk_index(), 5);
        assert_eq!(d.chunk_count(), 9);
        assert_eq!(d.engine_hint(), 6);
        assert_eq!(d.transfer_bytes(), 9 << 20);
        assert_eq!(BatchDescriptor::from_bytes(&d.to_bytes()), Some(d));
        // Un-chunked: sum parks in inline_val's low 16 bits.
        let p = BatchDescriptor::put(1, 0, 0, 256).with_checksum(0x1234);
        assert_eq!(p.checksum(), Some(0x1234));
        assert_eq!(p.transfer_bytes(), 256);
        // No flag → no sum, even with residue in the field.
        let bare = BatchDescriptor::put(1, 0, 0, 8);
        assert_eq!(bare.checksum(), None);
    }

    #[test]
    fn attempt_counter_roundtrips_and_saturates_at_max() {
        let d = BatchDescriptor::put(0, 0, 0, 64);
        assert_eq!(d.attempt(), 0);
        for a in 0..=ATTEMPT_MAX {
            let r = d.with_attempt(a);
            assert_eq!(r.attempt(), a);
            assert_eq!(BatchDescriptor::from_bytes(&r.to_bytes()), Some(r));
        }
        // Re-stamping replaces, never accumulates.
        assert_eq!(d.with_attempt(3).with_attempt(1).attempt(), 1);
        // Attempt bits leave the CL/chunk/checksum flags alone.
        let rich = BatchDescriptor::put(0, 0, 0, 64)
            .with_standard_cl(true)
            .with_checksum(0xFFFF)
            .with_attempt(ATTEMPT_MAX);
        assert!(rich.standard_cl() && rich.has_checksum());
        assert_eq!(rich.checksum(), Some(0xFFFF));
    }

    #[test]
    fn chain_stage_packs_and_roundtrips() {
        // Put/Get: stage rides the dtype byte.
        let d = BatchDescriptor::put(2, 512, 1024, 4096).with_stage(3);
        assert!(d.is_triggered());
        assert_eq!(d.chain_stage(), 3);
        assert_eq!(BatchDescriptor::from_bytes(&d.to_bytes()), Some(d));
        let g = BatchDescriptor::get(1, 0, 64, 8).with_stage(255);
        assert_eq!(g.chain_stage(), 255);
        // Amo: stage rides the low byte of the always-zero src_off.
        let a = BatchDescriptor::amo(4, 128, 7, 2, 42, 9).with_stage(5);
        assert_eq!(a.chain_stage(), 5);
        assert_eq!(a.dtype, 7, "AMO width dispatch byte untouched");
        assert_eq!((a.inline_val, a.inline_val2), (42, 9));
        assert_eq!(BatchDescriptor::from_bytes(&a.to_bytes()), Some(a));
        // Non-chain entries always report stage 0, even with dtype residue.
        let plain = BatchDescriptor::amo(4, 128, 7, 2, 42, 9);
        assert!(!plain.is_triggered());
        assert_eq!(plain.chain_stage(), 0);
    }

    #[test]
    fn wait_signal_descriptor_roundtrips() {
        let w = BatchDescriptor::wait_signal(6, 4096, 0xFEED_F00D).with_stage(2);
        assert_eq!(w.ring_op(), Some(RingOp::WaitSignal));
        assert_eq!(w.pe, 6);
        assert_eq!(w.dst_off, 4096);
        assert_eq!(w.inline_val, 0xFEED_F00D);
        assert_eq!(w.len, 0, "trigger gates carry no payload");
        assert_eq!(w.chain_stage(), 2);
        assert_eq!(BatchDescriptor::from_bytes(&w.to_bytes()), Some(w));
    }

    #[test]
    fn triggered_flag_is_disjoint_from_cl_chunk_checksum_attempt_bits() {
        assert_eq!(DESC_FLAG_TRIGGERED & DESC_FLAG_STANDARD_CL, 0);
        assert_eq!(DESC_FLAG_TRIGGERED & DESC_FLAG_CHUNKED, 0);
        assert_eq!(DESC_FLAG_TRIGGERED & DESC_FLAG_CHECKSUM, 0);
        assert_eq!(DESC_FLAG_TRIGGERED & (ATTEMPT_MAX << ATTEMPT_SHIFT), 0);
        // A maximally-decorated chained chunk keeps every field readable.
        let d = BatchDescriptor::put(3, 4096, 8192, 1 << 20)
            .with_stage(2)
            .with_standard_cl(true)
            .with_chunk(5, 9, 6)
            .with_transfer_bytes(9 << 20)
            .with_checksum(0xBEEF)
            .with_attempt(3);
        assert!(d.is_triggered() && d.standard_cl() && d.is_chunked() && d.has_checksum());
        assert_eq!(d.chain_stage(), 2);
        assert_eq!((d.chunk_index(), d.chunk_count(), d.engine_hint()), (5, 9, 6));
        assert_eq!(d.transfer_bytes(), 9 << 20);
        assert_eq!(d.checksum(), Some(0xBEEF));
        assert_eq!(d.attempt(), 3);
        assert_eq!(BatchDescriptor::from_bytes(&d.to_bytes()), Some(d));
    }

    #[test]
    fn payload_checksum_detects_single_byte_flips() {
        let payload: Vec<u8> = (0..4096u32).map(|i| (i * 31 + 7) as u8).collect();
        let sum = payload_checksum(&payload);
        assert_eq!(payload_checksum(&payload), sum, "deterministic");
        let mut flipped = payload.clone();
        flipped[1234] ^= 0x01;
        assert_ne!(payload_checksum(&flipped), sum, "single bit flip must change the sum");
        assert_ne!(payload_checksum(&[]), payload_checksum(&[0]), "length-extension aware");
    }

    #[test]
    fn cl_policy_flag() {
        let d = BatchDescriptor::put(0, 0, 0, 8);
        assert!(!d.standard_cl());
        assert!(d.with_standard_cl(true).standard_cl());
        assert!(!d.with_standard_cl(true).with_standard_cl(false).standard_cl());
    }
}
