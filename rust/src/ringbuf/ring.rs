//! The lock-free MPSC message ring (paper §III-D).
//!
//! Vyukov-style bounded queue specialized to a single consumer (the host
//! proxy thread): producers claim a slot with one `fetch_add` on the
//! enqueue cursor — the paper's "single atomic fetch and increment,
//! providing fast arbitration among thousands of GPU threads" — write the
//! 64-byte message, then publish it by bumping the slot's sequence number
//! (the "single bus operation" store; fire-and-forget).
//!
//! Flow control is off the critical path: a producer only ever waits when
//! the ring is genuinely full (it spins on the slot sequence), and the
//! consumer recycles slots immediately after copying the message out.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::message::Message;

struct Slot {
    /// Vyukov sequence: `pos` ⇒ free for the producer of ticket `pos`;
    /// `pos + 1` ⇒ full, readable by the consumer at `pos`.
    seq: AtomicU64,
    msg: UnsafeCell<Message>,
}

// SAFETY: slot contents are only touched by the ticket holder (producer)
// or the consumer after observing the matching seq with Acquire ordering.
unsafe impl Sync for Slot {}

pub struct Ring {
    slots: Box<[Slot]>,
    mask: u64,
    enqueue: AtomicU64,
    /// Consumer cursor — only `RingConsumer` advances it, but it is atomic
    /// so producers can read an (approximate) fill level for stats.
    dequeue: AtomicU64,
}

impl Ring {
    /// `capacity` must be a power of two (mask indexing).
    pub fn new(capacity: usize) -> Arc<Self> {
        assert!(capacity.is_power_of_two() && capacity >= 2);
        let slots = (0..capacity)
            .map(|i| Slot {
                seq: AtomicU64::new(i as u64),
                msg: UnsafeCell::new(Message::nop()),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Arc::new(Ring {
            slots,
            mask: (capacity - 1) as u64,
            enqueue: AtomicU64::new(0),
            dequeue: AtomicU64::new(0),
        })
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Approximate number of queued messages (stats only).
    pub fn len(&self) -> usize {
        let e = self.enqueue.load(Ordering::Relaxed);
        let d = self.dequeue.load(Ordering::Relaxed);
        e.saturating_sub(d) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Post a message; spins only when the ring is full (flow control is
    /// not in the critical path — paper claims <1% overhead).
    pub fn send(&self, msg: Message) {
        // THE single atomic fetch-and-increment.
        let ticket = self.enqueue.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket & self.mask) as usize];
        // Wait for the slot to be recycled (only under backpressure).
        let mut spins = 0u32;
        while slot.seq.load(Ordering::Acquire) != ticket {
            spins += 1;
            if spins > 64 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        // SAFETY: we hold ticket `ticket`; nobody else may touch this slot
        // until we publish seq = ticket + 1.
        unsafe { *slot.msg.get() = msg };
        slot.seq.store(ticket + 1, Ordering::Release);
    }

    /// Create the unique consumer handle. Call once.
    pub fn consumer(self: &Arc<Self>) -> RingConsumer {
        RingConsumer { ring: Arc::clone(self), pos: 0 }
    }
}

/// The single consumer (host proxy thread). Holding it by value enforces
/// the SC in MPSC at compile time.
pub struct RingConsumer {
    ring: Arc<Ring>,
    pos: u64,
}

impl RingConsumer {
    /// Non-blocking poll: copy out the next message if one is ready.
    pub fn try_recv(&mut self) -> Option<Message> {
        let slot = &self.ring.slots[(self.pos & self.ring.mask) as usize];
        if slot.seq.load(Ordering::Acquire) != self.pos + 1 {
            return None;
        }
        // SAFETY: seq == pos+1 means the producer fully published this slot
        // and no other producer can claim it until we recycle it below.
        let msg = unsafe { *slot.msg.get() };
        // Recycle for the producer of ticket pos + capacity.
        slot.seq
            .store(self.pos + self.ring.capacity() as u64, Ordering::Release);
        self.pos += 1;
        self.ring.dequeue.store(self.pos, Ordering::Relaxed);
        Some(msg)
    }

    /// Blocking receive with spin→yield backoff.
    pub fn recv(&mut self) -> Message {
        let mut spins = 0u32;
        loop {
            if let Some(m) = self.try_recv() {
                return m;
            }
            spins += 1;
            if spins > 128 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    /// Drain up to `max` pending messages into `out` (batch service).
    pub fn recv_batch(&mut self, out: &mut Vec<Message>, max: usize) -> usize {
        let mut n = 0;
        while n < max {
            match self.try_recv() {
                Some(m) => {
                    out.push(m);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ringbuf::message::RingOp;

    #[test]
    fn single_thread_fifo() {
        let ring = Ring::new(8);
        let mut cons = ring.consumer();
        for i in 0..20u64 {
            let mut m = Message::nop();
            m.inline_val = i;
            ring.send(m);
            assert_eq!(cons.recv().inline_val, i);
        }
        assert!(cons.try_recv().is_none());
    }

    #[test]
    fn wraps_past_capacity() {
        let ring = Ring::new(4);
        let mut cons = ring.consumer();
        for round in 0..10u64 {
            for i in 0..4u64 {
                let mut m = Message::nop();
                m.inline_val = round * 4 + i;
                ring.send(m);
            }
            for i in 0..4u64 {
                assert_eq!(cons.recv().inline_val, round * 4 + i);
            }
        }
    }

    #[test]
    fn multi_producer_no_loss_no_dup() {
        const PRODUCERS: u64 = 8;
        const PER: u64 = 2_000;
        let ring = Ring::new(256);
        let mut cons = ring.consumer();
        let mut handles = vec![];
        for p in 0..PRODUCERS {
            let r = Arc::clone(&ring);
            handles.push(std::thread::spawn(move || {
                for i in 0..PER {
                    let mut m = Message::nop();
                    m.op = RingOp::Put as u8;
                    m.src_pe = p as u32;
                    m.inline_val = i;
                    r.send(m);
                }
            }));
        }
        let mut seen = vec![vec![]; PRODUCERS as usize];
        for _ in 0..PRODUCERS * PER {
            let m = cons.recv();
            seen[m.src_pe as usize].push(m.inline_val);
        }
        for h in handles {
            h.join().unwrap();
        }
        for (p, vals) in seen.iter().enumerate() {
            assert_eq!(vals.len() as u64, PER, "producer {p} message count");
            // Per-producer order is preserved (each producer's sends are
            // sequenced by its own ticket order).
            let mut sorted = vals.clone();
            sorted.sort_unstable();
            assert_eq!(&sorted, vals, "producer {p} order");
        }
        assert!(cons.try_recv().is_none());
    }

    #[test]
    fn batch_recv() {
        let ring = Ring::new(16);
        let mut cons = ring.consumer();
        for i in 0..10u64 {
            let mut m = Message::nop();
            m.inline_val = i;
            ring.send(m);
        }
        let mut out = Vec::new();
        assert_eq!(cons.recv_batch(&mut out, 6), 6);
        assert_eq!(cons.recv_batch(&mut out, 100), 4);
        assert_eq!(out.len(), 10);
    }

    #[test]
    #[should_panic]
    fn capacity_must_be_power_of_two() {
        Ring::new(6);
    }
}
