//! OFI-libfabric-like transport over the simulated NIC (paper §III-C/E).
//!
//! SOS reaches remote nodes through libfabric providers with `FI_HMEM`
//! (device-memory) support. The behaviours ishmem depends on:
//!
//!   * one-sided put/get between *registered* symmetric regions;
//!   * RDMA lands directly in GPU memory iff the target heap was
//!     registered (`FI_MR_HMEM`) during postinit — otherwise traffic
//!     bounces through host memory at a penalty (failure-injection tests
//!     exercise this);
//!   * remote AMOs executed at the target NIC.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::sim::memory::HeapRegistry;
use crate::sim::{CostModel, SimClock};

#[derive(Debug)]
pub enum TransportError {
    Unregistered(usize),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Unregistered(pe) => write!(
                f,
                "target PE {pe} heap not registered for FI_HMEM and strict mode is on"
            ),
        }
    }
}

impl std::error::Error for TransportError {}

/// Node-level transport endpoint (one per host proxy).
pub struct OfiTransport {
    heaps: Arc<HeapRegistry>,
    cost: Arc<CostModel>,
    /// Per-PE "device heap registered with the NIC" bits, set by postinit.
    registered: Vec<std::sync::atomic::AtomicBool>,
    /// Strict mode: error instead of bouncing when unregistered.
    pub strict_hmem: bool,
}

impl OfiTransport {
    pub fn new(heaps: Arc<HeapRegistry>, cost: Arc<CostModel>) -> Self {
        let npes = heaps.npes();
        OfiTransport {
            heaps,
            cost,
            registered: (0..npes)
                .map(|_| std::sync::atomic::AtomicBool::new(false))
                .collect(),
            strict_hmem: false,
        }
    }

    /// Mark `pe`'s device heap as FI_MR_HMEM-registered (postinit).
    pub fn register_heap(&self, pe: usize) {
        self.registered[pe].store(true, Ordering::Release);
    }

    pub fn is_registered(&self, pe: usize) -> bool {
        self.registered[pe].load(Ordering::Acquire)
    }

    /// One-sided put: initiator-side buffer → target PE heap.
    pub fn put(
        &self,
        src_pe: usize,
        src_off: usize,
        dst_pe: usize,
        dst_off: usize,
        len: usize,
        clock: &SimClock,
    ) -> Result<(), TransportError> {
        let registered = self.is_registered(dst_pe);
        if !registered && self.strict_hmem {
            return Err(TransportError::Unregistered(dst_pe));
        }
        self.heaps.copy(src_pe, src_off, dst_pe, dst_off, len);
        clock.advance(self.wire_ns(len, registered));
        Ok(())
    }

    /// One-sided get: target PE heap → initiator-side buffer.
    pub fn get(
        &self,
        src_pe: usize,
        src_off: usize,
        dst_pe: usize,
        dst_off: usize,
        len: usize,
        clock: &SimClock,
    ) -> Result<(), TransportError> {
        let registered = self.is_registered(src_pe);
        if !registered && self.strict_hmem {
            return Err(TransportError::Unregistered(src_pe));
        }
        self.heaps.copy(src_pe, src_off, dst_pe, dst_off, len);
        clock.advance(self.wire_ns(len, registered));
        Ok(())
    }

    /// Put from a raw in-process pointer (the initiator's private buffer —
    /// OpenSHMEM permits non-symmetric sources). Used by the host proxy,
    /// which receives raw pointers through ring messages.
    ///
    /// # Safety contract
    /// The pointer must stay valid for the duration of the call; blocking
    /// initiators guarantee this by waiting on the completion.
    pub fn put_from_ptr(
        &self,
        src_ptr: u64,
        dst_pe: usize,
        dst_off: usize,
        len: usize,
        clock: &SimClock,
    ) -> Result<(), TransportError> {
        let registered = self.is_registered(dst_pe);
        if !registered && self.strict_hmem {
            return Err(TransportError::Unregistered(dst_pe));
        }
        // SAFETY: see contract above.
        let src = unsafe { std::slice::from_raw_parts(src_ptr as *const u8, len) };
        self.heaps.heap(dst_pe).write(dst_off, src);
        clock.advance(self.wire_ns(len, registered));
        Ok(())
    }

    /// Get into a raw in-process pointer (see `put_from_ptr`).
    pub fn get_to_ptr(
        &self,
        src_pe: usize,
        src_off: usize,
        dst_ptr: u64,
        len: usize,
        clock: &SimClock,
    ) -> Result<(), TransportError> {
        let registered = self.is_registered(src_pe);
        if !registered && self.strict_hmem {
            return Err(TransportError::Unregistered(src_pe));
        }
        // SAFETY: see `put_from_ptr` contract.
        let dst = unsafe { std::slice::from_raw_parts_mut(dst_ptr as *mut u8, len) };
        self.heaps.heap(src_pe).read(src_off, dst);
        clock.advance(self.wire_ns(len, registered));
        Ok(())
    }

    /// Remote fetch-add executed "at the target NIC" (real atomic).
    pub fn amo_fetch_add_u64(
        &self,
        dst_pe: usize,
        dst_off: usize,
        operand: u64,
        clock: &SimClock,
    ) -> Result<u64, TransportError> {
        let registered = self.is_registered(dst_pe);
        if !registered && self.strict_hmem {
            return Err(TransportError::Unregistered(dst_pe));
        }
        let old = self
            .heaps
            .heap(dst_pe)
            .atomic_u64(dst_off)
            .fetch_add(operand, Ordering::AcqRel);
        clock.advance(self.cost.params.nic.rdma_ns(8) * 2.0); // round trip
        Ok(old)
    }

    /// Small-message one-way wire latency (used by leader collectives).
    pub fn nic_latency_ns(&self) -> f64 {
        self.cost.params.nic.latency_ns
    }

    fn wire_ns(&self, len: usize, registered: bool) -> f64 {
        if registered {
            self.cost.params.nic.rdma_ns(len)
        } else {
            self.cost.params.nic.bounce_ns(len)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{CostParams, Topology};

    fn setup() -> (OfiTransport, SimClock) {
        let topo = Topology::new(2, 6, 2);
        let cost = CostModel::new(topo, CostParams::default());
        let heaps = Arc::new(HeapRegistry::new(24, 1 << 16));
        (OfiTransport::new(heaps, cost), SimClock::new())
    }

    #[test]
    fn put_moves_bytes_across_nodes() {
        let (t, clock) = setup();
        t.register_heap(12);
        t.heaps.heap(0).write(0, &[3u8; 128]);
        t.put(0, 0, 12, 256, 128, &clock).unwrap();
        let mut out = [0u8; 128];
        t.heaps.heap(12).read(256, &mut out);
        assert!(out.iter().all(|&b| b == 3));
        assert!(clock.now_ns() > 0.0);
    }

    #[test]
    fn unregistered_bounce_costs_more() {
        let (t, _) = setup();
        t.register_heap(12);
        let c1 = SimClock::new();
        t.put(0, 0, 12, 0, 1 << 16, &c1).unwrap();
        let c2 = SimClock::new();
        t.put(0, 0, 13, 0, 1 << 16, &c2).unwrap(); // 13 unregistered
        assert!(c2.now_ns() > c1.now_ns());
    }

    #[test]
    fn strict_mode_rejects_unregistered() {
        let (mut t, clock) = setup();
        t.strict_hmem = true;
        let err = t.put(0, 0, 12, 0, 64, &clock);
        assert!(matches!(err, Err(TransportError::Unregistered(12))));
        t.register_heap(12);
        t.put(0, 0, 12, 0, 64, &clock).unwrap();
    }

    #[test]
    fn remote_amo_fetches_old_value() {
        let (t, clock) = setup();
        t.register_heap(20);
        t.heaps.heap(20).atomic_u64(0).store(100, Ordering::SeqCst);
        let old = t.amo_fetch_add_u64(20, 0, 5, &clock).unwrap();
        assert_eq!(old, 100);
        assert_eq!(t.heaps.heap(20).atomic_u64(0).load(Ordering::SeqCst), 105);
    }
}
