//! SOS symmetric heaps + the external device-heap extension (paper §III-E).
//!
//! SOS owns a *host* symmetric heap; Intel SHMEM additionally registers a
//! symmetric heap resident in GPU memory through the experimental
//! extension APIs, which this module reproduces 1:1:
//!
//!   * `shmemx_heap_preinit` / `shmemx_heap_preinit_thread`
//!   * `shmemx_heap_create(base, size, kind, device)`
//!   * `shmemx_heap_postinit`
//!
//! Preinit allocates host heaps and brings up PMI; between the phases the
//! application may attach an external (device) region; postinit registers
//! every region with the NIC (`FI_MR_HMEM`) and finishes wire-up. The
//! state machine is enforced — calling out of order is an error, matching
//! SOS's dual-phase initialization contract.

use std::cell::Cell;
use std::sync::Arc;

use super::pmi::PmiHandle;
use crate::sim::memory::HeapRegistry;

/// Per-PE staging slab: a runtime-owned region at the *top* of the device
/// symmetric heap, used by the batched submission path (`xfer::stream`):
///
/// * descriptor blocks for `RingOp::Batch` messages live here, so the
///   proxy reads them straight out of the initiator's heap;
/// * raw-pointer payloads (private initiator buffers) are copied through
///   the slab, which turns every batched transfer into a heap-offset
///   transfer — the shape that executes on real `DeviceAddr` command
///   lists (paper §III-C) instead of the raw-pointer staging fallback.
///
/// Allocation is a bump arena with allocation-count reclamation: batches
/// retire in ring-FIFO order and `release` one claim per `try_alloc`;
/// once nothing is outstanding the cursor rewinds to the base, so the
/// arena never fragments. The slab is per-PE state (like `PeCtx` itself,
/// `!Sync`), so plain `Cell`s suffice.
///
/// Reliability note (`retry.enable`): claims are released only when a
/// batch's *completion* is acknowledged, never at staging — which is what
/// makes a chunk's payload bytes still be in the slab, pristine, when a
/// NACK demands an idempotent replay. The retention high-water mark below
/// makes that hold-until-ack behavior observable to tests and benches.
#[derive(Debug)]
pub struct StagingSlab {
    base: usize,
    bytes: usize,
    cursor: Cell<usize>,
    live_allocs: Cell<usize>,
    /// Deepest the bump cursor has ever reached (bytes): how much payload
    /// the slab has had to retain at once awaiting completion-acks.
    high_water: Cell<usize>,
}

impl StagingSlab {
    /// A slab covering `[base, base + bytes)` of the owning PE's heap.
    pub fn new(base: usize, bytes: usize) -> Self {
        StagingSlab {
            base,
            bytes,
            cursor: Cell::new(0),
            live_allocs: Cell::new(0),
            high_water: Cell::new(0),
        }
    }

    /// Total slab capacity, bytes.
    pub fn capacity(&self) -> usize {
        self.bytes
    }

    /// Bytes still allocatable before a drain is needed.
    pub fn available(&self) -> usize {
        self.bytes - self.cursor.get()
    }

    /// Number of claims not yet released (pending + in-flight batches).
    pub fn outstanding(&self) -> usize {
        self.live_allocs.get()
    }

    /// Claim `len` bytes (64-byte aligned); returns the heap byte offset,
    /// or `None` when the slab cannot fit the request until outstanding
    /// batches retire (caller drains and retries, or falls back to the
    /// raw-pointer path for oversized payloads).
    pub fn try_alloc(&self, len: usize) -> Option<usize> {
        let start = crate::util::round_up(self.cursor.get(), 64);
        let end = start.checked_add(len)?;
        if end > self.bytes {
            return None;
        }
        self.cursor.set(end);
        self.live_allocs.set(self.live_allocs.get() + 1);
        self.high_water.set(self.high_water.get().max(end));
        Some(self.base + start)
    }

    /// Bytes currently retained awaiting completion-acks (cursor depth).
    pub fn retained_bytes(&self) -> usize {
        self.cursor.get()
    }

    /// Deepest retention the slab has ever seen, bytes.
    pub fn high_water_bytes(&self) -> usize {
        self.high_water.get()
    }

    /// Release one claim from a retired batch. When nothing remains
    /// outstanding the cursor rewinds to the base.
    pub fn release(&self) {
        let live = self.live_allocs.get();
        assert!(live > 0, "staging slab release without a live claim");
        self.live_allocs.set(live - 1);
        if live == 1 {
            self.cursor.set(0);
        }
    }
}

/// Memory kind constants for `shmemx_heap_create` (paper lists ZE + CUDA).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExternalHeapKind {
    /// `SHMEMX_EXTERNAL_HEAP_ZE` — Level-Zero device memory (our case).
    Ze,
    /// `SHMEMX_EXTERNAL_HEAP_CUDA` — accepted by the API, unused here.
    Cuda,
}

/// Dual-phase init progress.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum HeapPhase {
    Fresh,
    Preinit,
    Postinit,
}

/// A registered external (device-resident) symmetric heap region.
#[derive(Clone, Debug)]
pub struct ExternalRegion {
    pub kind: ExternalHeapKind,
    pub device_index: usize,
    pub bytes: usize,
    /// Set during postinit: the NIC may RDMA directly into this region
    /// (FI_MR_HMEM). Before postinit the region exists but is not
    /// reachable by the wire.
    pub nic_registered: bool,
}

/// Thread-safety model: one `SosHeaps` per PE (SOS is per-process state).
pub struct SosHeaps {
    pmi: PmiHandle,
    phase: HeapPhase,
    /// Host symmetric heap (SOS's standard heap).
    host_heap_bytes: usize,
    /// The external device heap, if created.
    external: Option<ExternalRegion>,
    /// Device heap registry shared with the simulator (so the "registered"
    /// flag actually gates wire reachability in `transport`).
    device_heaps: Arc<HeapRegistry>,
    requested_threading: ThreadLevel,
}

/// OpenSHMEM threading levels (only what preinit_thread needs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ThreadLevel {
    Single,
    Funneled,
    Serialized,
    Multiple,
}

#[derive(Debug)]
pub enum HeapError {
    Phase(&'static str),
    Bounds { got: usize, max: usize },
}

impl std::fmt::Display for HeapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HeapError::Phase(what) => write!(f, "dual-phase init violation: {what}"),
            HeapError::Bounds { got, max } => {
                write!(f, "external heap bounds exceed device heap: {got} > {max}")
            }
        }
    }
}

impl std::error::Error for HeapError {}

impl SosHeaps {
    pub fn new(pmi: PmiHandle, device_heaps: Arc<HeapRegistry>, host_heap_bytes: usize) -> Self {
        SosHeaps {
            pmi,
            phase: HeapPhase::Fresh,
            host_heap_bytes,
            external: None,
            device_heaps,
            requested_threading: ThreadLevel::Single,
        }
    }

    pub fn phase(&self) -> HeapPhase {
        self.phase
    }

    /// `shmemx_heap_preinit` — allocate host heap, bring up PMI, publish
    /// this PE's heap descriptor.
    pub fn preinit(&mut self) -> Result<(), HeapError> {
        self.preinit_thread(ThreadLevel::Single).map(|_| ())
    }

    /// `shmemx_heap_preinit_thread(requested, &provided)`.
    pub fn preinit_thread(&mut self, requested: ThreadLevel) -> Result<ThreadLevel, HeapError> {
        if self.phase != HeapPhase::Fresh {
            return Err(HeapError::Phase("preinit called twice"));
        }
        self.requested_threading = requested;
        self.pmi
            .put("host_heap", format!("{}", self.host_heap_bytes));
        self.pmi.barrier();
        self.phase = HeapPhase::Preinit;
        // The proxy thread services the ring concurrently with app threads:
        // SOS must provide at least SERIALIZED; we grant MULTIPLE.
        Ok(ThreadLevel::Multiple)
    }

    /// `shmemx_heap_create(base_ptr, size, kind, device_index)` — attach
    /// the device-resident region as an external symmetric heap.
    pub fn heap_create(
        &mut self,
        kind: ExternalHeapKind,
        device_index: usize,
        bytes: usize,
    ) -> Result<(), HeapError> {
        if self.phase != HeapPhase::Preinit {
            return Err(HeapError::Phase("heap_create outside preinit→postinit window"));
        }
        let max = self.device_heaps.heap_bytes();
        if bytes > max {
            return Err(HeapError::Bounds { got: bytes, max });
        }
        self.external = Some(ExternalRegion {
            kind,
            device_index,
            bytes,
            nic_registered: false,
        });
        Ok(())
    }

    /// `shmemx_heap_postinit` — register every symmetric region with the
    /// NIC and complete initialization.
    pub fn postinit(&mut self) -> Result<(), HeapError> {
        if self.phase != HeapPhase::Preinit {
            return Err(HeapError::Phase("postinit before preinit"));
        }
        if let Some(ext) = &mut self.external {
            ext.nic_registered = true; // FI_MR_HMEM registration
            self.pmi.put(
                "ext_heap",
                format!("{}:{}", ext.device_index, ext.bytes),
            );
        }
        self.pmi.barrier();
        self.phase = HeapPhase::Postinit;
        Ok(())
    }

    pub fn external(&self) -> Option<&ExternalRegion> {
        self.external.as_ref()
    }

    /// Is this PE's device heap reachable by remote NICs?
    pub fn device_heap_registered(&self) -> bool {
        self.external.as_ref().is_some_and(|e| e.nic_registered)
    }

    pub fn granted_threading(&self) -> ThreadLevel {
        ThreadLevel::Multiple
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sos::pmi::PmiWorld;

    fn setup() -> SosHeaps {
        let w = PmiWorld::new(1);
        let reg = Arc::new(HeapRegistry::new(1, 1 << 16));
        SosHeaps::new(w.handle(0), reg, 1 << 20)
    }

    #[test]
    fn happy_path_dual_phase() {
        let mut h = setup();
        assert_eq!(h.phase(), HeapPhase::Fresh);
        h.preinit().unwrap();
        assert_eq!(h.phase(), HeapPhase::Preinit);
        h.heap_create(ExternalHeapKind::Ze, 0, 1 << 16).unwrap();
        assert!(!h.device_heap_registered());
        h.postinit().unwrap();
        assert_eq!(h.phase(), HeapPhase::Postinit);
        assert!(h.device_heap_registered());
        assert_eq!(h.external().unwrap().kind, ExternalHeapKind::Ze);
    }

    #[test]
    fn preinit_thread_grants_multiple() {
        let mut h = setup();
        let granted = h.preinit_thread(ThreadLevel::Multiple).unwrap();
        assert_eq!(granted, ThreadLevel::Multiple);
    }

    #[test]
    fn out_of_order_calls_rejected() {
        let mut h = setup();
        assert!(matches!(h.postinit(), Err(HeapError::Phase(_))));
        assert!(matches!(
            h.heap_create(ExternalHeapKind::Ze, 0, 64),
            Err(HeapError::Phase(_))
        ));
        h.preinit().unwrap();
        assert!(matches!(h.preinit(), Err(HeapError::Phase(_))));
    }

    #[test]
    fn oversized_external_heap_rejected() {
        let mut h = setup();
        h.preinit().unwrap();
        let err = h.heap_create(ExternalHeapKind::Ze, 0, 1 << 30);
        assert!(matches!(err, Err(HeapError::Bounds { .. })));
    }

    #[test]
    fn postinit_without_external_heap_is_host_only() {
        let mut h = setup();
        h.preinit().unwrap();
        h.postinit().unwrap();
        assert!(!h.device_heap_registered());
    }

    #[test]
    fn staging_slab_bump_and_rewind() {
        let slab = StagingSlab::new(1 << 20, 4096);
        let a = slab.try_alloc(100).unwrap();
        assert_eq!(a, 1 << 20);
        let b = slab.try_alloc(100).unwrap();
        // 64-byte aligned, above the first claim.
        assert_eq!(b % 64, 0);
        assert!(b >= a + 100);
        assert_eq!(slab.outstanding(), 2);
        // Exhaustion: a claim that cannot fit fails without side effects.
        assert!(slab.try_alloc(4096).is_none());
        assert_eq!(slab.outstanding(), 2);
        // Retention is observable while claims await their acks.
        assert!(slab.retained_bytes() >= 200);
        let deepest = slab.retained_bytes();
        // Full release rewinds the cursor: the arena is reusable.
        slab.release();
        slab.release();
        assert_eq!(slab.outstanding(), 0);
        assert_eq!(slab.retained_bytes(), 0, "rewind empties retention");
        assert_eq!(slab.try_alloc(4096).unwrap(), 1 << 20);
        // The high-water mark survives the rewind and tracks the deepest
        // simultaneous retention ever seen.
        assert_eq!(slab.high_water_bytes(), deepest.max(4096));
        slab.release();
    }

    #[test]
    #[should_panic(expected = "without a live claim")]
    fn staging_slab_release_underflow_panics() {
        StagingSlab::new(0, 64).release();
    }
}
