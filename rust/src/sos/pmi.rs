//! PMI: the process-management interface (paper §III-E, [18]).
//!
//! SOS's dual-phase init uses PMI as "a key-value store for publishing and
//! retrieving all relevant addresses and information". Here: a shared map
//! with fence/barrier semantics — PEs publish their heap handles during
//! preinit and read everyone else's before postinit.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// World-level PMI state shared by all PEs of a job.
pub struct PmiWorld {
    npes: usize,
    kv: Mutex<HashMap<String, String>>,
    barrier: Mutex<BarrierState>,
    cv: Condvar,
}

struct BarrierState {
    count: usize,
    generation: u64,
}

impl PmiWorld {
    pub fn new(npes: usize) -> Arc<Self> {
        assert!(npes > 0);
        Arc::new(PmiWorld {
            npes,
            kv: Mutex::new(HashMap::new()),
            barrier: Mutex::new(BarrierState { count: 0, generation: 0 }),
            cv: Condvar::new(),
        })
    }

    pub fn npes(&self) -> usize {
        self.npes
    }

    pub fn handle(self: &Arc<Self>, pe: usize) -> PmiHandle {
        assert!(pe < self.npes);
        PmiHandle { world: Arc::clone(self), pe }
    }
}

/// Per-PE PMI handle.
#[derive(Clone)]
pub struct PmiHandle {
    world: Arc<PmiWorld>,
    pe: usize,
}

impl PmiHandle {
    pub fn pe(&self) -> usize {
        self.pe
    }

    pub fn npes(&self) -> usize {
        self.world.npes
    }

    /// Publish a key (namespaced by PE to mirror PMI_KVS_Put usage).
    pub fn put(&self, key: &str, value: impl Into<String>) {
        let k = format!("pe{}:{}", self.pe, key);
        self.world.kv.lock().unwrap().insert(k, value.into());
    }

    /// Read a key published by `pe`. `None` until the owner fences.
    pub fn get(&self, pe: usize, key: &str) -> Option<String> {
        let k = format!("pe{pe}:{key}");
        self.world.kv.lock().unwrap().get(&k).cloned()
    }

    /// PMI barrier (also the KV fence — all prior puts are visible to all
    /// PEs after everyone returns).
    pub fn barrier(&self) {
        let mut st = self.world.barrier.lock().unwrap();
        let gen = st.generation;
        st.count += 1;
        if st.count == self.world.npes {
            st.count = 0;
            st.generation += 1;
            self.world.cv.notify_all();
        } else {
            while st.generation == gen {
                st = self.world.cv.wait(st).unwrap();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_publish_and_read() {
        let w = PmiWorld::new(2);
        let h0 = w.handle(0);
        let h1 = w.handle(1);
        h0.put("heap", "0xdead");
        assert_eq!(h1.get(0, "heap").as_deref(), Some("0xdead"));
        assert_eq!(h1.get(1, "heap"), None);
    }

    #[test]
    fn barrier_synchronizes_publishes() {
        let w = PmiWorld::new(4);
        let mut handles = vec![];
        for pe in 0..4 {
            let h = w.handle(pe);
            handles.push(std::thread::spawn(move || {
                h.put("addr", format!("addr-of-{pe}"));
                h.barrier();
                // After the barrier every peer's key must be visible.
                for other in 0..4 {
                    assert_eq!(
                        h.get(other, "addr").as_deref(),
                        Some(format!("addr-of-{other}").as_str())
                    );
                }
                h.barrier();
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
    }

    #[test]
    fn barrier_reusable_across_generations() {
        let w = PmiWorld::new(3);
        let mut handles = vec![];
        for pe in 0..3 {
            let h = w.handle(pe);
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    h.barrier();
                }
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
    }
}
