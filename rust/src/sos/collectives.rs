//! Host-side inter-node collectives (paper §III-G.2: Intel SHMEM "relies
//! on OpenSHMEM for inter-node operations").
//!
//! ishmem composes node-local "push" collectives with these host-level
//! primitives when a team spans nodes: the per-node leader PEs run a
//! dissemination pattern over the NIC, then fan results back out
//! intra-node. Only what ishmem needs is implemented: leader barrier,
//! leader broadcast, and leader allgather.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::transport::OfiTransport;
use crate::sim::SimClock;

/// Dissemination-style synchronization state for up to `nodes` leaders.
pub struct LeaderBarrier {
    round_flags: Vec<Vec<AtomicU64>>, // [round][node]
    generation: Vec<AtomicU64>,
    nodes: usize,
}

impl LeaderBarrier {
    pub fn new(nodes: usize) -> Arc<Self> {
        let rounds = nodes.next_power_of_two().trailing_zeros() as usize;
        Arc::new(LeaderBarrier {
            round_flags: (0..rounds.max(1))
                .map(|_| (0..nodes).map(|_| AtomicU64::new(0)).collect())
                .collect(),
            generation: (0..nodes).map(|_| AtomicU64::new(0)).collect(),
            nodes,
        })
    }

    /// Dissemination barrier among node leaders. `node` is this leader's
    /// node index. Charges NIC latency per round.
    pub fn wait(&self, node: usize, transport: &OfiTransport, clock: &SimClock) {
        if self.nodes == 1 {
            return;
        }
        let gen = self.generation[node].fetch_add(1, Ordering::AcqRel) + 1;
        let rounds = self.nodes.next_power_of_two().trailing_zeros() as usize;
        for r in 0..rounds {
            let peer = (node + (1 << r)) % self.nodes;
            // Notify peer (one small wire message).
            self.round_flags[r][peer].fetch_add(1, Ordering::AcqRel);
            clock.advance(transport.nic_latency_ns());
            // Wait for our notification of this generation.
            while self.round_flags[r][node].load(Ordering::Acquire) < gen {
                std::thread::yield_now();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::memory::HeapRegistry;
    use crate::sim::{CostModel, CostParams, Topology};

    #[test]
    fn leader_barrier_synchronizes() {
        let nodes = 4;
        let topo = Topology::new(nodes, 2, 2);
        let cost = CostModel::new(topo, CostParams::default());
        let heaps = Arc::new(HeapRegistry::new(nodes * 4, 1 << 12));
        let transport = Arc::new(OfiTransport::new(heaps, cost));
        let barrier = LeaderBarrier::new(nodes);

        let counter = Arc::new(AtomicU64::new(0));
        let mut handles = vec![];
        for node in 0..nodes {
            let b = barrier.clone();
            let t = transport.clone();
            let c = counter.clone();
            handles.push(std::thread::spawn(move || {
                let clock = SimClock::new();
                for round in 0..20u64 {
                    c.fetch_add(1, Ordering::AcqRel);
                    b.wait(node, &t, &clock);
                    // After each barrier all increments of the round landed.
                    assert!(c.load(Ordering::Acquire) >= (round + 1) * nodes as u64);
                    b.wait(node, &t, &clock);
                }
                assert!(clock.now_ns() > 0.0);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 20 * nodes as u64);
    }

    #[test]
    fn single_node_barrier_is_noop() {
        let topo = Topology::new(1, 6, 2);
        let cost = CostModel::new(topo, CostParams::default());
        let heaps = Arc::new(HeapRegistry::new(12, 1 << 12));
        let transport = OfiTransport::new(heaps, cost);
        let barrier = LeaderBarrier::new(1);
        let clock = SimClock::new();
        barrier.wait(0, &transport, &clock);
        assert_eq!(clock.now_ns(), 0.0);
    }
}
