//! Host OpenSHMEM substrate — the stand-in for Sandia OpenSHMEM (SOS).
//!
//! Intel SHMEM does not talk to the network itself: a host proxy thread
//! hands GPU-initiated inter-node operations to a standard OpenSHMEM
//! library (paper §III-C), and that library also provides the *external
//! symmetric heap* registration that lets the NIC RDMA straight into GPU
//! memory (§III-E, FI_HMEM). This module rebuilds those seams:
//!
//!   * `pmi` — process-management KV store + init barriers (SOS's
//!     dual-phase init: preinit → publish addresses → postinit).
//!   * `heap` — host symmetric heap + `shmemx_heap_create`-style external
//!     device-heap registration state machine.
//!   * `transport` — OFI-libfabric-like RDMA over the simulated NIC,
//!     honouring FI_HMEM registration (unregistered device memory bounces
//!     through host at a penalty).
//!   * `collectives` — host-side inter-node collectives (barrier, bcast,
//!     allgather-of-leaders) used by ishmem's scale-out phases.

pub mod collectives;
pub mod heap;
pub mod pmi;
pub mod transport;

pub use heap::{ExternalHeapKind, HeapPhase, SosHeaps};
pub use pmi::{PmiHandle, PmiWorld};
pub use transport::OfiTransport;
