//! # rishmem — Intel® SHMEM reproduced as a Rust + JAX + Pallas stack
//!
//! A research reproduction of *"Intel® SHMEM: GPU-initiated OpenSHMEM using
//! SYCL"* (Brooks et al., 2024) as a three-layer system:
//!
//! * **L3 (this crate)** — the ishmem library itself: device/host-initiated
//!   RMA, AMOs, signaling, collectives, teams, `work_group` extensions, the
//!   unified transfer-plan engine ([`xfer`]: cutover policy incl. the
//!   online-adaptive mode, executors, completion tracking), the lock-free
//!   reverse-offload ring, and the host proxy
//!   — running against a simulated Aurora-class node (real shared-memory
//!   data movement + an analytic hardware cost model, see [`sim`]).
//! * **L2** — a JAX transformer (`python/compile/model.py`) AOT-lowered to
//!   HLO text; the dist-train example drives data-parallel training whose
//!   gradient allreduce flows through `ishmem_reduce`.
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for the reduction
//!   compute lanes and the collaborative copy, executed from the Rust
//!   request path through PJRT ([`runtime`]).
//!
//! See `DESIGN.md` for the system inventory and the paper↔module map, and
//! `EXPERIMENTS.md` for the reproduced figures.

pub mod bench;
pub mod coordinator;
pub mod device;
pub mod ishmem;
pub mod train;
pub mod ringbuf;
pub mod runtime;
pub mod sim;
pub mod sos;
pub mod util;
pub mod xfer;
pub mod ze;

pub use coordinator::launch::{run_npes, run_spmd, Machine};
pub use device::WorkGroup;
pub use ishmem::{
    Cmp, CollAlgoMode, CollConfig, CutoverConfig, CutoverMode, Ishmem, IshmemConfig, PeCtx,
    ReduceOp, SymAddr, TeamId,
};
pub use runtime::{HostTensor, XlaRuntime};
pub use sim::{Locality, Topology};
pub use xfer::{Route, TransferPlan, XferEngine};
