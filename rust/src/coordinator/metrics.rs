//! Traffic/operation counters, aggregated across PEs and the proxy.
//!
//! Every counter is a relaxed atomic — the hot path pays one uncontended
//! `fetch_add`; snapshots are approximate under concurrency, exact at
//! quiescence (which is when reports read them).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sim::topology::Locality;

/// Data-path index into the per-(path, locality) byte table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathIdx {
    LoadStore = 0,
    CopyEngine = 1,
    Nic = 2,
}

/// Proxy service-time op families (per-op service histograms).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceOp {
    Put = 0,
    Get = 1,
    Amo = 2,
    Other = 3,
}

/// Collective op families tracked by the per-op counters and the
/// per-(op, stage) byte table. `Other` absorbs collect, alltoall and the
/// host-side fcollect — ops without a hierarchical variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollOpIdx {
    Broadcast = 0,
    Fcollect = 1,
    Reduce = 2,
    Other = 3,
}

/// Stage of a collective's data movement: intra-node hops (load/store or
/// striped copy engines) vs inter-node hops (NIC wire).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollStage {
    Intra = 0,
    Inter = 1,
}

/// Rows of the collective byte table (mirrors `CollOpIdx`).
pub const COLL_OPS: usize = 4;
/// Columns of the collective byte table (mirrors `CollStage`).
pub const COLL_STAGES: usize = 2;

/// Batch-depth histogram buckets: depth 1, 2, 3–4, 5–8, 9–16, ≥17.
/// (Shared shape with the chunks-per-transfer histogram.)
pub const BATCH_DEPTH_BUCKETS: usize = 6;
/// Per-engine metric slots (engine index within the source GPU; indices
/// past the table clamp into the last slot).
pub const ENGINE_SLOTS: usize = 8;
/// Per-NIC-rail metric slots (rail index within the source node; indices
/// past the table clamp into the last slot).
pub const RAIL_SLOTS: usize = 8;
/// Payload-size classes of the wall-vs-model service comparison
/// (`rishmem figure service-delta`): ≤4KiB, ≤64KiB, ≤256KiB, ≤1MiB,
/// ≤4MiB, larger.
pub const SERVICE_SIZE_BUCKETS: usize = 6;
/// Upper byte bound of each size class but the last (class `i` holds
/// payloads in `(BOUNDS[i-1], BOUNDS[i]]`; the last class is unbounded).
/// The **single source of truth** for the size-class geometry: the
/// service-delta tables, their labels, and the calibrator's observation
/// buckets (`xfer::calibrate`) all derive from this array, so the
/// classes can never drift apart.
pub const SERVICE_SIZE_BOUNDS: [u64; SERVICE_SIZE_BUCKETS - 1] =
    [4 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20];
/// Proxy service-time histogram: log2-ns buckets, 2^4 ns … ≥2^19 ns.
pub const SERVICE_NS_BUCKETS: usize = 16;
const SERVICE_NS_SHIFT: u32 = 4;
/// Number of op families tracked by the proxy service metrics.
pub const SERVICE_OPS: usize = 4;
/// Number of locality classes (mirrors `sim::topology::Locality`).
pub const LOCALITIES: usize = 4;

#[derive(Debug, Default)]
pub struct Metrics {
    // Op counts by API family.
    pub puts: AtomicU64,
    pub gets: AtomicU64,
    pub amos: AtomicU64,
    // Collectives, per op family: broadcast/fcollect/reduce each count
    // one per team-wide call; `coll_sync` counts team syncs/barriers
    // (including the syncs staged collectives issue internally);
    // `coll_other` counts collect/alltoall/host-fcollect. `coll_hier`
    // counts calls that took a hierarchical (leader-staged) algorithm,
    // and `coll_stage_bytes` splits each op's payload bytes into
    // intra-node vs inter-node hops.
    pub coll_broadcast: AtomicU64,
    pub coll_fcollect: AtomicU64,
    pub coll_reduce: AtomicU64,
    pub coll_sync: AtomicU64,
    pub coll_other: AtomicU64,
    pub coll_hier: AtomicU64,
    pub coll_stage_bytes: [[AtomicU64; COLL_STAGES]; COLL_OPS],
    // Bytes by data path (the paper's three regimes).
    pub bytes_loadstore: AtomicU64,
    pub bytes_copy_engine: AtomicU64,
    pub bytes_nic: AtomicU64,
    // Bytes by (data path, locality): the per-locality breakdown of the
    // three counters above, filled by the same call sites.
    pub bytes_by_path_loc: [[AtomicU64; LOCALITIES]; 3],
    // Transfer-plan engine: route decisions by executor, and online
    // adaptive-table refinements (adaptive-cutover feedback).
    pub xfer_plans_loadstore: AtomicU64,
    pub xfer_plans_copy_engine: AtomicU64,
    pub xfer_plans_nic: AtomicU64,
    pub adaptive_updates: AtomicU64,
    // Plan cache (memoized structural plans): hits and misses count only
    // while the cache is enabled; invalidations count entries dropped by
    // model-version/CL-boundary generation flushes, per-entry stale
    // evictions, and capacity resets.
    pub plan_cache_hits: AtomicU64,
    pub plan_cache_misses: AtomicU64,
    pub plan_cache_invalidations: AtomicU64,
    // Reverse-offload ring.
    pub ring_messages: AtomicU64,
    pub ring_completions: AtomicU64,
    // Batched command streams: one `RingOp::Batch` doorbell per
    // plan-group; depth distribution of the serviced batches.
    pub xfer_batches: AtomicU64,
    pub xfer_batch_entries: AtomicU64,
    pub xfer_batch_depth_hist: [AtomicU64; BATCH_DEPTH_BUCKETS],
    // Striped chunk pipeline: chunked transfers, their chunk count, and
    // the chunks-per-transfer distribution (same buckets as batch depth).
    pub stripe_transfers: AtomicU64,
    pub stripe_chunks: AtomicU64,
    pub stripe_chunk_hist: [AtomicU64; BATCH_DEPTH_BUCKETS],
    // Proxy-side per-engine dispatch tables (engine slot on the source
    // GPU): bytes moved and entries dispatched per engine.
    pub engine_bytes: [AtomicU64; ENGINE_SLOTS],
    pub engine_ops: [AtomicU64; ENGINE_SLOTS],
    // Proxy-side per-rail dispatch tables (NIC rail slot on the source
    // node): bytes injected and entries dispatched per rail.
    pub rail_bytes: [AtomicU64; RAIL_SLOTS],
    pub rail_ops: [AtomicU64; RAIL_SLOTS],
    // Proxy-side service time (wall clock) per op family: sums + counts
    // for averages, log2-ns histograms for the shape.
    pub proxy_service_ns: [AtomicU64; SERVICE_OPS],
    pub proxy_service_ops: [AtomicU64; SERVICE_OPS],
    pub proxy_service_hist: [[AtomicU64; SERVICE_NS_BUCKETS]; SERVICE_OPS],
    // Wall-vs-model service comparison per (data path, payload-size
    // class): the proxy fills the wall side per serviced put/get entry,
    // executors the model side per charged transfer. `rishmem figure
    // service-delta` diffs the sums and flags classes off by >2×. The
    // same proxy-side wall observations also feed the calibrator
    // (`xfer::calibrate`, per-(path, lane, size-class)) when
    // `calib.enable` is on — the flagged classes close the loop into
    // ModelParams instead of dead-ending in the report.
    pub service_wall_ns: [[AtomicU64; SERVICE_SIZE_BUCKETS]; 3],
    pub service_wall_ops: [[AtomicU64; SERVICE_SIZE_BUCKETS]; 3],
    pub service_model_ns: [[AtomicU64; SERVICE_SIZE_BUCKETS]; 3],
    pub service_model_ops: [[AtomicU64; SERVICE_SIZE_BUCKETS]; 3],
    // XLA kernel invocations (reduce path).
    pub xla_reduce_calls: AtomicU64,
    pub xla_reduce_elems: AtomicU64,
    // Native (non-kernel) reduce fallbacks.
    pub native_reduce_elems: AtomicU64,
    // Fault injection & degraded mode (ISSUE 8). Counters: applied lane
    // transitions (scripted or manual), calibrator-driven quarantines and
    // revival probes, proxy chunks re-dispatched off a dead lane, and
    // plans that hit a domain with zero live lanes and fell back to a
    // single-lane shape. All zero on a fault-free run.
    pub fault_rail_kills: AtomicU64,
    pub fault_rail_revives: AtomicU64,
    pub fault_engine_kills: AtomicU64,
    pub fault_engine_revives: AtomicU64,
    pub fault_quarantines: AtomicU64,
    pub fault_probes: AtomicU64,
    pub fault_redispatched_chunks: AtomicU64,
    pub fault_last_lane_fallbacks: AtomicU64,
    // Collective waits that hit their configured deadline instead of
    // spinning forever (PE churn).
    pub coll_decision_timeouts: AtomicU64,
    pub coll_sync_timeouts: AtomicU64,
    // Transfer reliability (ISSUE 9): transient chunk faults the proxy
    // applied (drop / detected-or-undetected corrupt / delay), checksum
    // verification failures, NACKed batch completions, entries replayed,
    // replay budgets exhausted, total modeled backoff charged, strike
    // escalations into quarantine, and p2p op-deadline expiries. All zero
    // while `retry.enable` is off and no transient events are scripted.
    pub fault_dropped_chunks: AtomicU64,
    pub fault_corrupted_chunks: AtomicU64,
    pub fault_delayed_chunks: AtomicU64,
    pub retry_checksum_fail: AtomicU64,
    pub retry_nacks: AtomicU64,
    pub retry_replays: AtomicU64,
    pub retry_exhausted: AtomicU64,
    pub retry_backoff_ns_total: AtomicU64,
    pub retry_escalations: AtomicU64,
    pub xfer_op_timeouts: AtomicU64,
    // Triggered operation chains (ISSUE 10): fused chains submitted (one
    // doorbell each), their stage-depth distribution, chained successors
    // the proxy released on a met trigger without a new ring message,
    // doorbells reclaimed by fusing ops that previously forced their own
    // submission (put-signal), and chains the fuse-vs-flush decision
    // declined (fell back to sequential submission). All zero while
    // `chain.enable` is off.
    pub chain_submitted: AtomicU64,
    pub chain_triggered: AtomicU64,
    pub chain_fused_doorbells: AtomicU64,
    pub chain_flushed_unfusable: AtomicU64,
    pub chain_depth_hist: [AtomicU64; BATCH_DEPTH_BUCKETS],
    // Gauges: 1 while any lane anywhere is dead; per-slot counts of how
    // many nodes/GPUs currently have that rail/engine slot dead (indices
    // past the table clamp into the last slot, like the dispatch tables).
    pub degraded_mode: AtomicU64,
    pub rail_dead: [AtomicU64; RAIL_SLOTS],
    pub engine_dead: [AtomicU64; ENGINE_SLOTS],
}

/// Bucket index for a serviced batch of `depth` entries.
pub fn batch_depth_bucket(depth: usize) -> usize {
    match depth {
        0 | 1 => 0,
        2 => 1,
        3..=4 => 2,
        5..=8 => 3,
        9..=16 => 4,
        _ => 5,
    }
}

/// Log2 bucket index for a service time of `ns` nanoseconds.
pub fn service_ns_bucket(ns: u64) -> usize {
    let log2 = 64 - u64::leading_zeros(ns.max(1)) as u32 - 1;
    (log2.saturating_sub(SERVICE_NS_SHIFT) as usize).min(SERVICE_NS_BUCKETS - 1)
}

/// Payload-size class of the wall-vs-model service tables (and of the
/// calibrator's observation buckets — shared geometry by construction).
pub fn service_size_bucket(bytes: u64) -> usize {
    SERVICE_SIZE_BOUNDS
        .iter()
        .position(|&bound| bytes <= bound)
        .unwrap_or(SERVICE_SIZE_BUCKETS - 1)
}

/// Human label of a [`service_size_bucket`] index.
pub fn service_size_label(bucket: usize) -> &'static str {
    ["<=4KiB", "<=64KiB", "<=256KiB", "<=1MiB", "<=4MiB", ">4MiB"][bucket.min(5)]
}

impl Metrics {
    pub fn new() -> std::sync::Arc<Self> {
        std::sync::Arc::new(Self::default())
    }

    #[inline]
    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Count `bytes` on a data path *and* its locality row.
    pub fn add_path_bytes(&self, path: PathIdx, loc: Locality, bytes: u64) {
        let total = match path {
            PathIdx::LoadStore => &self.bytes_loadstore,
            PathIdx::CopyEngine => &self.bytes_copy_engine,
            PathIdx::Nic => &self.bytes_nic,
        };
        Self::add(total, bytes);
        Self::add(&self.bytes_by_path_loc[path as usize][loc as usize], bytes);
    }

    /// Count `bytes` of collective payload moved by `op` during `stage`.
    pub fn add_coll_bytes(&self, op: CollOpIdx, stage: CollStage, bytes: u64) {
        Self::add(&self.coll_stage_bytes[op as usize][stage as usize], bytes);
    }

    /// Record one serviced batch of `entries` descriptors.
    pub fn add_batch(&self, entries: usize) {
        Self::add(&self.xfer_batches, 1);
        Self::add(&self.xfer_batch_entries, entries as u64);
        Self::add(&self.xfer_batch_depth_hist[batch_depth_bucket(entries)], 1);
    }

    /// Record one fused chain submission of `depth` dependent stages.
    pub fn add_chain(&self, depth: usize) {
        Self::add(&self.chain_submitted, 1);
        Self::add(&self.chain_depth_hist[batch_depth_bucket(depth)], 1);
    }

    /// Record one striped transfer of `chunks` chunks.
    pub fn add_stripe(&self, chunks: usize) {
        Self::add(&self.stripe_transfers, 1);
        Self::add(&self.stripe_chunks, chunks as u64);
        Self::add(&self.stripe_chunk_hist[batch_depth_bucket(chunks)], 1);
    }

    /// Record one proxy engine dispatch of `bytes` on engine slot
    /// `engine` (indices past the table clamp into the last slot).
    pub fn add_engine_dispatch(&self, engine: usize, bytes: u64) {
        let i = engine.min(ENGINE_SLOTS - 1);
        Self::add(&self.engine_bytes[i], bytes);
        Self::add(&self.engine_ops[i], 1);
    }

    /// Record one proxy NIC injection of `bytes` on rail slot `rail`
    /// (indices past the table clamp into the last slot).
    pub fn add_rail_dispatch(&self, rail: usize, bytes: u64) {
        let i = rail.min(RAIL_SLOTS - 1);
        Self::add(&self.rail_bytes[i], bytes);
        Self::add(&self.rail_ops[i], 1);
    }

    /// Record one proxy-side *wall-clock* put/get service of a
    /// `bytes`-sized payload on `path` (the wall half of the
    /// `service-delta` tables).
    pub fn add_service_wall(&self, path: PathIdx, bytes: u64, ns: u64) {
        let b = service_size_bucket(bytes);
        Self::add(&self.service_wall_ns[path as usize][b], ns);
        Self::add(&self.service_wall_ops[path as usize][b], 1);
    }

    /// Record one executor-side *modeled* transfer charge of a
    /// `bytes`-sized payload on `path` (the model half of the
    /// `service-delta` tables).
    pub fn add_service_model(&self, path: PathIdx, bytes: u64, ns: u64) {
        let b = service_size_bucket(bytes);
        Self::add(&self.service_model_ns[path as usize][b], ns);
        Self::add(&self.service_model_ops[path as usize][b], 1);
    }

    /// Count one *applied* lane transition (fault injection — the caller
    /// guarantees it was a real state change): the kill/revive counter
    /// and the per-slot dead-lane gauge move together, and the degraded
    /// flag is refreshed from the cost model's aggregate view.
    pub fn count_fault_action(&self, action: crate::sim::fault::FaultAction, degraded: bool) {
        use crate::sim::fault::FaultAction as A;
        match action {
            A::KillRail { rail, .. } => {
                Self::add(&self.fault_rail_kills, 1);
                Self::add(&self.rail_dead[rail.min(RAIL_SLOTS - 1)], 1);
            }
            A::ReviveRail { rail, .. } => {
                Self::add(&self.fault_rail_revives, 1);
                self.rail_dead[rail.min(RAIL_SLOTS - 1)].fetch_sub(1, Ordering::Relaxed);
            }
            A::KillEngine { engine, .. } => {
                Self::add(&self.fault_engine_kills, 1);
                Self::add(&self.engine_dead[engine.min(ENGINE_SLOTS - 1)], 1);
            }
            A::ReviveEngine { engine, .. } => {
                Self::add(&self.fault_engine_revives, 1);
                self.engine_dead[engine.min(ENGINE_SLOTS - 1)].fetch_sub(1, Ordering::Relaxed);
            }
        }
        self.degraded_mode.store(degraded as u64, Ordering::Relaxed);
    }

    /// Record one proxy service of `op` taking `ns` wall-clock nanoseconds.
    pub fn add_service(&self, op: ServiceOp, ns: u64) {
        let i = op as usize;
        Self::add(&self.proxy_service_ns[i], ns);
        Self::add(&self.proxy_service_ops[i], 1);
        Self::add(&self.proxy_service_hist[i][service_ns_bucket(ns)], 1);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        fn load(c: &AtomicU64) -> u64 {
            c.load(Ordering::Relaxed)
        }
        MetricsSnapshot {
            puts: load(&self.puts),
            gets: load(&self.gets),
            amos: load(&self.amos),
            coll_broadcast: load(&self.coll_broadcast),
            coll_fcollect: load(&self.coll_fcollect),
            coll_reduce: load(&self.coll_reduce),
            coll_sync: load(&self.coll_sync),
            coll_other: load(&self.coll_other),
            coll_hier: load(&self.coll_hier),
            coll_stage_bytes: std::array::from_fn(|o| {
                std::array::from_fn(|s| load(&self.coll_stage_bytes[o][s]))
            }),
            bytes_loadstore: load(&self.bytes_loadstore),
            bytes_copy_engine: load(&self.bytes_copy_engine),
            bytes_nic: load(&self.bytes_nic),
            bytes_by_path_loc: std::array::from_fn(|p| {
                std::array::from_fn(|l| load(&self.bytes_by_path_loc[p][l]))
            }),
            xfer_plans_loadstore: load(&self.xfer_plans_loadstore),
            xfer_plans_copy_engine: load(&self.xfer_plans_copy_engine),
            xfer_plans_nic: load(&self.xfer_plans_nic),
            adaptive_updates: load(&self.adaptive_updates),
            plan_cache_hits: load(&self.plan_cache_hits),
            plan_cache_misses: load(&self.plan_cache_misses),
            plan_cache_invalidations: load(&self.plan_cache_invalidations),
            ring_messages: load(&self.ring_messages),
            ring_completions: load(&self.ring_completions),
            xfer_batches: load(&self.xfer_batches),
            xfer_batch_entries: load(&self.xfer_batch_entries),
            xfer_batch_depth_hist: std::array::from_fn(|i| {
                load(&self.xfer_batch_depth_hist[i])
            }),
            stripe_transfers: load(&self.stripe_transfers),
            stripe_chunks: load(&self.stripe_chunks),
            stripe_chunk_hist: std::array::from_fn(|i| load(&self.stripe_chunk_hist[i])),
            engine_bytes: std::array::from_fn(|i| load(&self.engine_bytes[i])),
            engine_ops: std::array::from_fn(|i| load(&self.engine_ops[i])),
            rail_bytes: std::array::from_fn(|i| load(&self.rail_bytes[i])),
            rail_ops: std::array::from_fn(|i| load(&self.rail_ops[i])),
            proxy_service_ns: std::array::from_fn(|i| load(&self.proxy_service_ns[i])),
            proxy_service_ops: std::array::from_fn(|i| load(&self.proxy_service_ops[i])),
            proxy_service_hist: std::array::from_fn(|o| {
                std::array::from_fn(|b| load(&self.proxy_service_hist[o][b]))
            }),
            service_wall_ns: std::array::from_fn(|p| {
                std::array::from_fn(|b| load(&self.service_wall_ns[p][b]))
            }),
            service_wall_ops: std::array::from_fn(|p| {
                std::array::from_fn(|b| load(&self.service_wall_ops[p][b]))
            }),
            service_model_ns: std::array::from_fn(|p| {
                std::array::from_fn(|b| load(&self.service_model_ns[p][b]))
            }),
            service_model_ops: std::array::from_fn(|p| {
                std::array::from_fn(|b| load(&self.service_model_ops[p][b]))
            }),
            xla_reduce_calls: load(&self.xla_reduce_calls),
            xla_reduce_elems: load(&self.xla_reduce_elems),
            native_reduce_elems: load(&self.native_reduce_elems),
            fault_rail_kills: load(&self.fault_rail_kills),
            fault_rail_revives: load(&self.fault_rail_revives),
            fault_engine_kills: load(&self.fault_engine_kills),
            fault_engine_revives: load(&self.fault_engine_revives),
            fault_quarantines: load(&self.fault_quarantines),
            fault_probes: load(&self.fault_probes),
            fault_redispatched_chunks: load(&self.fault_redispatched_chunks),
            fault_last_lane_fallbacks: load(&self.fault_last_lane_fallbacks),
            coll_decision_timeouts: load(&self.coll_decision_timeouts),
            coll_sync_timeouts: load(&self.coll_sync_timeouts),
            fault_dropped_chunks: load(&self.fault_dropped_chunks),
            fault_corrupted_chunks: load(&self.fault_corrupted_chunks),
            fault_delayed_chunks: load(&self.fault_delayed_chunks),
            retry_checksum_fail: load(&self.retry_checksum_fail),
            retry_nacks: load(&self.retry_nacks),
            retry_replays: load(&self.retry_replays),
            retry_exhausted: load(&self.retry_exhausted),
            retry_backoff_ns_total: load(&self.retry_backoff_ns_total),
            retry_escalations: load(&self.retry_escalations),
            xfer_op_timeouts: load(&self.xfer_op_timeouts),
            chain_submitted: load(&self.chain_submitted),
            chain_triggered: load(&self.chain_triggered),
            chain_fused_doorbells: load(&self.chain_fused_doorbells),
            chain_flushed_unfusable: load(&self.chain_flushed_unfusable),
            chain_depth_hist: std::array::from_fn(|i| load(&self.chain_depth_hist[i])),
            degraded_mode: load(&self.degraded_mode),
            rail_dead: std::array::from_fn(|i| load(&self.rail_dead[i])),
            engine_dead: std::array::from_fn(|i| load(&self.engine_dead[i])),
        }
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub puts: u64,
    pub gets: u64,
    pub amos: u64,
    pub coll_broadcast: u64,
    pub coll_fcollect: u64,
    pub coll_reduce: u64,
    pub coll_sync: u64,
    pub coll_other: u64,
    pub coll_hier: u64,
    pub coll_stage_bytes: [[u64; COLL_STAGES]; COLL_OPS],
    pub bytes_loadstore: u64,
    pub bytes_copy_engine: u64,
    pub bytes_nic: u64,
    pub bytes_by_path_loc: [[u64; LOCALITIES]; 3],
    pub xfer_plans_loadstore: u64,
    pub xfer_plans_copy_engine: u64,
    pub xfer_plans_nic: u64,
    pub adaptive_updates: u64,
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    pub plan_cache_invalidations: u64,
    pub ring_messages: u64,
    pub ring_completions: u64,
    pub xfer_batches: u64,
    pub xfer_batch_entries: u64,
    pub xfer_batch_depth_hist: [u64; BATCH_DEPTH_BUCKETS],
    pub stripe_transfers: u64,
    pub stripe_chunks: u64,
    pub stripe_chunk_hist: [u64; BATCH_DEPTH_BUCKETS],
    pub engine_bytes: [u64; ENGINE_SLOTS],
    pub engine_ops: [u64; ENGINE_SLOTS],
    pub rail_bytes: [u64; RAIL_SLOTS],
    pub rail_ops: [u64; RAIL_SLOTS],
    pub proxy_service_ns: [u64; SERVICE_OPS],
    pub proxy_service_ops: [u64; SERVICE_OPS],
    pub proxy_service_hist: [[u64; SERVICE_NS_BUCKETS]; SERVICE_OPS],
    pub service_wall_ns: [[u64; SERVICE_SIZE_BUCKETS]; 3],
    pub service_wall_ops: [[u64; SERVICE_SIZE_BUCKETS]; 3],
    pub service_model_ns: [[u64; SERVICE_SIZE_BUCKETS]; 3],
    pub service_model_ops: [[u64; SERVICE_SIZE_BUCKETS]; 3],
    pub xla_reduce_calls: u64,
    pub xla_reduce_elems: u64,
    pub native_reduce_elems: u64,
    pub fault_rail_kills: u64,
    pub fault_rail_revives: u64,
    pub fault_engine_kills: u64,
    pub fault_engine_revives: u64,
    pub fault_quarantines: u64,
    pub fault_probes: u64,
    pub fault_redispatched_chunks: u64,
    pub fault_last_lane_fallbacks: u64,
    pub coll_decision_timeouts: u64,
    pub coll_sync_timeouts: u64,
    pub fault_dropped_chunks: u64,
    pub fault_corrupted_chunks: u64,
    pub fault_delayed_chunks: u64,
    pub retry_checksum_fail: u64,
    pub retry_nacks: u64,
    pub retry_replays: u64,
    pub retry_exhausted: u64,
    pub retry_backoff_ns_total: u64,
    pub retry_escalations: u64,
    pub xfer_op_timeouts: u64,
    pub chain_submitted: u64,
    pub chain_triggered: u64,
    pub chain_fused_doorbells: u64,
    pub chain_flushed_unfusable: u64,
    pub chain_depth_hist: [u64; BATCH_DEPTH_BUCKETS],
    pub degraded_mode: u64,
    pub rail_dead: [u64; RAIL_SLOTS],
    pub engine_dead: [u64; ENGINE_SLOTS],
}

impl MetricsSnapshot {
    /// Total collective calls across all op families (syncs included) —
    /// the pre-split `collectives` counter, preserved as a derived sum.
    pub fn collectives(&self) -> u64 {
        self.coll_broadcast
            + self.coll_fcollect
            + self.coll_reduce
            + self.coll_sync
            + self.coll_other
    }

    /// Collective payload bytes moved by `op` during `stage`.
    pub fn coll_bytes(&self, op: CollOpIdx, stage: CollStage) -> u64 {
        self.coll_stage_bytes[op as usize][stage as usize]
    }

    /// Collective payload bytes of `stage` summed over all op families.
    pub fn coll_stage_total(&self, stage: CollStage) -> u64 {
        self.coll_stage_bytes.iter().map(|row| row[stage as usize]).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_loadstore + self.bytes_copy_engine + self.bytes_nic
    }

    pub fn total_xfer_plans(&self) -> u64 {
        self.xfer_plans_loadstore + self.xfer_plans_copy_engine + self.xfer_plans_nic
    }

    /// Bytes moved on `path` to `loc`-distant targets.
    pub fn path_loc_bytes(&self, path: PathIdx, loc: Locality) -> u64 {
        self.bytes_by_path_loc[path as usize][loc as usize]
    }

    /// Per-locality total for `path` (sum over localities — equals the
    /// flat per-path counter when every call site reports its locality).
    pub fn path_bytes_sum(&self, path: PathIdx) -> u64 {
        self.bytes_by_path_loc[path as usize].iter().sum()
    }

    /// Mean serviced batch depth (0 when no batch was serviced).
    pub fn mean_batch_depth(&self) -> f64 {
        if self.xfer_batches == 0 {
            0.0
        } else {
            self.xfer_batch_entries as f64 / self.xfer_batches as f64
        }
    }

    /// Mean chunks per striped transfer (0 when nothing striped).
    pub fn mean_chunks_per_transfer(&self) -> f64 {
        if self.stripe_transfers == 0 {
            0.0
        } else {
            self.stripe_chunks as f64 / self.stripe_transfers as f64
        }
    }

    /// Mean proxy service time for `op`, ns (0 when none serviced).
    pub fn mean_service_ns(&self, op: ServiceOp) -> f64 {
        let i = op as usize;
        if self.proxy_service_ops[i] == 0 {
            0.0
        } else {
            self.proxy_service_ns[i] as f64 / self.proxy_service_ops[i] as f64
        }
    }

    /// Serialize the whole snapshot as one JSON object (dashboard
    /// scraping: `rishmem metrics --json`). Counters are exact — every
    /// value fits f64's 2^53 integer range long before the counters
    /// saturate a run.
    pub fn to_json(&self) -> String {
        self.to_json_with(Vec::new())
    }

    /// [`Self::to_json`] with caller-provided extra top-level entries —
    /// how `rishmem metrics --json` folds the calibration snapshot
    /// (learned params, sample counts, residuals) into the same object
    /// the dashboards already scrape.
    pub fn to_json_with(&self, extra: Vec<(String, crate::util::json::Json)>) -> String {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        fn n(v: u64) -> Json {
            Json::Num(v as f64)
        }
        fn arr(v: &[u64]) -> Json {
            Json::Arr(v.iter().map(|&x| n(x)).collect())
        }
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        let mut put = |k: &str, v: Json| o.insert(k.to_string(), v);
        put("puts", n(self.puts));
        put("gets", n(self.gets));
        put("amos", n(self.amos));
        put("collectives", n(self.collectives()));
        put("coll_broadcast", n(self.coll_broadcast));
        put("coll_fcollect", n(self.coll_fcollect));
        put("coll_reduce", n(self.coll_reduce));
        put("coll_sync", n(self.coll_sync));
        put("coll_other", n(self.coll_other));
        put("coll_hier", n(self.coll_hier));
        let mut stages: BTreeMap<String, Json> = BTreeMap::new();
        for (name, stage) in [("intra", CollStage::Intra), ("inter", CollStage::Inter)] {
            let row: Vec<u64> = (0..COLL_OPS)
                .map(|o| self.coll_stage_bytes[o][stage as usize])
                .collect();
            stages.insert(name.to_string(), arr(&row));
        }
        put("coll_stage_bytes", Json::Obj(stages));
        put("bytes_loadstore", n(self.bytes_loadstore));
        put("bytes_copy_engine", n(self.bytes_copy_engine));
        put("bytes_nic", n(self.bytes_nic));
        let mut by_loc: BTreeMap<String, Json> = BTreeMap::new();
        for (name, path) in [
            ("loadstore", PathIdx::LoadStore),
            ("copy_engine", PathIdx::CopyEngine),
            ("nic", PathIdx::Nic),
        ] {
            by_loc.insert(name.to_string(), arr(&self.bytes_by_path_loc[path as usize]));
        }
        put("bytes_by_path_loc", Json::Obj(by_loc));
        put("xfer_plans_loadstore", n(self.xfer_plans_loadstore));
        put("xfer_plans_copy_engine", n(self.xfer_plans_copy_engine));
        put("xfer_plans_nic", n(self.xfer_plans_nic));
        put("adaptive_updates", n(self.adaptive_updates));
        put("plan_cache_hits", n(self.plan_cache_hits));
        put("plan_cache_misses", n(self.plan_cache_misses));
        put("plan_cache_invalidations", n(self.plan_cache_invalidations));
        put("ring_messages", n(self.ring_messages));
        put("ring_completions", n(self.ring_completions));
        put("xfer_batches", n(self.xfer_batches));
        put("xfer_batch_entries", n(self.xfer_batch_entries));
        put("xfer_batch_depth_hist", arr(&self.xfer_batch_depth_hist));
        put("stripe_transfers", n(self.stripe_transfers));
        put("stripe_chunks", n(self.stripe_chunks));
        put("stripe_chunk_hist", arr(&self.stripe_chunk_hist));
        put("engine_bytes", arr(&self.engine_bytes));
        put("engine_ops", arr(&self.engine_ops));
        put("rail_bytes", arr(&self.rail_bytes));
        put("rail_ops", arr(&self.rail_ops));
        put("proxy_service_ns", arr(&self.proxy_service_ns));
        put("proxy_service_ops", arr(&self.proxy_service_ops));
        put(
            "proxy_service_hist",
            Json::Arr(self.proxy_service_hist.iter().map(|row| arr(row)).collect()),
        );
        put(
            "service_wall_ns",
            Json::Arr(self.service_wall_ns.iter().map(|row| arr(row)).collect()),
        );
        put(
            "service_wall_ops",
            Json::Arr(self.service_wall_ops.iter().map(|row| arr(row)).collect()),
        );
        put(
            "service_model_ns",
            Json::Arr(self.service_model_ns.iter().map(|row| arr(row)).collect()),
        );
        put(
            "service_model_ops",
            Json::Arr(self.service_model_ops.iter().map(|row| arr(row)).collect()),
        );
        put("xla_reduce_calls", n(self.xla_reduce_calls));
        put("xla_reduce_elems", n(self.xla_reduce_elems));
        put("native_reduce_elems", n(self.native_reduce_elems));
        put("fault_rail_kills", n(self.fault_rail_kills));
        put("fault_rail_revives", n(self.fault_rail_revives));
        put("fault_engine_kills", n(self.fault_engine_kills));
        put("fault_engine_revives", n(self.fault_engine_revives));
        put("fault_quarantines", n(self.fault_quarantines));
        put("fault_probes", n(self.fault_probes));
        put("fault_redispatched_chunks", n(self.fault_redispatched_chunks));
        put("fault_last_lane_fallbacks", n(self.fault_last_lane_fallbacks));
        put("coll_decision_timeouts", n(self.coll_decision_timeouts));
        put("coll_sync_timeouts", n(self.coll_sync_timeouts));
        put("fault_dropped_chunks", n(self.fault_dropped_chunks));
        put("fault_corrupted_chunks", n(self.fault_corrupted_chunks));
        put("fault_delayed_chunks", n(self.fault_delayed_chunks));
        put("retry_checksum_fail", n(self.retry_checksum_fail));
        put("retry_nacks", n(self.retry_nacks));
        put("retry_replays", n(self.retry_replays));
        put("retry_exhausted", n(self.retry_exhausted));
        put("retry_backoff_ns_total", n(self.retry_backoff_ns_total));
        put("retry_escalations", n(self.retry_escalations));
        put("xfer_op_timeouts", n(self.xfer_op_timeouts));
        put("chain_submitted", n(self.chain_submitted));
        put("chain_triggered", n(self.chain_triggered));
        put("chain_fused_doorbells", n(self.chain_fused_doorbells));
        put("chain_flushed_unfusable", n(self.chain_flushed_unfusable));
        put("chain_depth_hist", arr(&self.chain_depth_hist));
        put("degraded_mode", n(self.degraded_mode));
        put("rail_dead", arr(&self.rail_dead));
        put("engine_dead", arr(&self.engine_dead));
        // Extras go in last so a caller-provided key takes precedence over
        // a colliding built-in instead of silently vanishing.
        for (k, v) in extra {
            o.insert(k, v);
        }
        Json::Obj(o).to_string()
    }

    /// Wall-clock vs modeled service-time comparison per (path,
    /// size-class): the proxy's measured wall sums next to the cost
    /// model's charged sums, with classes whose totals disagree by more
    /// than 2× flagged. Expected to flag heavily on this substrate (wall
    /// clocks measure host memcpys, the model charges Aurora-class
    /// hardware) — the report's purpose is making that gap visible per
    /// regime instead of hiding it in aggregates. Caveats: a striped
    /// transfer records one model charge but one wall charge *per chunk*
    /// (all bucketed by the whole transfer's size, so the ns sums stay
    /// comparable while the ops columns differ), and standard-CL batch
    /// entries measure only the proxy's append — their deferred
    /// per-engine execute time lands in `ServiceOp::Other`, not here.
    pub fn service_delta_report(&self) -> String {
        let mut out = String::from(
            "service-delta: proxy wall-clock vs modeled service time by (path, size)\n\
             path         size       wall-ops  wall-ns-sum   model-ops  model-ns-sum  wall/model\n",
        );
        let mut flagged = 0usize;
        for (pi, name) in [(1usize, "copy-engine"), (2usize, "nic")] {
            for b in 0..SERVICE_SIZE_BUCKETS {
                let (wn, wo) = (self.service_wall_ns[pi][b], self.service_wall_ops[pi][b]);
                let (mn, mo) = (self.service_model_ns[pi][b], self.service_model_ops[pi][b]);
                if wo == 0 && mo == 0 {
                    continue;
                }
                let (ratio, flag) = if wn > 0 && mn > 0 {
                    let r = wn as f64 / mn as f64;
                    let f = !(0.5..=2.0).contains(&r);
                    (format!("{r:.3}"), f)
                } else {
                    ("-".to_string(), true)
                };
                if flag {
                    flagged += 1;
                }
                out.push_str(&format!(
                    "{:<12} {:<10} {:<9} {:<13} {:<10} {:<13} {}{}\n",
                    name,
                    service_size_label(b),
                    wo,
                    wn,
                    mo,
                    mn,
                    ratio,
                    if flag { "  DELTA>2x" } else { "" },
                ));
            }
        }
        out.push_str(&format!("classes off by >2x: {flagged}\n"));
        out
    }

    pub fn report(&self) -> String {
        let loc_row = |p: PathIdx| {
            let r = &self.bytes_by_path_loc[p as usize];
            format!(
                "tile={} gpu={} node={} remote={}",
                crate::util::fmt_bytes(r[0] as usize),
                crate::util::fmt_bytes(r[1] as usize),
                crate::util::fmt_bytes(r[2] as usize),
                crate::util::fmt_bytes(r[3] as usize),
            )
        };
        let coll_row = |s: CollStage| {
            format!(
                "bcast={} fcollect={} reduce={} other={}",
                crate::util::fmt_bytes(self.coll_bytes(CollOpIdx::Broadcast, s) as usize),
                crate::util::fmt_bytes(self.coll_bytes(CollOpIdx::Fcollect, s) as usize),
                crate::util::fmt_bytes(self.coll_bytes(CollOpIdx::Reduce, s) as usize),
                crate::util::fmt_bytes(self.coll_bytes(CollOpIdx::Other, s) as usize),
            )
        };
        format!(
            "ops: put={} get={} amo={} coll={}\n\
             coll ops: bcast={} fcollect={} reduce={} sync={} other={} hier={}\n\
             coll bytes: intra-node [{}] | inter-node [{}]\n\
             bytes: load/store={} copy-engine={} nic={}\n\
             bytes by locality: load/store [{}] | copy-engine [{}] | nic [{}]\n\
             plans: load/store={} copy-engine={} nic={} adaptive-updates={}\n\
             plan cache: hits={} misses={} invalidations={}\n\
             ring: msgs={} completions={} batches={} batch-entries={} mean-depth={:.2}\n\
             stripes: transfers={} chunks={} mean-chunks={:.2}\n\
             engine bytes: [{}]\n\
             rail bytes: [{}]\n\
             proxy service ns (mean): put={:.0} get={:.0} amo={:.0} other={:.0}\n\
             fault: rail-kills={} rail-revives={} engine-kills={} engine-revives={} \
             quarantines={} probes={} redispatched={} last-lane-fallbacks={} \
             decision-timeouts={} sync-timeouts={} degraded={}\n\
             retry: dropped={} corrupted={} delayed={} checksum-fail={} nacks={} \
             replays={} exhausted={} backoff-ns={} escalations={} op-timeouts={}\n\
             chain: submitted={} triggered={} fused-doorbells={} flushed-unfusable={}\n\
             reduce: xla-calls={} xla-elems={} native-elems={}",
            self.puts,
            self.gets,
            self.amos,
            self.collectives(),
            self.coll_broadcast,
            self.coll_fcollect,
            self.coll_reduce,
            self.coll_sync,
            self.coll_other,
            self.coll_hier,
            coll_row(CollStage::Intra),
            coll_row(CollStage::Inter),
            crate::util::fmt_bytes(self.bytes_loadstore as usize),
            crate::util::fmt_bytes(self.bytes_copy_engine as usize),
            crate::util::fmt_bytes(self.bytes_nic as usize),
            loc_row(PathIdx::LoadStore),
            loc_row(PathIdx::CopyEngine),
            loc_row(PathIdx::Nic),
            self.xfer_plans_loadstore,
            self.xfer_plans_copy_engine,
            self.xfer_plans_nic,
            self.adaptive_updates,
            self.plan_cache_hits,
            self.plan_cache_misses,
            self.plan_cache_invalidations,
            self.ring_messages,
            self.ring_completions,
            self.xfer_batches,
            self.xfer_batch_entries,
            self.mean_batch_depth(),
            self.stripe_transfers,
            self.stripe_chunks,
            self.mean_chunks_per_transfer(),
            self.engine_bytes
                .iter()
                .map(|&b| crate::util::fmt_bytes(b as usize))
                .collect::<Vec<_>>()
                .join(" "),
            self.rail_bytes
                .iter()
                .map(|&b| crate::util::fmt_bytes(b as usize))
                .collect::<Vec<_>>()
                .join(" "),
            self.mean_service_ns(ServiceOp::Put),
            self.mean_service_ns(ServiceOp::Get),
            self.mean_service_ns(ServiceOp::Amo),
            self.mean_service_ns(ServiceOp::Other),
            self.fault_rail_kills,
            self.fault_rail_revives,
            self.fault_engine_kills,
            self.fault_engine_revives,
            self.fault_quarantines,
            self.fault_probes,
            self.fault_redispatched_chunks,
            self.fault_last_lane_fallbacks,
            self.coll_decision_timeouts,
            self.coll_sync_timeouts,
            self.degraded_mode,
            self.fault_dropped_chunks,
            self.fault_corrupted_chunks,
            self.fault_delayed_chunks,
            self.retry_checksum_fail,
            self.retry_nacks,
            self.retry_replays,
            self.retry_exhausted,
            self.retry_backoff_ns_total,
            self.retry_escalations,
            self.xfer_op_timeouts,
            self.chain_submitted,
            self.chain_triggered,
            self.chain_fused_doorbells,
            self.chain_flushed_unfusable,
            self.xla_reduce_calls,
            self.xla_reduce_elems,
            self.native_reduce_elems,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_adds() {
        let m = Metrics::new();
        Metrics::add(&m.puts, 3);
        Metrics::add(&m.bytes_loadstore, 4096);
        let s = m.snapshot();
        assert_eq!(s.puts, 3);
        assert_eq!(s.total_bytes(), 4096);
        assert!(s.report().contains("put=3"));
    }

    #[test]
    fn plan_counters_aggregate() {
        let m = Metrics::new();
        Metrics::add(&m.xfer_plans_loadstore, 2);
        Metrics::add(&m.xfer_plans_copy_engine, 1);
        Metrics::add(&m.xfer_plans_nic, 4);
        Metrics::add(&m.adaptive_updates, 5);
        Metrics::add(&m.plan_cache_hits, 9);
        Metrics::add(&m.plan_cache_misses, 3);
        Metrics::add(&m.plan_cache_invalidations, 2);
        let s = m.snapshot();
        assert_eq!(s.total_xfer_plans(), 7);
        assert_eq!(s.adaptive_updates, 5);
        assert!(s.report().contains("adaptive-updates=5"));
        assert_eq!(
            (s.plan_cache_hits, s.plan_cache_misses, s.plan_cache_invalidations),
            (9, 3, 2)
        );
        assert!(s.report().contains("plan cache: hits=9 misses=3 invalidations=2"));
        let j = crate::util::json::Json::parse(&s.to_json()).unwrap();
        assert_eq!(j.get("plan_cache_hits").unwrap().as_usize(), Some(9));
        assert_eq!(j.get("plan_cache_misses").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("plan_cache_invalidations").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn retry_counters_roundtrip() {
        let m = Metrics::new();
        Metrics::add(&m.fault_dropped_chunks, 3);
        Metrics::add(&m.fault_corrupted_chunks, 2);
        Metrics::add(&m.fault_delayed_chunks, 1);
        Metrics::add(&m.retry_checksum_fail, 2);
        Metrics::add(&m.retry_nacks, 4);
        Metrics::add(&m.retry_replays, 5);
        Metrics::add(&m.retry_exhausted, 1);
        Metrics::add(&m.retry_backoff_ns_total, 350_000);
        Metrics::add(&m.retry_escalations, 1);
        Metrics::add(&m.xfer_op_timeouts, 2);
        let s = m.snapshot();
        assert_eq!(
            (s.fault_dropped_chunks, s.fault_corrupted_chunks, s.fault_delayed_chunks),
            (3, 2, 1)
        );
        assert_eq!((s.retry_nacks, s.retry_replays, s.retry_exhausted), (4, 5, 1));
        let r = s.report();
        assert!(
            r.contains(
                "retry: dropped=3 corrupted=2 delayed=1 checksum-fail=2 nacks=4 \
                 replays=5 exhausted=1 backoff-ns=350000 escalations=1 op-timeouts=2"
            ),
            "{r}"
        );
        let j = crate::util::json::Json::parse(&s.to_json()).unwrap();
        assert_eq!(j.get("retry_replays").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("retry_backoff_ns_total").unwrap().as_usize(), Some(350_000));
        assert_eq!(j.get("xfer_op_timeouts").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn chain_counters_roundtrip() {
        let m = Metrics::new();
        m.add_chain(2);
        m.add_chain(3);
        m.add_chain(3);
        Metrics::add(&m.chain_triggered, 4);
        Metrics::add(&m.chain_fused_doorbells, 3);
        Metrics::add(&m.chain_flushed_unfusable, 1);
        let s = m.snapshot();
        assert_eq!(s.chain_submitted, 3);
        assert_eq!(s.chain_triggered, 4);
        assert_eq!(s.chain_fused_doorbells, 3);
        assert_eq!(s.chain_flushed_unfusable, 1);
        assert_eq!(s.chain_depth_hist[batch_depth_bucket(2)], 1);
        assert_eq!(s.chain_depth_hist[batch_depth_bucket(3)], 2);
        assert_eq!(s.chain_depth_hist.iter().sum::<u64>(), s.chain_submitted);
        let r = s.report();
        assert!(
            r.contains("chain: submitted=3 triggered=4 fused-doorbells=3 flushed-unfusable=1"),
            "{r}"
        );
        let j = crate::util::json::Json::parse(&s.to_json()).unwrap();
        assert_eq!(j.get("chain_submitted").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("chain_fused_doorbells").unwrap().as_usize(), Some(3));
        let hist = j.get("chain_depth_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), BATCH_DEPTH_BUCKETS);
        assert_eq!(hist[batch_depth_bucket(3)].as_usize(), Some(2));
    }

    #[test]
    fn fault_counters_and_lane_gauges() {
        use crate::sim::fault::FaultAction;
        let m = Metrics::new();
        m.count_fault_action(FaultAction::KillRail { node: 0, rail: 2 }, true);
        m.count_fault_action(FaultAction::KillEngine { gpu: 1, engine: 5 }, true);
        // Out-of-range lane indices clamp into the last gauge slot.
        m.count_fault_action(FaultAction::KillRail { node: 0, rail: 99 }, true);
        Metrics::add(&m.fault_quarantines, 1);
        Metrics::add(&m.fault_redispatched_chunks, 4);
        Metrics::add(&m.fault_last_lane_fallbacks, 2);
        Metrics::add(&m.coll_decision_timeouts, 1);
        let s = m.snapshot();
        assert_eq!(s.fault_rail_kills, 2);
        assert_eq!(s.fault_engine_kills, 1);
        assert_eq!(s.degraded_mode, 1);
        assert_eq!(s.rail_dead[2], 1);
        assert_eq!(s.rail_dead[RAIL_SLOTS - 1], 1);
        assert_eq!(s.engine_dead[5], 1);
        let r = s.report();
        assert!(
            r.contains(
                "fault: rail-kills=2 rail-revives=0 engine-kills=1 engine-revives=0 \
                 quarantines=1 probes=0 redispatched=4 last-lane-fallbacks=2 \
                 decision-timeouts=1 sync-timeouts=0 degraded=1"
            ),
            "{r}"
        );
        let j = crate::util::json::Json::parse(&s.to_json()).unwrap();
        assert_eq!(j.get("fault_rail_kills").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("fault_redispatched_chunks").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("degraded_mode").unwrap().as_usize(), Some(1));
        let dead = j.get("rail_dead").unwrap().as_arr().unwrap();
        assert_eq!(dead.len(), RAIL_SLOTS);
        assert_eq!(dead[2].as_usize(), Some(1));
        // Revival walks the gauges back down and clears the flag.
        m.count_fault_action(FaultAction::ReviveRail { node: 0, rail: 2 }, false);
        m.count_fault_action(FaultAction::ReviveEngine { gpu: 1, engine: 5 }, false);
        let s = m.snapshot();
        assert_eq!(s.fault_rail_revives, 1);
        assert_eq!(s.rail_dead[2], 0);
        assert_eq!(s.engine_dead[5], 0);
        assert_eq!(s.degraded_mode, 0);
    }

    #[test]
    fn coll_counters_and_stage_byte_table() {
        let m = Metrics::new();
        Metrics::add(&m.coll_broadcast, 2);
        Metrics::add(&m.coll_reduce, 1);
        Metrics::add(&m.coll_sync, 4);
        Metrics::add(&m.coll_hier, 2);
        m.add_coll_bytes(CollOpIdx::Broadcast, CollStage::Intra, 1000);
        m.add_coll_bytes(CollOpIdx::Broadcast, CollStage::Inter, 250);
        m.add_coll_bytes(CollOpIdx::Reduce, CollStage::Inter, 750);
        let s = m.snapshot();
        assert_eq!(s.collectives(), 7);
        assert_eq!(s.coll_bytes(CollOpIdx::Broadcast, CollStage::Intra), 1000);
        assert_eq!(s.coll_stage_total(CollStage::Inter), 1000);
        assert_eq!(s.coll_stage_total(CollStage::Intra), 1000);
        let r = s.report();
        assert!(r.contains("coll=7"), "{r}");
        assert!(r.contains("bcast=2 fcollect=0 reduce=1 sync=4 other=0 hier=2"), "{r}");
        assert!(r.contains("intra-node ["), "{r}");
        let j = crate::util::json::Json::parse(&s.to_json()).unwrap();
        assert_eq!(j.get("collectives").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("coll_broadcast").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("coll_hier").unwrap().as_usize(), Some(2));
        let stages = j.get("coll_stage_bytes").unwrap();
        let inter = stages.get("inter").unwrap().as_arr().unwrap();
        assert_eq!(inter.len(), COLL_OPS);
        assert_eq!(inter[CollOpIdx::Reduce as usize].as_usize(), Some(750));
    }

    #[test]
    fn path_loc_bytes_split_and_sum() {
        let m = Metrics::new();
        m.add_path_bytes(PathIdx::CopyEngine, Locality::SameNode, 1000);
        m.add_path_bytes(PathIdx::CopyEngine, Locality::SameGpu, 24);
        m.add_path_bytes(PathIdx::Nic, Locality::Remote, 512);
        let s = m.snapshot();
        assert_eq!(s.bytes_copy_engine, 1024);
        assert_eq!(s.path_loc_bytes(PathIdx::CopyEngine, Locality::SameNode), 1000);
        assert_eq!(s.path_bytes_sum(PathIdx::CopyEngine), 1024);
        assert_eq!(s.path_loc_bytes(PathIdx::Nic, Locality::Remote), 512);
        assert_eq!(s.path_bytes_sum(PathIdx::LoadStore), 0);
    }

    #[test]
    fn batch_depth_histogram_buckets() {
        assert_eq!(batch_depth_bucket(1), 0);
        assert_eq!(batch_depth_bucket(2), 1);
        assert_eq!(batch_depth_bucket(4), 2);
        assert_eq!(batch_depth_bucket(8), 3);
        assert_eq!(batch_depth_bucket(16), 4);
        assert_eq!(batch_depth_bucket(100), 5);
        let m = Metrics::new();
        m.add_batch(1);
        m.add_batch(8);
        m.add_batch(8);
        let s = m.snapshot();
        assert_eq!(s.xfer_batches, 3);
        assert_eq!(s.xfer_batch_entries, 17);
        assert_eq!(s.xfer_batch_depth_hist[0], 1);
        assert_eq!(s.xfer_batch_depth_hist[3], 2);
        assert_eq!(s.xfer_batch_depth_hist.iter().sum::<u64>(), s.xfer_batches);
        assert!((s.mean_batch_depth() - 17.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn stripe_and_engine_tables() {
        let m = Metrics::new();
        m.add_stripe(1);
        m.add_stripe(9);
        m.add_engine_dispatch(0, 1024);
        m.add_engine_dispatch(3, 2048);
        m.add_engine_dispatch(3, 2048);
        m.add_engine_dispatch(999, 8); // clamps into the last slot
        let s = m.snapshot();
        assert_eq!(s.stripe_transfers, 2);
        assert_eq!(s.stripe_chunks, 10);
        assert_eq!(s.stripe_chunk_hist.iter().sum::<u64>(), s.stripe_transfers);
        assert!((s.mean_chunks_per_transfer() - 5.0).abs() < 1e-9);
        assert_eq!(s.engine_bytes[0], 1024);
        assert_eq!(s.engine_bytes[3], 4096);
        assert_eq!(s.engine_ops[3], 2);
        assert_eq!(s.engine_bytes[ENGINE_SLOTS - 1], 8);
        assert!(s.report().contains("mean-chunks=5.00"));
    }

    #[test]
    fn json_snapshot_parses_and_mirrors_counters() {
        let m = Metrics::new();
        Metrics::add(&m.puts, 7);
        m.add_stripe(4);
        m.add_engine_dispatch(2, 512);
        m.add_service(ServiceOp::Get, 99);
        let s = m.snapshot();
        let j = crate::util::json::Json::parse(&s.to_json()).expect("snapshot JSON parses");
        assert_eq!(j.get("puts").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("stripe_chunks").unwrap().as_usize(), Some(4));
        let eng = j.get("engine_bytes").unwrap().as_arr().unwrap();
        assert_eq!(eng.len(), ENGINE_SLOTS);
        assert_eq!(eng[2].as_usize(), Some(512));
        assert_eq!(
            j.get("proxy_service_ops").unwrap().idx(ServiceOp::Get as usize).unwrap().as_usize(),
            Some(1)
        );
        assert!(j.get("bytes_by_path_loc").unwrap().get("nic").is_some());
    }

    #[test]
    fn rail_tables_and_service_delta() {
        assert_eq!(service_size_bucket(64), 0);
        assert_eq!(service_size_bucket(4096), 0);
        assert_eq!(service_size_bucket(4097), 1);
        assert_eq!(service_size_bucket(1 << 20), 3);
        assert_eq!(service_size_bucket(u64::MAX), SERVICE_SIZE_BUCKETS - 1);
        assert_eq!(service_size_label(0), "<=4KiB");

        let m = Metrics::new();
        m.add_rail_dispatch(1, 1024);
        m.add_rail_dispatch(1, 1024);
        m.add_rail_dispatch(999, 8); // clamps into the last slot
        m.add_service_wall(PathIdx::Nic, 1 << 20, 300);
        m.add_service_model(PathIdx::Nic, 1 << 20, 90_000);
        m.add_service_wall(PathIdx::CopyEngine, 512, 100);
        m.add_service_model(PathIdx::CopyEngine, 512, 150);
        let s = m.snapshot();
        assert_eq!(s.rail_bytes[1], 2048);
        assert_eq!(s.rail_ops[1], 2);
        assert_eq!(s.rail_bytes[RAIL_SLOTS - 1], 8);
        assert_eq!(s.service_wall_ns[PathIdx::Nic as usize][3], 300);
        assert_eq!(s.service_model_ns[PathIdx::Nic as usize][3], 90_000);
        let report = s.service_delta_report();
        // The wildly-off NIC class is flagged, the close engine one not.
        assert!(report.contains("nic") && report.contains("DELTA>2x"), "{report}");
        assert!(report.contains("classes off by >2x: 1"), "{report}");
        assert!(s.report().contains("rail bytes"), "{}", s.report());
        // JSON export mirrors the new tables.
        let j = crate::util::json::Json::parse(&s.to_json()).unwrap();
        let rails = j.get("rail_bytes").unwrap().as_arr().unwrap();
        assert_eq!(rails.len(), RAIL_SLOTS);
        assert_eq!(rails[1].as_usize(), Some(2048));
        assert!(j.get("service_wall_ns").unwrap().as_arr().is_some());
    }

    #[test]
    fn size_class_bounds_are_the_single_source_of_truth() {
        // Every bound is the inclusive top of its class and the exclusive
        // floor of the next — the geometry the calibrator shares.
        for (i, &bound) in SERVICE_SIZE_BOUNDS.iter().enumerate() {
            assert_eq!(service_size_bucket(bound), i, "top of class {i}");
            assert_eq!(service_size_bucket(bound + 1), i + 1, "floor of class {}", i + 1);
        }
        assert_eq!(service_size_bucket(0), 0);
        assert_eq!(
            service_size_bucket(*SERVICE_SIZE_BOUNDS.last().unwrap() * 2),
            SERVICE_SIZE_BUCKETS - 1
        );
        // One label per class.
        for b in 0..SERVICE_SIZE_BUCKETS {
            assert!(!service_size_label(b).is_empty());
        }
    }

    #[test]
    fn json_with_extra_entries_merges_at_top_level() {
        use crate::util::json::Json;
        let m = Metrics::new();
        Metrics::add(&m.puts, 2);
        let s = m.snapshot();
        let text = s.to_json_with(vec![(
            "calibration".to_string(),
            Json::Bool(true),
        )]);
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("calibration"), Some(&Json::Bool(true)));
        assert_eq!(j.get("puts").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn service_time_histogram() {
        assert_eq!(service_ns_bucket(0), 0);
        assert_eq!(service_ns_bucket(16), 0);
        assert_eq!(service_ns_bucket(32), 1);
        assert_eq!(service_ns_bucket(u64::MAX), SERVICE_NS_BUCKETS - 1);
        let m = Metrics::new();
        m.add_service(ServiceOp::Put, 100);
        m.add_service(ServiceOp::Put, 300);
        m.add_service(ServiceOp::Amo, 50);
        let s = m.snapshot();
        assert_eq!(s.proxy_service_ops[ServiceOp::Put as usize], 2);
        assert_eq!(s.proxy_service_ns[ServiceOp::Put as usize], 400);
        assert_eq!(s.mean_service_ns(ServiceOp::Put), 200.0);
        assert_eq!(s.mean_service_ns(ServiceOp::Get), 0.0);
        let hist_total: u64 = s.proxy_service_hist.iter().flatten().sum();
        assert_eq!(hist_total, 3);
    }
}
