//! Traffic/operation counters, aggregated across PEs and the proxy.
//!
//! Every counter is a relaxed atomic — the hot path pays one uncontended
//! `fetch_add`; snapshots are approximate under concurrency, exact at
//! quiescence (which is when reports read them).

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct Metrics {
    // Op counts by API family.
    pub puts: AtomicU64,
    pub gets: AtomicU64,
    pub amos: AtomicU64,
    pub collectives: AtomicU64,
    // Bytes by data path (the paper's three regimes).
    pub bytes_loadstore: AtomicU64,
    pub bytes_copy_engine: AtomicU64,
    pub bytes_nic: AtomicU64,
    // Transfer-plan engine: route decisions by executor, and online
    // adaptive-table refinements (adaptive-cutover feedback).
    pub xfer_plans_loadstore: AtomicU64,
    pub xfer_plans_copy_engine: AtomicU64,
    pub xfer_plans_nic: AtomicU64,
    pub adaptive_updates: AtomicU64,
    // Reverse-offload ring.
    pub ring_messages: AtomicU64,
    pub ring_completions: AtomicU64,
    // XLA kernel invocations (reduce path).
    pub xla_reduce_calls: AtomicU64,
    pub xla_reduce_elems: AtomicU64,
    // Native (non-kernel) reduce fallbacks.
    pub native_reduce_elems: AtomicU64,
}

impl Metrics {
    pub fn new() -> std::sync::Arc<Self> {
        std::sync::Arc::new(Self::default())
    }

    #[inline]
    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            puts: self.puts.load(Ordering::Relaxed),
            gets: self.gets.load(Ordering::Relaxed),
            amos: self.amos.load(Ordering::Relaxed),
            collectives: self.collectives.load(Ordering::Relaxed),
            bytes_loadstore: self.bytes_loadstore.load(Ordering::Relaxed),
            bytes_copy_engine: self.bytes_copy_engine.load(Ordering::Relaxed),
            bytes_nic: self.bytes_nic.load(Ordering::Relaxed),
            xfer_plans_loadstore: self.xfer_plans_loadstore.load(Ordering::Relaxed),
            xfer_plans_copy_engine: self.xfer_plans_copy_engine.load(Ordering::Relaxed),
            xfer_plans_nic: self.xfer_plans_nic.load(Ordering::Relaxed),
            adaptive_updates: self.adaptive_updates.load(Ordering::Relaxed),
            ring_messages: self.ring_messages.load(Ordering::Relaxed),
            ring_completions: self.ring_completions.load(Ordering::Relaxed),
            xla_reduce_calls: self.xla_reduce_calls.load(Ordering::Relaxed),
            xla_reduce_elems: self.xla_reduce_elems.load(Ordering::Relaxed),
            native_reduce_elems: self.native_reduce_elems.load(Ordering::Relaxed),
        }
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub puts: u64,
    pub gets: u64,
    pub amos: u64,
    pub collectives: u64,
    pub bytes_loadstore: u64,
    pub bytes_copy_engine: u64,
    pub bytes_nic: u64,
    pub xfer_plans_loadstore: u64,
    pub xfer_plans_copy_engine: u64,
    pub xfer_plans_nic: u64,
    pub adaptive_updates: u64,
    pub ring_messages: u64,
    pub ring_completions: u64,
    pub xla_reduce_calls: u64,
    pub xla_reduce_elems: u64,
    pub native_reduce_elems: u64,
}

impl MetricsSnapshot {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_loadstore + self.bytes_copy_engine + self.bytes_nic
    }

    pub fn total_xfer_plans(&self) -> u64 {
        self.xfer_plans_loadstore + self.xfer_plans_copy_engine + self.xfer_plans_nic
    }

    pub fn report(&self) -> String {
        format!(
            "ops: put={} get={} amo={} coll={}\n\
             bytes: load/store={} copy-engine={} nic={}\n\
             plans: load/store={} copy-engine={} nic={} adaptive-updates={}\n\
             ring: msgs={} completions={}\n\
             reduce: xla-calls={} xla-elems={} native-elems={}",
            self.puts,
            self.gets,
            self.amos,
            self.collectives,
            crate::util::fmt_bytes(self.bytes_loadstore as usize),
            crate::util::fmt_bytes(self.bytes_copy_engine as usize),
            crate::util::fmt_bytes(self.bytes_nic as usize),
            self.xfer_plans_loadstore,
            self.xfer_plans_copy_engine,
            self.xfer_plans_nic,
            self.adaptive_updates,
            self.ring_messages,
            self.ring_completions,
            self.xla_reduce_calls,
            self.xla_reduce_elems,
            self.native_reduce_elems,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_adds() {
        let m = Metrics::new();
        Metrics::add(&m.puts, 3);
        Metrics::add(&m.bytes_loadstore, 4096);
        let s = m.snapshot();
        assert_eq!(s.puts, 3);
        assert_eq!(s.total_bytes(), 4096);
        assert!(s.report().contains("put=3"));
    }

    #[test]
    fn plan_counters_aggregate() {
        let m = Metrics::new();
        Metrics::add(&m.xfer_plans_loadstore, 2);
        Metrics::add(&m.xfer_plans_copy_engine, 1);
        Metrics::add(&m.xfer_plans_nic, 4);
        Metrics::add(&m.adaptive_updates, 5);
        let s = m.snapshot();
        assert_eq!(s.total_xfer_plans(), 7);
        assert_eq!(s.adaptive_updates, 5);
        assert!(s.report().contains("adaptive-updates=5"));
    }
}
