//! SPMD job launching helpers (the `mpirun`/`oshrun` analogue).

use std::sync::Arc;

use crate::ishmem::{Ishmem, IshmemConfig, PeCtx};
use crate::runtime::XlaRuntime;

/// Build a machine, optionally attach the PJRT runtime, run `f` SPMD, and
/// return per-PE results. The one-call entry used by examples and benches.
pub fn run_spmd<R, F>(config: IshmemConfig, with_runtime: bool, f: F) -> anyhow::Result<Vec<R>>
where
    R: Send,
    F: Fn(&mut PeCtx) -> R + Send + Sync,
{
    let ish = Ishmem::new(config)?;
    if with_runtime {
        let rt = XlaRuntime::load_default()?;
        ish.attach_runtime(rt);
    }
    let out = ish.launch(f);
    ish.shutdown();
    Ok(out)
}

/// Convenience wrapper: default single-node config with `npes` PEs.
pub fn run_npes<R, F>(npes: usize, f: F) -> anyhow::Result<Vec<R>>
where
    R: Send,
    F: Fn(&mut PeCtx) -> R + Send + Sync,
{
    run_spmd(IshmemConfig::with_npes(npes), false, f)
}

/// Reusable machine handle for harnesses that launch many phases without
/// re-creating proxies/heaps each time.
pub struct Machine {
    pub ish: Arc<Ishmem>,
}

impl Machine {
    pub fn new(config: IshmemConfig) -> anyhow::Result<Machine> {
        Ok(Machine { ish: Ishmem::new(config)? })
    }

    pub fn with_runtime(config: IshmemConfig) -> anyhow::Result<Machine> {
        let m = Machine::new(config)?;
        m.ish.attach_runtime(XlaRuntime::load_default()?);
        Ok(m)
    }

    pub fn launch<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(&mut PeCtx) -> R + Send + Sync,
    {
        self.ish.launch(f)
    }
}

impl Drop for Machine {
    fn drop(&mut self) {
        self.ish.shutdown();
    }
}
