//! L3 coordination: PE launching, metrics, and job orchestration.
//!
//! ishmem's execution model is SPMD: `npes` processing elements run the
//! same program against the symmetric heap. [`launch`] materializes that
//! model with one OS thread per PE (each owning a [`crate::ishmem::PeCtx`])
//! and propagates panics; [`metrics`] aggregates per-path traffic counters
//! the way the real library's stats interface does.

pub mod launch;
pub mod metrics;

pub use metrics::Metrics;
