//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the CPU
//! PJRT client via the `xla` crate.
//!
//! The crate's `PjRtClient` is `Rc`-based (not `Send`), so the runtime runs
//! a dedicated **service thread** that owns the client and the compiled-
//! executable cache; PE threads submit [`HostTensor`] requests over an
//! mpsc channel and block on a reply channel. On a GPU system this thread
//! is the moral equivalent of the device's compute queue.
//!
//! Artifacts are HLO **text** (`HloModuleProto::from_text_file`); see
//! DESIGN.md — serialized jax≥0.5 protos are rejected by xla_extension
//! 0.5.1, text round-trips.
//!
//! In the hermetic offline build the native binding crate is replaced by
//! [`xla_stub`] (identical call surface, client startup fails
//! descriptively); reductions then use the native fold. Swap the alias
//! below for the real `xla` crate to enable PJRT.

pub mod artifacts;
mod xla_stub;

use xla_stub as xla;

pub use artifacts::{Manifest, ModelManifest};

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// Element type of a [`HostTensor`] (the subset our artifacts use).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I64,
}

impl DType {
    fn element_type(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::I64 => xla::ElementType::S64,
        }
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I64 => 8,
        }
    }

    pub fn from_kernel_name(name: &str) -> Option<DType> {
        match name {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            "i64" => Some(DType::I64),
            _ => None,
        }
    }
}

/// A host-side tensor: raw little-endian bytes + dims + dtype. The wire
/// format between PE threads and the PJRT service thread.
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl HostTensor {
    pub fn new(dtype: DType, dims: Vec<usize>, bytes: Vec<u8>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>() * dtype.size(), bytes.len());
        HostTensor { dtype, dims, bytes }
    }

    pub fn from_f32(dims: Vec<usize>, v: &[f32]) -> Self {
        let mut bytes = Vec::with_capacity(v.len() * 4);
        for x in v {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        HostTensor::new(DType::F32, dims, bytes)
    }

    pub fn from_i32(dims: Vec<usize>, v: &[i32]) -> Self {
        let mut bytes = Vec::with_capacity(v.len() * 4);
        for x in v {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        HostTensor::new(DType::I32, dims, bytes)
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::new(DType::I32, vec![], v.to_le_bytes().to_vec())
    }

    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn to_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32);
        self.bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn scalar_f32(&self) -> f32 {
        assert_eq!(self.dtype, DType::F32);
        f32::from_le_bytes(self.bytes[..4].try_into().unwrap())
    }
}

enum Request {
    /// Execute artifact `file` with `args`; reply with the flattened
    /// output tuple.
    Execute {
        file: String,
        args: Vec<HostTensor>,
        reply: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    /// Warm the executable cache (compile without running).
    Precompile {
        file: String,
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// Handle to the PJRT service thread. Cheap to share (`Arc`).
pub struct XlaRuntime {
    manifest: Manifest,
    tx: Mutex<mpsc::Sender<Request>>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl XlaRuntime {
    /// Load `artifacts/` (or `$RISHMEM_ARTIFACTS`) and start the service.
    pub fn load_default() -> Result<std::sync::Arc<Self>> {
        Self::load(Manifest::default_dir())
    }

    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<std::sync::Arc<Self>> {
        // §Perf iteration 3 (EXPERIMENTS.md): the Eigen intra-op pool adds
        // ~12% dispatch overhead per kernel launch on this 1-core box;
        // disable it unless the user set their own XLA_FLAGS.
        if std::env::var_os("XLA_FLAGS").is_none() {
            std::env::set_var("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false");
        }
        let manifest = Manifest::load(dir)?;
        let (tx, rx) = mpsc::channel::<Request>();
        let base = manifest.dir.clone();
        // Probe the client on the service thread; surface startup errors.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_loop(base, rx, ready_tx))
            .context("spawning PJRT service thread")?;
        ready_rx
            .recv()
            .context("PJRT service thread died during startup")??;
        Ok(std::sync::Arc::new(XlaRuntime {
            manifest,
            tx: Mutex::new(tx),
            worker: Mutex::new(Some(worker)),
        }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn reduce_chunk_elems(&self) -> usize {
        self.manifest.reduce_chunk_elems()
    }

    fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(req)
            .map_err(|_| anyhow!("PJRT service thread is gone"))
    }

    /// Execute an artifact by file name (relative to the artifacts dir).
    pub fn execute(&self, file: &str, args: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.submit(Request::Execute { file: file.to_string(), args, reply })?;
        rx.recv().map_err(|_| anyhow!("PJRT service dropped reply"))?
    }

    /// Pre-compile an artifact (hot-path warmup).
    pub fn precompile(&self, file: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.submit(Request::Precompile { file: file.to_string(), reply })?;
        rx.recv().map_err(|_| anyhow!("PJRT service dropped reply"))?
    }

    /// Wide-chunk element count, if the artifacts provide one.
    pub fn reduce_wide_elems(&self) -> Option<usize> {
        (self.manifest.reduce_wide_rows > 0)
            .then(|| self.manifest.reduce_wide_rows * self.manifest.reduce_cols)
    }

    /// One pairwise reduce-kernel fold: `acc = op(acc, other)` over one
    /// (rows × cols) chunk of `dtype`. Bytes in, bytes out.
    pub fn reduce_fold_bytes(
        &self,
        op: &str,
        dtype: &str,
        acc: &mut [u8],
        other: &[u8],
    ) -> Result<()> {
        self.fold_family(op, dtype, acc, other, false)
    }

    /// Same fold over one *wide* chunk (launch-amortized bulk path).
    pub fn reduce_fold_bytes_wide(
        &self,
        op: &str,
        dtype: &str,
        acc: &mut [u8],
        other: &[u8],
    ) -> Result<()> {
        self.fold_family(op, dtype, acc, other, true)
    }

    fn fold_family(
        &self,
        op: &str,
        dtype: &str,
        acc: &mut [u8],
        other: &[u8],
        wide: bool,
    ) -> Result<()> {
        let dt = DType::from_kernel_name(dtype)
            .ok_or_else(|| anyhow!("dtype {dtype:?} has no reduce kernel"))?;
        let (rows, files) = if wide {
            anyhow::ensure!(self.manifest.reduce_wide_rows > 0, "no wide reduce artifacts");
            (self.manifest.reduce_wide_rows, &self.manifest.reduce_wide_files)
        } else {
            (self.manifest.reduce_rows, &self.manifest.reduce_files)
        };
        let dims = vec![rows, self.manifest.reduce_cols];
        let expect = dims.iter().product::<usize>() * dt.size();
        anyhow::ensure!(
            acc.len() == expect && other.len() == expect,
            "reduce fold wants exactly one chunk ({expect} bytes), got {}/{}",
            acc.len(),
            other.len()
        );
        let file = files
            .get(&(op.to_string(), dtype.to_string()))
            .ok_or_else(|| anyhow!("no reduce artifact for ({op}, {dtype})"))?
            .clone();
        let out = self.execute(
            &file,
            vec![
                HostTensor::new(dt, dims.clone(), acc.to_vec()),
                HostTensor::new(dt, dims, other.to_vec()),
            ],
        )?;
        anyhow::ensure!(out.len() == 1, "reduce kernel returned {} outputs", out.len());
        acc.copy_from_slice(&out[0].bytes);
        Ok(())
    }

    pub fn shutdown(&self) {
        let _ = self.submit(Request::Shutdown);
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for XlaRuntime {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------- worker ---

fn service_loop(
    base: std::path::PathBuf,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PJRT CPU client: {e}")));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => return,
            Request::Precompile { file, reply } => {
                let r = get_exec(&client, &base, &mut cache, &file).map(|_| ());
                let _ = reply.send(r);
            }
            Request::Execute { file, args, reply } => {
                let r = (|| -> Result<Vec<HostTensor>> {
                    let exec = get_exec(&client, &base, &mut cache, &file)?;
                    let literals: Vec<xla::Literal> = args
                        .iter()
                        .map(|t| {
                            xla::Literal::create_from_shape_and_untyped_data(
                                t.dtype.element_type(),
                                &t.dims,
                                &t.bytes,
                            )
                            .map_err(|e| anyhow!("literal: {e}"))
                        })
                        .collect::<Result<_>>()?;
                    let bufs = exec
                        .execute::<xla::Literal>(&literals)
                        .map_err(|e| anyhow!("execute {file}: {e}"))?;
                    let result = bufs[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("fetch result: {e}"))?;
                    // aot.py lowers with return_tuple=True: always a tuple.
                    let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
                    parts.into_iter().map(literal_to_tensor).collect()
                })();
                let _ = reply.send(r);
            }
        }
    }
}

fn get_exec<'c>(
    client: &xla::PjRtClient,
    base: &std::path::Path,
    cache: &'c mut HashMap<String, xla::PjRtLoadedExecutable>,
    file: &str,
) -> Result<&'c xla::PjRtLoadedExecutable> {
    if !cache.contains_key(file) {
        let path = base.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exec = client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {file}: {e}"))?;
        cache.insert(file.to_string(), exec);
    }
    Ok(cache.get(file).unwrap())
}

fn literal_to_tensor(lit: xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let (dtype, bytes) = match shape.ty() {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("f32: {e}"))?;
            (DType::F32, v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow!("i32: {e}"))?;
            (DType::I32, v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        xla::ElementType::S64 => {
            let v = lit.to_vec::<i64>().map_err(|e| anyhow!("i64: {e}"))?;
            (DType::I64, v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        other => anyhow::bail!("unsupported output element type {other:?}"),
    };
    Ok(HostTensor { dtype, dims, bytes })
}
