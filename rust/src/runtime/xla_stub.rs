//! Offline stub of the `xla` crate surface used by [`super`] (PJRT CPU
//! client bindings, a.k.a. `xla-rs` over `xla_extension`).
//!
//! The real crate links the native XLA/PJRT C++ runtime, which is not
//! available in the hermetic build environment. This stub keeps the
//! service-thread code compiling with identical call shapes;
//! [`PjRtClient::cpu`] fails with a descriptive error, which
//! `XlaRuntime::load` surfaces at startup — so reductions transparently
//! use the native fold (the `ishmem` request path never requires PJRT; it
//! is an acceleration, see `ishmem/collectives.rs::fold`).
//!
//! To use the real backend, replace the `use xla_stub as xla;` alias in
//! `runtime/mod.rs` with the external `xla` crate dependency.

use std::fmt;

/// Error type mirroring the binding crate's (Display-able, opaque).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT backend not available: offline build uses the xla stub \
         (rust/src/runtime/xla_stub.rs); native reduce fold is used instead"
            .to_string(),
    )
}

/// XLA element types (subset + spares to mirror the real enum).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    U64,
    F32,
    F64,
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _bytes: &[u8],
    ) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
