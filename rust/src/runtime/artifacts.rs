//! `artifacts/manifest.json` parsing: the index of every AOT-lowered HLO
//! module emitted by `python/compile/aot.py` (the L1/L2 → L3 ABI).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub reduce_rows: usize,
    pub reduce_cols: usize,
    /// (op, dtype) → artifact file name.
    pub reduce_files: HashMap<(String, String), String>,
    /// Wide-chunk variant (launch-overhead amortization); empty when the
    /// artifacts predate it.
    pub reduce_wide_rows: usize,
    pub reduce_wide_files: HashMap<(String, String), String>,
    pub copy_file: String,
    pub models: HashMap<String, ModelManifest>,
}

#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub param_count: usize,
    /// Canonical flat (name, shape) parameter order — the calling
    /// convention of `train_step` / `eval_loss` / `init_params`.
    pub params: Vec<(String, Vec<usize>)>,
    pub train_step_file: String,
    pub eval_loss_file: String,
    pub init_file: String,
}

impl ModelManifest {
    pub fn param_elems(&self, i: usize) -> usize {
        self.params[i].1.iter().product()
    }
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let need = |j: &Json, k: &str| -> Result<Json> {
            j.get(k).cloned().ok_or_else(|| anyhow!("manifest missing key {k:?}"))
        };
        let need_usize = |j: &Json, k: &str| -> Result<usize> {
            need(j, k)?.as_usize().ok_or_else(|| anyhow!("key {k:?} not a usize"))
        };
        let need_str = |j: &Json, k: &str| -> Result<String> {
            Ok(need(j, k)?
                .as_str()
                .ok_or_else(|| anyhow!("key {k:?} not a string"))?
                .to_string())
        };

        let red = need(&v, "reduce")?;
        let mut reduce_files = HashMap::new();
        for e in need(&red, "entries")?.as_arr().unwrap_or(&[]) {
            reduce_files.insert(
                (need_str(e, "op")?, need_str(e, "dtype")?),
                need_str(e, "file")?,
            );
        }
        let mut reduce_wide_files = HashMap::new();
        let mut reduce_wide_rows = 0;
        if let Some(wide) = v.get("reduce_wide") {
            reduce_wide_rows = need_usize(wide, "rows")?;
            for e in need(wide, "entries")?.as_arr().unwrap_or(&[]) {
                reduce_wide_files.insert(
                    (need_str(e, "op")?, need_str(e, "dtype")?),
                    need_str(e, "file")?,
                );
            }
        }

        let copy = need(&v, "copy")?;

        let mut models = HashMap::new();
        if let Some(obj) = v.get("models").and_then(|m| m.as_obj()) {
            for (name, m) in obj {
                let mut params = Vec::new();
                for p in need(m, "params")?.as_arr().unwrap_or(&[]) {
                    let shape = need(p, "shape")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
                        .collect::<Result<Vec<_>>>()?;
                    params.push((need_str(p, "name")?, shape));
                }
                models.insert(
                    name.clone(),
                    ModelManifest {
                        name: name.clone(),
                        vocab: need_usize(m, "vocab")?,
                        d_model: need_usize(m, "d_model")?,
                        n_heads: need_usize(m, "n_heads")?,
                        n_layers: need_usize(m, "n_layers")?,
                        seq_len: need_usize(m, "seq_len")?,
                        batch: need_usize(m, "batch")?,
                        param_count: need_usize(m, "param_count")?,
                        params,
                        train_step_file: need_str(m, "train_step")?,
                        eval_loss_file: need_str(m, "eval_loss")?,
                        init_file: need_str(m, "init")?,
                    },
                );
            }
        }

        Ok(Manifest {
            reduce_rows: need_usize(&red, "rows")?,
            reduce_cols: need_usize(&red, "cols")?,
            reduce_files,
            reduce_wide_rows,
            reduce_wide_files,
            copy_file: need_str(&copy, "file")?,
            models,
            dir,
        })
    }

    pub fn reduce_chunk_elems(&self) -> usize {
        self.reduce_rows * self.reduce_cols
    }

    pub fn reduce_file(&self, op: &str, dtype: &str) -> Option<PathBuf> {
        self.reduce_files
            .get(&(op.to_string(), dtype.to_string()))
            .map(|f| self.dir.join(f))
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest (re-run aot with --models)"))
    }

    /// Default artifacts directory: `$RISHMEM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("RISHMEM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("rishmem-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,
                "reduce":{"rows":64,"cols":128,
                  "entries":[{"op":"sum","dtype":"f32","file":"reduce_sum_f32.hlo.txt"}]},
                "copy":{"rows":64,"cols":128,"dtype":"f32","file":"copy_f32.hlo.txt"},
                "models":{"tiny":{"vocab":64,"d_model":32,"n_heads":2,"n_layers":1,
                  "seq_len":16,"batch":2,"param_count":100,
                  "params":[{"name":"tok_emb","shape":[64,32]}],
                  "train_step":"t.hlo.txt","eval_loss":"e.hlo.txt","init":"i.hlo.txt"}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.reduce_chunk_elems(), 8192);
        assert!(m.reduce_file("sum", "f32").is_some());
        assert!(m.reduce_file("xor", "f32").is_none());
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.params[0].0, "tok_emb");
        assert_eq!(tiny.param_elems(0), 2048);
        assert!(m.model("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
