//! Collective operations (paper §III-G.2).
//!
//! Intra-node algorithms are interconnect-aware, exactly as the paper
//! describes:
//!
//! * **sync**: every PE *pushes* an atomic increment to each member (the
//!   Xe-Links pipeline fire-and-forget remote atomics), then waits on its
//!   own cached counter.
//! * **broadcast / fcollect**: "push" stores — stores are faster than
//!   loads, and looping destinations innermost load-shares across all the
//!   Xe-Links.
//! * **reduce**: split by address across threads, vector load one local +
//!   one remote block, combine, store — with *every PE duplicating the
//!   computation* to avoid extra synchronization. The combine lanes run
//!   the AOT Pallas kernel through PJRT when attached (L1 on the request
//!   path), with a native fallback for small sizes and uncovered dtypes.
//!
//! Inter-node members are reached through the OFI transport (the paper
//! "relies on OpenSHMEM for inter-node operations").
//!
//! The collective cutover (Fig 6/7): the work-item store fan-out competes
//! with host-initiated copy engines; the decision depends on message size,
//! work-group size *and* PE count. All of it flows through the unified
//! transfer-plan engine: this module digests the member list into a
//! [`FanoutShape`] (it owns the IPC table) and the planner
//! ([`crate::xfer::plan::XferEngine::plan_fanout`]) picks the path.
//!
//! Team-spanning broadcast/fcollect/reduce additionally choose an
//! *algorithm*: the flat per-peer fan-out, or a hierarchical
//! tile/GPU/node decomposition where only node leaders touch the NIC
//! (inter-node hops composed as per-hop rail-striped [`TransferPlan`]s,
//! intra-node redistribution on the striped copy-engine path). The choice
//! runs through the same cost-model + adaptive-cutover machinery as p2p
//! routing ([`crate::sim::CostModel::coll_estimates_at`],
//! [`crate::xfer::plan::XferEngine::coll_decide`]); single-node teams
//! always take the flat path, bit-for-bit the pre-hierarchy behavior.
//!
//! [`TransferPlan`]: crate::xfer::plan::TransferPlan

use std::sync::atomic::Ordering;

use crate::coordinator::metrics::{CollOpIdx, CollStage, Metrics, PathIdx};
use crate::device::{collaborative_copy, WorkGroup};
use crate::sim::cost::tree_depth;
use crate::sim::topology::Locality;
use crate::sim::{CollAlgo, CollOp, CollShape, DegradedError, DegradedKind, ParamsSnapshot, SimClock};
use crate::xfer::plan::{FanoutShape, OpKind, Route};

use super::config::CollAlgoMode;
use super::cutover::Path;
use super::heap::{team_sync_offset, MAX_TEAMS, RESERVED_BYTES};
use super::types::{as_bytes, as_bytes_mut, ReduceElem, ReduceOp};
use super::{PeCtx, SymAddr, TeamId};

/// Reserved-region base for collect's size-exchange slots (one u64 per
/// world PE, above the team sync words).
const COLLECT_BASE: usize = MAX_TEAMS * 16;

/// Spin until `poll` yields a value, with the usual spin → yield
/// escalation — bounded by `timeout_ms` when non-zero. `timeout_ms == 0`
/// waits forever, bit-for-bit the pre-fault unbounded spin (the wall
/// clock is never consulted on that path). On expiry the wait returns a
/// structured [`DegradedError`] instead of hanging the thread on a peer
/// that died or churned out mid-collective.
fn bounded_wait<T>(
    timeout_ms: u64,
    kind: DegradedKind,
    team: usize,
    epoch: u64,
    pe: usize,
    poll: impl FnMut() -> Option<T>,
) -> Result<T, DegradedError> {
    crate::sim::bounded_poll(timeout_ms, poll, |waited_ms| {
        DegradedError::collective(kind, team, epoch, pe, waited_ms)
    })
}

impl PeCtx {
    // ------------------------------------------------------------- sync ----

    /// `ishmem_team_sync` — the "push" synchronization.
    pub fn team_sync(&self, team: TeamId) {
        let spec = self.team_spec(team);
        let tid = team.index();
        let off = team_sync_offset(tid);
        let round = {
            let mut rounds = self.team_rounds.borrow_mut();
            rounds[tid] += 1;
            rounds[tid]
        };

        let mut remote_members = 0usize;
        for peer in spec.members() {
            if self.ipc.lookup(peer).is_some() {
                self.rt
                    .heaps
                    .heap(peer)
                    .atomic_u64(off)
                    .fetch_add(1, Ordering::AcqRel);
            } else {
                let dummy = SimClock::new();
                self.rt
                    .transport
                    .amo_fetch_add_u64(peer, off, 1, &dummy)
                    .expect("sync atomic");
                remote_members += 1;
            }
        }
        // Pipelined fire-and-forget atomics + NIC hops for remote members.
        self.clock
            .advance(self.rt.cost.pipelined_atomics_ns(spec.size));
        if remote_members > 0 {
            self.clock
                .advance(self.rt.cost.params.nic.latency_ns * remote_members as f64);
        }

        // Local wait: atomic compare on the GPU cache (paper: the local
        // wait "can use the local GPU caches effectively"). Bounded by
        // `coll.sync_timeout_ms` when set — a dead peer's missing
        // increment surfaces as a structured error, not an infinite spin.
        let me = self.rt.heaps.heap(self.pe()).atomic_u64(off);
        let target = round * spec.size as u64;
        if let Err(e) = bounded_wait(
            self.rt.config.coll.sync_timeout_ms,
            DegradedKind::SyncTimeout,
            tid,
            round,
            self.pe(),
            || (me.load(Ordering::Acquire) >= target).then_some(()),
        ) {
            Metrics::add(&self.rt.metrics.coll_sync_timeouts, 1);
            panic!("{e}");
        }
        self.clock
            .advance(self.rt.cost.params.xe.atomic_fetch_ns * 0.2);
        Metrics::add(&self.rt.metrics.coll_sync, 1);
    }

    /// `ishmem_sync_all`.
    pub fn sync_all(&self) {
        self.team_sync(TeamId::WORLD);
    }

    /// `ishmem_barrier_all` — quiet + sync (barrier implies completion of
    /// all outstanding ops, unlike sync).
    pub fn barrier_all(&self) {
        self.quiet();
        self.sync_all();
    }

    /// Team barrier.
    pub fn team_barrier(&self, team: TeamId) {
        self.quiet();
        self.team_sync(team);
    }

    // ------------------------------------------------------ fan-out core ---

    /// Push `len` bytes from my heap (`src_off`) to `dst_off` on `peer`,
    /// over the chosen path. Data movement is real; cost charged by the
    /// caller via the fan-out models (so parallel lanes aren't serially
    /// double-charged).
    fn push_block(&self, peer: usize, src_off: usize, dst_off: usize, len: usize, wg: &WorkGroup) {
        if self.ipc.lookup(peer).is_some() {
            collaborative_copy(&self.rt.heaps, self.pe(), src_off, peer, dst_off, len, wg);
        } else {
            let dummy = SimClock::new();
            self.rt
                .transport
                .put(self.pe(), src_off, peer, dst_off, len, &dummy)
                .expect("collective push");
            self.rt
                .metrics
                .add_path_bytes(PathIdx::Nic, Locality::Remote, len as u64);
        }
    }

    /// Digest a member list into the planner's [`FanoutShape`]: peers
    /// grouped per target GPU (one Xe-Link each), with NIC spill-over for
    /// unreachable members. This is the only fan-out knowledge that lives
    /// outside the planner — it needs the IPC table, which is per-PE.
    pub(crate) fn fanout_shape(&self, peers: &[usize], bytes: usize) -> FanoutShape {
        let topo = self.rt.topo();
        let mut per_link: std::collections::HashMap<usize, (Locality, usize, usize)> =
            std::collections::HashMap::new();
        let mut nic_bytes = 0usize;
        let mut rep_loc = Locality::SameTile;
        for &peer in peers {
            if self.ipc.lookup(peer).is_none() {
                nic_bytes += bytes;
                continue;
            }
            let loc = self.loc_of(peer);
            if loc as u8 > rep_loc as u8 {
                rep_loc = loc;
            }
            let link = topo.global_gpu_of(peer);
            let e = per_link.entry(link).or_insert((loc, 0, 0));
            e.1 += bytes;
            e.2 += 1;
        }
        FanoutShape {
            per_link: per_link.into_values().collect(),
            nic_bytes,
            npeers: peers.len(),
            loc: rep_loc,
        }
    }

    /// Execute + charge a fan-out of my `src_off` block to `dst_off` on
    /// each peer, over the path planned by the xfer engine (paper Fig 6:
    /// the decision depends on nelems, work-items, and npes). Returns the
    /// path taken (reports/tests).
    pub(crate) fn fanout(
        &self,
        peers: &[usize],
        src_off: usize,
        dst_off: usize,
        bytes: usize,
        items: usize,
    ) -> Path {
        if peers.is_empty() || bytes == 0 {
            return Path::LoadStore;
        }
        let shape = self.fanout_shape(peers, bytes);
        let plan = self.rt.xfer.plan_fanout(&shape, bytes, items);
        let wg = WorkGroup::new(items.max(1).min(WorkGroup::MAX_SIZE));
        match plan.route {
            Route::LoadStore => {
                for &peer in peers {
                    if self.ipc.lookup(peer).is_some() {
                        self.rt.metrics.add_path_bytes(
                            PathIdx::LoadStore,
                            self.loc_of(peer),
                            bytes as u64,
                        );
                    }
                    // Reachable: collaborative work-item stores;
                    // unreachable: OFI (counted inside push_block).
                    self.push_block(peer, src_off, dst_off, bytes, &wg);
                }
            }
            Route::CopyEngine => {
                // One batched doorbell for the whole plan-group: every
                // reachable peer becomes heap-offset Put descriptors
                // (source is my user heap — no staging copy needed) that
                // the proxy runs on real `DeviceAddr` command lists. Large
                // per-peer blocks are stripe-aware: chunks carry ids and
                // least-loaded-engine hints so each link's fan-out spreads
                // over its GPU's engines. The blocking flush returns once
                // all entries executed, so the usual fan-out → team_sync
                // ordering holds.
                let gpu = self.my_gpu();
                // Hints cycle over *all* engines (lightest first): the
                // fan-out model charges the link at the aggregate
                // engines_per_gpu rate, so dispatch must spread that wide
                // too — per-transfer stripe width only sets chunk sizes.
                let all_engines = self.rt.cost.params.ce.engines_per_gpu.max(1);
                let engines = self.rt.cost.engine_pick(gpu, all_engines);
                // Remote members stripe their blocks across the node's
                // NIC rails the same way (lightest rails first).
                let all_rails = self.rt.cost.params.nic.rails.max(1);
                let rails = self.rt.cost.rail_pick(self.node(), all_rails);
                // One lane counter per lane kind across the whole
                // fan-out, so peers don't all pile their first chunk on
                // the same engine/rail.
                let mut lane = 0usize;
                let mut rail_lane = 0usize;
                for &peer in peers {
                    if self.ipc.lookup(peer).is_some() {
                        let loc = self.loc_of(peer);
                        let (chunk, _width) = self.rt.cost.stripe_for(
                            loc,
                            bytes,
                            usize::MAX,
                            self.rt.xfer.cl_immediate_boundary(),
                        );
                        let total = bytes.div_ceil(chunk.max(1));
                        let std_cl = !self.rt.xfer.cl_immediate_for(chunk.min(bytes));
                        for (idx, off, len) in crate::xfer::exec::chunk_iter(bytes, chunk) {
                            let eng = engines[lane % engines.len()];
                            lane += 1;
                            let desc = crate::ringbuf::BatchDescriptor::put(
                                peer,
                                dst_off + off,
                                src_off + off,
                                len,
                            )
                            .with_standard_cl(std_cl)
                            .with_chunk(idx as u32, total as u32, eng as u8)
                            .with_transfer_bytes(bytes as u64);
                            self.stream_append(desc, 0);
                        }
                        if total > 1 {
                            self.rt.metrics.add_stripe(total);
                        }
                        self.rt
                            .metrics
                            .add_path_bytes(PathIdx::CopyEngine, loc, bytes as u64);
                    } else {
                        // Unreachable member: the block rides the same
                        // batched doorbell as rail-hinted chunked Put
                        // descriptors (source = my user heap, no staging
                        // claim), so a cross-node block stripes across
                        // the node's NIC rails like p2p remote puts do.
                        let (chunk, _w) =
                            self.rt.cost.rail_stripe_for(bytes, usize::MAX);
                        let total = bytes.div_ceil(chunk.max(1));
                        for (idx, off, len) in crate::xfer::exec::chunk_iter(bytes, chunk) {
                            let rail = rails[rail_lane % rails.len()];
                            rail_lane += 1;
                            let desc = crate::ringbuf::BatchDescriptor::put(
                                peer,
                                dst_off + off,
                                src_off + off,
                                len,
                            )
                            .with_chunk(idx as u32, total as u32, rail as u8)
                            .with_transfer_bytes(bytes as u64);
                            self.stream_append(desc, 0);
                        }
                        if total > 1 {
                            self.rt.metrics.add_stripe(total);
                        }
                        self.rt.metrics.add_path_bytes(
                            PathIdx::Nic,
                            Locality::Remote,
                            bytes as u64,
                        );
                    }
                }
                self.stream_flush_blocking();
            }
            // push_block already routes unreachable members over OFI and
            // counts their bytes_nic; the fan-out itself never plans Nic.
            Route::Nic => unreachable!("plan_fanout only routes LoadStore/CopyEngine"),
        }
        self.clock.advance(plan.modeled_ns);
        self.rt.xfer.record(&plan, plan.modeled_ns);
        match plan.route {
            Route::LoadStore => Path::LoadStore,
            Route::CopyEngine => Path::CopyEngine,
            Route::Nic => unreachable!(),
        }
    }

    // ---------------------------------------- hierarchical machinery ------
    //
    // ISSUE 7: team-spanning collectives decompose into tile/GPU/node
    // stages with only node leaders on the wire. Real data still moves
    // through the same substrate as the flat path (`fanout` collaborative
    // stores / copy engines, `push_block` OFI), so results are bitwise
    // identical; the hierarchy shows up in the modeled schedule (per-hop
    // `TransferPlan`s on the striped NIC rails) and the per-stage byte
    // table.

    /// Pick the algorithm for one team collective: config-forced, or the
    /// cost model's estimates fed through the same adaptive cutover
    /// machinery as p2p routing (one cell per op/size/team-size bucket,
    /// [`crate::xfer::plan::XferEngine::coll_decide`]). Single-node teams
    /// always take the flat path — there is no inter-node stage, and the
    /// pre-hierarchy behavior must reproduce exactly.
    ///
    /// Flat and hierarchical executions issue *different numbers of team
    /// syncs*, so every member must take the same branch or the counting
    /// barrier deadlocks — and per-member adaptive reads can diverge (a
    /// concurrent observe may flip a close cell between two members'
    /// reads). The team's lowest member therefore decides once and
    /// publishes through `rt.coll_decisions`, keyed by the mirrored
    /// per-team epoch; the rest wait (a real-time spin, like the sync
    /// barrier — no modeled time). Returns the chosen algorithm and the
    /// snapshot it was priced under (its version guards the feedback).
    fn coll_select(
        &self,
        op: CollOp,
        team: TeamId,
        shape: &CollShape,
        bytes: usize,
    ) -> (CollAlgo, std::sync::Arc<ParamsSnapshot>) {
        let snap = self.rt.cost.model.snapshot();
        if shape.single_node() || bytes == 0 {
            return (CollAlgo::Flat, snap);
        }
        let spec = self.team_spec(team);
        let tid = team.index();
        let epoch = {
            let mut e = self.coll_epoch.borrow_mut();
            e[tid] += 1;
            e[tid]
        };
        if self.pe() == spec.start {
            let algo = match self.rt.config.coll.algo {
                CollAlgoMode::Flat => CollAlgo::Flat,
                CollAlgoMode::HierRing => CollAlgo::HierRing,
                CollAlgoMode::HierTree => CollAlgo::HierTree,
                CollAlgoMode::Auto => {
                    let est = self.rt.cost.coll_estimates_at(
                        &snap.params,
                        shape,
                        op,
                        bytes,
                        self.rt.config.coll.leader_fanout,
                    );
                    let (hier, hier_ns) = est.best_hier();
                    let take_hier = self.rt.xfer.coll_decide(
                        op,
                        bytes,
                        shape.npes,
                        est.flat_ns,
                        hier_ns,
                        snap.version,
                    );
                    if take_hier { hier } else { CollAlgo::Flat }
                }
            };
            self.rt
                .coll_decisions
                .lock()
                .unwrap()
                .insert((tid, epoch), (algo, spec.size - 1));
            (algo, snap)
        } else {
            // Bounded by `coll.decision_timeout_ms` when set: a leader
            // that died before publishing surfaces as a structured
            // error instead of spinning this member forever.
            match bounded_wait(
                self.rt.config.coll.decision_timeout_ms,
                DegradedKind::DecisionTimeout,
                tid,
                epoch,
                self.pe(),
                || {
                    let mut map = self.rt.coll_decisions.lock().unwrap();
                    let entry = map.get_mut(&(tid, epoch))?;
                    let algo = entry.0;
                    entry.1 -= 1;
                    if entry.1 == 0 {
                        map.remove(&(tid, epoch));
                    }
                    Some(algo)
                },
            ) {
                Ok(algo) => (algo, snap),
                Err(e) => {
                    Metrics::add(&self.rt.metrics.coll_decision_timeouts, 1);
                    panic!("{e}");
                }
            }
        }
    }

    /// Flat-path stage accounting: a per-peer fan-out of `bytes` splits
    /// into IPC-reachable (intra-node) and transport (inter-node) volume.
    fn count_flat_coll_bytes(&self, op: CollOpIdx, peers: &[usize], bytes: usize) {
        let local = peers
            .iter()
            .filter(|&&p| self.ipc.lookup(p).is_some())
            .count();
        let remote = peers.len() - local;
        if local > 0 {
            self.rt
                .metrics
                .add_coll_bytes(op, CollStage::Intra, (bytes * local) as u64);
        }
        if remote > 0 {
            self.rt
                .metrics
                .add_coll_bytes(op, CollStage::Inter, (bytes * remote) as u64);
        }
    }

    /// Charge (and feed back) one inter-node leader hop as a composed p2p
    /// [`crate::xfer::plan::TransferPlan`] — hierarchical stages ride the
    /// exact rail-striped machinery p2p remote puts plan with, so rail
    /// calibration and occupancy reach collective schedules too.
    fn coll_wire_hop(&self, bytes: usize) {
        if bytes == 0 {
            return;
        }
        let plan = self
            .rt
            .xfer
            .plan_p2p(OpKind::Put, false, Locality::Remote, bytes, 1);
        self.clock.advance(plan.modeled_ns);
        self.rt.xfer.record(&plan, plan.modeled_ns);
    }

    /// Clamped inter-node tree arity + depth (mirrors the estimator's
    /// clamping so executed schedules match priced ones).
    fn coll_tree_arity(&self, nnodes: usize) -> (usize, usize) {
        let k = self
            .rt
            .config
            .coll
            .leader_fanout
            .clamp(2, nnodes.max(2))
            .min(nnodes.saturating_sub(1).max(1));
        (k, tree_depth(nnodes, k))
    }

    /// Inter-node broadcast schedule among `nnodes` leaders: the ring
    /// forwards the full payload once plus one rail-chunk per extra hop
    /// (pipelined chain); the tree serializes `k` children per level on
    /// each parent's rails.
    fn coll_bcast_wire_charge(&self, algo: CollAlgo, nnodes: usize, bytes: usize) {
        match algo {
            CollAlgo::Flat => {}
            CollAlgo::HierRing => {
                self.coll_wire_hop(bytes);
                let (chunk, _w) = self.rt.cost.rail_stripe_for(bytes.max(1), usize::MAX);
                for _ in 0..nnodes.saturating_sub(2) {
                    self.coll_wire_hop(chunk.min(bytes));
                }
            }
            CollAlgo::HierTree => {
                let (k, depth) = self.coll_tree_arity(nnodes);
                for _ in 0..depth * k {
                    self.coll_wire_hop(bytes);
                }
            }
        }
    }

    /// Inter-node exchange schedule among leaders (fcollect's slice
    /// allgather, reduce's gathered-block exchange): the ring moves my
    /// node's slice once per hop; the tree gathers to the root and
    /// broadcasts the assembled result back down.
    fn coll_exchange_wire_charge(
        &self,
        algo: CollAlgo,
        nnodes: usize,
        slice_bytes: usize,
        total_bytes: usize,
    ) {
        match algo {
            CollAlgo::Flat => {}
            CollAlgo::HierRing => {
                for _ in 0..nnodes.saturating_sub(1) {
                    self.coll_wire_hop(slice_bytes);
                }
            }
            CollAlgo::HierTree => {
                let (k, depth) = self.coll_tree_arity(nnodes);
                for _ in 0..2 * k * depth {
                    self.coll_wire_hop(total_bytes / depth.max(1));
                }
            }
        }
    }

    // -------------------------------------------------------- broadcast ----

    /// `ishmem_broadcast` (single calling thread).
    pub fn broadcast<T: super::ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        root: usize,
        team: TeamId,
    ) {
        self.broadcast_items(dest, src, nelems, root, team, 1);
    }

    /// Shared impl; `items` = cooperating work-items (work_group variant).
    pub(crate) fn broadcast_items<T: super::ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        root: usize,
        team: TeamId,
        items: usize,
    ) {
        assert!(nelems <= dest.len() && nelems <= src.len());
        let spec = self.team_spec(team);
        let bytes = nelems * std::mem::size_of::<T>();
        Metrics::add(&self.rt.metrics.coll_broadcast, 1);
        let shape = CollShape::from_members(self.rt.topo(), spec.members());
        let (algo, snap) = self.coll_select(CollOp::Broadcast, team, &shape, bytes);
        let t0 = self.clock.now_ns();
        if algo == CollAlgo::Flat {
            if self.team_my_pe(team) == root {
                // Push to every other member; self dest gets a local copy.
                let peers: Vec<usize> =
                    spec.members().filter(|&p| p != self.pe()).collect();
                self.rt.heaps.copy(
                    self.pe(),
                    src.byte_offset(),
                    self.pe(),
                    dest.byte_offset(),
                    bytes,
                );
                self.count_flat_coll_bytes(CollOpIdx::Broadcast, &peers, bytes);
                self.fanout(&peers, src.byte_offset(), dest.byte_offset(), bytes, items);
            }
            self.team_sync(team);
        } else {
            Metrics::add(&self.rt.metrics.coll_hier, 1);
            self.broadcast_hier(
                src.byte_offset(),
                dest.byte_offset(),
                bytes,
                root,
                team,
                items,
                algo,
                &shape,
            );
        }
        // The root saw the whole schedule — it feeds the algorithm cell.
        if !shape.single_node() && self.team_my_pe(team) == root {
            self.rt.xfer.coll_observe(
                CollOp::Broadcast,
                bytes,
                spec.size,
                algo != CollAlgo::Flat,
                self.clock.now_ns() - t0,
                snap.version,
            );
        }
    }

    /// Hierarchical broadcast: root → other node leaders on the wire
    /// (stage 1), node leaders → their node's GPU leaders over Xe-Link
    /// (stage 2), GPU leaders → remaining tile members over MDFI (stage
    /// 3). Every stage moves real bytes over the same substrate as flat,
    /// so results match bit for bit.
    #[allow(clippy::too_many_arguments)]
    fn broadcast_hier(
        &self,
        src_off: usize,
        dst_off: usize,
        bytes: usize,
        root: usize,
        team: TeamId,
        items: usize,
        algo: CollAlgo,
        shape: &CollShape,
    ) {
        let spec = self.team_spec(team);
        let topo = self.rt.topo();
        let me = self.pe();
        let root_pe = spec.start + root * spec.stride;
        let my_node = topo.node_of(me);
        let root_node = topo.node_of(root_pe);
        // The root leads its own node; elsewhere the lowest member leads.
        let leader = if my_node == root_node {
            root_pe
        } else {
            spec.node_leader(topo, me)
        };

        // Stage 1 — inter-node: root feeds every other node's leader.
        if me == root_pe {
            self.rt.heaps.copy(me, src_off, me, dst_off, bytes);
            let wg = WorkGroup::new(items.max(1).min(WorkGroup::MAX_SIZE));
            let leaders: Vec<usize> = spec
                .node_groups(topo)
                .into_iter()
                .filter(|(n, _)| *n != root_node)
                .map(|(_, g)| g[0])
                .collect();
            for &l in &leaders {
                self.push_block(l, src_off, dst_off, bytes, &wg);
            }
            self.rt.metrics.add_coll_bytes(
                CollOpIdx::Broadcast,
                CollStage::Inter,
                (bytes * leaders.len()) as u64,
            );
            self.coll_bcast_wire_charge(algo, shape.nnodes(), bytes);
        }
        self.team_sync(team);

        // Stage 2 — node leaders feed their node's GPU leaders.
        if me == leader {
            let targets: Vec<usize> = spec
                .gpu_leaders_on_node(topo, my_node)
                .into_iter()
                .filter(|&g| g != me)
                .collect();
            if !targets.is_empty() {
                self.rt.metrics.add_coll_bytes(
                    CollOpIdx::Broadcast,
                    CollStage::Intra,
                    (bytes * targets.len()) as u64,
                );
                self.fanout(&targets, dst_off, dst_off, bytes, items);
            }
        }
        self.team_sync(team);

        // Stage 3 — GPU leaders fan to their remaining tile members.
        if spec.gpu_leader(topo, me) == me {
            let my_gpu = topo.global_gpu_of(me);
            let targets: Vec<usize> = spec
                .members()
                .filter(|&p| topo.global_gpu_of(p) == my_gpu && p != me && p != leader)
                .collect();
            if !targets.is_empty() {
                self.rt.metrics.add_coll_bytes(
                    CollOpIdx::Broadcast,
                    CollStage::Intra,
                    (bytes * targets.len()) as u64,
                );
                self.fanout(&targets, dst_off, dst_off, bytes, items);
            }
        }
        self.team_sync(team);
    }

    // ---------------------------------------------------------- fcollect ---

    /// `ishmem_fcollect` — fixed-size allgather: my `nelems` block lands at
    /// team-rank offset in every member's `dest`.
    pub fn fcollect<T: super::ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        team: TeamId,
    ) {
        self.fcollect_items(dest, src, nelems, team, 1);
    }

    pub(crate) fn fcollect_items<T: super::ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        team: TeamId,
        items: usize,
    ) {
        let spec = self.team_spec(team);
        assert!(nelems <= src.len());
        assert!(spec.size * nelems <= dest.len(), "fcollect dest too small");
        let bytes = nelems * std::mem::size_of::<T>();
        let my_rank = self.team_my_pe(team);
        Metrics::add(&self.rt.metrics.coll_fcollect, 1);
        let shape = CollShape::from_members(self.rt.topo(), spec.members());
        let (algo, snap) = self.coll_select(CollOp::Fcollect, team, &shape, bytes);
        let t0 = self.clock.now_ns();

        if algo == CollAlgo::Flat {
            let dst_off = dest.byte_offset() + my_rank * bytes;
            self.rt
                .heaps
                .copy(self.pe(), src.byte_offset(), self.pe(), dst_off, bytes);
            let peers: Vec<usize> = spec.members().filter(|&p| p != self.pe()).collect();
            self.count_flat_coll_bytes(CollOpIdx::Fcollect, &peers, bytes);
            self.fanout(&peers, src.byte_offset(), dst_off, bytes, items);
            self.team_sync(team);
        } else {
            Metrics::add(&self.rt.metrics.coll_hier, 1);
            self.fcollect_hier(
                src.byte_offset(),
                dest.byte_offset(),
                bytes,
                team,
                items,
                algo,
                &shape,
            );
        }
        // Node leaders carry the wire schedule — they feed the cell.
        if !shape.single_node()
            && spec.node_leader(self.rt.topo(), self.pe()) == self.pe()
        {
            self.rt.xfer.coll_observe(
                CollOp::Fcollect,
                bytes,
                spec.size,
                algo != CollAlgo::Flat,
                self.clock.now_ns() - t0,
                snap.version,
            );
        }
    }

    /// Hierarchical fcollect: members gather their blocks to the node
    /// leader (stage 1), leaders exchange whole node slices — contiguous
    /// team-rank ranges, the [`TeamSpec`] monotone-node invariant — on
    /// the wire (stage 2), then redistribute the assembled buffer down
    /// the GPU-leader chain (stage 3).
    ///
    /// [`TeamSpec`]: super::teams::TeamSpec
    fn fcollect_hier(
        &self,
        src_off: usize,
        dst_base: usize,
        bytes: usize,
        team: TeamId,
        items: usize,
        algo: CollAlgo,
        shape: &CollShape,
    ) {
        let spec = self.team_spec(team);
        let topo = self.rt.topo();
        let me = self.pe();
        let my_rank = spec.rank_of(me).expect("not a member");
        let my_node = topo.node_of(me);
        let leader = spec.node_leader(topo, me);
        let total = bytes * spec.size;

        // Everyone parks their own block at rank offset first.
        self.rt
            .heaps
            .copy(me, src_off, me, dst_base + my_rank * bytes, bytes);

        // Stage 1 — intra gather to the node leader.
        if me != leader {
            self.rt
                .metrics
                .add_coll_bytes(CollOpIdx::Fcollect, CollStage::Intra, bytes as u64);
            self.fanout(&[leader], src_off, dst_base + my_rank * bytes, bytes, items);
        }
        self.team_sync(team);

        // Stage 2 — leaders exchange node slices.
        if me == leader {
            let group: Vec<usize> = spec
                .members()
                .filter(|&p| topo.node_of(p) == my_node)
                .collect();
            let first_rank = spec.rank_of(group[0]).expect("member");
            let slice_off = dst_base + first_rank * bytes;
            let slice_bytes = bytes * group.len();
            let others: Vec<usize> = spec
                .node_groups(topo)
                .into_iter()
                .filter(|(n, _)| *n != my_node)
                .map(|(_, g)| g[0])
                .collect();
            let wg = WorkGroup::new(items.max(1).min(WorkGroup::MAX_SIZE));
            for &l in &others {
                self.push_block(l, slice_off, slice_off, slice_bytes, &wg);
            }
            self.rt.metrics.add_coll_bytes(
                CollOpIdx::Fcollect,
                CollStage::Inter,
                (slice_bytes * others.len()) as u64,
            );
            self.coll_exchange_wire_charge(algo, shape.nnodes(), slice_bytes, total);
        }
        self.team_sync(team);

        // Stage 3 — redistribute the assembled buffer: leader → GPU
        // leaders over Xe-Link, then GPU leaders → their tiles over MDFI
        // (the pipelined GPU-leader chain the estimator prices).
        if me == leader {
            let targets: Vec<usize> = spec
                .gpu_leaders_on_node(topo, my_node)
                .into_iter()
                .filter(|&g| g != me)
                .collect();
            if !targets.is_empty() {
                self.rt.metrics.add_coll_bytes(
                    CollOpIdx::Fcollect,
                    CollStage::Intra,
                    (total * targets.len()) as u64,
                );
                self.fanout(&targets, dst_base, dst_base, total, items);
            }
        }
        self.team_sync(team);
        if spec.gpu_leader(topo, me) == me {
            let my_gpu = topo.global_gpu_of(me);
            let targets: Vec<usize> = spec
                .members()
                .filter(|&p| topo.global_gpu_of(p) == my_gpu && p != me && p != leader)
                .collect();
            if !targets.is_empty() {
                self.rt.metrics.add_coll_bytes(
                    CollOpIdx::Fcollect,
                    CollStage::Intra,
                    (total * targets.len()) as u64,
                );
                self.fanout(&targets, dst_base, dst_base, total, items);
            }
        }
        self.team_sync(team);
    }

    /// Host-initiated fcollect — the Fig 6 dashed baseline: the host
    /// starts one copy-engine transfer per destination (no ring, PCIe
    /// doorbell per transfer).
    pub fn host_fcollect<T: super::ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        team: TeamId,
    ) {
        let spec = self.team_spec(team);
        let bytes = nelems * std::mem::size_of::<T>();
        let my_rank = self.team_my_pe(team);
        Metrics::add(&self.rt.metrics.coll_other, 1);
        let dst_off = dest.byte_offset() + my_rank * bytes;
        // The host enqueues one copy per destination and the engines run
        // them concurrently (up to engines_per_gpu), so the modeled time
        // is doorbells (serial) + the slowest link's startup+transfer —
        // not a serial sum.
        let mut per_link: std::collections::HashMap<usize, (Locality, usize, usize)> =
            std::collections::HashMap::new();
        let mut doorbells = 0usize;
        for peer in spec.members() {
            if peer == self.pe() {
                self.rt
                    .heaps
                    .copy(self.pe(), src.byte_offset(), self.pe(), dst_off, bytes);
                continue;
            }
            if self.ipc.lookup(peer).is_some() {
                let loc = self.loc_of(peer);
                self.rt
                    .heaps
                    .copy(self.pe(), src.byte_offset(), peer, dst_off, bytes);
                let link = self.rt.topo().global_gpu_of(peer);
                let e = per_link.entry(link).or_insert((loc, 0, 0));
                e.1 += bytes;
                e.2 += 1;
                doorbells += 1;
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::CopyEngine, loc, bytes as u64);
            } else {
                self.rt
                    .transport
                    .put(self.pe(), src.byte_offset(), peer, dst_off, bytes, &self.clock)
                    .expect("host_fcollect transport");
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::Nic, Locality::Remote, bytes as u64);
            }
        }
        // Learnable constants (startup, single-engine fraction) read live
        // through the calibrated overlay, like the device-initiated path.
        let ce = self.rt.cost.ce_eff();
        let xe = &self.rt.cost.params.xe;
        let mut engine_time: f64 = 0.0;
        for (_link, (loc, link_bytes, transfers)) in per_link {
            let startups = transfers.div_ceil(ce.engines_per_gpu) as f64;
            engine_time = engine_time.max(
                startups * ce.startup_immediate_ns
                    + link_bytes as f64 / ce.striped_bw_gbs(xe, loc, ce.engines_per_gpu),
            );
        }
        self.clock.advance(
            self.rt.cost.params.overhead.host_issue_ns
                + ce.host_doorbell_ns * doorbells as f64
                + engine_time,
        );
        self.team_sync(team);
    }

    // ------------------------------------------------------------ collect --

    /// `ishmem_collect` — variable-size allgather. Exchanges block sizes
    /// through the reserved-region slots, then pushes data at the computed
    /// offsets.
    pub fn collect<T: super::ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        my_nelems: usize,
        team: TeamId,
    ) {
        self.collect_items(dest, src, my_nelems, team, 1)
    }

    pub(crate) fn collect_items<T: super::ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        my_nelems: usize,
        team: TeamId,
        items: usize,
    ) {
        let spec = self.team_spec(team);
        assert!(my_nelems <= src.len());
        assert!(
            COLLECT_BASE + self.npes() * 8 <= RESERVED_BYTES,
            "too many PEs for collect size-exchange region"
        );
        Metrics::add(&self.rt.metrics.coll_other, 1);

        // Phase 1: publish my size into every member's slot[my_world_pe].
        for peer in spec.members() {
            let slot = COLLECT_BASE + self.pe() * 8;
            if self.ipc.lookup(peer).is_some() {
                self.rt
                    .heaps
                    .heap(peer)
                    .atomic_u64(slot)
                    .store(my_nelems as u64, Ordering::Release);
            } else {
                let dummy = SimClock::new();
                let bytes = (my_nelems as u64).to_le_bytes();
                self.rt
                    .transport
                    .put_from_ptr(bytes.as_ptr() as u64, peer, slot, 8, &dummy)
                    .expect("collect size publish");
            }
        }
        self.clock
            .advance(self.rt.cost.pipelined_atomics_ns(spec.size));
        self.team_sync(team);

        // Phase 2: compute my element offset = sum of lower ranks' sizes.
        let my_rank = spec.rank_of(self.pe()).expect("not a member");
        let mut offset_elems = 0usize;
        let mut total = 0usize;
        for (rank, peer) in spec.members().enumerate() {
            let sz = self
                .rt
                .heaps
                .heap(self.pe())
                .atomic_u64(COLLECT_BASE + peer * 8)
                .load(Ordering::Acquire) as usize;
            if rank < my_rank {
                offset_elems += sz;
            }
            total += sz;
        }
        assert!(total <= dest.len(), "collect dest too small for {total} elems");

        // Phase 3: push my block everywhere.
        let esz = std::mem::size_of::<T>();
        let bytes = my_nelems * esz;
        let dst_off = dest.byte_offset() + offset_elems * esz;
        self.rt
            .heaps
            .copy(self.pe(), src.byte_offset(), self.pe(), dst_off, bytes);
        let peers: Vec<usize> = spec.members().filter(|&p| p != self.pe()).collect();
        self.fanout(&peers, src.byte_offset(), dst_off, bytes, items);
        self.team_sync(team);
    }

    // ----------------------------------------------------------- alltoall --

    /// `ishmem_alltoall` — block `j` of my `src` lands in member `j`'s
    /// `dest` at my team-rank offset.
    pub fn alltoall<T: super::ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        team: TeamId,
    ) {
        self.alltoall_items(dest, src, nelems, team, 1)
    }

    pub(crate) fn alltoall_items<T: super::ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        team: TeamId,
        items: usize,
    ) {
        let spec = self.team_spec(team);
        assert!(spec.size * nelems <= src.len());
        assert!(spec.size * nelems <= dest.len());
        let esz = std::mem::size_of::<T>();
        let bytes = nelems * esz;
        let my_rank = self.team_my_pe(team);
        Metrics::add(&self.rt.metrics.coll_other, 1);

        let wg = WorkGroup::new(1);
        for (j, peer) in spec.members().enumerate() {
            let s_off = src.byte_offset() + j * bytes;
            let d_off = dest.byte_offset() + my_rank * bytes;
            if peer == self.pe() {
                self.rt.heaps.copy(self.pe(), s_off, self.pe(), d_off, bytes);
            } else {
                if self.ipc.lookup(peer).is_some() {
                    self.rt.metrics.add_path_bytes(
                        PathIdx::LoadStore,
                        self.loc_of(peer),
                        bytes as u64,
                    );
                }
                self.push_block(peer, s_off, d_off, bytes, &wg);
            }
        }
        let peers: Vec<usize> = spec.members().filter(|&p| p != self.pe()).collect();
        let shape = self.fanout_shape(&peers, bytes);
        self.clock
            .advance(self.rt.xfer.fanout_store_ns(&shape, 1));
        self.team_sync(team);
    }

    // ------------------------------------------------------------- reduce --

    /// `ishmem_reduce` family (sum/prod/min/max/and/or/xor via `op`).
    pub fn reduce<T: ReduceElem>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        op: ReduceOp,
        team: TeamId,
    ) {
        self.reduce_items(dest, src, nelems, op, team, 1);
    }

    pub(crate) fn reduce_items<T: ReduceElem>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        op: ReduceOp,
        team: TeamId,
        items: usize,
    ) {
        assert!(T::supports(op), "op {op:?} undefined for this dtype");
        assert!(nelems <= src.len() && nelems <= dest.len());
        let spec = self.team_spec(team);
        let esz = std::mem::size_of::<T>();
        let bytes = nelems * esz;
        Metrics::add(&self.rt.metrics.coll_reduce, 1);
        let topo = self.rt.topo();
        let shape = CollShape::from_members(topo, spec.members());
        let (algo, snap) = self.coll_select(CollOp::Reduce, team, &shape, bytes);
        let hier = algo != CollAlgo::Flat;
        if hier {
            Metrics::add(&self.rt.metrics.coll_hier, 1);
        }
        let t0 = self.clock.now_ns();

        // Inputs must be globally ready before anyone reads them.
        self.team_sync(team);

        // Gather + fold, duplicated on every PE (paper §III-G.2). The
        // duplicated gather is the bit contract: the fold order is my
        // member order under BOTH algorithms, so hierarchical results
        // match flat ones bit for bit — the hierarchy lives in the
        // modeled schedule and the byte table, not in the arithmetic.
        let mut acc = vec![T::from_zeroed(); nelems];
        self.rt
            .heaps
            .heap(self.pe())
            .read(src.byte_offset(), as_bytes_mut(&mut acc));
        let mut tmp = vec![T::from_zeroed(); nelems];
        let mut gathered: f64 = 0.0;
        for peer in spec.members() {
            if peer == self.pe() {
                continue;
            }
            if self.ipc.lookup(peer).is_some() {
                self.rt
                    .heaps
                    .heap(peer)
                    .read(src.byte_offset(), as_bytes_mut(&mut tmp));
                gathered += self
                    .rt
                    .cost
                    .params
                    .xe
                    .loadstore_ns(self.loc_of(peer), bytes, items);
            } else {
                let dummy = SimClock::new();
                self.rt
                    .transport
                    .get_to_ptr(
                        peer,
                        src.byte_offset(),
                        tmp.as_mut_ptr() as u64,
                        bytes,
                        &dummy,
                    )
                    .expect("reduce gather");
                gathered += self.rt.cost.internode_ns(bytes, true, true);
            }
            self.fold(op, &mut acc, &tmp);
        }
        if !hier {
            // Flat charge + accounting (the pre-hierarchy behavior).
            // Loads from distinct peers pipeline across links; approximate
            // with the max of per-peer times plus a per-peer issue charge.
            let peers: Vec<usize> = spec.members().filter(|&p| p != self.pe()).collect();
            self.count_flat_coll_bytes(CollOpIdx::Reduce, &peers, bytes);
            let members = spec.size.saturating_sub(1) as f64;
            self.clock
                .advance(self.rt.cost.device_issue_ns() * members + gathered.max(0.0) / members.max(1.0) + self.reduce_compute_ns(bytes, spec.size));
        } else {
            // Hierarchical charge: node-local gather, leader-only wire
            // exchange (composed per-hop plans), duplicated compute, and
            // the result fan-out down the GPU-leader chain. The modeled
            // roles drive the byte table too: non-leaders account their
            // gather push, leaders the slice exchange + result broadcast.
            let me = self.pe();
            let leader = spec.node_leader(topo, me);
            let my_node = topo.node_of(me);
            let group = spec
                .members()
                .filter(|&p| topo.node_of(p) == my_node)
                .count();
            let gpus = spec.gpu_leaders_on_node(topo, my_node).len().max(1);
            let cost = &self.rt.cost;
            let gather_ns =
                cost.coll_intra_ns_at(&snap.params, bytes * group, group.saturating_sub(1), gpus);
            let bcast_ns = cost.coll_intra_bcast_ns_at(&snap.params, bytes, group, gpus);
            self.clock.advance(
                cost.device_issue_ns() * group as f64
                    + gather_ns
                    + self.reduce_compute_ns(bytes, spec.size)
                    + bcast_ns,
            );
            if me == leader {
                self.rt.metrics.add_coll_bytes(
                    CollOpIdx::Reduce,
                    CollStage::Inter,
                    (bytes * group * shape.nnodes().saturating_sub(1)) as u64,
                );
                self.rt.metrics.add_coll_bytes(
                    CollOpIdx::Reduce,
                    CollStage::Intra,
                    (bytes * group.saturating_sub(1)) as u64,
                );
                self.coll_exchange_wire_charge(
                    algo,
                    shape.nnodes(),
                    bytes * group,
                    bytes * spec.size,
                );
            } else {
                self.rt
                    .metrics
                    .add_coll_bytes(CollOpIdx::Reduce, CollStage::Intra, bytes as u64);
            }
        }

        // In-place reductions (dest == src, spec-legal) must not clobber a
        // source block a slower peer is still gathering: wait for everyone
        // to finish gathering before writing results.
        self.team_sync(team);
        self.rt
            .heaps
            .heap(self.pe())
            .write(dest.byte_offset(), as_bytes(&acc));
        self.team_sync(team);

        // Node leaders carry the wire schedule — they feed the cell.
        if !shape.single_node() && spec.node_leader(topo, self.pe()) == self.pe() {
            self.rt.xfer.coll_observe(
                CollOp::Reduce,
                bytes,
                spec.size,
                hier,
                self.clock.now_ns() - t0,
                snap.version,
            );
        }
    }

    /// Elementwise fold of `other` into `acc` — the compute lane.
    ///
    /// Full (64, 128) chunks go through the AOT Pallas reduce kernel via
    /// PJRT when a runtime is attached, the dtype is covered and the size
    /// clears the launch threshold; everything else folds natively.
    pub(crate) fn fold<T: ReduceElem>(&self, op: ReduceOp, acc: &mut [T], other: &[T]) {
        debug_assert_eq!(acc.len(), other.len());
        let rt = self.rt.runtime();
        let use_xla = rt.is_some()
            && T::TAG.kernel_dtype().is_some()
            && acc.len() >= self.rt.config.xla_reduce_min_elems;

        let mut start = 0usize;
        if use_xla {
            let xla = rt.as_ref().unwrap();
            let dtype = T::TAG.kernel_dtype().unwrap();
            // §Perf iterations 1–2 (EXPERIMENTS.md): wide (512×128) chunks
            // were tried for launch amortization and measured *slower* on
            // the CPU PJRT backend (intra-op task slicing overhead grows
            // with rows on a 1-core pool: 15.7 vs 9.0 ns/elem), so the
            // fold deliberately sticks to standard chunks. The wide
            // artifacts remain available (`reduce_fold_bytes_wide`) as the
            // recorded ablation and for multi-core backends.
            let chunk = xla.reduce_chunk_elems();
            while acc.len() - start >= chunk {
                let r = start..start + chunk;
                xla.reduce_fold_bytes(
                    op.kernel_name(),
                    dtype,
                    as_bytes_mut(&mut acc[r.clone()]),
                    as_bytes(&other[r]),
                )
                .expect("XLA reduce kernel");
                start += chunk;
                Metrics::add(&self.rt.metrics.xla_reduce_calls, 1);
                Metrics::add(&self.rt.metrics.xla_reduce_elems, chunk as u64);
            }
        }
        for i in start..acc.len() {
            acc[i] = T::combine(op, acc[i], other[i]);
        }
        if start < acc.len() {
            Metrics::add(
                &self.rt.metrics.native_reduce_elems,
                (acc.len() - start) as u64,
            );
        }
    }

    /// Modeled compute time of the duplicated reduction (vector ALU bound,
    /// roughly HBM-rate for one load + one op + one store per element).
    fn reduce_compute_ns(&self, bytes: usize, team_size: usize) -> f64 {
        let passes = team_size.saturating_sub(1) as f64;
        bytes as f64 * passes / (self.rt.cost.params.xe.hbm_bw_gbs / 2.0)
    }
}

/// Zero-init helper for gather buffers (all ShmemTypes are POD).
pub(crate) trait FromZeroed: Sized {
    fn from_zeroed() -> Self;
}

impl<T: super::ShmemType> FromZeroed for T {
    fn from_zeroed() -> T {
        // SAFETY: ShmemType contract — all-zero bytes are a valid value.
        unsafe { std::mem::zeroed() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_wait_returns_immediately_on_ready() {
        let r = bounded_wait(1, DegradedKind::SyncTimeout, 0, 1, 0, || Some(42));
        assert_eq!(r, Ok(42));
    }

    #[test]
    fn bounded_wait_zero_timeout_waits_indefinitely() {
        // timeout_ms = 0 is the pre-fault unbounded spin: a poll that
        // only succeeds after many rounds (well past the yield
        // escalation) still completes rather than erroring.
        let mut calls = 0u64;
        let r = bounded_wait(0, DegradedKind::DecisionTimeout, 3, 7, 2, || {
            calls += 1;
            (calls >= 500).then_some(calls)
        });
        assert_eq!(r, Ok(500));
    }

    #[test]
    fn bounded_wait_expires_with_structured_error() {
        let r: Result<(), DegradedError> =
            bounded_wait(1, DegradedKind::DecisionTimeout, 5, 9, 4, || None);
        let e = r.unwrap_err();
        assert_eq!(e.kind, DegradedKind::DecisionTimeout);
        assert_eq!(
            e.scope,
            crate::sim::DegradedScope::Collective { team: 5, epoch: 9 }
        );
        assert_eq!(e.pe, 4);
        assert!(e.waited_ms >= 1);
        assert!(e.to_string().contains("collective decision"));
    }
}
