//! Device-resident symmetric heap management (paper §III-E).
//!
//! The layout invariant of a PGAS symmetric heap: every PE performs the
//! same sequence of collective allocations, so an object lives at the same
//! offset in every PE's heap and a remote address is computed as
//! `local_offset + remote_heap_base` (the paper's `ishmem_long_p` recipe).
//!
//! The first `RESERVED_BYTES` of every heap belong to the runtime: team
//! sync counters for the "push" collectives (§III-G.2), signal words, and
//! the internal scratch slot. User allocations start above.

use std::marker::PhantomData;

use super::types::ShmemType;

/// Bytes reserved at the bottom of every heap for runtime structures.
pub const RESERVED_BYTES: usize = 64 * 1024;

/// Max teams (each gets one sync word + one op-sequence word per PE).
pub const MAX_TEAMS: usize = 256;

/// Offset of team `t`'s sync counter within the reserved region.
pub fn team_sync_offset(team: usize) -> usize {
    assert!(team < MAX_TEAMS);
    team * 16
}

/// Offset of team `t`'s broadcast/collect arrival counter.
pub fn team_arrive_offset(team: usize) -> usize {
    assert!(team < MAX_TEAMS);
    team * 16 + 8
}

/// A typed symmetric address: the same offset is valid on every PE.
///
/// This is the moral equivalent of the pointer returned by
/// `ishmem_malloc`; indexing yields element addresses, `slice` yields
/// sub-buffers. It is `Copy` and can be freely shared across PE closures.
pub struct SymAddr<T: ShmemType> {
    offset: usize,
    len: usize,
    _t: PhantomData<T>,
}

impl<T: ShmemType> Clone for SymAddr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T: ShmemType> Copy for SymAddr<T> {}

impl<T: ShmemType> std::fmt::Debug for SymAddr<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SymAddr<{}>({}+{})", std::any::type_name::<T>(), self.offset, self.len)
    }
}

impl<T: ShmemType> SymAddr<T> {
    pub(crate) fn new(offset: usize, len: usize) -> Self {
        SymAddr { offset, len, _t: PhantomData }
    }

    pub fn byte_offset(&self) -> usize {
        self.offset
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len * std::mem::size_of::<T>()
    }

    /// Address of element `i` (bounds-checked).
    pub fn at(&self, i: usize) -> SymAddr<T> {
        assert!(i < self.len, "index {i} out of {}", self.len);
        SymAddr::new(self.offset + i * std::mem::size_of::<T>(), self.len - i)
    }

    /// Sub-buffer `[start, start+len)`.
    pub fn slice(&self, start: usize, len: usize) -> SymAddr<T> {
        assert!(start + len <= self.len, "slice {start}+{len} out of {}", self.len);
        SymAddr::new(self.offset + start * std::mem::size_of::<T>(), len)
    }
}

/// Mirrored bump allocator: each PE runs an identical instance, so
/// identical collective allocation sequences produce identical offsets
/// (the symmetric-heap contract; divergence is detected by the debug
/// cross-check in `PeCtx::malloc`).
#[derive(Debug)]
pub struct SymAllocator {
    cursor: usize,
    limit: usize,
    allocs: usize,
}

impl SymAllocator {
    pub fn new(heap_bytes: usize) -> Self {
        SymAllocator { cursor: RESERVED_BYTES, limit: heap_bytes, allocs: 0 }
    }

    /// Allocate `len` elements of `T`, 128-byte aligned like the real
    /// device allocator.
    pub fn alloc<T: ShmemType>(&mut self, len: usize) -> SymAddr<T> {
        let bytes = len * std::mem::size_of::<T>();
        let start = crate::util::round_up(self.cursor, 128);
        let end = start + bytes;
        assert!(
            end <= self.limit,
            "symmetric heap exhausted: need {bytes} at {start}, heap {}",
            self.limit
        );
        self.cursor = end;
        self.allocs += 1;
        SymAddr::new(start, len)
    }

    /// Allocation count — used to cross-check symmetry across PEs.
    pub fn alloc_seq(&self) -> usize {
        self.allocs
    }

    pub fn used_bytes(&self) -> usize {
        self.cursor - RESERVED_BYTES
    }

    /// Reset all user allocations (between benchmark phases; mirrors
    /// tearing down and re-running an OpenSHMEM job).
    pub fn reset(&mut self) {
        self.cursor = RESERVED_BYTES;
        self.allocs = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn reserved_region_untouchable() {
        let mut a = SymAllocator::new(1 << 20);
        let addr = a.alloc::<u64>(10);
        assert!(addr.byte_offset() >= RESERVED_BYTES);
    }

    #[test]
    fn alignment_is_128() {
        let mut a = SymAllocator::new(1 << 20);
        for _ in 0..10 {
            let addr = a.alloc::<u8>(3);
            assert_eq!(addr.byte_offset() % 128, 0);
        }
    }

    #[test]
    fn mirrored_instances_agree() {
        prop_check("mirrored allocators yield identical offsets", 50, |rng| {
            let mut a = SymAllocator::new(1 << 20);
            let mut b = SymAllocator::new(1 << 20);
            for _ in 0..20 {
                let n = rng.range(1, 500) as usize;
                assert_eq!(
                    a.alloc::<f32>(n).byte_offset(),
                    b.alloc::<f32>(n).byte_offset()
                );
            }
        });
    }

    #[test]
    #[should_panic(expected = "exhausted")]
    fn exhaustion_panics() {
        let mut a = SymAllocator::new(RESERVED_BYTES + 1024);
        a.alloc::<u8>(4096);
    }

    #[test]
    fn symaddr_indexing() {
        let mut a = SymAllocator::new(1 << 20);
        let addr = a.alloc::<u64>(16);
        assert_eq!(addr.at(2).byte_offset(), addr.byte_offset() + 16);
        assert_eq!(addr.slice(4, 8).len(), 8);
        assert_eq!(addr.byte_len(), 128);
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn symaddr_oob_index() {
        let mut a = SymAllocator::new(1 << 20);
        a.alloc::<u32>(4).at(4);
    }

    #[test]
    fn team_slots_fit_reserved_region() {
        assert!(team_arrive_offset(MAX_TEAMS - 1) + 8 <= RESERVED_BYTES);
    }
}
