//! `ishmemx_*_work_group` — the paper's proposed device extension APIs
//! (§III-F): thread-collaborative variants where every work-item of a SYCL
//! work-group participates in one communication operation.
//!
//! * RMA: intra-node transfers become a multi-threaded vectorized memcpy
//!   (bandwidth scales with the work-group, Fig 4a); reverse-offloaded
//!   transfers elect the leader item to append a descriptor to the
//!   initiator's batched command stream ([`crate::xfer::stream`]) while
//!   the group barriers — the whole plan-group rides one `Batch`
//!   doorbell (engine bandwidth is work-group-invariant, Fig 4b).
//! * Collectives: fan-outs load-share the work-items across Xe-Links.
//! * AMOs have **no** work_group variants (scalar ops don't benefit —
//!   paper §III-F), and none are provided here.
//!
//! Every variant delegates to the scalar `*_items` implementation with the
//! group size as the cooperating work-item count, so the unified planner
//! ([`crate::xfer::plan::XferEngine`]) sees the work-group dimension of the
//! cutover (Fig 5: the crossover moves right as items grow).

use crate::device::WorkGroup;

use super::types::{ReduceElem, ReduceOp, ShmemType};
use super::{PeCtx, SymAddr, TeamId};

impl PeCtx {
    /// `ishmemx_put_work_group`.
    pub fn put_work_group<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        pe: usize,
        wg: &WorkGroup,
    ) {
        // Inter-node / engine paths: group barrier to validate the source
        // buffer, leader posts; modeled in put_items via the items count.
        self.charge_group_entry(wg, pe);
        self.put_items(dest, src, pe, wg.size());
    }

    /// `ishmemx_get_work_group`.
    pub fn get_work_group<T: ShmemType>(
        &self,
        dest: &mut [T],
        src: SymAddr<T>,
        pe: usize,
        wg: &WorkGroup,
    ) {
        self.charge_group_entry(wg, pe);
        self.get_items(dest, src, pe, wg.size());
    }

    /// `ishmemx_put_nbi_work_group`.
    pub fn put_nbi_work_group<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        pe: usize,
        wg: &WorkGroup,
    ) {
        self.charge_group_entry(wg, pe);
        self.put_nbi_items(dest, src, pe, wg.size());
    }

    /// `ishmemx_get_nbi_work_group`.
    pub fn get_nbi_work_group<T: ShmemType>(
        &self,
        dest: &mut [T],
        src: SymAddr<T>,
        pe: usize,
        wg: &WorkGroup,
    ) {
        self.charge_group_entry(wg, pe);
        self.get_nbi_items(dest, src, pe, wg.size());
    }

    /// `ishmemx_broadcast_work_group`. Collective work-group variants
    /// delegate to the shared `*_items` bodies, so the hierarchical
    /// algorithm selection (and the published team-wide decision) applies
    /// to device work-group launches exactly as to single-thread calls —
    /// `wg.size()` feeds the cooperating-item count the planner prices.
    pub fn broadcast_work_group<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        root: usize,
        team: TeamId,
        wg: &WorkGroup,
    ) {
        self.broadcast_items(dest, src, nelems, root, team, wg.size());
    }

    /// `ishmemx_fcollect_work_group`.
    pub fn fcollect_work_group<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        team: TeamId,
        wg: &WorkGroup,
    ) {
        self.fcollect_items(dest, src, nelems, team, wg.size());
    }

    /// `ishmemx_alltoall_work_group`.
    pub fn alltoall_work_group<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        team: TeamId,
        wg: &WorkGroup,
    ) {
        self.alltoall_items(dest, src, nelems, team, wg.size());
    }

    /// `ishmemx_collect_work_group`.
    pub fn collect_work_group<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        my_nelems: usize,
        team: TeamId,
        wg: &WorkGroup,
    ) {
        self.collect_items(dest, src, my_nelems, team, wg.size());
    }

    /// `ishmemx_reduce_work_group`.
    pub fn reduce_work_group<T: ReduceElem>(
        &self,
        dest: SymAddr<T>,
        src: SymAddr<T>,
        nelems: usize,
        op: ReduceOp,
        team: TeamId,
        wg: &WorkGroup,
    ) {
        self.reduce_items(dest, src, nelems, op, team, wg.size());
    }

    /// `ishmemx_barrier_all_work_group` — the group barriers, the leader
    /// runs the barrier, the group re-converges.
    pub fn barrier_all_work_group(&self, wg: &WorkGroup) {
        self.clock.advance(self.rt.cost.group_barrier_ns());
        self.barrier_all();
        self.clock.advance(self.rt.cost.group_barrier_ns());
        let _ = wg.leader();
    }

    /// `ishmemx_sync_all_work_group`.
    pub fn sync_all_work_group(&self, wg: &WorkGroup) {
        self.clock.advance(self.rt.cost.group_barrier_ns());
        self.sync_all();
        self.clock.advance(self.rt.cost.group_barrier_ns());
        let _ = wg.leader();
    }

    /// `ishmemx_team_sync_work_group`.
    pub fn team_sync_work_group(&self, team: TeamId, wg: &WorkGroup) {
        self.clock.advance(self.rt.cost.group_barrier_ns());
        self.team_sync(team);
        self.clock.advance(self.rt.cost.group_barrier_ns());
        let _ = wg.leader();
    }

    /// Group-entry cost: inter-node (or any proxied) group ops barrier the
    /// group so the leader sees a valid source buffer (paper §III-G.1).
    fn charge_group_entry(&self, wg: &WorkGroup, pe: usize) {
        if wg.size() > 1 && self.ipc.lookup(pe).is_none() {
            self.clock.advance(self.rt.cost.group_barrier_ns());
        }
    }
}
