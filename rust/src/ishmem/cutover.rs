//! Cutover policy: load/store vs copy-engine path selection (paper §III-B,
//! §IV).
//!
//! "We have implemented cutover logic to switch from the use of organic
//! load-store for smaller operations, to, for larger operations, making an
//! up-call to the host in order to start the copy engines. Cutover tuning
//! is dependent on the data size and on the number of active GPU
//! work-items." — and, for collectives, on the number of PEs (Fig 6).
//!
//! Four modes: `Never` (= ishmem_cutover_never.patch, store path only),
//! `Always` (= ishmem_cutover_always.patch, engine path only), `Tuned`
//! (= ishmem_cutover_current.patch, the shipping model-argmin policy —
//! evaluates the same first-order cost terms the paper tuned against, so
//! the crossover moves with work-group size and PE count as in Fig 5–7),
//! and `Adaptive`, which seeds from `Tuned` and then learns
//! per-(locality, size-bucket, work-items-bucket) thresholds online from
//! observed costs (see [`crate::xfer::adaptive`]).
//!
//! This module holds the *policy type* only; every actual path decision is
//! made by the single planner in [`crate::xfer::plan::XferEngine`].

use crate::sim::cost::CostModel;
use crate::sim::topology::Locality;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CutoverMode {
    /// Always use direct load/store (never start the copy engines).
    Never,
    /// Always reverse-offload to the copy engines.
    Always,
    /// Model-estimated best path (the shipping policy).
    Tuned,
    /// Online-adaptive: seeded by `Tuned`, refined by EMAs of observed
    /// costs per (locality, size, work-items) bucket.
    Adaptive,
}

/// Which data path a device-initiated transfer takes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Path {
    /// Organic load/store by the calling work-item(s).
    LoadStore,
    /// Reverse offload → host proxy → copy engine.
    CopyEngine,
}

#[derive(Clone, Debug)]
pub struct CutoverConfig {
    pub mode: CutoverMode,
    /// Optional hard threshold override (bytes): below ⇒ LoadStore,
    /// at/above ⇒ CopyEngine. Mirrors ishmem's env-var tuning knob.
    pub fixed_threshold: Option<usize>,
    /// EMA weight of one observation in `Adaptive` mode (0 < α ≤ 1).
    pub ema_alpha: f64,
    /// ε-exploration rate in `Adaptive` mode: with this probability a
    /// decision takes the losing path, keeping both EMAs fresh so a
    /// mis-seeded bucket can recover (0 = greedy, the default — benches
    /// that want recovery opt in via [`Self::with_exploration`]).
    pub explore_eps: f64,
    /// `Adaptive` table persistence (`cutover.table_path`): when set, the
    /// machine loads previously-learned cells from this JSON file at
    /// construction (if it exists) and saves the refined table back at
    /// shutdown, so learned crossovers survive across runs.
    pub table_path: Option<String>,
}

impl Default for CutoverConfig {
    fn default() -> Self {
        CutoverConfig {
            mode: CutoverMode::Tuned,
            fixed_threshold: None,
            ema_alpha: 0.25,
            explore_eps: 0.0,
            table_path: None,
        }
    }
}

impl CutoverConfig {
    pub fn mode(mode: CutoverMode) -> Self {
        CutoverConfig { mode, ..Default::default() }
    }

    /// Store path only (the artifact's `cutover_never` patch).
    pub fn never() -> Self {
        Self::mode(CutoverMode::Never)
    }

    /// Engine path only (the artifact's `cutover_always` patch).
    pub fn always() -> Self {
        Self::mode(CutoverMode::Always)
    }

    /// The shipping model-argmin policy.
    pub fn tuned() -> Self {
        Self::mode(CutoverMode::Tuned)
    }

    /// Online-adaptive thresholds (seeded by `Tuned`).
    pub fn adaptive() -> Self {
        Self::mode(CutoverMode::Adaptive)
    }

    /// Hard byte-threshold override on top of the current mode.
    pub fn with_threshold(mut self, bytes: usize) -> Self {
        self.fixed_threshold = Some(bytes);
        self
    }

    /// ε-exploration on top of `Adaptive` (clamped to [0, 0.5] by the
    /// learned table).
    pub fn with_exploration(mut self, eps: f64) -> Self {
        self.explore_eps = eps;
        self
    }

    /// Persist/load the `Adaptive` learned table at this JSON path.
    pub fn with_table_path(mut self, path: impl Into<String>) -> Self {
        self.table_path = Some(path.into());
        self
    }

    /// Decide the path for a device-initiated transfer of `bytes` to a
    /// `loc`-distant PE, issued by `items` cooperating work-items.
    ///
    /// This is the *model-only, immediate-CL reference* decision used by
    /// policy-level tests: `Adaptive` answers like `Tuned` here (its
    /// seed), and the engine startup constant is the immediate-CL one.
    /// The live decision — including the learned table and the configured
    /// command-list flavour — is made by the planner
    /// ([`crate::xfer::plan::XferEngine`]).
    pub fn decide(&self, cost: &CostModel, loc: Locality, bytes: usize, items: usize) -> Path {
        match self.mode {
            CutoverMode::Never => Path::LoadStore,
            CutoverMode::Always => Path::CopyEngine,
            CutoverMode::Tuned | CutoverMode::Adaptive => {
                if let Some(t) = self.fixed_threshold {
                    return if bytes < t { Path::LoadStore } else { Path::CopyEngine };
                }
                // Model both paths the way §IV describes the tuning: the
                // store path scales with work-items; the engine path pays
                // ring RTT + startup but runs at full link speed.
                let ls = cost.loadstore_ns(loc, bytes, items);
                let ce = cost.p2p_engine_estimate_ns(loc, bytes, true);
                if ls <= ce {
                    Path::LoadStore
                } else {
                    Path::CopyEngine
                }
            }
        }
    }

    /// The crossover size (bytes) for a given locality/work-group — used
    /// by reports and tests; scans power-of-two sizes.
    pub fn crossover_bytes(&self, cost: &CostModel, loc: Locality, items: usize) -> Option<usize> {
        (3..28).map(|p| 1usize << p).find(|&b| {
            self.decide(cost, loc, b, items) == Path::CopyEngine
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::CostParams;
    use crate::sim::Topology;
    use std::sync::Arc;

    fn cost() -> Arc<CostModel> {
        CostModel::new(Topology::default(), CostParams::default())
    }

    #[test]
    fn never_and_always_are_absolute() {
        let c = cost();
        let never = CutoverConfig::never();
        let always = CutoverConfig::always();
        for bytes in [8usize, 1 << 12, 1 << 24] {
            assert_eq!(never.decide(&c, Locality::SameNode, bytes, 1), Path::LoadStore);
            assert_eq!(always.decide(&c, Locality::SameNode, bytes, 1), Path::CopyEngine);
        }
    }

    #[test]
    fn tuned_small_is_loadstore_large_is_engine() {
        let c = cost();
        let tuned = CutoverConfig::default();
        assert_eq!(tuned.decide(&c, Locality::SameNode, 64, 1), Path::LoadStore);
        assert_eq!(
            tuned.decide(&c, Locality::SameNode, 16 << 20, 1),
            Path::CopyEngine
        );
    }

    #[test]
    fn adaptive_seed_equals_tuned_model() {
        let c = cost();
        let tuned = CutoverConfig::tuned();
        let adaptive = CutoverConfig::adaptive();
        for p in 3..26 {
            for items in [1usize, 64, 1024] {
                assert_eq!(
                    tuned.decide(&c, Locality::SameNode, 1 << p, items),
                    adaptive.decide(&c, Locality::SameNode, 1 << p, items),
                );
            }
        }
    }

    #[test]
    fn crossover_moves_right_with_work_items() {
        // Fig 4a/5: more work-items keep the store path competitive longer,
        // so the cutover point grows with the work-group size.
        let c = cost();
        let tuned = CutoverConfig::default();
        let x1 = tuned.crossover_bytes(&c, Locality::SameNode, 1).unwrap();
        let x128 = tuned.crossover_bytes(&c, Locality::SameNode, 128).unwrap();
        assert!(x1 < x128, "{x1} !< {x128}");
    }

    #[test]
    fn fixed_threshold_override() {
        let c = cost();
        let cfg = CutoverConfig::tuned().with_threshold(4096);
        assert_eq!(cfg.decide(&c, Locality::SameNode, 4095, 1), Path::LoadStore);
        assert_eq!(cfg.decide(&c, Locality::SameNode, 4096, 1), Path::CopyEngine);
    }

    #[test]
    fn single_thread_crossover_in_paper_regime() {
        // Fig 3: "For small to medium message sizes of up to 4 KB, Intel
        // SHMEM outperforms ... Beyond 4 KB message size, the copy engine
        // based transfer performs better" (for the tuned single-thread op).
        let c = cost();
        let x = CutoverConfig::default()
            .crossover_bytes(&c, Locality::SameNode, 1)
            .unwrap();
        assert!((1 << 11..=1 << 15).contains(&x), "crossover {x} outside 2KB..32KB");
    }
}
