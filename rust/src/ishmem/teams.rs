//! Teams: subsets of PEs for collective scoping (OpenSHMEM §9.4; the
//! paper's collectives are "aligned with the OpenSHMEM 1.5 teams API").
//!
//! `TeamId::WORLD` is every PE; `TeamId::SHARED` is the caller's
//! load/store domain (the node — ISHMEM_TEAM_SHARED, paper §III-G.2);
//! user teams come from `team_split_strided`. Creation is collective and
//! mirrored: every member computes the same key and the first arrival
//! registers the spec, so ids agree without a global barrier.

use super::{PeCtx, SymAddr};

/// A team handle (plain id, freely copyable across PE closures).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TeamId(pub(crate) usize);

impl TeamId {
    /// All PEs (`ISHMEM_TEAM_WORLD`).
    pub const WORLD: TeamId = TeamId(0);
    /// The caller's shared-memory domain (`ISHMEM_TEAM_SHARED`).
    pub const SHARED: TeamId = TeamId(1);

    pub(crate) fn index(self) -> usize {
        self.0
    }
}

/// Strided team specification over world ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TeamSpec {
    pub start: usize,
    pub stride: usize,
    pub size: usize,
}

impl TeamSpec {
    pub fn members(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.size).map(move |i| self.start + i * self.stride)
    }

    pub fn contains(&self, pe: usize) -> bool {
        pe >= self.start
            && (pe - self.start) % self.stride == 0
            && (pe - self.start) / self.stride < self.size
    }

    /// Team rank of world-PE `pe`.
    pub fn rank_of(&self, pe: usize) -> Option<usize> {
        self.contains(pe).then(|| (pe - self.start) / self.stride)
    }

    // ------------------------------- hierarchical-collective leaders --
    //
    // Members ascend in world rank and `node_of`/`global_gpu_of` are
    // monotone over a node's PEs, so every node (and GPU) group covers a
    // *contiguous* team-rank range — hierarchical fcollect exchanges
    // whole node slices on the wire because of this invariant.

    /// Members grouped by node, in member order: `(node, members)` for
    /// every node holding at least one member.
    pub fn node_groups(&self, topo: &crate::sim::Topology) -> Vec<(usize, Vec<usize>)> {
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for pe in self.members() {
            let node = topo.node_of(pe);
            match groups.last_mut() {
                Some((n, g)) if *n == node => g.push(pe),
                _ => groups.push((node, vec![pe])),
            }
        }
        groups
    }

    /// Node leader of `pe`'s node within this team: the lowest member on
    /// that node. Leaders are the only ranks on the wire in hierarchical
    /// collectives. Panics if the node holds no member (callers pass a
    /// member's own node).
    pub fn node_leader(&self, topo: &crate::sim::Topology, pe: usize) -> usize {
        let node = topo.node_of(pe);
        self.members()
            .find(|&m| topo.node_of(m) == node)
            .unwrap_or_else(|| panic!("no team member on node {node}"))
    }

    /// GPU leader of `pe`'s GPU within this team: the lowest member on
    /// the same global GPU (stages tile-level redistribution over MDFI).
    pub fn gpu_leader(&self, topo: &crate::sim::Topology, pe: usize) -> usize {
        let gpu = topo.global_gpu_of(pe);
        self.members()
            .find(|&m| topo.global_gpu_of(m) == gpu)
            .unwrap_or_else(|| panic!("no team member on gpu {gpu}"))
    }

    /// GPU leaders of `node`'s member group, in member order — one per
    /// global GPU holding members (monotone GPU ids within a node make
    /// the single-pass dedup exact).
    pub fn gpu_leaders_on_node(&self, topo: &crate::sim::Topology, node: usize) -> Vec<usize> {
        let mut leaders: Vec<usize> = Vec::new();
        let mut last_gpu = usize::MAX;
        for m in self.members().filter(|&m| topo.node_of(m) == node) {
            let gpu = topo.global_gpu_of(m);
            if gpu != last_gpu {
                leaders.push(m);
                last_gpu = gpu;
            }
        }
        leaders
    }
}

/// Key identifying one collective team-creation call site (mirrored
/// sequence number per parent keeps repeated identical splits distinct).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TeamKey {
    pub parent: usize,
    pub spec: TeamSpec,
    pub seq: usize,
}

impl PeCtx {
    /// Resolve a team id into its world-rank spec (SHARED depends on the
    /// calling PE's node).
    pub(crate) fn team_spec(&self, team: TeamId) -> TeamSpec {
        match team {
            TeamId::WORLD => TeamSpec { start: 0, stride: 1, size: self.npes() },
            TeamId::SHARED => {
                let peers = self.topo().node_peers(self.pe());
                TeamSpec { start: peers.start, stride: 1, size: peers.len() }
            }
            TeamId(id) => {
                let teams = self.rt.teams.read().unwrap();
                *teams
                    .get(id - 2)
                    .unwrap_or_else(|| panic!("unknown team id {id}"))
            }
        }
    }

    /// `ishmem_team_my_pe` — my rank within `team` (panics if not a member,
    /// mirroring the spec's undefined behaviour as a loud failure).
    pub fn team_my_pe(&self, team: TeamId) -> usize {
        self.team_spec(team)
            .rank_of(self.pe())
            .unwrap_or_else(|| panic!("PE {} is not in team {team:?}", self.pe()))
    }

    /// `ishmem_team_n_pes`.
    pub fn team_n_pes(&self, team: TeamId) -> usize {
        self.team_spec(team).size
    }

    /// `ishmem_team_translate_pe` — translate my `src_pe` rank in
    /// `src_team` to the rank in `dst_team` (None if not a member).
    pub fn team_translate_pe(
        &self,
        src_team: TeamId,
        src_pe: usize,
        dst_team: TeamId,
    ) -> Option<usize> {
        let src = self.team_spec(src_team);
        if src_pe >= src.size {
            return None;
        }
        let world = src.start + src_pe * src.stride;
        self.team_spec(dst_team).rank_of(world)
    }

    /// `ishmem_team_split_strided` — collective among the parent team's
    /// members; every member passes identical (start, stride, size) in
    /// *parent ranks*. Returns the new team (same id on every member).
    pub fn team_split_strided(
        &self,
        parent: TeamId,
        start: usize,
        stride: usize,
        size: usize,
    ) -> TeamId {
        assert!(stride >= 1 && size >= 1);
        let pspec = self.team_spec(parent);
        assert!(
            start + (size - 1) * stride < pspec.size,
            "split exceeds parent team"
        );
        // Translate parent-rank stride into world-rank stride.
        let spec = TeamSpec {
            start: pspec.start + start * pspec.stride,
            stride: stride * pspec.stride,
            size,
        };
        // Mirrored per-parent sequence number.
        let seq = {
            let mut seqs = self.team_seq.borrow_mut();
            let c = seqs.entry(parent.index()).or_insert(0);
            let s = *c;
            *c += 1;
            s
        };
        let key = TeamKey { parent: parent.index(), spec, seq };

        let mut index = self.rt.team_index.lock().unwrap();
        if let Some(&id) = index.get(&key) {
            return TeamId(id);
        }
        let mut teams = self.rt.teams.write().unwrap();
        let id = teams.len() + 2;
        assert!(id < super::heap::MAX_TEAMS, "team limit exceeded");
        teams.push(spec);
        index.insert(key, id);
        TeamId(id)
    }

    /// Members of `team` as world PEs (allocation-light helper).
    pub fn team_members(&self, team: TeamId) -> Vec<usize> {
        self.team_spec(team).members().collect()
    }

    /// Symmetric address of my block within a team-indexed buffer
    /// (`dest` is `nelems * team_size` long; block `rank` is mine).
    pub fn team_block<T: super::ShmemType>(
        &self,
        team: TeamId,
        dest: SymAddr<T>,
        nelems: usize,
    ) -> SymAddr<T> {
        let rank = self.team_my_pe(team);
        dest.slice(rank * nelems, nelems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_membership() {
        let s = TeamSpec { start: 2, stride: 3, size: 4 }; // {2,5,8,11}
        assert!(s.contains(2) && s.contains(11));
        assert!(!s.contains(3) && !s.contains(14));
        assert_eq!(s.rank_of(8), Some(2));
        assert_eq!(s.members().collect::<Vec<_>>(), vec![2, 5, 8, 11]);
    }

    #[test]
    fn leaders_and_node_groups() {
        use crate::sim::Topology;
        // 2 nodes × 2 GPUs × 2 tiles = 8 PEs; odd PEs: {1,3,5,7}.
        let topo = Topology::new(2, 2, 2);
        let s = TeamSpec { start: 1, stride: 2, size: 4 };
        let groups = s.node_groups(&topo);
        assert_eq!(groups, vec![(0, vec![1, 3]), (1, vec![5, 7])]);
        // Node-group team ranks are contiguous (the slice invariant).
        assert_eq!(s.rank_of(5), Some(2));
        assert_eq!(s.rank_of(7), Some(3));
        // Node leader = lowest member on the node.
        assert_eq!(s.node_leader(&topo, 3), 1);
        assert_eq!(s.node_leader(&topo, 7), 5);
        // PEs 1 (gpu 0) and 3 (gpu 1) lead their own GPUs.
        assert_eq!(s.gpu_leader(&topo, 1), 1);
        assert_eq!(s.gpu_leader(&topo, 3), 3);
        assert_eq!(s.gpu_leaders_on_node(&topo, 0), vec![1, 3]);
        // A full-node team: tile peers share their GPU leader.
        let w = TeamSpec { start: 0, stride: 1, size: 8 };
        assert_eq!(w.gpu_leader(&topo, 1), 0);
        assert_eq!(w.gpu_leaders_on_node(&topo, 1), vec![4, 6]);
        assert_eq!(w.node_groups(&topo).len(), 2);
    }
}
