//! Point-to-point synchronization: `ishmem_wait_until` / `ishmem_test`
//! (OpenSHMEM §9.10; paper Table of device APIs).
//!
//! Waits spin on the *local* heap word with an atomic compare — the paper
//! notes this uses the GPU caches effectively (the remote side's pipelined
//! atomic stores invalidate the line on arrival).

use std::sync::atomic::Ordering;

use super::types::{AmoElem, TypeTag};
use super::{PeCtx, SymAddr};

/// Comparison operators for wait/test (SHMEM_CMP_*).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Eq,
    Ne,
    Gt,
    Ge,
    Lt,
    Le,
}

impl Cmp {
    fn eval_bits(self, tag: TypeTag, lhs: u64, rhs: u64) -> bool {
        // Compare in the value domain, not the bit domain (signed/float!).
        match tag {
            TypeTag::F32 => self.eval(f32::from_bits(lhs as u32), f32::from_bits(rhs as u32)),
            TypeTag::F64 => self.eval(f64::from_bits(lhs), f64::from_bits(rhs)),
            TypeTag::I32 => self.eval(lhs as u32 as i32, rhs as u32 as i32),
            TypeTag::I64 => self.eval(lhs as i64, rhs as i64),
            _ => self.eval(lhs, rhs),
        }
    }

    fn eval<T: PartialOrd>(self, a: T, b: T) -> bool {
        match self {
            Cmp::Eq => a == b,
            Cmp::Ne => a != b,
            Cmp::Gt => a > b,
            Cmp::Ge => a >= b,
            Cmp::Lt => a < b,
            Cmp::Le => a <= b,
        }
    }
}

impl PeCtx {
    #[inline]
    fn load_bits<T: AmoElem>(&self, addr: SymAddr<T>) -> u64 {
        let heap = self.rt.heaps.heap(self.pe());
        match std::mem::size_of::<T>() {
            4 => heap.atomic_u32(addr.byte_offset()).load(Ordering::Acquire) as u64,
            8 => heap.atomic_u64(addr.byte_offset()).load(Ordering::Acquire),
            _ => unreachable!("AmoElem is 4 or 8 bytes"),
        }
    }

    /// `ishmem_wait_until(ivar, cmp, value)` — block until the local
    /// symmetric variable satisfies the comparison.
    pub fn wait_until<T: AmoElem>(&self, addr: SymAddr<T>, cmp: Cmp, value: T) {
        let rhs = value.to_bits();
        let mut spins = 0u64;
        while !cmp.eval_bits(T::TAG, self.load_bits(addr), rhs) {
            spins += 1;
            if spins > 64 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        // Modeled cost: local cached poll loop — charge one cache-resident
        // compare-exchange-ish latency, not wall spins.
        self.clock
            .advance(self.rt.cost.params.xe.atomic_fetch_ns * 0.2);
    }

    /// `ishmem_test` — non-blocking probe of the condition.
    pub fn test<T: AmoElem>(&self, addr: SymAddr<T>, cmp: Cmp, value: T) -> bool {
        let r = cmp.eval_bits(T::TAG, self.load_bits(addr), value.to_bits());
        self.clock
            .advance(self.rt.cost.params.xe.atomic_fetch_ns * 0.2);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cmp_value_domain() {
        assert!(Cmp::Gt.eval_bits(TypeTag::I64, (-1i64) as u64, (-2i64) as u64));
        // Same bits compared unsigned: u64::MAX is huge, not negative.
        assert!(Cmp::Gt.eval_bits(TypeTag::U64, (-1i64) as u64, 5));
        assert!(Cmp::Lt.eval_bits(TypeTag::I64, (-1i64) as u64, 5));
        assert!(Cmp::Lt.eval_bits(
            TypeTag::F32,
            (-0.5f32).to_bits() as u64,
            0.25f32.to_bits() as u64
        ));
        assert!(Cmp::Ne.eval_bits(TypeTag::I32, 1, 2));
        assert!(Cmp::Le.eval_bits(TypeTag::U32, 3, 3));
    }
}
