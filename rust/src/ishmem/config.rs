//! Runtime configuration (env-tunable in real ishmem; struct-tunable here).

use crate::sim::cost::CostParams;
use crate::sim::Topology;

use super::cutover::CutoverConfig;

/// Collective algorithm policy (`coll.algo`): `Auto` selects flat vs
/// hierarchical per call through the cost model + adaptive table, the
/// fixed variants force one shape (ablations / determinism).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollAlgoMode {
    Auto,
    Flat,
    HierRing,
    HierTree,
}

/// Collective knobs (`coll.*`): how broadcast/fcollect/reduce decompose
/// into tile/GPU/node stages and how the inter-node algorithm is picked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CollConfig {
    pub algo: CollAlgoMode,
    /// Fan-out degree `k` of the inter-node tree stage
    /// (`coll.leader_fanout`): each node leader forwards to up to `k`
    /// children per level. Ignored by the ring variant.
    pub leader_fanout: usize,
    /// Deadline for the per-(team, epoch) decision registry wait
    /// (`coll.decision_timeout_ms`): a non-leader that never sees the
    /// leader's published algorithm within this many milliseconds gets a
    /// structured `DegradedError` instead of spinning forever. 0 (the
    /// default) preserves the pre-fault unbounded wait.
    pub decision_timeout_ms: u64,
    /// Deadline for a team sync round (`coll.sync_timeout_ms`): same
    /// contract as `decision_timeout_ms` — a peer that never arrives
    /// turns the spin into a `DegradedError`. 0 = wait forever.
    pub sync_timeout_ms: u64,
}

impl Default for CollConfig {
    fn default() -> Self {
        CollConfig {
            algo: CollAlgoMode::Auto,
            leader_fanout: 4,
            decision_timeout_ms: 0,
            sync_timeout_ms: 0,
        }
    }
}

/// Transfer-reliability knobs (`retry.*`): checksummed chunk replay with
/// bounded exponential backoff. Off by default — a `retry.enable = false`
/// machine stamps no checksums, never NACKs, and replays nothing, so the
/// whole data path is bit-for-bit identical to the pre-reliability code
/// (property-tested in `tests/prop_invariants.rs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryConfig {
    /// Master switch for checksums + NACK replay.
    pub enable: bool,
    /// Replay budget per batch: a NACKed batch is re-posted at most this
    /// many times before the op surfaces `DegradedError::RetryExhausted`.
    /// Bounded by the descriptor's 4-bit attempt field (≤ 15).
    pub max_attempts: u32,
    /// Modeled backoff charged to the initiator clock before replay
    /// attempt `n`: `backoff_base_ns × backoff_mult^(n-1)`.
    pub backoff_base_ns: u64,
    /// Exponential backoff multiplier (≥ 1.0; 1.0 = constant backoff).
    pub backoff_mult: f64,
    /// Consecutive transient faults on one lane before it escalates into
    /// the PR 8 quarantine machinery (rails via the detector's probation
    /// bookkeeping, engines as a direct kill). 0 = never escalate.
    pub escalate_strikes: u32,
}

impl Default for RetryConfig {
    fn default() -> Self {
        RetryConfig {
            enable: false,
            max_attempts: 4,
            backoff_base_ns: 50_000,
            backoff_mult: 2.0,
            escalate_strikes: 8,
        }
    }
}

/// Triggered-chain knobs (`chain.*`): stream-ordered dependent-operation
/// chains fused into one doorbell (ISSUE 10). Off by default — a
/// `chain.enable = false` machine never stamps stage fields, never emits
/// `WaitSignal` gates, and keeps put-signal's forced flush, so the whole
/// data path is bit-for-bit identical to the pre-chain code
/// (property-tested in `tests/prop_invariants.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChainConfig {
    /// Master switch for fused triggered chains.
    pub enable: bool,
    /// Deepest dependency chain (stage count) one doorbell may carry.
    /// Chains past this depth — or whose entry count exceeds
    /// `max_batch_depth` — fall back to sequential submission and count
    /// `chain_flushed_unfusable`.
    pub max_depth: usize,
}

impl Default for ChainConfig {
    fn default() -> Self {
        ChainConfig { enable: false, max_depth: 4 }
    }
}

/// P2p transfer knobs (`xfer.*`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct XferConfig {
    /// Deadline for every p2p completion wait (`xfer.op_timeout_ms`):
    /// blocking put/get, NBI quiet/fence drains, and slab-reclaim waits
    /// poll at most this many milliseconds before surfacing a structured
    /// `DegradedError::OpTimeout`. 0 (the default) preserves the
    /// unbounded spin bit-for-bit.
    pub op_timeout_ms: u64,
}

impl Default for XferConfig {
    fn default() -> Self {
        XferConfig { op_timeout_ms: 0 }
    }
}

#[derive(Clone, Debug)]
pub struct IshmemConfig {
    pub topology: Topology,
    /// Per-PE device symmetric heap size, bytes.
    pub heap_bytes: usize,
    /// Host symmetric heap (SOS side), bytes.
    pub host_heap_bytes: usize,
    pub cutover: CutoverConfig,
    pub cost: CostParams,
    /// Reverse-offload ring capacity (messages, power of two).
    pub ring_capacity: usize,
    /// Completion pool per node.
    pub completion_slots: usize,
    /// Use immediate command lists in the proxy (paper §III-C low-latency).
    /// Acts as the enable bit for the per-op CL policy below; false forces
    /// standard lists everywhere (the ablation knob).
    pub use_immediate_cl: bool,
    /// Per-op command-list policy boundary (§III-C): batched descriptors
    /// at or below this size run on immediate command lists, larger ones
    /// on standard lists (append → close → execute).
    pub cl_immediate_max_bytes: usize,
    /// Staging slab carved from the top of each PE's device heap: holds
    /// batched payloads (raw-pointer transfers become heap-offset
    /// transfers) and batch descriptor blocks. Oversized payloads chunk
    /// *through* the slab (striped chunk pipeline; see
    /// `cost.ce.stripe_max_engines` / `cost.ce.chunk_min_bytes` for the
    /// striping knobs) — the raw-pointer fallback engages only when a
    /// single chunk cannot fit an empty slab.
    pub staging_slab_bytes: usize,
    /// Maximum descriptors per batched ring message (one `Batch` doorbell
    /// per plan-group); 1 reproduces per-op submission.
    pub max_batch_depth: usize,
    /// Size-adaptive batch depth (`stream.large_flush_bytes`): a batched
    /// descriptor whose payload is at or above this size flushes its
    /// plan-group immediately, so a big chunk never waits behind a
    /// filling batch of tiny entries. Tiny descriptors still batch up to
    /// `max_batch_depth` deep; `usize::MAX` disables the auto-flush. The
    /// default (1 MiB) sits *above* the default slab's chunk cap
    /// (`chunk_max_bytes()`), so striped chunk pipelines keep batching
    /// exactly as before — only genuinely large single descriptors (e.g.
    /// collectives' un-staged multi-MiB blocks) ship at once.
    pub large_flush_bytes: usize,
    /// Strict FI_HMEM: inter-node traffic to unregistered heaps errors out
    /// instead of bouncing (failure injection).
    pub strict_hmem: bool,
    /// Elements below this never go through the XLA reduce kernel (kernel
    /// launch dominates); above, the AOT Pallas kernel path is used when
    /// the dtype is covered and a runtime is attached.
    pub xla_reduce_min_elems: usize,
    /// Closed-loop cost-model calibration (`calib.enable`,
    /// `calib.ema_alpha`, `calib.min_samples`, `calib.clamp_frac`): the
    /// proxy's wall-time observations refine the learnable hardware
    /// constants in the shared `ModelParams` store. Off by default — a
    /// `calib.enable = false` machine reproduces today's estimates
    /// bit-for-bit.
    pub calib: crate::xfer::calibrate::CalibConfig,
    /// Planner memoization (`plan_cache.enable`, `plan_cache.capacity`):
    /// structural plan shapes (width scans + pure estimates) cached per
    /// learned-params generation. Occupancy terms and route decisions are
    /// re-applied live on every hit, so a `plan_cache.enable = false`
    /// machine plans bit-for-bit identically — just slower.
    pub plan_cache: crate::xfer::plan::PlanCacheConfig,
    /// Hierarchical-collective knobs (`coll.algo`, `coll.leader_fanout`):
    /// single-node teams always take the flat path regardless.
    pub coll: CollConfig,
    /// Fault injection & degraded mode (`fault.enable`,
    /// `fault.detect_frac`, `fault.detect_min_samples`,
    /// `fault.probe_after`, `fault.events`): scripted rail/engine kills,
    /// the calibrator-as-detector thresholds, and revival probing. Off by
    /// default — a `fault.enable = false` machine plans bit-for-bit like
    /// the pre-fault code.
    pub fault: crate::sim::FaultConfig,
    /// Transfer reliability (`retry.*`): payload checksums, NACK replay
    /// with bounded exponential backoff, strike escalation into
    /// quarantine. Off by default (bit-for-bit pre-reliability).
    pub retry: RetryConfig,
    /// P2p deadlines (`xfer.op_timeout_ms`). 0 = unbounded waits.
    pub xfer: XferConfig,
    /// Triggered chains (`chain.enable`, `chain.max_depth`): dependent
    /// put→signal→op sequences fused into one doorbell with proxy-side
    /// stage gating. Off by default (bit-for-bit pre-chain).
    pub chain: ChainConfig,
}

impl Default for IshmemConfig {
    fn default() -> Self {
        IshmemConfig {
            topology: Topology::default(),
            heap_bytes: 8 << 20,
            host_heap_bytes: 1 << 20,
            cutover: CutoverConfig::default(),
            cost: CostParams::default(),
            ring_capacity: 4096,
            completion_slots: 1024,
            use_immediate_cl: true,
            cl_immediate_max_bytes: 64 << 10,
            staging_slab_bytes: 2 << 20,
            max_batch_depth: 16,
            large_flush_bytes: 1 << 20,
            strict_hmem: false,
            xla_reduce_min_elems: 1024,
            calib: crate::xfer::calibrate::CalibConfig::default(),
            plan_cache: crate::xfer::plan::PlanCacheConfig::default(),
            coll: CollConfig::default(),
            fault: crate::sim::FaultConfig::default(),
            retry: RetryConfig::default(),
            xfer: XferConfig::default(),
            chain: ChainConfig::default(),
        }
    }
}

impl IshmemConfig {
    /// Convenience: single-node config with `npes` PEs (must fit the
    /// default 6-GPU × 2-tile node).
    pub fn with_npes(npes: usize) -> Self {
        IshmemConfig {
            topology: Topology::single_node_for(npes),
            ..Default::default()
        }
    }

    pub fn npes(&self) -> usize {
        self.topology.npes()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.ring_capacity.is_power_of_two(), "ring capacity must be 2^k");
        anyhow::ensure!(
            self.heap_bytes >= super::heap::RESERVED_BYTES * 2 + self.staging_slab_bytes,
            "heap too small for internal sync region + staging slab"
        );
        anyhow::ensure!(self.completion_slots > 0, "need completion slots");
        anyhow::ensure!(self.max_batch_depth >= 1, "batch depth must be at least 1");
        anyhow::ensure!(
            self.staging_slab_bytes
                >= (self.max_batch_depth + 1) * crate::ringbuf::DESC_SIZE + 1024,
            "staging slab too small for one full descriptor block"
        );
        anyhow::ensure!(
            self.cutover.ema_alpha > 0.0 && self.cutover.ema_alpha <= 1.0,
            "cutover.ema_alpha must be in (0, 1]"
        );
        anyhow::ensure!(
            (0.0..=0.5).contains(&self.cutover.explore_eps),
            "cutover.explore_eps must be in [0, 0.5]"
        );
        anyhow::ensure!(
            self.cost.ce.stripe_max_engines >= 1,
            "cost.ce.stripe_max_engines must be at least 1"
        );
        anyhow::ensure!(
            self.cost.ce.chunk_min_bytes >= 1024,
            "cost.ce.chunk_min_bytes below 1KB cannot amortize an engine startup"
        );
        anyhow::ensure!(
            self.cost.ce.single_engine_frac > 0.0 && self.cost.ce.single_engine_frac <= 1.0,
            "cost.ce.single_engine_frac must be in (0, 1]"
        );
        anyhow::ensure!(self.cost.nic.rails >= 1, "cost.nic.rails must be at least 1");
        anyhow::ensure!(
            self.cost.nic.rails <= self.cost.nic.nics_per_node,
            "cost.nic.rails cannot exceed cost.nic.nics_per_node"
        );
        anyhow::ensure!(
            self.cost.nic.rail_bw_frac > 0.0 && self.cost.nic.rail_bw_frac <= 1.0,
            "cost.nic.rail_bw_frac must be in (0, 1]"
        );
        anyhow::ensure!(
            self.cost.nic.rail_chunk_min_bytes >= 1024,
            "cost.nic.rail_chunk_min_bytes below 1KB cannot amortize a rail startup"
        );
        anyhow::ensure!(
            self.cost.stripe.ramp_factor > 0.0 && self.cost.stripe.ramp_factor <= 1.0,
            "cost.stripe.ramp_factor must be in (0, 1]"
        );
        anyhow::ensure!(
            self.cost.stripe.ramp_chunks >= 1,
            "cost.stripe.ramp_chunks must be at least 1"
        );
        anyhow::ensure!(
            self.large_flush_bytes >= 1,
            "large_flush_bytes must be at least 1"
        );
        anyhow::ensure!(
            self.calib.ema_alpha > 0.0 && self.calib.ema_alpha <= 1.0,
            "calib.ema_alpha must be in (0, 1]"
        );
        anyhow::ensure!(self.calib.min_samples >= 1, "calib.min_samples must be at least 1");
        anyhow::ensure!(
            self.calib.clamp_frac >= 1.0,
            "calib.clamp_frac below 1 would forbid the configured seed itself"
        );
        anyhow::ensure!(
            !self.plan_cache.enable || self.plan_cache.capacity >= 1,
            "plan_cache.capacity must be at least 1 when the cache is enabled"
        );
        anyhow::ensure!(
            self.coll.leader_fanout >= 2,
            "coll.leader_fanout below 2 cannot form a tree"
        );
        anyhow::ensure!(
            self.fault.detect_frac > 0.0 && self.fault.detect_frac < 1.0,
            "fault.detect_frac must be in (0, 1) exclusive: 0 never detects, \
             1 would quarantine healthy rails on EMA noise"
        );
        anyhow::ensure!(
            self.fault.detect_min_samples >= 1,
            "fault.detect_min_samples must be at least 1"
        );
        anyhow::ensure!(
            self.fault.probe_after >= 1,
            "fault.probe_after must be at least 1 (a 0-observation probation \
             would revive a quarantined rail on the very next observation)"
        );
        for t in &self.fault.transients {
            anyhow::ensure!(t.period >= 1, "fault transient period must be at least 1");
            anyhow::ensure!(
                t.from_op <= t.until_op,
                "fault transient window is empty (from_op > until_op)"
            );
            anyhow::ensure!(
                t.min_bytes <= t.max_bytes,
                "fault transient size filter is empty (min_bytes > max_bytes)"
            );
        }
        anyhow::ensure!(
            self.retry.max_attempts >= 1
                && self.retry.max_attempts <= crate::ringbuf::batch::ATTEMPT_MAX as u32,
            "retry.max_attempts must be in 1..=15 (the descriptor carries a \
             4-bit attempt counter)"
        );
        anyhow::ensure!(
            self.retry.backoff_mult >= 1.0,
            "retry.backoff_mult below 1 would shrink the backoff per attempt"
        );
        anyhow::ensure!(
            !self.retry.enable || self.max_batch_depth <= crate::xfer::stream::NACK_MASK_BITS,
            "retry.enable needs max_batch_depth to fit the per-entry NACK mask \
             (≤ 48 entries per batch)"
        );
        anyhow::ensure!(
            !self.chain.enable || self.chain.max_depth >= 2,
            "chain.max_depth below 2 cannot express a dependent pair"
        );
        anyhow::ensure!(
            !self.chain.enable || self.chain.max_depth <= self.max_batch_depth,
            "chain.max_depth cannot exceed max_batch_depth (a fused chain is \
             one descriptor block behind one doorbell)"
        );
        anyhow::ensure!(
            !self.chain.enable
                || self.max_batch_depth <= crate::xfer::stream::NACK_MASK_BITS,
            "chain.enable needs max_batch_depth to fit the per-entry NACK mask \
             (a NACKed predecessor stage suppresses successors by mask bit)"
        );
        Ok(())
    }

    /// Largest chunk the striped pipeline can double-buffer through the
    /// staging slab (two chunks in flight + the stream's per-claim
    /// headroom + alignment slack for both claims). Below
    /// `chunk_min_bytes` the chunk pipeline disables itself and oversized
    /// payloads take the raw-pointer fallback.
    pub fn chunk_max_bytes(&self) -> usize {
        let headroom =
            crate::xfer::stream::slab_headroom_bytes(self.max_batch_depth) + 2 * 64;
        self.staging_slab_bytes.saturating_sub(headroom) / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        IshmemConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_ring_capacity_rejected() {
        let cfg = IshmemConfig { ring_capacity: 1000, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn stripe_knobs_validated() {
        let mut cfg = IshmemConfig::default();
        cfg.cost.ce.stripe_max_engines = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.cost.ce.chunk_min_bytes = 64;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.cost.ce.single_engine_frac = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.cutover.explore_eps = 0.9;
        assert!(cfg.validate().is_err());
        // Default slab double-buffers roughly 1 MiB chunks.
        let cfg = IshmemConfig::default();
        let cap = cfg.chunk_max_bytes();
        assert!(cap > 1000 << 10 && cap <= 1 << 20, "chunk cap {cap}");
    }

    #[test]
    fn rail_and_ramp_knobs_validated() {
        let mut cfg = IshmemConfig::default();
        cfg.cost.nic.rails = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.cost.nic.rails = cfg.cost.nic.nics_per_node + 1;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.cost.nic.rail_bw_frac = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.cost.nic.rail_chunk_min_bytes = 64;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.cost.stripe.ramp_factor = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.cost.stripe.ramp_chunks = 0;
        assert!(cfg.validate().is_err());
        let cfg = IshmemConfig { large_flush_bytes: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        // A degraded single-rail machine stays valid.
        let mut cfg = IshmemConfig::default();
        cfg.cost.nic.rails = 1;
        assert!(cfg.validate().is_ok());
        // The default auto-flush boundary sits above the slab's chunk cap,
        // so default striped pipelines batch exactly as before.
        let cfg = IshmemConfig::default();
        assert!(cfg.large_flush_bytes > cfg.chunk_max_bytes());
    }

    #[test]
    fn calib_knobs_validated() {
        let mut cfg = IshmemConfig::default();
        assert!(!cfg.calib.enable, "calibration must default off");
        cfg.calib.ema_alpha = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.calib.ema_alpha = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.calib.min_samples = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.calib.clamp_frac = 0.5;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.calib.enable = true;
        cfg.calib.clamp_frac = 1.0;
        assert!(cfg.validate().is_ok(), "clamp 1.0 pins learning to the seed but is legal");
    }

    #[test]
    fn plan_cache_knobs_validated() {
        let cfg = IshmemConfig::default();
        assert!(cfg.plan_cache.enable, "plan cache must default on");
        assert!(cfg.plan_cache.capacity >= 1024, "default capacity covers a real working set");
        let mut cfg = IshmemConfig::default();
        cfg.plan_cache.capacity = 0;
        assert!(cfg.validate().is_err());
        // Capacity is irrelevant when the cache is off.
        cfg.plan_cache.enable = false;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn coll_knobs_validated() {
        let cfg = IshmemConfig::default();
        assert_eq!(cfg.coll.algo, CollAlgoMode::Auto, "collectives must default to Auto");
        assert!(cfg.coll.leader_fanout >= 2);
        let mut cfg = IshmemConfig::default();
        cfg.coll.leader_fanout = 1;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.coll.algo = CollAlgoMode::Flat;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn fault_knobs_validated() {
        let cfg = IshmemConfig::default();
        assert!(!cfg.fault.enable, "fault injection must default off");
        assert_eq!(cfg.coll.decision_timeout_ms, 0, "decision wait defaults unbounded");
        assert_eq!(cfg.coll.sync_timeout_ms, 0, "sync wait defaults unbounded");
        // detect_frac is (0, 1) *exclusive* at both ends.
        let mut cfg = IshmemConfig::default();
        cfg.fault.detect_frac = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.fault.detect_frac = 1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.fault.detect_frac = -0.1;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.fault.detect_frac = 0.999;
        assert!(cfg.validate().is_ok());
        let mut cfg = IshmemConfig::default();
        cfg.fault.detect_min_samples = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.fault.probe_after = 0;
        assert!(cfg.validate().is_err());
        // An enabled plane with a kill script validates like any other.
        let mut cfg = IshmemConfig::default();
        cfg.fault.enable = true;
        cfg.fault.events.push(crate::sim::FaultEvent::kill_rail(8, 0, 1));
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn retry_and_xfer_knobs_validated() {
        let cfg = IshmemConfig::default();
        assert!(!cfg.retry.enable, "reliability layer must default off");
        assert_eq!(cfg.xfer.op_timeout_ms, 0, "p2p waits default unbounded");
        let mut cfg = IshmemConfig::default();
        cfg.retry.max_attempts = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = IshmemConfig::default();
        cfg.retry.max_attempts = 16;
        assert!(cfg.validate().is_err(), "attempt counter is 4 bits");
        let mut cfg = IshmemConfig::default();
        cfg.retry.backoff_mult = 0.5;
        assert!(cfg.validate().is_err());
        // An enabled retry layer must fit the NACK mask.
        let mut cfg = IshmemConfig::default();
        cfg.retry.enable = true;
        assert!(cfg.validate().is_ok());
        cfg.max_batch_depth = crate::xfer::stream::NACK_MASK_BITS + 1;
        assert!(cfg.validate().is_err());
        // Disabled retry tolerates any legal batch depth.
        let mut cfg = IshmemConfig::default();
        cfg.max_batch_depth = 64;
        cfg.staging_slab_bytes = 4 << 20;
        assert!(cfg.validate().is_ok());
        // Transient scripts are sanity-checked.
        let mut cfg = IshmemConfig::default();
        cfg.fault.transients.push(crate::sim::TransientEvent::drop_chunk(10, 5, 1));
        assert!(cfg.validate().is_err(), "empty op window");
        let mut cfg = IshmemConfig::default();
        cfg.fault
            .transients
            .push(crate::sim::TransientEvent::drop_chunk(0, 100, 20).with_bytes(4096, 1024));
        assert!(cfg.validate().is_err(), "empty size filter");
        let mut cfg = IshmemConfig::default();
        cfg.fault.enable = true;
        cfg.retry.enable = true;
        cfg.fault.transients.push(
            crate::sim::TransientEvent::corrupt_chunk(0, u64::MAX, 20).with_lane(1),
        );
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn chain_knobs_validated() {
        let cfg = IshmemConfig::default();
        assert!(!cfg.chain.enable, "triggered chains must default off");
        assert_eq!(cfg.chain.max_depth, 4);
        // Depth limits only bind while chains are enabled.
        let mut cfg = IshmemConfig::default();
        cfg.chain.max_depth = 1;
        assert!(cfg.validate().is_ok(), "disabled chains tolerate any depth");
        cfg.chain.enable = true;
        assert!(cfg.validate().is_err(), "depth 1 cannot express a dependent pair");
        let mut cfg = IshmemConfig::default();
        cfg.chain.enable = true;
        assert!(cfg.validate().is_ok());
        cfg.chain.max_depth = cfg.max_batch_depth + 1;
        assert!(cfg.validate().is_err(), "a fused chain is one descriptor block");
        // Enabled chains must fit the NACK mask like the retry layer.
        let mut cfg = IshmemConfig::default();
        cfg.chain.enable = true;
        cfg.max_batch_depth = crate::xfer::stream::NACK_MASK_BITS + 1;
        cfg.chain.max_depth = 4;
        cfg.staging_slab_bytes = 4 << 20;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn batch_knobs_validated() {
        let cfg = IshmemConfig { max_batch_depth: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = IshmemConfig { staging_slab_bytes: 64, ..Default::default() };
        assert!(cfg.validate().is_err());
        // A slab that eats the whole heap leaves no room for user data.
        let cfg = IshmemConfig { staging_slab_bytes: 8 << 20, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = IshmemConfig { max_batch_depth: 1, ..Default::default() };
        assert!(cfg.validate().is_ok());
    }
}
