//! Runtime configuration (env-tunable in real ishmem; struct-tunable here).

use crate::sim::cost::CostParams;
use crate::sim::Topology;

use super::cutover::CutoverConfig;

#[derive(Clone, Debug)]
pub struct IshmemConfig {
    pub topology: Topology,
    /// Per-PE device symmetric heap size, bytes.
    pub heap_bytes: usize,
    /// Host symmetric heap (SOS side), bytes.
    pub host_heap_bytes: usize,
    pub cutover: CutoverConfig,
    pub cost: CostParams,
    /// Reverse-offload ring capacity (messages, power of two).
    pub ring_capacity: usize,
    /// Completion pool per node.
    pub completion_slots: usize,
    /// Use immediate command lists in the proxy (paper §III-C low-latency).
    pub use_immediate_cl: bool,
    /// Strict FI_HMEM: inter-node traffic to unregistered heaps errors out
    /// instead of bouncing (failure injection).
    pub strict_hmem: bool,
    /// Elements below this never go through the XLA reduce kernel (kernel
    /// launch dominates); above, the AOT Pallas kernel path is used when
    /// the dtype is covered and a runtime is attached.
    pub xla_reduce_min_elems: usize,
}

impl Default for IshmemConfig {
    fn default() -> Self {
        IshmemConfig {
            topology: Topology::default(),
            heap_bytes: 8 << 20,
            host_heap_bytes: 1 << 20,
            cutover: CutoverConfig::default(),
            cost: CostParams::default(),
            ring_capacity: 4096,
            completion_slots: 1024,
            use_immediate_cl: true,
            strict_hmem: false,
            xla_reduce_min_elems: 1024,
        }
    }
}

impl IshmemConfig {
    /// Convenience: single-node config with `npes` PEs (must fit the
    /// default 6-GPU × 2-tile node).
    pub fn with_npes(npes: usize) -> Self {
        IshmemConfig {
            topology: Topology::single_node_for(npes),
            ..Default::default()
        }
    }

    pub fn npes(&self) -> usize {
        self.topology.npes()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.ring_capacity.is_power_of_two(), "ring capacity must be 2^k");
        anyhow::ensure!(self.heap_bytes >= super::heap::RESERVED_BYTES * 2,
            "heap too small for internal sync region");
        anyhow::ensure!(self.completion_slots > 0, "need completion slots");
        anyhow::ensure!(
            self.cutover.ema_alpha > 0.0 && self.cutover.ema_alpha <= 1.0,
            "cutover.ema_alpha must be in (0, 1]"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        IshmemConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_ring_capacity_rejected() {
        let cfg = IshmemConfig { ring_capacity: 1000, ..Default::default() };
        assert!(cfg.validate().is_err());
    }
}
