//! Remote memory access: put/get families (paper §III-G.1).
//!
//! Device-initiated path per `ishmem_long_p`'s recipe: load the GPU info
//! block, look up whether the target PE is load/store-reachable (IPC
//! table), translate `dest` into the peer heap — then hand the request to
//! the unified transfer-plan engine ([`crate::xfer`]): the planner picks
//! organic load/store, reverse-offload → copy engine, or inter-node
//! proxy → OFI (§III-B cutover), and the matching executor moves the bytes,
//! charges the cost model, and tracks blocking/NBI completion. This module
//! holds only the API surface and its argument checking.

use crate::coordinator::metrics::{Metrics, PathIdx};
use crate::sim::topology::Locality;
use crate::xfer::plan::OpKind;

use super::types::{as_bytes, as_bytes_mut, ShmemType};
use super::{PeCtx, SymAddr};

impl PeCtx {
    // --------------------------------------------------- blocking put/get --

    /// `ishmem_put` — blocking contiguous put of `src` into the symmetric
    /// `dest` on PE `pe`. Device-initiated, single calling work-item.
    pub fn put<T: ShmemType>(&self, dest: SymAddr<T>, src: &[T], pe: usize) {
        self.put_items(dest, src, pe, 1)
    }

    /// `ishmem_get` — blocking contiguous get from PE `pe`.
    pub fn get<T: ShmemType>(&self, dest: &mut [T], src: SymAddr<T>, pe: usize) {
        self.get_items(dest, src, pe, 1)
    }

    /// Shared implementation; `items` is the cooperating work-item count
    /// (1 for the scalar-thread API, N for `_work_group`).
    pub(crate) fn put_items<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        pe: usize,
        items: usize,
    ) {
        assert!(src.len() <= dest.len(), "put overflows destination");
        assert!(pe < self.npes(), "PE {pe} out of range");
        let bytes = std::mem::size_of_val(src);
        Metrics::add(&self.rt.metrics.puts, 1);
        if bytes == 0 {
            return;
        }
        let plan = self.plan_to(OpKind::Put, pe, bytes, items);
        self.exec_put(&plan, pe, dest.byte_offset(), as_bytes(src));
    }

    pub(crate) fn get_items<T: ShmemType>(
        &self,
        dest: &mut [T],
        src: SymAddr<T>,
        pe: usize,
        items: usize,
    ) {
        assert!(dest.len() <= src.len(), "get overflows source");
        assert!(pe < self.npes(), "PE {pe} out of range");
        let bytes = std::mem::size_of_val(dest);
        Metrics::add(&self.rt.metrics.gets, 1);
        if bytes == 0 {
            return;
        }
        let plan = self.plan_to(OpKind::Get, pe, bytes, items);
        self.exec_get(&plan, pe, src.byte_offset(), as_bytes_mut(dest));
    }

    // ------------------------------------------------------------ scalars --

    /// `ishmem_TYPE_p` — blocking scalar store (the paper's worked example).
    pub fn p<T: ShmemType>(&self, dest: SymAddr<T>, value: T, pe: usize) {
        Metrics::add(&self.rt.metrics.puts, 1);
        let bytes = std::mem::size_of::<T>();
        if self.ipc.lookup(pe).is_some() {
            // Steps of §III-G.1: table lookup → translate → store. A scalar
            // is always below any cutover point: straight store path.
            let loc = self.loc_of(pe);
            self.rt
                .heaps
                .heap(pe)
                .write(dest.byte_offset(), as_bytes(std::slice::from_ref(&value)));
            self.clock.advance(self.rt.cost.loadstore_ns(loc, bytes, 1));
            self.rt
                .metrics
                .add_path_bytes(PathIdx::LoadStore, loc, bytes as u64);
        } else {
            // Scalar rides inside the 64-byte message (PutInline).
            let mut raw = [0u8; 8];
            raw[..bytes].copy_from_slice(as_bytes(std::slice::from_ref(&value)));
            self.proxied_put_inline(
                pe,
                dest.byte_offset(),
                T::TAG as u8,
                bytes,
                u64::from_le_bytes(raw),
            );
        }
    }

    /// `ishmem_TYPE_g` — blocking scalar fetch.
    pub fn g<T: ShmemType + Default>(&self, src: SymAddr<T>, pe: usize) -> T {
        let mut out = [T::default()];
        self.get(&mut out, src, pe);
        out[0]
    }

    // -------------------------------------------------------- non-blocking --

    /// `ishmem_put_nbi`. Data movement is performed eagerly (Rust borrow
    /// safety: the source buffer may be reused on return, which is
    /// *stronger* than the spec's contract); the *modeled* completion is
    /// deferred to `quiet` through the xfer completion tracker, so overlap
    /// behaves like real nbi in the figures. See DESIGN.md §7.
    pub fn put_nbi<T: ShmemType>(&self, dest: SymAddr<T>, src: &[T], pe: usize) {
        self.put_nbi_items(dest, src, pe, 1)
    }

    pub fn get_nbi<T: ShmemType>(&self, dest: &mut [T], src: SymAddr<T>, pe: usize) {
        self.get_nbi_items(dest, src, pe, 1)
    }

    pub(crate) fn put_nbi_items<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        pe: usize,
        items: usize,
    ) {
        assert!(src.len() <= dest.len());
        let bytes = std::mem::size_of_val(src);
        Metrics::add(&self.rt.metrics.puts, 1);
        if bytes == 0 {
            return;
        }
        let plan = self.plan_to(OpKind::Put, pe, bytes, items);
        self.exec_put_nbi(&plan, pe, dest.byte_offset(), as_bytes(src));
    }

    pub(crate) fn get_nbi_items<T: ShmemType>(
        &self,
        dest: &mut [T],
        src: SymAddr<T>,
        pe: usize,
        items: usize,
    ) {
        assert!(dest.len() <= src.len());
        let bytes = std::mem::size_of_val(dest);
        Metrics::add(&self.rt.metrics.gets, 1);
        if bytes == 0 {
            return;
        }
        let plan = self.plan_to(OpKind::Get, pe, bytes, items);
        self.exec_get_nbi(&plan, pe, src.byte_offset(), as_bytes_mut(dest));
    }

    // ------------------------------------------------------------ strided --

    /// `ishmem_iput` — strided put: element `i` of `src` (stride `sst`)
    /// lands at element `i*dst` stride of `dest`.
    pub fn iput<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        dst_stride: usize,
        src_stride: usize,
        nelems: usize,
        pe: usize,
    ) {
        assert!(dst_stride >= 1 && src_stride >= 1);
        assert!((nelems.saturating_sub(1)) * src_stride < src.len() || nelems == 0);
        assert!((nelems.saturating_sub(1)) * dst_stride < dest.len() || nelems == 0);
        Metrics::add(&self.rt.metrics.puts, 1);
        let esz = std::mem::size_of::<T>();
        let heap = self.rt.heaps.heap(pe);
        for i in 0..nelems {
            let v = src[i * src_stride];
            heap.write(
                dest.byte_offset() + i * dst_stride * esz,
                as_bytes(std::slice::from_ref(&v)),
            );
        }
        // SYCL-vector-op strided copy: ~20% penalty over contiguous
        // (paper §III-G.1 "special memory functions").
        let loc = self.loc_of(pe);
        let bytes = nelems * esz;
        if bytes > 0 {
            assert!(self.ipc.lookup(pe).is_some(), "iput requires load/store reach");
            self.clock
                .advance(self.rt.cost.loadstore_ns(loc, bytes, 1) * 1.2);
            self.rt
                .metrics
                .add_path_bytes(PathIdx::LoadStore, loc, bytes as u64);
        }
    }

    /// `ishmem_iget` — strided get.
    pub fn iget<T: ShmemType>(
        &self,
        dest: &mut [T],
        src: SymAddr<T>,
        dst_stride: usize,
        src_stride: usize,
        nelems: usize,
        pe: usize,
    ) {
        assert!(dst_stride >= 1 && src_stride >= 1);
        Metrics::add(&self.rt.metrics.gets, 1);
        let esz = std::mem::size_of::<T>();
        let heap = self.rt.heaps.heap(pe);
        for i in 0..nelems {
            let mut v = [dest[i * dst_stride]];
            heap.read(src.byte_offset() + i * src_stride * esz, as_bytes_mut(&mut v));
            dest[i * dst_stride] = v[0];
        }
        let loc = self.loc_of(pe);
        let bytes = nelems * esz;
        if bytes > 0 {
            assert!(self.ipc.lookup(pe).is_some(), "iget requires load/store reach");
            self.clock
                .advance(self.rt.cost.loadstore_ns(loc, bytes, 1) * 1.2);
            self.rt
                .metrics
                .add_path_bytes(PathIdx::LoadStore, loc, bytes as u64);
        }
    }

    // ------------------------------------------------------ host-initiated --

    /// Host-initiated put (`ishmem_put` from host code): drives the copy
    /// engine through a Level-Zero immediate command list, or OFI for
    /// remote targets — no reverse-offload ring involved, so it bypasses
    /// the device planner (the paper's host path).
    pub fn host_put<T: ShmemType>(&self, dest: SymAddr<T>, src: &[T], pe: usize) {
        assert!(src.len() <= dest.len());
        let bytes = std::mem::size_of_val(src);
        Metrics::add(&self.rt.metrics.puts, 1);
        if bytes == 0 {
            return;
        }
        self.clock
            .advance(self.rt.cost.params.overhead.host_issue_ns);
        if self.ipc.lookup(pe).is_some() {
            let loc = self.loc_of(pe);
            self.rt
                .heaps
                .heap(pe)
                .write(dest.byte_offset(), as_bytes(src));
            self.clock.advance(self.rt.cost.copy_engine_ns(
                self.my_gpu(),
                loc,
                bytes,
                self.rt.config.use_immediate_cl,
                true,
                false,
            ));
            self.rt
                .metrics
                .add_path_bytes(PathIdx::CopyEngine, loc, bytes as u64);
        } else {
            self.rt
                .transport
                .put_from_ptr(src.as_ptr() as u64, pe, dest.byte_offset(), bytes, &self.clock)
                .expect("host_put transport");
            self.rt
                .metrics
                .add_path_bytes(PathIdx::Nic, Locality::Remote, bytes as u64);
        }
    }

    /// Host-initiated get.
    pub fn host_get<T: ShmemType>(&self, dest: &mut [T], src: SymAddr<T>, pe: usize) {
        assert!(dest.len() <= src.len());
        let bytes = std::mem::size_of_val(dest);
        Metrics::add(&self.rt.metrics.gets, 1);
        if bytes == 0 {
            return;
        }
        self.clock
            .advance(self.rt.cost.params.overhead.host_issue_ns);
        if self.ipc.lookup(pe).is_some() {
            let loc = self.loc_of(pe);
            self.rt
                .heaps
                .heap(pe)
                .read(src.byte_offset(), as_bytes_mut(dest));
            self.clock.advance(self.rt.cost.copy_engine_ns(
                self.my_gpu(),
                loc,
                bytes,
                self.rt.config.use_immediate_cl,
                true,
                false,
            ));
            self.rt
                .metrics
                .add_path_bytes(PathIdx::CopyEngine, loc, bytes as u64);
        } else {
            self.rt
                .transport
                .get_to_ptr(pe, src.byte_offset(), dest.as_mut_ptr() as u64, bytes, &self.clock)
                .expect("host_get transport");
            self.rt
                .metrics
                .add_path_bytes(PathIdx::Nic, Locality::Remote, bytes as u64);
        }
    }
}
