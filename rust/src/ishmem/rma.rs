//! Remote memory access: put/get families (paper §III-G.1).
//!
//! Device-initiated path per `ishmem_long_p`'s recipe: load the GPU info
//! block, look up whether the target PE is load/store-reachable (IPC
//! table), translate `dest` into the peer heap, then either store directly
//! or compose a reverse-offload message for the host proxy. The cutover
//! policy (§III-B) picks between organic load/store and the copy engines
//! for reachable targets; unreachable (inter-node) targets always take the
//! proxy + OFI path.

use crate::coordinator::metrics::Metrics;
use crate::ringbuf::{Message, RingOp, COMPLETION_NONE};
use crate::sim::topology::Locality;
use crate::sim::SimClock;

use super::cutover::Path;
use super::types::{as_bytes, as_bytes_mut, ShmemType};
use super::{PeCtx, SymAddr};

/// Message flag: `src_off`/`dst_off` is a raw in-process pointer (the
/// initiator's private buffer), not a symmetric-heap offset.
pub(crate) const FLAG_RAW_PTR: u16 = 1 << 8;

/// Completion payloads for non-fetching proxied ops.
pub(crate) const PROXY_OK: u64 = 0;
pub(crate) const PROXY_ERR_UNREGISTERED: u64 = 1;

impl PeCtx {
    // ------------------------------------------------------------ helpers --

    #[inline]
    pub(crate) fn loc_of(&self, pe: usize) -> Locality {
        self.rt.cost.locality(self.pe(), pe)
    }

    #[inline]
    pub(crate) fn my_gpu(&self) -> usize {
        self.rt.topo().global_gpu_of(self.pe())
    }

    /// Post a ring message and block for its completion payload.
    pub(crate) fn proxied_blocking(&self, mut msg: Message) -> u64 {
        let pool = self.completions().clone();
        let token = pool.alloc();
        msg.completion = token.index;
        msg.src_pe = self.pe() as u32;
        Metrics::add(&self.rt.metrics.ring_messages, 1);
        self.ring().send(msg);
        pool.wait(token)
    }

    /// Post a fire-and-forget ring message.
    pub(crate) fn proxied_ff(&self, mut msg: Message) {
        msg.completion = COMPLETION_NONE;
        msg.src_pe = self.pe() as u32;
        Metrics::add(&self.rt.metrics.ring_messages, 1);
        self.note_proxy_ff();
        self.ring().send(msg);
    }

    fn check_proxy_status(&self, status: u64, what: &str, pe: usize) {
        match status {
            PROXY_OK => {}
            PROXY_ERR_UNREGISTERED => panic!(
                "{what} to PE {pe} failed: target heap not FI_HMEM-registered (strict mode)"
            ),
            other => panic!("{what} to PE {pe} failed: proxy status {other}"),
        }
    }

    // --------------------------------------------------- blocking put/get --

    /// `ishmem_put` — blocking contiguous put of `src` into the symmetric
    /// `dest` on PE `pe`. Device-initiated, single calling work-item.
    pub fn put<T: ShmemType>(&self, dest: SymAddr<T>, src: &[T], pe: usize) {
        self.put_items(dest, src, pe, 1)
    }

    /// `ishmem_get` — blocking contiguous get from PE `pe`.
    pub fn get<T: ShmemType>(&self, dest: &mut [T], src: SymAddr<T>, pe: usize) {
        self.get_items(dest, src, pe, 1)
    }

    /// Shared implementation; `items` is the cooperating work-item count
    /// (1 for the scalar-thread API, N for `_work_group`).
    pub(crate) fn put_items<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        pe: usize,
        items: usize,
    ) {
        assert!(src.len() <= dest.len(), "put overflows destination");
        assert!(pe < self.npes(), "PE {pe} out of range");
        let bytes = std::mem::size_of_val(src);
        Metrics::add(&self.rt.metrics.puts, 1);
        if bytes == 0 {
            return;
        }
        let loc = self.loc_of(pe);

        if self.ipc.lookup(pe).is_none() {
            // Inter-node: reverse offload to the host proxy → OFI.
            let mut m = Message::nop();
            m.op = RingOp::Put as u8;
            m.flags = FLAG_RAW_PTR;
            m.pe = pe as u32;
            m.dst_off = dest.byte_offset() as u64;
            m.src_off = src.as_ptr() as u64;
            m.len = bytes as u64;
            let status = self.proxied_blocking(m);
            self.check_proxy_status(status, "put", pe);
            let registered = self.rt.transport.is_registered(pe);
            self.clock
                .advance(self.rt.cost.internode_ns(bytes, registered, true));
            Metrics::add(&self.rt.metrics.bytes_nic, bytes as u64);
            return;
        }

        match self.rt.config.cutover.decide(&self.rt.cost, loc, bytes, items) {
            Path::LoadStore => {
                self.rt
                    .heaps
                    .heap(pe)
                    .write(dest.byte_offset(), as_bytes(src));
                self.clock.advance(self.rt.cost.loadstore_ns(loc, bytes, items));
                Metrics::add(&self.rt.metrics.bytes_loadstore, bytes as u64);
            }
            Path::CopyEngine => {
                let mut m = Message::nop();
                m.op = RingOp::Put as u8;
                m.flags = FLAG_RAW_PTR;
                m.pe = pe as u32;
                m.dst_off = dest.byte_offset() as u64;
                m.src_off = src.as_ptr() as u64;
                m.len = bytes as u64;
                let status = self.proxied_blocking(m);
                self.check_proxy_status(status, "put", pe);
                self.clock.advance(self.rt.cost.copy_engine_ns(
                    self.my_gpu(),
                    loc,
                    bytes,
                    self.rt.config.use_immediate_cl,
                    false,
                    true,
                ));
                Metrics::add(&self.rt.metrics.bytes_copy_engine, bytes as u64);
            }
        }
    }

    pub(crate) fn get_items<T: ShmemType>(
        &self,
        dest: &mut [T],
        src: SymAddr<T>,
        pe: usize,
        items: usize,
    ) {
        assert!(dest.len() <= src.len(), "get overflows source");
        assert!(pe < self.npes(), "PE {pe} out of range");
        let bytes = std::mem::size_of_val(dest);
        Metrics::add(&self.rt.metrics.gets, 1);
        if bytes == 0 {
            return;
        }
        let loc = self.loc_of(pe);

        if self.ipc.lookup(pe).is_none() {
            let mut m = Message::nop();
            m.op = RingOp::Get as u8;
            m.flags = FLAG_RAW_PTR;
            m.pe = pe as u32;
            m.src_off = src.byte_offset() as u64;
            m.dst_off = dest.as_mut_ptr() as u64;
            m.len = bytes as u64;
            let status = self.proxied_blocking(m);
            self.check_proxy_status(status, "get", pe);
            let registered = self.rt.transport.is_registered(pe);
            self.clock
                .advance(self.rt.cost.internode_ns(bytes, registered, true));
            Metrics::add(&self.rt.metrics.bytes_nic, bytes as u64);
            return;
        }

        match self.rt.config.cutover.decide(&self.rt.cost, loc, bytes, items) {
            Path::LoadStore => {
                self.rt
                    .heaps
                    .heap(pe)
                    .read(src.byte_offset(), as_bytes_mut(dest));
                self.clock.advance(self.rt.cost.loadstore_ns(loc, bytes, items));
                Metrics::add(&self.rt.metrics.bytes_loadstore, bytes as u64);
            }
            Path::CopyEngine => {
                let mut m = Message::nop();
                m.op = RingOp::Get as u8;
                m.flags = FLAG_RAW_PTR;
                m.pe = pe as u32;
                m.src_off = src.byte_offset() as u64;
                m.dst_off = dest.as_mut_ptr() as u64;
                m.len = bytes as u64;
                let status = self.proxied_blocking(m);
                self.check_proxy_status(status, "get", pe);
                self.clock.advance(self.rt.cost.copy_engine_ns(
                    self.my_gpu(),
                    loc,
                    bytes,
                    self.rt.config.use_immediate_cl,
                    false,
                    true,
                ));
                Metrics::add(&self.rt.metrics.bytes_copy_engine, bytes as u64);
            }
        }
    }

    // ------------------------------------------------------------ scalars --

    /// `ishmem_TYPE_p` — blocking scalar store (the paper's worked example).
    pub fn p<T: ShmemType>(&self, dest: SymAddr<T>, value: T, pe: usize) {
        Metrics::add(&self.rt.metrics.puts, 1);
        let bytes = std::mem::size_of::<T>();
        if self.ipc.lookup(pe).is_some() {
            // Steps of §III-G.1: table lookup → translate → store.
            let loc = self.loc_of(pe);
            self.rt
                .heaps
                .heap(pe)
                .write(dest.byte_offset(), as_bytes(std::slice::from_ref(&value)));
            self.clock.advance(self.rt.cost.loadstore_ns(loc, bytes, 1));
            Metrics::add(&self.rt.metrics.bytes_loadstore, bytes as u64);
        } else {
            // Scalar rides inside the 64-byte message (PutInline):
            // locally complete as soon as the message is posted.
            let mut m = Message::nop();
            m.op = RingOp::PutInline as u8;
            m.dtype = T::TAG as u8;
            m.pe = pe as u32;
            m.dst_off = dest.byte_offset() as u64;
            m.len = bytes as u64;
            let mut raw = [0u8; 8];
            raw[..bytes].copy_from_slice(as_bytes(std::slice::from_ref(&value)));
            m.inline_val = u64::from_le_bytes(raw);
            self.proxied_ff(m);
            self.clock.advance(self.rt.cost.ring_post_ns());
            Metrics::add(&self.rt.metrics.bytes_nic, bytes as u64);
        }
    }

    /// `ishmem_TYPE_g` — blocking scalar fetch.
    pub fn g<T: ShmemType + Default>(&self, src: SymAddr<T>, pe: usize) -> T {
        let mut out = [T::default()];
        self.get(&mut out, src, pe);
        out[0]
    }

    // -------------------------------------------------------- non-blocking --

    /// `ishmem_put_nbi`. Data movement is performed eagerly (Rust borrow
    /// safety: the source buffer may be reused on return, which is
    /// *stronger* than the spec's contract); the *modeled* completion is
    /// deferred to `quiet`, so overlap behaves like real nbi in the
    /// figures. See DESIGN.md §7.
    pub fn put_nbi<T: ShmemType>(&self, dest: SymAddr<T>, src: &[T], pe: usize) {
        self.put_nbi_items(dest, src, pe, 1)
    }

    pub fn get_nbi<T: ShmemType>(&self, dest: &mut [T], src: SymAddr<T>, pe: usize) {
        self.get_nbi_items(dest, src, pe, 1)
    }

    pub(crate) fn put_nbi_items<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        pe: usize,
        items: usize,
    ) {
        assert!(src.len() <= dest.len());
        let bytes = std::mem::size_of_val(src);
        Metrics::add(&self.rt.metrics.puts, 1);
        if bytes == 0 {
            return;
        }
        let loc = self.loc_of(pe);
        let issue = self.rt.cost.ring_post_ns();

        // Eager movement.
        if self.ipc.lookup(pe).is_some() {
            self.rt
                .heaps
                .heap(pe)
                .write(dest.byte_offset(), as_bytes(src));
        } else {
            let dummy = SimClock::new();
            self.rt
                .transport
                .put_from_ptr(src.as_ptr() as u64, pe, dest.byte_offset(), bytes, &dummy)
                .expect("put_nbi transport");
            Metrics::add(&self.rt.metrics.bytes_nic, bytes as u64);
        }

        // Deferred modeled completion.
        let full = if self.ipc.lookup(pe).is_some() {
            match self.rt.config.cutover.decide(&self.rt.cost, loc, bytes, items) {
                Path::LoadStore => {
                    Metrics::add(&self.rt.metrics.bytes_loadstore, bytes as u64);
                    self.rt.cost.loadstore_ns(loc, bytes, items)
                }
                Path::CopyEngine => {
                    Metrics::add(&self.rt.metrics.bytes_copy_engine, bytes as u64);
                    self.rt.cost.copy_engine_ns(
                        self.my_gpu(),
                        loc,
                        bytes,
                        self.rt.config.use_immediate_cl,
                        false,
                        true,
                    )
                }
            }
        } else {
            self.rt
                .cost
                .internode_ns(bytes, self.rt.transport.is_registered(pe), true)
        };
        self.clock.advance(issue);
        let done_at = self.clock.now_ns() + (full - issue).max(0.0);
        self.nbi_horizon_ns
            .set(self.nbi_horizon_ns.get().max(done_at));
    }

    pub(crate) fn get_nbi_items<T: ShmemType>(
        &self,
        dest: &mut [T],
        src: SymAddr<T>,
        pe: usize,
        items: usize,
    ) {
        assert!(dest.len() <= src.len());
        let bytes = std::mem::size_of_val(dest);
        Metrics::add(&self.rt.metrics.gets, 1);
        if bytes == 0 {
            return;
        }
        let loc = self.loc_of(pe);
        let issue = self.rt.cost.ring_post_ns();

        if self.ipc.lookup(pe).is_some() {
            self.rt
                .heaps
                .heap(pe)
                .read(src.byte_offset(), as_bytes_mut(dest));
        } else {
            let dummy = SimClock::new();
            self.rt
                .transport
                .get_to_ptr(pe, src.byte_offset(), dest.as_mut_ptr() as u64, bytes, &dummy)
                .expect("get_nbi transport");
            Metrics::add(&self.rt.metrics.bytes_nic, bytes as u64);
        }

        let full = if self.ipc.lookup(pe).is_some() {
            Metrics::add(&self.rt.metrics.bytes_loadstore, bytes as u64);
            self.rt.cost.loadstore_ns(loc, bytes, items)
        } else {
            self.rt
                .cost
                .internode_ns(bytes, self.rt.transport.is_registered(pe), true)
        };
        self.clock.advance(issue);
        let done_at = self.clock.now_ns() + (full - issue).max(0.0);
        self.nbi_horizon_ns
            .set(self.nbi_horizon_ns.get().max(done_at));
    }

    // ------------------------------------------------------------ strided --

    /// `ishmem_iput` — strided put: element `i` of `src` (stride `sst`)
    /// lands at element `i*dst` stride of `dest`.
    pub fn iput<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        dst_stride: usize,
        src_stride: usize,
        nelems: usize,
        pe: usize,
    ) {
        assert!(dst_stride >= 1 && src_stride >= 1);
        assert!((nelems.saturating_sub(1)) * src_stride < src.len() || nelems == 0);
        assert!((nelems.saturating_sub(1)) * dst_stride < dest.len() || nelems == 0);
        Metrics::add(&self.rt.metrics.puts, 1);
        let esz = std::mem::size_of::<T>();
        let heap = self.rt.heaps.heap(pe);
        for i in 0..nelems {
            let v = src[i * src_stride];
            heap.write(
                dest.byte_offset() + i * dst_stride * esz,
                as_bytes(std::slice::from_ref(&v)),
            );
        }
        // SYCL-vector-op strided copy: ~20% penalty over contiguous
        // (paper §III-G.1 "special memory functions").
        let loc = self.loc_of(pe);
        let bytes = nelems * esz;
        if bytes > 0 {
            assert!(self.ipc.lookup(pe).is_some(), "iput requires load/store reach");
            self.clock
                .advance(self.rt.cost.loadstore_ns(loc, bytes, 1) * 1.2);
            Metrics::add(&self.rt.metrics.bytes_loadstore, bytes as u64);
        }
    }

    /// `ishmem_iget` — strided get.
    pub fn iget<T: ShmemType>(
        &self,
        dest: &mut [T],
        src: SymAddr<T>,
        dst_stride: usize,
        src_stride: usize,
        nelems: usize,
        pe: usize,
    ) {
        assert!(dst_stride >= 1 && src_stride >= 1);
        Metrics::add(&self.rt.metrics.gets, 1);
        let esz = std::mem::size_of::<T>();
        let heap = self.rt.heaps.heap(pe);
        for i in 0..nelems {
            let mut v = [dest[i * dst_stride]];
            heap.read(src.byte_offset() + i * src_stride * esz, as_bytes_mut(&mut v));
            dest[i * dst_stride] = v[0];
        }
        let loc = self.loc_of(pe);
        let bytes = nelems * esz;
        if bytes > 0 {
            assert!(self.ipc.lookup(pe).is_some(), "iget requires load/store reach");
            self.clock
                .advance(self.rt.cost.loadstore_ns(loc, bytes, 1) * 1.2);
            Metrics::add(&self.rt.metrics.bytes_loadstore, bytes as u64);
        }
    }

    // ------------------------------------------------------ host-initiated --

    /// Host-initiated put (`ishmem_put` from host code): drives the copy
    /// engine through a Level-Zero immediate command list, or OFI for
    /// remote targets — no reverse-offload ring involved.
    pub fn host_put<T: ShmemType>(&self, dest: SymAddr<T>, src: &[T], pe: usize) {
        assert!(src.len() <= dest.len());
        let bytes = std::mem::size_of_val(src);
        Metrics::add(&self.rt.metrics.puts, 1);
        if bytes == 0 {
            return;
        }
        self.clock
            .advance(self.rt.cost.params.overhead.host_issue_ns);
        if self.ipc.lookup(pe).is_some() {
            let loc = self.loc_of(pe);
            self.rt
                .heaps
                .heap(pe)
                .write(dest.byte_offset(), as_bytes(src));
            self.clock.advance(self.rt.cost.copy_engine_ns(
                self.my_gpu(),
                loc,
                bytes,
                self.rt.config.use_immediate_cl,
                true,
                false,
            ));
            Metrics::add(&self.rt.metrics.bytes_copy_engine, bytes as u64);
        } else {
            self.rt
                .transport
                .put_from_ptr(src.as_ptr() as u64, pe, dest.byte_offset(), bytes, &self.clock)
                .expect("host_put transport");
            Metrics::add(&self.rt.metrics.bytes_nic, bytes as u64);
        }
    }

    /// Host-initiated get.
    pub fn host_get<T: ShmemType>(&self, dest: &mut [T], src: SymAddr<T>, pe: usize) {
        assert!(dest.len() <= src.len());
        let bytes = std::mem::size_of_val(dest);
        Metrics::add(&self.rt.metrics.gets, 1);
        if bytes == 0 {
            return;
        }
        self.clock
            .advance(self.rt.cost.params.overhead.host_issue_ns);
        if self.ipc.lookup(pe).is_some() {
            let loc = self.loc_of(pe);
            self.rt
                .heaps
                .heap(pe)
                .read(src.byte_offset(), as_bytes_mut(dest));
            self.clock.advance(self.rt.cost.copy_engine_ns(
                self.my_gpu(),
                loc,
                bytes,
                self.rt.config.use_immediate_cl,
                true,
                false,
            ));
            Metrics::add(&self.rt.metrics.bytes_copy_engine, bytes as u64);
        } else {
            self.rt
                .transport
                .get_to_ptr(pe, src.byte_offset(), dest.as_mut_ptr() as u64, bytes, &self.clock)
                .expect("host_get transport");
            Metrics::add(&self.rt.metrics.bytes_nic, bytes as u64);
        }
    }
}
