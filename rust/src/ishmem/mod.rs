//! The ishmem library core — the paper's primary contribution.
//!
//! [`Ishmem`] is the job-wide runtime (heaps, rings, proxies, teams,
//! cutover); [`PeCtx`] is one processing element's handle, carrying the
//! device-initiated API surface:
//!
//! | paper API                         | here                              |
//! |-----------------------------------|-----------------------------------|
//! | `ishmem_put/get/p/g/iput/iget`    | `PeCtx::{put,get,p,g,iput,iget}`  |
//! | `ishmem_put_nbi/get_nbi`          | `PeCtx::{put_nbi,get_nbi}`        |
//! | `ishmem_atomic_*`                 | `PeCtx::atomic_*`                 |
//! | `ishmem_put_signal`, wait         | `PeCtx::{put_signal,signal_*}`    |
//! | `ishmem_fence/quiet`              | `PeCtx::{fence,quiet}`            |
//! | `ishmem_wait_until/test`          | `PeCtx::{wait_until,test}`        |
//! | `ishmem_team_*`                   | `PeCtx::team_*`, [`TeamId`]       |
//! | `ishmem_barrier/sync/broadcast/…` | `PeCtx::{barrier_all,team_sync,…}`|
//! | `ishmemx_*_work_group`            | `PeCtx::*_work_group`             |
//! | cutover / path selection (§III-B) | [`crate::xfer::plan::XferEngine`] |
//! | reverse-offload wire ops (§III-D) | [`crate::xfer::exec`]             |
//! | nbi / fire-and-forget completion  | [`crate::xfer::track`]            |
//!
//! Every device-initiated transfer above plans through the single
//! [`crate::xfer`] engine (plan → execute → complete); this module holds
//! the API surface, teams, sync and heap management.
//!
//! Host-initiated variants (`ishmem_*` called from host code) are the
//! `host_*` methods; they skip the ring and drive the Level-Zero command
//! lists / OFI transport directly, like the paper's host path.

pub mod amo;
pub mod chain;
pub mod collectives;
pub mod config;
pub mod cutover;
pub mod heap;
pub mod order;
pub mod proxy;
pub mod rma;
pub mod signal;
pub mod sync;
pub mod teams;
pub mod types;
pub mod workgroup;

pub use chain::ChainBuilder;
pub use config::{ChainConfig, CollAlgoMode, CollConfig, IshmemConfig, RetryConfig, XferConfig};
pub use cutover::{CutoverConfig, CutoverMode, Path};
pub use heap::{SymAddr, SymAllocator};
pub use sync::Cmp;
pub use teams::TeamId;
pub use types::{AmoElem, ReduceElem, ReduceOp, ShmemType, TypeTag};

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::coordinator::metrics::Metrics;
use crate::ringbuf::{CompletionPool, Message, Ring, RingOp};
use crate::runtime::XlaRuntime;
use crate::sim::{CollAlgo, CostModel, HeapRegistry, SimClock, Topology};
use crate::sos::heap::{ExternalHeapKind, SosHeaps, StagingSlab, ThreadLevel};
use crate::sos::pmi::PmiWorld;
use crate::sos::transport::OfiTransport;
use crate::xfer::{Calibrator, CmdStream, CompletionTracker, XferEngine};
use crate::ze::{IpcTable, ZeDriver};

/// Job-wide runtime state (one per "machine").
pub struct Ishmem {
    pub config: IshmemConfig,
    pub cost: Arc<CostModel>,
    pub heaps: Arc<HeapRegistry>,
    pub transport: Arc<OfiTransport>,
    pub metrics: Arc<Metrics>,
    /// The unified transfer-plan engine: every device-initiated path
    /// decision (RMA, signals, collectives) flows through here.
    pub xfer: XferEngine,
    /// Closed-loop cost-model calibration: consumes the proxy's per-(path,
    /// lane, size-class) wall-time observations and refines the learnable
    /// constants in `cost.model` (no-op while `calib.enable` is false).
    pub calib: Arc<Calibrator>,
    /// Fault-injection plane (ISSUE 8): scripted lane kill/revive events
    /// plus the calibrator's quarantine detector, all funneled through
    /// the cost model's health masks. Inert while `fault.enable` is off.
    pub fault: Arc<crate::sim::FaultPlane>,
    #[allow(dead_code)] // held so host-initiated paths can mint command lists
    pub(crate) driver: ZeDriver,
    /// One reverse-offload ring + completion pool per node.
    pub(crate) rings: Vec<Arc<Ring>>,
    pub(crate) completions: Vec<Arc<CompletionPool>>,
    pmi: Arc<PmiWorld>,
    proxies: Mutex<Vec<std::thread::JoinHandle<()>>>,
    shutdown: AtomicBool,
    /// User teams (ids ≥ 2); WORLD=0 and SHARED=1 are implicit.
    pub(crate) teams: RwLock<Vec<teams::TeamSpec>>,
    pub(crate) team_index: Mutex<HashMap<teams::TeamKey, usize>>,
    /// Published algorithm choices for in-flight hierarchical-capable
    /// collectives, keyed by (team id, per-team collective epoch). The
    /// team's lowest member decides (flat vs hier — the stage/sync
    /// structure differs, so every member MUST agree) and publishes with
    /// a waiter count; the entry retires when the last member reads it.
    pub(crate) coll_decisions: Mutex<HashMap<(usize, u64), (CollAlgo, usize)>>,
    /// AOT kernel runtime (PJRT); optional — reductions fall back to the
    /// native combine when absent.
    pub(crate) xla: RwLock<Option<Arc<XlaRuntime>>>,
}

impl Ishmem {
    pub fn new(config: IshmemConfig) -> anyhow::Result<Arc<Self>> {
        config.validate()?;
        let topo = config.topology.clone();
        let npes = topo.npes();
        let cost = CostModel::new(topo.clone(), config.cost.clone());
        let heaps = Arc::new(HeapRegistry::new(npes, config.heap_bytes));
        let transport = Arc::new({
            let mut t = OfiTransport::new(heaps.clone(), cost.clone());
            t.strict_hmem = config.strict_hmem;
            t
        });
        let driver = ZeDriver::new(heaps.clone(), cost.clone());
        let metrics = Metrics::new();
        let calib = Arc::new(Calibrator::new(cost.clone(), config.calib.clone()));
        // Fault-injection plane (ISSUE 8): scripted kill/revive events
        // tick on the proxy's op clock; the calibrator's detector applies
        // quarantine/probe transitions through the same plane. Disabled
        // (the default) it never ticks and the machine is bit-for-bit the
        // pre-fault build.
        let fault = crate::sim::FaultPlane::new(cost.clone(), config.fault.clone());
        calib.set_fault_plane(fault.clone());

        let mut rings = Vec::new();
        let mut completions = Vec::new();
        let mut proxies = Vec::new();
        for node in 0..topo.nodes {
            let ring = Ring::new(config.ring_capacity);
            let pool = Arc::new(CompletionPool::new(config.completion_slots));
            let consumer = ring.consumer();
            proxies.push(proxy::spawn_proxy(
                node,
                consumer,
                proxy::ProxyShared {
                    heaps: heaps.clone(),
                    transport: transport.clone(),
                    driver: driver.clone(),
                    completions: pool.clone(),
                    metrics: metrics.clone(),
                    use_immediate_cl: config.use_immediate_cl,
                    calib: calib.clone(),
                    fault: fault.clone(),
                    retry: config.retry,
                },
            ));
            rings.push(ring);
            completions.push(pool);
        }

        let mut xfer = XferEngine::new(
            cost.clone(),
            config.cutover.clone(),
            config.use_immediate_cl,
            metrics.clone(),
        );
        // Per-op command-list policy (§III-C): descriptors above this size
        // ask the proxy for standard lists; the planner's estimates use
        // the same boundary so decisions and charges agree. The value
        // seeds the shared ModelParams store — it is the third learned
        // quantity when calibration is on.
        xfer.set_cl_immediate_max_bytes(config.cl_immediate_max_bytes);
        // Striped chunk pipeline: the stripe planner's chunk cap is what
        // the staging slab can double-buffer, so modeled stripes and the
        // executor's slicing agree.
        xfer.chunk_max_bytes = config.chunk_max_bytes();
        // Plan cache: memoized structural plans, keyed per learned-params
        // generation (`plan_cache.enable = false` plans identically,
        // recomputing every shape).
        xfer.set_plan_cache(config.plan_cache.clone());
        // Adaptive-table persistence: pick up what a previous run learned
        // (missing file = cold start; a malformed table is an error — a
        // silently-ignored typo'd path would discard the learning).
        if config.cutover.mode == CutoverMode::Adaptive {
            if let Some(path) = &config.cutover.table_path {
                if std::path::Path::new(path).exists() {
                    xfer.adaptive_load(path)?;
                }
            }
        }

        Ok(Arc::new(Ishmem {
            pmi: PmiWorld::new(npes),
            xfer,
            calib,
            fault,
            cost,
            heaps,
            transport,
            metrics,
            driver,
            rings,
            completions,
            proxies: Mutex::new(proxies),
            shutdown: AtomicBool::new(false),
            teams: RwLock::new(Vec::new()),
            team_index: Mutex::new(HashMap::new()),
            coll_decisions: Mutex::new(HashMap::new()),
            xla: RwLock::new(None),
            config,
        }))
    }

    pub fn topo(&self) -> &Topology {
        &self.cost.topo
    }

    pub fn npes(&self) -> usize {
        self.topo().npes()
    }

    /// Attach the PJRT runtime so reductions run the AOT Pallas kernel.
    pub fn attach_runtime(&self, rt: Arc<XlaRuntime>) {
        *self.xla.write().unwrap() = Some(rt);
    }

    pub fn runtime(&self) -> Option<Arc<XlaRuntime>> {
        self.xla.read().unwrap().clone()
    }

    /// Run `f` SPMD on every PE (one thread each); returns per-PE results
    /// in PE order. Panics in any PE propagate after all threads unwind.
    pub fn launch<R, F>(self: &Arc<Self>, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(&mut PeCtx) -> R + Send + Sync,
    {
        let npes = self.npes();
        // Quiesce internal sync state between launches: team counters and
        // collect slots live in the reserved region and restart at zero.
        for pe in 0..npes {
            let zeros = vec![0u8; heap::RESERVED_BYTES];
            self.heaps.heap(pe).write(0, &zeros);
        }
        // Reset per-launch team registry (user teams don't outlive a job).
        self.teams.write().unwrap().clear();
        self.team_index.lock().unwrap().clear();
        // Algorithm-decision slots drain by construction (the last waiter
        // removes the entry), but a panicked launch may leak some.
        self.coll_decisions.lock().unwrap().clear();

        let results: Vec<Mutex<Option<R>>> = (0..npes).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for pe in 0..npes {
                let me = Arc::clone(self);
                let fref = &f;
                let slot = &results[pe];
                handles.push(s.spawn(move || {
                    let mut ctx = me.make_ctx(pe);
                    let r = fref(&mut ctx);
                    // Retire any batches the closure left pending or in
                    // flight and return any reserved engine-queue backlog:
                    // completion slots, slab claims and backlog live in
                    // shared machine state and must not leak into the
                    // next launch once this PE's context is dropped.
                    ctx.drain_outstanding();
                    *slot.lock().unwrap() = Some(r);
                }));
            }
            for h in handles {
                if let Err(e) = h.join() {
                    std::panic::resume_unwind(e);
                }
            }
        });
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("PE produced no result"))
            .collect()
    }

    /// `ishmem_init` for one PE: SOS dual-phase init (preinit → external
    /// heap create → postinit), NIC registration, IPC table build.
    fn make_ctx(self: &Arc<Self>, pe: usize) -> PeCtx {
        let pmi = self.pmi.handle(pe);
        let mut sos = SosHeaps::new(pmi, self.heaps.clone(), self.config.host_heap_bytes);
        sos.preinit_thread(ThreadLevel::Multiple)
            .expect("SOS preinit");
        sos.heap_create(ExternalHeapKind::Ze, pe, self.config.heap_bytes)
            .expect("external heap create");
        sos.postinit().expect("SOS postinit");
        self.transport.register_heap(pe);

        let ipc = IpcTable::build(pe, self.topo(), self.config.heap_bytes);
        // The top `staging_slab_bytes` of the heap belong to the batched
        // submission path; user allocations stop below the slab.
        let user_heap_bytes = self.config.heap_bytes - self.config.staging_slab_bytes;
        PeCtx {
            pe,
            rt: Arc::clone(self),
            clock: SimClock::new(),
            ipc,
            alloc: RefCell::new(SymAllocator::new(user_heap_bytes)),
            team_rounds: RefCell::new(vec![0u64; heap::MAX_TEAMS]),
            coll_epoch: RefCell::new(vec![0u64; heap::MAX_TEAMS]),
            track: CompletionTracker::new(),
            slab: StagingSlab::new(user_heap_bytes, self.config.staging_slab_bytes),
            stream: CmdStream::new(self.config.max_batch_depth)
                .with_large_flush_bytes(self.config.large_flush_bytes),
            team_seq: RefCell::new(HashMap::new()),
            sos: RefCell::new(sos),
        }
    }

    /// Stop proxy threads. Called by `Drop`; idempotent. An `Adaptive`
    /// machine with a `cutover.table_path` saves its learned table here,
    /// so the next run starts from the refined crossovers (best-effort:
    /// shutdown also runs from `Drop`, where failing is worse than
    /// warning).
    pub fn shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        if self.config.cutover.mode == CutoverMode::Adaptive {
            if let Some(path) = &self.config.cutover.table_path {
                if let Err(e) = self.xfer.adaptive_save(path) {
                    eprintln!("warning: {e:#}");
                }
            }
        }
        for ring in &self.rings {
            let mut m = Message::nop();
            m.op = RingOp::Shutdown as u8;
            ring.send(m);
        }
        for h in self.proxies.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Ishmem {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One processing element's context (owned by its PE thread; `!Sync`).
pub struct PeCtx {
    pe: usize,
    pub(crate) rt: Arc<Ishmem>,
    /// Modeled device timeline of this PE (µ-benchmark instrument).
    pub clock: SimClock,
    pub(crate) ipc: IpcTable,
    pub(crate) alloc: RefCell<SymAllocator>,
    /// Per-team sync round counters (push-barrier generations).
    pub(crate) team_rounds: RefCell<Vec<u64>>,
    /// Per-team collective epochs (mirrored across members — collectives
    /// are collective calls), keying the published algorithm decisions.
    pub(crate) coll_epoch: RefCell<Vec<u64>>,
    /// Unified blocking/NBI completion state (xfer "complete" stage):
    /// modeled nbi horizon + outstanding fire-and-forget proxy posts +
    /// reserved engine-queue backlog bytes.
    pub(crate) track: CompletionTracker,
    /// Staging slab: the runtime-owned top of this PE's device heap,
    /// holding batched payloads and descriptor blocks (`xfer::stream`).
    pub(crate) slab: StagingSlab,
    /// The per-initiator batched command stream: one `RingOp::Batch`
    /// doorbell per plan-group instead of one message per op.
    pub(crate) stream: CmdStream,
    /// Per-parent team-creation sequence numbers (mirrored across PEs).
    pub(crate) team_seq: RefCell<HashMap<usize, usize>>,
    #[allow(dead_code)] // held for the lifetime contract (finalize order)
    pub(crate) sos: RefCell<SosHeaps>,
}

impl PeCtx {
    /// `ishmem_my_pe`.
    pub fn pe(&self) -> usize {
        self.pe
    }

    /// `ishmem_n_pes`.
    pub fn npes(&self) -> usize {
        self.rt.npes()
    }

    pub fn topo(&self) -> &Topology {
        self.rt.topo()
    }

    pub(crate) fn node(&self) -> usize {
        self.rt.topo().node_of(self.pe)
    }

    pub(crate) fn ring(&self) -> &Arc<Ring> {
        &self.rt.rings[self.node()]
    }

    pub(crate) fn completions(&self) -> &Arc<CompletionPool> {
        &self.rt.completions[self.node()]
    }

    /// `ishmem_ptr` analogue: is `pe`'s heap reachable by direct
    /// load/store from this PE (IPC-mapped)? `false` means every access
    /// reverse-offloads through the proxy.
    pub fn pe_accessible(&self, pe: usize) -> bool {
        self.ipc.lookup(pe).is_some()
    }

    /// Chunks of striped non-blocking transfers whose single aggregated
    /// completion is still outstanding on this PE (drains to 0 at
    /// `quiet`) — the observability hook for the per-chunk→one-token
    /// aggregation in [`crate::xfer::track`].
    pub fn outstanding_chunk_count(&self) -> u64 {
        self.track.outstanding_chunks()
    }

    /// `ishmem_malloc` — collective symmetric allocation (synchronizing,
    /// like the spec requires: the buffer is usable by remote PEs on
    /// return).
    pub fn malloc<T: ShmemType>(&self, len: usize) -> SymAddr<T> {
        let addr = self.alloc.borrow_mut().alloc::<T>(len);
        self.barrier_all();
        addr
    }

    /// `ishmem_calloc` — also zero-fills the local instance.
    pub fn calloc<T: ShmemType>(&self, len: usize) -> SymAddr<T> {
        let addr = self.alloc.borrow_mut().alloc::<T>(len);
        let zeros = vec![0u8; addr.byte_len()];
        self.rt.heaps.heap(self.pe).write(addr.byte_offset(), &zeros);
        self.barrier_all();
        addr
    }

    /// Write the *local* instance of a symmetric object (host-style
    /// initialization; not a communication op).
    pub fn write_local<T: ShmemType>(&self, addr: SymAddr<T>, data: &[T]) {
        assert!(data.len() <= addr.len());
        self.rt
            .heaps
            .heap(self.pe)
            .write(addr.byte_offset(), types::as_bytes(data));
    }

    /// Read the *local* instance of a symmetric object.
    pub fn read_local<T: ShmemType>(&self, addr: SymAddr<T>, out: &mut [T]) {
        assert!(out.len() <= addr.len());
        self.rt
            .heaps
            .heap(self.pe)
            .read(addr.byte_offset(), types::as_bytes_mut(out));
    }

    /// Convenience: read the whole local instance into a Vec.
    pub fn read_local_vec<T: ShmemType + Default>(&self, addr: SymAddr<T>) -> Vec<T> {
        let mut v = vec![T::default(); addr.len()];
        self.read_local(addr, &mut v);
        v
    }
}
