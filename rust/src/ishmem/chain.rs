//! Triggered operation chains (ISSUE 10): the public face of fully
//! offloaded progress.
//!
//! A *chain* is a stream-ordered sequence of dependent operations —
//! put → signal, signal-gate → get, or an arbitrary put/signal/wait
//! ladder — submitted as ONE `Batch` doorbell. Descriptors carry stage
//! numbers (`BatchDescriptor::with_stage`); the proxy dispatches stage
//! *s+1* only after every stage-*s* entry completes, and holds
//! `WaitSignal` gates in its pending-trigger table until the watched
//! signal word reaches its target. Dependency progress therefore lives
//! entirely on the proxy: the initiator crosses the host boundary once
//! per chain instead of once per dependent step.
//!
//! With `chain.enable` off (the default) everything here degrades to
//! the chain-free program a caller would have written by hand —
//! bit-for-bit: [`PeCtx::put_then_signal`] is `put_signal`,
//! [`PeCtx::signal_then_get`] is `wait_until` + `get`, and
//! [`ChainBuilder`] executes each step eagerly as it is recorded.
//!
//! Fusion is priced, not assumed: the planner compares the one-doorbell
//! estimate against sequential submission under one parameter snapshot
//! (`XferEngine::chain_fuse_wins`) and chains that cannot fuse — too
//! deep, slab-starved, or model-priced slower — fall back and count
//! `chain_flushed_unfusable`.

use std::sync::atomic::Ordering;

use crate::coordinator::metrics::{Metrics, PathIdx};
use crate::ringbuf::message::AmoKind;
use crate::ringbuf::{BatchDescriptor, RingOp};
use crate::sim::topology::Locality;
use crate::xfer::plan::{ChainStage, OpKind};

use super::signal::SignalOp;
use super::sync::Cmp;
use super::types::{as_bytes, as_bytes_mut, ShmemType, TypeTag};
use super::{PeCtx, SymAddr};

impl PeCtx {
    /// `ishmemx_put_then_signal` — explicit chain spelling of
    /// [`PeCtx::put_signal`]: payload then signal word, ordered. With
    /// chains enabled this fuses into one triggered-chain doorbell; the
    /// alias exists so call sites written against the chain API survive
    /// a `chain.enable` flip in either direction.
    pub fn put_then_signal<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        sig: SymAddr<u64>,
        signal: u64,
        sig_op: SignalOp,
        pe: usize,
    ) {
        self.put_signal(dest, src, sig, signal, sig_op, pe);
    }

    /// `ishmemx_signal_then_get` — block until the **local** signal word
    /// `sig` reaches `target` (a producer's put-signal lands it), then
    /// get `dest.len()` elements from `src` on PE `pe`.
    ///
    /// With chains enabled the whole dependency offloads: a `WaitSignal`
    /// gate plus the get chunks ship as one doorbell and the *proxy*
    /// waits, re-checking parked gates between ring messages — the
    /// initiator pays one host crossing instead of a host-side spin plus
    /// a separately submitted get. Disabled (or unfusable), it is
    /// exactly `wait_until(sig, >=, target)` followed by `get`.
    pub fn signal_then_get<T: ShmemType>(
        &self,
        sig: SymAddr<u64>,
        target: u64,
        dest: &mut [T],
        src: SymAddr<T>,
        pe: usize,
    ) {
        assert!(dest.len() <= src.len(), "signal_then_get overflows source");
        assert!(pe < self.npes(), "PE {pe} out of range");
        let bytes = std::mem::size_of_val(dest);
        if bytes > 0 {
            let plan = self.plan_to(OpKind::Get, pe, bytes, 1);
            if self.exec_signal_get_chain(
                &plan,
                self.pe(),
                sig.byte_offset(),
                target,
                pe,
                src.byte_offset(),
                as_bytes_mut(dest),
            ) {
                Metrics::add(&self.rt.metrics.gets, 1);
                return;
            }
        }
        self.wait_until::<u64>(sig, Cmp::Ge, target);
        self.get(dest, src, pe);
    }

    /// Open a [`ChainBuilder`] recording a dependent-operation chain on
    /// this PE's stream.
    pub fn chain(&self) -> ChainBuilder<'_> {
        ChainBuilder {
            ctx: self,
            stage: 0,
            entries: Vec::new(),
            fused: self.rt.config.chain.enable,
            submitted: false,
        }
    }
}

/// One recorded (not yet stage-stamped) chain entry plus the shape the
/// pricing model needs.
struct ChainEntry {
    desc: BatchDescriptor,
    stage: u8,
    claims: usize,
    reachable: bool,
    loc: Locality,
    bytes: usize,
}

/// Builder for an arbitrary put → signal → dependent-op chain
/// ([`PeCtx::chain`]). Steps recorded in the same *stage* run
/// concurrently; [`ChainBuilder::then`] starts a new stage that the
/// proxy releases only after every earlier stage completes.
///
/// Two execution modes, chosen by `chain.enable`:
/// * **fused** — steps record stage-tagged descriptors (put payloads
///   stage into the slab eagerly so the source borrow can end);
///   [`ChainBuilder::submit`] prices the chain and ships it as one
///   doorbell, or flushes stage groups sequentially when fusion loses.
///   A step the chain cannot absorb (depth cap, slab pressure) submits
///   the recorded prefix as a chain and degrades the rest to eager
///   execution — ordering holds either way because the prefix
///   submission is blocking.
/// * **eager** — every step executes immediately through the ordinary
///   blocking API: the resulting machine history is bit-for-bit the
///   chain-free program.
///
/// Dropping a builder without calling [`ChainBuilder::submit`] discards
/// any recorded-but-unsubmitted steps (their slab claims are returned);
/// eagerly executed steps have already happened.
pub struct ChainBuilder<'a> {
    ctx: &'a PeCtx,
    stage: u8,
    entries: Vec<ChainEntry>,
    fused: bool,
    submitted: bool,
}

impl ChainBuilder<'_> {
    /// Start the next stage: steps recorded after this call depend on
    /// the completion of *every* step recorded before it.
    pub fn then(mut self) -> Self {
        self.stage = self.stage.saturating_add(1);
        self
    }

    /// Record a blocking put of `src` into PE `pe` at `dest` as a step
    /// of the current stage.
    pub fn put<T: ShmemType>(mut self, dest: SymAddr<T>, src: &[T], pe: usize) -> Self {
        assert!(src.len() <= dest.len(), "chain put overflows destination");
        assert!(pe < self.ctx.npes(), "PE {pe} out of range");
        let bytes = as_bytes(src);
        if self.fused && !bytes.is_empty() {
            if self.has_room() {
                if let Some(slab_off) = self.ctx.stream_stage_payload_uncharged(bytes) {
                    Metrics::add(&self.ctx.rt.metrics.puts, 1);
                    // Device-side staging copy is real work even before
                    // submission; the execution charge waits for submit.
                    self.ctx
                        .clock
                        .advance(self.ctx.rt.cost.staging_copy_ns(bytes.len()));
                    let desc =
                        BatchDescriptor::put(pe, dest.byte_offset(), slab_off, bytes.len())
                            .with_standard_cl(!self.ctx.rt.xfer.cl_immediate_for(bytes.len()));
                    self.push(desc, 1, pe, bytes.len());
                    return self;
                }
            }
            // Depth cap or slab pressure: run the prefix, go eager.
            self.degrade();
        }
        self.ctx.put(dest, src, pe);
        self
    }

    /// Record a signal-word update (`set`/`add`) on PE `pe` as a step of
    /// the current stage.
    pub fn signal(mut self, sig: SymAddr<u64>, value: u64, op: SignalOp, pe: usize) -> Self {
        assert!(pe < self.ctx.npes(), "PE {pe} out of range");
        if self.fused {
            if self.has_room() {
                Metrics::add(&self.ctx.rt.metrics.amos, 1);
                let kind = match op {
                    SignalOp::Set => AmoKind::Set,
                    SignalOp::Add => AmoKind::Add,
                };
                let desc = BatchDescriptor::amo(
                    pe,
                    sig.byte_offset(),
                    TypeTag::U64 as u8,
                    kind as u8,
                    value,
                    0,
                );
                self.push(desc, 0, pe, 8);
                return self;
            }
            self.degrade();
        }
        match op {
            SignalOp::Set => self.ctx.atomic_set::<u64>(sig, value, pe),
            SignalOp::Add => self.ctx.atomic_add::<u64>(sig, value, pe),
        }
        self
    }

    /// Record a gate: later steps of later stages wait until the signal
    /// word `sig` on PE `pe` reaches `target` (`>=`, the put-signal
    /// convention).
    pub fn wait_signal(mut self, sig: SymAddr<u64>, target: u64, pe: usize) -> Self {
        assert!(pe < self.ctx.npes(), "PE {pe} out of range");
        if self.fused {
            if self.has_room() {
                let desc = BatchDescriptor::wait_signal(pe, sig.byte_offset(), target);
                self.push(desc, 0, pe, 8);
                return self;
            }
            self.degrade();
        }
        self.eager_wait(sig, target, pe);
        self
    }

    /// Submit the chain. Fused chains that price ahead of sequential
    /// submission ship as one doorbell; otherwise each stage group
    /// flushes with its own doorbell (still stream-ordered, still
    /// correct — just unfused, counted in `chain_flushed_unfusable`).
    pub fn submit(mut self) {
        self.submitted = true;
        if self.entries.is_empty() {
            return; // pure-eager chain: everything already happened
        }
        let stages = self.stage_shapes();
        if self.ctx.rt.xfer.chain_fuse_wins(&stages) {
            self.post_fused(&stages);
        } else {
            Metrics::add(&self.ctx.rt.metrics.chain_flushed_unfusable, 1);
            self.flush_sequential(&stages);
        }
    }

    // ------------------------------------------------------ internals --

    /// Whether one more entry still fits under the chain depth cap.
    fn has_room(&self) -> bool {
        let cap = self
            .ctx
            .rt
            .config
            .chain
            .max_depth
            .min(self.ctx.stream.max_depth());
        self.entries.len() < cap
    }

    fn push(&mut self, desc: BatchDescriptor, claims: usize, pe: usize, bytes: usize) {
        self.entries.push(ChainEntry {
            desc,
            stage: self.stage,
            claims,
            reachable: self.ctx.ipc.lookup(pe).is_some(),
            loc: self.ctx.loc_of(pe),
            bytes,
        });
    }

    /// The chain stopped being fusable mid-build: ship the recorded
    /// prefix as a (blocking) chain so its effects land before the
    /// offending step, then record nothing further — every later step
    /// executes eagerly. Counted once per chain.
    fn degrade(&mut self) {
        self.fused = false;
        Metrics::add(&self.ctx.rt.metrics.chain_flushed_unfusable, 1);
        if !self.entries.is_empty() {
            let stages = self.stage_shapes();
            self.post_fused(&stages);
        }
    }

    /// Collapse the recorded entries into per-stage pricing shapes: a
    /// stage's bytes aggregate, its route pessimistically follows the
    /// least-reachable member, and its locality follows the largest
    /// member (the transfer that dominates the stage's execution).
    fn stage_shapes(&self) -> Vec<ChainStage> {
        let mut stages: Vec<ChainStage> = Vec::new();
        let mut last: Option<u8> = None;
        let mut max_b = 0usize;
        for e in &self.entries {
            if last == Some(e.stage) {
                let shape = stages.last_mut().expect("stage group open");
                shape.bytes += e.bytes;
                shape.reachable &= e.reachable;
                if e.bytes > max_b {
                    max_b = e.bytes;
                    shape.loc = e.loc;
                }
            } else {
                last = Some(e.stage);
                max_b = e.bytes;
                stages.push(ChainStage {
                    reachable: e.reachable,
                    loc: e.loc,
                    bytes: e.bytes,
                });
            }
        }
        stages
    }

    /// Ship the recorded entries as one stage-stamped doorbell and
    /// charge the fused-chain estimate.
    fn post_fused(&mut self, stages: &[ChainStage]) {
        let drained = std::mem::take(&mut self.entries);
        let mut entries: Vec<(BatchDescriptor, usize)> = Vec::with_capacity(drained.len());
        for e in drained {
            self.note_path_bytes(&e);
            entries.push((e.desc.with_stage(e.stage), e.claims));
        }
        self.ctx
            .track
            .note_chain_links(entries.len().saturating_sub(1) as u64);
        self.ctx.stream_post_chain(entries);
        self.ctx.clock.advance(self.ctx.rt.xfer.est_chain_ns(stages));
    }

    /// Unfused fallback: flush each stage group behind its own doorbell
    /// (blocking, so stage *s* completes before *s+1* posts) and charge
    /// the sequential estimate. Descriptors stay unstamped — the proxy
    /// sees ordinary all-stage-0 batches, exactly the pre-chain wire.
    fn flush_sequential(&mut self, stages: &[ChainStage]) {
        let drained = std::mem::take(&mut self.entries);
        let mut cur: Option<u8> = None;
        for e in drained {
            if cur.is_some() && cur != Some(e.stage) {
                self.ctx.stream_flush_blocking();
            }
            cur = Some(e.stage);
            self.note_path_bytes(&e);
            self.ctx.stream_append(e.desc, e.claims);
        }
        self.ctx.stream_flush_blocking();
        self.ctx
            .clock
            .advance(self.ctx.rt.xfer.est_chain_sequential_ns(stages));
    }

    /// Path accounting for a recorded put: the proxy routes it over the
    /// engines or the NIC by target reachability, exactly like dispatch.
    fn note_path_bytes(&self, e: &ChainEntry) {
        if e.desc.op == RingOp::Put as u8 {
            let (path, loc) = if e.reachable {
                (PathIdx::CopyEngine, e.loc)
            } else {
                (PathIdx::Nic, Locality::Remote)
            };
            self.ctx.rt.metrics.add_path_bytes(path, loc, e.bytes as u64);
        }
    }

    /// Host-side gate for eager/degraded chains: spin until the signal
    /// word on `pe` reaches `target`, charged like `wait_until`'s
    /// cache-resident poll.
    fn eager_wait(&self, sig: SymAddr<u64>, target: u64, pe: usize) {
        if pe == self.ctx.pe() {
            self.ctx.wait_until::<u64>(sig, Cmp::Ge, target);
            return;
        }
        let heap = self.ctx.rt.heaps.heap(pe);
        let word = heap.atomic_u64(sig.byte_offset());
        let mut spins = 0u64;
        while word.load(Ordering::Acquire) < target {
            spins += 1;
            if spins > 64 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        self.ctx
            .clock
            .advance(self.ctx.rt.cost.params.xe.atomic_fetch_ns * 0.2);
    }
}

impl Drop for ChainBuilder<'_> {
    fn drop(&mut self) {
        if !self.submitted {
            // Recorded-but-unsubmitted steps are discarded: return their
            // slab claims so the arena can rewind.
            for e in self.entries.drain(..) {
                for _ in 0..e.claims {
                    self.ctx.slab.release();
                }
            }
        }
    }
}
