//! Memory ordering: `ishmem_fence` / `ishmem_quiet` (OpenSHMEM §9.11).
//!
//! Batched submission makes ordering real work: proxied entries sit in
//! the pending command stream (and in in-flight batches) until a flush,
//! so `fence`/`quiet` must push the stream out and retire it. On top of
//! that, `quiet` (a) collapses the modeled nbi completion horizon into
//! the PE timeline, (b) releases this PE's reserved engine-queue backlog,
//! and (c) flushes the proxy pipeline when fire-and-forget messages
//! (scalar `p`, non-fetching remote AMOs) may still be in flight. The
//! outstanding state lives in the xfer completion tracker and the
//! command stream ([`crate::xfer::track`], [`crate::xfer::stream`]).

use crate::ringbuf::{Message, RingOp};
use crate::xfer::exec::PROXY_OK;

use super::PeCtx;

impl PeCtx {
    /// `ishmem_fence` — order prior puts before later puts (per-PE).
    /// Pending batched entries must be delivered before any later direct
    /// store can overtake them: drain the command stream, then charge the
    /// fence instruction.
    pub fn fence(&self) {
        if self.stream_quiet_drain() {
            self.clock.advance(self.rt.cost.ring_rtt_ns());
        }
        self.clock.advance(20.0);
    }

    /// `ishmem_quiet` — complete all outstanding operations by this PE.
    pub fn quiet(&self) {
        // (a) push out the pending plan-group, retire every batch in
        // flight (wall-clock wait on the batch completions; slab claims
        // return to the arena), and release this PE's reserved
        // engine-queue backlog.
        let drained_batches = self.drain_outstanding();

        // (b) modeled nbi horizon.
        let horizon = self.track.take_horizon_ns();
        let now = self.clock.now_ns();
        if horizon > now {
            self.clock.advance(horizon - now);
        }
        // One round trip proves the drained batches were serviced.
        if drained_batches {
            self.clock.advance(self.rt.cost.ring_rtt_ns());
        }

        // (c) drain the proxy: one Quiet round trip if anything was posted
        // fire-and-forget since the last quiet. The ring is FIFO per
        // consumer, so one completed Quiet proves all earlier messages of
        // this PE were serviced.
        if self.track.take_fire_and_forget() > 0 {
            let mut m = Message::nop();
            m.op = RingOp::Quiet as u8;
            let status = self.proxied_blocking(m);
            assert_eq!(status, PROXY_OK, "quiet proxy flush failed");
            self.clock.advance(self.rt.cost.ring_rtt_ns());
        }
    }
}
