//! Memory ordering: `ishmem_fence` / `ishmem_quiet` (OpenSHMEM §9.11).
//!
//! Our data movement is eager (see rma.rs), so the *correctness* side of
//! fence/quiet is trivially satisfied; what these calls do is (a) collapse
//! the modeled nbi completion horizon into the PE timeline, and (b) flush
//! the proxy pipeline when proxied fire-and-forget messages (scalar p,
//! non-fetching AMOs to remote PEs) may still be in flight. Both pieces of
//! outstanding state live in the xfer completion tracker
//! ([`crate::xfer::track::CompletionTracker`]) — the "complete" stage of
//! the unified plan→execute→complete flow.

use crate::ringbuf::{Message, RingOp};
use crate::xfer::exec::PROXY_OK;

use super::PeCtx;

impl PeCtx {
    /// `ishmem_fence` — order prior puts before later puts (per-PE).
    /// Eager movement already provides this; charge the instruction cost.
    pub fn fence(&self) {
        self.clock.advance(20.0);
    }

    /// `ishmem_quiet` — complete all outstanding operations by this PE.
    pub fn quiet(&self) {
        // (a) modeled nbi horizon.
        let horizon = self.track.take_horizon_ns();
        let now = self.clock.now_ns();
        if horizon > now {
            self.clock.advance(horizon - now);
        }

        // (b) drain the proxy: one Quiet round trip if anything was posted
        // fire-and-forget since the last quiet. The ring is FIFO per
        // consumer, so one completed Quiet proves all earlier messages of
        // this PE were serviced.
        if self.track.take_fire_and_forget() > 0 {
            let mut m = Message::nop();
            m.op = RingOp::Quiet as u8;
            let status = self.proxied_blocking(m);
            assert_eq!(status, PROXY_OK, "quiet proxy flush failed");
            self.clock.advance(self.rt.cost.ring_rtt_ns());
        }
    }
}
