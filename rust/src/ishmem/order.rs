//! Memory ordering: `ishmem_fence` / `ishmem_quiet` (OpenSHMEM §9.11).
//!
//! Our data movement is eager (see rma.rs), so the *correctness* side of
//! fence/quiet is trivially satisfied; what these calls do is (a) collapse
//! the modeled nbi completion horizon into the PE timeline, and (b) flush
//! the proxy pipeline when proxied fire-and-forget messages (scalar p,
//! non-fetching AMOs to remote PEs) may still be in flight.

use crate::ringbuf::{Message, RingOp};

use super::rma::PROXY_OK;
use super::PeCtx;

impl PeCtx {
    /// `ishmem_fence` — order prior puts before later puts (per-PE).
    /// Eager movement already provides this; charge the instruction cost.
    pub fn fence(&self) {
        self.clock.advance(20.0);
    }

    /// `ishmem_quiet` — complete all outstanding operations by this PE.
    pub fn quiet(&self) {
        // (a) modeled nbi horizon.
        let horizon = self.nbi_horizon_ns.get();
        let now = self.clock.now_ns();
        if horizon > now {
            self.clock.advance(horizon - now);
        }
        self.nbi_horizon_ns.set(0.0);

        // (b) drain the proxy: one Quiet round trip if anything was posted
        // fire-and-forget since the last quiet. The ring is FIFO per
        // consumer, so one completed Quiet proves all earlier messages of
        // this PE were serviced.
        if self.outstanding_proxy_nbi.replace(0) > 0 {
            let mut m = Message::nop();
            m.op = RingOp::Quiet as u8;
            let status = self.proxied_blocking(m);
            assert_eq!(status, PROXY_OK, "quiet proxy flush failed");
            self.clock.advance(self.rt.cost.ring_rtt_ns());
        }
    }

    /// Track a fire-and-forget proxy post (internal; makes quiet() flush).
    pub(crate) fn note_proxy_ff(&self) {
        self.outstanding_proxy_nbi
            .set(self.outstanding_proxy_nbi.get() + 1);
    }
}
