//! The host proxy thread (paper Fig 2 circle 3, §III-C/D).
//!
//! One proxy per node services that node's reverse-offload ring: it pops
//! 64-byte messages, executes them — Level-Zero copy engines for
//! intra-node transfers, the OFI transport for inter-node, heap atomics
//! for AMOs — and posts replies into the completion pool. A single
//! host thread sustains the whole node (the paper: >20 M req/s with one
//! CPU-side thread), so correctness never depends on proxy parallelism.

use std::sync::Arc;

use crate::coordinator::metrics::Metrics;
use crate::ringbuf::{CompletionPool, Message, RingConsumer, RingOp, COMPLETION_NONE};
use crate::sim::{HeapRegistry, SimClock};
use crate::sos::transport::OfiTransport;
use crate::xfer::exec::{FLAG_RAW_PTR, PROXY_ERR_UNREGISTERED, PROXY_OK};
use crate::ze::cmdlist::{CommandQueue, DeviceAddr};
use crate::ze::ZeDriver;

use super::amo::atomic_rmw_bits;
use super::types::TypeTag;

pub(crate) struct ProxyShared {
    pub heaps: Arc<HeapRegistry>,
    pub transport: Arc<OfiTransport>,
    pub driver: ZeDriver,
    pub completions: Arc<CompletionPool>,
    pub metrics: Arc<Metrics>,
    /// §III-C: immediate command lists (low-latency append-executes) vs
    /// standard lists (batched append → close → execute on a queue).
    pub use_immediate_cl: bool,
}

/// Dispatch one intra-node engine copy on the configured command-list
/// flavour (the `use_immediate_cl` knob, paper §III-C). Serves
/// heap-offset (non-raw) Put/Get messages; today every device-initiated
/// RMA ships the raw-pointer shape instead (see `xfer::exec`), which
/// takes the staged-write branch + `raw_engine_charge` below.
fn engine_copy(sh: &ProxyShared, src_pe: usize, dst: DeviceAddr, src: DeviceAddr, len: usize, clock: &SimClock) {
    if sh.use_immediate_cl {
        let icl = sh.driver.create_immediate_command_list(src_pe);
        icl.append_memory_copy(dst, src, len, None, clock);
    } else {
        let mut cl = sh.driver.create_command_list(src_pe);
        cl.append_memory_copy(dst, src, len, None);
        cl.close();
        cl.execute(&CommandQueue::default(), clock);
    }
}

/// Raw-pointer transfers (private initiator buffer → peer heap) can't go
/// through a `DeviceAddr` command list; the bytes are staged directly, but
/// the copy still runs on the initiator GPU's engines: charge the engine
/// time on the configured command-list flavour so the immediate-vs-
/// standard startup difference stays honest (§III-C). Pure transfer time
/// only — the *initiator* registers this transfer's EngineQueue occupancy
/// when it charges its own modeled wait, so registering here too would
/// double-count one logical transfer against the queue.
fn raw_engine_charge(sh: &ProxyShared, src_pe: usize, dst_pe: usize, len: usize, clock: &SimClock) {
    let cost = &sh.driver.cost;
    let loc = cost.locality(src_pe, dst_pe);
    clock.advance(
        cost.params
            .ce
            .transfer_ns(&cost.params.xe, loc, len, sh.use_immediate_cl, false),
    );
}

pub(crate) fn spawn_proxy(
    node: usize,
    mut consumer: RingConsumer,
    shared: ProxyShared,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("ishmem-proxy-{node}"))
        .spawn(move || proxy_loop(&mut consumer, &shared))
        .expect("spawn proxy")
}

fn proxy_loop(consumer: &mut RingConsumer, sh: &ProxyShared) {
    // Engine dispatches are timed on a proxy-local clock; the *initiator*
    // charges its own modeled wait (ring RTT + engine time), this clock
    // only keeps the EngineQueue occupancy honest.
    let proxy_clock = SimClock::new();
    loop {
        let msg = consumer.recv();
        match msg.ring_op() {
            Some(RingOp::Shutdown) => return,
            Some(op) => service(op, &msg, sh, &proxy_clock),
            None => panic!("proxy received malformed message op={}", msg.op),
        }
    }
}

fn complete(sh: &ProxyShared, msg: &Message, value: u64) {
    if msg.completion != COMPLETION_NONE {
        sh.completions.complete(msg.completion, value);
        Metrics::add(&sh.metrics.ring_completions, 1);
    }
}

fn is_local(sh: &ProxyShared, a: usize, b: usize) -> bool {
    sh.driver.cost.topo.node_of(a) == sh.driver.cost.topo.node_of(b)
}

fn service(op: RingOp, msg: &Message, sh: &ProxyShared, proxy_clock: &SimClock) {
    let pe = msg.pe as usize;
    let src_pe = msg.src_pe as usize;
    let len = msg.len as usize;
    let raw = msg.flags & FLAG_RAW_PTR != 0;

    match op {
        RingOp::Nop => complete(sh, msg, PROXY_OK),

        RingOp::Put => {
            if is_local(sh, src_pe, pe) {
                // Intra-node: copy-engine path via L0 immediate CL.
                if raw {
                    // Private-source put: stage straight into the peer heap
                    // (the engine reads mapped device memory either way).
                    // SAFETY: blocking initiator keeps the pointer alive.
                    let src =
                        unsafe { std::slice::from_raw_parts(msg.src_off as *const u8, len) };
                    sh.heaps.heap(pe).write(msg.dst_off as usize, src);
                    raw_engine_charge(sh, src_pe, pe, len, proxy_clock);
                } else {
                    engine_copy(
                        sh,
                        src_pe,
                        DeviceAddr { pe, offset: msg.dst_off as usize },
                        DeviceAddr { pe: src_pe, offset: msg.src_off as usize },
                        len,
                        proxy_clock,
                    );
                }
                complete(sh, msg, PROXY_OK);
            } else {
                let dummy = SimClock::new();
                let r = if raw {
                    sh.transport
                        .put_from_ptr(msg.src_off, pe, msg.dst_off as usize, len, &dummy)
                } else {
                    sh.transport.put(
                        src_pe,
                        msg.src_off as usize,
                        pe,
                        msg.dst_off as usize,
                        len,
                        &dummy,
                    )
                };
                complete(
                    sh,
                    msg,
                    if r.is_ok() { PROXY_OK } else { PROXY_ERR_UNREGISTERED },
                );
            }
        }

        RingOp::Get => {
            if is_local(sh, src_pe, pe) {
                if raw {
                    // SAFETY: blocking initiator keeps the pointer alive.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(msg.dst_off as *mut u8, len)
                    };
                    sh.heaps.heap(pe).read(msg.src_off as usize, dst);
                    raw_engine_charge(sh, src_pe, pe, len, proxy_clock);
                } else {
                    engine_copy(
                        sh,
                        src_pe,
                        DeviceAddr { pe: src_pe, offset: msg.dst_off as usize },
                        DeviceAddr { pe, offset: msg.src_off as usize },
                        len,
                        proxy_clock,
                    );
                }
                complete(sh, msg, PROXY_OK);
            } else {
                let dummy = SimClock::new();
                let r = if raw {
                    sh.transport
                        .get_to_ptr(pe, msg.src_off as usize, msg.dst_off, len, &dummy)
                } else {
                    sh.transport.get(
                        pe,
                        msg.src_off as usize,
                        src_pe,
                        msg.dst_off as usize,
                        len,
                        &dummy,
                    )
                };
                complete(
                    sh,
                    msg,
                    if r.is_ok() { PROXY_OK } else { PROXY_ERR_UNREGISTERED },
                );
            }
        }

        RingOp::PutInline => {
            let bytes = msg.inline_val.to_le_bytes();
            sh.heaps
                .heap(pe)
                .write(msg.dst_off as usize, &bytes[..len]);
            complete(sh, msg, PROXY_OK);
        }

        RingOp::Amo => {
            let tag = TypeTag::from_u8(msg.dtype).expect("bad AMO dtype");
            let kind = msg.amo_kind().expect("bad AMO kind");
            let old = atomic_rmw_bits(
                sh.heaps.heap(pe),
                msg.dst_off as usize,
                tag,
                kind,
                msg.inline_val,
                msg.inline_val2,
            );
            complete(sh, msg, old);
        }

        RingOp::PutSignal => {
            // Payload …
            // SAFETY: blocking initiator keeps the pointer alive.
            let src = unsafe { std::slice::from_raw_parts(msg.src_off as *const u8, len) };
            let dummy = SimClock::new();
            let ok = if is_local(sh, src_pe, pe) {
                sh.heaps.heap(pe).write(msg.dst_off as usize, src);
                true
            } else {
                sh.transport
                    .put_from_ptr(msg.src_off, pe, msg.dst_off as usize, len, &dummy)
                    .is_ok()
            };
            if !ok {
                complete(sh, msg, PROXY_ERR_UNREGISTERED);
                return;
            }
            // … then the signal (flags bit 0: 1 = add, 0 = set).
            let kind = if msg.flags & 1 != 0 {
                crate::ringbuf::message::AmoKind::Add
            } else {
                crate::ringbuf::message::AmoKind::Set
            };
            atomic_rmw_bits(
                sh.heaps.heap(pe),
                msg.inline_val2 as usize,
                TypeTag::U64,
                kind,
                msg.inline_val,
                0,
            );
            complete(sh, msg, PROXY_OK);
        }

        RingOp::Quiet | RingOp::Barrier => {
            // Ring FIFO order means every prior message of every PE on this
            // node is already serviced when we get here.
            complete(sh, msg, PROXY_OK);
        }

        RingOp::Shutdown => unreachable!("handled by caller"),
    }
}
