//! The host proxy thread (paper Fig 2 circle 3, §III-C/D).
//!
//! One proxy per node services that node's reverse-offload ring: it pops
//! 64-byte messages, executes them — Level-Zero copy engines for
//! intra-node transfers, the OFI transport for inter-node, heap atomics
//! for AMOs — and posts replies into the completion pool. A single
//! host thread sustains the whole node (the paper: >20 M req/s with one
//! CPU-side thread), so correctness never depends on proxy parallelism.
//!
//! `RingOp::Batch` is the batched-submission doorbell: the proxy reads a
//! descriptor block out of the initiator's staging slab and dispatches
//! each entry under its own command-list policy (§III-C) — immediate
//! lists for latency-critical entries, and one *staged standard command
//! list per engine per batch* (append → close → execute) for the rest:
//! striped chunks carry an engine hint assigned initiator-side from the
//! least-loaded engine queues, and the proxy round-robins them onto the
//! matching per-engine lists so a large transfer's chunks genuinely run
//! on different blitters. Because batched payloads are staged into the
//! symmetric heap, every batched entry is heap-offset shaped and runs on
//! real `DeviceAddr` command lists; the raw-pointer staging branch below
//! survives only for payloads whose single chunk cannot fit an empty
//! slab.
//!
//! **Triggered chains** (ISSUE 10, fully offloaded progress): a batch may
//! carry stage-stamped descriptors (`DESC_FLAG_TRIGGERED`; see
//! `BatchDescriptor::with_stage`). The proxy dispatches such a batch
//! *stage by stage*: each stage's staged lists/rail sequences execute at
//! the stage boundary — that execution IS the predecessor-completion
//! event the next stage dispatches on, with no additional ring message.
//! `RingOp::WaitSignal` entries are pure gates: the chain suffix
//! dispatches only once the target signal word reaches its value;
//! an unmet gate *parks* the suffix in the proxy's pending-trigger
//! table, re-checked between ring messages (the proxy switches to a
//! non-blocking poll while anything is parked). A NACKed predecessor
//! stage mask-NACKs every later triggered entry un-dispatched — a
//! successor never fires early — and the initiator's replay loop
//! re-submits the failed suffix in stage order. A batch with no
//! triggered descriptors is one implicit stage-0 group, dispatched
//! bit-for-bit like the pre-chain code.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::metrics::{Metrics, PathIdx, ServiceOp};
use crate::ringbuf::{
    BatchDescriptor, CompletionPool, Message, RingConsumer, RingOp, COMPLETION_NONE, DESC_SIZE,
};
use crate::ringbuf::payload_checksum;
use crate::sim::fault::LaneRef;
use crate::sim::{FaultAction, FaultPlane, HeapRegistry, SimClock, TransientKind};
use crate::sos::transport::OfiTransport;
use crate::xfer::exec::{FLAG_RAW_PTR, PROXY_ERR_UNREGISTERED, PROXY_OK};
use crate::xfer::stream::encode_nack;
use crate::ze::cmdlist::{CommandList, CommandQueue, DeviceAddr};
use crate::ze::ZeDriver;

use super::amo::atomic_rmw_bits;
use super::types::TypeTag;

pub(crate) struct ProxyShared {
    pub heaps: Arc<HeapRegistry>,
    pub transport: Arc<OfiTransport>,
    pub driver: ZeDriver,
    pub completions: Arc<CompletionPool>,
    pub metrics: Arc<Metrics>,
    /// §III-C: immediate command lists (low-latency append-executes) vs
    /// standard lists (batched append → close → execute on a queue).
    /// Batched descriptors carry their own per-op choice; this global
    /// knob governs the raw-pointer fallback path and acts as the enable
    /// bit for immediate lists.
    pub use_immediate_cl: bool,
    /// Closed-loop calibration sink: every serviced data entry is tagged
    /// with its lane (engine slot / NIC rail) and observed wall-clock ns
    /// and fed here (no-op while `calib.enable` is off).
    pub calib: Arc<crate::xfer::Calibrator>,
    /// Fault-injection plane (ISSUE 8): the proxy ticks it once per
    /// serviced descriptor so scripted kill/revive events fire at their
    /// op counts, and re-dispatches in-flight chunks bound for lanes
    /// that died. A disabled plane (`fault.enable = false`, the default)
    /// never ticks and never re-routes.
    pub fault: Arc<FaultPlane>,
    /// Reliability knobs (ISSUE 9): checksum verification fires only when
    /// the initiator stamped a checksum, but the strike-escalation
    /// threshold lives here so the proxy can quarantine repeat offenders.
    pub retry: crate::ishmem::RetryConfig,
}

/// Advance the fault plane's op clock by one serviced descriptor and
/// count any scripted transitions it fired into the metrics (an empty
/// vec — the disabled fast path — costs nothing). Returns the op number
/// this descriptor was serviced as (0 while the plane is disabled), which
/// keys the transient-event windows.
fn tick_fault(sh: &ProxyShared) -> u64 {
    let (op_no, actions) = sh.fault.tick_counted();
    for a in actions {
        sh.metrics.count_fault_action(a, sh.fault.cost().degraded());
    }
    op_no
}

/// Count a health transition the calibrator's detector applied: the
/// quarantine/probe tallies plus the shared kill/revive counters and
/// per-lane gauges.
fn count_detector_action(sh: &ProxyShared, a: FaultAction) {
    match a {
        FaultAction::KillRail { .. } | FaultAction::KillEngine { .. } => {
            Metrics::add(&sh.metrics.fault_quarantines, 1)
        }
        FaultAction::ReviveRail { .. } | FaultAction::ReviveEngine { .. } => {
            Metrics::add(&sh.metrics.fault_probes, 1)
        }
    }
    sh.metrics.count_fault_action(a, sh.fault.cost().degraded());
}

/// Note one reliability strike against `lane` and, once
/// `retry.escalate_strikes` *consecutive* strikes accumulate (0 = never),
/// hand the repeat offender to the quarantine machinery: rails go through
/// the calibrator's detector state so probation revival applies; engines
/// are killed on the fault plane directly. The ledger resets on
/// escalation and on any clean dispatch.
fn strike_and_maybe_escalate(sh: &ProxyShared, lane: LaneRef) {
    let count = sh.fault.note_strike(lane);
    let limit = sh.retry.escalate_strikes;
    if limit == 0 || count < limit {
        return;
    }
    sh.fault.clear_strikes(lane);
    let action = match lane {
        LaneRef::Rail { node, rail } => sh.calib.escalate_rail(node, rail),
        LaneRef::Engine { gpu, engine } => {
            sh.fault.apply(FaultAction::KillEngine { gpu, engine })
        }
    };
    if let Some(a) = action {
        Metrics::add(&sh.metrics.retry_escalations, 1);
        count_detector_action(sh, a);
    }
}

/// Dispatch one intra-node engine copy on the requested command-list
/// flavour (per-op CL policy, paper §III-C).
fn engine_copy(
    sh: &ProxyShared,
    src_pe: usize,
    dst: DeviceAddr,
    src: DeviceAddr,
    len: usize,
    immediate: bool,
    clock: &SimClock,
) {
    if immediate {
        let icl = sh.driver.create_immediate_command_list(src_pe);
        icl.append_memory_copy(dst, src, len, None, clock);
    } else {
        let mut cl = sh.driver.create_command_list(src_pe);
        cl.append_memory_copy(dst, src, len, None);
        cl.close();
        cl.execute(&CommandQueue::default(), clock);
    }
}

/// Raw-pointer transfers (private initiator buffer → peer heap) can't go
/// through a `DeviceAddr` command list; the bytes are staged directly, but
/// the copy still runs on the initiator GPU's engines: charge the engine
/// time on the configured command-list flavour so the immediate-vs-
/// standard startup difference stays honest (§III-C). Pure transfer time
/// only — the *initiator* registers this transfer's EngineQueue occupancy
/// when it charges its own modeled wait, so registering here too would
/// double-count one logical transfer against the queue.
fn raw_engine_charge(sh: &ProxyShared, src_pe: usize, dst_pe: usize, len: usize, clock: &SimClock) {
    let cost = &sh.driver.cost;
    let loc = cost.locality(src_pe, dst_pe);
    clock.advance(
        cost.ce_eff()
            .transfer_ns(&cost.params.xe, loc, len, sh.use_immediate_cl, false),
    );
}

pub(crate) fn spawn_proxy(
    node: usize,
    mut consumer: RingConsumer,
    shared: ProxyShared,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("ishmem-proxy-{node}"))
        .spawn(move || proxy_loop(&mut consumer, &shared))
        .expect("spawn proxy")
}

/// Service-time family of a top-level ring op.
fn service_family(op: RingOp) -> ServiceOp {
    match op {
        RingOp::Put | RingOp::PutInline | RingOp::PutSignal => ServiceOp::Put,
        RingOp::Get => ServiceOp::Get,
        RingOp::Amo => ServiceOp::Amo,
        _ => ServiceOp::Other,
    }
}

fn proxy_loop(consumer: &mut RingConsumer, sh: &ProxyShared) {
    // Engine dispatches are timed on a proxy-local clock; the *initiator*
    // charges its own modeled wait (ring RTT + engine time), this clock
    // only keeps the EngineQueue occupancy honest.
    let proxy_clock = SimClock::new();
    // Pending-trigger table: chain suffixes parked on unmet `WaitSignal`
    // gates. While anything is parked the loop polls instead of blocking,
    // re-evaluating gates between messages — another PE's op on this ring
    // (or remote traffic landing in this node's heap) may satisfy them.
    // Empty table → blocking `recv()`, the bit-for-bit pre-chain path.
    let mut parked: Vec<ParkedChain> = Vec::new();
    let mut spins = 0u32;
    loop {
        let msg = if parked.is_empty() {
            consumer.recv()
        } else {
            match consumer.try_recv() {
                Some(m) => m,
                None => {
                    for p in std::mem::take(&mut parked) {
                        if let Some(still) = resume_parked(p, sh, &proxy_clock) {
                            parked.push(still);
                        }
                    }
                    spins += 1;
                    if spins < 128 {
                        std::hint::spin_loop();
                    } else {
                        std::thread::yield_now();
                    }
                    continue;
                }
            }
        };
        spins = 0;
        match msg.ring_op() {
            Some(RingOp::Shutdown) => {
                // Fail-complete still-parked chains so no initiator blocks
                // forever on a gate that can no longer fire.
                for p in parked.drain(..) {
                    complete(sh, &p.msg, PROXY_ERR_UNREGISTERED);
                }
                return;
            }
            // Batches record per-entry service times inside the arm; a
            // batch returning a parked chain joins the trigger table.
            Some(RingOp::Batch) => {
                if let Some(p) = service_batch(&msg, sh, &proxy_clock) {
                    parked.push(p);
                }
            }
            Some(op) => {
                tick_fault(sh);
                let t0 = Instant::now();
                service(op, &msg, sh, &proxy_clock);
                let elapsed = t0.elapsed().as_nanos() as u64;
                sh.metrics.add_service(service_family(op), elapsed);
                // Wall half of the service-delta tables (data ops only),
                // and the same observation feeds the calibrator.
                if matches!(op, RingOp::Put | RingOp::Get) {
                    let (src, dst) = (msg.src_pe as usize, msg.pe as usize);
                    if is_local(sh, src, dst) {
                        sh.metrics.add_service_wall(PathIdx::CopyEngine, msg.len, elapsed);
                        sh.calib.observe_engine(
                            sh.driver.cost.locality(src, dst),
                            msg.len as usize,
                            sh.use_immediate_cl,
                            elapsed as f64,
                        );
                    } else {
                        sh.metrics.add_service_wall(PathIdx::Nic, msg.len, elapsed);
                        // Un-batched remote ops carry no rail hint: they
                        // inject on rail 0 (the un-chunked default).
                        let node = sh.driver.cost.topo.node_of(src);
                        if let Some(a) =
                            sh.calib.observe_rail(node, 0, msg.len as usize, elapsed as f64)
                        {
                            count_detector_action(sh, a);
                        }
                    }
                }
            }
            None => panic!("proxy received malformed message op={}", msg.op),
        }
        // The serviced message may have satisfied a parked gate (e.g. it
        // wrote the very signal word a chain waits on): re-check now so
        // chain latency tracks the triggering op, not the poll backoff.
        if !parked.is_empty() {
            for p in std::mem::take(&mut parked) {
                if let Some(still) = resume_parked(p, sh, &proxy_clock) {
                    parked.push(still);
                }
            }
        }
    }
}

fn complete(sh: &ProxyShared, msg: &Message, value: u64) {
    if msg.completion != COMPLETION_NONE {
        sh.completions.complete(msg.completion, value);
        Metrics::add(&sh.metrics.ring_completions, 1);
    }
}

fn is_local(sh: &ProxyShared, a: usize, b: usize) -> bool {
    sh.driver.cost.topo.node_of(a) == sh.driver.cost.topo.node_of(b)
}

// --------------------------------------------------- batch service loop ---

/// The lanes one batch entry actually runs on (normally the
/// initiator-assigned hints).
#[derive(Clone, Copy)]
struct EntryLanes {
    engine: usize,
    rail: usize,
}

/// One tracker-reservation migration performed for a dead-lane
/// re-dispatch, undone after the batch's lists execute (see
/// [`effective_lanes`]).
enum LaneMove {
    Engine { gpu: usize, from: usize, to: usize, bytes: u64 },
    Rail { node: usize, from: usize, to: usize, bytes: u64 },
}

/// Resolve the lanes one batch entry will run on: the initiator-assigned
/// hints — unless the hinted lane died after the initiator placed the
/// chunk. Then the least-loaded *live* lane takes over and the chunk's
/// tracker reservation migrates with it (recorded in `moved`, counted as
/// a re-dispatch). The initiator releases its reservation against the
/// original hint at completion time, so `service_batch` migrates the
/// bytes back once the lists have executed — the backlog sits on the
/// live lane exactly while the chunk is in flight. With *every* lane
/// dead there is nothing to migrate to: the hint stands (estimates stay
/// sane via the lane-exclusion floor of 1) and the degenerate case is
/// counted as a last-lane fallback instead.
fn effective_lanes(
    sh: &ProxyShared,
    src_pe: usize,
    d: &BatchDescriptor,
    op: RingOp,
    moved: &mut Vec<LaneMove>,
) -> EntryLanes {
    let mut lanes = EntryLanes { engine: d.engine_hint(), rail: d.rail_hint() };
    let cost = &sh.driver.cost;
    if !matches!(op, RingOp::Put | RingOp::Get) || !cost.degraded() {
        return lanes;
    }
    let bytes = d.len as u64;
    if is_local(sh, src_pe, d.pe as usize) {
        let gpu = cost.topo.global_gpu_of(src_pe);
        if !cost.engine_is_live(gpu, lanes.engine) {
            if cost.engine_live_count(gpu) == 0 {
                Metrics::add(&sh.metrics.fault_last_lane_fallbacks, 1);
            } else if let Some(&to) = cost.engine_pick(gpu, 1).first() {
                cost.engine_migrate(gpu, lanes.engine, to, bytes);
                moved.push(LaneMove::Engine { gpu, from: lanes.engine, to, bytes });
                Metrics::add(&sh.metrics.fault_redispatched_chunks, 1);
                lanes.engine = to;
            }
        }
    } else {
        let node = cost.topo.node_of(src_pe);
        if !cost.rail_is_live(node, lanes.rail) {
            if cost.rail_live_count(node) == 0 {
                Metrics::add(&sh.metrics.fault_last_lane_fallbacks, 1);
            } else if let Some(&to) = cost.rail_pick(node, 1).first() {
                cost.rail_migrate(node, lanes.rail, to, bytes);
                moved.push(LaneMove::Rail { node, from: lanes.rail, to, bytes });
                Metrics::add(&sh.metrics.fault_redispatched_chunks, 1);
                lanes.rail = to;
            }
        }
    }
    lanes
}

/// Calibration bookkeeping for the staged standard lists: the per-entry
/// wall time of a standard-CL entry measures only the append, so the
/// lane observation happens at execute time instead — per engine, over
/// the bytes that list accumulated — while the append wall times are
/// summed so the CL-*flavor* comparison can charge standard lists their
/// full cost (append + execute), not the engine time alone. The
/// locality and entry size of the list's first entry stand in for the
/// whole list (chunked transfers target one peer with uniform chunks,
/// so lists are homogeneous in practice).
struct StagedMeta {
    bytes: u64,
    entries: u64,
    loc: crate::sim::topology::Locality,
    append_ns: u64,
    first_len: usize,
}

/// A chain suffix parked on an unmet `WaitSignal` gate: every entry
/// before `next` has fully dispatched *and executed* (the gate arm runs
/// `execute_stage` before reading the signal word), so no scratch state
/// survives the park — only the remaining descriptors and the carried
/// NACK/status ledger, whose mask bits keep their original entry indices
/// so replay masks line up across park/resume.
struct ParkedChain {
    msg: Message,
    descs: Vec<BatchDescriptor>,
    next: usize,
    nack_mask: u64,
    status: u64,
    nacked_stage: Option<u8>,
}

/// Execute everything the current stage accumulated: per-engine staged
/// lists (close → execute, each on its own scratch clock — different
/// blitters run concurrently, so the proxy clock advances by the slowest,
/// not the sum), per-rail in-flight sequences (same max fold), and the
/// migrate-back of any dead-lane re-dispatches now that the lists have
/// run. For a chained batch this execution *is* the predecessor-completion
/// event the next stage dispatches on; for an all-stage-0 batch the one
/// call after the scan is exactly the pre-chain end-of-batch block.
fn execute_stage(
    sh: &ProxyShared,
    proxy_clock: &SimClock,
    staged_cls: &mut BTreeMap<usize, CommandList>,
    rail_clocks: &mut BTreeMap<usize, SimClock>,
    staged_meta: &mut BTreeMap<usize, StagedMeta>,
    tainted_engines: &mut std::collections::BTreeSet<usize>,
    moved: &mut Vec<LaneMove>,
) {
    let mut slowest = 0.0f64;
    for (engine, mut cl) in std::mem::take(staged_cls) {
        let t0 = Instant::now();
        cl.close();
        let scratch = SimClock::new();
        cl.execute(&CommandQueue::default(), &scratch);
        slowest = slowest.max(scratch.now_ns());
        let elapsed = t0.elapsed().as_nanos() as u64;
        sh.metrics.add_service(ServiceOp::Other, elapsed);
        // Standard-CL lane observation: the list executes its N appended
        // commands back-to-back on one engine and the engine model charges
        // a startup *per command*, so the honest width-1 sample is the
        // per-entry mean (T/N ≈ startup + (bytes/N)/lane_bw) — feeding the
        // whole list as one chunk would inflate the learned startup by ~N×
        // in small classes and drag the learned fraction low in large
        // ones. The CL-flavor comparison charges the full service cost
        // (appends + execute) per byte, bucketed at the per-entry size the
        // boundary decision is about.
        if let Some(m) = staged_meta.get(&engine) {
            // A list that carried any replayed or delayed entry yields a
            // mixed-attempt wall time: discard it (satellite 1).
            if !tainted_engines.contains(&engine) {
                let n = m.entries.max(1);
                sh.calib.observe_engine(
                    m.loc,
                    (m.bytes / n).max(1) as usize,
                    false,
                    elapsed as f64 / n as f64,
                );
                sh.calib.observe_cl_flavor(
                    m.first_len,
                    false,
                    (m.append_ns + elapsed) as f64 / m.bytes.max(1) as f64,
                );
            }
        }
    }
    // Likewise the per-rail sequences inject on different NICs.
    for (_rail, clock) in std::mem::take(rail_clocks) {
        slowest = slowest.max(clock.now_ns());
    }
    proxy_clock.advance(slowest);
    // Undo the re-dispatch migrations now that the lists have executed:
    // the initiator releases its tracker reservation against the
    // *original* hint once the completion lands, so the bytes must be
    // back on that lane for the release to balance — otherwise the live
    // lane would accrue phantom backlog forever.
    for m in moved.drain(..) {
        match m {
            LaneMove::Engine { gpu, from, to, bytes } => {
                sh.driver.cost.engine_migrate(gpu, to, from, bytes)
            }
            LaneMove::Rail { node, from, to, bytes } => {
                sh.driver.cost.rail_migrate(node, to, from, bytes)
            }
        }
    }
    staged_meta.clear();
    tainted_engines.clear();
}

/// Service one `Batch` doorbell: decode the descriptor block from the
/// initiator's staging slab and dispatch every entry. Standard-CL entries
/// accumulate on one staged command list *per engine hint* (striped
/// chunks land on their assigned engines; un-chunked entries on engine
/// 0's list), each executed once per *stage* (append → close → execute);
/// immediate entries run inline. Inter-node entries accumulate on one
/// in-flight command sequence *per rail hint* (a scratch clock per rail —
/// the NICs inject concurrently, so the proxy clock advances by the
/// slowest rail, not the sum). One completion retires the whole
/// plan-group — per-chunk completions aggregate into that single token on
/// the initiator side. Returns a [`ParkedChain`] when a `WaitSignal` gate
/// is not yet met; the caller re-checks it between ring messages.
fn service_batch(msg: &Message, sh: &ProxyShared, proxy_clock: &SimClock) -> Option<ParkedChain> {
    let src_pe = msg.src_pe as usize;
    let n = msg.len as usize;
    let mut block = vec![0u8; n * DESC_SIZE];
    sh.heaps.heap(src_pe).read(msg.dst_off as usize, &mut block);
    let descs = BatchDescriptor::decode_block(&block, n)
        .unwrap_or_else(|| panic!("corrupt batch descriptor block from PE {src_pe}"));
    sh.metrics.add_batch(n);
    run_batch_from(*msg, descs, 0, PROXY_OK, 0, None, sh, proxy_clock)
}

/// Re-evaluate a parked chain's gate and, once met, dispatch the suffix.
fn resume_parked(p: ParkedChain, sh: &ProxyShared, proxy_clock: &SimClock) -> Option<ParkedChain> {
    run_batch_from(p.msg, p.descs, p.next, p.status, p.nack_mask, p.nacked_stage, sh, proxy_clock)
}

/// The batch dispatch scan, resumable at any entry index. Entries are
/// grouped by ascending chain stage (stage 0 for every non-chain entry);
/// crossing a stage boundary executes the previous stage's staged
/// lists/rail sequences first — stream order *within* the batch, one
/// doorbell for the whole chain.
#[allow(clippy::too_many_arguments)]
fn run_batch_from(
    msg: Message,
    descs: Vec<BatchDescriptor>,
    start: usize,
    mut status: u64,
    mut nack_mask: u64,
    mut nacked_stage: Option<u8>,
    sh: &ProxyShared,
    proxy_clock: &SimClock,
) -> Option<ParkedChain> {
    let src_pe = msg.src_pe as usize;
    let mut staged_cls: BTreeMap<usize, CommandList> = BTreeMap::new();
    let mut rail_clocks: BTreeMap<usize, SimClock> = BTreeMap::new();
    let mut staged_meta: BTreeMap<usize, StagedMeta> = BTreeMap::new();
    // Dead-lane re-dispatches performed for this batch, migrated back
    // after the lists execute (see `effective_lanes`).
    let mut moved: Vec<LaneMove> = Vec::new();
    // Reliability layer (ISSUE 9): bit `i` of the NACK mask means entry
    // `i` was dropped, corrupted, or failed checksum verification — it
    // was never dispatched and the initiator replays it from the payload
    // bytes still retained in its staging slab. Engines whose staged
    // lists received any replayed/delayed entry are tainted: their
    // execute-time wall observation would mix attempts, so it is
    // discarded rather than fed to the calibrator.
    let mut tainted_engines: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
    let transients = sh.fault.has_transients();
    let mut cur_stage = descs.get(start).map_or(0, |d| d.chain_stage());
    for i in start..descs.len() {
        let d = descs[i];
        let op = d.ring_op().expect("validated by decode_block");
        let stage = d.chain_stage();
        if stage != cur_stage {
            // Stage boundary: the predecessor stage's execution is the
            // completion event this stage's dispatch was triggered on.
            execute_stage(
                sh,
                proxy_clock,
                &mut staged_cls,
                &mut rail_clocks,
                &mut staged_meta,
                &mut tainted_engines,
                &mut moved,
            );
            cur_stage = stage;
        }
        // A NACKed predecessor stage leaves every later-stage triggered
        // entry un-dispatched — a successor must never fire early. The
        // entries are mask-NACKed (no fault tick, no strike: the lane
        // never saw them) so the initiator's replay re-submits the whole
        // failed suffix, gates included, in stage order.
        let suppressed = d.is_triggered() && nacked_stage.is_some_and(|ns| stage > ns);
        if op == RingOp::WaitSignal {
            // Flush same-stage staged work first so the gate observes
            // memory its predecessor stage has actually written. Gates
            // skip the fault/transient/checksum machinery: they move no
            // payload and run on no lane.
            execute_stage(
                sh,
                proxy_clock,
                &mut staged_cls,
                &mut rail_clocks,
                &mut staged_meta,
                &mut tainted_engines,
                &mut moved,
            );
            if suppressed {
                if i < crate::xfer::stream::NACK_MASK_BITS {
                    nack_mask |= 1u64 << i;
                } else {
                    status = PROXY_ERR_UNREGISTERED;
                }
                continue;
            }
            let mut word = [0u8; 8];
            sh.heaps.heap(d.pe as usize).read(d.dst_off as usize, &mut word);
            if u64::from_le_bytes(word) >= d.inline_val {
                Metrics::add(&sh.metrics.chain_triggered, 1);
                continue;
            }
            // Unmet: park the suffix (gate included). Everything before
            // `i` has fully executed, so nothing is lost across the park.
            return Some(ParkedChain { msg, descs, next: i, nack_mask, status, nacked_stage });
        }
        if suppressed {
            if i < crate::xfer::stream::NACK_MASK_BITS {
                nack_mask |= 1u64 << i;
            } else {
                status = PROXY_ERR_UNREGISTERED;
            }
            continue;
        }
        let op_no = tick_fault(sh);
        let t0 = Instant::now();
        let lanes = effective_lanes(sh, src_pe, &d, op, &mut moved);
        let data = matches!(op, RingOp::Put | RingOp::Get);
        let local = data && is_local(sh, src_pe, d.pe as usize);
        let lane_ref = if local {
            LaneRef::Engine {
                gpu: sh.driver.cost.topo.global_gpu_of(src_pe),
                engine: lanes.engine,
            }
        } else {
            LaneRef::Rail { node: sh.driver.cost.topo.node_of(src_pe), rail: lanes.rail }
        };
        // Scripted transient events fire on the op clock, then stamped
        // checksums are verified against the payload the proxy would
        // dispatch (still held in the initiator's slab). Either failure
        // NACKs the entry: no dispatch, replay from the retained bytes.
        let mut nacked = false;
        let mut delayed = false;
        if data {
            let mut forced_corrupt = false;
            if transients {
                let lane_slot = if local { lanes.engine } else { lanes.rail };
                match sh.fault.transient_at(op_no, d.len, lane_slot) {
                    Some(TransientKind::DropChunk) => {
                        Metrics::add(&sh.metrics.fault_dropped_chunks, 1);
                        nacked = true;
                    }
                    Some(TransientKind::CorruptChunk) => forced_corrupt = true,
                    Some(TransientKind::DelayChunk { delay_ns }) => {
                        Metrics::add(&sh.metrics.fault_delayed_chunks, 1);
                        delayed = true;
                        // The stall happens on the entry's lane, not the
                        // proxy thread: remote delays push the rail's
                        // in-flight sequence; local ones stall the engine
                        // dispatch on the proxy clock.
                        if local {
                            proxy_clock.advance(delay_ns as f64);
                        } else {
                            rail_clocks
                                .entry(lanes.rail)
                                .or_insert_with(SimClock::new)
                                .advance(delay_ns as f64);
                        }
                    }
                    None => {}
                }
            }
            if !nacked && d.has_checksum() {
                // A CorruptChunk event forces the mismatch *without*
                // mutating memory — the slab is also the replay source,
                // so real corruption would poison every retry.
                let sum_ok = !forced_corrupt && {
                    let mut buf = vec![0u8; d.len as usize];
                    sh.heaps.heap(src_pe).read(d.src_off as usize, &mut buf);
                    Some(payload_checksum(&buf)) == d.checksum()
                };
                if !sum_ok {
                    if forced_corrupt {
                        Metrics::add(&sh.metrics.fault_corrupted_chunks, 1);
                    }
                    Metrics::add(&sh.metrics.retry_checksum_fail, 1);
                    nacked = true;
                }
            } else if forced_corrupt {
                // No stamped checksum to catch it: the corruption goes
                // undetected and the entry dispatches as if clean (the
                // simulated payload is never actually mutated).
                Metrics::add(&sh.metrics.fault_corrupted_chunks, 1);
            }
            if nacked {
                strike_and_maybe_escalate(sh, lane_ref);
                if d.is_triggered() {
                    // The failed entry's successors must not fire: record
                    // the earliest NACKed stage so later-stage triggered
                    // entries are suppressed (see above).
                    nacked_stage = Some(nacked_stage.map_or(stage, |ns| ns.min(stage)));
                }
                if i < crate::xfer::stream::NACK_MASK_BITS {
                    nack_mask |= 1u64 << i;
                } else {
                    // Beyond the mask's reach (only possible with retry
                    // disabled, where depth is unconstrained): fall back
                    // to the hard batch error.
                    status = PROXY_ERR_UNREGISTERED;
                }
            }
        }
        let mut ok = true;
        if !nacked {
            ok = dispatch_batch_entry(
                sh,
                src_pe,
                &d,
                op,
                lanes,
                &mut staged_cls,
                &mut rail_clocks,
                proxy_clock,
            );
            if !ok {
                status = PROXY_ERR_UNREGISTERED;
                if d.is_triggered() {
                    // Even a hard-failed predecessor gates its successors.
                    nacked_stage = Some(nacked_stage.map_or(stage, |ns| ns.min(stage)));
                }
            } else {
                if data && (transients || d.has_checksum()) {
                    sh.fault.clear_strikes(lane_ref);
                }
                if d.is_triggered() && stage > 0 {
                    // A dependent entry dispatched on its predecessor
                    // stage's completion — fully host-side progress, no
                    // extra ring crossing.
                    Metrics::add(&sh.metrics.chain_triggered, 1);
                }
            }
        }
        let elapsed = t0.elapsed().as_nanos() as u64;
        sh.metrics.add_service(service_family(op), elapsed);
        // Wall half of the service-delta tables (data ops only). Chunked
        // entries carry their whole transfer's byte count in the
        // descriptor (`transfer_bytes`), so every per-chunk wall charge
        // lands in exactly the (path, size-class) row of the executor's
        // one whole-transfer model charge — tail and ramped chunks
        // included. NACKed, delayed, and replayed (`attempt > 0`)
        // entries are excluded outright: their wall times measure fault
        // handling, not the lane, and feeding them to the service-delta
        // tables or the calibrator's adaptive cells would teach the
        // planner from garbage (ISSUE 9 satellite 1).
        let clean = !nacked && !delayed && d.attempt() == 0;
        if data && clean {
            let len = d.len as usize;
            if local {
                sh.metrics
                    .add_service_wall(PathIdx::CopyEngine, d.transfer_bytes(), elapsed);
                let loc = sh.driver.cost.locality(src_pe, d.pe as usize);
                if d.standard_cl() {
                    let m = staged_meta.entry(lanes.engine).or_insert(StagedMeta {
                        bytes: 0,
                        entries: 0,
                        loc,
                        append_ns: 0,
                        first_len: len,
                    });
                    m.bytes += len as u64;
                    m.entries += 1;
                    m.append_ns += elapsed;
                } else {
                    // Immediate entries execute inline: this per-chunk
                    // wall time is both a complete lane observation and
                    // the immediate side of the CL-flavor comparison.
                    sh.calib.observe_engine(loc, len, true, elapsed as f64);
                    sh.calib
                        .observe_cl_flavor(len, true, elapsed as f64 / len.max(1) as f64);
                }
            } else {
                sh.metrics.add_service_wall(PathIdx::Nic, d.transfer_bytes(), elapsed);
                // Remote entries inject inside the scan: one per-chunk
                // rail observation each — but only for transfers that
                // actually crossed the wire. A fast-failing unregistered
                // put would otherwise teach the calibrator an absurdly
                // fast rail.
                if ok {
                    let node = sh.driver.cost.topo.node_of(src_pe);
                    if let Some(a) = sh.calib.observe_rail(node, lanes.rail, len, elapsed as f64) {
                        count_detector_action(sh, a);
                    }
                }
            }
        } else if data && local && !nacked && d.standard_cl() {
            // The entry still executes on its staged list, but its wall
            // time must not leak into that list's execute-time lane
            // observation.
            tainted_engines.insert(lanes.engine);
        }
    }
    // Final stage boundary: execute whatever the last stage accumulated
    // (for an all-stage-0 batch this is the only call — exactly the
    // pre-chain end-of-batch execution, in the same BTreeMap order).
    execute_stage(
        sh,
        proxy_clock,
        &mut staged_cls,
        &mut rail_clocks,
        &mut staged_meta,
        &mut tainted_engines,
        &mut moved,
    );
    // Every few batches worth of flavor evidence may move the learned CL
    // boundary (no-op while calibration is off or evidence is thin).
    // Completion path only: a parked chain defers this to its resume.
    sh.calib.refine_cl_boundary();
    // Hard errors outrank NACKs (an unregistered put can't be fixed by
    // replaying it); otherwise a non-empty mask asks the initiator to
    // replay exactly the failed entries.
    if status == PROXY_OK && nack_mask != 0 {
        status = encode_nack(nack_mask);
    }
    complete(sh, &msg, status);
    None
}

/// Dispatch one batch entry; returns false on a transport failure (the
/// whole batch completes with an error status).
#[allow(clippy::too_many_arguments)]
fn dispatch_batch_entry(
    sh: &ProxyShared,
    src_pe: usize,
    d: &BatchDescriptor,
    op: RingOp,
    lanes: EntryLanes,
    staged_cls: &mut BTreeMap<usize, CommandList>,
    rail_clocks: &mut BTreeMap<usize, SimClock>,
    proxy_clock: &SimClock,
) -> bool {
    let pe = d.pe as usize;
    let len = d.len as usize;
    match op {
        RingOp::Put => {
            if is_local(sh, src_pe, pe) {
                let dst = DeviceAddr { pe, offset: d.dst_off as usize };
                let src = DeviceAddr { pe: src_pe, offset: d.src_off as usize };
                sh.metrics.add_engine_dispatch(lanes.engine, len as u64);
                if d.standard_cl() {
                    staged_cls
                        .entry(lanes.engine)
                        .or_insert_with(|| sh.driver.create_command_list(src_pe))
                        .append_memory_copy(dst, src, len, None);
                } else {
                    engine_copy(sh, src_pe, dst, src, len, true, proxy_clock);
                }
                true
            } else {
                // Inter-node: the chunk's rail hint selects which NIC's
                // in-flight command sequence carries it (hint 0 for
                // un-chunked entries).
                let rail = lanes.rail;
                sh.metrics.add_rail_dispatch(rail, len as u64);
                let clock = rail_clocks.entry(rail).or_insert_with(SimClock::new);
                sh.transport
                    .put(src_pe, d.src_off as usize, pe, d.dst_off as usize, len, clock)
                    .is_ok()
            }
        }
        RingOp::Get => {
            if is_local(sh, src_pe, pe) {
                // Result lands in the initiator's staging slab.
                let dst = DeviceAddr { pe: src_pe, offset: d.dst_off as usize };
                let src = DeviceAddr { pe, offset: d.src_off as usize };
                sh.metrics.add_engine_dispatch(lanes.engine, len as u64);
                if d.standard_cl() {
                    staged_cls
                        .entry(lanes.engine)
                        .or_insert_with(|| sh.driver.create_command_list(src_pe))
                        .append_memory_copy(dst, src, len, None);
                } else {
                    engine_copy(sh, src_pe, dst, src, len, true, proxy_clock);
                }
                true
            } else {
                let rail = lanes.rail;
                sh.metrics.add_rail_dispatch(rail, len as u64);
                let clock = rail_clocks.entry(rail).or_insert_with(SimClock::new);
                sh.transport
                    .get(pe, d.src_off as usize, src_pe, d.dst_off as usize, len, clock)
                    .is_ok()
            }
        }
        RingOp::PutInline => {
            let bytes = d.inline_val.to_le_bytes();
            sh.heaps.heap(pe).write(d.dst_off as usize, &bytes[..len]);
            true
        }
        RingOp::Amo => {
            // Non-fetching only: a fetching AMO gates its caller and ships
            // its own message; a batched result would have nowhere to go.
            // The kind rides in the descriptor's low flag byte, mirroring
            // `Message::amo_kind`.
            let tag = TypeTag::from_u8(d.dtype).expect("bad batched AMO dtype");
            let kind = crate::ringbuf::message::AmoKind::from_u8((d.flags & 0xFF) as u8)
                .expect("bad batched AMO kind");
            atomic_rmw_bits(
                sh.heaps.heap(pe),
                d.dst_off as usize,
                tag,
                kind,
                d.inline_val,
                d.inline_val2,
            );
            true
        }
        other => panic!("op {other:?} is not batchable"),
    }
}

fn service(op: RingOp, msg: &Message, sh: &ProxyShared, proxy_clock: &SimClock) {
    let pe = msg.pe as usize;
    let src_pe = msg.src_pe as usize;
    let len = msg.len as usize;
    let raw = msg.flags & FLAG_RAW_PTR != 0;

    match op {
        RingOp::Nop => complete(sh, msg, PROXY_OK),

        RingOp::Put => {
            if is_local(sh, src_pe, pe) {
                // Intra-node: copy-engine path.
                if raw {
                    // Oversized fallback: private-source put staged
                    // straight into the peer heap (the engine reads
                    // mapped device memory either way).
                    // SAFETY: blocking initiator keeps the pointer alive.
                    let src =
                        unsafe { std::slice::from_raw_parts(msg.src_off as *const u8, len) };
                    sh.heaps.heap(pe).write(msg.dst_off as usize, src);
                    raw_engine_charge(sh, src_pe, pe, len, proxy_clock);
                } else {
                    engine_copy(
                        sh,
                        src_pe,
                        DeviceAddr { pe, offset: msg.dst_off as usize },
                        DeviceAddr { pe: src_pe, offset: msg.src_off as usize },
                        len,
                        sh.use_immediate_cl,
                        proxy_clock,
                    );
                }
                complete(sh, msg, PROXY_OK);
            } else {
                let dummy = SimClock::new();
                let r = if raw {
                    sh.transport
                        .put_from_ptr(msg.src_off, pe, msg.dst_off as usize, len, &dummy)
                } else {
                    sh.transport.put(
                        src_pe,
                        msg.src_off as usize,
                        pe,
                        msg.dst_off as usize,
                        len,
                        &dummy,
                    )
                };
                complete(
                    sh,
                    msg,
                    if r.is_ok() { PROXY_OK } else { PROXY_ERR_UNREGISTERED },
                );
            }
        }

        RingOp::Get => {
            if is_local(sh, src_pe, pe) {
                if raw {
                    // SAFETY: blocking initiator keeps the pointer alive.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(msg.dst_off as *mut u8, len)
                    };
                    sh.heaps.heap(pe).read(msg.src_off as usize, dst);
                    raw_engine_charge(sh, src_pe, pe, len, proxy_clock);
                } else {
                    engine_copy(
                        sh,
                        src_pe,
                        DeviceAddr { pe: src_pe, offset: msg.dst_off as usize },
                        DeviceAddr { pe, offset: msg.src_off as usize },
                        len,
                        sh.use_immediate_cl,
                        proxy_clock,
                    );
                }
                complete(sh, msg, PROXY_OK);
            } else {
                let dummy = SimClock::new();
                let r = if raw {
                    sh.transport
                        .get_to_ptr(pe, msg.src_off as usize, msg.dst_off, len, &dummy)
                } else {
                    sh.transport.get(
                        pe,
                        msg.src_off as usize,
                        src_pe,
                        msg.dst_off as usize,
                        len,
                        &dummy,
                    )
                };
                complete(
                    sh,
                    msg,
                    if r.is_ok() { PROXY_OK } else { PROXY_ERR_UNREGISTERED },
                );
            }
        }

        RingOp::PutInline => {
            let bytes = msg.inline_val.to_le_bytes();
            sh.heaps
                .heap(pe)
                .write(msg.dst_off as usize, &bytes[..len]);
            complete(sh, msg, PROXY_OK);
        }

        RingOp::Amo => {
            let tag = TypeTag::from_u8(msg.dtype).expect("bad AMO dtype");
            let kind = msg.amo_kind().expect("bad AMO kind");
            let old = atomic_rmw_bits(
                sh.heaps.heap(pe),
                msg.dst_off as usize,
                tag,
                kind,
                msg.inline_val,
                msg.inline_val2,
            );
            complete(sh, msg, old);
        }

        RingOp::PutSignal => {
            // Payload …
            // SAFETY: blocking initiator keeps the pointer alive.
            let src = unsafe { std::slice::from_raw_parts(msg.src_off as *const u8, len) };
            let dummy = SimClock::new();
            let ok = if is_local(sh, src_pe, pe) {
                sh.heaps.heap(pe).write(msg.dst_off as usize, src);
                true
            } else {
                sh.transport
                    .put_from_ptr(msg.src_off, pe, msg.dst_off as usize, len, &dummy)
                    .is_ok()
            };
            if !ok {
                complete(sh, msg, PROXY_ERR_UNREGISTERED);
                return;
            }
            // … then the signal (flags bit 0: 1 = add, 0 = set).
            let kind = if msg.flags & 1 != 0 {
                crate::ringbuf::message::AmoKind::Add
            } else {
                crate::ringbuf::message::AmoKind::Set
            };
            atomic_rmw_bits(
                sh.heaps.heap(pe),
                msg.inline_val2 as usize,
                TypeTag::U64,
                kind,
                msg.inline_val,
                0,
            );
            complete(sh, msg, PROXY_OK);
        }

        RingOp::Quiet | RingOp::Barrier => {
            // Ring FIFO order means every prior message of every PE on this
            // node is already serviced when we get here.
            complete(sh, msg, PROXY_OK);
        }

        RingOp::Batch => unreachable!("handled by proxy_loop"),
        RingOp::Shutdown => unreachable!("handled by caller"),
    }
}
