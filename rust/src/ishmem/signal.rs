//! Signaling operations: `ishmem_put_signal[_nbi]`, `ishmem_signal_fetch`,
//! `ishmemx_signal_wait_until` (OpenSHMEM §9.8.3/§9.9).
//!
//! A put-with-signal delivers the payload, *then* updates a signal word on
//! the target with set/add semantics — the ordering is the API's whole
//! point (the target spins on the signal and may then read the payload).
//! The transfer itself plans through the unified xfer engine.
//!
//! With triggered chains enabled (`chain.enable`, ISSUE 10), a batched
//! put-signal fuses into ONE `Batch` doorbell: payload chunks at stage 0,
//! the signal AMO as a stage-1 triggered descriptor the proxy releases
//! only after every chunk completes. The paper's "put; fence; signal"
//! ordering moves off the host entirely — no forced stream flush.
//! Otherwise (the default) the pre-chain paths run bit-for-bit: reachable
//! targets put via the planned path (a blocking batched flush on the
//! engine route) then update the signal word; remote targets ship one
//! `PutSignal` ring message through the xfer executor so the proxy can
//! order payload and signal on the wire — that message is its own
//! ordering fence, flushing the pending command stream first (per-PE
//! FIFO).

use crate::coordinator::metrics::Metrics;
use crate::xfer::plan::{OpKind, Route};

use super::sync::Cmp;
use super::types::{as_bytes, ShmemType};
use super::{PeCtx, SymAddr};

/// Signal update operators (SHMEM_SIGNAL_SET / SHMEM_SIGNAL_ADD).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SignalOp {
    Set,
    Add,
}

impl PeCtx {
    /// `ishmem_put_signal` — blocking put + signal update on PE `pe`.
    pub fn put_signal<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        sig: SymAddr<u64>,
        signal: u64,
        sig_op: SignalOp,
        pe: usize,
    ) {
        assert!(src.len() <= dest.len(), "put_signal overflows destination");
        assert!(pe < self.npes(), "PE {pe} out of range");
        let bytes = std::mem::size_of_val(src);
        Metrics::add(&self.rt.metrics.puts, 1);
        let plan = self.plan_to(OpKind::PutSignal, pe, bytes, 1);
        // Fused triggered chain first (no-op unless `chain.enable`): one
        // doorbell carries payload + triggered signal, ordered proxy-side.
        if self.exec_put_signal_chain(
            &plan,
            pe,
            dest.byte_offset(),
            as_bytes(src),
            sig.byte_offset(),
            signal,
            sig_op == SignalOp::Add,
        ) {
            return;
        }
        if plan.route == Route::Nic {
            self.exec_put_signal_remote(
                &plan,
                pe,
                dest.byte_offset(),
                as_bytes(src),
                sig.byte_offset(),
                signal,
                sig_op == SignalOp::Add,
            );
        } else {
            // Payload first over the planned path (blocking put orders
            // it), then the signal store.
            self.exec_put(&plan, pe, dest.byte_offset(), as_bytes(src));
            match sig_op {
                SignalOp::Set => self.atomic_set::<u64>(sig, signal, pe),
                SignalOp::Add => self.atomic_add::<u64>(sig, signal, pe),
            }
        }
    }

    /// `ishmem_signal_fetch` — read the local signal word.
    pub fn signal_fetch(&self, sig: SymAddr<u64>) -> u64 {
        self.atomic_fetch::<u64>(sig, self.pe())
    }

    /// `ishmemx_signal_wait_until`.
    pub fn signal_wait_until(&self, sig: SymAddr<u64>, cmp: Cmp, value: u64) -> u64 {
        self.wait_until::<u64>(sig, cmp, value);
        self.signal_fetch(sig)
    }
}
