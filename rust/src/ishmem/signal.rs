//! Signaling operations: `ishmem_put_signal[_nbi]`, `ishmem_signal_fetch`,
//! `ishmemx_signal_wait_until` (OpenSHMEM §9.8.3/§9.9).
//!
//! A put-with-signal delivers the payload, *then* updates a signal word on
//! the target with set/add semantics — the ordering is the API's whole
//! point (the target spins on the signal and may then read the payload).

use crate::ringbuf::{Message, RingOp};

use super::rma::{FLAG_RAW_PTR, PROXY_OK};
use super::sync::Cmp;
use super::types::ShmemType;
use super::{PeCtx, SymAddr};

/// Signal update operators (SHMEM_SIGNAL_SET / SHMEM_SIGNAL_ADD).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SignalOp {
    Set,
    Add,
}

impl PeCtx {
    /// `ishmem_put_signal` — blocking put + signal update on PE `pe`.
    pub fn put_signal<T: ShmemType>(
        &self,
        dest: SymAddr<T>,
        src: &[T],
        sig: SymAddr<u64>,
        signal: u64,
        sig_op: SignalOp,
        pe: usize,
    ) {
        let bytes = std::mem::size_of_val(src);
        if self.ipc.lookup(pe).is_some() {
            // Payload first (blocking put orders it), then the signal store.
            self.put(dest, src, pe);
            match sig_op {
                SignalOp::Set => self.atomic_set::<u64>(sig, signal, pe),
                SignalOp::Add => self.atomic_add::<u64>(sig, signal, pe),
            }
        } else {
            // Single proxied message carries payload ptr + signal update so
            // the proxy can order them on the wire (put; fence; signal).
            let mut m = Message::nop();
            m.op = RingOp::PutSignal as u8;
            m.flags = FLAG_RAW_PTR
                | if sig_op == SignalOp::Add { 1 } else { 0 };
            m.pe = pe as u32;
            m.dst_off = dest.byte_offset() as u64;
            m.src_off = src.as_ptr() as u64;
            m.len = bytes as u64;
            m.inline_val = signal;
            m.inline_val2 = sig.byte_offset() as u64;
            let status = self.proxied_blocking(m);
            assert_eq!(status, PROXY_OK, "put_signal failed");
            let registered = self.rt.transport.is_registered(pe);
            self.clock
                .advance(self.rt.cost.internode_ns(bytes + 8, registered, true));
        }
    }

    /// `ishmem_signal_fetch` — read the local signal word.
    pub fn signal_fetch(&self, sig: SymAddr<u64>) -> u64 {
        self.atomic_fetch::<u64>(sig, self.pe())
    }

    /// `ishmemx_signal_wait_until`.
    pub fn signal_wait_until(&self, sig: SymAddr<u64>, cmp: Cmp, value: u64) -> u64 {
        self.wait_until::<u64>(sig, cmp, value);
        self.signal_fetch(sig)
    }

}
