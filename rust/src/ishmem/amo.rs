//! Atomic memory operations (OpenSHMEM §9.8; paper: device & host AMO
//! support, no `work_group` variants — "they are scalar operations that
//! would not benefit from group optimizations").
//!
//! Local (load/store-reachable) targets execute real hardware atomics on
//! the peer heap — the Xe-Link semantics. Inter-node targets reverse-
//! offload an `Amo` ring message through the xfer executor
//! ([`crate::xfer::exec`], the single composer of ring messages); the
//! proxy executes the op and replies with the fetched value through the
//! completion pool.

use std::sync::atomic::Ordering;

use crate::coordinator::metrics::Metrics;
use crate::ringbuf::message::AmoKind;
use crate::sim::memory::SymHeap;

use super::types::{AmoElem, TypeTag};
use super::{PeCtx, SymAddr};

/// Execute an atomic read-modify-write on a heap word, bit-level.
/// Shared by the device path (here) and the host proxy (proxy.rs).
pub(crate) fn atomic_rmw_bits(
    heap: &SymHeap,
    offset: usize,
    tag: TypeTag,
    kind: AmoKind,
    operand: u64,
    comparand: u64,
) -> u64 {
    match tag.size() {
        4 => {
            let a = heap.atomic_u32(offset);
            let op32 = operand as u32;
            let cmp32 = comparand as u32;
            let old = match kind {
                AmoKind::Set | AmoKind::Swap => a.swap(op32, Ordering::AcqRel),
                AmoKind::Fetch => a.load(Ordering::Acquire),
                AmoKind::Add | AmoKind::FetchAdd => add_bits_u32(a, op32, tag),
                AmoKind::Inc | AmoKind::FetchInc => add_bits_u32(a, one_bits(tag) as u32, tag),
                AmoKind::And => a.fetch_and(op32, Ordering::AcqRel),
                AmoKind::Or => a.fetch_or(op32, Ordering::AcqRel),
                AmoKind::Xor => a.fetch_xor(op32, Ordering::AcqRel),
                AmoKind::CompareSwap => {
                    match a.compare_exchange(cmp32, op32, Ordering::AcqRel, Ordering::Acquire) {
                        Ok(v) | Err(v) => v,
                    }
                }
            };
            old as u64
        }
        8 => {
            let a = heap.atomic_u64(offset);
            match kind {
                AmoKind::Set | AmoKind::Swap => a.swap(operand, Ordering::AcqRel),
                AmoKind::Fetch => a.load(Ordering::Acquire),
                AmoKind::Add | AmoKind::FetchAdd => add_bits_u64(a, operand, tag),
                AmoKind::Inc | AmoKind::FetchInc => add_bits_u64(a, one_bits(tag), tag),
                AmoKind::And => a.fetch_and(operand, Ordering::AcqRel),
                AmoKind::Or => a.fetch_or(operand, Ordering::AcqRel),
                AmoKind::Xor => a.fetch_xor(operand, Ordering::AcqRel),
                AmoKind::CompareSwap => {
                    match a.compare_exchange(comparand, operand, Ordering::AcqRel, Ordering::Acquire)
                    {
                        Ok(v) | Err(v) => v,
                    }
                }
            }
        }
        other => panic!("AMO on {other}-byte type"),
    }
}

/// The bit pattern of "1" for inc on this type (1.0 for floats).
fn one_bits(tag: TypeTag) -> u64 {
    match tag {
        TypeTag::F32 => 1.0f32.to_bits() as u64,
        TypeTag::F64 => 1.0f64.to_bits(),
        _ => 1,
    }
}

/// Integer add is native; float add is a CAS loop over the bit pattern
/// (exactly how GPU atomics implement FP add on formats without native
/// support).
fn add_bits_u32(a: &std::sync::atomic::AtomicU32, operand: u32, tag: TypeTag) -> u32 {
    if tag == TypeTag::F32 {
        loop {
            let cur = a.load(Ordering::Acquire);
            let next = (f32::from_bits(cur) + f32::from_bits(operand)).to_bits();
            if a.compare_exchange_weak(cur, next, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return cur;
            }
        }
    } else {
        a.fetch_add(operand, Ordering::AcqRel)
    }
}

fn add_bits_u64(a: &std::sync::atomic::AtomicU64, operand: u64, tag: TypeTag) -> u64 {
    if tag == TypeTag::F64 {
        loop {
            let cur = a.load(Ordering::Acquire);
            let next = (f64::from_bits(cur) + f64::from_bits(operand)).to_bits();
            if a.compare_exchange_weak(cur, next, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return cur;
            }
        }
    } else {
        a.fetch_add(operand, Ordering::AcqRel)
    }
}

impl PeCtx {
    /// Core AMO dispatch. Fetching kinds return the old value.
    fn amo<T: AmoElem>(
        &self,
        addr: SymAddr<T>,
        pe: usize,
        kind: AmoKind,
        operand: T,
        comparand: T,
        fetching: bool,
    ) -> T {
        assert!(pe < self.npes());
        Metrics::add(&self.rt.metrics.amos, 1);
        let loc = self.loc_of(pe);
        if self.ipc.lookup(pe).is_some() {
            let old = atomic_rmw_bits(
                self.rt.heaps.heap(pe),
                addr.byte_offset(),
                T::TAG,
                kind,
                operand.to_bits(),
                comparand.to_bits(),
            );
            // Fire-and-forget atomics pipeline; fetching ones round-trip.
            if fetching {
                self.clock.advance(self.rt.cost.fetch_atomic_ns(loc));
            } else {
                self.clock.advance(self.rt.cost.pipelined_atomics_ns(1));
            }
            T::from_bits(old)
        } else {
            let old = self.proxied_amo(
                pe,
                addr.byte_offset(),
                T::TAG as u8,
                kind,
                operand.to_bits(),
                comparand.to_bits(),
                fetching,
            );
            T::from_bits(old)
        }
    }

    /// `ishmem_atomic_set`.
    pub fn atomic_set<T: AmoElem>(&self, addr: SymAddr<T>, value: T, pe: usize) {
        self.amo(addr, pe, AmoKind::Set, value, value, false);
    }

    /// `ishmem_atomic_fetch`.
    pub fn atomic_fetch<T: AmoElem>(&self, addr: SymAddr<T>, pe: usize) -> T {
        self.amo(addr, pe, AmoKind::Fetch, T::from_bits(0), T::from_bits(0), true)
    }

    /// `ishmem_atomic_add` (non-fetching, pipelined fire-and-forget).
    pub fn atomic_add<T: AmoElem>(&self, addr: SymAddr<T>, value: T, pe: usize) {
        self.amo(addr, pe, AmoKind::Add, value, value, false);
    }

    /// `ishmem_atomic_fetch_add`.
    pub fn atomic_fetch_add<T: AmoElem>(&self, addr: SymAddr<T>, value: T, pe: usize) -> T {
        self.amo(addr, pe, AmoKind::FetchAdd, value, value, true)
    }

    /// `ishmem_atomic_inc`.
    pub fn atomic_inc<T: AmoElem>(&self, addr: SymAddr<T>, pe: usize) {
        self.amo(addr, pe, AmoKind::Inc, T::from_bits(0), T::from_bits(0), false);
    }

    /// `ishmem_atomic_fetch_inc`.
    pub fn atomic_fetch_inc<T: AmoElem>(&self, addr: SymAddr<T>, pe: usize) -> T {
        self.amo(addr, pe, AmoKind::FetchInc, T::from_bits(0), T::from_bits(0), true)
    }

    /// `ishmem_atomic_swap`.
    pub fn atomic_swap<T: AmoElem>(&self, addr: SymAddr<T>, value: T, pe: usize) -> T {
        self.amo(addr, pe, AmoKind::Swap, value, value, true)
    }

    /// `ishmem_atomic_compare_swap` — returns the old value.
    pub fn atomic_compare_swap<T: AmoElem>(
        &self,
        addr: SymAddr<T>,
        cond: T,
        value: T,
        pe: usize,
    ) -> T {
        self.amo(addr, pe, AmoKind::CompareSwap, value, cond, true)
    }

    /// `ishmem_atomic_and` (fixed-point only, enforced at the type level
    /// by calling with integer `T`; floats would be a compile error in the
    /// real templates — here we assert).
    pub fn atomic_and<T: AmoElem>(&self, addr: SymAddr<T>, value: T, pe: usize) {
        assert!(
            !matches!(T::TAG, TypeTag::F32 | TypeTag::F64),
            "bitwise AMO on floating-point type"
        );
        self.amo(addr, pe, AmoKind::And, value, value, false);
    }

    /// `ishmem_atomic_or`.
    pub fn atomic_or<T: AmoElem>(&self, addr: SymAddr<T>, value: T, pe: usize) {
        assert!(!matches!(T::TAG, TypeTag::F32 | TypeTag::F64));
        self.amo(addr, pe, AmoKind::Or, value, value, false);
    }

    /// `ishmem_atomic_xor`.
    pub fn atomic_xor<T: AmoElem>(&self, addr: SymAddr<T>, value: T, pe: usize) {
        assert!(!matches!(T::TAG, TypeTag::F32 | TypeTag::F64));
        self.amo(addr, pe, AmoKind::Xor, value, value, false);
    }

    /// `ishmem_atomic_fetch_and`.
    pub fn atomic_fetch_and<T: AmoElem>(&self, addr: SymAddr<T>, value: T, pe: usize) -> T {
        assert!(!matches!(T::TAG, TypeTag::F32 | TypeTag::F64));
        self.amo(addr, pe, AmoKind::And, value, value, true)
    }

    /// `ishmem_atomic_fetch_or`.
    pub fn atomic_fetch_or<T: AmoElem>(&self, addr: SymAddr<T>, value: T, pe: usize) -> T {
        assert!(!matches!(T::TAG, TypeTag::F32 | TypeTag::F64));
        self.amo(addr, pe, AmoKind::Or, value, value, true)
    }

    /// `ishmem_atomic_fetch_xor`.
    pub fn atomic_fetch_xor<T: AmoElem>(&self, addr: SymAddr<T>, value: T, pe: usize) -> T {
        assert!(!matches!(T::TAG, TypeTag::F32 | TypeTag::F64));
        self.amo(addr, pe, AmoKind::Xor, value, value, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::memory::HeapRegistry;

    #[test]
    fn rmw_bits_i64_ops() {
        let reg = HeapRegistry::new(1, 4096);
        let h = reg.heap(0);
        h.atomic_u64(0).store(10, Ordering::SeqCst);
        assert_eq!(
            atomic_rmw_bits(h, 0, TypeTag::I64, AmoKind::FetchAdd, 5, 0),
            10
        );
        assert_eq!(atomic_rmw_bits(h, 0, TypeTag::I64, AmoKind::Fetch, 0, 0), 15);
        assert_eq!(
            atomic_rmw_bits(h, 0, TypeTag::I64, AmoKind::CompareSwap, 99, 15),
            15
        );
        assert_eq!(atomic_rmw_bits(h, 0, TypeTag::I64, AmoKind::Fetch, 0, 0), 99);
        // Failed CAS leaves value untouched and returns current.
        assert_eq!(
            atomic_rmw_bits(h, 0, TypeTag::I64, AmoKind::CompareSwap, 1, 15),
            99
        );
    }

    #[test]
    fn rmw_bits_f32_add_cas_loop() {
        let reg = HeapRegistry::new(1, 4096);
        let h = reg.heap(0);
        h.atomic_u32(0).store(1.5f32.to_bits(), Ordering::SeqCst);
        let old = atomic_rmw_bits(
            h,
            0,
            TypeTag::F32,
            AmoKind::FetchAdd,
            2.25f32.to_bits() as u64,
            0,
        );
        assert_eq!(f32::from_bits(old as u32), 1.5);
        let now = h.atomic_u32(0).load(Ordering::SeqCst);
        assert_eq!(f32::from_bits(now), 3.75);
    }

    #[test]
    fn rmw_bits_u32_bitwise() {
        let reg = HeapRegistry::new(1, 4096);
        let h = reg.heap(0);
        h.atomic_u32(4).store(0b1100, Ordering::SeqCst);
        atomic_rmw_bits(h, 4, TypeTag::U32, AmoKind::Xor, 0b1010, 0);
        assert_eq!(h.atomic_u32(4).load(Ordering::SeqCst), 0b0110);
        atomic_rmw_bits(h, 4, TypeTag::U32, AmoKind::Or, 0b1001, 0);
        assert_eq!(h.atomic_u32(4).load(Ordering::SeqCst), 0b1111);
        atomic_rmw_bits(h, 4, TypeTag::U32, AmoKind::And, 0b0101, 0);
        assert_eq!(h.atomic_u32(4).load(Ordering::SeqCst), 0b0101);
    }

    #[test]
    fn inc_is_typed_one() {
        let reg = HeapRegistry::new(1, 4096);
        let h = reg.heap(0);
        h.atomic_u64(8).store(2.0f64.to_bits(), Ordering::SeqCst);
        atomic_rmw_bits(h, 8, TypeTag::F64, AmoKind::Inc, 0, 0);
        assert_eq!(
            f64::from_bits(h.atomic_u64(8).load(Ordering::SeqCst)),
            3.0
        );
    }
}
