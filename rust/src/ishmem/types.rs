//! Typed API surface: the Rust rendering of ishmem's C++ function templates
//! (the paper: "a complete set of C++ function templates that supersede the
//! C11 Generic routines in the current OpenSHMEM specification").
//!
//! `ShmemType` is implemented for every OpenSHMEM standard RMA type; the
//! reduction/AMO subsets are narrowed by `ReduceElem` / `AmoElem` exactly
//! like the spec's type tables (bitwise ops: fixed-point only; AMOs: 32/64
//! bit).

/// Tag used for ring-message dispatch and AOT kernel selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum TypeTag {
    I8 = 0,
    I16 = 1,
    I32 = 2,
    I64 = 3,
    U8 = 4,
    U16 = 5,
    U32 = 6,
    U64 = 7,
    F32 = 8,
    F64 = 9,
}

impl TypeTag {
    pub fn from_u8(v: u8) -> Option<TypeTag> {
        Some(match v {
            0 => TypeTag::I8,
            1 => TypeTag::I16,
            2 => TypeTag::I32,
            3 => TypeTag::I64,
            4 => TypeTag::U8,
            5 => TypeTag::U16,
            6 => TypeTag::U32,
            7 => TypeTag::U64,
            8 => TypeTag::F32,
            9 => TypeTag::F64,
            _ => return None,
        })
    }

    pub fn size(self) -> usize {
        match self {
            TypeTag::I8 | TypeTag::U8 => 1,
            TypeTag::I16 | TypeTag::U16 => 2,
            TypeTag::I32 | TypeTag::U32 | TypeTag::F32 => 4,
            TypeTag::I64 | TypeTag::U64 | TypeTag::F64 => 8,
        }
    }

    /// AOT reduce-kernel dtype name, if the L1 kernel family covers it.
    pub fn kernel_dtype(self) -> Option<&'static str> {
        match self {
            TypeTag::F32 => Some("f32"),
            TypeTag::I32 => Some("i32"),
            TypeTag::I64 => Some("i64"),
            _ => None,
        }
    }
}

/// Element type usable with RMA/collective data movement.
///
/// # Safety
/// Implementors must be plain-old-data: every bit pattern valid, no padding
/// (we reinterpret heap bytes as `Self`).
pub unsafe trait ShmemType: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static {
    const TAG: TypeTag;
}

macro_rules! shmem_type {
    ($($t:ty => $tag:expr),* $(,)?) => {
        $(unsafe impl ShmemType for $t { const TAG: TypeTag = $tag; })*
    };
}

shmem_type! {
    i8 => TypeTag::I8,
    i16 => TypeTag::I16,
    i32 => TypeTag::I32,
    i64 => TypeTag::I64,
    u8 => TypeTag::U8,
    u16 => TypeTag::U16,
    u32 => TypeTag::U32,
    u64 => TypeTag::U64,
    f32 => TypeTag::F32,
    f64 => TypeTag::F64,
}

/// Reinterpret a typed slice as bytes (PODs only, via `ShmemType`).
pub fn as_bytes<T: ShmemType>(v: &[T]) -> &[u8] {
    // SAFETY: T is POD (ShmemType contract), lifetimes preserved.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// Reinterpret a typed mutable slice as bytes.
pub fn as_bytes_mut<T: ShmemType>(v: &mut [T]) -> &mut [u8] {
    // SAFETY: T is POD; every byte pattern is a valid T.
    unsafe {
        std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, std::mem::size_of_val(v))
    }
}

/// OpenSHMEM reduction operators (spec §9.9.4, paper §III-G.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReduceOp {
    Sum,
    Prod,
    Min,
    Max,
    And,
    Or,
    Xor,
}

impl ReduceOp {
    pub fn kernel_name(self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::Prod => "prod",
            ReduceOp::Min => "min",
            ReduceOp::Max => "max",
            ReduceOp::And => "and",
            ReduceOp::Or => "or",
            ReduceOp::Xor => "xor",
        }
    }

    pub fn is_bitwise(self) -> bool {
        matches!(self, ReduceOp::And | ReduceOp::Or | ReduceOp::Xor)
    }
}

/// Types that participate in reductions, with a native combine used as the
/// small-size fast path and as the oracle for the XLA kernel path.
pub trait ReduceElem: ShmemType {
    /// Whether `op` is defined for this type (bitwise ⇒ fixed-point only).
    fn supports(op: ReduceOp) -> bool;
    fn combine(op: ReduceOp, a: Self, b: Self) -> Self;
}

macro_rules! reduce_int {
    ($($t:ty),*) => {$(
        impl ReduceElem for $t {
            fn supports(_op: ReduceOp) -> bool { true }
            fn combine(op: ReduceOp, a: Self, b: Self) -> Self {
                match op {
                    ReduceOp::Sum => a.wrapping_add(b),
                    ReduceOp::Prod => a.wrapping_mul(b),
                    ReduceOp::Min => a.min(b),
                    ReduceOp::Max => a.max(b),
                    ReduceOp::And => a & b,
                    ReduceOp::Or => a | b,
                    ReduceOp::Xor => a ^ b,
                }
            }
        }
    )*};
}

reduce_int!(i8, i16, i32, i64, u8, u16, u32, u64);

macro_rules! reduce_float {
    ($($t:ty),*) => {$(
        impl ReduceElem for $t {
            fn supports(op: ReduceOp) -> bool { !op.is_bitwise() }
            fn combine(op: ReduceOp, a: Self, b: Self) -> Self {
                match op {
                    ReduceOp::Sum => a + b,
                    ReduceOp::Prod => a * b,
                    ReduceOp::Min => a.min(b),
                    ReduceOp::Max => a.max(b),
                    _ => panic!("bitwise reduction on floating-point type"),
                }
            }
        }
    )*};
}

reduce_float!(f32, f64);

/// Types usable with atomic memory operations (32/64-bit words).
///
/// # Safety
/// `Self` must be exactly 4 or 8 bytes and bit-convertible to u32/u64.
pub unsafe trait AmoElem: ShmemType {
    fn to_bits(self) -> u64;
    fn from_bits(bits: u64) -> Self;
}

macro_rules! amo_elem {
    ($($t:ty),*) => {$(
        unsafe impl AmoElem for $t {
            fn to_bits(self) -> u64 { self as u64 }
            fn from_bits(bits: u64) -> Self { bits as $t }
        }
    )*};
}

amo_elem!(i32, i64, u32, u64);

unsafe impl AmoElem for f32 {
    fn to_bits(self) -> u64 {
        self.to_bits() as u64
    }
    fn from_bits(bits: u64) -> Self {
        f32::from_bits(bits as u32)
    }
}

unsafe impl AmoElem for f64 {
    fn to_bits(self) -> u64 {
        self.to_bits()
    }
    fn from_bits(bits: u64) -> Self {
        f64::from_bits(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_roundtrip_and_size() {
        for (t, sz) in [
            (TypeTag::I8, 1),
            (TypeTag::U16, 2),
            (TypeTag::F32, 4),
            (TypeTag::F64, 8),
        ] {
            assert_eq!(TypeTag::from_u8(t as u8), Some(t));
            assert_eq!(t.size(), sz);
        }
    }

    #[test]
    fn kernel_dtypes_match_artifacts() {
        assert_eq!(TypeTag::F32.kernel_dtype(), Some("f32"));
        assert_eq!(TypeTag::I64.kernel_dtype(), Some("i64"));
        assert_eq!(TypeTag::F64.kernel_dtype(), None);
    }

    #[test]
    fn as_bytes_roundtrip() {
        let v: Vec<u32> = vec![1, 2, 0xDEADBEEF];
        let b = as_bytes(&v);
        assert_eq!(b.len(), 12);
        assert_eq!(&b[8..12], &0xDEADBEEFu32.to_le_bytes());
    }

    #[test]
    fn float_bitwise_unsupported() {
        assert!(!<f32 as ReduceElem>::supports(ReduceOp::Xor));
        assert!(<i32 as ReduceElem>::supports(ReduceOp::Xor));
    }

    #[test]
    fn combine_semantics() {
        assert_eq!(i32::combine(ReduceOp::Min, -3, 4), -3);
        assert_eq!(u8::combine(ReduceOp::Sum, 250, 10), 4); // wrapping
        assert_eq!(i64::combine(ReduceOp::Xor, 0b1100, 0b1010), 0b0110);
        assert_eq!(f32::combine(ReduceOp::Max, 1.5, -2.0), 1.5);
    }

    #[test]
    fn amo_bits_roundtrip() {
        assert_eq!(<f32 as AmoElem>::from_bits(AmoElem::to_bits(1.25f32)), 1.25);
        assert_eq!(<i64 as AmoElem>::from_bits((-5i64) as u64), -5);
        assert_eq!(<u32 as AmoElem>::from_bits(7), 7u32);
    }
}
