//! Data-parallel training harness — the end-to-end driver composing all
//! three layers (DESIGN.md E12):
//!
//!   L2 transformer `train_step` (AOT HLO) runs per PE through PJRT →
//!   per-tensor gradients land in a symmetric buffer → `ishmem_reduce`
//!   all-reduces them across PEs (running the L1 Pallas reduce kernel on
//!   full chunks) → each PE applies an identical SGD update.
//!
//! Python never runs; the artifacts are the only Python residue.

pub mod data;
pub mod trainer;

pub use data::TokenStream;
pub use trainer::{train_data_parallel, TrainConfig, TrainReport};
