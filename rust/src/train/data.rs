//! Synthetic training corpus: a deterministic, *learnable* token stream.
//!
//! Pure-uniform tokens have ln(V) irreducible loss — useless for an
//! end-to-end "loss goes down" signal. This stream instead draws from a
//! seeded order-1 Markov chain with skewed transitions, so a model can
//! learn real structure while every PE reproduces its own shard
//! deterministically (shard = (seed, pe)).

use crate::util::rng::Rng;

pub struct TokenStream {
    vocab: usize,
    rng: Rng,
    state: usize,
    /// Per-state transition "hot" targets (skewed mass).
    hot: Vec<usize>,
}

impl TokenStream {
    pub fn new(vocab: usize, seed: u64, pe: usize) -> Self {
        assert!(vocab >= 4);
        // The chain structure depends only on `seed` (shared across PEs);
        // the sampling noise depends on the shard.
        let mut structure_rng = Rng::new(seed);
        let hot = (0..vocab)
            .map(|_| structure_rng.below(vocab as u64) as usize)
            .collect();
        TokenStream {
            vocab,
            rng: Rng::new(seed ^ 0x9E37_79B9 ^ ((pe as u64) << 32)),
            state: 0,
            hot,
        }
    }

    /// Next token: 75% follow the hot edge, 25% uniform noise.
    pub fn next_token(&mut self) -> i32 {
        let t = if self.rng.f64() < 0.75 {
            self.hot[self.state]
        } else {
            self.rng.below(self.vocab as u64) as usize
        };
        self.state = t;
        t as i32
    }

    /// Fill one (batch, seq) token matrix, flattened row-major.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        (0..batch * seq).map(|_| self.next_token()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_shard() {
        let mut a = TokenStream::new(64, 9, 3);
        let mut b = TokenStream::new(64, 9, 3);
        let mut c = TokenStream::new(64, 9, 4);
        let (ba, bb, bc) = (a.batch(2, 16), b.batch(2, 16), c.batch(2, 16));
        assert_eq!(ba, bb);
        assert_ne!(ba, bc, "different PEs must see different shards");
    }

    #[test]
    fn tokens_in_range_and_structured() {
        let mut s = TokenStream::new(32, 1, 0);
        let toks = s.batch(4, 64);
        assert!(toks.iter().all(|&t| (0..32).contains(&t)));
        // Structure check: bigram repetition above uniform chance.
        let mut follows = std::collections::HashMap::new();
        for w in toks.windows(2) {
            *follows.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let max_bigram = follows.values().max().copied().unwrap_or(0);
        assert!(max_bigram >= 3, "stream looks uniform");
    }
}
