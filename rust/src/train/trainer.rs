//! The data-parallel trainer (e2e driver, DESIGN.md E12).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::ishmem::heap::RESERVED_BYTES;
use crate::ishmem::{Ishmem, IshmemConfig, PeCtx, ReduceOp, TeamId};
use crate::runtime::{HostTensor, ModelManifest, XlaRuntime};
use crate::train::data::TokenStream;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model config name from the manifest ("tiny", "small", …).
    pub model: String,
    /// Data-parallel degree (PEs).
    pub pes: usize,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub log_every: usize,
    /// Evaluate held-out loss every N steps (0 = never).
    pub eval_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "small".into(),
            pes: 4,
            steps: 100,
            lr: 0.25,
            seed: 42,
            log_every: 10,
            eval_every: 25,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<(usize, f32)>,
    pub eval_losses: Vec<(usize, f32)>,
    pub first_loss: f32,
    pub final_loss: f32,
    pub tokens_per_step: usize,
    pub wall_seconds: f64,
    pub param_count: usize,
    pub xla_reduce_calls: u64,
}

/// Run data-parallel training; returns PE 0's report.
pub fn train_data_parallel(cfg: &TrainConfig) -> Result<TrainReport> {
    let rt = XlaRuntime::load_default().context("loading artifacts")?;
    let model = rt.manifest().model(&cfg.model)?.clone();

    // Symmetric heap must fit grads + loss cell (params live host-side),
    // plus the staging slab the runtime carves from the heap top.
    let grad_bytes = model.param_count * 4;
    let base = IshmemConfig::with_npes(cfg.pes);
    let ish_cfg = IshmemConfig {
        heap_bytes: RESERVED_BYTES + grad_bytes + (1 << 20) + base.staging_slab_bytes,
        ..base
    };
    let ish = Ishmem::new(ish_cfg)?;
    ish.attach_runtime(rt.clone());

    let t0 = std::time::Instant::now();
    let cfg2 = cfg.clone();
    let model2 = model.clone();
    let rt2 = rt.clone();
    let mut reports = ish.launch(move |ctx| train_pe(ctx, &cfg2, &model2, &rt2));
    let wall = t0.elapsed().as_secs_f64();
    let snap = ish.metrics.snapshot();
    ish.shutdown();

    let mut report = reports.swap_remove(0)?;
    report.wall_seconds = wall;
    report.xla_reduce_calls = snap.xla_reduce_calls;
    Ok(report)
}

fn train_pe(
    ctx: &mut PeCtx,
    cfg: &TrainConfig,
    model: &ModelManifest,
    rt: &Arc<XlaRuntime>,
) -> Result<TrainReport> {
    let npes = ctx.npes();
    let p = model.param_count;

    // ---- parameters: identical init everywhere (same seed through the
    // AOT init_params HLO — deterministic on the CPU backend).
    let mut params: Vec<HostTensor> = rt
        .execute(&model.init_file, vec![HostTensor::scalar_i32(cfg.seed as i32)])
        .context("init_params")?;

    // ---- symmetric buffers: flat gradient vector + per-PE loss cell.
    let grads_sym = ctx.calloc::<f32>(p);
    let loss_sym = ctx.calloc::<f32>(npes);

    let mut stream = TokenStream::new(model.vocab, cfg.seed, ctx.pe());
    // Held-out eval: same corpus *structure* (same Markov chain), disjoint
    // sampling shard — measures generalization within the language rather
    // than loss on a different language.
    let mut eval_stream = TokenStream::new(model.vocab, cfg.seed, 10_000 + ctx.pe());

    let mut losses = Vec::new();
    let mut eval_losses = Vec::new();
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;

    for step in 0..cfg.steps {
        // L2 compute: loss + grads on my shard.
        let tokens = stream.batch(model.batch, model.seq_len);
        let mut args = params.clone();
        args.push(HostTensor::from_i32(
            vec![model.batch, model.seq_len],
            &tokens,
        ));
        let out = rt
            .execute(&model.train_step_file, args)
            .with_context(|| format!("train_step at step {step}"))?;
        let my_loss = out[0].scalar_f32();
        anyhow::ensure!(my_loss.is_finite(), "loss diverged at step {step}");

        // Flatten grads into the symmetric buffer.
        let mut flat = Vec::with_capacity(p);
        for g in &out[1..] {
            flat.extend_from_slice(&g.to_f32());
        }
        debug_assert_eq!(flat.len(), p);
        ctx.write_local(grads_sym, &flat);

        // Gradient allreduce THROUGH ishmem (runs the Pallas kernel), plus
        // the loss mean for logging.
        ctx.reduce(grads_sym, grads_sym, p, ReduceOp::Sum, TeamId::WORLD);
        ctx.p(loss_sym.at(ctx.pe()), my_loss, 0);
        let reduced = ctx.read_local_vec(grads_sym);

        // SGD: identical update on every PE (grads now identical).
        let scale = cfg.lr / npes as f32;
        let mut off = 0usize;
        for t in params.iter_mut() {
            let n = t.elems();
            let mut vals = t.to_f32();
            for (v, g) in vals.iter_mut().zip(&reduced[off..off + n]) {
                *v -= scale * g;
            }
            *t = HostTensor::from_f32(t.dims.clone(), &vals);
            off += n;
        }

        // Mean loss across PEs (PE 0 gathered everyone's loss cells).
        ctx.barrier_all();
        let mean_loss = if ctx.pe() == 0 {
            let cells = ctx.read_local_vec(loss_sym);
            cells.iter().sum::<f32>() / npes as f32
        } else {
            my_loss
        };
        if step == 0 {
            first_loss = mean_loss;
        }
        last_loss = mean_loss;
        if ctx.pe() == 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            losses.push((step, mean_loss));
            eprintln!("[train pe0] step {step:4}  loss {mean_loss:.4}");
        }

        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 && ctx.pe() == 0 {
            let toks = eval_stream.batch(model.batch, model.seq_len);
            let mut args = params.clone();
            args.push(HostTensor::from_i32(
                vec![model.batch, model.seq_len],
                &toks,
            ));
            let ev = rt.execute(&model.eval_loss_file, args)?[0].scalar_f32();
            eval_losses.push((step + 1, ev));
            eprintln!("[train pe0] step {:4}  eval-loss {ev:.4}", step + 1);
        }
        ctx.barrier_all();
    }

    Ok(TrainReport {
        losses,
        eval_losses,
        first_loss,
        final_loss: last_loss,
        tokens_per_step: model.batch * model.seq_len * npes,
        wall_seconds: 0.0,
        param_count: p,
        xla_reduce_calls: 0,
    })
}
