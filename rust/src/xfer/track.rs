//! Unified completion tracking for one PE (plan→execute→**complete**).
//!
//! Replaces the ad-hoc `nbi_horizon_ns` / `outstanding_proxy_nbi` cells
//! that used to live directly on `PeCtx`. Outstanding state on the
//! device-initiated path:
//!
//! * a **modeled completion horizon**: non-blocking transfers move data
//!   eagerly (Rust borrow safety) but their modeled duration completes
//!   later — `ishmem_quiet` collapses the horizon into the PE timeline;
//! * a **fire-and-forget proxy count**: scalar `p` and other
//!   posted-without-completion ring messages that `quiet` must flush with
//!   one ring round trip (FIFO order makes one `Quiet` message prove all
//!   earlier ones were serviced, paper §III-D);
//! * the **per-engine byte backlog** this PE reserved on its GPU's copy
//!   engines for still-outstanding NBI transfers (released engine-by-
//!   engine at `quiet`) — what makes the planner occupancy-aware and
//!   keeps striped placement balanced;
//! * the **per-rail byte backlog** this PE reserved on its node's NIC
//!   rails for still-outstanding remote NBI transfers (released rail-by-
//!   rail at `quiet`) — the remote-path twin of the engine ledger;
//! * an **outstanding-chunk ledger**: a striped NBI transfer issues many
//!   chunks but completes as *one* unit — every chunk defers into the
//!   same horizon, and the ledger counts how many chunks that single
//!   completion still covers (drained at `quiet`).
//!
//! The tracker is per-PE (`!Sync` like `PeCtx` itself), so plain `Cell`s
//! and a `RefCell` map suffice.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;

/// Per-PE outstanding-completion state for the xfer engine.
#[derive(Debug, Default)]
pub struct CompletionTracker {
    /// Modeled device-timeline instant when every outstanding non-blocking
    /// transfer is complete.
    horizon_ns: Cell<f64>,
    /// Number of fire-and-forget proxied messages since the last flush.
    outstanding_ff: Cell<u64>,
    /// Copy-engine bytes this PE has reserved, per engine slot of its
    /// GPU, for still-outstanding NBI transfers (released at `quiet`).
    engine_bytes: RefCell<BTreeMap<usize, u64>>,
    /// NIC-rail bytes this PE has reserved, per rail slot of its node,
    /// for still-outstanding remote NBI transfers (released at `quiet`) —
    /// the remote-path twin of the per-engine ledger above.
    rail_bytes: RefCell<BTreeMap<usize, u64>>,
    /// Chunks of striped NBI transfers whose single aggregated completion
    /// is still outstanding.
    outstanding_chunks: Cell<u64>,
    /// Replay ledger (reliability layer): entries re-posted after a NACK,
    /// since the last drain.
    replayed_entries: Cell<u64>,
    /// Per-attempt completion histogram: `attempt_hist[a]` counts batches
    /// that completed cleanly on attempt `a` (0 = first transmission).
    /// Sized by the descriptor's 4-bit attempt field.
    attempt_hist: RefCell<[u64; 16]>,
    /// Dependency links of triggered chains (ISSUE 10) released via the
    /// proxy's pending-trigger table since the last drain: a depth-*d*
    /// chain contributes `d − 1` links. Chains retire blocking, so the
    /// ledger is a released-work count, not an outstanding one — `quiet`
    /// still drains it so per-launch accounting cannot leak.
    chain_links: Cell<u64>,
}

impl CompletionTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that an NBI transfer's modeled completion lands at `done_at`
    /// on the PE timeline.
    pub fn defer(&self, done_at_ns: f64) {
        self.horizon_ns.set(self.horizon_ns.get().max(done_at_ns));
    }

    /// Current modeled completion horizon (0 when nothing is outstanding).
    pub fn horizon_ns(&self) -> f64 {
        self.horizon_ns.get()
    }

    /// Collapse the horizon (quiet): returns it and resets to zero.
    pub fn take_horizon_ns(&self) -> f64 {
        self.horizon_ns.replace(0.0)
    }

    /// Record one fire-and-forget proxied message.
    pub fn note_fire_and_forget(&self) {
        self.outstanding_ff.set(self.outstanding_ff.get() + 1);
    }

    /// Take the fire-and-forget count (quiet flush), resetting it.
    pub fn take_fire_and_forget(&self) -> u64 {
        self.outstanding_ff.replace(0)
    }

    /// Record `bytes` of engine-queue backlog reserved on `engine` for an
    /// NBI transfer.
    pub fn note_engine_bytes(&self, engine: usize, bytes: u64) {
        *self.engine_bytes.borrow_mut().entry(engine).or_insert(0) += bytes;
    }

    /// Total reserved engine backlog across engines (reports/tests).
    pub fn engine_bytes_total(&self) -> u64 {
        self.engine_bytes.borrow().values().sum()
    }

    /// Take the reserved backlog per engine (quiet releases each on the
    /// owning GPU's queue), resetting the ledger.
    pub fn take_engine_bytes(&self) -> Vec<(usize, u64)> {
        std::mem::take(&mut *self.engine_bytes.borrow_mut())
            .into_iter()
            .collect()
    }

    /// Record `bytes` of NIC-rail backlog reserved on `rail` for a remote
    /// NBI transfer.
    pub fn note_rail_bytes(&self, rail: usize, bytes: u64) {
        *self.rail_bytes.borrow_mut().entry(rail).or_insert(0) += bytes;
    }

    /// Total reserved rail backlog across rails (reports/tests).
    pub fn rail_bytes_total(&self) -> u64 {
        self.rail_bytes.borrow().values().sum()
    }

    /// Take the reserved backlog per rail (quiet releases each on the
    /// owning node's rail set), resetting the ledger.
    pub fn take_rail_bytes(&self) -> Vec<(usize, u64)> {
        std::mem::take(&mut *self.rail_bytes.borrow_mut())
            .into_iter()
            .collect()
    }

    /// Record `n` chunks of a striped NBI transfer whose aggregated
    /// completion is still outstanding.
    pub fn note_chunks(&self, n: u64) {
        self.outstanding_chunks.set(self.outstanding_chunks.get() + n);
    }

    /// Chunks still covered by outstanding aggregated completions.
    pub fn outstanding_chunks(&self) -> u64 {
        self.outstanding_chunks.get()
    }

    /// Drain the chunk ledger (quiet), returning how many chunks the
    /// collapsed horizon just completed.
    pub fn take_chunks(&self) -> u64 {
        self.outstanding_chunks.replace(0)
    }

    /// Record `n` entries re-posted after a NACK (replay loop).
    pub fn note_replayed(&self, n: u64) {
        self.replayed_entries.set(self.replayed_entries.get() + n);
    }

    /// Entries replayed since the last drain.
    pub fn replayed_entries(&self) -> u64 {
        self.replayed_entries.get()
    }

    /// Drain the replay counter.
    pub fn take_replayed(&self) -> u64 {
        self.replayed_entries.replace(0)
    }

    /// Record a batch completing cleanly on replay attempt `attempt`
    /// (0 = first transmission; saturates into the last bucket).
    pub fn note_attempt(&self, attempt: u32) {
        let mut h = self.attempt_hist.borrow_mut();
        let i = (attempt as usize).min(h.len() - 1);
        h[i] += 1;
    }

    /// The per-attempt completion histogram (index = attempt number).
    pub fn attempt_hist(&self) -> [u64; 16] {
        *self.attempt_hist.borrow()
    }

    /// Record `n` dependency links of a submitted triggered chain
    /// (depth − 1 for a depth-*d* chain).
    pub fn note_chain_links(&self, n: u64) {
        self.chain_links.set(self.chain_links.get() + n);
    }

    /// Chain links released since the last drain.
    pub fn chain_links(&self) -> u64 {
        self.chain_links.get()
    }

    /// Drain the chain-link ledger (quiet / launch exit).
    pub fn take_chain_links(&self) -> u64 {
        self.chain_links.replace(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn horizon_is_max_and_resets() {
        let t = CompletionTracker::new();
        assert_eq!(t.horizon_ns(), 0.0);
        t.defer(100.0);
        t.defer(50.0);
        assert_eq!(t.horizon_ns(), 100.0);
        assert_eq!(t.take_horizon_ns(), 100.0);
        assert_eq!(t.horizon_ns(), 0.0);
    }

    #[test]
    fn fire_and_forget_counts_and_drains() {
        let t = CompletionTracker::new();
        t.note_fire_and_forget();
        t.note_fire_and_forget();
        assert_eq!(t.take_fire_and_forget(), 2);
        assert_eq!(t.take_fire_and_forget(), 0);
    }

    #[test]
    fn engine_bytes_accumulate_per_engine_and_drain() {
        let t = CompletionTracker::new();
        t.note_engine_bytes(2, 4096);
        t.note_engine_bytes(5, 100);
        t.note_engine_bytes(2, 4);
        assert_eq!(t.engine_bytes_total(), 4200);
        let drained = t.take_engine_bytes();
        assert_eq!(drained, vec![(2, 4100), (5, 100)]);
        assert_eq!(t.engine_bytes_total(), 0);
        assert!(t.take_engine_bytes().is_empty());
    }

    #[test]
    fn rail_bytes_accumulate_per_rail_and_drain() {
        let t = CompletionTracker::new();
        t.note_rail_bytes(1, 1 << 20);
        t.note_rail_bytes(3, 100);
        t.note_rail_bytes(1, 24);
        assert_eq!(t.rail_bytes_total(), (1 << 20) + 124);
        let drained = t.take_rail_bytes();
        assert_eq!(drained, vec![(1, (1 << 20) + 24), (3, 100)]);
        assert_eq!(t.rail_bytes_total(), 0);
        assert!(t.take_rail_bytes().is_empty());
    }

    #[test]
    fn replay_ledger_counts_and_histograms() {
        let t = CompletionTracker::new();
        assert_eq!(t.replayed_entries(), 0);
        t.note_replayed(3);
        t.note_replayed(1);
        assert_eq!(t.replayed_entries(), 4);
        assert_eq!(t.take_replayed(), 4);
        assert_eq!(t.replayed_entries(), 0);
        t.note_attempt(0);
        t.note_attempt(0);
        t.note_attempt(2);
        t.note_attempt(99); // saturates into the last bucket
        let h = t.attempt_hist();
        assert_eq!((h[0], h[2], h[15]), (2, 1, 1));
        assert_eq!(h.iter().sum::<u64>(), 4);
    }

    #[test]
    fn chain_link_ledger_counts_and_drains() {
        let t = CompletionTracker::new();
        assert_eq!(t.chain_links(), 0);
        t.note_chain_links(3); // a depth-4 chain
        t.note_chain_links(1); // a depth-2 chain
        assert_eq!(t.chain_links(), 4);
        assert_eq!(t.take_chain_links(), 4);
        assert_eq!(t.chain_links(), 0);
    }

    #[test]
    fn chunk_ledger_aggregates_into_one_completion() {
        let t = CompletionTracker::new();
        t.note_chunks(5);
        t.note_chunks(3);
        assert_eq!(t.outstanding_chunks(), 8);
        assert_eq!(t.take_chunks(), 8);
        assert_eq!(t.outstanding_chunks(), 0);
    }
}
