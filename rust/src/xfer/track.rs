//! Unified completion tracking for one PE (plan→execute→**complete**).
//!
//! Replaces the ad-hoc `nbi_horizon_ns` / `outstanding_proxy_nbi` cells
//! that used to live directly on `PeCtx`. Two kinds of outstanding state
//! exist on the device-initiated path:
//!
//! * a **modeled completion horizon**: non-blocking transfers move data
//!   eagerly (Rust borrow safety) but their modeled duration completes
//!   later — `ishmem_quiet` collapses the horizon into the PE timeline;
//! * a **fire-and-forget proxy count**: scalar `p`, non-fetching remote
//!   AMOs and other posted-without-completion ring messages that `quiet`
//!   must flush with one ring round trip (FIFO order makes one `Quiet`
//!   message prove all earlier ones were serviced, paper §III-D).
//!
//! The tracker is per-PE (`!Sync` like `PeCtx` itself), so plain `Cell`s
//! suffice.

use std::cell::Cell;

/// Per-PE outstanding-completion state for the xfer engine.
#[derive(Debug, Default)]
pub struct CompletionTracker {
    /// Modeled device-timeline instant when every outstanding non-blocking
    /// transfer is complete.
    horizon_ns: Cell<f64>,
    /// Number of fire-and-forget proxied messages since the last flush.
    outstanding_ff: Cell<u64>,
    /// Copy-engine bytes this PE has reserved on its GPU's engine queue
    /// for still-outstanding NBI transfers (released at `quiet`, when the
    /// horizon collapses). Feeds the planner's occupancy-aware estimate.
    engine_bytes: Cell<u64>,
}

impl CompletionTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that an NBI transfer's modeled completion lands at `done_at`
    /// on the PE timeline.
    pub fn defer(&self, done_at_ns: f64) {
        self.horizon_ns.set(self.horizon_ns.get().max(done_at_ns));
    }

    /// Current modeled completion horizon (0 when nothing is outstanding).
    pub fn horizon_ns(&self) -> f64 {
        self.horizon_ns.get()
    }

    /// Collapse the horizon (quiet): returns it and resets to zero.
    pub fn take_horizon_ns(&self) -> f64 {
        self.horizon_ns.replace(0.0)
    }

    /// Record one fire-and-forget proxied message.
    pub fn note_fire_and_forget(&self) {
        self.outstanding_ff.set(self.outstanding_ff.get() + 1);
    }

    /// Take the fire-and-forget count (quiet flush), resetting it.
    pub fn take_fire_and_forget(&self) -> u64 {
        self.outstanding_ff.replace(0)
    }

    /// Record `bytes` of engine-queue backlog reserved for an NBI transfer.
    pub fn note_engine_bytes(&self, bytes: u64) {
        self.engine_bytes.set(self.engine_bytes.get() + bytes);
    }

    /// Take the reserved engine-backlog bytes (quiet releases them on the
    /// owning GPU's queue), resetting to zero.
    pub fn take_engine_bytes(&self) -> u64 {
        self.engine_bytes.replace(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn horizon_is_max_and_resets() {
        let t = CompletionTracker::new();
        assert_eq!(t.horizon_ns(), 0.0);
        t.defer(100.0);
        t.defer(50.0);
        assert_eq!(t.horizon_ns(), 100.0);
        assert_eq!(t.take_horizon_ns(), 100.0);
        assert_eq!(t.horizon_ns(), 0.0);
    }

    #[test]
    fn fire_and_forget_counts_and_drains() {
        let t = CompletionTracker::new();
        t.note_fire_and_forget();
        t.note_fire_and_forget();
        assert_eq!(t.take_fire_and_forget(), 2);
        assert_eq!(t.take_fire_and_forget(), 0);
    }

    #[test]
    fn engine_bytes_accumulate_and_drain() {
        let t = CompletionTracker::new();
        t.note_engine_bytes(4096);
        t.note_engine_bytes(100);
        assert_eq!(t.take_engine_bytes(), 4196);
        assert_eq!(t.take_engine_bytes(), 0);
    }
}
