//! Batched command streams: one ring doorbell per plan-group.
//!
//! The reverse-offload path used to pay one 64-byte ring message and one
//! proxy service *per device-initiated op* (§III-D) — which dominates
//! latency exactly in the small-message regime the copy-engine route is
//! supposed to win. A [`CmdStream`] amortizes that: executors append
//! [`TransferPlan`]-shaped entries as [`BatchDescriptor`]s, payloads are
//! staged through the PE's symmetric-heap [`StagingSlab`] (turning
//! raw-pointer transfers into heap-offset transfers that run on real
//! `DeviceAddr` command lists), and the stream flushes as a single
//! `RingOp::Batch` message pointing at a descriptor block in the slab.
//!
//! Flush triggers:
//! * **capacity** — pending depth reaches `max_batch_depth` (fire-and-
//!   forget flush; the batch completion is tracked so `quiet` can drain);
//! * **blocking completion** — a blocking op appends its own entry and
//!   flushes synchronously (which also pushes out any pending NBI
//!   entries, preserving per-PE FIFO order);
//! * **non-batchable op** — anything that still ships its own ring
//!   message (fetching AMOs, put-signal, quiet itself) flushes the
//!   pending stream first so the ring stays FIFO-consistent.
//!
//! Slab reclamation is batch-granular: every payload stage and every
//! descriptor block is one slab claim; when a batch's completion arrives
//! the claims are released and the arena rewinds once idle.
//!
//! [`TransferPlan`]: super::plan::TransferPlan
//! [`BatchDescriptor`]: crate::ringbuf::BatchDescriptor
//! [`StagingSlab`]: crate::sos::heap::StagingSlab

use std::cell::RefCell;
use std::collections::VecDeque;

use crate::coordinator::metrics::Metrics;
use crate::ishmem::PeCtx;
use crate::ringbuf::{BatchDescriptor, CompletionToken, Message, RingOp, DESC_SIZE};

use super::exec::{PROXY_ERR_UNREGISTERED, PROXY_OK};

/// Pending (not yet flushed) batch entry: the wire descriptor plus the
/// number of staging-slab claims its payload holds.
#[derive(Debug)]
struct PendingEntry {
    desc: BatchDescriptor,
    slab_claims: usize,
}

/// A posted-but-unretired batch: its completion token and the slab claims
/// (entries + descriptor block) to release when it completes.
#[derive(Debug)]
struct InflightBatch {
    token: CompletionToken,
    slab_claims: usize,
}

/// Per-(initiator, work-group) command stream. `PeCtx` is `!Sync` and all
/// work-group variants funnel through their leader's `PeCtx`, so plain
/// interior mutability suffices.
#[derive(Debug)]
pub struct CmdStream {
    max_depth: usize,
    /// Size-adaptive batch depth: a descriptor whose payload is at or
    /// above this size flushes its plan-group immediately after the
    /// append, so a big chunk never waits behind a filling batch of tiny
    /// entries (deep batches for small descriptors, shallow auto-flush
    /// for large ones).
    large_flush_bytes: usize,
    pending: RefCell<Vec<PendingEntry>>,
    inflight: RefCell<VecDeque<InflightBatch>>,
}

impl CmdStream {
    pub fn new(max_depth: usize) -> Self {
        assert!(max_depth >= 1, "batch depth must be at least 1");
        CmdStream {
            max_depth,
            large_flush_bytes: usize::MAX,
            pending: RefCell::new(Vec::new()),
            inflight: RefCell::new(VecDeque::new()),
        }
    }

    /// Set the size-adaptive flush boundary (`stream.large_flush_bytes`).
    pub fn with_large_flush_bytes(mut self, bytes: usize) -> Self {
        self.large_flush_bytes = bytes.max(1);
        self
    }

    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    pub fn large_flush_bytes(&self) -> usize {
        self.large_flush_bytes
    }

    pub fn pending_len(&self) -> usize {
        self.pending.borrow().len()
    }

    pub fn inflight_len(&self) -> usize {
        self.inflight.borrow().len()
    }
}

/// Slab headroom preserved above every payload claim so a descriptor
/// block for a full plan-group can always be written at flush time —
/// the single source for `stream_slab_alloc`/`stream_slab_try_alloc`
/// and for `IshmemConfig::chunk_max_bytes()`'s double-buffer cap.
pub(crate) fn slab_headroom_bytes(max_depth: usize) -> usize {
    (max_depth + 1) * DESC_SIZE + 192
}

impl PeCtx {
    // ------------------------------------------------------ slab staging --

    /// Claim `len` slab bytes for a payload or a get-result, retiring
    /// finished (and, if needed, pending) batches to make room. `None`
    /// means the payload cannot fit the slab at all — the caller falls
    /// back to the raw-pointer path.
    pub(crate) fn stream_slab_alloc(&self, len: usize) -> Option<usize> {
        let headroom = slab_headroom_bytes(self.stream.max_depth());
        let need = len.checked_add(64 + headroom)?;
        if need > self.slab.capacity() {
            // Can never fit, even empty: take the raw-pointer fallback
            // without stalling on in-flight batches or force-flushing the
            // pending plan-group (the fallback's own ring post flushes
            // pending for FIFO).
            return None;
        }
        if self.slab.available() < need {
            self.stream_drain_inflight();
            if self.slab.available() < need && self.stream.pending_len() > 0 {
                self.stream_flush_ff();
                self.stream_drain_inflight();
            }
        }
        if self.slab.available() < need {
            return None;
        }
        self.slab.try_alloc(len)
    }

    /// Claim `len` slab bytes *without* force-flushing the pending
    /// plan-group: retires finished batches only. Used by the chunked-get
    /// window builder, whose own pending descriptors must stay pending
    /// (flushing them fire-and-forget would release their slab claims
    /// before the single-threaded PE copies the results out). `None`
    /// simply ends the current window.
    pub(crate) fn stream_slab_try_alloc(&self, len: usize) -> Option<usize> {
        let headroom = slab_headroom_bytes(self.stream.max_depth());
        let need = len.checked_add(64 + headroom)?;
        if need > self.slab.capacity() {
            return None;
        }
        if self.slab.available() < need {
            self.stream_drain_inflight();
        }
        if self.slab.available() < need {
            return None;
        }
        self.slab.try_alloc(len)
    }

    /// Stage a private (raw-pointer) payload into the slab: after this
    /// copy the transfer is heap-offset shaped and can execute on real
    /// `DeviceAddr` command lists. Charges the HBM-local staging copy.
    pub(crate) fn stream_stage_payload(&self, src: &[u8]) -> Option<usize> {
        let off = self.stream_stage_payload_uncharged(src)?;
        self.clock.advance(self.rt.cost.staging_copy_ns(src.len()));
        Some(off)
    }

    /// Stage without the modeled charge — the striped chunk pipeline
    /// overlaps staging of chunk *k+1* with engine execution of chunk
    /// *k*, so chunked executors charge one aggregate pipeline time
    /// instead of serial per-chunk copies.
    pub(crate) fn stream_stage_payload_uncharged(&self, src: &[u8]) -> Option<usize> {
        let off = self.stream_slab_alloc(src.len())?;
        self.rt.heaps.heap(self.pe()).write(off, src);
        Some(off)
    }

    // ----------------------------------------------------------- append ---

    /// Append a descriptor to the stream (`slab_claims` = claims its
    /// payload holds; 0 for entries whose source already lives in the
    /// user heap). Charges the descriptor write; flushes fire-and-forget
    /// when the plan-group reaches capacity *or* the entry's payload is
    /// large (`stream.large_flush_bytes` — the size-adaptive depth: tiny
    /// descriptors batch deep, a big chunk ships at once).
    pub(crate) fn stream_append(&self, desc: BatchDescriptor, slab_claims: usize) {
        self.clock.advance(self.rt.cost.staging_copy_ns(DESC_SIZE));
        let large = desc.len as usize >= self.stream.large_flush_bytes();
        let depth = {
            let mut pending = self.stream.pending.borrow_mut();
            pending.push(PendingEntry { desc, slab_claims });
            pending.len()
        };
        if depth >= self.stream.max_depth() || large {
            self.stream_flush_ff();
        }
    }

    // ----------------------------------------------------------- flushes --

    /// Write the pending descriptors into a slab block and post the one
    /// `Batch` doorbell. Returns the completion token and the batch's
    /// total slab claims; `None` when nothing is pending.
    fn stream_post_batch(&self) -> Option<(CompletionToken, usize)> {
        let entries: Vec<PendingEntry> = {
            let mut pending = self.stream.pending.borrow_mut();
            if pending.is_empty() {
                return None;
            }
            pending.drain(..).collect()
        };
        let n = entries.len();
        let block_len = n * DESC_SIZE;
        let block_off = match self.slab.try_alloc(block_len) {
            Some(off) => off,
            None => {
                // Slab pinned by in-flight batches: retire them (FIFO —
                // always safe) and retry; the headroom invariant makes
                // this allocation infallible afterwards.
                self.stream_drain_inflight();
                self.slab
                    .try_alloc(block_len)
                    .expect("staging slab cannot hold a descriptor block")
            }
        };
        let descs: Vec<BatchDescriptor> = entries.iter().map(|e| e.desc).collect();
        self.rt
            .heaps
            .heap(self.pe())
            .write(block_off, &BatchDescriptor::encode_block(&descs));
        let claims: usize = entries.iter().map(|e| e.slab_claims).sum::<usize>() + 1;

        let pool = self.completions().clone();
        let token = pool.alloc();
        let mut m = Message::nop();
        m.op = RingOp::Batch as u8;
        m.src_pe = self.pe() as u32;
        m.dst_off = block_off as u64;
        m.len = n as u64;
        m.completion = token.index;
        Metrics::add(&self.rt.metrics.ring_messages, 1);
        self.ring().send(m);
        Some((token, claims))
    }

    /// Fire-and-forget flush: one doorbell for the pending plan-group;
    /// completion is tracked in-flight so `quiet` (or a later capacity
    /// squeeze) retires it. Charges one ring post for the whole group.
    pub(crate) fn stream_flush_ff(&self) {
        if let Some((token, slab_claims)) = self.stream_post_batch() {
            self.stream
                .inflight
                .borrow_mut()
                .push_back(InflightBatch { token, slab_claims });
            self.clock.advance(self.rt.cost.ring_post_ns());
        }
    }

    /// A batch completion carries one status for the whole plan-group;
    /// decode the failure like `check_proxy_status` does for single ops.
    /// (NBI entries surface here at the next flush/quiet/fence — later
    /// than the offending op, the price of fire-and-forget batching.)
    fn check_batch_status(&self, status: u64) {
        match status {
            PROXY_OK => {}
            PROXY_ERR_UNREGISTERED => panic!(
                "batched submission failed: a target heap in the plan-group is not \
                 FI_HMEM-registered (strict mode)"
            ),
            other => panic!("batched submission failed: proxy status {other}"),
        }
    }

    /// Blocking flush: retire everything in flight, post the pending
    /// plan-group, and wait for its completion. The ring is FIFO per
    /// node, so on return every earlier entry of this PE is serviced.
    /// Callers charge the modeled route cost themselves.
    pub(crate) fn stream_flush_blocking(&self) {
        self.stream_drain_inflight();
        if let Some((token, slab_claims)) = self.stream_post_batch() {
            let status = self.completions().wait(token);
            self.check_batch_status(status);
            for _ in 0..slab_claims {
                self.slab.release();
            }
        }
    }

    /// Wait out all in-flight batches and release their slab claims.
    /// Returns how many batches were retired (no modeled charge here —
    /// `quiet` charges one ring round trip for the drain).
    pub(crate) fn stream_drain_inflight(&self) -> usize {
        let mut drained = 0;
        loop {
            let batch = match self.stream.inflight.borrow_mut().pop_front() {
                Some(b) => b,
                None => break,
            };
            let status = self.completions().wait(batch.token);
            self.check_batch_status(status);
            for _ in 0..batch.slab_claims {
                self.slab.release();
            }
            drained += 1;
        }
        drained
    }

    /// `quiet`/`fence` entry point: push out the pending plan-group and
    /// retire every batch in flight. Returns whether anything was
    /// outstanding (the caller charges the drain round trip if so).
    pub(crate) fn stream_quiet_drain(&self) -> bool {
        self.stream_flush_ff();
        self.stream_drain_inflight() > 0
    }

    /// Retire every outstanding batch *and* return this PE's reserved
    /// per-engine and per-rail backlog to the shared `CostModel` (each
    /// engine/rail slot releases exactly what striped NBI transfers
    /// reserved on it). The cleanup half of `quiet` (no modeled charges)
    /// — shared with launch exit so per-PE state can never leak into the
    /// machine across launches.
    pub(crate) fn drain_outstanding(&self) -> bool {
        let drained = self.stream_quiet_drain();
        let gpu = self.my_gpu();
        for (engine, bytes) in self.track.take_engine_bytes() {
            self.rt.cost.engine_release_on(gpu, engine, bytes);
        }
        let node = self.node();
        for (rail, bytes) in self.track.take_rail_bytes() {
            self.rt.cost.rail_release_on(node, rail, bytes);
        }
        self.track.take_chunks();
        drained
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_state_starts_empty() {
        let s = CmdStream::new(16);
        assert_eq!(s.max_depth(), 16);
        assert_eq!(s.pending_len(), 0);
        assert_eq!(s.inflight_len(), 0);
    }

    #[test]
    #[should_panic(expected = "at least 1")]
    fn zero_depth_rejected() {
        CmdStream::new(0);
    }

    #[test]
    fn large_flush_boundary_defaults_off_and_clamps() {
        let s = CmdStream::new(8);
        assert_eq!(s.large_flush_bytes(), usize::MAX);
        let s = CmdStream::new(8).with_large_flush_bytes(256 << 10);
        assert_eq!(s.large_flush_bytes(), 256 << 10);
        // 0 would flush every append including empty AMOs; clamp to ≥1.
        let s = CmdStream::new(8).with_large_flush_bytes(0);
        assert_eq!(s.large_flush_bytes(), 1);
    }
}
